"""Online-protocol engine throughput: seed host loop vs. the device-resident
engine (repro.sim), on the identical replay stream.

Five comparisons, recorded to ``BENCH_protocol.json`` at the repo root
(schema documented in README.md):

  baseline_protocol_single — one 4-policy protocol run: host Python loop
      (T x policies device round-trips) vs. one jitted lax.scan per policy.
  baseline_sweep           — the paper-style multi-seed sweep: host loop
      over seeds vs. one vmap over PRNG keys (the headline speedup; the
      seed path *cannot* amortize seeds).
  neuralucb_slice_step     — Algorithm 1's hot loop for one slice
      (DECIDE -> feedback lookup -> rank-k UPDATE): host decide()/update()
      round-trip vs. the fused jit step.
  neuralucb_scan_vs_stepped — a full Algorithm 1 run on the same fixed
      training schedule: the PR-1-style per-slice runner
      (~ceil(steps/32)+2 dispatches + one sync per slice) vs. the
      single-dispatch lax.scan (DESIGN.md §8.4).
  neuralucb_sweep          — the paper's multi-seed NeuralUCB sweep:
      sequential per-slice runs (the only way the stepped runner can
      sweep) vs. one vmapped scan dispatch sharded over local devices.
  scenario_scan            — the non-stationary scenario engine's cost
      (DESIGN.md §9): the same Algorithm-1 scan with and without the
      price_shock per-slice transforms (acceptance bound <= 1.3x).
  scenario_adaptivity      — what forgetting buys: vanilla vs the
      recency-forgetting variant (replay_rho=0.4) on the price_shock
      and arm_outage scenarios, seed-mean avg reward per config.
  nucb_fused_decide        — the fused DECIDE op (kernels.nucb_decide)
      per backend (jnp / pallas) with an analytic v5e roofline; off-TPU
      the pallas entry records the self-dispatched jnp reference.
  ainv_rebuild             — the streamed blocked-Cholesky A^-1 rebuild
      (kernels.ainv_rebuild) per backend, same schema.
  nucb_fused_update        — the fused rank-k Woodbury A^-1 update
      (kernels.nucb_update, single launch, A^-1 VMEM-resident) per
      backend, same schema.
  policy_zoo_sweep         — the unified runtime's policy axis
      (DESIGN.md §10): a 5-policy × seed sweep as ONE sharded dispatch
      vs per-policy sweeps and sequential per-seed runs, with
      per-policy decisions/s.
  experiment_compile       — the declarative ExperimentSpec layer's
      overhead (DESIGN.md §11): spec→plan compile wall time and the
      planned device-dispatch count vs the minimal hand-wired count
      (must be 0 extra dispatches) for the driver presets.
  physical_pool            — the arm pool's measured-vs-analytic
      calibration (DESIGN.md §16.3): REAL jitted decode steps for the
      two smallest zoo configs vs the host roofline lower bound, the
      measured/analytic ratio recorded per backend, plus the
      physical_pool preset's pool-compile stats and provenance
      (checksum, chips, $/token). ``--pool-tiny`` swaps in the reduced
      configs (CI-sized; the ``reduced`` flag marks the reshape).

The sweep-shaped sections (neuralucb_sweep, policy_zoo_sweep) are
expressed through the same ExperimentSpec presets the driver runs
(``bench_nucb_sweep`` / ``bench_zoo_sweep``), so the bench measures the
exact code path a ``--preset`` invocation takes.

  python -m benchmarks.bench_protocol [--n-samples N] [--n-slices T]
      [--seeds S] [--nucb-samples N] [--nucb-slices T] [--nucb-seeds S]
      [--nucb-train-steps K] [--nucb-batch B] [--scen-samples N]
      [--scen-slices T] [--scen-seeds S] [--zoo-samples N]
      [--zoo-slices T] [--zoo-seeds S] [--pool-only] [--pool-tiny]
      [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cached
from repro.core.baselines import (
    EmpiricalGreedy,
    FixedActionPolicy,
    RandomPolicy,
)
from repro.core.policy import NeuralUCBRouter
from repro.core.protocol import run_protocol
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim
from repro.experiments import (
    compile_spec,
    make_preset,
    run_plan,
    spec_hash,
)
from repro.sim import (
    DeviceNeuralUCB,
    DeviceReplayEnv,
    ForgettingConfig,
    as_bandit_policy,
    fixed_policy,
    greedy_policy,
    make_policy,
    random_policy,
    run_baseline_sweep,
    run_neuralucb_device,
    run_neuralucb_sweep,
    run_policy_device,
    run_policy_sweep,
)
from repro.sim.engine import (
    _cum_valid,
    _nucb_slice_step,
    _policy_scan,
    _tables,
)
from repro.core import neuralucb as NU
from repro.core.utilitynet import init_utilitynet
from repro.kernels.ainv_rebuild import ainv_rebuild
from repro.kernels.nucb_update import nucb_update
from repro.kernels.backend import PALLAS, resolve_backend
from repro.roofline.model import roofline_terms
from repro.sim.policies import _decide_ucb

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                         os.pardir))

ROOT_OUT = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_protocol.json")


def _host_policies(env: RouterBenchSim, seed: int):
    return {
        "random": RandomPolicy(env.K, seed=seed),
        "min-cost": FixedActionPolicy(env.min_cost_action()),
        "max-quality": FixedActionPolicy(env.max_quality_action()),
        "greedy": EmpiricalGreedy(env.K),
    }


def _device_policies(env: DeviceReplayEnv):
    return [
        random_policy(env.K),
        fixed_policy(env.min_cost_action(), "min-cost"),
        fixed_policy(env.max_quality_action(), "max-quality"),
        greedy_policy(env.K),
    ]


def _median_wall(fn, reps: int = 3) -> float:
    """Median-of-reps wall time (protocol runs are seconds-long; medians
    absorb scheduler noise better than means)."""
    walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        walls.append(time.perf_counter() - t0)
    return sorted(walls)[len(walls) // 2]


def bench_neuralucb_runs(n_samples: int = 1200, n_slices: int = 32,
                         n_seeds: int = 4, train_steps: int = 32,
                         batch_size: int = 32) -> Dict:
    """Full-Algorithm-1 comparisons on one fixed training schedule: the
    per-slice runner vs. the single-dispatch scan, single-run and as a
    multi-seed sweep (DESIGN.md §8.4). The workload is the paper's
    protocol shape at reduced stream size — what's measured here is
    engine structure (dispatch count, sweep amortization, device
    sharding), which the full stream only dilutes with model FLOPs."""
    henv = RouterBenchSim(seed=0, n_samples=n_samples, n_slices=n_slices)
    denv = DeviceReplayEnv.from_host(henv)
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)

    def stepped_run(seed: int):
        return DeviceNeuralUCB(denv, cfg, seed=seed, batch_size=batch_size
                               ).run(train_steps=train_steps, scan=False)

    def scan_run():
        return run_neuralucb_device(denv, cfg, seed=0,
                                    train_steps=train_steps,
                                    batch_size=batch_size)

    # the sweep leg IS the driver's preset path: spec -> plan -> run
    sweep_plan = compile_spec(
        make_preset("bench_nucb_sweep", {
            "data.n_samples": n_samples, "data.n_slices": n_slices,
            "seeds": list(range(n_seeds)),
            "train.train_steps": train_steps,
            "train.batch_size": batch_size}),
        env=denv, host_env=henv)

    def sweep_run():
        return run_plan(sweep_plan)

    stepped_run(0)                      # compile all three paths
    scan_run()
    sweep_run()

    stepped_single = _median_wall(lambda: stepped_run(0))
    scan_single = _median_wall(scan_run)
    stepped_sweep = _median_wall(
        lambda: [stepped_run(s) for s in range(n_seeds)])
    scan_sweep = _median_wall(sweep_run)
    shape = {"n_samples": n_samples, "n_slices": n_slices,
             "train_steps": train_steps, "batch_size": batch_size}
    return {
        "neuralucb_scan_vs_stepped": dict(
            shape, stepped_s=stepped_single, scan_s=scan_single,
            speedup=stepped_single / scan_single),
        "neuralucb_sweep": dict(
            shape, n_seeds=n_seeds, stepped_s=stepped_sweep,
            scan_s=scan_sweep, speedup=stepped_sweep / scan_sweep,
            n_devices=len(jax.local_devices())),
    }


def bench_scenarios(n_samples: int = 6000, n_slices: int = 12,
                    n_seeds: int = 6, train_steps: int = 32,
                    batch_size: int = 32) -> Dict:
    """Non-stationary scenario engine (DESIGN.md §9), two questions:

    * ``scenario_scan`` — what does the declarative per-slice transform
      path COST? The same Algorithm-1 scan with and without the
      price_shock transforms (per-slice quality/cost/reward re-derive +
      availability handling); the ISSUE acceptance bound is <= 1.3x.
    * ``scenario_adaptivity`` — what does forgetting BUY? Seed-mean avg
      reward of vanilla NeuralUCB vs the recency-forgetting variant
      (replay_rho=0.4, §9.2) under the price_shock and arm_outage
      scenarios, each config one vmapped sweep dispatch.
    """
    henv = RouterBenchSim(seed=0, n_samples=n_samples, n_slices=n_slices)
    denv = DeviceReplayEnv.from_host(henv)
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
    kw = dict(train_steps=train_steps, batch_size=batch_size,
              ucb_backend="jnp")

    def stationary():
        return run_neuralucb_device(denv, cfg, seed=0, **kw)

    def scenario():
        return run_neuralucb_device(denv, cfg, seed=0,
                                    scenario="price_shock", **kw)

    stationary()                        # compile both traces
    scenario()
    stat_s = _median_wall(stationary)
    scen_s = _median_wall(scenario)

    adaptivity = {}
    fg = ForgettingConfig(replay_rho=0.4)
    for scen in ("price_shock", "arm_outage"):
        row = {}
        for name, f in (("vanilla", None), ("forgetting", fg)):
            skw = dict(seeds=range(n_seeds), train_steps=train_steps,
                       batch_size=batch_size, scenario=scen)
            if f is not None:
                skw["forgetting"] = f
            sw = run_neuralucb_sweep(denv, cfg, **skw)
            row[name] = float(sw["avg_reward"][0, :, 1:].mean())
        row["delta"] = row["forgetting"] - row["vanilla"]
        adaptivity[scen] = row

    shape = {"n_samples": n_samples, "n_slices": n_slices,
             "train_steps": train_steps, "batch_size": batch_size}
    return {
        "scenario_scan": dict(
            shape, scenario="price_shock", stationary_s=stat_s,
            scenario_s=scen_s, overhead=scen_s / stat_s),
        "scenario_adaptivity": dict(
            shape, n_seeds=n_seeds, replay_rho=0.4,
            n_devices=len(jax.local_devices()), **adaptivity),
    }


def bench_policy_zoo(n_samples: int = 1200, n_slices: int = 8,
                     n_seeds: int = 4, train_steps: int = 32,
                     batch_size: int = 32) -> Dict:
    """The unified runtime's policy axis (DESIGN.md §10): a 5-policy
    (neuralucb / linucb / neural_ts / eps_greedy / boltzmann) × seed
    sweep as ONE sharded dispatch vs (a) each policy's own one-dispatch
    sweep and (b) sequential per-seed single runs — per-policy
    decisions/s and the sweep speedup recorded per policy."""
    henv = RouterBenchSim(seed=0, n_samples=n_samples, n_slices=n_slices)
    denv = DeviceReplayEnv.from_host(henv)
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
    names = ("neuralucb", "linucb", "neural_ts", "eps_greedy", "boltzmann")
    policies = {n: make_policy(n, denv, cfg, ucb_backend="jnp")
                for n in names}
    kw = dict(train_steps=train_steps, batch_size=batch_size)

    # the one-dispatch zoo leg IS the driver's preset path
    zoo_plan = compile_spec(
        make_preset("bench_zoo_sweep", {
            "data.n_samples": n_samples, "data.n_slices": n_slices,
            "seeds": list(range(n_seeds)),
            "train.train_steps": train_steps,
            "train.batch_size": batch_size}),
        env=denv, host_env=henv)
    assert zoo_plan.n_dispatches == 1

    def zoo():
        return run_plan(zoo_plan)

    zoo()                               # compile the one-dispatch program
    zoo_s = _median_wall(zoo)

    per_policy = {}
    sum_sweep = 0.0
    sum_seq = 0.0
    decisions = n_seeds * henv.n
    for name in names:
        pol, hyp = policies[name]

        def psweep(name=name, pol=pol, hyp=hyp):
            return run_policy_sweep(denv, {name: (pol, hyp)},
                                    seeds=range(n_seeds), **kw)

        def pseq(pol=pol, hyp=hyp):
            for s in range(n_seeds):
                run_policy_device(denv, pol, hyp, seed=s, **kw)

        psweep()                        # compile both reference paths
        pseq()
        ps = _median_wall(psweep)
        sq = _median_wall(pseq, reps=1)
        per_policy[name] = {
            "sweep_s": ps, "sequential_s": sq, "speedup": sq / ps,
            "decisions_per_s": decisions / ps,
        }
        sum_sweep += ps
        sum_seq += sq

    return {"policy_zoo_sweep": {
        "n_samples": n_samples, "n_slices": n_slices,
        "train_steps": train_steps, "batch_size": batch_size,
        "n_seeds": n_seeds, "n_policies": len(names),
        "n_devices": len(jax.local_devices()),
        "zoo_dispatch_s": zoo_s,
        "sum_single_policy_sweeps_s": sum_sweep,
        "sequential_runs_s": sum_seq,
        "speedup_vs_sequential": sum_seq / zoo_s,
        "per_policy": per_policy,
    }}


def bench_nucb_kernels(batch: int = 4096, buffer_rows: int = 8192,
                       reps: int = 10) -> Dict:
    """Per-backend microbenchmarks of the two fused neural hot-path ops
    (DESIGN.md §14.1): the fused DECIDE (trunk forward → augment →
    g^T A^-1 g bonus → gated masked argmax) and the streamed
    blocked-Cholesky A^-1 REBUILD, each against the plain-XLA path, with
    an analytic roofline per op (TPU v5e constants). Off-TPU the
    "pallas" entries record what the self-dispatch resolves to — the
    jnp reference (``mode: "reference"``); on TPU they are the compiled
    kernels (``mode: "compiled"``). Interpret mode is never timed: it
    measures the interpreter, not the kernel."""
    cfg = UtilityNetConfig()
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    params = init_utilitynet(ks[0], cfg)
    batch_in = {
        "x_emb": jax.random.normal(ks[1], (batch, cfg.emb_dim)),
        "x_feat": jax.random.normal(ks[2], (batch, cfg.feat_dim)),
        "domain": jax.random.randint(ks[3], (batch,), 0,
                                     cfg.num_domains),
    }
    F = cfg.ucb_feature_dim
    ainv = jnp.eye(F) * 0.5
    beta, tau_g = jnp.float32(1.0), jnp.float32(0.5)
    pallas_mode = ("compiled" if resolve_backend(None) == PALLAS
                   else "reference")

    def decide(backend):
        fn = jax.jit(lambda p, ai, b: _decide_ucb(p, ai, b, beta, tau_g,
                                                  cfg, backend))
        jax.block_until_ready(fn(params, ainv, batch_in))   # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(params, ainv, batch_in))
        wall = (time.perf_counter() - t0) / reps
        return {"decisions_per_s": batch / wall, "wall_s": wall}

    dec = {"jnp": dict(decide("jnp"), mode="xla"),
           "pallas": dict(decide("pallas"), mode=pallas_mode)}

    # analytic decide roofline: one context GEMM + per-action trunk2 /
    # u-head / quadratic form (C=d_text+d_feat, H=d_hidden, D=d_last)
    C = cfg.d_text + cfg.d_feat
    H, D, K = cfg.d_hidden, cfg.d_last, cfg.num_actions
    dec_flops = 2.0 * batch * (C * H + K * (H * D + D * D + 4 * D))
    dec_bytes = 4.0 * (batch * (cfg.emb_dim + cfg.feat_dim + C + F + 2)
                       + C * H + K * H + H * D + F * F)

    gs = jax.random.normal(jax.random.PRNGKey(7), (buffer_rows, F)) * 0.3
    w = jnp.ones((buffer_rows,)).at[: buffer_rows // 4].set(0.0)

    def rebuild(backend):
        # gs / w stay jit ARGUMENTS — a zero-arg closure lets XLA
        # constant-fold the whole rebuild at compile time
        if backend == "pallas":
            fn = jax.jit(lambda g, ww: ainv_rebuild(g, 1.0, weights=ww))
        else:
            fn = jax.jit(lambda g, ww: NU.rebuild_ainv(g, 1.0,
                                                       weights=ww))
        jax.block_until_ready(fn(gs, w))                    # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(gs, w))
        wall = (time.perf_counter() - t0) / reps
        return {"rebuilds_per_s": 1.0 / wall,
                "rows_per_s": buffer_rows / wall, "wall_s": wall}

    reb = {"jnp": dict(rebuild("jnp"), mode="xla"),
           "pallas": dict(rebuild("pallas"), mode=pallas_mode)}

    # Gram accumulation + blocked Cholesky + triangular inverse + L^-T L^-1
    reb_flops = 2.0 * buffer_rows * F * F + 2.0 * F ** 3
    reb_bytes = 4.0 * (buffer_rows * (F + 1) + 3 * F * F)

    # the fused rank-k Woodbury UPDATE (kernels.nucb_update): one slice's
    # worth of rows folded into A^-1 in a single launch, A^-1 resident
    gs_upd = gs[:batch]

    def update(backend):
        if backend == "pallas":
            fn = jax.jit(lambda ai, g: nucb_update(ai, g))
        else:
            fn = jax.jit(lambda ai, g: NU.woodbury_update(ai, g))
        jax.block_until_ready(fn(ainv, gs_upd))             # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(ainv, gs_upd))
        wall = (time.perf_counter() - t0) / reps
        return {"updates_per_s": 1.0 / wall,
                "rows_per_s": batch / wall, "wall_s": wall}

    upd = {"jnp": dict(update("jnp"), mode="xla"),
           "pallas": dict(update("pallas"), mode=pallas_mode)}

    # per 128-row block of k rows: u = G A^-1 (2kF^2), S = I + u G^T
    # (2k^2 F), Cholesky k^3/3, x = S^-1 u (2k^2 F), downdate u^T x
    # (2kF^2) -> aggregated over batch rows
    bk = 128.0
    upd_flops = batch * (4.0 * F * F + 4.0 * bk * F + bk * bk / 3.0)
    upd_bytes = 4.0 * (batch * F + 2.0 * F * F)

    return {
        "nucb_fused_decide": {
            "batch": batch, "num_actions": K, "feature_dim": F,
            "d_hidden": H, "d_last": D,
            "backends": dec,
            "speedup_pallas_vs_jnp": (dec["jnp"]["wall_s"]
                                      / dec["pallas"]["wall_s"]),
            "roofline": dict(
                roofline_terms(dec_flops, dec_bytes, 0.0),
                flops=dec_flops, bytes=dec_bytes),
        },
        "ainv_rebuild": {
            "buffer_rows": buffer_rows, "feature_dim": F,
            "backends": reb,
            "speedup_pallas_vs_jnp": (reb["jnp"]["wall_s"]
                                      / reb["pallas"]["wall_s"]),
            "roofline": dict(
                roofline_terms(reb_flops, reb_bytes, 0.0),
                flops=reb_flops, bytes=reb_bytes),
        },
        "nucb_fused_update": {
            "update_rows": batch, "feature_dim": F, "block_k": int(bk),
            "backends": upd,
            "speedup_pallas_vs_jnp": (upd["jnp"]["wall_s"]
                                      / upd["pallas"]["wall_s"]),
            "roofline": dict(
                roofline_terms(upd_flops, upd_bytes, 0.0),
                flops=upd_flops, bytes=upd_bytes),
        },
    }


def bench_physical_pool(configs=("mamba2_130m", "whisper_medium"),
                        batch: int = 4, steps: int = 6,
                        tiny: bool = False) -> Dict:
    """Physical-arm-pool calibration + compile stats (DESIGN.md §16.3).

    For the two smallest real configs, times REAL jitted decode steps
    (the serving engine's own decode program) against the host
    roofline's analytic lower bound; ``measured_over_analytic`` is the
    per-backend efficiency de-rating that ``ArmPoolSpec(calibrate=True)``
    folds into the pool tables. Also compiles the ``physical_pool``
    preset's pool and records its wall time + provenance manifest
    (the crc32 checksum is the cross-process determinism pin)."""
    from repro.armpool import build_pool_env, measured_ratio
    from repro.configs import get_config

    backend = jax.default_backend()
    calibration: Dict[str, Dict] = {}
    for name in configs:
        cfg = get_config(name)
        if tiny:
            cfg = cfg.reduced()
        r = measured_ratio(cfg, batch, steps=steps)
        calibration[name] = {
            "params_b": cfg.param_count() / 1e9,
            "backends": {backend: {
                "measured_step_s": r["step_s"],
                "analytic_step_s": r["analytic_step_s"],
                "measured_over_analytic": r["ratio"],
                "init_s": r["init_s"],
                "compile_s": r["compile_s"],
            }},
        }

    spec = make_preset("physical_pool")
    t0 = time.perf_counter()
    henv, pool = build_pool_env(spec.armpool, spec.data)
    pool_compile_s = time.perf_counter() - t0
    return {"physical_pool": {
        "backend": backend, "batch": batch, "steps": steps,
        "reduced": bool(tiny),
        "calibration": calibration,
        "pool": dict(pool.manifest(), n_samples=int(henv.n),
                     compile_s=pool_compile_s),
    }}


def bench_experiment_compile(n_samples: int = 1500,
                             n_slices: int = 3) -> Dict:
    """The ExperimentSpec layer's cost (DESIGN.md §11): per driver
    preset, the spec→plan compile wall time (registry resolution, axis
    validation, dispatch grouping — the replay env is injected so data
    generation is excluded) and the planned device-dispatch count
    pinned against the MINIMAL hand-wired count (one
    ``run_policy_sweep`` per (scenario × forgetting-variant) group).
    ``extra_dispatches`` must be 0: expressing a study as a spec may
    cost microseconds of host time but never an extra compiled
    program."""
    henv = RouterBenchSim(seed=0, n_samples=n_samples, n_slices=n_slices)
    denv = DeviceReplayEnv.from_host(henv)
    # LITERAL hand-derived run_policy_sweep call counts per preset —
    # independent of the compiler's grouping code, so a grouping
    # regression shows up as extra_dispatches != 0 here:
    #   fig2_beta_sweep: 1 scenario (stationary) x 1 variant      = 1
    #   scenario_suite:  2 scenarios x (vanilla + forget) variants = 4
    #   ci_smoke:        3 scenarios x (vanilla + forget) variants = 6
    hand_wired_calls = {"fig2_beta_sweep": 1, "scenario_suite": 4,
                        "ci_smoke": 6}
    out: Dict[str, Dict] = {}
    for name, hand_wired in hand_wired_calls.items():
        spec = make_preset(name)
        compile_s = _median_wall(
            lambda: compile_spec(spec, env=denv, host_env=henv), reps=5)
        plan = compile_spec(spec, env=denv, host_env=henv)
        out[name] = {
            "spec_hash": spec_hash(spec),
            "compile_s": compile_s,
            "n_dispatches": plan.n_dispatches,
            "hand_wired_dispatches": hand_wired,
            "extra_dispatches": plan.n_dispatches - hand_wired,
            "n_cells": plan.n_cells,
        }
    return {"experiment_compile": out}


def bench_offline_pretrain(henv: RouterBenchSim, denv: DeviceReplayEnv,
                           corpus_size: int = 20_000,
                           pretrain_steps: int = 512,
                           train_steps: int = 32) -> Dict:
    """Lifecycle bench (DESIGN.md §13.3): offline pretraining wall time
    per hooked policy plus the warm-vs-cold cumulative-reward delta
    over the EARLY window — the first 20% of slices of the
    paper_table1-shaped stream, where a warm start must pay off before
    the cold online learner catches up. Warm and cold runs share the
    seed (identical PRNG streams); warm additionally drops the slice-0
    uniform warm-up (``warm_slice=False``) so the pretrained state
    routes from the first request."""
    from repro.data.logged import replay_corpus
    from repro.sim import pretrain_policy_state

    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1],
                           num_actions=henv.K)
    corpus = replay_corpus(denv, corpus_size, seed=0)
    n_early = max(1, int(denv.mask.shape[0]) // 5)
    out: Dict = {"corpus_size": corpus.n, "pretrain_steps": pretrain_steps,
                 "early_slices": n_early, "policies": {}}
    for name in ("neuralucb", "sup_winrate", "linucb"):
        pol_c, hyp = make_policy(name, denv, cfg)
        try:
            pol_w, hyp = make_policy(name, denv, cfg, warm_slice=False)
        except TypeError:
            pol_w = pol_c
        t0 = time.perf_counter()
        state = jax.block_until_ready(pretrain_policy_state(
            denv, pol_w, hyp, corpus, seed=0, steps=pretrain_steps))
        pretrain_s = time.perf_counter() - t0
        res_w = run_policy_device(denv, pol_w, hyp, seed=0,
                                  train_steps=train_steps,
                                  init_state=state)
        res_c = run_policy_device(denv, pol_c, hyp, seed=0,
                                  train_steps=train_steps)
        warm = res_w["cum_reward"][n_early - 1]
        cold = res_c["cum_reward"][n_early - 1]
        out["policies"][name] = {
            "pretrain_s": pretrain_s,
            "early_cum_reward_warm": warm,
            "early_cum_reward_cold": cold,
            "early_delta": warm - cold,
            "final_cum_reward_warm": res_w["cum_reward"][-1],
            "final_cum_reward_cold": res_c["cum_reward"][-1],
        }
    return {"offline_pretrain": out}


def _bench_subprocess(args, n_seeds: int) -> Dict:
    """Run a bench section in a subprocess with the host's CPU cores
    exposed as XLA host-platform devices (sweeps shard their lane axis
    across them, DESIGN.md §8.4 — same mechanism as the 512-device
    dry-run). Isolating the flag in a child process keeps this process,
    and every other benchmark section, on the default single device."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        # more devices than sweep lanes would be pure startup overhead in
        # the child — shard_sweep_axis only ever uses the first n_seeds
        n_dev = max(1, min(os.cpu_count() or 1, n_seeds))
        env["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n_dev}"
        ).strip()
    src = os.path.join(REPO_ROOT, "src")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH")) if p)
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_protocol", *args],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True)
    if out.returncode != 0:
        raise RuntimeError("bench subprocess failed:\n"
                           + out.stderr[-2000:])
    return json.loads(out.stdout)


def bench_neuralucb_subprocess(n_samples: int, n_slices: int, n_seeds: int,
                               train_steps: int, batch_size: int) -> Dict:
    return _bench_subprocess(
        ["--nucb-only",
         "--nucb-samples", str(n_samples), "--nucb-slices", str(n_slices),
         "--nucb-seeds", str(n_seeds),
         "--nucb-train-steps", str(train_steps),
         "--nucb-batch", str(batch_size)], n_seeds)


def bench_scenarios_subprocess(n_samples: int, n_slices: int,
                               n_seeds: int, train_steps: int,
                               batch_size: int) -> Dict:
    return _bench_subprocess(
        ["--scen-only",
         "--scen-samples", str(n_samples), "--scen-slices", str(n_slices),
         "--scen-seeds", str(n_seeds),
         "--nucb-train-steps", str(train_steps),
         "--nucb-batch", str(batch_size)], n_seeds)


def bench_policy_zoo_subprocess(n_samples: int, n_slices: int,
                                n_seeds: int, train_steps: int,
                                batch_size: int) -> Dict:
    return _bench_subprocess(
        ["--zoo-only",
         "--zoo-samples", str(n_samples), "--zoo-slices", str(n_slices),
         "--zoo-seeds", str(n_seeds),
         "--nucb-train-steps", str(train_steps),
         "--nucb-batch", str(batch_size)], n_seeds)


def bench_protocol(n_samples: int = 36_497, n_slices: int = 20,
                   n_seeds: int = 32, nucb_samples: int = 1200,
                   nucb_slices: int = 32, nucb_seeds: int = 4,
                   nucb_train_steps: int = 32,
                   nucb_batch: int = 32, scen_samples: int = 6000,
                   scen_slices: int = 12, scen_seeds: int = 6,
                   zoo_samples: int = 1200, zoo_slices: int = 8,
                   zoo_seeds: int = 4, pool_tiny: bool = False) -> Dict:
    henv = RouterBenchSim(seed=0, n_samples=n_samples, n_slices=n_slices)
    denv = DeviceReplayEnv.from_host(henv)
    tables, xs = _tables(denv), denv.slice_xs()
    cum0 = _cum_valid(denv)
    dpols = [as_bandit_policy(p) for p in _device_policies(denv)]
    n_policies = len(dpols)

    def _scan_run(p):
        return jax.block_until_ready(_policy_scan(
            tables, xs, denv.idx, cum0, jax.random.PRNGKey(0), (), p)[1])

    # --- single protocol run ---------------------------------------------
    run_protocol(henv, _host_policies(henv, 0), verbose=False)  # warm numpy
    t0 = time.perf_counter()
    run_protocol(henv, _host_policies(henv, 0), verbose=False)
    host_single = time.perf_counter() - t0

    for p in dpols:  # compile the unified scan per policy
        _scan_run(p)
    t0 = time.perf_counter()
    for p in dpols:
        _scan_run(p)
    dev_single = time.perf_counter() - t0

    # --- multi-seed sweep -------------------------------------------------
    t0 = time.perf_counter()
    for s in range(n_seeds):
        run_protocol(henv, _host_policies(henv, s), verbose=False)
    host_sweep = time.perf_counter() - t0

    for p in dpols:  # compile the vmapped scan
        run_baseline_sweep(denv, p, range(n_seeds))
    t0 = time.perf_counter()
    for p in dpols:
        run_baseline_sweep(denv, p, range(n_seeds))
    dev_sweep = time.perf_counter() - t0
    sweep_decisions = n_seeds * n_policies * henv.n

    # --- NeuralUCB slice step (post-warm decide+update, no training) ------
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
    router = NeuralUCBRouter(cfg, seed=0)
    b = henv.slice_batch(0)
    n0 = len(b["idx"])
    dec = router.decide(b["x_emb"], b["x_feat"], b["domain"])
    router.update(b["x_emb"], b["x_feat"], b["domain"], dec,
                  b["reward"][np.arange(n0), dec["action"]])
    router.end_slice(epochs=1)

    def host_step():
        d = router.decide(b["x_emb"], b["x_feat"], b["domain"])
        router.update(b["x_emb"], b["x_feat"], b["domain"], d,
                      b["reward"][np.arange(n0), d["action"]])

    host_step()
    t0 = time.perf_counter()
    for _ in range(5):
        host_step()
    host_step_s = (time.perf_counter() - t0) / 5

    nucb = DeviceNeuralUCB(denv, cfg, seed=0)

    # ainv/bufs are donated by _nucb_slice_step — thread the returned
    # buffers through the timing loop exactly like the stepped runner does
    def dev_step(ainv, bufs):
        ainv, bufs, _ = _nucb_slice_step(
            nucb.params, ainv, tables, bufs, jnp.int32(1),
            denv.idx[1], denv.mask[1], jax.random.PRNGKey(0),
            jnp.float32(1.0), jnp.float32(0.5), jnp.float32(0.05),
            cfg, nucb.ucb_backend, False)
        return ainv, bufs

    ainv, bufs = dev_step(nucb.ainv, nucb.bufs)
    jax.block_until_ready(ainv)
    t0 = time.perf_counter()
    for _ in range(5):
        ainv, bufs = dev_step(ainv, bufs)
        jax.block_until_ready(ainv)
    dev_step_s = (time.perf_counter() - t0) / 5
    nucb.ainv, nucb.bufs = ainv, bufs

    nucb_runs = bench_neuralucb_subprocess(
        nucb_samples, nucb_slices, nucb_seeds, nucb_train_steps, nucb_batch)
    scen_runs = bench_scenarios_subprocess(
        scen_samples, scen_slices, scen_seeds, nucb_train_steps,
        nucb_batch)
    zoo_runs = bench_policy_zoo_subprocess(
        zoo_samples, zoo_slices, zoo_seeds, nucb_train_steps, nucb_batch)
    kernel_runs = bench_nucb_kernels()
    compile_runs = bench_experiment_compile()
    pretrain_runs = bench_offline_pretrain(henv, denv)
    pool_runs = bench_physical_pool(tiny=pool_tiny)

    return {
        # headline: protocol-engine throughput on the paper-style workload
        # (multi-seed baseline sweep) vs. the seed host loop
        "speedup": host_sweep / dev_sweep,
        "meta": {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "n_samples": n_samples,
            "n_slices": n_slices,
            "n_seeds": n_seeds,
            "n_policies": n_policies,
            "n_devices": len(jax.local_devices()),
            "ucb_backend": nucb.ucb_backend,
            "kernel_backends": ["jnp", "pallas"],
        },
        "baseline_protocol_single": {
            "host_s": host_single,
            "device_s": dev_single,
            "speedup": host_single / dev_single,
        },
        "baseline_sweep": {
            "host_s": host_sweep,
            "device_s": dev_sweep,
            "speedup": host_sweep / dev_sweep,
            "host_decisions_per_s": sweep_decisions / host_sweep,
            "device_decisions_per_s": sweep_decisions / dev_sweep,
        },
        "neuralucb_slice_step": {
            "slice_width": int(denv.slice_width),
            "host_s": host_step_s,
            "device_s": dev_step_s,
            "speedup": host_step_s / dev_step_s,
        },
        **nucb_runs,
        **scen_runs,
        **zoo_runs,
        **kernel_runs,
        **compile_runs,
        **pretrain_runs,
        **pool_runs,
    }


def run(refresh: bool = False, **kw):
    out = cached("protocol_engine_v9", lambda: bench_protocol(**kw), refresh)
    with open(ROOT_OUT, "w") as f:
        json.dump(out, f, indent=1, default=float)
    rows = [("bench_protocol/section", "host_s", "device_s", "speedup")]
    for sec in ("baseline_protocol_single", "baseline_sweep",
                "neuralucb_slice_step"):
        s = out[sec]
        rows.append((sec, round(s["host_s"], 4), round(s["device_s"], 5),
                     round(s["speedup"], 2)))
    for sec in ("neuralucb_scan_vs_stepped", "neuralucb_sweep"):
        s = out[sec]
        rows.append((sec, round(s["stepped_s"], 4), round(s["scan_s"], 4),
                     round(s["speedup"], 2)))
    s = out["scenario_scan"]
    rows.append(("scenario_scan(overhead)", round(s["stationary_s"], 4),
                 round(s["scenario_s"], 4), round(s["overhead"], 3)))
    for scen, row in out["scenario_adaptivity"].items():
        if isinstance(row, dict):
            rows.append((f"adaptivity/{scen}", round(row["vanilla"], 4),
                         round(row["forgetting"], 4),
                         f"+{row['delta']:.4f}"))
    z = out["policy_zoo_sweep"]
    rows.append(("policy_zoo(one dispatch)", round(z["sequential_runs_s"], 4),
                 round(z["zoo_dispatch_s"], 4),
                 round(z["speedup_vs_sequential"], 2)))
    for name, p in z["per_policy"].items():
        rows.append((f"zoo/{name}", round(p["sequential_s"], 4),
                     round(p["sweep_s"], 4),
                     f"{p['decisions_per_s']:.0f}/s"))
    for sec in ("nucb_fused_decide", "ainv_rebuild", "nucb_fused_update"):
        s = out[sec]
        for bk, row in s["backends"].items():
            rate = row.get("decisions_per_s", row.get("rows_per_s"))
            rows.append((f"{sec}/{bk}", round(row["wall_s"], 5),
                         f"{rate:.0f}/s", row["mode"]))
    for name, c in out["experiment_compile"].items():
        rows.append((f"spec_compile/{name}", round(c["compile_s"], 5),
                     f"{c['n_dispatches']} disp",
                     f"+{c['extra_dispatches']}"))
    for name, p in out["offline_pretrain"]["policies"].items():
        rows.append((f"pretrain/{name}", round(p["pretrain_s"], 3),
                     f"{p['early_cum_reward_warm']:.0f}w/"
                     f"{p['early_cum_reward_cold']:.0f}c",
                     f"{p['early_delta']:+.0f}"))
    if "physical_pool" in out:
        pp = out["physical_pool"]
        for name, c in pp["calibration"].items():
            for bk, row in c["backends"].items():
                rows.append((f"pool_calib/{name}/{bk}",
                             round(row["measured_step_s"], 5),
                             round(row["analytic_step_s"], 6),
                             f"x{row['measured_over_analytic']:.1f}"))
        rows.append(("pool_compile",
                     round(pp["pool"]["compile_s"], 4),
                     f"{len(pp['pool']['arms'])} arms",
                     f"crc {pp['pool']['checksum']}"))
    rows.append(("sweep_device_decisions_per_s",
                 round(out["baseline_sweep"]["device_decisions_per_s"]),
                 "", ""))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-samples", type=int, default=36_497)
    ap.add_argument("--n-slices", type=int, default=20)
    ap.add_argument("--seeds", type=int, default=32)
    ap.add_argument("--nucb-samples", type=int, default=1200)
    ap.add_argument("--nucb-slices", type=int, default=32)
    ap.add_argument("--nucb-seeds", type=int, default=4)
    ap.add_argument("--nucb-train-steps", type=int, default=32)
    ap.add_argument("--nucb-batch", type=int, default=32)
    ap.add_argument("--scen-samples", type=int, default=6000)
    ap.add_argument("--scen-slices", type=int, default=12)
    ap.add_argument("--scen-seeds", type=int, default=6)
    ap.add_argument("--zoo-samples", type=int, default=1200)
    ap.add_argument("--zoo-slices", type=int, default=8)
    ap.add_argument("--zoo-seeds", type=int, default=4)
    ap.add_argument("--nucb-only", action="store_true",
                    help="internal: run only the NeuralUCB sections and "
                         "print their JSON (the subprocess entry point)")
    ap.add_argument("--scen-only", action="store_true",
                    help="internal: run only the scenario sections and "
                         "print their JSON (the subprocess entry point)")
    ap.add_argument("--zoo-only", action="store_true",
                    help="internal: run only the policy-zoo sweep section "
                         "and print its JSON (the subprocess entry point)")
    ap.add_argument("--pool-only", action="store_true",
                    help="run only the physical_pool calibration section "
                         "and print its JSON")
    ap.add_argument("--pool-tiny", action="store_true",
                    help="calibrate the REDUCED configs (CI-sized; marks "
                         "the section reduced=true so the regression "
                         "guard treats it as a reshape)")
    ap.add_argument("--pool-batch", type=int, default=4)
    ap.add_argument("--pool-steps", type=int, default=6)
    ap.add_argument("--out", default=ROOT_OUT)
    args = ap.parse_args()
    if args.pool_only:
        out = bench_physical_pool(batch=args.pool_batch,
                                  steps=args.pool_steps,
                                  tiny=args.pool_tiny)
        print(json.dumps(out, default=float))
        return
    if args.nucb_only:
        out = bench_neuralucb_runs(
            args.nucb_samples, args.nucb_slices, args.nucb_seeds,
            args.nucb_train_steps, args.nucb_batch)
        print(json.dumps(out, default=float))
        return
    if args.scen_only:
        out = bench_scenarios(
            args.scen_samples, args.scen_slices, args.scen_seeds,
            args.nucb_train_steps, args.nucb_batch)
        print(json.dumps(out, default=float))
        return
    if args.zoo_only:
        out = bench_policy_zoo(
            args.zoo_samples, args.zoo_slices, args.zoo_seeds,
            args.nucb_train_steps, args.nucb_batch)
        print(json.dumps(out, default=float))
        return
    out = bench_protocol(args.n_samples, args.n_slices, args.seeds,
                         args.nucb_samples, args.nucb_slices,
                         args.nucb_seeds, args.nucb_train_steps,
                         args.nucb_batch, args.scen_samples,
                         args.scen_slices, args.scen_seeds,
                         args.zoo_samples, args.zoo_slices,
                         args.zoo_seeds, pool_tiny=args.pool_tiny)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(json.dumps(out, indent=1, default=float))


if __name__ == "__main__":
    main()
