"""Online-protocol engine throughput: seed host loop vs. the device-resident
engine (repro.sim), on the identical replay stream.

Three comparisons, recorded to ``BENCH_protocol.json`` at the repo root
(schema documented in README.md):

  baseline_protocol_single — one 4-policy protocol run: host Python loop
      (T x policies device round-trips) vs. one jitted lax.scan per policy.
  baseline_sweep           — the paper-style multi-seed sweep: host loop
      over seeds vs. one vmap over PRNG keys (the headline speedup; the
      seed path *cannot* amortize seeds).
  neuralucb_slice_step     — Algorithm 1's hot loop for one slice
      (DECIDE -> feedback lookup -> rank-k UPDATE): host decide()/update()
      round-trip vs. the fused jit step.

  python -m benchmarks.bench_protocol [--n-samples N] [--n-slices T]
                                      [--seeds S] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cached
from repro.core.baselines import (
    EmpiricalGreedy,
    FixedActionPolicy,
    RandomPolicy,
)
from repro.core.policy import NeuralUCBRouter
from repro.core.protocol import run_protocol
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim
from repro.sim import (
    DeviceNeuralUCB,
    DeviceReplayEnv,
    fixed_policy,
    greedy_policy,
    random_policy,
    run_baseline_sweep,
)
from repro.sim.engine import _baseline_scan, _nucb_slice_step, _tables

ROOT_OUT = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_protocol.json")


def _host_policies(env: RouterBenchSim, seed: int):
    return {
        "random": RandomPolicy(env.K, seed=seed),
        "min-cost": FixedActionPolicy(env.min_cost_action()),
        "max-quality": FixedActionPolicy(env.max_quality_action()),
        "greedy": EmpiricalGreedy(env.K),
    }


def _device_policies(env: DeviceReplayEnv):
    return [
        random_policy(env.K),
        fixed_policy(env.min_cost_action(), "min-cost"),
        fixed_policy(env.max_quality_action(), "max-quality"),
        greedy_policy(env.K),
    ]


def bench_protocol(n_samples: int = 36_497, n_slices: int = 20,
                   n_seeds: int = 32) -> Dict:
    henv = RouterBenchSim(seed=0, n_samples=n_samples, n_slices=n_slices)
    denv = DeviceReplayEnv.from_host(henv)
    tables, xs = _tables(denv), denv.slice_xs()
    dpols = _device_policies(denv)
    n_policies = len(dpols)

    # --- single protocol run ---------------------------------------------
    run_protocol(henv, _host_policies(henv, 0), verbose=False)  # warm numpy
    t0 = time.perf_counter()
    run_protocol(henv, _host_policies(henv, 0), verbose=False)
    host_single = time.perf_counter() - t0

    for p in dpols:  # compile
        jax.block_until_ready(_baseline_scan(
            tables, xs, jax.random.PRNGKey(0), p))
    t0 = time.perf_counter()
    for p in dpols:
        jax.block_until_ready(_baseline_scan(
            tables, xs, jax.random.PRNGKey(0), p))
    dev_single = time.perf_counter() - t0

    # --- multi-seed sweep -------------------------------------------------
    t0 = time.perf_counter()
    for s in range(n_seeds):
        run_protocol(henv, _host_policies(henv, s), verbose=False)
    host_sweep = time.perf_counter() - t0

    for p in dpols:  # compile the vmapped scan
        run_baseline_sweep(denv, p, range(n_seeds))
    t0 = time.perf_counter()
    for p in dpols:
        run_baseline_sweep(denv, p, range(n_seeds))
    dev_sweep = time.perf_counter() - t0
    sweep_decisions = n_seeds * n_policies * henv.n

    # --- NeuralUCB slice step (post-warm decide+update, no training) ------
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
    router = NeuralUCBRouter(cfg, seed=0)
    b = henv.slice_batch(0)
    n0 = len(b["idx"])
    dec = router.decide(b["x_emb"], b["x_feat"], b["domain"])
    router.update(b["x_emb"], b["x_feat"], b["domain"], dec,
                  b["reward"][np.arange(n0), dec["action"]])
    router.end_slice(epochs=1)

    def host_step():
        d = router.decide(b["x_emb"], b["x_feat"], b["domain"])
        router.update(b["x_emb"], b["x_feat"], b["domain"], d,
                      b["reward"][np.arange(n0), d["action"]])

    host_step()
    t0 = time.perf_counter()
    for _ in range(5):
        host_step()
    host_step_s = (time.perf_counter() - t0) / 5

    nucb = DeviceNeuralUCB(denv, cfg, seed=0)
    step_args = (nucb.params, nucb.ainv, tables, nucb.bufs, jnp.int32(1),
                 denv.idx[1], denv.mask[1], jax.random.PRNGKey(0),
                 jnp.float32(1.0), jnp.float32(0.5), jnp.float32(0.05))
    jax.block_until_ready(
        _nucb_slice_step(*step_args, cfg, nucb.ucb_backend, False)[0])
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(
            _nucb_slice_step(*step_args, cfg, nucb.ucb_backend, False)[0])
    dev_step_s = (time.perf_counter() - t0) / 5

    return {
        # headline: protocol-engine throughput on the paper-style workload
        # (multi-seed baseline sweep) vs. the seed host loop
        "speedup": host_sweep / dev_sweep,
        "meta": {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "n_samples": n_samples,
            "n_slices": n_slices,
            "n_seeds": n_seeds,
            "n_policies": n_policies,
            "ucb_backend": nucb.ucb_backend,
        },
        "baseline_protocol_single": {
            "host_s": host_single,
            "device_s": dev_single,
            "speedup": host_single / dev_single,
        },
        "baseline_sweep": {
            "host_s": host_sweep,
            "device_s": dev_sweep,
            "speedup": host_sweep / dev_sweep,
            "host_decisions_per_s": sweep_decisions / host_sweep,
            "device_decisions_per_s": sweep_decisions / dev_sweep,
        },
        "neuralucb_slice_step": {
            "slice_width": int(denv.slice_width),
            "host_s": host_step_s,
            "device_s": dev_step_s,
            "speedup": host_step_s / dev_step_s,
        },
    }


def run(refresh: bool = False, **kw):
    out = cached("protocol_engine", lambda: bench_protocol(**kw), refresh)
    with open(ROOT_OUT, "w") as f:
        json.dump(out, f, indent=1, default=float)
    rows = [("bench_protocol/section", "host_s", "device_s", "speedup")]
    for sec in ("baseline_protocol_single", "baseline_sweep",
                "neuralucb_slice_step"):
        s = out[sec]
        rows.append((sec, round(s["host_s"], 4), round(s["device_s"], 5),
                     round(s["speedup"], 2)))
    rows.append(("sweep_device_decisions_per_s",
                 round(out["baseline_sweep"]["device_decisions_per_s"]),
                 "", ""))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-samples", type=int, default=36_497)
    ap.add_argument("--n-slices", type=int, default=20)
    ap.add_argument("--seeds", type=int, default=32)
    ap.add_argument("--out", default=ROOT_OUT)
    args = ap.parse_args()
    out = bench_protocol(args.n_samples, args.n_slices, args.seeds)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(json.dumps(out, indent=1, default=float))


if __name__ == "__main__":
    main()
