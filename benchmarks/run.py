"""Benchmark driver — one section per paper table/figure plus the roofline
table. Prints ``name,us_per_call,derived``-style CSV per section.

  python -m benchmarks.run            # all (cached artifacts reused)
  python -m benchmarks.run --only rewards --refresh
"""
from __future__ import annotations

import argparse

from benchmarks import (
    bench_cost_quality,
    bench_encoders,
    bench_kernels,
    bench_protocol,
    bench_rewards,
    bench_roofline,
    bench_serving,
)
from benchmarks.common import emit_csv

SECTIONS = {
    "rewards": bench_rewards.run,        # paper Fig. 2
    "encoders": bench_encoders.run,      # paper Fig. 3
    "cost_quality": bench_cost_quality.run,  # paper Fig. 4
    "kernels": bench_kernels.run,
    "roofline": bench_roofline.run,      # deliverable (g)
    "protocol": bench_protocol.run,      # sim engine vs seed host loop
    "serving": bench_serving.run,        # async engine vs sync loop
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=list(SECTIONS), default=None)
    ap.add_argument("--refresh", action="store_true")
    args = ap.parse_args()
    names = [args.only] if args.only else list(SECTIONS)
    for name in names:
        print(f"# --- {name} ---", flush=True)
        emit_csv(SECTIONS[name](refresh=args.refresh))


if __name__ == "__main__":
    main()
