"""Kernel microbenchmarks.

On this CPU-only host the Pallas kernels execute in interpret mode (Python
— correctness, not speed), so the wall-times below are NOT TPU numbers.
What IS meaningful here: the pure-jnp reference path timings (the XLA-CPU
fallback the models use) and the kernels' analytic FLOPs/bytes, which the
roofline analysis uses for the TPU projections."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import cached, timeit_us
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba2_ssd.ref import ssd_ref
from repro.kernels.ucb_score.ref import ucb_score_ref


def _run():
    out = {}
    key = jax.random.PRNGKey(0)

    # flash attention ref (XLA path) — prefill-like tile
    B, H, KV, S, D = 1, 8, 4, 1024, 128
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, D), jnp.float32)
    f = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    us = timeit_us(f, q, k, v)
    flops = 4.0 * B * H * S * S * D
    out["attention_ref_1k"] = {"us_per_call": us, "flops": flops,
                               "gflops_s": flops / us / 1e3}

    # decode attention ref — 32k cache row
    S = 32768
    k2 = jax.random.normal(ks[1], (B, KV, S, D), jnp.float32)
    v2 = jax.random.normal(ks[2], (B, KV, S, D), jnp.float32)
    qd = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    fd = jax.jit(lambda q, k, v: decode_attention_ref(q, k, v, S - 1))
    us = timeit_us(fd, qd, k2, v2)
    bytes_moved = 2 * B * KV * S * D * 4
    out["decode_ref_32k"] = {"us_per_call": us, "bytes": bytes_moved,
                             "gb_s": bytes_moved / us / 1e3}

    # ssd ref — mamba2-130m-like block
    B2, L, Hm, P, N = 1, 2048, 24, 64, 128
    ks2 = jax.random.split(key, 5)
    x = jax.random.normal(ks2[0], (B2, L, Hm, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks2[1], (B2, L, Hm)))
    A = -jnp.exp(jax.random.normal(ks2[2], (Hm,)) * 0.5)
    Bm = jax.random.normal(ks2[3], (B2, L, N))
    Cm = jax.random.normal(ks2[4], (B2, L, N))
    fs = jax.jit(lambda *a: ssd_ref(*a)[0])
    us = timeit_us(fs, x, dt, A, Bm, Cm)
    out["ssd_ref_2k"] = {"us_per_call": us}

    # ucb score ref — the paper's serving hot loop at production batch
    T, K, F = 1024, 11, 129
    g = jax.random.normal(ks2[0], (T, K, F), jnp.float32)
    ainv = jnp.eye(F)
    mu = jax.random.normal(ks2[1], (T, K))
    fu = jax.jit(lambda g, a, m: ucb_score_ref(g, a, m, 1.0))
    us = timeit_us(fu, g, ainv, mu)
    flops = 2.0 * T * K * F * F
    out["ucb_score_ref_1k"] = {"us_per_call": us, "flops": flops,
                               "gflops_s": flops / us / 1e3,
                               "us_per_request": us / T}
    return out


def run(refresh: bool = False):
    out = cached("kernel_micro", _run, refresh)
    rows = [("bench_kernels/name", "us_per_call", "derived")]
    for name, s in out.items():
        derived = s.get("gflops_s") or s.get("gb_s") or ""
        rows.append((name, round(s["us_per_call"], 1),
                     round(derived, 2) if derived else ""))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit_csv

    emit_csv(run())
