"""Paper Figure 4: NeuralUCB vs the max-quality reference — inference cost
and selected quality. Reads the full-protocol artifact from bench_rewards
(runs it if missing) and derives the Fig. 4 comparison."""
from __future__ import annotations

from benchmarks import bench_rewards
from benchmarks.common import cached


def run(refresh: bool = False):
    bench_rewards.run(refresh=refresh)  # ensure artifact exists
    out = cached("rewards_full", lambda: (_ for _ in ()).throw(
        RuntimeError("rewards artifact missing")))
    mq = out["max_quality_reference"]
    nucb = out["summary"]["neuralucb"]
    rows = [("bench_cost_quality/metric", "neuralucb", "max_quality_ref",
             "ratio")]
    rows.append(("avg_cost", round(nucb["avg_cost"], 5),
                 round(mq["avg_cost"], 5),
                 round(nucb["avg_cost"] / mq["avg_cost"], 4)))
    rows.append(("avg_quality", round(nucb["avg_quality"], 4),
                 round(mq["avg_quality"], 4),
                 round(nucb["avg_quality"] / mq["avg_quality"], 4)))
    rows.append(("avg_reward", round(nucb["avg_reward"], 4),
                 round(mq["avg_reward"], 4),
                 round(nucb["avg_reward"] / mq["avg_reward"], 4)))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit_csv

    emit_csv(run())
