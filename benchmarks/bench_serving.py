"""Async serving engine throughput (DESIGN.md §12.7): the microbatched
continuous-batching engine vs. a synchronous per-request loop, on the
SAME engine code path and the same device-resident NeuralUCB router.

Two measured modes, recorded to ``BENCH_serving.json`` at the repo root
(schema documented in README.md):

  microbatched — ``run_storm`` over a >=1M-request steady trace with
      ``decide_batch`` requests per jitted decide/update call: sustained
      requests/s, p50/p99 decide-call latency, per-request decide cost,
      periodic train pauses included in the wall clock.
  sync_reference — the identical storm driver with ``decide_batch=1``
      (one jitted decide + one update dispatch per request): the
      pre-continuous-batching serving shape. Run at reduced request
      count (it is the slow side) and reported as measured requests/s.

The headline ``speedup`` is microbatched / sync requests-per-second;
the acceptance bound (>= 10x) is asserted by the CI smoke via the
recorded artifact, not silently assumed.

A third section, ``overlap_vs_sync``, isolates the zero-sync train
overlap (DESIGN.md §15.2): the same storm at a train-heavy cadence
(train every 2 waves, ring depth 8) with ``max_train_lag=0`` (end_slice
blocks on the train) vs ``=2`` (SGD and rebuild dispatched as separate
async device programs, bounded staleness). The compared tail is
``decide_path_p99_us`` — decide-call wall plus any slice-boundary train
stall the next decide waits behind — at zero lost/shed on both sides.
Both train programs are warmed before measurement.

  python -m benchmarks.bench_serving [--requests N] [--waves W]
      [--decide-batch B] [--sync-requests N] [--n-samples N] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

import jax

from benchmarks.common import cached
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim
from repro.serving import DevicePolicyRouter, run_storm
from repro.sim import DeviceReplayEnv, make_policy
from repro.sim.engine import _tables

ROOT_OUT = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_serving.json")

BENCH_SCHEMA = "bench-serving-v2"


def _router(env, *, decide_batch: int, train_steps: int = 32,
            batch_size: int = 64, capacity_slices: int = 256,
            seed: int = 0, train_lag: int = 0) -> DevicePolicyRouter:
    cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1], num_actions=env.K)
    pol, hyp = make_policy("neuralucb", env, cfg)
    return DevicePolicyRouter(
        pol, hyp, _tables(env), seed=seed, slice_width=decide_batch,
        capacity_slices=capacity_slices, batch_size=batch_size,
        train_chunks=max(1, -(-train_steps // 32)),
        max_train_lag=train_lag)


def bench_serving(requests: int = 1_000_000, waves: int = 200,
                  decide_batch: int = 512, sync_requests: int = 2_000,
                  n_samples: int = 36_497, train_every: int = 25) -> Dict:
    henv = RouterBenchSim(seed=0, n_samples=n_samples, n_slices=20)
    env = DeviceReplayEnv.from_host(henv)

    t0 = time.perf_counter()
    micro = run_storm(
        env, _router(env, decide_batch=decide_batch),
        requests=requests, waves=waves, pattern="steady",
        queue_capacity=max(4096, 2 * (requests // waves)),
        decide_batch=decide_batch, serve_batch=decide_batch,
        train_every=train_every, seed=0, log_capacity=1024)
    micro_wall = time.perf_counter() - t0

    sync_waves = max(1, sync_requests // 100)
    t0 = time.perf_counter()
    sync = run_storm(
        env, _router(env, decide_batch=1, capacity_slices=1024),
        requests=sync_requests, waves=sync_waves, pattern="steady",
        queue_capacity=max(256, 2 * (sync_requests // sync_waves)),
        decide_batch=1, serve_batch=1,
        train_every=max(1, train_every * sync_requests // requests),
        seed=0, log_capacity=1024)
    sync_wall = time.perf_counter() - t0

    # zero-sync train overlap at a train-heavy cadence (every 2 waves,
    # ring depth 8): identical storm, max_train_lag 0 vs 2 — the only
    # knob that moves. Both train programs (fused sync, staged
    # sgd+rebuild) are compiled by throwaway warmup storms first so the
    # measured stalls are execution, not XLA compile.
    ov_req, ov_waves, ov_cap, lag = min(requests, 400_000), 40, 8, 2
    for wlag in (lag, 0):
        wr = _router(env, decide_batch=decide_batch,
                     capacity_slices=ov_cap, train_lag=wlag)
        run_storm(env, wr, requests=4 * decide_batch, waves=2,
                  pattern="steady", queue_capacity=4 * decide_batch,
                  decide_batch=decide_batch, serve_batch=decide_batch,
                  train_every=1, seed=0)
        wr.state_dict()   # flush: forces the staged rebuild compile too
    ov_kw = dict(requests=ov_req, waves=ov_waves, pattern="steady",
                 queue_capacity=max(4096, 2 * (ov_req // ov_waves)),
                 decide_batch=decide_batch, serve_batch=decide_batch,
                 train_every=2, seed=0, log_capacity=1024)
    ov = {}
    for name, tl in (("sync", 0), ("overlap", lag)):
        t0 = time.perf_counter()
        res = run_storm(env, _router(env, decide_batch=decide_batch,
                                     capacity_slices=ov_cap,
                                     train_lag=tl), **ov_kw)
        ov[name] = {**res, "total_wall_s": time.perf_counter() - t0,
                    "max_train_lag": tl}

    dev = jax.local_devices()
    return {
        "schema": BENCH_SCHEMA,
        "env": {"n_samples": int(n_samples), "n_arms": int(env.K),
                "backend": jax.default_backend(),
                "device_kind": dev[0].device_kind if dev else "none"},
        "microbatched": {**micro, "total_wall_s": micro_wall},
        "sync_reference": {**sync, "total_wall_s": sync_wall},
        "speedup": micro["requests_per_s"] / sync["requests_per_s"],
        "overlap_vs_sync": {
            **ov,
            "p99_decide_path_improvement": (
                ov["sync"]["decide_path_p99_us"]
                / max(ov["overlap"]["decide_path_p99_us"], 1e-9)),
            "throughput_improvement": (
                ov["overlap"]["requests_per_s"]
                / max(ov["sync"]["requests_per_s"], 1e-9)),
        },
    }


def run(refresh: bool = False, **kw):
    out = cached("serving_engine_v2", lambda: bench_serving(**kw), refresh)
    with open(ROOT_OUT, "w") as f:
        json.dump(out, f, indent=1, default=float)
    rows = [("bench_serving/mode", "requests", "req_per_s",
             "p99_decide_us")]
    for mode in ("microbatched", "sync_reference"):
        s = out[mode]
        rows.append((mode, s["requests"], round(s["requests_per_s"]),
                     round(s["decide_p99_us"], 1)))
    rows.append(("speedup(micro/sync)", "", round(out["speedup"], 2), ""))
    for mode in ("sync", "overlap"):
        s = out["overlap_vs_sync"][mode]
        rows.append((f"overlap_vs_sync/{mode}(lag={s['max_train_lag']})",
                     s["requests"], round(s["requests_per_s"]),
                     round(s["decide_path_p99_us"], 1)))
    rows.append(("overlap_p99_decide_path_gain", "",
                 round(out["overlap_vs_sync"]
                       ["p99_decide_path_improvement"], 2), ""))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=1_000_000)
    ap.add_argument("--waves", type=int, default=200)
    ap.add_argument("--decide-batch", type=int, default=512)
    ap.add_argument("--sync-requests", type=int, default=2_000)
    ap.add_argument("--n-samples", type=int, default=36_497)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    global ROOT_OUT
    if args.out:
        ROOT_OUT = args.out
    for row in run(refresh=True, requests=args.requests, waves=args.waves,
                   decide_batch=args.decide_batch,
                   sync_requests=args.sync_requests,
                   n_samples=args.n_samples):
        print(",".join(str(x) for x in row), flush=True)


if __name__ == "__main__":
    main()
