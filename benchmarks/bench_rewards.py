"""Paper Figure 2 (+Fig. 4 data): full-protocol reward comparison over the
complete 36,497-sample stream, 20 slices — NeuralUCB vs random / min-cost /
RouteLLM-BERT (+ LinUCB as a beyond-paper partial-feedback reference, and
the max-quality reference row)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import cached
from repro.core.baselines import (
    FixedActionPolicy,
    LinUCB,
    RandomPolicy,
    RouteLLMBert,
)
from repro.core.policy import NeuralUCBRouter
from repro.core.protocol import run_protocol, summarize
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim


def _run(n_samples=36_497, n_slices=20, epochs=5):
    env = RouterBenchSim(seed=0, n_samples=n_samples, n_slices=n_slices)
    s, w = env.strong_weak_actions()
    rl = RouteLLMBert(s, w, env.x_emb.shape[1])
    b0 = env.slice_batch(0)
    rl.fit_offline(b0["x_emb"], b0["quality"][:, s], b0["quality"][:, w])
    cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1], num_actions=env.K,
                           d_hidden=384, d_action=32)
    pols = {
        "neuralucb": NeuralUCBRouter(cfg, seed=0),
        "random": RandomPolicy(env.K, seed=1),
        "min-cost": FixedActionPolicy(env.min_cost_action()),
        "max-quality-arm": FixedActionPolicy(env.max_quality_action()),
        "routellm-bert": rl,
        "linucb": LinUCB(env.K, env.x_emb.shape[1]),
    }
    res = run_protocol(env, pols, epochs=epochs, verbose=True)
    summ = summarize(res)

    n = env.n
    aq = env.data["quality"].argmax(1)
    maxq = {
        "avg_reward": float(env.reward_table[np.arange(n), aq].mean()),
        "avg_cost": float(env.data["cost"][np.arange(n), aq].mean()),
        "avg_quality": float(env.data["quality"][np.arange(n), aq].mean()),
    }
    oracle = float(env.reward_table.max(1).mean())
    return {
        "summary": summ,
        "per_slice": {k: {kk: vv for kk, vv in v.items()
                          if kk != "action_hist"}
                      for k, v in res.items()},
        "max_quality_reference": maxq,
        "oracle_reward": oracle,
    }


def run(refresh: bool = False):
    out = cached("rewards_full", _run, refresh)
    rows = [("bench_rewards/policy", "avg_reward", "avg_cost", "avg_quality")]
    for name, s in out["summary"].items():
        rows.append((f"fig2_{name}", round(s["avg_reward"], 4),
                     round(s["avg_cost"], 5), round(s["avg_quality"], 4)))
    mq = out["max_quality_reference"]
    rows.append(("fig4_max_quality_ref", round(mq["avg_reward"], 4),
                 round(mq["avg_cost"], 5), round(mq["avg_quality"], 4)))
    rows.append(("oracle", round(out["oracle_reward"], 4), "", ""))
    nucb_cost_frac = out["summary"]["neuralucb"]["avg_cost"] / mq["avg_cost"]
    rows.append(("fig4_neuralucb_cost_fraction", round(nucb_cost_frac, 4),
                 "", ""))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit_csv

    emit_csv(run())
