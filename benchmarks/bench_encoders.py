"""Paper Figure 3: encoder ablation. Four sentence-encoder stubs with the
fidelity ordering of the paper (mpnet ~ MiniLM > qwen3-0.6B > e5-large-
instruct) under the simulated online protocol (reduced stream to keep the
4x protocol affordable on this host)."""
from __future__ import annotations

from benchmarks.common import cached
from repro.core.policy import NeuralUCBRouter
from repro.core.protocol import run_protocol, summarize
from repro.core.utilitynet import UtilityNetConfig
from repro.data.encoders import ENCODERS
from repro.data.routerbench import RouterBenchSim, generate_routerbench


def _run(n_samples=14_000, n_slices=10, epochs=5):
    data = generate_routerbench(seed=0, n_samples=n_samples)
    out = {}
    for enc in ENCODERS:
        env = RouterBenchSim(seed=0, encoder=enc, n_slices=n_slices,
                             data=data)
        cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1],
                               num_actions=env.K, d_hidden=384, d_action=32)
        pols = {"neuralucb": NeuralUCBRouter(cfg, seed=0)}
        res = run_protocol(env, pols, epochs=epochs, verbose=False)
        summ = summarize(res)["neuralucb"]
        out[enc] = {
            "avg_reward": summ["avg_reward"],
            "final_cum_reward": summ["final_cum_reward"],
            "per_slice_reward": res["neuralucb"]["avg_reward"],
        }
        print(f"[encoders] {enc}: avg_reward={summ['avg_reward']:.4f}",
              flush=True)
    return out


def run(refresh: bool = False):
    out = cached("encoder_ablation", _run, refresh)
    rows = [("bench_encoders/encoder", "avg_reward", "final_cum_reward")]
    for enc, s in out.items():
        rows.append((f"fig3_{enc}", round(s["avg_reward"], 4),
                     round(s["final_cum_reward"], 1)))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit_csv

    emit_csv(run())
