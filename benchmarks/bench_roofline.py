"""Roofline table (deliverable (g)): reads the dry-run artifacts produced by
``python -m repro.launch.dryrun --all`` and emits the per-(arch x shape)
three-term roofline with the dominant bottleneck. Single-pod (16x16) mesh
per the spec; the 2x16x16 artifacts prove the pod axis shards."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load_reports(mesh: str = "16x16"):
    reps = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}.json"))):
        if mesh == "16x16" and "2x16x16" in os.path.basename(path):
            continue
        with open(path) as f:
            reps.append(json.load(f))
    return reps


def run(refresh: bool = False):
    reps = load_reports()
    rows = [("bench_roofline/arch_x_shape", "compute_s", "memory_s",
             "collective_s", "dominant", "useful_flop_frac", "mfu_ub")]
    for r in reps:
        t = r["roofline"]
        rows.append((
            f"{r['arch']}@{r['shape']}",
            f"{t['compute_s']:.5f}",
            f"{t['memory_s']:.5f}",
            f"{t['collective_s']:.5f}",
            t["dominant"].replace("_s", ""),
            round(t.get("useful_flop_fraction", 0), 3),
            round(t.get("mfu_upper_bound", 0), 4),
        ))
    if len(reps) < 33:
        rows.append((f"WARNING_only_{len(reps)}_reports_run_dryrun_all",
                     "", "", "", "", "", ""))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit_csv

    emit_csv(run())
