"""Shared benchmark plumbing: artifact caching + CSV emission."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def cached(name: str, fn: Callable[[], Dict], refresh: bool = False) -> Dict:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, name + ".json")
    if os.path.exists(path) and not refresh:
        with open(path) as f:
            return json.load(f)
    out = fn()
    with open(path, "w") as f:
        json.dump(out, f, indent=1, default=float)
    return out


def emit_csv(rows):
    for r in rows:
        print(",".join(str(x) for x in r), flush=True)


def timeit_us(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6
