"""Train a ~100M-parameter pool member for a few hundred steps (deliverable
(b) training driver). Uses the real training substrate: AdamW, cosine
schedule, grad clipping, checkpointing, synthetic LM data.

Default is a CPU-sized quick run; ``--full`` trains a ~100M llama-family
config for 300 steps (slow on this 1-core host, the same code path the
dry-run lowers at production scale).

    PYTHONPATH=src python examples/train_lm.py [--steps 30] [--full]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.training import train_step as TS
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.schedule import cosine_schedule


def synthetic_batch(rng, vocab, batch, seq):
    """Markov-ish synthetic token stream (learnable bigram structure)."""
    trans = (np.arange(vocab)[:, None] * 31 + np.arange(8)[None]) % vocab
    toks = np.zeros((batch, seq), np.int32)
    toks[:, 0] = rng.integers(0, vocab, batch)
    choices = rng.integers(0, 8, (batch, seq))
    for t in range(1, seq):
        toks[:, t] = trans[toks[:, t - 1], choices[:, t]]
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--full", action="store_true",
                    help="~100M-param config instead of the toy one")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_lm.npz")
    args = ap.parse_args()

    base = get_config("llama3.2-3b")
    if args.full:
        cfg = dataclasses.replace(
            base, name="llama-100m", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32000, dtype="float32")
    else:
        cfg = dataclasses.replace(base.reduced(), dtype="float32")
    print(f"config {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")

    state = TS.make_train_state(jax.random.PRNGKey(0), cfg)
    step_fn = jax.jit(lambda s, b, lr: TS.train_step(s, b, cfg=cfg, lr=lr))
    rng = np.random.default_rng(0)

    losses = []
    t0 = time.time()
    for step in range(args.steps):
        batch = synthetic_batch(rng, cfg.vocab_size, args.batch, args.seq)
        lr = cosine_schedule(jnp.int32(step), args.lr, args.steps,
                             warmup_steps=max(args.steps // 10, 1))
        state, m = step_fn(state, batch, lr)
        losses.append(float(m["loss"]))
        if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"({(time.time() - t0) / (step + 1):.2f}s/step)")
    assert losses[-1] < losses[0], "training must reduce loss"

    save_checkpoint(args.ckpt, state["params"])
    back = load_checkpoint(args.ckpt)
    n = sum(x.size for x in jax.tree.leaves(back))
    print(f"checkpoint round-trip OK ({n / 1e6:.1f}M params) -> {args.ckpt}")


if __name__ == "__main__":
    main()
