"""End-to-end driver: serve batched requests through the FULL stack —
NeuralUCB router in front of the PHYSICAL arm pool (DESIGN.md §16):
each arm a real `ModelConfig`, cost/latency derived from its decode
roofline on tpu-v5e, quality from the RouterBench tables via the
explicit arm mapping. The small arm (mamba2-130m) executes REAL jitted
prefill+decode on CPU; the large arms are roofline-clocked. Bandit
feedback closes the loop, Algorithm-1 style slices.

    PYTHONPATH=src python examples/serve_routed.py [--waves 6 --wave-size 16]
"""
import argparse

import numpy as np

from repro.armpool import build_arm_engines, build_pool_env
from repro.core.policy import NeuralUCBRouter
from repro.core.utilitynet import UtilityNetConfig
from repro.experiments import ArmPoolSpec, DataSpec
from repro.serving import Request, RoutedServingPool

# dense / SSM / MoE / hybrid-frontier — one arm per architecture class
POOL_ARMS = ("mamba2_130m", "llama3_2_3b", "qwen3_moe_30b_a3b",
             "jamba_1_5_large_398b")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--waves", type=int, default=6)
    ap.add_argument("--wave-size", type=int, default=16)
    ap.add_argument("--train-every", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=4)
    args = ap.parse_args()

    aspec = ArmPoolSpec(arms=POOL_ARMS, hardware="tpu-v5e",
                        decode_batch=8, context=2048,
                        max_new=args.max_new)
    env, pool = build_pool_env(aspec, DataSpec(n_samples=2000, n_slices=4))
    engines, info = build_arm_engines(pool, aspec)

    print(f"physical pool on {pool.hardware} "
          f"(real decode: {info['real_decode_arms']}):")
    for a in range(pool.K):
        print(f"  {pool.arms[a]:<22} {pool.params_b[a]:8.1f}B "
              f"{int(pool.chips[a]):3d} chip(s) "
              f"{pool.usd_per_token[a]:.2e} $/tok  "
              f"quality<-{pool.rb_models[a]}")

    ucfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1],
                            num_actions=pool.K)
    router = NeuralUCBRouter(ucfg, seed=0, batch_size=64)
    # cost = the pool's roofline $/token; quality = the mapped replay
    # columns already compiled into env's tables
    serving = RoutedServingPool(router, engines, pool.usd_per_token,
                                quality_table=env.data["quality"],
                                max_batch=8)

    rng = np.random.default_rng(0)
    for wave in range(args.waves):
        idx = rng.integers(0, env.n, size=args.wave_size)
        reqs = [Request(tokens=rng.integers(1, 200,
                                            size=int(rng.integers(4, 12))),
                        x_emb=env.x_emb[i], x_feat=env.data["x_feat"][i],
                        domain=int(env.data["domain"][i]), sample_idx=int(i))
                for i in idx]
        out = serving.submit(reqs)
        rewards = [o["reward"] for o in out]
        actions = [o["action"] for o in out]
        print(f"wave {wave + 1}: mean_reward={np.mean(rewards):.3f} "
              f"action_mix={np.bincount(actions, minlength=pool.K)} "
              f"tokens[0]={out[0]['tokens'][:5]}")
        if (wave + 1) % args.train_every == 0:
            metrics = serving.end_slice(epochs=2)
            print(f"  [slice end] trained: "
                  f"{ {k: round(v, 4) for k, v in metrics.items()} }")
    real = {e.name: e.decode_steps for e in engines if e.real_decode}
    print(f"served {len(serving.log)} requests total; "
          f"avg reward {np.mean([r['reward'] for r in serving.log]):.3f}; "
          f"real decode steps {real}")


if __name__ == "__main__":
    main()
