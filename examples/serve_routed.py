"""End-to-end driver (deliverable (b)): serve batched requests through the
FULL stack — NeuralUCB router in front of a pool of REAL models (reduced
variants of the assigned architectures, running actual prefill+decode on
CPU), with bandit feedback closing the loop, Algorithm-1 style slices.

    PYTHONPATH=src python examples/serve_routed.py [--waves 6 --wave-size 16]
"""
import argparse
import dataclasses

import numpy as np

from repro.configs import get_config
from repro.core.policy import NeuralUCBRouter
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim
from repro.serving import Request, RoutedServingPool, ServingEngine

# the serving pool: three assigned architectures spanning dense/SSM/MoE
POOL_ARCHS = ["llama3.2-3b", "mamba2-130m", "granite-moe-1b-a400m"]
# per-token chip-seconds derived from each arch's decode roofline terms
# (benchmarks/artifacts/dryrun) x an illustrative $/chip-hour, rescaled to
# the RouterBench cost range
COST_PER_TOKEN = [2.0e-4, 1.5e-5, 6.0e-5]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--waves", type=int, default=6)
    ap.add_argument("--wave-size", type=int, default=16)
    ap.add_argument("--train-every", type=int, default=2)
    args = ap.parse_args()

    print("building pool:", POOL_ARCHS)
    engines = []
    for i, arch in enumerate(POOL_ARCHS):
        cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
        engines.append(ServingEngine(cfg, seed=i, max_seq=64))

    env = RouterBenchSim(seed=0, n_samples=2000, n_slices=4)
    # quality replay restricted to the pool's K=3 columns (paper protocol:
    # graded feedback comes from the benchmark tables)
    qcols = [0, 5, 2]  # gpt4-ish / mixtral-ish / gpt35-ish quality profiles
    quality = env.data["quality"][:, qcols]

    ucfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1],
                            num_actions=len(engines))
    router = NeuralUCBRouter(ucfg, seed=0, batch_size=64)
    pool = RoutedServingPool(router, engines, COST_PER_TOKEN,
                             quality_table=quality, c_max=0.5, max_batch=8)

    rng = np.random.default_rng(0)
    for wave in range(args.waves):
        idx = rng.integers(0, env.n, size=args.wave_size)
        reqs = [Request(tokens=rng.integers(1, 200,
                                            size=int(rng.integers(4, 12))),
                        x_emb=env.x_emb[i], x_feat=env.data["x_feat"][i],
                        domain=int(env.data["domain"][i]), sample_idx=int(i))
                for i in idx]
        out = pool.submit(reqs)
        rewards = [o["reward"] for o in out]
        actions = [o["action"] for o in out]
        print(f"wave {wave + 1}: mean_reward={np.mean(rewards):.3f} "
              f"action_mix={np.bincount(actions, minlength=len(engines))} "
              f"tokens[0]={out[0]['tokens'][:5]}")
        if (wave + 1) % args.train_every == 0:
            metrics = pool.end_slice(epochs=2)
            print(f"  [slice end] trained: "
                  f"{ {k: round(v, 4) for k, v in metrics.items()} }")
    print(f"served {len(pool.log)} requests total; "
          f"avg reward {np.mean([r['reward'] for r in pool.log]):.3f}")


if __name__ == "__main__":
    main()
