"""Paper Figure 3 at example scale: run the online protocol once per text
encoder and print the comparison (full-scale version: benchmarks/bench_encoders).

    PYTHONPATH=src python examples/encoder_ablation.py [--samples 5000]
"""
import argparse

from repro.core.policy import NeuralUCBRouter
from repro.core.protocol import run_protocol, summarize
from repro.core.utilitynet import UtilityNetConfig
from repro.data.encoders import ENCODERS
from repro.data.routerbench import RouterBenchSim, generate_routerbench


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=5000)
    ap.add_argument("--slices", type=int, default=4)
    args = ap.parse_args()

    data = generate_routerbench(seed=0, n_samples=args.samples)
    rows = []
    for enc in ENCODERS:
        env = RouterBenchSim(seed=0, encoder=enc, n_slices=args.slices,
                             data=data)
        cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1], num_actions=env.K)
        res = run_protocol(env, {"nucb": NeuralUCBRouter(cfg, seed=0)},
                           epochs=3, verbose=False)
        s = summarize(res)["nucb"]
        rows.append((enc, s["avg_reward"]))
        print(f"{enc:35s} avg_reward={s['avg_reward']:.4f}")
    best = max(rows, key=lambda r: r[1])
    print(f"\nbest encoder: {best[0]} ({best[1]:.4f}) — expected ordering: "
          "mpnet ~ MiniLM > Qwen3-0.6B > e5-large-instruct (paper Fig. 3)")


if __name__ == "__main__":
    main()
