"""Quickstart: train the NeuralUCB router online over a small RouterBench
slice stream and compare against the paper's baselines.

    PYTHONPATH=src python examples/quickstart.py [--samples 6000 --slices 5]
"""
import argparse
import json

from repro.core.baselines import FixedActionPolicy, RandomPolicy, RouteLLMBert
from repro.core.policy import NeuralUCBRouter
from repro.core.protocol import run_protocol, summarize
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=6000)
    ap.add_argument("--slices", type=int, default=5)
    ap.add_argument("--epochs", type=int, default=4)
    args = ap.parse_args()

    env = RouterBenchSim(seed=0, n_samples=args.samples, n_slices=args.slices)
    print(f"RouterBench surrogate: {env.n} samples, {env.K} models, "
          f"{args.slices} slices; C_max=${env.c_max:.2f}")

    strong, weak = env.strong_weak_actions()
    rl = RouteLLMBert(strong, weak, env.x_emb.shape[1])
    b0 = env.slice_batch(0)
    rl.fit_offline(b0["x_emb"], b0["quality"][:, strong],
                   b0["quality"][:, weak])

    cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1], num_actions=env.K)
    policies = {
        "neuralucb": NeuralUCBRouter(cfg, seed=0),
        "random": RandomPolicy(env.K, seed=1),
        "min-cost": FixedActionPolicy(env.min_cost_action()),
        "routellm-bert": rl,
    }
    results = run_protocol(env, policies, epochs=args.epochs)
    print(json.dumps(summarize(results), indent=2))


if __name__ == "__main__":
    main()
