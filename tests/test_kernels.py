"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba2_ssd.ops import ssd_chunk_scan
from repro.kernels.mamba2_ssd.ref import ssd_ref
from repro.kernels.ucb_score.ops import ucb_score
from repro.kernels.ucb_score.ref import ucb_score_ref

ATOL = {jnp.float32: 3e-5, jnp.bfloat16: 5e-2}


@pytest.mark.parametrize("B,H,KV,Sq,Sk,D", [
    (2, 4, 2, 256, 256, 64),
    (1, 8, 4, 300, 300, 128),
    (2, 2, 2, 128, 512, 64),
    (1, 4, 1, 130, 260, 80),   # ragged + padded head_dim + MQA
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, KV, Sq, Sk, D, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, KV, Sk, D), dtype)
    v = jax.random.normal(ks[2], (B, KV, Sk, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=128, block_k=128)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=ATOL[dtype], rtol=ATOL[dtype])


@pytest.mark.parametrize("B,H,KV,S,D,pos,window", [
    (2, 8, 4, 512, 64, 300, 0),
    (1, 16, 8, 2048, 128, 2047, 0),
    (2, 4, 4, 384, 64, 100, 64),
    (1, 8, 8, 256, 96, 0, 0),   # first decode step, padded head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, H, KV, S, D, pos, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, D), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, D), dtype)
    out = decode_attention(q, k, v, pos, window=window, block_s=128)
    ref = decode_attention_ref(q, k, v, pos, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=ATOL[dtype], rtol=ATOL[dtype])


@pytest.mark.parametrize("B,L,H,P,N,cs", [
    (2, 128, 8, 64, 32, 32),
    (1, 100, 4, 32, 64, 32),   # ragged length
    (2, 64, 16, 64, 128, 64),
    (1, 96, 24, 64, 128, 32),  # mamba2-130m head count (HB=8 path)
])
def test_ssd_sweep(B, L, H, P, N, cs):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, L, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, L, N))
    Cm = jax.random.normal(ks[4], (B, L, N))
    y, st = ssd_chunk_scan(x, dt, A, Bm, Cm, chunk=cs)
    yr, str_ = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_), atol=2e-3,
                               rtol=2e-3)


def test_ssd_carries_state_across_calls():
    """Chunked scan with an initial state == one long scan split in two."""
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, L, H, P, N = 1, 64, 4, 32, 32
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, L, N))
    Cm = jax.random.normal(ks[4], (B, L, N))
    y_full, st_full = ssd_chunk_scan(x, dt, A, Bm, Cm, chunk=16)
    y1, st1 = ssd_chunk_scan(x[:, :32], dt[:, :32], A, Bm[:, :32],
                             Cm[:, :32], chunk=16)
    y2, st2 = ssd_chunk_scan(x[:, 32:], dt[:, 32:], A, Bm[:, 32:],
                             Cm[:, 32:], chunk=16, init_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("T,K,F", [(64, 11, 129), (128, 11, 257), (7, 3, 50)])
@pytest.mark.parametrize("beta", [0.0, 1.0, 2.5])
def test_ucb_score_sweep(T, K, F, beta):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    g = jax.random.normal(ks[0], (T, K, F), jnp.float32)
    Lm = jax.random.normal(ks[1], (F, F)) * 0.1
    ainv = Lm @ Lm.T + jnp.eye(F)
    mu = jax.random.normal(ks[2], (T, K))
    # interpret=True pins the Pallas path: the default now self-resolves
    # to the jnp ref off-TPU (repro.kernels.backend), which would make
    # this parity check vacuous on CPU CI
    out = ucb_score(g, ainv, mu, beta, block_r=128, interpret=True)
    ref = ucb_score_ref(g, ainv, mu, beta)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)
