"""Unit + property tests for the utility reward (paper Eq. 1)."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep — fall back to the local stub
    from _hypothesis_stub import given, settings, st

from repro.core.reward import normalize_cost, utility_reward


def test_zero_cost_keeps_quality():
    assert float(utility_reward(0.8, 0.0, 1.0)) == np.float32(0.8)


def test_max_cost_applies_full_penalty():
    r = float(utility_reward(1.0, 3.0, 3.0, cost_lambda=1.0))
    assert abs(r - np.exp(-1.0)) < 1e-6


def test_monotone_decreasing_in_cost():
    costs = jnp.linspace(0.0, 2.0, 50)
    r = np.asarray(utility_reward(1.0, costs, 2.0))
    assert np.all(np.diff(r) < 0)


@settings(max_examples=200, deadline=None)
@given(q=st.floats(0, 1), c=st.floats(0, 100), cmax=st.floats(0.01, 100),
       lam=st.floats(0.01, 5))
def test_reward_bounded(q, c, cmax, lam):
    c = min(c, cmax)
    r = float(utility_reward(q, c, cmax, lam))
    assert -1e-6 <= r <= q + 1e-6


@settings(max_examples=100, deadline=None)
@given(c=st.floats(0, 50), cmax=st.floats(0.01, 50))
def test_cost_normalization_range(c, cmax):
    c = min(c, cmax)
    ct = float(normalize_cost(c, cmax))
    assert -1e-6 <= ct <= 1.0 + 1e-6


@settings(max_examples=50, deadline=None)
@given(q=st.floats(0.01, 1), c=st.floats(0.01, 10))
def test_reward_scale_invariance_of_ordering(q, c):
    """Reordering models never changes under a global cost rescale (the
    log normalization uses the same C_max for every arm)."""
    cmax = 20.0
    r1a = float(utility_reward(q, c, cmax))
    r1b = float(utility_reward(q, 2 * c, cmax))
    assert r1a >= r1b


@settings(max_examples=100, deadline=None)
@given(q1=st.floats(0.0, 1.0), q2=st.floats(0.0, 1.0),
       c=st.floats(0.0, 50.0), lam=st.floats(0.01, 5))
def test_reward_monotone_increasing_in_quality(q1, q2, c, lam):
    """At fixed cost, more quality never hurts: the cost factor is a
    positive multiplier independent of q."""
    lo, hi = sorted((q1, q2))
    r_lo = float(utility_reward(lo, c, 50.0, lam))
    r_hi = float(utility_reward(hi, c, 50.0, lam))
    assert r_hi >= r_lo - 1e-7


@settings(max_examples=100, deadline=None)
@given(q=st.floats(0.01, 1.0), over=st.floats(1.0, 100.0),
       lam=st.floats(0.01, 5))
def test_cost_above_cmax_penalized_beyond_full_clamp(q, over, lam):
    """Costs past C_max push the normalized cost past 1 (no hard clamp):
    the reward is strictly below the full-penalty floor q*exp(-lam) —
    the behavior a price-shocked arm relies on (DESIGN.md §9.1)."""
    cmax = 10.0
    r_at_cap = float(utility_reward(q, cmax, cmax, lam))
    r_over = float(utility_reward(q, cmax * over, cmax, lam))
    assert abs(r_at_cap - q * np.exp(-lam)) < 1e-5
    assert r_over <= r_at_cap + 1e-7
    if over > 1.0:
        assert r_over < r_at_cap


@settings(max_examples=100, deadline=None)
@given(n=st.integers(1, 64), k=st.integers(1, 8), lam=st.floats(0.1, 3))
def test_reward_table_bounds_elementwise(n, k, lam):
    """Whole-table form (the env generator's path): every entry lies in
    [0, q] and equals the scalar form."""
    rng = np.random.default_rng(n * 100 + k)
    q = rng.uniform(0, 1, (n, k)).astype(np.float32)
    c = rng.uniform(0, 5, (n, k)).astype(np.float32)
    table = np.asarray(utility_reward(jnp.asarray(q), jnp.asarray(c),
                                      5.0, lam))
    assert table.shape == (n, k)
    assert (table >= -1e-7).all() and (table <= q + 1e-6).all()
    one = float(utility_reward(float(q[0, 0]), float(c[0, 0]), 5.0, lam))
    assert abs(one - table[0, 0]) < 1e-6
