"""Unit + property tests for the utility reward (paper Eq. 1)."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep — fall back to the local stub
    from _hypothesis_stub import given, settings, st

from repro.core.reward import normalize_cost, utility_reward


def test_zero_cost_keeps_quality():
    assert float(utility_reward(0.8, 0.0, 1.0)) == np.float32(0.8)


def test_max_cost_applies_full_penalty():
    r = float(utility_reward(1.0, 3.0, 3.0, cost_lambda=1.0))
    assert abs(r - np.exp(-1.0)) < 1e-6


def test_monotone_decreasing_in_cost():
    costs = jnp.linspace(0.0, 2.0, 50)
    r = np.asarray(utility_reward(1.0, costs, 2.0))
    assert np.all(np.diff(r) < 0)


@settings(max_examples=200, deadline=None)
@given(q=st.floats(0, 1), c=st.floats(0, 100), cmax=st.floats(0.01, 100),
       lam=st.floats(0.01, 5))
def test_reward_bounded(q, c, cmax, lam):
    c = min(c, cmax)
    r = float(utility_reward(q, c, cmax, lam))
    assert -1e-6 <= r <= q + 1e-6


@settings(max_examples=100, deadline=None)
@given(c=st.floats(0, 50), cmax=st.floats(0.01, 50))
def test_cost_normalization_range(c, cmax):
    c = min(c, cmax)
    ct = float(normalize_cost(c, cmax))
    assert -1e-6 <= ct <= 1.0 + 1e-6


@settings(max_examples=50, deadline=None)
@given(q=st.floats(0.01, 1), c=st.floats(0.01, 10))
def test_reward_scale_invariance_of_ordering(q, c):
    """Reordering models never changes under a global cost rescale (the
    log normalization uses the same C_max for every arm)."""
    cmax = 20.0
    r1a = float(utility_reward(q, c, cmax))
    r1b = float(utility_reward(q, 2 * c, cmax))
    assert r1a >= r1b
