"""Integration: the simulated online protocol (Algorithm 1) end to end on a
small stream — NeuralUCB must clearly beat random and approach/exceed
min-cost; the replay/Sherman-Morrison/rebuild machinery must hold together.
"""
import numpy as np
import pytest

from repro.core.baselines import FixedActionPolicy, LinUCB, RandomPolicy
from repro.core.policy import NeuralUCBRouter
from repro.core.protocol import run_protocol, summarize
from repro.core.replay import ReplayBuffer
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim


@pytest.fixture(scope="module")
def small_env():
    return RouterBenchSim(seed=0, n_samples=4000, n_slices=4)


def test_protocol_end_to_end(small_env):
    env = small_env
    cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1], num_actions=env.K)
    pols = {
        "neuralucb": NeuralUCBRouter(cfg, seed=0, batch_size=128),
        "random": RandomPolicy(env.K, seed=1),
        "min-cost": FixedActionPolicy(env.min_cost_action()),
    }
    res = run_protocol(env, pols, epochs=3, verbose=False)
    summ = summarize(res)
    assert summ["neuralucb"]["avg_reward"] > summ["random"]["avg_reward"] + 0.1
    # cumulative curves are monotone
    assert all(b >= a for a, b in zip(res["neuralucb"]["cum_reward"],
                                      res["neuralucb"]["cum_reward"][1:]))
    # action histogram covers the pool during warm start
    assert (res["neuralucb"]["action_hist"][0] > 0).sum() >= env.K - 2


def test_router_decide_shapes(small_env):
    env = small_env
    cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1], num_actions=env.K)
    router = NeuralUCBRouter(cfg, seed=0)
    b = env.slice_batch(0)
    dec = router.decide(b["x_emb"][:32], b["x_feat"][:32], b["domain"][:32])
    assert dec["action"].shape == (32,)
    assert dec["action"].min() >= 0 and dec["action"].max() < env.K
    assert dec["g"].shape == (32, cfg.ucb_feature_dim)
    router.update(b["x_emb"][:32], b["x_feat"][:32], b["domain"][:32], dec,
                  b["reward"][np.arange(32), dec["action"]])
    assert len(router.buffer) == 32


def test_warm_start_then_ucb(small_env):
    env = small_env
    cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1], num_actions=env.K)
    router = NeuralUCBRouter(cfg, seed=0, batch_size=64)
    assert router.warm
    b = env.slice_batch(0)
    dec = router.decide(b["x_emb"][:128], b["x_feat"][:128], b["domain"][:128])
    router.update(b["x_emb"][:128], b["x_feat"][:128], b["domain"][:128],
                  dec, b["reward"][np.arange(128), dec["action"]])
    router.end_slice(epochs=1)
    assert not router.warm
    dec2 = router.decide(b["x_emb"][:8], b["x_feat"][:8], b["domain"][:8])
    assert dec2["action"].shape == (8,)


def _fill_buffer(buf: ReplayBuffer, n: int, emb: int = 8, feat: int = 4):
    rng = np.random.default_rng(0)
    buf.add_batch(rng.normal(size=(n, emb)), rng.normal(size=(n, feat)),
                  rng.integers(0, 3, n), rng.integers(0, 5, n),
                  rng.uniform(size=n), rng.integers(0, 2, n))


def test_replay_short_buffer_yields_tail():
    """Regression: len(buffer) < batch_size used to yield NOTHING, so
    train() silently did zero SGD steps on early slices and small
    serving pools. The tail must come out as one short minibatch."""
    buf = ReplayBuffer(8, 4)
    _fill_buffer(buf, 40)
    mbs = list(buf.minibatches(np.random.default_rng(1), batch_size=64))
    assert len(mbs) == 1
    assert len(mbs[0]["action"]) == 40


def test_replay_epoch_covers_tail():
    """An epoch covers EVERY stored sample: full batches plus the short
    shuffle tail (dropping it under-trained on up to batch_size-1
    samples per epoch; tests/test_replay_buffer.py holds the full
    coverage property). drop_tail=True remains for jit-hot callers that
    need fixed shapes."""
    buf = ReplayBuffer(8, 4)
    _fill_buffer(buf, 100)
    mbs = list(buf.minibatches(np.random.default_rng(1), batch_size=64))
    assert [len(m["action"]) for m in mbs] == [64, 36]
    buf2 = ReplayBuffer(8, 4)
    _fill_buffer(buf2, 128)
    mbs2 = list(buf2.minibatches(np.random.default_rng(1), batch_size=64))
    assert [len(m["action"]) for m in mbs2] == [64, 64]
    mbs3 = list(buf.minibatches(np.random.default_rng(1), batch_size=64,
                                drop_tail=True))
    assert [len(m["action"]) for m in mbs3] == [64]


def test_router_trains_on_short_buffer(small_env):
    """The host router must take SGD steps even when the buffer is
    smaller than one batch (the bug left params untouched)."""
    env = small_env
    cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1], num_actions=env.K)
    router = NeuralUCBRouter(cfg, seed=0, batch_size=256)
    b = env.slice_batch(0)
    n = 48                                 # < batch_size
    dec = router.decide(b["x_emb"][:n], b["x_feat"][:n], b["domain"][:n])
    router.update(b["x_emb"][:n], b["x_feat"][:n], b["domain"][:n], dec,
                  b["reward"][np.arange(n), dec["action"]])
    before = np.asarray(router.params["trunk1"]["w"]).copy()
    metrics = router.train(epochs=1)
    assert metrics, "train() returned no metrics -> no SGD step ran"
    assert not np.array_equal(before, np.asarray(router.params["trunk1"]["w"]))


def test_linucb_runs(small_env):
    env = small_env
    pol = LinUCB(env.K, env.x_emb.shape[1])
    b = env.slice_batch(0)
    a = pol.decide(b["x_emb"][:64], b["x_feat"][:64], b["domain"][:64])
    pol.update(b["x_emb"][:64], b["x_feat"][:64], b["domain"][:64], a,
               b["reward"][np.arange(64), a])
    a2 = pol.decide(b["x_emb"][:16], b["x_feat"][:16], b["domain"][:16])
    assert a2.shape == (16,)
