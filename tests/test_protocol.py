"""Integration: the simulated online protocol (Algorithm 1) end to end on a
small stream — NeuralUCB must clearly beat random and approach/exceed
min-cost; the replay/Sherman-Morrison/rebuild machinery must hold together.
"""
import numpy as np
import pytest

from repro.core.baselines import FixedActionPolicy, LinUCB, RandomPolicy
from repro.core.policy import NeuralUCBRouter
from repro.core.protocol import run_protocol, summarize
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim


@pytest.fixture(scope="module")
def small_env():
    return RouterBenchSim(seed=0, n_samples=4000, n_slices=4)


def test_protocol_end_to_end(small_env):
    env = small_env
    cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1], num_actions=env.K)
    pols = {
        "neuralucb": NeuralUCBRouter(cfg, seed=0, batch_size=128),
        "random": RandomPolicy(env.K, seed=1),
        "min-cost": FixedActionPolicy(env.min_cost_action()),
    }
    res = run_protocol(env, pols, epochs=3, verbose=False)
    summ = summarize(res)
    assert summ["neuralucb"]["avg_reward"] > summ["random"]["avg_reward"] + 0.1
    # cumulative curves are monotone
    assert all(b >= a for a, b in zip(res["neuralucb"]["cum_reward"],
                                      res["neuralucb"]["cum_reward"][1:]))
    # action histogram covers the pool during warm start
    assert (res["neuralucb"]["action_hist"][0] > 0).sum() >= env.K - 2


def test_router_decide_shapes(small_env):
    env = small_env
    cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1], num_actions=env.K)
    router = NeuralUCBRouter(cfg, seed=0)
    b = env.slice_batch(0)
    dec = router.decide(b["x_emb"][:32], b["x_feat"][:32], b["domain"][:32])
    assert dec["action"].shape == (32,)
    assert dec["action"].min() >= 0 and dec["action"].max() < env.K
    assert dec["g"].shape == (32, cfg.ucb_feature_dim)
    router.update(b["x_emb"][:32], b["x_feat"][:32], b["domain"][:32], dec,
                  b["reward"][np.arange(32), dec["action"]])
    assert len(router.buffer) == 32


def test_warm_start_then_ucb(small_env):
    env = small_env
    cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1], num_actions=env.K)
    router = NeuralUCBRouter(cfg, seed=0, batch_size=64)
    assert router.warm
    b = env.slice_batch(0)
    dec = router.decide(b["x_emb"][:128], b["x_feat"][:128], b["domain"][:128])
    router.update(b["x_emb"][:128], b["x_feat"][:128], b["domain"][:128],
                  dec, b["reward"][np.arange(128), dec["action"]])
    router.end_slice(epochs=1)
    assert not router.warm
    dec2 = router.decide(b["x_emb"][:8], b["x_feat"][:8], b["domain"][:8])
    assert dec2["action"].shape == (8,)


def test_linucb_runs(small_env):
    env = small_env
    pol = LinUCB(env.K, env.x_emb.shape[1])
    b = env.slice_batch(0)
    a = pol.decide(b["x_emb"][:64], b["x_feat"][:64], b["domain"][:64])
    pol.update(b["x_emb"][:64], b["x_feat"][:64], b["domain"][:64], a,
               b["reward"][np.arange(64), a])
    a2 = pol.decide(b["x_emb"][:16], b["x_feat"][:16], b["domain"][:16])
    assert a2.shape == (16,)
