"""Protocol-layer regression: the device-resident engine (repro.sim) must
reproduce the seed host loop (repro.core.protocol.run_protocol) on the
same slice stream — deterministic policies match per-slice within float
tolerance — and the shared summarize() must exclude slice 1."""
import numpy as np
import pytest

from repro.core.baselines import EmpiricalGreedy, FixedActionPolicy
from repro.core.protocol import run_protocol, summarize
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim
from repro.sim import (
    DeviceNeuralUCB,
    DeviceReplayEnv,
    fixed_policy,
    greedy_policy,
    random_policy,
    run_baseline_device,
    run_baseline_sweep,
    run_protocol_device,
)


@pytest.fixture(scope="module")
def envs():
    henv = RouterBenchSim(seed=0, n_samples=2500, n_slices=4)
    return henv, DeviceReplayEnv.from_host(henv)


def test_device_env_replays_same_stream(envs):
    henv, denv = envs
    assert denv.n_slices == henv.n_slices and denv.K == henv.K
    sizes = denv.slice_sizes
    for t in range(henv.n_slices):
        n = len(henv.slices[t])
        assert sizes[t] == n
        np.testing.assert_array_equal(
            np.asarray(denv.idx[t])[:n], henv.slices[t])


def test_deterministic_policies_match_host_loop(envs):
    """Same seeds/stream -> same per-slice metrics (ISSUE acceptance)."""
    henv, denv = envs
    host = run_protocol(henv, {
        "min-cost": FixedActionPolicy(henv.min_cost_action()),
        "max-quality-arm": FixedActionPolicy(henv.max_quality_action()),
        "greedy": EmpiricalGreedy(henv.K),
    }, verbose=False)
    dev = run_protocol_device(denv, {
        "min-cost": fixed_policy(denv.min_cost_action(), "min-cost"),
        "max-quality-arm": fixed_policy(denv.max_quality_action(),
                                        "max-quality"),
        "greedy": greedy_policy(denv.K),
    })
    assert denv.min_cost_action() == henv.min_cost_action()
    assert denv.max_quality_action() == henv.max_quality_action()
    for name in host:
        for key in ("avg_reward", "cum_reward", "avg_cost", "avg_quality"):
            np.testing.assert_allclose(
                dev[name][key], host[name][key], rtol=2e-5, atol=1e-5,
                err_msg=f"{name}/{key}")
        np.testing.assert_array_equal(dev[name]["action_hist"],
                                      host[name]["action_hist"])


def test_random_policy_matches_in_distribution(envs):
    """jax-PRNG random can't bit-match numpy's; check the mean reward is
    statistically indistinguishable from the per-slice mean over arms."""
    henv, denv = envs
    res = run_baseline_device(denv, random_policy(denv.K), seed=3)
    expected = float(henv.reward_table.mean())
    got = float(np.mean(res["avg_reward"]))
    assert abs(got - expected) < 0.05
    hist = res["action_hist"].sum(axis=0)
    assert (hist > 0).all()                    # every arm gets traffic
    assert hist.sum() == denv.slice_sizes.sum()


def test_multi_seed_sweep_shapes_and_variation(envs):
    _, denv = envs
    out = run_baseline_sweep(denv, random_policy(denv.K), seeds=range(5))
    assert out["avg_reward"].shape == (5, denv.n_slices)
    assert out["action_hist"].shape == (5, denv.n_slices, denv.K)
    # distinct seeds -> distinct draws
    assert len({round(float(v), 6)
                for v in out["avg_reward"].mean(axis=1)}) > 1


def test_device_neuralucb_learns_and_is_monotone(envs):
    henv, denv = envs
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
    nucb = DeviceNeuralUCB(denv, cfg, seed=0, batch_size=128)
    res = nucb.run(epochs=3)
    rand = run_baseline_device(denv, random_policy(denv.K), seed=1)
    summ = summarize({"neuralucb": res, "random": rand})
    assert summ["neuralucb"]["avg_reward"] > summ["random"]["avg_reward"] + 0.1
    cum = res["cum_reward"]
    assert all(b >= a for a, b in zip(cum, cum[1:]))
    # warm slice covers most of the pool
    assert (res["action_hist"][0] > 0).sum() >= denv.K - 2


def test_summarize_skip_first_excludes_slice_1(envs):
    """summarize(skip_first=True) must drop slice 1 (paper §4.2) — checked
    against hand-computed means on an engine result."""
    _, denv = envs
    res = {"p": run_baseline_device(denv, fixed_policy(0, "p"), seed=0)}
    full = summarize(res, skip_first=False)["p"]
    skip = summarize(res, skip_first=True)["p"]
    np.testing.assert_allclose(
        skip["avg_reward"], np.mean(res["p"]["avg_reward"][1:]), rtol=1e-6)
    np.testing.assert_allclose(
        full["avg_reward"], np.mean(res["p"]["avg_reward"]), rtol=1e-6)
    # both keep the final cumulative total
    assert skip["final_cum_reward"] == res["p"]["cum_reward"][-1]
