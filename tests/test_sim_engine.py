"""Protocol-layer regression: the device-resident engine (repro.sim) must
reproduce the seed host loop (repro.core.protocol.run_protocol) on the
same slice stream — deterministic policies match per-slice within float
tolerance — the single-dispatch scanned NeuralUCB runner must match the
host-stepped parity reference, and the shared summarize() must exclude
slice 1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import EmpiricalGreedy, FixedActionPolicy
from repro.core.protocol import run_protocol, summarize
from repro.core.utilitynet import UtilityNetConfig, init_utilitynet
from repro.data.routerbench import RouterBenchSim
from repro.sim import (
    DeviceNeuralUCB,
    DeviceReplayEnv,
    fixed_policy,
    greedy_policy,
    random_policy,
    run_baseline_device,
    run_baseline_sweep,
    run_neuralucb_device,
    run_neuralucb_sweep,
    run_protocol_device,
    sweep_point_results,
)
from repro.sim.engine import _cum_valid, _sample_valid


@pytest.fixture(scope="module")
def envs():
    henv = RouterBenchSim(seed=0, n_samples=2500, n_slices=4)
    return henv, DeviceReplayEnv.from_host(henv)


def test_device_env_replays_same_stream(envs):
    henv, denv = envs
    assert denv.n_slices == henv.n_slices and denv.K == henv.K
    sizes = denv.slice_sizes
    for t in range(henv.n_slices):
        n = len(henv.slices[t])
        assert sizes[t] == n
        np.testing.assert_array_equal(
            np.asarray(denv.idx[t])[:n], henv.slices[t])


def test_deterministic_policies_match_host_loop(envs):
    """Same seeds/stream -> same per-slice metrics (ISSUE acceptance)."""
    henv, denv = envs
    host = run_protocol(henv, {
        "min-cost": FixedActionPolicy(henv.min_cost_action()),
        "max-quality-arm": FixedActionPolicy(henv.max_quality_action()),
        "greedy": EmpiricalGreedy(henv.K),
    }, verbose=False)
    dev = run_protocol_device(denv, {
        "min-cost": fixed_policy(denv.min_cost_action(), "min-cost"),
        "max-quality-arm": fixed_policy(denv.max_quality_action(),
                                        "max-quality"),
        "greedy": greedy_policy(denv.K),
    })
    assert denv.min_cost_action() == henv.min_cost_action()
    assert denv.max_quality_action() == henv.max_quality_action()
    for name in host:
        for key in ("avg_reward", "cum_reward", "avg_cost", "avg_quality"):
            np.testing.assert_allclose(
                dev[name][key], host[name][key], rtol=2e-5, atol=1e-5,
                err_msg=f"{name}/{key}")
        np.testing.assert_array_equal(dev[name]["action_hist"],
                                      host[name]["action_hist"])


def test_random_policy_matches_in_distribution(envs):
    """jax-PRNG random can't bit-match numpy's; check the mean reward is
    statistically indistinguishable from the per-slice mean over arms."""
    henv, denv = envs
    res = run_baseline_device(denv, random_policy(denv.K), seed=3)
    expected = float(henv.reward_table.mean())
    got = float(np.mean(res["avg_reward"]))
    assert abs(got - expected) < 0.05
    hist = res["action_hist"].sum(axis=0)
    assert (hist > 0).all()                    # every arm gets traffic
    assert hist.sum() == denv.slice_sizes.sum()


def test_multi_seed_sweep_shapes_and_variation(envs):
    """Baseline sweeps emit the unified grid-annotated schema: metric
    leaves (G=1, n_seeds, T, ...) plus seed annotations, so any policy's
    sweep cell feeds summarize via sweep_point_results."""
    _, denv = envs
    out = run_baseline_sweep(denv, random_policy(denv.K), seeds=range(5))
    assert out["avg_reward"].shape == (1, 5, denv.n_slices)
    assert out["action_hist"].shape == (1, 5, denv.n_slices, denv.K)
    assert out["seeds"].tolist() == [0, 1, 2, 3, 4]
    # distinct seeds -> distinct draws
    assert len({round(float(v), 6)
                for v in out["avg_reward"][0].mean(axis=1)}) > 1
    # a sweep cell is summarize-compatible
    summ = summarize({"p": sweep_point_results(out, 0, 2)})
    assert np.isfinite(summ["p"]["avg_reward"])


def test_device_neuralucb_learns_and_is_monotone(envs):
    henv, denv = envs
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
    nucb = DeviceNeuralUCB(denv, cfg, seed=0, batch_size=128)
    res = nucb.run(epochs=3)
    rand = run_baseline_device(denv, random_policy(denv.K), seed=1)
    summ = summarize({"neuralucb": res, "random": rand})
    assert summ["neuralucb"]["avg_reward"] > summ["random"]["avg_reward"] + 0.1
    cum = res["cum_reward"]
    assert all(b >= a for a, b in zip(cum, cum[1:]))
    # warm slice covers most of the pool
    assert (res["action_hist"][0] > 0).sum() >= denv.K - 2


@pytest.fixture(scope="module")
def tiny_envs():
    """Smaller stream for the scanned-runner tests (compile cost)."""
    henv = RouterBenchSim(seed=0, n_samples=900, n_slices=3)
    return henv, DeviceReplayEnv.from_host(henv)


def test_scanned_matches_stepped_parity(tiny_envs):
    """ISSUE acceptance: the single-dispatch scanned runner and the
    host-stepped parity reference consume identical PRNG streams and run
    identical per-slice math — metrics must match (bit-exact on CPU; the
    tolerance absorbs cross-program fusion differences elsewhere)."""
    henv, denv = tiny_envs
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
    scanned = run_neuralucb_device(denv, cfg, seed=0, train_steps=32,
                                   batch_size=128)
    stepped = DeviceNeuralUCB(denv, cfg, seed=0, batch_size=128).run(
        train_steps=32, scan=False)
    for key in ("avg_reward", "cum_reward", "avg_cost", "avg_quality"):
        np.testing.assert_allclose(scanned[key], stepped[key],
                                   rtol=1e-4, atol=1e-4, err_msg=key)
    np.testing.assert_array_equal(scanned["action_hist"],
                                  stepped["action_hist"])


def test_run_delegates_to_scan_and_matches(tiny_envs):
    """run(scan='auto') with a fixed schedule must take the scanned path
    and agree with an explicitly scanned run; scan=True after a stepped
    run must refuse."""
    henv, denv = tiny_envs
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
    nucb = DeviceNeuralUCB(denv, cfg, seed=3, batch_size=128)
    auto = nucb.run(train_steps=32)
    ref = run_neuralucb_device(denv, cfg, seed=3, train_steps=32,
                               batch_size=128)
    np.testing.assert_allclose(auto["avg_reward"], ref["avg_reward"],
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError):
        nucb.run(train_steps=32, scan=True)   # state already consumed


def test_neuralucb_sweep_shapes_and_determinism(tiny_envs):
    henv, denv = tiny_envs
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
    kw = dict(seeds=[0, 1], betas=[0.5, 1.0], tau_gs=[0.5],
              train_steps=32, batch_size=128)
    sw = run_neuralucb_sweep(denv, cfg, **kw)
    T = denv.n_slices
    assert sw["avg_reward"].shape == (2, 2, T)
    assert sw["action_hist"].shape == (2, 2, T, denv.K)
    assert sw["beta"].tolist() == [0.5, 1.0]
    assert sw["seeds"].tolist() == [0, 1]
    # same seeds/grid -> bit-identical metrics (single cached dispatch)
    sw2 = run_neuralucb_sweep(denv, cfg, **kw)
    np.testing.assert_array_equal(sw["avg_reward"], sw2["avg_reward"])
    # distinct seeds genuinely differ (uncorrelated init + exploration)
    assert not np.array_equal(sw["avg_reward"][0, 0], sw["avg_reward"][0, 1])
    # a sweep cell is exactly the corresponding single scanned run (pin
    # the jnp backend: sweeps always use it, but a bare single run would
    # pick the Pallas kernel on TPU and score with a different kernel)
    single = run_neuralucb_device(denv, cfg, seed=1, beta=0.5,
                                  train_steps=32, batch_size=128,
                                  ucb_backend="jnp")
    np.testing.assert_allclose(sw["avg_reward"][0, 1], single["avg_reward"],
                               rtol=1e-5, atol=1e-6)
    # sweep cells feed the shared summarize() unchanged
    summ = summarize({"p": sweep_point_results(sw, 0, 1)})
    assert np.isfinite(summ["p"]["avg_reward"])


def test_neuralucb_sweep_cost_lambda_axis(tiny_envs):
    """Sweeping cost_lambda re-derives the reward table on device: lambda
    equal to the env's must reproduce the env-table sentinel run, and a
    harsher lambda must lower the measured reward."""
    henv, denv = tiny_envs
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
    sw = run_neuralucb_sweep(denv, cfg, seeds=[0],
                             cost_lambdas=[None, henv.cost_lambda, 4.0],
                             train_steps=32, batch_size=128)
    np.testing.assert_allclose(sw["avg_reward"][0, 0],
                               sw["avg_reward"][1, 0], rtol=1e-5, atol=1e-6)
    assert (sw["avg_reward"][2, 0].mean()
            < sw["avg_reward"][0, 0].mean())


def test_device_neuralucb_prng_streams_decorrelated(tiny_envs):
    """Regression (PR-1 bug): PRNGKey(seed) fed BOTH init_utilitynet and
    the run stream. Now one split feeds both consumers."""
    henv, denv = tiny_envs
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
    nucb = DeviceNeuralUCB(denv, cfg, seed=7)
    k_init, k_run = jax.random.split(jax.random.PRNGKey(7))
    np.testing.assert_array_equal(nucb.key, k_run)
    expect = init_utilitynet(k_init, cfg)
    np.testing.assert_array_equal(nucb.params["text1"]["w"],
                                  expect["text1"]["w"])
    # and the old correlated layout is gone
    old = init_utilitynet(jax.random.PRNGKey(7), cfg)
    assert not np.array_equal(nucb.params["text1"]["w"], old["text1"]["w"])


def test_sample_valid_never_hits_padding(envs):
    """Regression (PR-1 bug): replay minibatch indices were drawn from the
    padded (t+1)*S range, diluting batches by the padding fraction. The
    valid-prefix draw must only ever land on real samples."""
    _, denv = envs
    cum0 = _cum_valid(denv)
    t = denv.n_slices - 1
    count = cum0[t + 1]
    row, col = _sample_valid(jax.random.PRNGKey(0), 4096, cum0, count)
    row, col = np.asarray(row), np.asarray(col)
    assert row.min() >= 0 and row.max() <= t
    mask = np.asarray(denv.mask)
    assert (mask[row, col] == 1.0).all()
    # every slice gets sampled (uniform over the valid prefix)
    assert len(np.unique(row)) == denv.n_slices


def test_summarize_skip_first_excludes_slice_1(envs):
    """summarize(skip_first=True) must drop slice 1 (paper §4.2) — checked
    against hand-computed means on an engine result."""
    _, denv = envs
    res = {"p": run_baseline_device(denv, fixed_policy(0, "p"), seed=0)}
    full = summarize(res, skip_first=False)["p"]
    skip = summarize(res, skip_first=True)["p"]
    np.testing.assert_allclose(
        skip["avg_reward"], np.mean(res["p"]["avg_reward"][1:]), rtol=1e-6)
    np.testing.assert_allclose(
        full["avg_reward"], np.mean(res["p"]["avg_reward"]), rtol=1e-6)
    # both keep the final cumulative total
    assert skip["final_cum_reward"] == res["p"]["cum_reward"][-1]
