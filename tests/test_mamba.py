"""Mamba2 model-layer tests: the jnp chunked SSD inside repro.models must
match the sequential oracle, and the decode recurrence must continue it."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.mamba2_ssd.ref import ssd_ref
from repro.models.mamba import ssd_chunked, ssd_decode_step


def _inputs(seed, B=2, L=64, H=4, P=32, N=32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, L, N))
    Cm = jax.random.normal(ks[4], (B, L, N))
    return x, dt, A, Bm, Cm


def test_model_ssd_matches_sequential_oracle():
    x, dt, A, Bm, Cm = _inputs(0)
    D = jnp.zeros((4,))
    y, st = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=16)
    yr, str_ = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_), atol=2e-3,
                               rtol=2e-3)


def test_decode_step_continues_chunked_state():
    x, dt, A, Bm, Cm = _inputs(1, L=33)
    D = jnp.ones((4,))
    # process first 32 tokens chunked, then one decode step
    y0, st = ssd_chunked(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32],
                         D, chunk=16)
    y1, st1 = ssd_decode_step(x[:, 32], dt[:, 32], A, Bm[:, 32], Cm[:, 32],
                              D, st)
    y_full, st_full = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_full[:, 32]),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st_full),
                               atol=2e-3, rtol=2e-3)


def test_state_decays_without_input():
    """With x=0 the state decays monotonically (A<0): ||h_t|| decreasing."""
    B, H, P, N = 1, 2, 4, 4
    st = jnp.ones((B, H, P, N))
    A = -jnp.ones((H,))
    norms = []
    for _ in range(5):
        _, st = ssd_decode_step(jnp.zeros((B, H, P)), jnp.ones((B, H)), A,
                                jnp.zeros((B, N)), jnp.zeros((B, N)),
                                jnp.zeros((H,)), st)
        norms.append(float(jnp.linalg.norm(st)))
    assert all(b < a for a, b in zip(norms, norms[1:]))
