"""Physical arm pool (DESIGN.md §16): loud mapping validation, the
analytic decode-step cost model, pool compilation (bit-identical
across processes, pinned by crc32 — not ``hash()``), the
RouterBench-cost parity contract against the replay sweep, the
ArmPoolSpec codec (pre-PR-10 spec hashes must be untouched), and a
tiny end-to-end ``physical_pool`` run whose serve stage executes REAL
jitted decode steps for the small arm."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.armpool import (
    DEFAULT_RB_MAPPING,
    arm_roofline,
    build_pool_env,
    compile_pool,
    get_hardware_target,
    resolve_arms,
    resolve_mapping,
)
from repro.configs import get_config
from repro.data.routerbench import (
    RouterBenchSim,
    generate_routerbench,
    model_prices,
)
from repro.experiments import (
    ArmPoolSpec,
    DataSpec,
    make_preset,
    run_spec,
    spec_from_json,
    spec_hash,
    spec_to_json,
)
from repro.roofline import decode_step_costs
from repro.sim import DeviceReplayEnv, greedy_policy, run_baseline_device

ARMS4 = ("mamba2_130m", "llama3_2_3b", "mistral_nemo_12b",
         "jamba_1_5_large_398b")

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                         os.pardir))


@pytest.fixture(scope="module")
def data():
    return generate_routerbench(0, 400)


# -------------------------------------------------- loud validation --
def test_unknown_arch_raises_with_name():
    with pytest.raises(ValueError, match="no_such_model"):
        resolve_arms(("mamba2_130m", "no_such_model"))


def test_duplicate_arm_raises_with_name():
    with pytest.raises(ValueError, match="mamba2_130m"):
        resolve_arms(("mamba2_130m", "mamba2-130m"))  # alias == same arm


def test_empty_pool_raises():
    with pytest.raises(ValueError, match="empty"):
        resolve_arms(())


def test_mapping_override_for_absent_arm_raises():
    with pytest.raises(ValueError, match="gemma3_4b"):
        resolve_mapping(["mamba2_130m"], ["zephyr-7b"],
                        overrides=(("gemma3_4b", "gpt-4"),))


def test_unmapped_arm_raises():
    # an arm with no mapping entry must not pair positionally
    assert "custom_ft_7b" not in DEFAULT_RB_MAPPING
    with pytest.raises(ValueError, match="custom_ft_7b"):
        resolve_mapping(["custom_ft_7b"], ["gpt-4"])


def test_mapped_model_missing_from_tables_raises():
    with pytest.raises(ValueError, match="zephyr-7b"):
        resolve_mapping(["mamba2_130m"], ["gpt-4", "claude-v2"])


def test_pool_env_k_mismatch_raises(data):
    pool = compile_pool(ArmPoolSpec(arms=ARMS4), data)
    with pytest.raises(ValueError, match="K mismatch"):
        pool.validate_against(11, what="device env")


def test_unknown_hardware_target_raises():
    with pytest.raises(ValueError, match="moonbase"):
        get_hardware_target("moonbase")


# ------------------------------------------- decode-step cost model --
def test_decode_step_costs_scale_with_batch_and_params():
    small = get_config("mamba2_130m")
    big = get_config("mistral_nemo_12b")
    c1 = decode_step_costs(small, 4, 2048)
    c8 = decode_step_costs(small, 8, 2048)
    cb = decode_step_costs(big, 4, 2048)
    for k in ("flops", "hbm_bytes", "weight_bytes"):
        assert c1[k] > 0
    # flops scale ~linearly with batch; weight traffic does not
    assert c8["flops"] > 1.8 * c1["flops"]
    assert c8["weight_bytes"] == c1["weight_bytes"]
    # a 95x-params model costs far more per step
    assert cb["flops"] > 20 * c1["flops"]
    assert cb["weight_bytes"] > 20 * c1["weight_bytes"]


def test_attention_costs_grow_with_context_mamba_does_not():
    attn = get_config("mistral_nemo_12b")
    mamba = get_config("mamba2_130m")
    assert decode_step_costs(attn, 4, 4096)["kv_bytes"] \
        > decode_step_costs(attn, 4, 512)["kv_bytes"]
    assert decode_step_costs(mamba, 4, 4096)["kv_bytes"] \
        == decode_step_costs(mamba, 4, 512)["kv_bytes"]


def test_arm_roofline_economics():
    target = get_hardware_target("tpu-v5e")
    small = arm_roofline(get_config("mamba2_130m"), target,
                         batch=8, context=2048)
    big = arm_roofline(get_config("jamba_1_5_large_398b"), target,
                       batch=8, context=2048)
    assert small["chips"] == 1
    assert big["chips"] > 1              # 398B cannot fit one v5e HBM
    assert big["usd_per_token"] > 50 * small["usd_per_token"]
    assert small["step_s"] > 0 and big["step_s"] > small["step_s"]


# ------------------------------------------------- pool compilation --
def test_compiled_tables_shape_and_finiteness(data):
    aspec = ArmPoolSpec(arms=ARMS4)
    pool = compile_pool(aspec, data)
    n, K = 400, len(ARMS4)
    assert pool.K == K and pool.arms == ARMS4
    for t in (pool.quality, pool.cost, pool.latency_s):
        assert t.shape == (n, K) and t.dtype == np.float32
        assert np.isfinite(t).all()
    assert (pool.cost > 0).all() and (pool.latency_s > 0).all()
    # per-arm scalars follow the declared hardware, not the table order
    order = np.argsort(pool.params_b)
    assert list(order) == sorted(order, key=lambda i: pool.params_b[i])
    assert pool.cost_source == "roofline"


def test_compile_is_deterministic_in_process(data):
    aspec = ArmPoolSpec(arms=ARMS4)
    p1 = compile_pool(aspec, data)
    p2 = compile_pool(aspec, data)
    assert p1.checksum == p2.checksum
    np.testing.assert_array_equal(p1.cost, p2.cost)
    np.testing.assert_array_equal(p1.quality, p2.quality)


_CHILD = """
import json, sys
from repro.armpool import compile_pool
from repro.data.routerbench import generate_routerbench
from repro.experiments import ArmPoolSpec
pool = compile_pool(ArmPoolSpec(arms={arms!r}), generate_routerbench(0, 400))
print(json.dumps({{"checksum": pool.checksum}}))
"""


def test_compile_is_deterministic_cross_process(data):
    """crc32 over table bytes + arm names must agree across processes
    (``hash()`` would not: PYTHONHASHSEED)."""
    here = compile_pool(ArmPoolSpec(arms=ARMS4), data).checksum
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO_ROOT, "src"),
                    env.get("PYTHONPATH")) if p)
    out = subprocess.run(
        [sys.executable, "-c", _CHILD.format(arms=ARMS4)],
        capture_output=True, text=True, env=env, cwd=REPO_ROOT)
    assert out.returncode == 0, out.stderr[-2000:]
    assert json.loads(out.stdout)["checksum"] == here


def test_calibration_scales_small_arms_only(data):
    aspec = ArmPoolSpec(arms=ARMS4, calibrate=True,
                        calibrate_max_params=2_000_000_000)
    calls = []

    def fake_ratio(cfg, batch):
        calls.append(cfg.name)
        return {"ratio": 3.0, "step_s": 0.0, "analytic_step_s": 0.0}

    base = compile_pool(ArmPoolSpec(arms=ARMS4), data)
    pool = compile_pool(aspec, data, calibrate_fn=fake_ratio)
    # only the <=2B arms get measured; their step time is de-rated 3x
    assert calls == ["mamba2-130m"]
    np.testing.assert_allclose(pool.step_s[0], 3.0 * base.step_s[0])
    np.testing.assert_allclose(pool.step_s[1:], base.step_s[1:])
    assert pool.calibration is not None
    assert "mamba2_130m" in pool.calibration


def test_completion_backout_uses_name_keyed_prices(data):
    """cost = price * (prompt + completion)/1000 backed out per mapped
    column — keyed by model NAME so a reordered table cannot re-price."""
    pool = compile_pool(ArmPoolSpec(arms=ARMS4), data)
    prices = model_prices()
    for rb in pool.rb_models:
        assert rb in prices


# ------------------------------------------------------- parity leg --
def test_routerbench_cost_pool_reproduces_replay_sweep():
    """A pool whose costs are forced back to the RouterBench tables
    must reproduce the replay-table run bit-exactly over its mapped
    columns — proof the pool path adds no hidden transform."""
    dspec = DataSpec(n_samples=600, n_slices=3)
    aspec = ArmPoolSpec(arms=ARMS4, cost_source="routerbench")
    henv_pool, pool = build_pool_env(aspec, dspec)

    base = generate_routerbench(0, 600)
    ref = dict(base)
    cols = list(pool.cols)
    ref["quality"] = base["quality"][:, cols]
    ref["cost"] = base["cost"][:, cols]
    ref["model_names"] = np.asarray(
        [base["model_names"][c] for c in cols])
    henv_ref = RouterBenchSim(seed=0, n_slices=3, data=ref)

    d_pool = DeviceReplayEnv.from_host(henv_pool)
    d_ref = DeviceReplayEnv.from_host(henv_ref)
    r_pool = run_baseline_device(d_pool, greedy_policy(d_pool.K), seed=0)
    r_ref = run_baseline_device(d_ref, greedy_policy(d_ref.K), seed=0)
    np.testing.assert_array_equal(np.asarray(r_pool["avg_reward"]),
                                  np.asarray(r_ref["avg_reward"]))
    np.testing.assert_array_equal(np.asarray(r_pool["avg_cost"]),
                                  np.asarray(r_ref["avg_cost"]))


# -------------------------------------------------------- spec codec --
# pre-PR-10 spec hashes, computed BEFORE ArmPoolSpec existed: adding
# the optional section must leave every old preset's canonical JSON —
# and therefore its hash — untouched (emit-only-when-set).
PRE_PR10_HASHES = {
    "paper_table1": "85591add0e29de38",
    "fig2_beta_sweep": "c3b573e341919152",
    "scenario_suite": "a6fd36f2cf38743a",
    "policy_zoo": "28847c5d8d6024a4",
    "ci_smoke": "0a5b4d08377d8795",
    "serving_storm": "fcb9e3941b5490a9",
    "offline_online": "fb6613d2a8e0ce88",
    "ope_selection": "4a23fdba263fc2eb",
    "bench_nucb_sweep": "17f16e06becc5aea",
    "bench_zoo_sweep": "ec1669407b3efafd",
}


def test_pre_pr10_spec_hashes_unchanged():
    for name, want in PRE_PR10_HASHES.items():
        assert spec_hash(make_preset(name)) == want, name


def test_armpool_section_emitted_only_when_set():
    assert "armpool" not in spec_to_json(make_preset("paper_table1"))
    doc = spec_to_json(make_preset("physical_pool"))
    assert doc["armpool"]["arms"][0] == "mamba2_130m"
    rt = spec_from_json(json.loads(json.dumps(doc)))
    assert rt == make_preset("physical_pool")


def test_armpool_spec_validation():
    with pytest.raises(ValueError, match="no arms"):
        ArmPoolSpec(arms=())
    with pytest.raises(ValueError, match="cost_source"):
        ArmPoolSpec(arms=ARMS4, cost_source="vibes")
    with pytest.raises(ValueError, match="max_new"):
        ArmPoolSpec(arms=ARMS4, max_new=0)
    with pytest.raises(ValueError):
        ArmPoolSpec(arms=ARMS4,
                    mapping=(("mamba2_130m", "gpt-4"),
                             ("mamba2_130m", "claude-v2")))


def test_armpool_set_overrides():
    spec = make_preset("physical_pool", {
        "armpool.decode_batch": 4,
        "armpool.arms": list(ARMS4),
        "armpool.cost_source": "routerbench"})
    assert spec.armpool.decode_batch == 4
    assert spec.armpool.arms == ARMS4
    assert spec.armpool.cost_source == "routerbench"
    with pytest.raises((KeyError, ValueError)):
        make_preset("physical_pool", {"armpool.decode_bacth": 4})


def test_armpool_spec_rejects_env_injection():
    from repro.experiments import compile_spec
    henv = RouterBenchSim(seed=0, n_samples=400, n_slices=2)
    denv = DeviceReplayEnv.from_host(henv)
    spec = make_preset("physical_pool", {"data.n_samples": 400})
    with pytest.raises(ValueError, match="armpool"):
        compile_spec(spec, env=denv, host_env=henv)


# ------------------------------------------------------- end to end --
def test_physical_pool_preset_tiny_end_to_end():
    """Shrunk ``--preset physical_pool``: BOTH the replay sweep and the
    semi-real storm run from one spec; the small arm must report real
    decode-step dispatches; the artifact carries pool provenance."""
    spec = make_preset("physical_pool", {
        "data.n_samples": 600, "data.n_slices": 2,
        "train.train_steps": 4,
        "armpool.arms": list(ARMS4),
        "serving.requests": 200, "serving.waves": 4,
        "serving.decide_batch": 32, "serving.serve_batch": 32,
        "serving.train_every": 0})
    res = run_spec(spec)
    assert res.ok
    scen = res.scenario_names()
    assert "stationary" in scen
    assert any(s.startswith("serving:") for s in scen)
    srv = next(c for c in res.cells if c["scenario"].startswith("serving"))
    steps = srv["serving"]["decode_steps"]
    assert steps["real"].get("mamba2_130m", 0) > 0
    assert set(steps["clocked"]) == set(ARMS4) - {"mamba2_130m"}
    assert srv["armpool_engines"]["real_decode_arms"] == ["mamba2_130m"]
    mani = res.manifest["armpool"]
    assert mani["arms"] == list(ARMS4)
    assert mani["checksum"] == compile_pool(
        ArmPoolSpec(arms=ARMS4),
        generate_routerbench(0, 600)).checksum
