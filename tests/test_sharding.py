"""Distribution-layer tests.

Structural checks run in-process on a 1-device mesh; a REAL multi-device
lowering test runs in a subprocess with 8 forced host devices (the same
mechanism the 512-device dry-run uses — conftest keeps this process at 1
device so smoke tests see realistic defaults)."""
import json
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.common.config import INPUT_SHAPES
from repro.configs import ARCH_IDS, get_config
from repro.distributed.sharding import (
    activation_rules,
    cache_partition_specs,
    param_partition_specs,
)
from repro.launch import specs as SPECS


def _tiny_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_structure(arch):
    cfg = get_config(arch)
    mesh = _tiny_mesh()
    ptree = SPECS.param_specs(cfg)
    parts = param_partition_specs(cfg, ptree, mesh)
    flat_p = jax.tree.leaves(ptree)
    flat_s = jax.tree.leaves(parts, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert isinstance(spec, P)
        assert len(spec) <= len(leaf.shape), (leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", ["decode_32k"])
def test_cache_specs_structure(arch, shape):
    cfg = get_config(arch)
    mesh = _tiny_mesh()
    cache, _ = SPECS.decode_input_specs(cfg, INPUT_SHAPES[shape])
    parts = cache_partition_specs(cfg, INPUT_SHAPES[shape], mesh, cache)
    flat_c = jax.tree.leaves(cache)
    flat_s = jax.tree.leaves(parts, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_c) == len(flat_s)


def test_activation_rules_divisibility():
    """Every rule maps a dim that divides the mesh axis size (the reason
    llama3.2-3b with 24 heads must NOT use head-parallel TP at 16-way)."""
    import numpy as np

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16), dtype=object)

    mesh = FakeMesh()
    cfg = get_config("llama3.2-3b")  # 24 heads: not divisible by 16
    rules = activation_rules(cfg, INPUT_SHAPES["train_4k"], mesh)
    assert rules["heads"] is None
    assert rules["head_dim"] == "model"  # 128 divides 16

    cfg2 = get_config("mistral-large-123b")  # 96 heads: divisible
    rules2 = activation_rules(cfg2, INPUT_SHAPES["train_4k"], mesh)
    assert rules2["heads"] == "model"

    # long_500k batch=1 cannot be data-sharded
    rules3 = activation_rules(get_config("mamba2-130m"),
                              INPUT_SHAPES["long_500k"], mesh, decode=True)
    assert rules3["batch"] is None


SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.common.config import InputShape
from repro.configs import get_config
from repro.distributed import logical_axis_rules
from repro.distributed.sharding import (activation_rules,
    batch_partition_specs, param_partition_specs)
from repro.launch import specs as SPECS
from repro.training import train_step as TS
import functools, numpy as np

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(get_config("granite-moe-1b-a400m").reduced(),
                          dtype="float32", num_experts=4,
                          num_heads=4, num_kv_heads=4)
shape = InputShape("t", 32, 4, "train")
rules = activation_rules(cfg, shape, mesh)
ptree = SPECS.param_specs(cfg)
pparts = param_partition_specs(cfg, ptree, mesh)
named = jax.tree.map(lambda s: NamedSharding(mesh, s), pparts,
                     is_leaf=lambda x: isinstance(x, P))
batch = SPECS.train_input_specs(cfg, shape)
bparts = batch_partition_specs(cfg, shape, mesh, batch)
bnamed = jax.tree.map(lambda s: NamedSharding(mesh, s), bparts,
                      is_leaf=lambda x: isinstance(x, P))
state = jax.eval_shape(lambda: TS.make_train_state(jax.random.PRNGKey(0), cfg))
sparts = {"params": named, "opt": {"mu": named, "nu": named,
          "count": NamedSharding(mesh, P())}, "step": NamedSharding(mesh, P())}
fn = functools.partial(TS.train_step, cfg=cfg)
with logical_axis_rules(rules, mesh):
    lowered = jax.jit(fn, in_shardings=(sparts, bnamed)).lower(state, batch)
    compiled = lowered.compile()
# ALSO execute for real on the 8 fake devices: numerics under SPMD
state_r = jax.jit(lambda k: TS.make_train_state(k, cfg),
                  out_shardings=sparts)(jax.random.PRNGKey(0))
rngb = np.random.default_rng(0)
real_batch = {"tokens": jnp.asarray(rngb.integers(0, cfg.vocab_size, (4, 32))),
              "labels": jnp.asarray(rngb.integers(0, cfg.vocab_size, (4, 32)))}
with logical_axis_rules(rules, mesh):
    new_state, m = jax.jit(fn, in_shardings=(sparts, bnamed))(state_r, real_batch)
loss = float(m["loss"])
assert loss == loss and loss < 20, loss
print("SUBPROC_OK", loss)
"""


def test_spmd_lowering_and_execution_8dev():
    """Real SPMD check: an MoE train step lowers AND executes on a forced
    8-device host mesh with the production sharding rules."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SUBPROC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SUBPROC_OK" in out.stdout


def test_shard_sweep_axis_single_device_identity():
    """On a single device (this process — see conftest) the sweep-shard
    helper must be a no-op so engine callers need no gating."""
    from repro.distributed.sharding import shard_sweep_axis

    x = jax.numpy.arange(8.0)
    tree = shard_sweep_axis({"a": x})
    assert tree["a"] is x


SWEEP_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp
from repro.data.routerbench import RouterBenchSim
from repro.distributed.sharding import shard_sweep_axis
from repro.sim import DeviceReplayEnv, random_policy, run_baseline_sweep
assert len(jax.local_devices()) == 2
keys = jnp.stack([jax.random.PRNGKey(s) for s in range(4)])
sk = shard_sweep_axis(keys)
assert len(sk.sharding.device_set) == 2, sk.sharding
odd = shard_sweep_axis(jnp.arange(3.0))        # 3 lanes on 2 devices
assert len(odd.sharding.device_set) == 1       # legacy path falls back
# the engine's sweep runner now PADS instead of degrading: 3 lanes on 2
# devices get one dead lane and still shard 2-ways
from repro.distributed.sharding import pad_sweep_lanes, sweep_lane_layout
from repro.launch.mesh import make_sweep_mesh
mesh = make_sweep_mesh(1, 3)
lay = sweep_lane_layout(3, mesh)
assert (lay.pad, lay.n_devices, lay.total) == (1, 2, 4), lay
padded = pad_sweep_lanes(jnp.arange(1.0, 4.0), lay.pad)
assert padded.shape == (4,) and float(padded[3]) == 1.0  # lane-0 copy
henv = RouterBenchSim(seed=0, n_samples=600, n_slices=3)
denv = DeviceReplayEnv.from_host(henv)
out = run_baseline_sweep(denv, random_policy(denv.K), seeds=range(4))
assert out["avg_reward"].shape == (1, 4, 3)     # annotated (G, seeds, T)
assert out["layout"] == {"n_lanes": 4, "pad": 0, "n_devices": 2,
                         "mesh": {"grid": 1, "seed": 2},
                         "hosts": {"n_hosts": 1, "devices_per_host": 2}}
# non-dividing lane count: dead lane dropped from results, layout says so
out3 = run_baseline_sweep(denv, random_policy(denv.K), seeds=range(3))
assert out3["avg_reward"].shape == (1, 3, 3)
assert out3["layout"]["pad"] == 1 and out3["layout"]["n_devices"] == 2
# the policy AXIS shares the same lane sharding: a 2-policy zoo sweep
# executes as one dispatch with each policy's 4 lanes split 2-ways
from repro.sim import make_policy, run_policy_sweep
zoo = {n: make_policy(n, denv, None) for n in ("greedy", "dyn_min_cost")}
sw = run_policy_sweep(denv, zoo, seeds=range(4))
assert set(sw) == {"greedy", "dyn_min_cost"}
for d in sw.values():
    assert d["avg_reward"].shape == (1, 4, 3)
print("SWEEP_SUBPROC_OK")
"""


def test_process_lane_slice_partition():
    """Per-process grid spans partition [0, G) contiguously, disjointly
    and completely for any (G, hosts) shape, with seed-major lane spans
    scaled by n_seeds; out-of-range process indices raise."""
    from repro.distributed.sharding import process_lane_slice

    for G, h, S in [(4, 2, 3), (5, 2, 1), (1, 4, 2), (7, 3, 2)]:
        spans = [process_lane_slice(G, S, h, p) for p in range(h)]
        assert spans[0][0] == 0 and spans[-1][1] == G
        for (gs, ge, ls, le), nxt in zip(spans, spans[1:]):
            assert ge == nxt[0]                 # contiguous + disjoint
        for gs, ge, ls, le in spans:
            assert (ls, le) == (gs * S, ge * S)
    with pytest.raises(ValueError):
        process_lane_slice(4, 1, 2, 2)


def test_run_sweep_multihost_single_process_degenerate():
    """Single-process `run_sweep_multihost` == plain `run_policy_sweep`
    on the metrics, plus the multi-host annotations (full grid span,
    1-host topology manifest)."""
    import numpy as np

    from repro.data.routerbench import RouterBenchSim
    from repro.distributed import run_sweep_multihost
    from repro.sim import DeviceReplayEnv, make_policy, run_policy_sweep
    from repro.sim.policies import LinUCBHypers

    env = DeviceReplayEnv.from_host(
        RouterBenchSim(seed=0, n_samples=300, n_slices=3))
    pol, _ = make_policy("linucb", env)
    hyp = LinUCBHypers(alpha=jax.numpy.asarray([0.5, 1.5]),
                       ridge=jax.numpy.ones(2))
    zoo = {"linucb": (pol, hyp)}
    ref = run_policy_sweep(env, zoo, seeds=range(2))["linucb"]
    got = run_sweep_multihost(env, zoo, seeds=range(2))["linucb"]
    for k in ("avg_reward", "avg_cost", "action_hist"):
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(ref[k]), err_msg=k)
    assert got["grid_span"] == [0, 2] and got["n_grid_total"] == 2
    assert got["lane_span"] == [0, 4]
    assert got["layout"]["hosts"]["n_hosts"] == 1


def test_sweep_sharding_multi_device_subprocess():
    """The protocol sweep's lane axis really shards across forced host
    devices and the sharded sweep executes (DESIGN.md §8.4)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SWEEP_SUBPROC], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SWEEP_SUBPROC_OK" in out.stdout
