"""Property tests for the host ReplayBuffer (ISSUE satellite): the
valid count never exceeds what was stored, and `minibatches` covers
every stored sample exactly once per epoch — INCLUDING the short
shuffle tail (previously dropped once full batches existed, silently
under-training up to batch_size-1 samples per epoch)."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep — fall back to the local stub
    from _hypothesis_stub import given, settings, st

from repro.core.replay import ReplayBuffer


def _fill(buf: ReplayBuffer, n: int, seed: int = 0,
          emb: int = 8, feat: int = 4):
    rng = np.random.default_rng(seed)
    # tag rewards with the global sample index so coverage is checkable
    start = len(buf)
    buf.add_batch(rng.normal(size=(n, emb)), rng.normal(size=(n, feat)),
                  rng.integers(0, 3, n), rng.integers(0, 5, n),
                  np.arange(start, start + n, dtype=np.float32),
                  rng.integers(0, 2, n))


@settings(max_examples=30, deadline=None)
@given(chunks=st.integers(1, 5), chunk_size=st.integers(1, 70),
       batch_size=st.sampled_from([1, 16, 64]))
def test_valid_count_matches_stored(chunks, chunk_size, batch_size):
    """len(buffer) is exactly the number of samples added, however the
    adds were chunked, and data() concatenates to the same count."""
    buf = ReplayBuffer(8, 4)
    for i in range(chunks):
        _fill(buf, chunk_size, seed=i)
    assert len(buf) == chunks * chunk_size
    data = buf.data()
    assert all(len(v) == len(buf) for v in data.values())


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 200), batch_size=st.sampled_from([16, 64, 128]),
       seed=st.integers(0, 3))
def test_minibatches_cover_every_sample_once_per_epoch(n, batch_size, seed):
    """One epoch = a partition of the buffer: every stored sample appears
    exactly once across the yielded batches (full batches + short tail),
    and only the final batch may be short."""
    buf = ReplayBuffer(8, 4)
    _fill(buf, n)
    mbs = list(buf.minibatches(np.random.default_rng(seed), batch_size))
    sizes = [len(m["reward"]) for m in mbs]
    assert sum(sizes) == n
    assert all(s == batch_size for s in sizes[:-1])
    assert 1 <= sizes[-1] <= batch_size
    seen = np.sort(np.concatenate([m["reward"] for m in mbs]))
    np.testing.assert_array_equal(seen, np.arange(n, dtype=np.float32))


def test_minibatches_drop_tail_keeps_static_shapes():
    """drop_tail=True restores fixed shapes for jit-hot callers — full
    batches only — but still yields the whole buffer when it is smaller
    than one batch (the PR-1 regression)."""
    buf = ReplayBuffer(8, 4)
    _fill(buf, 100)
    sizes = [len(m["reward"])
             for m in buf.minibatches(np.random.default_rng(0), 64,
                                      drop_tail=True)]
    assert sizes == [64]
    small = ReplayBuffer(8, 4)
    _fill(small, 40)
    sizes = [len(m["reward"])
             for m in small.minibatches(np.random.default_rng(0), 64,
                                        drop_tail=True)]
    assert sizes == [40]
