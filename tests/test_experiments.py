"""Declarative ExperimentSpec API tests (DESIGN.md §11): strict JSON
round-trip, ``--set`` override paths, compile-time validation and
minimal dispatch grouping, the schema-versioned artifact, and the
parity tests pinning the ``paper_table1`` / ``fig2_beta_sweep`` /
``scenario_suite`` presets against the pre-redesign (PR-4) driver path
on a tiny config."""
import json

import numpy as np
import pytest

from repro.core.protocol import summarize, summarize_sweep
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim
from repro.experiments import (
    PRESETS,
    ExperimentSpec,
    ForgettingSpec,
    PolicySpec,
    apply_overrides,
    compile_spec,
    make_preset,
    parse_override_value,
    run_plan,
    run_spec,
    spec_from_json,
    spec_hash,
    spec_to_json,
)
from repro.sim import (
    DeviceReplayEnv,
    ForgettingConfig,
    greedy_policy,
    random_policy,
    run_baseline_device,
    run_neuralucb_device,
    run_neuralucb_sweep,
)

TINY = {"data.n_samples": 600, "data.n_slices": 3,
        "train.train_steps": 8, "train.batch_size": 32}


@pytest.fixture(scope="module")
def envs():
    henv = RouterBenchSim(seed=0, n_samples=600, n_slices=3)
    return henv, DeviceReplayEnv.from_host(henv)


@pytest.fixture(scope="module")
def cfg(envs):
    henv, _ = envs
    return UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)


# ------------------------------------------------------------ spec codec --
def test_every_preset_round_trips():
    for name in PRESETS:
        spec = make_preset(name)
        doc = json.loads(json.dumps(spec_to_json(spec)))
        assert spec_from_json(doc) == spec, name
        assert spec_hash(spec_from_json(doc)) == spec_hash(spec)


def test_round_trip_preserves_axes_and_variants():
    spec = ExperimentSpec(
        name="rt",
        policies=(PolicySpec("neuralucb",
                             axes=(("beta", (0.5, 1.0)),
                                   ("cost_lambda", (None, 0.5)))),
                  PolicySpec("neuralucb", name="nucb-forget",
                             forgetting=ForgettingSpec(replay_rho=0.4),
                             overrides=(("tau_g", 0.25),))),
        scenarios=("price_shock", None),
        seeds=(0, 7))
    rt = spec_from_json(spec_to_json(spec))
    assert rt == spec
    assert rt.policies[0].axes[1][1] == (None, 0.5)  # null sentinel kept


def test_unknown_keys_rejected_everywhere():
    doc = spec_to_json(make_preset("ci_smoke"))
    top = dict(doc, bogus=1)
    with pytest.raises(ValueError, match="bogus"):
        spec_from_json(top)
    nested = json.loads(json.dumps(doc))
    nested["data"]["n_sample"] = 10          # typo'd field
    with pytest.raises(ValueError, match="n_sample"):
        spec_from_json(nested)
    pol = json.loads(json.dumps(doc))
    pol["policies"][0]["beta"] = 2.0         # hyper outside axes
    with pytest.raises(ValueError, match="beta"):
        spec_from_json(pol)
    fg = json.loads(json.dumps(doc))
    fg["forgetting"]["rho"] = 0.4
    with pytest.raises(ValueError, match="rho"):
        spec_from_json(fg)


def test_schema_tag_is_mandatory():
    doc = spec_to_json(make_preset("ci_smoke"))
    del doc["schema"]
    with pytest.raises(ValueError, match="schema"):
        spec_from_json(doc)
    doc["schema"] = "experiment-spec-v999"
    with pytest.raises(ValueError, match="v999"):
        spec_from_json(doc)


def test_spec_invariants():
    with pytest.raises(ValueError, match="duplicate policy labels"):
        ExperimentSpec(name="dup", policies=(PolicySpec("neuralucb"),
                                             PolicySpec("neuralucb")))
    with pytest.raises(ValueError, match="no values"):
        PolicySpec("neuralucb", axes=(("beta", ()),))
    with pytest.raises(ValueError, match="null"):
        PolicySpec("neuralucb", axes=(("beta", (None, 1.0)),))
    with pytest.raises(ValueError, match="gamma"):
        ForgettingSpec(gamma=0.0)
    with pytest.raises(ValueError, match="no seeds"):
        ExperimentSpec(name="s", seeds=())


# -------------------------------------------------------- --set overrides --
def test_parse_override_value():
    assert parse_override_value("32") == 32
    assert parse_override_value("0.5") == 0.5
    assert parse_override_value("null") is None
    assert parse_override_value("0.5,1.0") == [0.5, 1.0]
    assert parse_override_value("price_shock,arm_outage") == \
        ["price_shock", "arm_outage"]
    assert parse_override_value("price_shock") == "price_shock"


def test_apply_overrides_paths():
    spec = make_preset("fig2_beta_sweep", {
        "data.n_samples": 600, "seeds": [0, 1],
        "policies.neuralucb.axes.beta": [0.5],
        "policies.neuralucb.axes.tau_g": 0.25,
        "scenarios": ["price_shock"],
        "train.train_steps": 8})
    assert spec.data.n_samples == 600
    assert spec.seeds == (0, 1)
    assert spec.scenarios == ("price_shock",)
    assert dict(spec.policies[0].axes) == {"beta": (0.5,),
                                           "tau_g": (0.25,)}
    assert spec.train.train_steps == 8


def test_apply_overrides_rejects_unknown_paths():
    spec = make_preset("fig2_beta_sweep")
    with pytest.raises(KeyError, match="n_sample"):
        apply_overrides(spec, {"data.n_sample": 600})
    with pytest.raises(KeyError, match="no policy entry"):
        apply_overrides(spec, {"policies.linucb.axes.alpha": [1.0]})


# ---------------------------------------------------------------- compile --
def test_compile_validates_registries(envs):
    henv, denv = envs
    with pytest.raises(ValueError, match="unknown policy"):
        compile_spec(ExperimentSpec(name="x",
                                    policies=(PolicySpec("nope"),)),
                     env=denv)
    with pytest.raises(ValueError, match="unknown scenario"):
        compile_spec(ExperimentSpec(name="x", scenarios=("nope",)),
                     env=denv)
    with pytest.raises(ValueError, match="unknown hyper axis"):
        compile_spec(ExperimentSpec(
            name="x", policies=(PolicySpec("neuralucb",
                                           axes=(("betta", (1.0,)),)),)),
            env=denv)
    with pytest.raises(ValueError, match="no hyper fields"):
        compile_spec(ExperimentSpec(
            name="x", policies=(PolicySpec("random",
                                           axes=(("beta", (1.0,)),)),)),
            env=denv)
    with pytest.raises(ValueError, match="bad override"):
        compile_spec(ExperimentSpec(
            name="x", policies=(PolicySpec("neuralucb",
                                           overrides=(("betta", 1.0),)),)),
            env=denv)


def test_compile_groups_into_minimal_dispatches(envs):
    henv, denv = envs
    plan = compile_spec(make_preset("ci_smoke"), env=denv, host_env=henv)
    # 3 scenarios × 2 forgetting variants — every vanilla policy of a
    # scenario shares ONE run_policy_sweep dispatch
    assert plan.n_dispatches == 6
    assert plan.n_cells == 18       # (2β + 4×1) cells × 3 scenarios
    vanilla = plan.calls[0]
    assert vanilla.scenario is None
    assert set(vanilla.policies) == {"neuralucb", "linucb", "neural_ts",
                                     "eps_greedy"}
    assert vanilla.forgetting == ForgettingConfig()
    forget = plan.calls[1]
    assert set(forget.policies) == {"neuralucb-forget"}
    assert forget.forgetting == ForgettingConfig(replay_rho=0.4)

    fig2 = compile_spec(make_preset("fig2_beta_sweep"), env=denv,
                        host_env=henv)
    assert fig2.n_dispatches == 1   # same count as hand-wired PR-2 sweep
    assert fig2.n_cells == 4


def test_compile_resolves_train_schedule(envs):
    henv, denv = envs
    spec = make_preset("paper_table1", TINY)
    plan = compile_spec(spec, env=denv, host_env=henv)
    assert plan.train_steps == 8
    derived = compile_spec(
        make_preset("paper_table1", {"data.n_samples": 600,
                                     "data.n_slices": 3}),
        env=denv, host_env=henv)
    assert derived.train_steps is not None and derived.train_steps > 0


# ----------------------------------------------------------- parity (PR-4) --
def test_fig2_beta_sweep_preset_matches_pr4_driver(envs, cfg):
    """Acceptance: the preset path must reproduce the PR-4
    ``run_neuralucb_sweep`` + ``summarize_sweep`` numbers exactly, from
    the same one-dispatch program."""
    henv, denv = envs
    spec = make_preset("fig2_beta_sweep", {
        **TINY, "seeds": [0, 1],
        "policies.neuralucb.axes.beta": [0.5, 1.0]})
    res = run_spec(spec, env=denv, host_env=henv)
    assert res.manifest["n_dispatches"] == 1

    ref = run_neuralucb_sweep(denv, cfg, seeds=[0, 1], betas=[0.5, 1.0],
                              train_steps=8, batch_size=32)
    points = summarize_sweep(ref)
    assert len(res.cells) == len(points) == 2
    for cell, point in zip(res.cells, points):
        assert cell["point"]["beta"] == point["beta"]
        for key in ("avg_reward_mean", "avg_reward_std", "avg_cost_mean",
                    "avg_quality_mean", "oracle_avg_reward_mean",
                    "dynamic_regret_mean", "final_cum_reward_mean"):
            assert cell[key] == point[key], (cell["point"], key)


def test_paper_table1_preset_matches_pr4_driver(envs, cfg):
    henv, denv = envs
    res = run_spec(make_preset("paper_table1",
                               {**TINY, "seeds": [0]}),
                   env=denv, host_env=henv)
    refs = {
        "neuralucb": run_neuralucb_device(denv, cfg, seed=0,
                                          train_steps=8, batch_size=32),
        "greedy": run_baseline_device(denv, greedy_policy(denv.K),
                                      seed=0),
        "random": run_baseline_device(denv, random_policy(denv.K),
                                      seed=0),
    }
    summ = summarize(refs, skip_first=True)
    for name, ref in summ.items():
        cell = res.cell(name)
        for k_new, k_old in (("avg_reward_mean", "avg_reward"),
                             ("avg_cost_mean", "avg_cost"),
                             ("avg_quality_mean", "avg_quality"),
                             ("final_cum_reward_mean",
                              "final_cum_reward")):
            np.testing.assert_allclose(cell[k_new], ref[k_old],
                                       rtol=0, atol=1e-12,
                                       err_msg=f"{name}/{k_new}")


def test_scenario_suite_preset_matches_pr4_driver(envs, cfg):
    henv, denv = envs
    res = run_spec(make_preset("scenario_suite",
                               {**TINY, "seeds": [0],
                                "scenarios": ["price_shock"]}),
                   env=denv, host_env=henv)
    fg = ForgettingConfig(replay_rho=0.4)
    refs = {
        "neuralucb": run_neuralucb_device(
            denv, cfg, seed=0, scenario="price_shock", train_steps=8,
            batch_size=32),
        "neuralucb-forget": run_neuralucb_device(
            denv, cfg, seed=0, scenario="price_shock", forgetting=fg,
            train_steps=8, batch_size=32),
        "greedy": run_baseline_device(denv, greedy_policy(denv.K),
                                      seed=0, scenario="price_shock"),
        "random": run_baseline_device(denv, random_policy(denv.K),
                                      seed=0, scenario="price_shock"),
    }
    summ = summarize(refs, skip_first=True)
    for name, ref in summ.items():
        cell = res.cell(name, "price_shock")
        for k_new, k_old in (("avg_reward_mean", "avg_reward"),
                             ("avg_cost_mean", "avg_cost"),
                             ("oracle_avg_reward_mean",
                              "oracle_avg_reward"),
                             ("dynamic_regret_mean", "dynamic_regret")):
            np.testing.assert_allclose(cell[k_new], ref[k_old],
                                       rtol=0, atol=1e-12,
                                       err_msg=f"{name}/{k_new}")


# ---------------------------------------------------------------- artifact --
def test_result_artifact_schema(envs, tmp_path):
    henv, denv = envs
    spec = make_preset("fig2_beta_sweep", {
        **TINY, "seeds": [0],
        "policies.neuralucb.axes.beta": [1.0]})
    plan = compile_spec(spec, env=denv, host_env=henv)
    res = run_plan(plan)
    m = res.manifest
    assert m["schema"] == "experiment-result-v1"
    assert m["spec_hash"] == spec_hash(spec)
    assert m["n_dispatches"] == 1 and m["n_cells"] == 1
    assert m["train_steps"] == 8
    assert m["backend"] and m["n_devices"] >= 1
    assert res.ok

    cell = res.cells[0]
    assert cell["scenario"] == "stationary"
    assert len(cell["curve_avg_reward"]) == 3    # summarize.curves
    path = tmp_path / "artifact.json"
    res.save(str(path))
    doc = json.loads(path.read_text())
    assert doc["schema"] == "experiment-result-v1"
    assert spec_from_json(doc["spec"]) == spec   # artifact reruns as-is


# ---------------------------------------------------------------- serving --
def test_serving_spec_codec_and_invariants():
    from repro.experiments import ServingSpec

    spec = make_preset("serving_storm")
    doc = json.loads(json.dumps(spec_to_json(spec)))
    assert spec_from_json(doc) == spec
    # the key is emitted only when set: pre-serving specs (and their
    # hashes) are untouched by the schema extension
    assert "serving" not in spec_to_json(make_preset("paper_table1"))
    with pytest.raises(ValueError, match="unknown keys"):
        bad = spec_to_json(spec)
        bad["serving"]["p99_decide_sec"] = 1
        spec_from_json(bad)
    with pytest.raises(ValueError, match="requests >= waves"):
        ServingSpec(requests=5, waves=10)
    with pytest.raises(ValueError, match="outage"):
        ServingSpec(outages=((0, 9, 3),))
    with pytest.raises(ValueError, match="max_shed_fraction"):
        ServingSpec(max_shed_fraction=1.5)
    with pytest.raises(ValueError, match="exactly one policy"):
        ExperimentSpec(name="s", serving=ServingSpec(),
                       policies=(PolicySpec("neuralucb"),
                                 PolicySpec("greedy")))
    with pytest.raises(ValueError, match="scenarios"):
        ExperimentSpec(name="s", serving=ServingSpec(),
                       scenarios=("price_shock",))
    # --set paths reach into the serving block (the CI-smoke shrink)
    small = apply_overrides(spec, {"serving.requests": 2000,
                                   "serving.decide_batch": 64})
    assert small.serving.requests == 2000
    assert small.serving.decide_batch == 64
    assert small.serving.outages == spec.serving.outages


def test_serving_compile_validates(envs):
    henv, denv = envs
    spec = make_preset("serving_storm", {"serving.pattern": "tsunami"})
    with pytest.raises(ValueError, match="traffic pattern"):
        compile_spec(spec, env=denv, host_env=henv)
    spec = make_preset("serving_storm", {"serving.waves": 10,
                                         "serving.outages": []})
    spec = apply_overrides(spec, {"serving.outages": [[99, 0, 2]]})
    with pytest.raises(ValueError, match="out of range"):
        compile_spec(spec, env=denv, host_env=henv)
    spec = make_preset("serving_storm")
    spec = apply_overrides(spec, {"serving.waves": 10})
    with pytest.raises(ValueError, match="past the last wave"):
        compile_spec(spec, env=denv, host_env=henv)


def test_serving_storm_preset_runs_and_gates(envs, tmp_path):
    """The serving_storm preset compiled against a tiny env: zero
    dispatches (the storm replaces the sweeps), one serving cell with
    the gate verdicts, `ExperimentResult.ok` wired to them, and the
    artifact round-trips."""
    henv, denv = envs
    spec = make_preset("serving_storm", {
        "train.train_steps": 8, "train.batch_size": 32,
        "serving.requests": 1200, "serving.waves": 40,
        "serving.decide_batch": 64, "serving.queue_capacity": 512,
        "serving.p99_decide_ms": 5000})
    plan = compile_spec(spec, env=denv, host_env=henv)
    assert plan.calls == () and plan.serving_policy[0] == "neuralucb"
    res = run_plan(plan)
    cell = res.cells[0]
    assert cell["scenario"] == "serving:flash_crowd"
    sv = cell["serving"]
    assert sv["lost_requests"] == 0
    assert sv["completed"] + sv["shed"] == 1200
    assert sv["decide_errors"] == 1          # the injected decide fault
    assert cell["serving_gates"]["zero_lost"]
    assert cell["serving_ok"] and res.ok
    path = tmp_path / "storm.json"
    res.save(str(path))
    doc = json.loads(path.read_text())
    assert spec_from_json(doc["spec"]) == spec

    # a failed gate must fail the artifact
    bad = dict(cell, serving_ok=False)
    res.cells[0] = bad
    assert not res.ok
