"""Non-stationary scenario engine tests (DESIGN.md §9): registry
compile+run (every scenario as a single-dispatch scan), identity-tables
parity with the stationary fast path, availability enforcement, delayed
feedback, domain-mix shift, forgetting parity between the scanned and
stepped runners, dynamic-regret accounting, and the adaptivity
acceptance — the recency-forgetting variant must beat vanilla NeuralUCB
on the price-shock and arm-outage scenarios (run in a subprocess so the
comparison is deterministic per machine)."""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.protocol import summarize
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim
from repro.sim import (
    SCENARIOS,
    DeviceNeuralUCB,
    DeviceReplayEnv,
    ForgettingConfig,
    Scenario,
    greedy_policy,
    identity_tables,
    make_scenario,
    resolve_scenario,
    run_baseline_device,
    run_neuralucb_device,
)

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))


@pytest.fixture(scope="module")
def envs():
    henv = RouterBenchSim(seed=0, n_samples=900, n_slices=3)
    return henv, DeviceReplayEnv.from_host(henv)


NUCB_KW = dict(train_steps=32, batch_size=64, ucb_backend="jnp")


def test_registry_has_required_scenarios():
    required = {"stationary", "price_shock", "cost_drift", "quality_decay",
                "arm_outage", "arm_arrival", "domain_shift",
                "delayed_feedback"}
    assert required <= set(SCENARIOS)
    assert len(SCENARIOS) >= 6


def test_every_scenario_runs_scanned_with_finite_metrics(envs):
    """ISSUE acceptance: each registered scenario runs via the
    single-dispatch scan; metrics stay finite, the per-slice dynamic
    oracle dominates the policy, and summaries JSON-serialize."""
    henv, denv = envs
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
    for name in sorted(SCENARIOS):
        res = run_neuralucb_device(denv, cfg, seed=0, scenario=name,
                                   **NUCB_KW)
        for key in ("avg_reward", "avg_cost", "avg_quality",
                    "oracle_avg_reward"):
            assert np.isfinite(res[key]).all(), f"{name}/{key}"
        assert (np.asarray(res["oracle_avg_reward"])
                >= np.asarray(res["avg_reward"]) - 1e-5).all(), name
        summ = summarize({name: res})[name]
        assert summ["dynamic_regret"] >= -1e-5, name
        json.dumps(summ)  # every field must be a plain Python scalar


def test_identity_scenario_matches_fast_path(envs):
    """Explicit identity transforms exercise the per-slice transform +
    reward-recompute path; it must reproduce the table fast path."""
    henv, denv = envs
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
    ident = Scenario("identity", identity_tables(denv.n_slices, denv.K))
    plain = run_neuralucb_device(denv, cfg, seed=0, **NUCB_KW)
    tfm = run_neuralucb_device(denv, cfg, seed=0, scenario=ident, **NUCB_KW)
    for key in ("avg_reward", "cum_reward", "avg_cost", "avg_quality",
                "oracle_avg_reward"):
        np.testing.assert_allclose(tfm[key], plain[key], rtol=1e-5,
                                   atol=1e-6, err_msg=key)
    np.testing.assert_array_equal(tfm["action_hist"], plain["action_hist"])


def test_availability_mask_enforced(envs):
    """arm_arrival marks the strongest arm unavailable early: neither
    NeuralUCB nor an availability-unaware baseline (engine fallback) may
    route any traffic to it in masked slices."""
    henv, denv = envs
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
    scen = make_scenario(denv, "arm_arrival")
    blocked = np.where(np.asarray(scen.tables.avail).min(axis=0) < 1)[0]
    assert len(blocked) == 1
    arm = int(blocked[0])
    masked_slices = np.where(np.asarray(scen.tables.avail)[:, arm] == 0)[0]
    assert len(masked_slices) >= 1
    nucb = run_neuralucb_device(denv, cfg, seed=0, scenario=scen, **NUCB_KW)
    base = run_baseline_device(denv, greedy_policy(denv.K), seed=0,
                               scenario=scen)
    for res in (nucb, base):
        hist = np.asarray(res["action_hist"])
        assert hist[masked_slices, arm].sum() == 0
        # traffic is conserved (fallback re-routes, never drops)
        np.testing.assert_allclose(hist.sum(axis=1), denv.slice_sizes)


def test_scenario_with_no_available_arm_rejected(envs):
    """A slice with every arm masked would make the warm draw emit the
    out-of-range action K — resolve_scenario must refuse it up front."""
    from repro.sim.scenarios import identity_transforms, tables_from
    _, denv = envs
    tr = identity_transforms(denv.n_slices, denv.K)
    tr["avail"][1, :] = 0.0
    with pytest.raises(ValueError, match="no\\s+available arm"):
        resolve_scenario(denv, Scenario("dead", tables_from(tr)))


def test_delayed_feedback_lags_learning_only(envs):
    """Delay changes what the learner SEES, not what it earns: slice-0
    metrics (decided before any feedback) are identical to stationary,
    and later trajectories diverge."""
    henv, denv = envs
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
    stat = run_neuralucb_device(denv, cfg, seed=0, **NUCB_KW)
    dly = run_neuralucb_device(denv, cfg, seed=0,
                               scenario="delayed_feedback", **NUCB_KW)
    np.testing.assert_allclose(dly["avg_reward"][0], stat["avg_reward"][0],
                               rtol=1e-6)
    assert not np.allclose(dly["avg_reward"][1:], stat["avg_reward"][1:])


def test_domain_shift_is_a_pure_stream_permutation(envs):
    """domain_shift re-slices the same samples in domain order: the valid
    id multiset and per-slice sizes are preserved, and the stream's
    domain sequence becomes sorted (the mix genuinely shifts)."""
    henv, denv = envs
    env2, tables, delay = resolve_scenario(denv, "domain_shift")
    assert tables is None and delay == 0
    m0, m1 = np.asarray(denv.mask), np.asarray(env2.mask)
    np.testing.assert_array_equal(m0, m1)
    ids0 = np.asarray(denv.idx)[m0 > 0]
    ids1 = np.asarray(env2.idx)[m1 > 0]
    np.testing.assert_array_equal(np.sort(ids0), np.sort(ids1))
    dom = np.asarray(denv.domain)[ids1]
    assert (np.diff(dom) >= 0).all()
    assert not (np.diff(np.asarray(denv.domain)[ids0]) >= 0).all()


def test_forgetting_parity_scanned_vs_stepped(envs):
    """The forgetting variants ride the shared train/rebuild helpers:
    the single-dispatch scan and the host-stepped parity reference must
    agree under a non-vanilla ForgettingConfig too."""
    henv, denv = envs
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
    fcfg = ForgettingConfig(gamma=0.9, window=2, replay_rho=0.8)
    scanned = run_neuralucb_device(denv, cfg, seed=0, train_steps=32,
                                   batch_size=128, forgetting=fcfg)
    stepped = DeviceNeuralUCB(denv, cfg, seed=0, batch_size=128,
                              forgetting=fcfg).run(train_steps=32,
                                                   scan=False)
    for key in ("avg_reward", "cum_reward", "avg_cost", "avg_quality"):
        np.testing.assert_allclose(scanned[key], stepped[key],
                                   rtol=1e-4, atol=1e-4, err_msg=key)
    np.testing.assert_array_equal(scanned["action_hist"],
                                  stepped["action_hist"])


def test_scenario_composes_with_stream_replacement(envs):
    """resolve_scenario on domain_shift + a table scenario built from the
    SAME env shape compose through dataclasses.replace without touching
    the resident tables (spot-check the env is not copied wholesale)."""
    henv, denv = envs
    env2, _, _ = resolve_scenario(denv, "domain_shift")
    assert env2.x_emb is denv.x_emb  # tables shared, only the stream swaps
    assert env2.idx is not denv.idx


_ADAPTIVITY_SRC = """
import json
import numpy as np
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim
from repro.sim import DeviceReplayEnv, ForgettingConfig, run_neuralucb_sweep

henv = RouterBenchSim(seed=0, n_samples=6000, n_slices=12)
denv = DeviceReplayEnv.from_host(henv)
cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
out = {}
for scen in ("price_shock", "arm_outage"):
    row = {}
    for nm, fg in (("vanilla", None),
                   ("forget", ForgettingConfig(replay_rho=0.4))):
        kw = dict(seeds=range(6), train_steps=32, batch_size=32,
                  scenario=scen)
        if fg is not None:
            kw["forgetting"] = fg
        sw = run_neuralucb_sweep(denv, cfg, **kw)
        row[nm] = float(sw["avg_reward"][0, :, 1:].mean())
    out[scen] = row
print("ADAPTIVITY=" + json.dumps(out))
"""


def test_forgetting_beats_vanilla_on_price_shock_and_outage():
    """ISSUE acceptance: the recency-forgetting variant (DESIGN.md §9.2)
    must beat vanilla NeuralUCB on seed-mean avg reward under both the
    price-shock and arm-outage scenarios. Runs in a subprocess with a
    pinned hash seed: the comparison is a deterministic function of the
    machine (the chaotic per-seed trajectories cancel in the 6-seed
    mean; margins measured at +0.02 / +0.06)."""
    env = dict(os.environ, PYTHONHASHSEED="0", JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"), env.get("PYTHONPATH")) if p)
    out = subprocess.run([sys.executable, "-c", _ADAPTIVITY_SRC], env=env,
                         capture_output=True, text=True, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("ADAPTIVITY=")][-1]
    res = json.loads(line.split("=", 1)[1])
    for scen in ("price_shock", "arm_outage"):
        v, f = res[scen]["vanilla"], res[scen]["forget"]
        assert f > v, (f"forgetting must beat vanilla on {scen}: "
                       f"forget={f:.4f} vanilla={v:.4f}")
