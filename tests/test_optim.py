"""Optimizer math vs closed form; schedules; clipping."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.training.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.training.schedule import cosine_schedule, linear_warmup


def test_adamw_first_step_closed_form():
    """After one step from zero moments, AdamW moves by ~lr*sign(g)
    (bias-corrected m/sqrt(v) = g/|g|)."""
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.5, -0.1, 2.0])}
    st = adamw_init(p)
    new_p, st2 = adamw_update(g, st, p, lr=0.01, weight_decay=0.0)
    expected = np.array([1.0, -2.0, 3.0]) - 0.01 * np.sign([0.5, -0.1, 2.0])
    np.testing.assert_allclose(np.asarray(new_p["w"]), expected, atol=1e-4)
    assert int(st2["count"]) == 1


def test_adamw_weight_decay_shrinks():
    p = {"w": jnp.array([10.0])}
    g = {"w": jnp.array([0.0])}
    st = adamw_init(p)
    new_p, _ = adamw_update(g, st, p, lr=0.1, weight_decay=0.1)
    assert float(new_p["w"][0]) < 10.0


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}  # norm 5
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 5.0) < 1e-5
    total = np.sqrt(float(clipped["a"][0]) ** 2 + float(clipped["b"][0]) ** 2)
    assert abs(total - 1.0) < 1e-5


def test_clip_noop_when_small():
    g = {"a": jnp.array([0.3])}
    clipped, _ = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.3], atol=1e-6)


def test_schedules():
    assert float(linear_warmup(jnp.int32(5), 1.0, 10)) == 0.5
    assert float(cosine_schedule(jnp.int32(0), 1.0, 100, warmup_steps=10)) == 0.0
    mid = float(cosine_schedule(jnp.int32(10), 1.0, 100, warmup_steps=10))
    assert abs(mid - 1.0) < 1e-5
    end = float(cosine_schedule(jnp.int32(100), 1.0, 100, warmup_steps=10))
    assert abs(end - 0.1) < 1e-5


def test_adamw_bf16_moments_track_f32():
    """bf16 optimizer state (EXPERIMENTS §Perf next lever): the update must
    stay close to the f32-state reference over several steps."""
    import jax

    p32 = {"w": jnp.linspace(-1, 1, 16)}
    pbf = {"w": jnp.linspace(-1, 1, 16)}
    s32 = adamw_init(p32)
    sbf = adamw_init(pbf, moment_dtype=jnp.bfloat16)
    assert jax.tree.leaves(sbf["mu"])[0].dtype == jnp.bfloat16
    key = jax.random.PRNGKey(0)
    for i in range(5):
        key, sub = jax.random.split(key)
        g = {"w": jax.random.normal(sub, (16,)) * 0.1}
        p32, s32 = adamw_update(g, s32, p32, lr=1e-2)
        pbf, sbf = adamw_update(g, sbf, pbf, lr=1e-2)
    np.testing.assert_allclose(np.asarray(pbf["w"]), np.asarray(p32["w"]),
                               atol=5e-3)
