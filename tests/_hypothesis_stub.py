"""Minimal stand-in for ``hypothesis`` so the property-test modules collect
and run when hypothesis is not installed (it is an optional dev dependency,
see pyproject.toml ``[project.optional-dependencies] dev``).

The stub runs each ``@given`` test over a small deterministic example set
(bounds + midpoint of every strategy) instead of randomized search — far
weaker than real hypothesis, but it keeps the properties exercised and the
suite green in minimal environments. Install hypothesis to get the real
engine; the test modules prefer it automatically.
"""
from __future__ import annotations

import functools
import itertools
from typing import Any, List


class _Strategy:
    def __init__(self, examples: List[Any]):
        self.examples = examples


def _integers(min_value: int = 0, max_value: int = 100) -> _Strategy:
    mid = (min_value + max_value) // 2
    return _Strategy(sorted({min_value, mid, max_value}))


def _floats(min_value: float = 0.0, max_value: float = 1.0, **_kw) -> _Strategy:
    mid = 0.5 * (min_value + max_value)
    return _Strategy(sorted({min_value, mid, max_value}))


def _sampled_from(elements) -> _Strategy:
    return _Strategy(list(elements))


def _booleans() -> _Strategy:
    return _Strategy([False, True])


class st:  # mirrors ``hypothesis.strategies`` for the subset the tests use
    integers = staticmethod(_integers)
    floats = staticmethod(_floats)
    sampled_from = staticmethod(_sampled_from)
    booleans = staticmethod(_booleans)


def given(**strategies):
    """Run the test once per example tuple. Examples are zipped (bounds with
    bounds, midpoints with midpoints) rather than crossed, so the number of
    invocations stays tiny; strategies with fewer examples repeat their last."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            names = list(strategies)
            n = max(len(strategies[k].examples) for k in names)
            for i in range(n):
                vals = {k: strategies[k].examples[min(i, len(strategies[k].examples) - 1)]
                        for k in names}
                fn(*args, **kwargs, **vals)

        # pytest resolves fixture names via inspect.signature, which follows
        # __wrapped__ back to fn and would treat the strategy kwargs as
        # fixtures — hide the original signature.
        del wrapper.__wrapped__
        return wrapper

    return deco


def settings(**_kw):
    def deco(fn):
        return fn

    return deco
