"""Collective-parsing layer for the roofline analysis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo import collective_bytes, parse_collectives
from repro.roofline.model import HW_V5E, roofline_terms

SYNTH = """
HloModule m
%cond.1 (a: s32[]) -> pred[] {
  %c = s32[] constant(24)
  ROOT %lt = pred[] compare(s32[] %a, s32[] %c), direction=LT
}
%body.1 (a: s32[]) -> s32[] {
  %ar = f32[128,256] all-reduce(f32[128,256] %x), replica_groups={}
  ROOT %n = s32[] add(s32[] %a, s32[] %one)
}
ENTRY %main () -> f32[] {
  %w = s32[] while(s32[] %i), condition=%cond.1, body=%body.1
  %ag = bf16[512,512] all-gather(bf16[512,256] %y), dimensions={1}
  ROOT %r = f32[] constant(0)
}
"""


def test_parse_synthetic_hlo():
    items = parse_collectives(SYNTH)
    kinds = sorted((k, m) for k, _, m in items)
    assert ("all-gather", 1) in kinds
    assert ("all-reduce", 24) in kinds  # trip count folded in
    agg = collective_bytes(SYNTH)
    expected_ar = 128 * 256 * 4 * 24 * 2.0  # f32, 24 trips, ring factor 2
    expected_ag = 512 * 512 * 2 * 1.0
    assert abs(agg["all-reduce"] - expected_ar) < 1
    assert abs(agg["all-gather"] - expected_ag) < 1


def test_parse_real_psum_module():
    """Lower an actual psum over a 1-device mesh and find the all-reduce."""
    mesh = jax.make_mesh((1,), ("x",))

    def f(a):
        return jax.lax.psum(a, "x")

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    g = jax.jit(shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P()))
    comp = g.lower(jax.ShapeDtypeStruct((8, 16), jnp.float32)).compile()
    agg = collective_bytes(comp.as_text())
    # 1-device groups may be optimized away; parser must not crash and must
    # return a well-formed dict either way
    assert "total" in agg


def test_roofline_terms_math():
    t = roofline_terms(197e12, 819e9, 50e9)  # exactly 1 second each
    assert abs(t["compute_s"] - 1) < 1e-9
    assert abs(t["memory_s"] - 1) < 1e-9
    assert abs(t["collective_s"] - 1) < 1e-9
    t2 = roofline_terms(1e12, 900e9, 0, model_flops=5e11, num_devices=2)
    assert t2["dominant"] == "memory_s"
    assert 0 < t2["useful_flop_fraction"] < 1
