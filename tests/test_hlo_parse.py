"""Collective-parsing layer for the roofline analysis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo import collective_bytes, parse_collectives
from repro.roofline.hlo_cost import hlo_cost
from repro.roofline.model import HW_V5E, roofline_terms

SYNTH = """
HloModule m
%cond.1 (a: s32[]) -> pred[] {
  %c = s32[] constant(24)
  ROOT %lt = pred[] compare(s32[] %a, s32[] %c), direction=LT
}
%body.1 (a: s32[]) -> s32[] {
  %ar = f32[128,256] all-reduce(f32[128,256] %x), replica_groups={}
  ROOT %n = s32[] add(s32[] %a, s32[] %one)
}
ENTRY %main () -> f32[] {
  %w = s32[] while(s32[] %i), condition=%cond.1, body=%body.1
  %ag = bf16[512,512] all-gather(bf16[512,256] %y), dimensions={1}
  ROOT %r = f32[] constant(0)
}
"""


def test_parse_synthetic_hlo():
    items = parse_collectives(SYNTH)
    kinds = sorted((k, m) for k, _, m in items)
    assert ("all-gather", 1) in kinds
    assert ("all-reduce", 24) in kinds  # trip count folded in
    agg = collective_bytes(SYNTH)
    expected_ar = 128 * 256 * 4 * 24 * 2.0  # f32, 24 trips, ring factor 2
    expected_ag = 512 * 512 * 2 * 1.0
    assert abs(agg["all-reduce"] - expected_ar) < 1
    assert abs(agg["all-gather"] - expected_ag) < 1


def test_parse_real_psum_module():
    """Lower an actual psum over a 1-device mesh and find the all-reduce."""
    mesh = jax.make_mesh((1,), ("x",))

    def f(a):
        return jax.lax.psum(a, "x")

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    g = jax.jit(shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P()))
    comp = g.lower(jax.ShapeDtypeStruct((8, 16), jnp.float32)).compile()
    agg = collective_bytes(comp.as_text())
    # 1-device groups may be optimized away; parser must not crash and must
    # return a well-formed dict either way
    assert "total" in agg


# one dot, hand-countable: flops = 2*4*16*8 = 1024; bytes = the dot's
# result (4*16*4=256) + both operands (4*8*4=128, 8*16*4=512) = 896
# (parameter defs are free ops — only the consumer pays the traffic)
_DOT_HLO = """
HloModule tiny
ENTRY %main (a: f32[4,8], b: f32[8,16]) -> f32[4,16] {
  %a = f32[4,8] parameter(0)
  %b = f32[8,16] parameter(1)
  ROOT %d = f32[4,16] dot(f32[4,8] %a, f32[8,16] %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""

# a 5-trip while whose body holds one dot + one add; XLA's own
# cost_analysis would count the body ONCE — hlo_cost must multiply by
# the known_trip_count (and fall back to the condition's constant)
_LOOP_HLO = """
HloModule loop
%cond (p: (s32[], f32[4,8])) -> pred[] {
  %p = (s32[], f32[4,8]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[4,8]) %p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %c), direction=LT
}
%body (p: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %p = (s32[], f32[4,8]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[4,8]) %p), index=0
  %x = f32[4,8] get-tuple-element((s32[], f32[4,8]) %p), index=1
  %w = f32[8,8] constant(0)
  %d = f32[4,8] dot(f32[4,8] %x, f32[8,8] %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ni = s32[] add(s32[] %i, s32[] %one)
  ROOT %t = (s32[], f32[4,8]) tuple(s32[] %ni, f32[4,8] %d)
}
ENTRY %main (a: f32[4,8]) -> (s32[], f32[4,8]) {
  %a = f32[4,8] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4,8]) tuple(s32[] %z, f32[4,8] %a)
  ROOT %w2 = (s32[], f32[4,8]) while((s32[], f32[4,8]) %t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
}
"""


def test_hlo_cost_hand_counted_dot():
    c = hlo_cost(_DOT_HLO)
    assert c["flops"] == 2 * 4 * 16 * 8          # 1024
    assert c["bytes"] == 256 + 128 + 512         # 896


def test_hlo_cost_while_multiplies_by_trip_count():
    c = hlo_cost(_LOOP_HLO)
    # per trip: dot 2*4*8*8 = 512 flops; bytes = dot (128 result +
    # 128 + 256 operands) + add (4 + 4 + 4) = 524. The while op itself,
    # tuples, GTEs, parameters and constants are free; the condition
    # computation is never charged.
    assert c["flops"] == 512 * 5
    assert c["bytes"] == 524 * 5


def test_hlo_cost_trip_count_falls_back_to_cond_constant():
    no_cfg = _LOOP_HLO.replace(
        ', backend_config={"known_trip_count":{"n":"5"}}', "")
    assert hlo_cost(no_cfg) == hlo_cost(_LOOP_HLO)


def test_roofline_terms_math():
    t = roofline_terms(197e12, 819e9, 50e9)  # exactly 1 second each
    assert abs(t["compute_s"] - 1) < 1e-9
    assert abs(t["memory_s"] - 1) < 1e-9
    assert abs(t["collective_s"] - 1) < 1e-9
    t2 = roofline_terms(1e12, 900e9, 0, model_flops=5e11, num_devices=2)
    assert t2["dominant"] == "memory_s"
    assert 0 < t2["useful_flop_fraction"] < 1
