"""MoE dispatch properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep — fall back to the local stub
    from _hypothesis_stub import given, settings, st

from repro.common.config import ModelConfig
from repro.models.moe import expert_capacity, init_moe, moe_ffn


def _cfg(E=4, K=2, cf=8.0):
    return ModelConfig(
        name="t", arch_type="moe", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=64, num_experts=E,
        experts_per_token=K, moe_capacity_factor=cf, dtype="float32")


def test_moe_output_shape_and_finite():
    cfg = _cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    out, aux = moe_ffn(params, cfg, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0


def test_moe_matches_dense_expert_sum():
    """With capacity ample, MoE output == sum of top-k expert FFNs applied
    densely (the dispatch/combine tensors are exact, not approximate)."""
    cfg = _cfg(E=4, K=2, cf=16.0)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 32))
    out, _ = moe_ffn(params, cfg, x)

    xt = x.reshape(-1, 32)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(probs, 2)
    topv = topv / topv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        acc = jnp.zeros((32,))
        for s in range(2):
            e = int(topi[t, s])
            h = jax.nn.silu(xt[t] @ params["wg"][e]) * (xt[t] @ params["wu"][e])
            acc = acc + topv[t, s] * (h @ params["wd"][e])
        ref = ref.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(out.reshape(-1, 32)),
                               np.asarray(ref), atol=1e-4, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(T=st.integers(1, 500), E=st.integers(2, 64), K=st.integers(1, 8))
def test_capacity_covers_topk_on_average(T, E, K):
    K = min(K, E)
    C = expert_capacity(T, E, K, 1.25)
    assert C * E >= T * K  # aggregate capacity >= aggregate demand


def test_tokens_conserved_under_ample_capacity():
    """No token is dropped when capacity factor is large: combine weights
    per token sum to ~1."""
    cfg = _cfg(E=8, K=2, cf=16.0)
    params = init_moe(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 32))
    # peek inside: rerun the routing math
    xt = x.reshape(-1, 32)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(probs, 2)
    topv = topv / topv.sum(-1, keepdims=True)
    # all weights positive and normalized
    np.testing.assert_allclose(np.asarray(topv.sum(-1)), 1.0, atol=1e-5)
