import os

# Tests run on the single real CPU device; the 512-device override is ONLY
# for the dry-run entry point (see launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import dataclasses  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def reduced_f32(arch: str):
    from repro.configs import get_config

    return dataclasses.replace(get_config(arch).reduced(), dtype="float32")
