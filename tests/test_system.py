"""End-to-end behaviour tests for the paper's system (reward ordering on a
reduced stream — the full-scale Figure-2/3/4 reproduction lives in
benchmarks/ and EXPERIMENTS.md)."""
import numpy as np
import pytest

from repro.core.baselines import FixedActionPolicy, RandomPolicy, RouteLLMBert
from repro.core.policy import NeuralUCBRouter
from repro.core.protocol import run_protocol, summarize
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim


@pytest.fixture(scope="module")
def results():
    env = RouterBenchSim(seed=0, n_samples=6000, n_slices=5)
    s, w = env.strong_weak_actions()
    rl = RouteLLMBert(s, w, env.x_emb.shape[1])
    b0 = env.slice_batch(0)
    rl.fit_offline(b0["x_emb"], b0["quality"][:, s], b0["quality"][:, w])
    cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1], num_actions=env.K)
    pols = {
        "neuralucb": NeuralUCBRouter(cfg, seed=0, batch_size=128),
        "random": RandomPolicy(env.K, seed=1),
        "min-cost": FixedActionPolicy(env.min_cost_action()),
        "routellm-bert": rl,
    }
    res = run_protocol(env, pols, epochs=4, verbose=False)
    return env, summarize(res), res


def test_reward_ordering_matches_paper(results):
    """Fig. 2 ordering: NeuralUCB > min-cost >(~) RouteLLM-BERT > random."""
    _, summ, _ = results
    assert summ["neuralucb"]["avg_reward"] > summ["routellm-bert"]["avg_reward"]
    assert summ["neuralucb"]["avg_reward"] > summ["random"]["avg_reward"] + 0.15
    assert summ["min-cost"]["avg_reward"] > summ["routellm-bert"]["avg_reward"]
    assert summ["routellm-bert"]["avg_reward"] > summ["random"]["avg_reward"]


def test_cumulative_gap_widens(results):
    """Fig. 2b: the cumulative-reward gap over random grows with slices."""
    _, _, res = results
    gap = (np.asarray(res["neuralucb"]["cum_reward"])
           - np.asarray(res["random"]["cum_reward"]))
    assert gap[-1] > gap[1]


def test_cost_quality_tradeoff(results):
    """Fig. 4: NeuralUCB spends a fraction of max-quality's cost while
    keeping most of its selected quality."""
    env, summ, _ = results
    n = env.n
    aq = env.data["quality"].argmax(1)
    maxq_cost = env.data["cost"][np.arange(n), aq].mean()
    maxq_quality = env.data["quality"][np.arange(n), aq].mean()
    frac = summ["neuralucb"]["avg_cost"] / maxq_cost
    assert frac < 0.7, f"cost fraction {frac}"
    assert summ["neuralucb"]["avg_quality"] > 0.55 * maxq_quality
