"""Serving substrate: batcher semantics (incl. the round-robin-aging
starvation fix), engine generate, routed pool, and serving-vs-protocol
parity: `RoutedServingPool.submit` over a full replay stream must
reproduce `core.protocol.run_protocol` rewards and action histograms
when given the same quality table and cost vector."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import NeuralUCBRouter
from repro.core.protocol import run_protocol
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim
from repro.serving import Request, RequestBatcher, RoutedServingPool, ServingEngine


def test_batcher_groups_and_pads():
    b = RequestBatcher(max_batch=2, pad_to_multiple=4)
    r1 = Request(tokens=np.array([1, 2, 3]))
    r2 = Request(tokens=np.array([1, 2, 3, 4, 5]))
    r3 = Request(tokens=np.array([9]))
    b.submit(0, r1)
    b.submit(0, r2)
    b.submit(1, r3)
    assert b.pending() == 3
    target, reqs, toks = b.next_batch()
    assert target == 0 and len(reqs) == 2
    assert toks.shape == (2, 8)  # padded to multiple of 4 over max len 5
    assert list(toks[0][:3]) == [1, 2, 3] and toks[0][3] == 0
    target2, reqs2, toks2 = b.next_batch()
    assert target2 == 1 and toks2.shape == (1, 4)
    assert b.next_batch() is None


def test_batcher_minority_queue_never_starves():
    """Regression: next_batch popped the fullest queue, so a minority
    target starved indefinitely whenever a majority queue refilled above
    it every round. Round-robin aging bounds the wait."""
    b = RequestBatcher(max_batch=2, pad_to_multiple=1)
    b.submit(1, Request(tokens=np.array([7])))      # lone minority request
    served = []
    for _ in range(8):                              # steady majority load
        for _ in range(3):
            b.submit(0, Request(tokens=np.array([1, 2])))
        target, _, _ = b.next_batch()
        served.append(target)
    assert 1 in served, f"minority target starved: {served}"
    # the wait is bounded at max_starve rounds even under growing backlog
    assert served.index(1) <= b.max_starve
    # and the majority queue still gets the bulk of the batches
    assert served.count(0) > served.count(1)


def test_batcher_age_resets_after_service():
    """A served queue's age resets — it cannot immediately leapfrog a
    fuller queue again on pure age."""
    b = RequestBatcher(max_batch=1, pad_to_multiple=1)
    b.submit(0, Request(tokens=np.array([1])))
    b.submit(0, Request(tokens=np.array([1])))
    b.submit(1, Request(tokens=np.array([2])))
    assert b.next_batch()[0] == 0       # fullest first
    assert b.next_batch()[0] == 1       # aged minority wins the tie
    assert b.next_batch()[0] == 0
    assert b.next_batch() is None


def test_batcher_flush_deadline_armed_by_arrival_not_epoch():
    """Regression (ISSUE satellite): the flush deadline used to be an
    epoch timer armed at the last flush, so after an empty-then-burst
    arrival the stale deadline had already expired and the first batch
    flushed immediately, undersized. Deadlines must arm per request
    from its OWN arrival time: an idle period leaves nothing armed."""
    now = [0.0]
    b = RequestBatcher(max_batch=4, pad_to_multiple=1, flush_timeout=1.0,
                       clock=lambda: now[0])
    assert b.next_batch() is None
    now[0] = 50.0                    # long idle gap, then a burst
    b.submit(0, Request(tokens=np.array([1])))
    b.submit(0, Request(tokens=np.array([2])))
    # stale-deadline bug: a deadline armed at t=0 expired long ago and
    # this pair would flush here, undersized
    assert b.next_batch() is None
    now[0] = 50.4
    b.submit(0, Request(tokens=np.array([3])))
    b.submit(0, Request(tokens=np.array([4])))
    target, reqs, _ = b.next_batch()         # full batch: always ready
    assert target == 0 and len(reqs) == 4
    # a straggler flushes when ITS OWN age crosses the window...
    b.submit(0, Request(tokens=np.array([5])))
    now[0] = 51.3
    assert b.next_batch() is None            # 0.9s old < 1.0s window
    now[0] = 51.5
    _, reqs, _ = b.next_batch()
    assert len(reqs) == 1
    # ...and force (drain) overrides the window
    b.submit(0, Request(tokens=np.array([6])))
    _, reqs, _ = b.next_batch(force=True)
    assert len(reqs) == 1
    assert b.pending() == 0


def test_batcher_flush_timeout_selects_among_ready_queues_only():
    """A queue inside its flush window is waiting, not starving: it is
    skipped (without aging toward starvation service) until ready."""
    now = [0.0]
    b = RequestBatcher(max_batch=4, pad_to_multiple=1, flush_timeout=1.0,
                       clock=lambda: now[0])
    b.submit(0, Request(tokens=np.array([1])))   # partial, in-window
    for _ in range(4):
        b.submit(1, Request(tokens=np.array([2])))
    target, reqs, _ = b.next_batch()
    assert target == 1 and len(reqs) == 4        # the full queue wins
    assert b.next_batch() is None                # 0 still inside window
    now[0] = 2.0
    assert b.next_batch()[0] == 0


@pytest.fixture(scope="module")
def tiny_engine():
    cfg = dataclasses.replace(get_config("llama3_2_3b").reduced(),
                              dtype="float32")
    return ServingEngine(cfg, seed=0, max_seq=32)


def test_engine_generates(tiny_engine):
    toks = np.ones((2, 5), np.int32)
    out, _ = tiny_engine.generate(toks, max_new=4)
    assert out.shape == (2, 4)
    assert int(out.max()) < tiny_engine.cfg.vocab_size


def test_engine_deterministic_greedy(tiny_engine):
    toks = np.arange(1, 7, dtype=np.int32)[None]
    a, _ = tiny_engine.generate(toks, max_new=3)
    b, _ = tiny_engine.generate(toks, max_new=3)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_routed_pool_round_trip():
    cfgs = [dataclasses.replace(get_config(a).reduced(), dtype="float32")
            for a in ("llama3_2_3b", "mamba2_130m")]
    engines = [ServingEngine(c, seed=i, max_seq=32)
               for i, c in enumerate(cfgs)]
    ucfg = UtilityNetConfig(emb_dim=16, num_actions=2, num_domains=3)
    router = NeuralUCBRouter(ucfg, seed=0, batch_size=8)
    qt = np.random.default_rng(0).uniform(0.3, 0.9, (50, 2)).astype(np.float32)
    pool = RoutedServingPool(router, engines, [1e-4, 1e-6],
                             quality_table=qt, c_max=0.05, max_batch=4)
    rng = np.random.default_rng(1)
    reqs = [Request(tokens=rng.integers(1, 50, size=5),
                    x_emb=rng.normal(size=16).astype(np.float32),
                    x_feat=rng.normal(size=4).astype(np.float32),
                    domain=int(rng.integers(0, 3)), sample_idx=i)
            for i in range(5)]
    out = pool.submit(reqs)
    assert len(out) == 5
    for o in out:
        assert 0 <= o["reward"] <= 1
        assert o["action"] in (0, 1)
        assert o["cost"] > 0
    assert len(router.buffer) == 5


def test_serving_pool_matches_protocol_replay():
    """Serving-parity (ISSUE): a RoutedServingPool driven slice-by-slice
    over a full replay stream must reproduce `run_protocol`'s NeuralUCB
    rewards and action histograms, given the same quality table and a
    cost table derived from the pool's own per-token prices. The cost
    bridge: `generate(max_new=8)` always emits 8 tokens, so request cost
    is cost_per_token * (prompt_len + 8) — the env's cost table is built
    from exactly that expression."""
    K, n, T = 2, 48, 3
    rng = np.random.default_rng(0)
    plen = rng.integers(4, 9, size=n)
    cpt = np.array([2e-4, 1e-5])
    cost = (cpt[None] * (plen[:, None] + 8)).astype(np.float32)
    quality = rng.uniform(0.2, 0.95, size=(n, K)).astype(np.float32)
    data = {
        "domain": rng.integers(0, 3, size=n).astype(np.int32),
        "topic": rng.normal(size=(n, 32)).astype(np.float32),
        "difficulty": np.zeros(n, np.float32),
        "prompt_tokens": plen.astype(np.float32),
        "quality": quality,
        "cost": cost,
        "x_feat": rng.normal(size=(n, 4)).astype(np.float32),
        "model_names": np.array(["a", "b"]),
    }
    henv = RouterBenchSim(seed=0, n_slices=T, cost_lambda=1.0, data=data)
    ucfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=K,
                            num_domains=3)

    # reference: the protocol host loop
    host = run_protocol(henv, {"nucb": NeuralUCBRouter(
        ucfg, seed=0, batch_size=16)}, epochs=2, verbose=False)["nucb"]

    # system under test: the serving pool over the identical stream
    cfgs = [dataclasses.replace(get_config(a).reduced(), dtype="float32")
            for a in ("llama3_2_3b", "mamba2_130m")]
    engines = [ServingEngine(c, seed=i, max_seq=32)
               for i, c in enumerate(cfgs)]
    pool = RoutedServingPool(
        NeuralUCBRouter(ucfg, seed=0, batch_size=16), engines, cpt,
        quality_table=quality, c_max=henv.c_max, cost_lambda=1.0,
        max_batch=8)
    tok_rng = np.random.default_rng(1)
    for t in range(T):
        b = henv.slice_batch(t)
        reqs = [Request(tokens=tok_rng.integers(1, 50, size=int(plen[i])),
                        x_emb=henv.x_emb[i], x_feat=data["x_feat"][i],
                        domain=int(data["domain"][i]), sample_idx=int(i))
                for i in b["idx"]]
        recs = pool.submit(reqs)
        pool.end_slice(epochs=2)
        # per-slice parity: rewards and the action histogram
        np.testing.assert_allclose(
            np.mean([r["reward"] for r in recs]),
            host["avg_reward"][t], rtol=1e-5, atol=1e-5,
            err_msg=f"slice {t} avg reward")
        hist = np.bincount([r["action"] for r in recs], minlength=K)
        np.testing.assert_array_equal(hist, host["action_hist"][t],
                                      err_msg=f"slice {t} action hist")
        np.testing.assert_allclose(
            np.mean([r["cost"] for r in recs]), host["avg_cost"][t],
            rtol=1e-5, err_msg=f"slice {t} avg cost")


def test_pool_default_c_max_uses_actual_max_seq():
    """Regression (ISSUE satellite): the default c_max normalized by a
    fixed 4096-token horizon while the engines cap sequences at
    max_seq — every realizable cost then normalized to < max_seq/4096
    of the range, compressing rewards toward quality-only and erasing
    cost discrimination between arms. The default must derive from the
    pool's actual max_seq (explicit c_max still wins)."""
    import types
    engines = [types.SimpleNamespace(max_seq=256),
               types.SimpleNamespace(max_seq=64)]
    cpt = [1e-4, 1e-6]
    pool = RoutedServingPool(object(), engines, cpt)
    assert pool.c_max == pytest.approx(1e-4 * 256)
    explicit = RoutedServingPool(object(), engines, cpt, c_max=0.05)
    assert explicit.c_max == 0.05
    # realizable cost at the cap now reaches the top of the normalized
    # range instead of 256/4096 of it
    assert 1e-4 * max(e.max_seq for e in engines) / pool.c_max == \
        pytest.approx(1.0)


def test_routed_pool_log_is_bounded():
    """Regression (PR-5 ISSUE): ``pool.log`` grew without bound under
    sustained traffic. It must be a capped deque keeping the most
    recent records, counting evictions, with ``log_capacity=None`` as
    the explicit unbounded opt-out."""
    import types
    engines = [types.SimpleNamespace(max_seq=64)]
    pool = RoutedServingPool(object(), engines, [1e-4], log_capacity=8)
    assert pool.log.maxlen == 8
    assert pool.dropped_log_records == 0

    unbounded = RoutedServingPool(object(), engines, [1e-4],
                                  log_capacity=None)
    assert unbounded.log.maxlen is None
    with pytest.raises(ValueError, match="log_capacity"):
        RoutedServingPool(object(), engines, [1e-4], log_capacity=0)


def test_routed_pool_submit_counts_dropped_records():
    """End-to-end: submit() itself maintains the eviction counter."""
    cfgs = [dataclasses.replace(get_config(a).reduced(), dtype="float32")
            for a in ("llama3_2_3b",)]
    engines = [ServingEngine(cfgs[0], seed=0, max_seq=32)]
    ucfg = UtilityNetConfig(emb_dim=16, num_actions=1, num_domains=3)
    router = NeuralUCBRouter(ucfg, seed=0, batch_size=8)
    pool = RoutedServingPool(router, engines, [1e-4], c_max=0.05,
                             max_batch=4, log_capacity=3)
    rng = np.random.default_rng(2)
    reqs = [Request(tokens=rng.integers(1, 50, size=5),
                    x_emb=rng.normal(size=16).astype(np.float32),
                    x_feat=rng.normal(size=4).astype(np.float32),
                    domain=int(rng.integers(0, 3)), sample_idx=-1)
            for i in range(5)]
    pool.submit(reqs)
    assert len(pool.log) == 3
    assert pool.dropped_log_records == 2
    pool.submit(reqs)
    assert len(pool.log) == 3
    assert pool.dropped_log_records == 7
