"""Serving substrate: batcher semantics, engine generate, routed pool."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import NeuralUCBRouter
from repro.core.utilitynet import UtilityNetConfig
from repro.serving import Request, RequestBatcher, RoutedServingPool, ServingEngine


def test_batcher_groups_and_pads():
    b = RequestBatcher(max_batch=2, pad_to_multiple=4)
    r1 = Request(tokens=np.array([1, 2, 3]))
    r2 = Request(tokens=np.array([1, 2, 3, 4, 5]))
    r3 = Request(tokens=np.array([9]))
    b.submit(0, r1)
    b.submit(0, r2)
    b.submit(1, r3)
    assert b.pending() == 3
    target, reqs, toks = b.next_batch()
    assert target == 0 and len(reqs) == 2
    assert toks.shape == (2, 8)  # padded to multiple of 4 over max len 5
    assert list(toks[0][:3]) == [1, 2, 3] and toks[0][3] == 0
    target2, reqs2, toks2 = b.next_batch()
    assert target2 == 1 and toks2.shape == (1, 4)
    assert b.next_batch() is None


@pytest.fixture(scope="module")
def tiny_engine():
    cfg = dataclasses.replace(get_config("llama3_2_3b").reduced(),
                              dtype="float32")
    return ServingEngine(cfg, seed=0, max_seq=32)


def test_engine_generates(tiny_engine):
    toks = np.ones((2, 5), np.int32)
    out, _ = tiny_engine.generate(toks, max_new=4)
    assert out.shape == (2, 4)
    assert int(out.max()) < tiny_engine.cfg.vocab_size


def test_engine_deterministic_greedy(tiny_engine):
    toks = np.arange(1, 7, dtype=np.int32)[None]
    a, _ = tiny_engine.generate(toks, max_new=3)
    b, _ = tiny_engine.generate(toks, max_new=3)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_routed_pool_round_trip():
    cfgs = [dataclasses.replace(get_config(a).reduced(), dtype="float32")
            for a in ("llama3_2_3b", "mamba2_130m")]
    engines = [ServingEngine(c, seed=i, max_seq=32)
               for i, c in enumerate(cfgs)]
    ucfg = UtilityNetConfig(emb_dim=16, num_actions=2, num_domains=3)
    router = NeuralUCBRouter(ucfg, seed=0, batch_size=8)
    qt = np.random.default_rng(0).uniform(0.3, 0.9, (50, 2)).astype(np.float32)
    pool = RoutedServingPool(router, engines, [1e-4, 1e-6],
                             quality_table=qt, c_max=0.05, max_batch=4)
    rng = np.random.default_rng(1)
    reqs = [Request(tokens=rng.integers(1, 50, size=5),
                    x_emb=rng.normal(size=16).astype(np.float32),
                    x_feat=rng.normal(size=4).astype(np.float32),
                    domain=int(rng.integers(0, 3)), sample_idx=i)
            for i in range(5)]
    out = pool.submit(reqs)
    assert len(out) == 5
    for o in out:
        assert 0 <= o["reward"] <= 1
        assert o["action"] in (0, 1)
        assert o["cost"] > 0
    assert len(router.buffer) == 5
