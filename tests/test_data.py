"""Synthetic RouterBench substrate: shapes, determinism, calibration bands."""
import numpy as np
import pytest

from repro.data.encoders import ENCODERS, encode
from repro.data.routerbench import (
    N_DOMAINS,
    N_MODELS,
    N_SAMPLES,
    RouterBenchSim,
    generate_routerbench,
)


@pytest.fixture(scope="module")
def env():
    return RouterBenchSim(seed=0, n_samples=8000)


def test_published_shape_defaults():
    assert N_SAMPLES == 36_497 and N_DOMAINS == 86 and N_MODELS == 11


def test_generator_shapes(env):
    d = env.data
    n = env.n
    assert d["quality"].shape == (n, 11)
    assert d["cost"].shape == (n, 11)
    assert d["domain"].max() < 86
    assert np.all((d["quality"] >= 0) & (d["quality"] <= 1))
    assert np.all(d["cost"] > 0)


def test_deterministic():
    a = generate_routerbench(seed=3, n_samples=500)
    b = generate_routerbench(seed=3, n_samples=500)
    np.testing.assert_array_equal(a["quality"], b["quality"])
    c = generate_routerbench(seed=4, n_samples=500)
    assert not np.array_equal(a["quality"], c["quality"])


def test_reward_table_matches_eq1(env):
    import jax.numpy as jnp

    from repro.core.reward import utility_reward

    i, k = 17, 3
    r = float(utility_reward(env.data["quality"][i, k],
                             env.data["cost"][i, k], env.c_max))
    assert abs(r - env.reward_table[i, k]) < 1e-6


def test_slices_partition(env):
    all_idx = np.sort(np.concatenate(env.slices))
    np.testing.assert_array_equal(all_idx, np.arange(env.n))


def test_encoders_dims(env):
    for name, spec in ENCODERS.items():
        e = encode(name, env.data["topic"][:100], env.data["domain"][:100])
        assert e.shape == (100, spec.dim)
        np.testing.assert_allclose(np.linalg.norm(e, axis=1), 1.0, atol=1e-5)


def test_calibration_bands(env):
    """The paper-anchored operating point (see DESIGN.md §5)."""
    mr = env.mean_reward()
    assert 0.29 <= mr.mean() <= 0.36, "random-policy band"
    mc = mr[env.min_cost_action()]
    assert 0.49 <= mc <= 0.55, "min-cost band"
    # max-quality reference: high quality, high cost
    aq = env.data["quality"].argmax(1)
    q = env.data["quality"][np.arange(env.n), aq].mean()
    assert q > 0.8
    # oracle leaves headroom above min-cost
    assert env.reward_table.max(1).mean() > mc + 0.12
