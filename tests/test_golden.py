"""Golden regression: fixed-seed `run_neuralucb_device` on a tiny env
against a committed metrics snapshot (tests/golden/neuralucb_tiny.json),
so engine refactors can't silently shift the Figures 2-4 numbers — plus
a baselines snapshot (tests/golden/baselines_tiny.json, generated from
the pre-unification `_baseline_scan`) that pins the unified
`BanditPolicy` runner to the exact trajectories of the scan it replaced
(stationary AND scenario paths, deterministic AND PRNG policies).

The run executes in a subprocess with PYTHONHASHSEED pinned: the whole
pipeline (dataset, encoder, protocol scan) is then a deterministic
function of (platform, jax version) — see the encoders crc32 fix.
Tolerances are two-tier: when the snapshot was produced under the same
jax version, per-slice curves must match tightly (2e-4); under a
different jax version, XLA codegen changes can flip argmax decisions and
chaotically perturb trajectories, so only the summary-level means are
held (0.03) — still enough to catch schedule/PRNG/reward regressions,
which shift means systematically.

Regenerate (after an INTENTIONAL semantics change only):

    PYTHONPATH=src python tests/test_golden.py --regen
"""
import json
import os
import subprocess
import sys

import numpy as np

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "neuralucb_tiny.json")
GOLDEN_BASE = os.path.join(os.path.dirname(__file__), "golden",
                           "baselines_tiny.json")
REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))

_RUN_SRC = """
import json
import jax
import numpy as np
from repro.core.protocol import summarize
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim
from repro.sim import DeviceReplayEnv, run_neuralucb_device

henv = RouterBenchSim(seed=0, n_samples=600, n_slices=3)
denv = DeviceReplayEnv.from_host(henv)
cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)
res = run_neuralucb_device(denv, cfg, seed=0, train_steps=32,
                           batch_size=64, ucb_backend="jnp")
summ = summarize({"neuralucb": res})["neuralucb"]
out = {
    "jax": jax.__version__,
    "config": {"n_samples": 600, "n_slices": 3, "seed": 0,
               "train_steps": 32, "batch_size": 64,
               "ucb_backend": "jnp"},
    "avg_reward": res["avg_reward"],
    "cum_reward": res["cum_reward"],
    "avg_cost": res["avg_cost"],
    "avg_quality": res["avg_quality"],
    "oracle_avg_reward": res["oracle_avg_reward"],
    "action_hist": np.asarray(res["action_hist"]).tolist(),
    "summary": summ,
}
print("GOLDEN=" + json.dumps(out))
"""


_BASE_SRC = """
import json
import jax
import numpy as np
from repro.data.routerbench import RouterBenchSim
from repro.sim import (DeviceReplayEnv, fixed_policy, greedy_policy,
                       random_policy, run_baseline_device)

henv = RouterBenchSim(seed=0, n_samples=600, n_slices=3)
denv = DeviceReplayEnv.from_host(henv)
out = {"jax": jax.__version__,
       "config": {"n_samples": 600, "n_slices": 3, "seed": 0}}
runs = {
    "greedy": (greedy_policy(denv.K), None),
    "min-cost": (fixed_policy(denv.min_cost_action(), "min-cost"), None),
    "random": (random_policy(denv.K), None),
    "greedy@price_shock": (greedy_policy(denv.K), "price_shock"),
    "random@arm_arrival": (random_policy(denv.K), "arm_arrival"),
}
for name, (pol, scen) in runs.items():
    res = run_baseline_device(denv, pol, seed=0, scenario=scen)
    rec = {k: [float(v) for v in res[k]]
           for k in ("avg_reward", "avg_cost", "avg_quality",
                     "oracle_avg_reward")}
    rec["action_hist"] = np.asarray(res["action_hist"]).tolist()
    out[name] = rec
print("BASEGOLDEN=" + json.dumps(out))
"""


def _run_subprocess(src: str, tag: str) -> dict:
    env = dict(os.environ, PYTHONHASHSEED="0", JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"), env.get("PYTHONPATH")) if p)
    out = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith(tag + "=")][-1]
    return json.loads(line.split("=", 1)[1])


def _run_golden() -> dict:
    return _run_subprocess(_RUN_SRC, "GOLDEN")


def _run_base_golden() -> dict:
    return _run_subprocess(_BASE_SRC, "BASEGOLDEN")


def test_baselines_match_pre_unification_scan_snapshot():
    """The unified BanditPolicy runner must replay the committed
    trajectories of the pre-refactor `_baseline_scan` exactly — the
    deterministic policies bit-wise, the PRNG policy through the
    preserved one-split-per-slice key discipline, and the scenario path
    (effective tables + availability fallback) included."""
    with open(GOLDEN_BASE) as f:
        golden = json.load(f)
    got = _run_base_golden()
    assert got["config"] == golden["config"]
    same_jax = got["jax"] == golden["jax"]
    names = [k for k in golden if k not in ("jax", "config")]
    for name in names:
        g0, g1 = golden[name], got[name]
        if same_jax:
            for key in ("avg_reward", "avg_cost", "avg_quality",
                        "oracle_avg_reward"):
                np.testing.assert_allclose(
                    g1[key], g0[key], rtol=2e-5, atol=1e-6,
                    err_msg=f"{name}/{key} drifted from tests/golden/"
                            f"baselines_tiny.json")
            np.testing.assert_array_equal(
                np.asarray(g1["action_hist"]),
                np.asarray(g0["action_hist"]), err_msg=name)
        else:
            for key in ("avg_reward", "avg_cost", "avg_quality"):
                np.testing.assert_allclose(
                    np.mean(g1[key][1:]), np.mean(g0[key][1:]), atol=0.03,
                    err_msg=f"{name}/{key} (cross-jax tolerance)")


def test_neuralucb_tiny_matches_golden_snapshot():
    with open(GOLDEN) as f:
        golden = json.load(f)
    got = _run_golden()
    assert got["config"] == golden["config"]
    same_jax = got["jax"] == golden["jax"]
    curves = ("avg_reward", "cum_reward", "avg_cost", "avg_quality",
              "oracle_avg_reward")
    if same_jax:
        for key in curves:
            np.testing.assert_allclose(
                got[key], golden[key], rtol=2e-4, atol=2e-4,
                err_msg=f"{key} drifted from tests/golden/"
                        f"neuralucb_tiny.json — if the change is an "
                        f"INTENDED semantics change, regenerate via "
                        f"`python tests/test_golden.py --regen`")
        # decisions: histograms may differ by a handful of argmax flips
        h0 = np.asarray(golden["action_hist"], np.float64)
        h1 = np.asarray(got["action_hist"], np.float64)
        assert np.abs(h0 - h1).sum() <= 0.02 * h0.sum()
    else:
        for key in ("avg_reward", "avg_cost", "avg_quality",
                    "oracle_avg_reward"):
            np.testing.assert_allclose(
                np.mean(got[key][1:]), np.mean(golden[key][1:]),
                atol=0.03, err_msg=f"{key} summary mean drifted "
                                   f"(cross-jax-version tolerance)")
    # structure is held unconditionally
    assert np.asarray(got["action_hist"]).shape == \
        np.asarray(golden["action_hist"]).shape
    np.testing.assert_allclose(
        np.asarray(got["action_hist"]).sum(axis=1),
        np.asarray(golden["action_hist"]).sum(axis=1))


if __name__ == "__main__":
    if "--regen" not in sys.argv and "--regen-baselines" not in sys.argv:
        sys.exit("usage: python tests/test_golden.py "
                 "--regen | --regen-baselines")
    os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
    if "--regen" in sys.argv:
        snap = _run_golden()
        with open(GOLDEN, "w") as f:
            json.dump(snap, f, indent=1)
        print(f"wrote {GOLDEN} (jax {snap['jax']})")
    if "--regen-baselines" in sys.argv:
        snap = _run_base_golden()
        with open(GOLDEN_BASE, "w") as f:
            json.dump(snap, f, indent=1)
        print(f"wrote {GOLDEN_BASE} (jax {snap['jax']})")
