"""Parity suites for the fused NeuralUCB hot-path kernels
(`kernels.nucb_decide`, `kernels.ainv_rebuild`) vs their jnp references,
plus the bf16 mixed-precision train path (DESIGN.md §14).

On CPU CI the Pallas legs run in interpret mode; on TPU they compile —
``INTERPRET`` pins whichever leg is NOT the default dispatch so the
parity checks never degenerate to ref-vs-ref.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep — fall back to the local stub
    from _hypothesis_stub import given, settings, st

from repro.core import neuralucb as NU
from repro.core import utilitynet as UN
from repro.kernels.ainv_rebuild import ainv_rebuild, ainv_rebuild_ref
from repro.kernels.backend import on_tpu
from repro.kernels.nucb_decide import (
    nucb_decide,
    nucb_decide_ref,
    prepare_decide_inputs,
)
from repro.sim.policies import _decide_ucb, _weighted_loss

INTERPRET = not on_tpu()
#: two-tier tolerances: f32 kernels are near-bit-exact vs the jnp refs;
#: the bf16 compute tier absorbs mantissa loss in the trunk GEMMs
ATOL = {jnp.float32: 3e-5, jnp.bfloat16: 5e-2}


def _cfg(**kw):
    return UN.UtilityNetConfig(**kw)


def _decide_case(seed, B, cfg):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    params = UN.init_utilitynet(ks[0], cfg)
    x_emb = jax.random.normal(ks[1], (B, cfg.emb_dim))
    x_feat = jax.random.normal(ks[2], (B, cfg.feat_dim))
    domain = jax.random.randint(ks[3], (B,), 0, cfg.num_domains)
    F = cfg.ucb_feature_dim
    Lm = jax.random.normal(jax.random.PRNGKey(seed + 7), (F, F)) * 0.05
    ainv = Lm @ Lm.T + jnp.eye(F) * 0.5
    return params, x_emb, x_feat, domain, ainv


@pytest.mark.parametrize("B", [5, 37, 256])
@pytest.mark.parametrize("beta,tau_g", [(0.0, 0.5), (1.3, 0.5),
                                        (2.0, 1.1)])
@pytest.mark.parametrize("masked", [False, True])
def test_nucb_decide_matches_ref(B, beta, tau_g, masked):
    cfg = _cfg()
    params, x_emb, x_feat, domain, ainv = _decide_case(0, B, cfg)
    avail = None
    if masked:
        avail = jnp.ones((cfg.num_actions,)).at[jnp.asarray([1, 4])].set(0.0)
    a_k, g_k, mu_k, gp_k = nucb_decide(
        params, cfg, x_emb, x_feat, domain, ainv, jnp.float32(beta),
        jnp.float32(tau_g), avail, block_b=64, interpret=INTERPRET)
    # the jnp oracle, platform-independent (interpret=None would resolve
    # to the compiled kernel on TPU)
    pre = prepare_decide_inputs(params, x_emb, x_feat, domain)
    ctx, gp_r = pre[0], pre[1]
    a_r, g_r, mu_r = nucb_decide_ref(
        ctx, *pre[2:], ainv, gp_r,
        None if avail is None else avail.astype(jnp.float32),
        jnp.float32(beta), jnp.float32(tau_g))
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
    tol = ATOL[jnp.float32]
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(mu_k), np.asarray(mu_r),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(gp_k), np.asarray(gp_r),
                               atol=tol, rtol=tol)
    if masked:
        assert not np.isin(np.asarray(a_k), [1, 4]).any()


@pytest.mark.parametrize("masked", [False, True])
def test_nucb_decide_matches_decide_ucb_jnp(masked):
    """End-to-end contract: the fused op must reproduce the policy
    zoo's jnp DECIDE (`_decide_ucb(backend="jnp")`) — action, chosen-arm
    feature, and safe-greedy mean."""
    cfg = _cfg()
    B = 96
    params, x_emb, x_feat, domain, ainv = _decide_case(3, B, cfg)
    batch = {"x_emb": x_emb, "x_feat": x_feat, "domain": domain}
    avail = None
    if masked:
        avail = jnp.ones((cfg.num_actions,)).at[0].set(0.0)
    beta, tau_g = jnp.float32(1.1), jnp.float32(0.5)
    a_j, lp_j, g_j, mu_j, _ = _decide_ucb(params, ainv, batch, beta,
                                          tau_g, cfg, "jnp", avail)
    a_k, g_k, mu_k, _ = nucb_decide(params, cfg, x_emb, x_feat, domain,
                                    ainv, beta, tau_g, avail,
                                    interpret=INTERPRET)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_j))
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_j),
                               atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(np.asarray(mu_k), np.asarray(mu_j),
                               atol=3e-5, rtol=3e-5)


def test_nucb_decide_bf16_compute_tier():
    """bf16 trunk compute stays within the loose tier: scores move by at
    most bf16 rounding, so the argmax agrees except near exact ties."""
    cfg = _cfg()
    B = 128
    params, x_emb, x_feat, domain, ainv = _decide_case(5, B, cfg)
    beta, tau_g = jnp.float32(1.0), jnp.float32(0.5)
    a_r, g_r, mu_r, _ = nucb_decide(params, cfg, x_emb, x_feat, domain,
                                    ainv, beta, tau_g)
    a_b, g_b, mu_b, _ = nucb_decide(params, cfg, x_emb, x_feat, domain,
                                    ainv, beta, tau_g, interpret=True,
                                    compute_dtype=jnp.bfloat16)
    tol = ATOL[jnp.bfloat16]
    assert float(np.mean(np.asarray(a_b) == np.asarray(a_r))) >= 0.9
    agree = np.asarray(a_b) == np.asarray(a_r)
    np.testing.assert_allclose(np.asarray(mu_b), np.asarray(mu_r),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(g_b)[agree],
                               np.asarray(g_r)[agree],
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("N,F", [(40, 129), (256, 129), (1024, 129),
                                 (64, 257), (7, 33)])
@pytest.mark.parametrize("weighted", [False, True])
def test_ainv_rebuild_matches_ref(N, F, weighted):
    ks = jax.random.split(jax.random.PRNGKey(N + F), 2)
    gs = jax.random.normal(ks[0], (N, F)) * 0.3
    w = None
    if weighted:
        w = jax.random.uniform(ks[1], (N,))
        w = w.at[: N // 3].set(0.0)          # dead buffer rows
    out = ainv_rebuild(gs, 1.3, weights=w, block_r=128,
                       interpret=INTERPRET)
    ref = ainv_rebuild_ref(gs, 1.3, weights=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)
    # SPD sanity: symmetric, positive diagonal
    np.testing.assert_allclose(np.asarray(out), np.asarray(out).T,
                               atol=1e-5)
    assert (np.diag(np.asarray(out)) > 0).all()


@settings(deadline=None, max_examples=25)
@given(n=st.integers(min_value=2, max_value=48),
       lam=st.floats(min_value=0.25, max_value=4.0),
       zero_frac=st.floats(min_value=0.0, max_value=1.0))
def test_ainv_rebuild_property(n, lam, zero_frac):
    """Property: for any buffer size, ridge strength, and dead-row
    fraction — INCLUDING all rows zero-weighted, where A^-1 must come
    back exactly (lambda0 I)^-1 — the kernel matches
    ``NU.rebuild_ainv``."""
    d = 17
    gs = jax.random.normal(jax.random.PRNGKey(n), (n, d)) * 0.5
    nz = int(round(zero_frac * n))
    w = jnp.ones((n,)).at[:nz].set(0.0)
    out = ainv_rebuild(gs, lam, weights=w, block_r=16, interpret=True)
    ref = NU.rebuild_ainv(gs, lam, weights=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)
    if nz == n:
        np.testing.assert_allclose(np.asarray(out),
                                   np.eye(d) / lam, atol=1e-5)


def test_weighted_loss_bf16_parity_and_f32_state():
    """bf16 train compute: loss within the bf16 tier of the f32 path,
    gradients finite and still f32 (master params / accumulators never
    leave f32 — DESIGN.md §14.2)."""
    cfg = _cfg()
    B = 64
    ks = jax.random.split(jax.random.PRNGKey(11), 6)
    params = UN.init_utilitynet(ks[0], cfg)
    batch = {
        "x_emb": jax.random.normal(ks[1], (B, cfg.emb_dim)),
        "x_feat": jax.random.normal(ks[2], (B, cfg.feat_dim)),
        "domain": jax.random.randint(ks[3], (B,), 0, cfg.num_domains),
        "action": jax.random.randint(ks[4], (B,), 0, cfg.num_actions),
        "reward": jax.random.uniform(ks[5], (B,)),
        "gate_label": (jax.random.uniform(ks[5], (B,)) > 0.5
                       ).astype(jnp.float32),
        "w": jnp.ones((B,)),
        "gate_w": jnp.ones((B,)),
    }
    vg = jax.value_and_grad(_weighted_loss, has_aux=True)
    (l32, _), g32 = vg(params, cfg, batch, "f32")
    (l16, _), g16 = vg(params, cfg, batch, "bf16")
    tol = ATOL[jnp.bfloat16]
    np.testing.assert_allclose(float(l16), float(l32), atol=tol, rtol=tol)
    for leaf in jax.tree.leaves(g16):
        assert leaf.dtype == jnp.float32
        assert np.isfinite(np.asarray(leaf)).all()


def test_neuralucb_precision_threads_through_registry():
    """The precision knob reaches every neural builder via make_policy
    (the experiments compiler passes ``train_precision`` when a spec
    sets TrainSpec.precision != "f32"); unknown values fail loudly."""
    from repro.sim.policies import make_policy
    cfg = _cfg()
    for name in ("neuralucb", "neural_ts", "eps_greedy", "boltzmann"):
        pol, hyp = make_policy(name, None, cfg, train_precision="bf16")
        assert pol.train is not None
    with pytest.raises(KeyError):
        make_policy("neuralucb", None, cfg,
                    train_precision="fp8")  # not in TRAIN_PRECISIONS
