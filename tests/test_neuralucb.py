"""NeuralUCB statistics: Sherman-Morrison, rebuild, UCB properties."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep — fall back to the local stub
    from _hypothesis_stub import given, settings, st

from repro.core.neuralucb import (
    augment,
    init_ainv,
    rebuild_ainv,
    sherman_morrison_batch,
    sherman_morrison_update,
    ucb_bonus,
)


def _rand_gs(seed, n, d):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * 0.5


def test_sherman_morrison_matches_direct_inverse():
    d, n = 16, 40
    gs = _rand_gs(0, n, d)
    ainv = init_ainv(d, ridge_lambda0=1.0)
    ainv = sherman_morrison_batch(ainv, gs)
    A = jnp.eye(d) + gs.T @ gs
    np.testing.assert_allclose(np.asarray(ainv @ A), np.eye(d), atol=1e-3)


def test_rebuild_matches_direct_inverse():
    d, n = 12, 100
    gs = _rand_gs(1, n, d)
    ainv = rebuild_ainv(gs, ridge_lambda0=2.0)
    A = 2.0 * jnp.eye(d) + gs.T @ gs
    np.testing.assert_allclose(np.asarray(ainv @ A), np.eye(d), atol=1e-3)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(1, 60))
def test_ainv_stays_symmetric_pd(seed, n):
    d = 8
    gs = _rand_gs(seed, n, d)
    ainv = sherman_morrison_batch(init_ainv(d), gs)
    a = np.asarray(ainv)
    np.testing.assert_allclose(a, a.T, atol=1e-5)
    eig = np.linalg.eigvalsh(a)
    assert np.all(eig > 0)


def test_bonus_shrinks_with_observations():
    d = 8
    g = jnp.ones((d,)) / np.sqrt(d)
    ainv0 = init_ainv(d)
    b0 = float(ucb_bonus(ainv0, g))
    ainv1 = sherman_morrison_update(ainv0, g)
    b1 = float(ucb_bonus(ainv1, g))
    assert b1 < b0


@settings(max_examples=30, deadline=None)
@given(beta1=st.floats(0.1, 2.0), beta2=st.floats(2.01, 10.0))
def test_ucb_score_monotone_in_beta(beta1, beta2):
    """s = mu + beta*bonus: larger beta never lowers any score."""
    d = 8
    h = jax.random.normal(jax.random.PRNGKey(0), (5, 3, d))
    g = augment(h)
    ainv = init_ainv(d + 1)
    mu = jnp.zeros((5, 3))
    s1 = mu + beta1 * ucb_bonus(ainv, g)
    s2 = mu + beta2 * ucb_bonus(ainv, g)
    assert bool(jnp.all(s2 >= s1))


def test_augment_unit_norm():
    h = jax.random.normal(jax.random.PRNGKey(2), (7, 16)) * 30.0
    g = augment(h)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(g), axis=-1), 1.0,
                               atol=1e-5)
