"""Unified BanditPolicy runtime + policy zoo tests (DESIGN.md §10):
registry coverage, zoo sanity (LinUCB beats random on a linear-reward
synthetic env; NeuralTS and ε-greedy reproduce net-greedy at zero
exploration), the scenario-aware dynamic min-cost baseline, the
(policy × hypers × seed) sweep's one-dispatch annotated schema, and the
serving-side exploration variants of NeuralUCBRouter."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.policy import NeuralUCBRouter
from repro.core.protocol import summarize, summarize_sweep
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim
from repro.sim import (
    POLICIES,
    DeviceReplayEnv,
    LinUCBHypers,
    fixed_policy,
    linucb_policy,
    make_policy,
    make_scenario,
    random_policy,
    run_baseline_device,
    run_policy_device,
    run_policy_sweep,
    sweep_point_results,
)

ZOO_KW = dict(train_steps=32, batch_size=64)


@pytest.fixture(scope="module")
def envs():
    henv = RouterBenchSim(seed=0, n_samples=900, n_slices=3)
    return henv, DeviceReplayEnv.from_host(henv)


@pytest.fixture(scope="module")
def cfg(envs):
    henv, _ = envs
    return UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)


def linear_env(seed=0, n=3000, K=5, d=16, T=10):
    """Synthetic replay env whose reward is LINEAR in the (normalized)
    context — LinUCB's realizable case: reward[i, k] = clip(x_i . theta_k)
    with well-separated per-arm directions."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    xn = x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-6)
    theta = rng.standard_normal((K, d)).astype(np.float32)
    theta /= np.maximum(np.linalg.norm(theta, axis=1, keepdims=True), 1e-6)
    reward = np.clip(0.5 + 0.5 * xn @ theta.T, 0.0, 1.0).astype(np.float32)
    S = n // T
    idx = np.arange(T * S, dtype=np.int32).reshape(T, S)
    mask = np.ones((T, S), np.float32)
    return DeviceReplayEnv(
        x_emb=jnp.asarray(x), x_feat=jnp.zeros((n, 4), jnp.float32),
        domain=jnp.zeros((n,), jnp.int32),
        quality=jnp.asarray(reward), cost=jnp.ones((n, K), jnp.float32),
        reward=jnp.asarray(reward), idx=jnp.asarray(idx),
        mask=jnp.asarray(mask), cost_lambda=1.0)


def test_registry_has_required_policies():
    required = {"random", "min_cost", "max_quality", "greedy",
                "dyn_min_cost", "linucb", "neuralucb", "neural_ts",
                "eps_greedy", "boltzmann"}
    assert required <= set(POLICIES)


def test_linucb_beats_random_on_linear_env():
    """Zoo sanity: on a realizable linear-reward env, disjoint LinUCB
    must decisively beat uniform random. T > K slices: decisions are
    batched per slice, so the first ~K slices are LinUCB's forced
    exploration of unplayed arms (bonus alpha*|g| dominates an all-zero
    mean) and exploitation needs slices left after that."""
    denv = linear_env()
    lin = run_policy_device(denv, linucb_policy(),
                            LinUCBHypers(alpha=jnp.float32(0.5),
                                         ridge=jnp.float32(1.0)), seed=0)
    rnd = run_baseline_device(denv, random_policy(denv.K), seed=1)
    summ = summarize({"linucb": lin, "random": rnd})
    assert summ["linucb"]["avg_reward"] > summ["random"]["avg_reward"] + 0.05
    # and approaches the oracle far closer than random does
    assert summ["linucb"]["dynamic_regret"] < \
        0.5 * summ["random"]["dynamic_regret"]


def test_neural_ts_and_eps_greedy_reproduce_greedy_at_zero_explore(envs, cfg):
    """At zero exploration both NeuralTS (nu=0) and ε-greedy (ε=0)
    degenerate to net-greedy (argmax of the UtilityNet mean). With the
    runner's fixed key discipline and the shared train path, the two
    trajectories must be IDENTICAL decision-for-decision."""
    _, denv = envs
    ts_pol, ts_hyp = make_policy("neural_ts", denv, cfg, explore=0.0)
    eg_pol, eg_hyp = make_policy("eps_greedy", denv, cfg, explore=0.0)
    ts = run_policy_device(denv, ts_pol, ts_hyp, seed=0, **ZOO_KW)
    eg = run_policy_device(denv, eg_pol, eg_hyp, seed=0, **ZOO_KW)
    np.testing.assert_array_equal(ts["action_hist"], eg["action_hist"])
    np.testing.assert_allclose(ts["avg_reward"], eg["avg_reward"],
                               rtol=1e-6, atol=1e-7)
    # nonzero exploration genuinely changes the trajectory
    ts2_pol, ts2_hyp = make_policy("neural_ts", denv, cfg, explore=2.0)
    ts2 = run_policy_device(denv, ts2_pol, ts2_hyp, seed=0, **ZOO_KW)
    assert not np.array_equal(ts["action_hist"], ts2["action_hist"])


def test_zoo_policies_learn_on_routerbench(envs, cfg):
    """Every neural explorer must clear the random baseline on the
    standard surrogate stream (exploration sanity, not a ranking claim
    at this tiny scale; LinUCB is excluded here — with K=11 arms and 3
    slice-batched decisions it is still in forced exploration, which the
    linear-env test covers properly)."""
    _, denv = envs
    rnd = summarize(
        {"r": run_baseline_device(denv, random_policy(denv.K), seed=1)})["r"]
    for name in ("neural_ts", "eps_greedy", "boltzmann"):
        pol, hyp = make_policy(name, denv, cfg)
        res = run_policy_device(denv, pol, hyp, seed=0, **ZOO_KW)
        summ = summarize({name: res})[name]
        assert summ["avg_reward"] > rnd["avg_reward"], name


def test_dyn_min_cost_tracks_effective_costs(envs):
    """The scenario-aware dynamic min-cost baseline re-reads the slice's
    effective cost tables: under cost_drift (frontier inversion) it must
    switch arms mid-run, while the static min-cost arm cannot; under no
    scenario it reproduces the static min-cost trajectory."""
    _, denv = envs
    pol, hyp = make_policy("dyn_min_cost", denv, None)
    stat = run_policy_device(denv, pol, hyp, seed=0)
    fixed = run_baseline_device(
        denv, fixed_policy(denv.min_cost_action(), "min-cost"), seed=0)
    np.testing.assert_array_equal(stat["action_hist"], fixed["action_hist"])
    drift = run_policy_device(denv, pol, hyp, seed=0, scenario="cost_drift")
    hist = np.asarray(drift["action_hist"])
    arms_used = {int(a) for a in hist.argmax(axis=1)}
    assert len(arms_used) >= 2  # switched arms as the frontier inverted
    summ = summarize({"dyn": drift})["dyn"]
    assert np.isfinite(summ["avg_cost"])


def test_policy_sweep_one_dispatch_annotated_schema(envs, cfg):
    """ISSUE acceptance: a ≥4-policy × seed sweep — including LinUCB and
    NeuralTS — runs as ONE jitted dispatch and returns the unified
    grid-annotated (G, n_seeds, T, ...) schema whose cells feed
    summarize() and whose sweeps feed summarize_sweep()."""
    _, denv = envs
    policies = {
        "neuralucb": make_policy("neuralucb", denv, cfg),
        "linucb": make_policy("linucb", denv, cfg),
        "neural_ts": make_policy("neural_ts", denv, cfg),
        "eps_greedy": make_policy("eps_greedy", denv, cfg),
        "greedy": make_policy("greedy", denv, cfg),
    }
    sw = run_policy_sweep(denv, policies, seeds=[0, 1], **ZOO_KW)
    T = denv.n_slices
    assert set(sw) == set(policies)
    for name, d in sw.items():
        assert d["avg_reward"].shape == (1, 2, T), name
        assert d["action_hist"].shape == (1, 2, T, denv.K), name
        assert d["seeds"].tolist() == [0, 1]
        assert np.isfinite(d["avg_reward"]).all(), name
        summ = summarize({name: sweep_point_results(d, 0, 1)})[name]
        assert np.isfinite(summ["avg_reward"]), name
        points = summarize_sweep(d)
        assert len(points) == 1 and np.isfinite(points[0]["avg_reward_mean"])
    # grid annotations carry the hyper fields
    assert "alpha" in sw["linucb"]["grid"]
    assert "beta" in sw["neuralucb"]["grid"]
    # a sweep cell equals the corresponding single-policy run
    single = run_policy_device(denv, *policies["linucb"], seed=1)
    np.testing.assert_allclose(sw["linucb"]["avg_reward"][0, 1],
                               single["avg_reward"], rtol=1e-5, atol=1e-6)


def test_policy_sweep_hyper_grid_axis(envs, cfg):
    """A (G,) hypers grid fans out along the lane axis: LinUCB with two
    alphas over two seeds comes back (2, 2, T) with per-point grid
    annotations, and alpha=0 differs from heavy exploration."""
    _, denv = envs
    pol, _ = make_policy("linucb", denv, None)
    grid = LinUCBHypers(alpha=jnp.asarray([0.0, 4.0], jnp.float32),
                        ridge=jnp.float32(1.0))
    sw = run_policy_sweep(denv, {"linucb": (pol, grid)}, seeds=[0, 1])
    assert sw["linucb"]["avg_reward"].shape == (2, 2, denv.n_slices)
    assert sw["linucb"]["grid"]["alpha"].tolist() == [0.0, 4.0]
    assert not np.allclose(sw["linucb"]["avg_reward"][0],
                           sw["linucb"]["avg_reward"][1])
    points = summarize_sweep(sw["linucb"])
    assert [p["alpha"] for p in points] == [0.0, 4.0]


def test_zoo_composes_with_scenarios(envs, cfg):
    """Scenario transforms thread through every policy automatically:
    LinUCB and NeuralTS under arm_arrival must route zero traffic to the
    masked arm (both are availability-aware) and conserve traffic."""
    _, denv = envs
    scen = make_scenario(denv, "arm_arrival")
    avail = np.asarray(scen.tables.avail)
    arm = int(np.where(avail.min(axis=0) < 1)[0][0])
    masked = np.where(avail[:, arm] == 0)[0]
    for name in ("linucb", "neural_ts"):
        pol, hyp = make_policy(name, denv, cfg)
        res = run_policy_device(denv, pol, hyp, seed=0, scenario=scen,
                                **ZOO_KW)
        hist = np.asarray(res["action_hist"])
        assert hist[masked, arm].sum() == 0, name
        np.testing.assert_allclose(hist.sum(axis=1), denv.slice_sizes)


def test_router_exploration_variants_serve(cfg):
    """The serving-side zoo: every NeuralUCBRouter exploration rule
    decides/updates/trains through the same host interface."""
    rng = np.random.default_rng(0)
    B = 32
    x_emb = rng.standard_normal((B, cfg.emb_dim)).astype(np.float32)
    x_feat = rng.standard_normal((B, cfg.feat_dim)).astype(np.float32)
    domain = rng.integers(0, cfg.num_domains, B).astype(np.int32)
    for rule in ("ucb", "ts", "eps", "boltzmann"):
        r = NeuralUCBRouter(cfg, seed=0, exploration=rule,
                            explore_scale=0.5, batch_size=16)
        for _ in range(2):          # warm slice, then the explore rule
            dec = r.decide(x_emb, x_feat, domain)
            assert dec["action"].shape == (B,)
            assert dec["action"].min() >= 0
            assert dec["action"].max() < cfg.num_actions
            r.update(x_emb, x_feat, domain, dec,
                     rng.random(B).astype(np.float32))
            r.end_slice(epochs=1)
    with pytest.raises(ValueError):
        NeuralUCBRouter(cfg, exploration="nope")
