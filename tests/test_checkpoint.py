"""Checkpoint roundtrip."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.checkpoint import load_checkpoint, save_checkpoint


def test_roundtrip(tmp_path):
    tree = {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                   "b": jnp.zeros((3,), jnp.bfloat16)},
        "opt": {"mu": [jnp.ones((2,)), jnp.full((1,), 7, jnp.int32)],
                "count": jnp.int32(5)},
    }
    path = os.path.join(tmp_path, "ck.npz")
    save_checkpoint(path, tree)
    back = load_checkpoint(path)
    flat1, td1 = jax.tree.flatten(tree)
    flat2, td2 = jax.tree.flatten(back)
    assert td1 == td2
    for a, b in zip(flat1, flat2):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
