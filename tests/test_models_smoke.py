"""Per-architecture smoke tests (deliverable (f)): a REDUCED variant of each
assigned config runs one forward + one train step + decode on CPU, asserting
output shapes and finiteness; decode-vs-forward consistency for every family.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.tree import tree_finite
from repro.configs import ARCH_IDS, get_config
from repro.models import model as MODEL
from repro.models.model import pad_vocab
from repro.training import train_step as TS


def _reduced(arch):
    return dataclasses.replace(get_config(arch).reduced(), dtype="float32")


def _batch(cfg, B=2, S=16, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.arch_type == "audio":
        batch["audio_embed"] = jax.random.normal(
            ks[2], (B, cfg.num_audio_frames, cfg.d_model))
    if cfg.arch_type == "vlm":
        batch["image_embed"] = jax.random.normal(
            ks[2], (B, cfg.num_image_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = _reduced(arch)
    params = MODEL.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = MODEL.forward_train(params, cfg, batch)
    assert logits.shape == (2, 16, pad_vocab(cfg.vocab_size))
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = _reduced(arch)
    state = TS.make_train_state(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    new_state, metrics = TS.train_step(state, batch, cfg=cfg, lr=1e-3)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert tree_finite(new_state["params"])
    assert int(new_state["step"]) == 1
    # params actually moved
    before = jax.tree.leaves(state["params"])[1]
    after = jax.tree.leaves(new_state["params"])[1]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = _reduced(arch)
    params = MODEL.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 10
    batch = _batch(cfg, B=B, S=S)
    logits_full, _ = MODEL.forward_train(params, cfg, batch)
    memory = batch.get("image_embed")
    if cfg.arch_type == "audio":
        memory = MODEL.encode_audio(params, cfg, batch["audio_embed"])
    cache = MODEL.init_cache(cfg, B, 32, memory=memory, params=params)
    errs = []
    toks = batch["tokens"]
    for i in range(S):
        lg, cache = MODEL.decode_step(params, cfg, cache, toks[:, i:i + 1])
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, i]))))
    assert max(errs) < 1e-3, f"decode diverges from forward: {errs}"
    assert int(cache["pos"]) == S


def test_loss_masks_padded_vocab():
    cfg = _reduced("llama3_2_3b")
    vp = pad_vocab(cfg.vocab_size)
    logits = jnp.zeros((1, 4, vp))
    # make padded ids hugely attractive; mask must neutralize them
    logits = logits.at[..., cfg.vocab_size:].set(100.0)
    labels = jnp.zeros((1, 4), jnp.int32)
    loss = MODEL.lm_loss(logits, labels, cfg.vocab_size)
    assert float(loss) < 20.0  # ~log(vocab) not ~100


def test_loss_decreases_over_steps():
    cfg = _reduced("llama3_2_3b")
    state = TS.make_train_state(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, B=4, S=16)
    step = TS.jit_train_step(cfg, lr=3e-3)
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses
