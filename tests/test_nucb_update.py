"""Property suite for the fused rank-k Woodbury update kernel
(`kernels.nucb_update`, DESIGN.md §15.1) — the third leg of Algorithm
1's hot path — plus the `REPRO_KERNEL_BACKEND` backend-override gate.

Parity pins (ISSUE acceptance):

* kernel (interpret mode on CPU) vs ``sherman_morrison_batch``:
  <= 2e-4 end-to-end;
* jnp backend vs ``woodbury_update``: BIT-level in f32 (the ref
  delegates verbatim, and dispatch must actually take that path);
* across block sizes, k=0, k=1, k>d, bf16 features, and all-dead
  (w=0) rows.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep — fall back to the local stub
    from _hypothesis_stub import given, settings, st

from repro.core import neuralucb as NU
from repro.kernels import backend as KB
from repro.kernels.nucb_update import nucb_update, nucb_update_ref

INTERPRET = not KB.on_tpu()
SM_ATOL = 2e-4     # kernel vs the sequential Sherman-Morrison oracle


def _case(seed, n, d, scale=0.3, warm=True):
    """A non-trivial SPD A^-1 (a few updates applied) plus fresh rows."""
    rng = np.random.default_rng(seed)
    ainv = NU.init_ainv(d, 1.0)
    if warm and n:
        ainv = NU.woodbury_update(
            ainv, jnp.asarray(rng.normal(size=(max(1, n // 2), d))
                              .astype(np.float32) * scale))
    gs = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32) * scale)
    return ainv, gs


@settings(deadline=None, max_examples=25)
@given(n=st.sampled_from([0, 1, 5, 64, 200, 300]),
       d=st.sampled_from([3, 9, 64, 130]),
       block_k=st.sampled_from([32, 128, 256]))
def test_nucb_update_matches_sherman_morrison(n, d, block_k):
    """k=0 / k=1 / k>d / multi-block all land within SM_ATOL of the
    n-sequential-rank-1 oracle (the paper's exact recurrence)."""
    ainv, gs = _case(0, n, d)
    ref = NU.sherman_morrison_batch(ainv, gs)
    got = nucb_update(ainv, gs, block_k=block_k, interpret=INTERPRET)
    assert got.shape == (d, d) and got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=SM_ATOL, rtol=0)


@settings(deadline=None, max_examples=10)
@given(n=st.sampled_from([1, 37, 260]), d=st.sampled_from([9, 130]))
def test_nucb_update_jnp_backend_bit_level(n, d, monkey=None):
    """The jnp backend IS ``woodbury_update`` — bit-identical in f32."""
    ainv, gs = _case(1, n, d)
    want = NU.woodbury_update(ainv, gs)
    got = nucb_update(ainv, gs) if not KB.on_tpu() else nucb_update_ref(
        ainv, gs)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_nucb_update_all_dead_rows_is_identity():
    """w=0 rows are exact no-ops: an all-masked batch leaves A^-1
    BIT-unchanged through the kernel (zero rows -> identity S)."""
    ainv, gs = _case(2, 64, 9)
    dead = gs * jnp.zeros((64, 1))
    got = nucb_update(ainv, dead, interpret=INTERPRET)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ainv),
                               atol=1e-6, rtol=0)
    # and mixed: dead rows contribute nothing next to live ones
    mask = jnp.asarray((np.arange(64) % 3 == 0).astype(np.float32))
    got_mixed = nucb_update(ainv, gs * mask[:, None], interpret=INTERPRET)
    ref_mixed = NU.sherman_morrison_batch(ainv, gs * mask[:, None])
    np.testing.assert_allclose(np.asarray(got_mixed), np.asarray(ref_mixed),
                               atol=SM_ATOL, rtol=0)


def test_nucb_update_bf16_features():
    """bf16 feature rows are accepted and cast at the kernel boundary;
    A^-1 stays f32 statistics state on every path."""
    ainv, gs = _case(3, 100, 30)
    gs16 = gs.astype(jnp.bfloat16)
    got = nucb_update(ainv, gs16, interpret=INTERPRET)
    assert got.dtype == jnp.float32
    ref = NU.sherman_morrison_batch(ainv, gs16.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=SM_ATOL, rtol=0)


def test_woodbury_update_blocked_matches_single_block():
    """The fori_loop multi-block path (padded tail included) matches the
    one-shot Woodbury solve and the sequential oracle."""
    ainv, gs = _case(4, 200, 9)
    one = NU._woodbury_block(ainv, gs)
    multi = NU.woodbury_update(ainv, gs, block_size=64)   # 200 = 3*64 + 8
    seq = NU.sherman_morrison_batch(ainv, gs)
    np.testing.assert_allclose(np.asarray(multi), np.asarray(one),
                               atol=1e-5, rtol=0)
    np.testing.assert_allclose(np.asarray(multi), np.asarray(seq),
                               atol=SM_ATOL, rtol=0)
    # k=0 is the identity
    assert np.array_equal(np.asarray(NU.woodbury_update(ainv, gs[:0])),
                          np.asarray(ainv))


# ------------------------------------------------ backend env override --
def test_backend_env_override(monkeypatch):
    """REPRO_KERNEL_BACKEND forces the interpret=None auto-detection;
    explicit interpret=True/False still wins; unknown values raise."""
    for val, want in (("jnp", KB.REF), ("pallas", KB.PALLAS),
                      ("interpret", KB.INTERPRET), ("  PALLAS ", KB.PALLAS)):
        monkeypatch.setenv("REPRO_KERNEL_BACKEND", val)
        assert KB.resolve_backend(None) == want, val
        assert KB.resolve_backend(True) == KB.INTERPRET
        assert KB.resolve_backend(False) == KB.PALLAS
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "cuda")
    with pytest.raises(ValueError, match="REPRO_KERNEL_BACKEND"):
        KB.resolve_backend(None)
    monkeypatch.delenv("REPRO_KERNEL_BACKEND")
    assert KB.resolve_backend(None) == (KB.PALLAS if KB.on_tpu() else KB.REF)


def test_backend_env_override_reaches_dispatch(monkeypatch):
    """The override steers a real op: forcing ``jnp`` on the update op
    must produce the bit-level woodbury result even if the process would
    otherwise pick a different default."""
    ainv, gs = _case(5, 40, 9)
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "jnp")
    got = nucb_update(ainv, gs)
    assert np.array_equal(np.asarray(got),
                          np.asarray(NU.woodbury_update(ainv, gs)))
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    got_i = nucb_update(ainv, gs)
    np.testing.assert_allclose(
        np.asarray(got_i), np.asarray(NU.sherman_morrison_batch(ainv, gs)),
        atol=SM_ATOL, rtol=0)
