"""Phased learning lifecycle tests (DESIGN.md §13): the
LoggedInteractions interchange format, the zoo's propensity semantics,
offline pretraining + warm starts (spec compilation, checkpoint cache,
PRNG invariance), the IPS/SNIPS/DM/DR estimators (unbiasedness on a
synthetic bandit with known propensities, DR parity against on-policy
replay), and the ``offline_online`` / ``ope_selection`` presets end to
end."""
import json
import math
import os

import numpy as np
import pytest

from repro.core.protocol import estimate_offline
from repro.core.utilitynet import UtilityNetConfig
from repro.data.logged import (
    LOGGED_SCHEMA_VERSION,
    LoggedInteractions,
    from_run_log,
    replay_corpus,
)
from repro.data.routerbench import RouterBenchSim
from repro.experiments import (
    ExperimentSpec,
    OPESpec,
    PolicySpec,
    PretrainSpec,
    apply_overrides,
    compile_spec,
    make_preset,
    pretrained_states,
    run_plan,
    spec_from_json,
    spec_to_json,
)
from repro.sim import (
    DeviceReplayEnv,
    make_policy,
    pretrain_policy_state,
    run_policy_device,
)

TINY = {"data.n_samples": 600, "data.n_slices": 3,
        "train.train_steps": 8, "train.batch_size": 32}


@pytest.fixture(scope="module")
def envs():
    henv = RouterBenchSim(seed=0, n_samples=600, n_slices=3)
    return henv, DeviceReplayEnv.from_host(henv)


@pytest.fixture(scope="module")
def cfg(envs):
    henv, _ = envs
    return UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)


# ----------------------------------------------------- logged data format --
def test_replay_corpus_exact_uniform_propensities(envs, tmp_path):
    _, env = envs
    corpus = replay_corpus(env, 500, seed=3)
    K = corpus.num_actions
    assert corpus.n == 500 and corpus.has_propensities
    np.testing.assert_allclose(corpus.logp, -math.log(K), rtol=1e-6)
    assert corpus.slice_idx.min() >= 0 and corpus.slice_idx.max() < 3
    # realized rewards read off the env's reward table at (row, arm)
    reward = np.asarray(env.reward)
    np.testing.assert_allclose(
        corpus.reward, reward[corpus.sample_idx, corpus.action], rtol=1e-6)

    path = os.path.join(tmp_path, "corpus.npz")
    corpus.save(path)
    back = LoggedInteractions.load(path)
    assert back.behavior == corpus.behavior
    assert back.num_actions == K
    np.testing.assert_array_equal(back.action, corpus.action)
    np.testing.assert_allclose(back.logp, corpus.logp)
    np.testing.assert_allclose(back.x_emb, corpus.x_emb)


def test_record_log_round_trips_through_sim_scan(envs, cfg):
    _, env = envs
    pol, hyp = make_policy("eps_greedy", env, cfg)
    _, logged = run_policy_device(env, pol, hyp, seed=0, record_log=True)
    # one row per VALID replay sample, propensities are log-probs
    assert logged.n == int((np.asarray(env.mask) > 0).sum())
    assert logged.behavior == pol.name
    assert logged.has_propensities
    assert logged.logp.max() <= 1e-6
    assert np.isfinite(logged.logp).all()
    # recording must not perturb the run itself (zero extra PRNG use)
    res_plain = run_policy_device(env, pol, hyp, seed=0)
    res_rec, _ = run_policy_device(env, pol, hyp, seed=0, record_log=True)
    np.testing.assert_allclose(res_plain["avg_reward"],
                               res_rec["avg_reward"], rtol=1e-6)


def test_logged_validation_errors():
    x = np.zeros((4, 3), np.float32)
    ok = dict(x_emb=x, x_feat=np.zeros((4, 2)), domain=np.zeros(4),
              action=np.zeros(4), reward=np.zeros(4), logp=None,
              slice_idx=np.zeros(4), num_actions=2)
    LoggedInteractions(**ok)
    with pytest.raises(ValueError, match="reward"):
        LoggedInteractions(**{**ok, "reward": np.zeros(3)})
    with pytest.raises(ValueError, match="actions outside"):
        LoggedInteractions(**{**ok, "action": np.full(4, 7)})
    with pytest.raises(ValueError, match="log-probabilities"):
        LoggedInteractions(**{**ok, "logp": np.full(4, 0.5)})


# -------------------------------------------------------- OPE estimators --
def _synthetic_log(n=40_000, seed=0):
    """Context-free bandit with KNOWN behavior propensities: arm means
    mu, behavior dist p — the ground truth any estimator must recover."""
    rng = np.random.default_rng(seed)
    mu = np.array([0.2, 0.5, 0.7, 0.4])
    p = np.array([0.4, 0.3, 0.2, 0.1])
    a = rng.choice(4, size=n, p=p)
    r = mu[a] + rng.uniform(-0.1, 0.1, size=n)
    logged = LoggedInteractions(
        x_emb=rng.normal(size=(n, 8)).astype(np.float32),
        x_feat=np.zeros((n, 2), np.float32), domain=np.zeros(n),
        action=a, reward=r, logp=np.log(p[a]).astype(np.float32),
        slice_idx=np.zeros(n), num_actions=4, behavior="synthetic")
    return logged, mu


def test_ips_snips_dr_unbiased_on_known_bandit():
    logged, mu = _synthetic_log()
    q = np.array([0.1, 0.2, 0.3, 0.4])
    truth = float(q @ mu)
    probs = np.broadcast_to(q, (logged.n, 4))
    qhat = np.broadcast_to(mu, (logged.n, 4))
    est = estimate_offline(logged, probs, qhat=qhat)
    assert abs(est["ips"] - truth) < 0.02
    assert abs(est["snips"] - truth) < 0.02
    assert abs(est["dm"] - truth) < 1e-6       # exact model -> exact DM
    assert abs(est["dr"] - truth) < 0.02
    assert est["n"] == logged.n and est["ess"] > 0
    # identity target (target == behavior): weights are ~1 and every
    # estimator collapses to the log's own mean reward
    own_probs = np.broadcast_to(np.array([0.4, 0.3, 0.2, 0.1]),
                                (logged.n, 4))
    own = estimate_offline(logged, own_probs)
    assert abs(own["snips"] - logged.reward.mean()) < 0.02
    assert abs(own["mean_w"] - 1.0) < 0.02


def test_estimate_offline_clip_bounds_weights():
    logged, mu = _synthetic_log(n=5000, seed=1)
    probs = np.broadcast_to(np.array([0.0, 0.0, 0.0, 1.0]), (logged.n, 4))
    raw = estimate_offline(logged, probs)
    clipped = estimate_offline(logged, probs, clip=1.0)
    # point mass on the rarest arm: w = 1/0.1 on ~10% of rows (E[w]=1);
    # clipping caps those at 1 -> mean weight collapses to ~P(a=3)
    assert abs(raw["mean_w"] - 1.0) < 0.1
    assert clipped["mean_w"] < 0.2
    assert clipped["ips"] < raw["ips"]          # downward clip bias
    assert clipped["ess"] > raw["ess"]          # variance bought with it


def test_estimate_offline_fails_loudly_without_propensities():
    logged, _ = _synthetic_log(n=100)
    logged.logp = None
    logged.behavior = "mystery-run"
    probs = np.full((100, 4), 0.25)
    with pytest.raises(ValueError, match="mystery-run"):
        estimate_offline(logged, probs)


def test_estimate_offline_shape_errors():
    logged, _ = _synthetic_log(n=100)
    with pytest.raises(ValueError):
        estimate_offline(logged, np.full((50, 4), 0.25))
    with pytest.raises(ValueError):
        estimate_offline(logged, np.full((100, 4), 0.25),
                         qhat=np.zeros((100, 3)))


# --------------------------------------------------- offline pretraining --
def test_pretrain_changes_state_and_beats_random(envs, cfg):
    _, env = envs
    corpus = replay_corpus(env, 2000, seed=0)
    pol, hyp = make_policy("sup_winrate", env, cfg)
    state = pretrain_policy_state(env, pol, hyp, corpus, seed=0)
    assert float(np.abs(np.asarray(state["b"])).sum()) > 0  # ridge folded
    res = run_policy_device(env, pol, hyp, seed=0, init_state=state)
    rnd, rh = make_policy("random", env, cfg)
    res_rnd = run_policy_device(env, rnd, rh, seed=0)
    assert (np.mean(res["avg_reward"])
            > np.mean(res_rnd["avg_reward"]) + 0.1)


def test_injected_init_state_preserves_prng_stream(envs, cfg):
    """Injecting a policy's own cold init state must be bit-identical
    to not injecting at all — the warm/cold comparison isolates state,
    never the PRNG stream."""
    _, env = envs
    corpus = replay_corpus(env, 200, seed=0)
    pol, hyp = make_policy("greedy", env, cfg)   # pretrain hook is a no-op
    state = pretrain_policy_state(env, pol, hyp, corpus, seed=0)
    res_inj = run_policy_device(env, pol, hyp, seed=0, init_state=state)
    res_plain = run_policy_device(env, pol, hyp, seed=0)
    np.testing.assert_array_equal(res_inj["avg_reward"],
                                  res_plain["avg_reward"])


def test_pretrain_requires_corpus(envs, cfg):
    _, env = envs
    pol, hyp = make_policy("linucb", env, cfg)
    with pytest.raises(ValueError, match="corpus"):
        pretrain_policy_state(env, pol, hyp, None)


# ------------------------------------------------------------ spec codec --
def test_pretrain_ope_specs_round_trip():
    spec = ExperimentSpec(
        name="lc", policies=(PolicySpec("neuralucb"),
                             PolicySpec("min_cost")),
        pretrain=PretrainSpec(corpus_size=1000, steps=64,
                              warm_start=(True, False)),
        ope=OPESpec(targets=("min_cost", "random"), parity=("min_cost",)))
    doc = json.loads(json.dumps(spec_to_json(spec)))
    assert spec_from_json(doc) == spec
    doc["pretrain"]["bogus"] = 1
    with pytest.raises(ValueError, match="unknown keys"):
        spec_from_json(doc)


def test_pre_lifecycle_specs_emit_no_lifecycle_keys():
    """Specs without pretrain/ope serialize exactly as before the
    lifecycle existed — their hashes are stable across the PR."""
    doc = spec_to_json(make_preset("paper_table1"))
    assert "pretrain" not in doc and "ope" not in doc


def test_lifecycle_spec_validation():
    with pytest.raises(ValueError):
        PretrainSpec(corpus_size=0)
    with pytest.raises(ValueError):
        PretrainSpec(warm_start=())
    with pytest.raises(ValueError):
        OPESpec(targets=())
    with pytest.raises(ValueError):   # parity must be a subset of targets
        OPESpec(targets=("random",), parity=("min_cost",))


def test_policies_filter_override():
    spec = make_preset("offline_online",
                       {"policies": ["neuralucb", "random"]})
    assert [p.label for p in spec.policies] == ["neuralucb", "random"]
    with pytest.raises(KeyError, match="no policy entry"):
        make_preset("offline_online", {"policies": ["nope"]})


# -------------------------------------------------------------- compiler --
def test_compiler_expands_warm_cold_axis(envs, cfg):
    henv, denv = envs
    spec = ExperimentSpec(
        name="wc", policies=(PolicySpec("neuralucb"),
                             PolicySpec("sup_winrate"),
                             PolicySpec("random")),
        pretrain=PretrainSpec(corpus_size=500, steps=8,
                              warm_start=(True, False)))
    plan = compile_spec(spec, env=denv, host_env=henv)
    call = plan.calls[0]
    assert set(call.policies) == {"neuralucb:warm", "neuralucb:cold",
                                  "sup_winrate:warm", "sup_winrate:cold",
                                  "random"}
    assert plan.pretrain_labels == {
        "neuralucb:warm": True, "neuralucb:cold": False,
        "sup_winrate:warm": True, "sup_winrate:cold": False}
    assert call.grids["neuralucb:warm"][0]["warm_start"] is True
    assert call.grids["neuralucb:cold"][0]["warm_start"] is False
    assert "warm_start" not in call.grids["random"][0]

    # a single warm_start value keeps the plain label
    spec1 = ExperimentSpec(
        name="w1", policies=(PolicySpec("linucb"),),
        pretrain=PretrainSpec(corpus_size=500, warm_start=(True,)))
    plan1 = compile_spec(spec1, env=denv, host_env=henv)
    assert plan1.pretrain_labels == {"linucb": True}


def test_compiler_validates_lifecycle_names(envs):
    henv, denv = envs
    bad_bh = ExperimentSpec(
        name="b", policies=(PolicySpec("random"),),
        pretrain=PretrainSpec(behavior="not_a_policy"))
    with pytest.raises(ValueError, match="not_a_policy"):
        compile_spec(bad_bh, env=denv, host_env=henv)
    bad_tgt = ExperimentSpec(
        name="b", policies=(PolicySpec("random"),),
        ope=OPESpec(targets=("no_such_target",)))
    with pytest.raises(ValueError, match="no_such_target"):
        compile_spec(bad_tgt, env=denv, host_env=henv)


def test_pretrain_checkpoint_cache_hits(envs, monkeypatch, tmp_path):
    henv, denv = envs
    monkeypatch.setenv("REPRO_PRETRAIN_CACHE", str(tmp_path))
    spec = ExperimentSpec(
        name="cache", policies=(PolicySpec("sup_winrate"),),
        pretrain=PretrainSpec(corpus_size=500, steps=8,
                              warm_start=(True,)))
    plan = compile_spec(spec, env=denv, host_env=henv)
    _, states1, info1 = pretrained_states(plan)
    assert info1["sup_winrate"]["cache_hit"] is False
    assert os.path.exists(info1["sup_winrate"]["path"])
    _, states2, info2 = pretrained_states(plan)
    assert info2["sup_winrate"]["cache_hit"] is True
    np.testing.assert_allclose(np.asarray(states1["sup_winrate"]["b"]),
                               np.asarray(states2["sup_winrate"]["b"]),
                               rtol=1e-6)


# ------------------------------------------------------------ end to end --
def test_offline_online_preset_end_to_end(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_PRETRAIN_CACHE", str(tmp_path))
    spec = make_preset("offline_online", {
        **TINY, "policies": ["sup_winrate", "linucb", "random"],
        "pretrain.corpus_size": 1500, "pretrain.steps": 32,
        "seeds": [0]})
    res = run_plan(compile_spec(spec))
    assert res.ok
    pols = {c["policy"] for c in res.cells}
    assert {"sup_winrate:warm", "sup_winrate:cold", "linucb:warm",
            "linucb:cold", "random"} <= pols
    # the warm supervised router must beat its cold (= untrained) self
    warm = res.cell("sup_winrate:warm", warm_start=True)
    cold = res.cell("sup_winrate:cold", warm_start=False)
    assert warm["avg_reward_mean"] > cold["avg_reward_mean"]
    assert res.manifest["pretrain"]["corpus_size"] == 1500
    assert set(res.manifest["pretrain"]["labels"]) == {
        "sup_winrate:warm", "linucb:warm"}


def test_ope_selection_preset_end_to_end():
    spec = make_preset("ope_selection", TINY)
    res = run_plan(compile_spec(spec))
    assert res.ok
    offline = res.cells_for("offline")
    assert {c["policy"] for c in offline} == {"min_cost", "greedy",
                                              "sup_winrate", "random"}
    for c in offline:
        for k in ("ips", "snips", "dm", "dr", "ess"):
            assert np.isfinite(c["ope"][k])
    pinned = res.cell("min_cost", scenario="offline")
    assert pinned["ope_ok"] and np.isfinite(pinned["onpolicy_value"])
    # random's uniform target is the easy sanity anchor: its estimate
    # must sit near the behavior env's uniform value, far below min_cost
    rnd = res.cell("random", scenario="offline")
    assert rnd["ope"]["snips"] < pinned["ope"]["snips"]
    assert res.manifest["ope"]["parity_ok"]


def test_ope_and_serving_cannot_share_a_spec():
    spec = make_preset("serving_storm")
    with pytest.raises(ValueError, match="serving"):
        ExperimentSpec(
            name="bad", policies=spec.policies, serving=spec.serving,
            ope=OPESpec(targets=("random",)))


# ----------------------------------------------------------- serving log --
def test_serving_router_log_round_trip(envs, cfg):
    from repro.serving.policy_router import DevicePolicyRouter
    from repro.sim.engine import _tables

    henv, env = envs
    pol, hyp = make_policy("eps_greedy", env, cfg)
    router = DevicePolicyRouter(pol, hyp, _tables(env), seed=0,
                                slice_width=32, capacity_slices=8,
                                batch_size=16, train_chunks=1,
                                log_capacity=64)
    reward = np.asarray(env.reward)
    for start in (0, 32, 64):
        ids = np.arange(start, start + 32)
        d = router.decide(sample_idx=ids)
        assert d["logp"].shape == (32,) and d["logp"].max() <= 1e-6
        router.update_wave(d, d["action"], reward[ids, d["action"]])
    logged = router.to_logged()
    assert logged.behavior == f"serving:{pol.name}"
    assert logged.has_propensities and logged.n == 64  # capacity-trimmed
    np.testing.assert_allclose(
        logged.reward, reward[logged.sample_idx, logged.action], rtol=1e-6)
    # a log-disabled router refuses loudly
    router_off = DevicePolicyRouter(pol, hyp, _tables(env), seed=0,
                                    slice_width=32, capacity_slices=8,
                                    batch_size=16, train_chunks=1)
    with pytest.raises(ValueError, match="log_capacity"):
        router_off.to_logged()


def test_serving_router_accepts_pretrained_state(envs, cfg):
    from repro.serving.policy_router import DevicePolicyRouter
    from repro.sim.engine import _tables

    _, env = envs
    corpus = replay_corpus(env, 1500, seed=0)
    pol, hyp = make_policy("sup_winrate", env, cfg)
    state = pretrain_policy_state(env, pol, hyp, corpus, seed=0)
    router = DevicePolicyRouter(pol, hyp, _tables(env), seed=0,
                                slice_width=32, capacity_slices=4,
                                batch_size=16, train_chunks=1,
                                pretrained_state=state)
    cold = DevicePolicyRouter(pol, hyp, _tables(env), seed=0,
                              slice_width=32, capacity_slices=4,
                              batch_size=16, train_chunks=1)
    ids = np.arange(32)
    reward = np.asarray(env.reward)
    r_warm = reward[ids, router.decide(sample_idx=ids)["action"]].mean()
    r_cold = reward[ids, cold.decide(sample_idx=ids)["action"]].mean()
    assert r_warm > r_cold   # pretrained scores route better than zeros
