"""Equivalence tests for the §Perf optimization paths: every optimized
code path must match its reference implementation exactly (the hillclimb
protocol keeps the speedup only if correctness holds)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models import model as MODEL
from repro.training import train_step as TS


def _cfg(arch="llama3_2_3b", **kw):
    return dataclasses.replace(get_config(arch).reduced(), dtype="float32",
                               **kw)


# --- iteration 2/4: attention path equivalences ---------------------------


@pytest.mark.parametrize("S,w", [(256, 64), (300, 64), (128, 64)])
def test_local_banded_equals_masked_full(S, w):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, H, KV, D = 2, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    pos = jnp.arange(S)
    ref = L._sdpa_folded(q, k, v, L._attn_mask(pos, pos, True, w))
    out = L._sdpa_local(q, k, v, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_chunked_equals_folded_with_window():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, KV, D = 1, 384, 8, 4, 32
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    pos = jnp.arange(S)
    ref = L._sdpa_folded(q, k, v, L._attn_mask(pos, pos, True, 128))
    out = L._sdpa_chunked(q, k, v, pos, pos, True, 128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_gemma_superblock_path_selected_and_consistent():
    """At S >= 2*window the gemma forward takes the static super-block path;
    it must agree with step-by-step decode (which uses the generic path)."""
    cfg = _cfg("gemma3_4b", num_layers=4, local_global_ratio=1,
               sliding_window=16)
    params = MODEL.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, 48  # >= 2*16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full, _ = MODEL.forward_train(params, cfg, {"tokens": toks})
    cache = MODEL.init_cache(cfg, B, 64)
    errs = []
    for i in range(S):
        lg, cache = MODEL.decode_step(params, cfg, cache, toks[:, i:i + 1])
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, i]))))
    assert max(errs) < 1e-3, max(errs)


# --- iteration 3: gradient accumulation -----------------------------------


def test_grad_accum_matches_full_batch():
    cfg = _cfg()
    state = TS.make_train_state(jax.random.PRNGKey(0), cfg)
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    B, S = 8, 16
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    batch["labels"] = batch["tokens"]
    s1, m1 = TS.train_step(state, batch, cfg=cfg, lr=1e-3, accum_steps=1)
    s4, m4 = TS.train_step(state, batch, cfg=cfg, lr=1e-3, accum_steps=4)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_default_accum_steps_heuristic():
    cfg = get_config("mistral-large-123b")
    m = TS.default_accum_steps(cfg, 256, 4096, data_shards=16)
    assert m == 16  # 141 GB residual stream -> capped at b_local
    cfg2 = get_config("mamba2-130m")
    assert TS.default_accum_steps(cfg2, 256, 4096, data_shards=16) == 1


# --- iteration 5: chunked cross-entropy ------------------------------------


@pytest.mark.parametrize("S,chunk", [(40, 16), (33, 8), (16, 32)])
def test_chunked_loss_equals_reference(S, chunk):
    cfg = _cfg()
    params = MODEL.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    logits, aux = MODEL.forward_train(params, cfg, batch)
    ref = MODEL.lm_loss(logits, toks, cfg.vocab_size, aux)
    hidden, aux2 = MODEL.forward_hidden(params, cfg, batch)
    out = MODEL.lm_loss_chunked(hidden, MODEL.unembed_matrix(params), toks,
                                cfg.vocab_size, aux2, chunk=chunk)
    assert abs(float(ref) - float(out)) < 1e-4


def test_chunked_loss_gradients_match():
    cfg = _cfg()
    params = MODEL.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 24), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    def loss_ref(p):
        logits, aux = MODEL.forward_train(p, cfg, batch)
        return MODEL.lm_loss(logits, toks, cfg.vocab_size, aux)

    def loss_chunked(p):
        hidden, aux = MODEL.forward_hidden(p, cfg, batch)
        return MODEL.lm_loss_chunked(hidden, MODEL.unembed_matrix(p), toks,
                                     cfg.vocab_size, aux, chunk=8)

    g1 = jax.grad(loss_ref)(params)
    g2 = jax.grad(loss_chunked)(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4,
                                   rtol=2e-3)


# --- iteration 1: sort-based MoE under jit/grad -----------------------------


def test_moe_sort_dispatch_differentiable():
    from repro.models.moe import init_moe, moe_ffn
    from repro.common.config import ModelConfig

    cfg = ModelConfig(name="t", arch_type="moe", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
                      num_experts=4, experts_per_token=2,
                      moe_capacity_factor=8.0, dtype="float32")
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))

    def f(p):
        out, aux = moe_ffn(p, cfg, x)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(f)(params)
    assert all(bool(jnp.all(jnp.isfinite(t))) for t in jax.tree.leaves(g))
    # router must receive gradient signal (through the gate weights)
    assert float(jnp.abs(g["router"]).max()) > 0
