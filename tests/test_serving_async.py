"""Async serving engine correctness (ISSUE tentpole): microbatched
continuous batching, admission control, and the two parity pins —

* `DevicePolicyRouter` driven one wave per slice reproduces
  `run_policy_device` BIT-EXACTLY (same PRNG discipline, same jitted
  policy callbacks, state device-resident throughout), and
* the microbatched async engine over the host `NeuralUCBRouter`
  reproduces the synchronous `RoutedServingPool` decision-for-decision
  on the same request stream.

Plus snapshot/restore round-trips: serve N, snapshot, kill, restore,
serve N more — identical to the uninterrupted run."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import NeuralUCBRouter
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim
from repro.serving import (
    AsyncRouterEngine,
    DevicePolicyRouter,
    Request,
    RoutedServingPool,
    ServingEngine,
)
from repro.sim import DeviceReplayEnv, make_policy, run_policy_device
from repro.sim.engine import _tables
from serving_fakes import FakeRouter

TOK = np.arange(1, 5, dtype=np.int32)


def _replay_env(K=2, n=48, T=3):
    """Tiny custom replay stream (same recipe as the PR-3 pool-parity
    test): deterministic tables, T slices of n/T samples."""
    rng = np.random.default_rng(0)
    plen = rng.integers(4, 9, size=n)
    cpt = np.array([2e-4, 1e-5])
    data = {
        "domain": rng.integers(0, 3, size=n).astype(np.int32),
        "topic": rng.normal(size=(n, 32)).astype(np.float32),
        "difficulty": np.zeros(n, np.float32),
        "prompt_tokens": plen.astype(np.float32),
        "quality": rng.uniform(0.2, 0.95, size=(n, K)).astype(np.float32),
        "cost": (cpt[None] * (plen[:, None] + 8)).astype(np.float32),
        "x_feat": rng.normal(size=(n, 4)).astype(np.float32),
        "model_names": np.array(["a", "b"]),
    }
    henv = RouterBenchSim(seed=0, n_slices=T, cost_lambda=1.0, data=data)
    return henv, DeviceReplayEnv.from_host(henv)


# ----------------------------------------------------- engine mechanics --
def _fake_engine(**kw):
    rng = np.random.default_rng(0)
    rw = rng.uniform(0.1, 0.9, (100, 3)).astype(np.float32)
    kw.setdefault("decide_batch", 32)
    return rw, AsyncRouterEngine(FakeRouter(3), 3, reward_table=rw, **kw)


def test_engine_microbatches_greedily():
    rw, eng = _fake_engine()
    reqs = [Request(tokens=TOK, sample_idx=i % 100) for i in range(100)]
    sample_of = {r.rid: r.sample_idx for r in reqs}
    eng.submit(reqs)
    recs = eng.pump() + eng.drain()
    assert eng.counters["decide_calls"] == 4     # 32 + 32 + 32 + 4
    assert eng.counters["completed"] == 100
    ok = [r for r in recs if r["status"] == "ok"]
    assert len(ok) == 100
    for r in ok:        # table feedback wired through exactly
        assert r["reward"] == pytest.approx(
            rw[sample_of[r["rid"]], r["action"]])
    assert eng.check_accounting()["lost"] == 0


def test_decide_flush_holds_partial_microbatches():
    """Admission control: with ``decide_flush`` set, an undersized
    microbatch waits for its window instead of dispatching a tiny decide
    per pump; ``force``/drain still flushes immediately."""
    now = [0.0]
    _, eng = _fake_engine(decide_flush=1.0, clock=lambda: now[0])
    eng.submit([Request(tokens=TOK, sample_idx=i) for i in range(5)])
    eng.pump()
    assert eng.counters["decide_calls"] == 0 and eng.in_flight == 5
    now[0] = 0.5
    eng.pump()
    assert eng.counters["decide_calls"] == 0     # still inside the window
    now[0] = 1.25
    recs = eng.pump()
    assert eng.counters["decide_calls"] == 1
    assert sum(1 for r in recs if r["status"] == "ok") == 5
    # a full microbatch never waits on the window
    eng.submit([Request(tokens=TOK, sample_idx=i % 100) for i in range(32)])
    eng.pump()
    assert eng.counters["decide_calls"] == 2
    assert eng.check_accounting()["lost"] == 0


# ---------------------------------------------------- sim bit-parity --
def test_device_router_bit_parity_with_sim_scan():
    """One serving wave per slice through `DevicePolicyRouter` ==
    `run_policy_device`: identical per-slice action histograms and
    BIT-IDENTICAL final state (params, optimizer, A^-1, PRNG key). This
    pins the serving adapter to the paper engine — a drifted key split
    or a reordered Woodbury update fails loudly here."""
    henv, env = _replay_env()
    T, S = henv.n_slices, 16
    cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1], num_actions=env.K)
    pol, hyp = make_policy("neuralucb", env, cfg)
    res, state, key = run_policy_device(
        env, pol, hyp, seed=0, train_steps=32, batch_size=16,
        return_state=True)

    router = DevicePolicyRouter(pol, hyp, _tables(env), seed=0,
                                slice_width=S, capacity_slices=T,
                                batch_size=16, train_chunks=1)
    router.warmup()    # must not perturb state or the PRNG stream
    reward = np.asarray(env.reward)
    for t in range(T):
        ids = henv.slice_batch(t)["idx"]
        dec = router.decide(sample_idx=ids)
        np.testing.assert_array_equal(
            np.bincount(dec["action"], minlength=env.K),
            res["action_hist"][t], err_msg=f"slice {t} actions")
        router.update_wave(dec, dec["action"], reward[ids, dec["action"]])
        router.end_slice()

    np.testing.assert_array_equal(np.asarray(router._key),
                                  np.asarray(key), err_msg="PRNG key")
    ref = jax.tree_util.tree_leaves(state)
    got = jax.tree_util.tree_leaves(router.state)
    assert len(ref) == len(got)
    for i, (a, b) in enumerate(zip(ref, got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"state leaf {i}")


def test_async_train_overlap_staleness_bounded():
    """Zero-sync train overlap (``max_train_lag > 0``): decide never
    reads state more than ``max_train_lag`` train epochs stale, the
    overlap really defers commits (staleness > 0 is observed), the run
    routes every wave, and `state_dict` is a flush barrier — staleness
    drops to 0 and a restored router resumes synchronously clean."""
    lag = 2
    henv, env = _replay_env(n=96, T=6)
    cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1], num_actions=env.K)
    pol, hyp = make_policy("neuralucb", env, cfg)
    reward = np.asarray(env.reward)

    def build():
        return DevicePolicyRouter(pol, hyp, _tables(env), seed=0,
                                  slice_width=16, capacity_slices=6,
                                  batch_size=16, train_chunks=1,
                                  max_train_lag=lag)

    router = build()
    seen = []
    for t in range(6):
        ids = henv.slice_batch(t)["idx"]
        dec = router.decide(sample_idx=ids)
        assert router.decide_staleness <= lag
        assert dec["action"].shape == ids.shape
        router.update_wave(dec, dec["action"], reward[ids, dec["action"]])
        router.end_slice()
        # dispatch happened, commit deferred to a later decide/flush
        seen.append(router.decide_staleness)
        assert router.decide_staleness <= lag
    assert max(seen) >= 1, "overlap never deferred a commit"
    sd = router.state_dict()                 # flush barrier
    assert router.decide_staleness == 0
    restored = build()
    restored.load_state_dict(sd)
    assert restored.decide_staleness == 0
    ids = henv.slice_batch(0)["idx"]
    dec = restored.decide(sample_idx=ids)    # restored router still serves
    assert dec["action"].shape == ids.shape


# ----------------------------------------------------- pool parity --
def test_async_engine_matches_sync_pool_decisions():
    """The microbatched async engine over the host router reproduces the
    synchronous `RoutedServingPool` decision-for-decision: same request
    stream, same seeds, decide_batch == wave size (so both consume the
    router's numpy PRNG stream in identical draws)."""
    K, n, waves, per = 2, 64, 3, 16
    rng = np.random.default_rng(0)
    qt = rng.uniform(0.3, 0.9, (n, K)).astype(np.float32)
    cpt = [1e-4, 1e-6]
    ucfg = UtilityNetConfig(emb_dim=16, num_actions=K, num_domains=3)

    cfgs = [dataclasses.replace(get_config(a).reduced(), dtype="float32")
            for a in ("llama3_2_3b", "mamba2_130m")]
    engines = [ServingEngine(c, seed=i, max_seq=32)
               for i, c in enumerate(cfgs)]
    pool = RoutedServingPool(NeuralUCBRouter(ucfg, seed=0, batch_size=16),
                             engines, cpt, quality_table=qt, c_max=0.05,
                             max_batch=8)
    eng = AsyncRouterEngine(NeuralUCBRouter(ucfg, seed=0, batch_size=16),
                            K, cost_per_token=cpt, quality_table=qt,
                            c_max=0.05, decide_batch=per, serve_batch=8,
                            max_new=8)

    feat_rng = np.random.default_rng(1)
    for w in range(waves):
        feats = [(feat_rng.normal(size=16).astype(np.float32),
                  feat_rng.normal(size=4).astype(np.float32),
                  int(feat_rng.integers(0, 3)),
                  int(feat_rng.integers(0, n)),
                  feat_rng.integers(1, 50, size=5))
                 for _ in range(per)]
        mk = lambda: [Request(tokens=t, x_emb=e, x_feat=f, domain=d,  # noqa: E731
                              sample_idx=s) for e, f, d, s, t in feats]
        pool_recs = pool.submit(mk())
        eng.submit(mk())
        async_recs = [r for r in eng.pump() + eng.drain()
                      if r["status"] == "ok"]
        assert len(async_recs) == per
        np.testing.assert_array_equal(
            [r["action"] for r in async_recs],
            [r["action"] for r in pool_recs],
            err_msg=f"wave {w} decisions diverge")
        np.testing.assert_allclose(
            [r["reward"] for r in async_recs],
            [r["reward"] for r in pool_recs], rtol=1e-6,
            err_msg=f"wave {w} rewards diverge")
        pool.end_slice(epochs=2)
        eng.end_slice(epochs=2)
    assert eng.check_accounting()["lost"] == 0


# -------------------------------------------------- snapshot/restore --
def _drive(eng, wave_ids):
    recs = []
    for ids in wave_ids:
        eng.submit([Request(tokens=TOK, sample_idx=int(i)) for i in ids])
        recs.extend(r for r in eng.pump() + eng.drain()
                    if r["status"] == "ok")
    return recs


def _wave_ids(n, waves, per, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, n, per) for _ in range(waves)]


def test_snapshot_restore_round_trip_device_router(tmp_path):
    """Serve N waves, snapshot, kill, restore into a FRESH engine, serve
    N more: decisions, rewards, and counters match the uninterrupted
    run exactly (the ring buffers, PRNG key, and wave cursor all travel
    through the npz+json snapshot)."""
    henv, env = _replay_env()
    cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1], num_actions=env.K)
    pol, hyp = make_policy("neuralucb", env, cfg)
    reward = np.asarray(env.reward)
    q = np.asarray(env.quality)
    c = np.asarray(env.cost)

    def build():
        router = DevicePolicyRouter(pol, hyp, _tables(env), seed=0,
                                    slice_width=16, capacity_slices=8,
                                    batch_size=16, train_chunks=1)
        return AsyncRouterEngine(router, env.K, reward_table=reward,
                                 quality_table=q, cost_table=c,
                                 decide_batch=16, serve_batch=16)

    ids = _wave_ids(reward.shape[0], 6, 16)
    path = str(tmp_path / "snap")

    eng_a = build()
    _drive(eng_a, ids[:3])
    eng_a.end_slice()
    eng_a.snapshot(path)
    recs_a = _drive(eng_a, ids[3:])      # uninterrupted continuation

    eng_b = build()                      # "kill": brand-new everything
    eng_b.restore(path)
    recs_b = _drive(eng_b, ids[3:])

    np.testing.assert_array_equal([r["action"] for r in recs_a],
                                  [r["action"] for r in recs_b])
    np.testing.assert_array_equal([r["reward"] for r in recs_a],
                                  [r["reward"] for r in recs_b])
    assert eng_a.counters == eng_b.counters


def test_snapshot_requires_drained_engine(tmp_path):
    henv, env = _replay_env()
    cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1], num_actions=env.K)
    pol, hyp = make_policy("neuralucb", env, cfg)
    router = DevicePolicyRouter(pol, hyp, _tables(env), seed=0,
                                slice_width=16, capacity_slices=4,
                                batch_size=16, train_chunks=1)
    eng = AsyncRouterEngine(router, env.K,
                            reward_table=np.asarray(env.reward),
                            decide_batch=16, decide_flush=9e9)
    eng.submit([Request(tokens=TOK, sample_idx=0)])
    with pytest.raises(RuntimeError, match="in flight"):
        eng.snapshot(str(tmp_path / "bad"))


def test_snapshot_restore_round_trip_host_router(tmp_path):
    """Same round-trip through the host `NeuralUCBRouter`: its replay
    buffer, optimizer, and numpy bit-generator state must all survive
    the snapshot (the RNG is what makes post-restore warm-phase draws
    reproduce)."""
    K, n = 2, 64
    rng = np.random.default_rng(3)
    qt = rng.uniform(0.3, 0.9, (n, K)).astype(np.float32)
    rw = rng.uniform(0.1, 0.9, (n, K)).astype(np.float32)
    ucfg = UtilityNetConfig(emb_dim=16, num_actions=K, num_domains=3)
    emb = rng.normal(size=(n, 16)).astype(np.float32)
    feat = rng.normal(size=(n, 4)).astype(np.float32)
    dom = rng.integers(0, 3, n).astype(np.int32)

    def build():
        return AsyncRouterEngine(
            NeuralUCBRouter(ucfg, seed=0, batch_size=16), K,
            reward_table=rw, quality_table=qt, decide_batch=16,
            serve_batch=16)

    def drive(eng, wave_ids):
        recs = []
        for ids in wave_ids:
            eng.submit([Request(tokens=TOK, x_emb=emb[i], x_feat=feat[i],
                                domain=int(dom[i]), sample_idx=int(i))
                        for i in ids])
            recs.extend(r for r in eng.pump() + eng.drain()
                        if r["status"] == "ok")
        return recs

    ids = _wave_ids(n, 4, 16, seed=9)
    path = str(tmp_path / "host-snap")
    eng_a = build()
    drive(eng_a, ids[:2])
    eng_a.end_slice()
    eng_a.snapshot(path)
    recs_a = drive(eng_a, ids[2:])

    eng_b = build()
    eng_b.restore(path)
    recs_b = drive(eng_b, ids[2:])
    np.testing.assert_array_equal([r["action"] for r in recs_a],
                                  [r["action"] for r in recs_b])
    np.testing.assert_allclose([r["reward"] for r in recs_a],
                               [r["reward"] for r in recs_b], rtol=1e-6)
    assert eng_a.counters == eng_b.counters
