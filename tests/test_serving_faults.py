"""Fault-injection suite for the async serving engine (ISSUE satellite):
arm outages mid-stream, decide-path exceptions, and queue saturation.
Every fault must be absorbed — fallback chains fire, bounded queues shed
with counted drops (never deadlock), the router never learns from
decisions it did not make, and the accounting invariant (submitted ==
completed + shed + in-flight) holds through every storm."""
import types

import numpy as np
import pytest

from repro.serving import (
    AsyncRouterEngine,
    Request,
    ScriptedFaults,
    outages_from_scenario,
    run_storm,
)
from serving_fakes import BlindFakeRouter, FakeRouter

TOK = np.arange(1, 5, dtype=np.int32)
K, N = 4, 200


def _tables(seed=0):
    rng = np.random.default_rng(seed)
    reward = rng.uniform(0.1, 0.9, (N, K)).astype(np.float32)
    quality = rng.uniform(0.2, 1.0, (N, K)).astype(np.float32)
    # arm k costs ~k+1: the cheapest-first fallback order is 0,1,2,3
    cost = (np.arange(1, K + 1, dtype=np.float32)[None]
            * rng.uniform(0.95, 1.05, (N, K))).astype(np.float32)
    return reward, quality, cost


def _engine(router, **kw):
    reward, quality, cost = _tables()
    kw.setdefault("decide_batch", 32)
    kw.setdefault("queue_capacity", 256)
    return AsyncRouterEngine(router, K, reward_table=reward,
                             quality_table=quality, cost_table=cost, **kw)


def _reqs(n, start=0):
    return [Request(tokens=TOK, sample_idx=(start + i) % N)
            for i in range(n)]


# ------------------------------------------------------------- outages --
def test_fallback_chain_fires_on_outage():
    """A mask-blind router keeps deciding onto a down arm; the engine
    walks the arm's fallback chain, serves, counts the remap, and
    EXCLUDES the remapped rows from learning."""
    r = BlindFakeRouter(K, prefer=0)
    eng = _engine(r, fallback_chains={0: [2, 1, 3], 1: [0], 2: [0],
                                      3: [0]})
    eng.set_arm_health(0, False)
    eng.submit(_reqs(20))
    recs = eng.pump() + eng.drain()
    ok = [x for x in recs if x["status"] == "ok"]
    assert len(ok) == 20
    assert all(x["action"] == 2 and x["decided"] == 0
               and x["fallback_depth"] == 1 for x in ok)
    assert eng.counters["fallbacks"] == 20
    # remapped rows never reach the router's learner
    assert eng.counters["learned"] == 0
    assert eng.counters["skipped_learn"] == 20

    eng.set_arm_health(2, False)            # cascading: next link serves
    eng.submit(_reqs(20))
    recs = eng.pump() + eng.drain()
    assert all(x["action"] == 1 and x["fallback_depth"] == 2
               for x in recs if x["status"] == "ok")

    eng.set_arm_health(0, True)             # recovery: chain goes quiet
    before = eng.counters["fallbacks"]
    eng.submit(_reqs(20))
    recs = eng.pump() + eng.drain()
    assert all(x["action"] == 0 and x["fallback_depth"] == 0
               for x in recs if x["status"] == "ok")
    assert eng.counters["fallbacks"] == before
    assert eng.check_accounting()["lost"] == 0


def test_availability_aware_router_never_needs_fallback():
    """A serving_v2 router gets the live mask in decide — it routes
    around the outage itself, so the chain never fires and every row
    learns."""
    eng = _engine(FakeRouter(K, prefer=0))
    eng.set_arm_health(0, False)
    eng.submit(_reqs(40))
    recs = eng.pump() + eng.drain()
    assert all(x["action"] == 1 and x["fallback_depth"] == 0
               for x in recs if x["status"] == "ok")
    assert eng.counters["fallbacks"] == 0
    assert eng.counters["learned"] == 40
    assert eng.check_accounting()["lost"] == 0


def test_whole_chain_down_sheds_counted():
    """Decided arm down and every chain link down: the request is shed
    with a counted drop and a log record — not an exception, not a
    silent loss."""
    r = BlindFakeRouter(K, prefer=0)
    eng = _engine(r, fallback_chains={0: [1]})
    eng.set_arm_health(0, False)
    eng.set_arm_health(1, False)
    eng.submit(_reqs(15))
    recs = eng.pump() + eng.drain()
    assert all(x["status"] == "shed_no_arm" for x in recs)
    assert eng.counters["shed_no_arm"] == 15
    assert eng.counters["completed"] == 0
    assert eng.check_accounting()["lost"] == 0


def test_all_arms_down_never_deadlocks():
    eng = _engine(FakeRouter(K))
    for a in range(K):
        eng.set_arm_health(a, False)
    eng.submit(_reqs(40))
    recs = eng.pump() + eng.drain()      # returns; no stall, no raise
    assert len(recs) == 40
    assert eng.counters["shed_no_arm"] == 40
    assert eng.in_flight == 0
    assert eng.check_accounting()["lost"] == 0


# --------------------------------------------------- decide exceptions --
def test_decide_exception_degrades_without_learning():
    """An injected decide fault degrades the microbatch to the cheapest
    healthy arm, serves it, and skips the router update — the router
    never learns from decisions it did not make."""
    r = FakeRouter(K, prefer=3)
    faults = ScriptedFaults(fail_decide_calls=[0])
    eng = _engine(r, fault_hook=faults.on_decide)
    eng.submit(_reqs(10))
    recs = eng.pump() + eng.drain()
    assert faults.injected_decide_faults == 1
    assert eng.counters["decide_errors"] == 1
    ok = [x for x in recs if x["status"] == "ok"]
    assert len(ok) == 10
    assert all(x["action"] == 0 for x in ok)   # cheapest healthy arm
    assert r.update_calls == []                # no update for the batch
    assert eng.counters["skipped_learn"] == 10

    eng.submit(_reqs(10))                      # call 1: back to normal
    recs = eng.pump() + eng.drain()
    assert eng.counters["decide_errors"] == 1
    assert all(x["action"] == 3 for x in recs if x["status"] == "ok")
    assert r.update_calls == [10]
    assert eng.check_accounting()["lost"] == 0


def test_decide_exception_with_outage_degrades_to_healthy():
    """Fault + outage stacked: the degrade target skips down arms."""
    faults = ScriptedFaults(fail_decide_calls=[0])
    eng = _engine(FakeRouter(K), fault_hook=faults.on_decide)
    eng.set_arm_health(0, False)
    eng.submit(_reqs(8))
    recs = eng.pump() + eng.drain()
    assert all(x["action"] == 1 for x in recs if x["status"] == "ok")
    assert eng.check_accounting()["lost"] == 0


# --------------------------------------------------- queue saturation --
def test_bounded_queue_sheds_burst_with_counted_drops():
    eng = _engine(FakeRouter(K), queue_capacity=32, decide_batch=32)
    admitted, shed = eng.submit(_reqs(100))
    assert (admitted, shed) == (32, 68)
    assert eng.counters["shed_queue_full"] == 68
    recs = eng.pump() + eng.drain()
    assert eng.counters["completed"] == 32
    assert eng.check_accounting()["lost"] == 0
    # shed records carry the drop reason
    sheds = [x for x in eng.log if x["status"] == "shed_queue_full"]
    assert len(sheds) == 68


def test_queue_saturation_mid_stream_recovers():
    """Saturate, drain, saturate again: capacity is per-moment, not a
    lifetime budget; later waves are admitted once the queue empties."""
    eng = _engine(FakeRouter(K), queue_capacity=32, decide_batch=32)
    total_ok = 0
    for w in range(5):
        eng.submit(_reqs(50, start=w * 50))
        recs = eng.pump() + eng.drain()
        total_ok += sum(1 for x in recs if x["status"] == "ok")
    assert total_ok == eng.counters["completed"] == 5 * 32
    assert eng.counters["shed_queue_full"] == 5 * 18
    assert eng.check_accounting()["lost"] == 0


def test_queue_capacity_must_fit_a_microbatch():
    with pytest.raises(ValueError, match="queue_capacity"):
        _engine(FakeRouter(K), queue_capacity=8, decide_batch=32)


# ------------------------------------------------- reward accounting --
def test_learning_accounting_consistent_under_chaos():
    """Messy run — outages toggling, injected decide faults, queue
    pressure — the learning ledger still balances: every completed
    request was either learned from or counted as skipped."""
    r = BlindFakeRouter(K, prefer=0)
    faults = ScriptedFaults(fail_decide_calls=[1, 4],
                            outages=[(0, 2, 5), (1, 3, 6), (2, 4, 6)])
    eng = _engine(r, fault_hook=faults.on_decide, queue_capacity=64,
                  decide_batch=16)
    for w in range(8):
        faults.apply_wave(eng, w)
        eng.submit(_reqs(40, start=w * 40))
        eng.pump()
        eng.drain()
    c = eng.check_accounting()
    assert c["lost"] == 0
    assert c["learned"] + c["skipped_learn"] == c["completed"]
    assert c["learned"] == sum(r.update_calls)
    assert c["decide_errors"] == 2
    assert c["completed"] + c["shed_queue_full"] + c["shed_no_arm"] \
        == c["submitted"]


# ------------------------------------------------------------- storms --
def test_storm_absorbs_everything_zero_lost():
    """run_storm end-to-end with cascading outages, an injected decide
    fault, and flash-crowd pressure on a tiny queue: every outage
    absorbed, zero unhandled exceptions, zero lost requests."""
    reward, quality, cost = _tables()
    env = types.SimpleNamespace(reward=reward, quality=quality, cost=cost)
    m = run_storm(env, FakeRouter(K), requests=2_000, waves=20,
                  pattern="flash_crowd",
                  outages=[(0, 4, 12), (1, 8, 14)],
                  fail_decide_calls=[3], queue_capacity=64,
                  decide_batch=32, serve_batch=32, seed=0)
    assert m["lost_requests"] == 0
    assert m["decide_errors"] == 1
    assert m["completed"] + m["shed"] == m["requests"]
    assert m["decide_calls"] > 0 and m["decide_p99_us"] >= m["decide_p50_us"]
    # the tiny queue under a 10x crowd must shed — and must count it
    assert m["shed"] == m["shed_queue_full"] + m["shed_no_arm"]


def test_scenario_engine_drives_outage_windows():
    """The sim scenario engine doubles as the outage generator: the
    `arm_outage` cascades map onto well-formed per-arm windows, and a
    storm driven by them loses nothing."""
    from repro.data.routerbench import RouterBenchSim
    from repro.sim import DeviceReplayEnv

    henv = RouterBenchSim(seed=0, n_samples=600, n_slices=4)
    env = DeviceReplayEnv.from_host(henv)
    waves = 12
    wins = outages_from_scenario("arm_outage", env, waves)
    assert wins, "arm_outage produced no outage windows"
    for arm, s, e in wins:
        assert 0 <= arm < env.K and 0 <= s < e <= waves
    m = run_storm(env, FakeRouter(env.K), requests=600, waves=waves,
                  pattern="steady", scenario="arm_outage",
                  queue_capacity=128, decide_batch=32, seed=0)
    assert m["lost_requests"] == 0
    assert m["completed"] + m["shed"] == 600
    assert m["outages"] == [list(w) for w in wins]
