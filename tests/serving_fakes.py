"""Deterministic in-memory router fakes for the async-engine tests.

`FakeRouter` implements the ``serving_v2`` protocol (id-addressed
``decide`` with a live availability mask, ``update_wave`` feedback) with
pure numpy — no jit, no device state — so the engine/fault tests can
exercise queueing, fallback, and accounting semantics in milliseconds.
`BlindFakeRouter` ignores the availability mask (``availability_aware``
off), forcing the engine's fallback chains to do the remapping.
"""
import numpy as np


class FakeRouter:
    """Always prefers ``prefer``; with an availability mask, falls back
    to the lowest-index healthy arm itself (availability-aware)."""

    serving_v2 = True

    def __init__(self, num_arms: int, prefer: int = 0):
        self.num_actions = int(num_arms)
        self.prefer = int(prefer)
        self.update_calls = []          # learned count per update_wave
        self.slices = 0

    def decide(self, x_emb=None, x_feat=None, domain=None, *,
               sample_idx=None, avail=None):
        ids = np.asarray(sample_idx, np.int64).reshape(-1)
        a = np.full(ids.size, self.prefer, np.int32)
        if avail is not None:
            av = np.asarray(avail)
            if av[self.prefer] <= 0:
                up = np.flatnonzero(av > 0)
                a[:] = up[0] if up.size else self.prefer
        return {"action": a, "ids": ids, "aux": {}, "n": ids.size}

    def update_wave(self, decision, served, rewards, learn_mask=None):
        n = decision["n"]
        learn = (np.ones(n, bool) if learn_mask is None
                 else np.asarray(learn_mask, bool).reshape(-1))
        learn = learn & (np.asarray(served) == decision["action"])
        self.update_calls.append(int(learn.sum()))
        return int(learn.sum())

    def end_slice(self, epochs=None):
        self.slices += 1


class BlindFakeRouter(FakeRouter):
    """Ignores the availability mask — decides onto ``prefer`` even when
    it is down, so the engine's fallback chain must remap."""

    def decide(self, x_emb=None, x_feat=None, domain=None, *,
               sample_idx=None, avail=None):
        ids = np.asarray(sample_idx, np.int64).reshape(-1)
        return {"action": np.full(ids.size, self.prefer, np.int32),
                "ids": ids, "aux": {}, "n": ids.size}
