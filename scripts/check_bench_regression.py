#!/usr/bin/env python
"""Bench-regression guard: compare a fresh BENCH_protocol.json against a
reference (by default ``git show HEAD:BENCH_protocol.json``) and fail on
any >20% drop in a throughput/speedup metric.

Rate-like leaves are discovered recursively: every numeric key ending in
``_per_s`` is a higher-is-better HARD metric (>threshold drop fails);
``speedup`` / ``speedup_vs_sequential`` / ``speedup_pallas_vs_jnp``
leaves are RATIOS of two measured legs and only WARN on a drop — a
ratio falls whenever its baseline denominator gets faster, which is an
improvement, not a regression (e.g. the bucketed A^-1 rebuild sped the
sequential legs more than the already-amortized vmapped legs).
One absolute floor is enforced on top:
``neuralucb_scan_vs_stepped.speedup`` must stay >= 1.0 — the scanned
engine may never lose to its own stepped runner (DESIGN.md §8.4).
Sections whose workload shape changed between the two files (any of
the shape keys ``n_samples`` / ``n_slices`` / ``n_seeds`` /
``train_steps`` / ``batch`` / ``buffer_rows`` differ) are skipped
unless ``--strict`` — a reshaped bench is a re-baseline, not a
regression.

    python scripts/check_bench_regression.py [CURRENT] [--ref PATH|-]
        [--threshold 0.2] [--strict]

Exit 0 = no regression; 1 = at least one metric regressed past the
threshold; 2 = usage/IO error (missing files, no reference).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, Iterator, List, Tuple

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                         os.pardir))
DEFAULT_CURRENT = os.path.join(REPO_ROOT, "BENCH_protocol.json")

SHAPE_KEYS = ("n_samples", "n_slices", "n_seeds", "train_steps",
              "batch_size", "batch", "buffer_rows", "slice_width",
              "steps", "reduced")
RATIO_NAMES = ("speedup", "speedup_vs_sequential",
               "speedup_pallas_vs_jnp",
               # physical_pool calibration: measured decode wall over the
               # analytic roofline lower bound — the measured leg is
               # machine-load dependent, so it never fails hard
               "measured_over_analytic")
#: (path, floor) invariants checked on the CURRENT file alone
FLOORS = ((("neuralucb_scan_vs_stepped", "speedup"), 1.0),)


def _is_rate(key: str) -> bool:
    return key.endswith("_per_s") or key in RATIO_NAMES


def _walk(d, path=()) -> Iterator[Tuple[Tuple[str, ...], float]]:
    if isinstance(d, dict):
        for k, v in d.items():
            if isinstance(v, dict):
                yield from _walk(v, path + (k,))
            elif _is_rate(k) and isinstance(v, (int, float)):
                yield path + (k,), float(v)


def _section_shape(d: Dict) -> Tuple:
    return tuple((k, d.get(k)) for k in SHAPE_KEYS if k in d)


def _lookup(d: Dict, path: Tuple[str, ...]):
    for k in path:
        if not isinstance(d, dict) or k not in d:
            return None
        d = d[k]
    return d


def load_reference(ref: str) -> Dict:
    """A file path, or '-' for the committed HEAD copy."""
    if ref != "-":
        with open(ref) as f:
            return json.load(f)
    out = subprocess.run(
        ["git", "show", "HEAD:BENCH_protocol.json"], cwd=REPO_ROOT,
        capture_output=True, text=True)
    if out.returncode != 0:
        raise FileNotFoundError(
            "no BENCH_protocol.json at HEAD: " + out.stderr.strip())
    return json.loads(out.stdout)


def compare(cur: Dict, ref: Dict, threshold: float,
            strict: bool) -> List[str]:
    failures = []
    skipped = set()
    for path, ref_v in _walk(ref):
        section = path[0]
        if not strict and section in cur and isinstance(cur[section], dict) \
                and isinstance(ref.get(section), dict) \
                and _section_shape(cur[section]) != _section_shape(
                    ref[section]):
            if section not in skipped:
                skipped.add(section)
                print(f"  skip  {section}: workload shape changed "
                      f"(re-baseline)")
            continue
        cur_v = _lookup(cur, path)
        name = "/".join(path)
        if cur_v is None:
            # a metric may legitimately disappear in a schema change;
            # never silently, though
            print(f"  warn  {name}: missing from current file")
            continue
        if ref_v <= 0:
            continue
        drop = 1.0 - float(cur_v) / ref_v
        hard = path[-1].endswith("_per_s")
        if drop > threshold and hard:
            failures.append(f"{name}: {ref_v:.4g} -> {float(cur_v):.4g} "
                            f"({drop:+.1%} drop)")
            status = "FAIL"
        elif drop > threshold:
            status = "warn"  # ratio leaf: denominator may have improved
        else:
            status = "ok"
        if drop > threshold / 2:
            print(f"  {status:4s}  {name}: {ref_v:.4g} -> "
                  f"{float(cur_v):.4g} ({-drop:+.1%})")
    for path, floor in FLOORS:
        v = _lookup(cur, path)
        if isinstance(v, (int, float)) and v < floor:
            failures.append(f"{'/'.join(path)}: {v:.4g} below the "
                            f"{floor:g} floor")
            print(f"  FAIL  {'/'.join(path)}: {v:.4g} < floor {floor:g}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", nargs="?", default=DEFAULT_CURRENT)
    ap.add_argument("--ref", default="-",
                    help="reference JSON path, or '-' for the HEAD copy")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="max allowed fractional drop (default 0.2)")
    ap.add_argument("--strict", action="store_true",
                    help="compare even when a section's workload shape "
                         "changed")
    args = ap.parse_args()
    try:
        with open(args.current) as f:
            cur = json.load(f)
        ref = load_reference(args.ref)
    except (OSError, FileNotFoundError, json.JSONDecodeError) as e:
        print(f"check_bench_regression: {e}", file=sys.stderr)
        return 2
    failures = compare(cur, ref, args.threshold, args.strict)
    if failures:
        print(f"\n{len(failures)} metric(s) regressed more than "
              f"{args.threshold:.0%}:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("bench regression guard: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
