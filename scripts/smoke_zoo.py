"""Dev smoke: reduced forward + decode for every family. Not a test file."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import model as MODEL

ok = True
for arch in ARCH_IDS:
    cfg = get_config(arch).reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    try:
        key = jax.random.PRNGKey(0)
        params = MODEL.init_params(key, cfg)
        B, S = 2, 16
        batch = {"tokens": jnp.ones((B, S), jnp.int32),
                 "labels": jnp.ones((B, S), jnp.int32)}
        if cfg.arch_type == "audio":
            batch["audio_embed"] = jnp.ones((B, cfg.num_audio_frames, cfg.d_model))
        if cfg.arch_type == "vlm":
            batch["image_embed"] = jnp.ones((B, cfg.num_image_tokens, cfg.d_model))
        logits, aux = MODEL.forward_train(params, cfg, batch)
        assert logits.shape[:2] == (B, S), logits.shape
        assert bool(jnp.all(jnp.isfinite(logits))), "NaN in logits"
        # decode
        memory = batch.get("audio_embed", batch.get("image_embed"))
        cache = MODEL.init_cache(cfg, B, 32, memory=memory, params=params)
        tok = jnp.ones((B, 1), jnp.int32)
        dlogits, cache2 = MODEL.decode_step(params, cfg, cache, tok)
        assert dlogits.shape[:2] == (B, 1)
        assert bool(jnp.all(jnp.isfinite(dlogits))), "NaN in decode"
        assert int(cache2["pos"]) == 1
        print(f"OK   {arch:28s} logits{logits.shape} aux={float(aux):.4f}")
    except Exception as e:
        ok = False
        import traceback
        print(f"FAIL {arch}: {type(e).__name__}: {e}")
        traceback.print_exc()
sys.exit(0 if ok else 1)
