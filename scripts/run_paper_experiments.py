"""Reproduce the paper's summary table (NeuralUCB vs. baselines on utility
reward / cost / quality, RouterBench replay, 20 slices) on the
device-resident protocol engine, with a multi-seed sweep for the random
baseline.

  PYTHONPATH=src python scripts/run_paper_experiments.py                # full
  PYTHONPATH=src python scripts/run_paper_experiments.py \
      --n-samples 4000 --n-slices 4 --epochs 2                          # smoke

Writes the summary (plus per-slice curves) to --out (default
``paper_experiments.json``) and prints the paper-style table. Slice 1 is
warm-start-affected and excluded from the summary means (paper §4.2).
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.core.protocol import summarize
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim
from repro.sim import (
    DeviceNeuralUCB,
    DeviceReplayEnv,
    fixed_policy,
    greedy_policy,
    random_policy,
    run_baseline_sweep,
    run_protocol_device,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-samples", type=int, default=36_497)
    ap.add_argument("--n-slices", type=int, default=20)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--random-seeds", type=int, default=5,
                    help="seeds for the random-baseline sweep (vmap)")
    ap.add_argument("--cost-lambda", type=float, default=1.0)
    ap.add_argument("--out", default="paper_experiments.json")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    henv = RouterBenchSim(seed=args.seed, n_samples=args.n_samples,
                          n_slices=args.n_slices,
                          cost_lambda=args.cost_lambda)
    denv = DeviceReplayEnv.from_host(henv)
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)

    policies = {
        "random": random_policy(denv.K),
        "min-cost": fixed_policy(denv.min_cost_action(), "min-cost"),
        "max-quality-arm": fixed_policy(denv.max_quality_action(),
                                        "max-quality"),
        "greedy": greedy_policy(denv.K),
    }
    nucb = DeviceNeuralUCB(denv, cfg, seed=args.seed)
    results = run_protocol_device(denv, policies, neuralucb=nucb,
                                  epochs=args.epochs,
                                  verbose=not args.quiet)
    summ = summarize(results, skip_first=True)

    # multi-seed random sweep: mean +/- std of the per-slice average reward
    sweep = run_baseline_sweep(denv, random_policy(denv.K),
                               range(args.random_seeds))
    r = sweep["avg_reward"][:, 1:].mean(axis=1)
    summ["random"]["avg_reward_seed_mean"] = float(r.mean())
    summ["random"]["avg_reward_seed_std"] = float(r.std())

    # oracle reference (full-information upper bound, not a policy)
    oracle = float(henv.reward_table.max(axis=1).mean())

    header = f"{'policy':<18}{'avg_reward':>11}{'avg_cost':>10}" \
             f"{'avg_quality':>12}"
    print("\n" + header)
    print("-" * len(header))
    order = ["neuralucb", "random", "min-cost", "max-quality-arm", "greedy"]
    for name in order:
        s = summ[name]
        print(f"{name:<18}{s['avg_reward']:>11.4f}{s['avg_cost']:>10.4f}"
              f"{s['avg_quality']:>12.4f}")
    print(f"{'oracle (ref)':<18}{oracle:>11.4f}")
    mq_cost = summ["max-quality-arm"]["avg_cost"]
    frac = summ["neuralucb"]["avg_cost"] / mq_cost if mq_cost else float("nan")
    print(f"\nneuralucb cost = {100 * frac:.1f}% of max-quality-arm "
          f"(paper: ~33%)")

    out = {
        "config": vars(args),
        "summary": summ,
        "oracle_reward": oracle,
        "neuralucb_cost_fraction_of_max_quality": frac,
        "per_slice": {k: {kk: vv for kk, vv in v.items()
                          if kk != "action_hist"}
                      for k, v in results.items()},
        "action_hist": {k: np.asarray(v["action_hist"]).tolist()
                        for k, v in results.items()},
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"\nwrote {args.out}")

    # paper's qualitative ordering must hold on the full run
    ok = (summ["neuralucb"]["avg_reward"] > summ["random"]["avg_reward"]
          and summ["neuralucb"]["avg_reward"]
          > summ["max-quality-arm"]["avg_reward"] * 0.9)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
