"""Paper experiment driver: the summary table (NeuralUCB vs. baselines on
utility reward / cost / quality, RouterBench replay, 20 slices) plus the
Figures 2-4 sweep — seeds x beta (x tau_g x cost_lambda) grids — all on
the device-resident protocol engine.

  PYTHONPATH=src python scripts/run_paper_experiments.py              # table
  PYTHONPATH=src python scripts/run_paper_experiments.py \
      --n-samples 4000 --n-slices 4 --epochs 2                        # smoke
  PYTHONPATH=src python scripts/run_paper_experiments.py \
      --sweep-seeds 5 --betas 0.25 0.5 1.0 2.0                       # Fig. 2-4
  PYTHONPATH=src python scripts/run_paper_experiments.py \
      --n-samples 1500 --n-slices 3 --sweep-seeds 2 --betas 0.5 1.0 \
      --train-steps 32 --sweep-only                                   # CI
  PYTHONPATH=src python scripts/run_paper_experiments.py \
      --scenario price_shock arm_outage --replay-rho 0.4              # §9
  PYTHONPATH=src python scripts/run_paper_experiments.py \
      --policies neuralucb linucb neural_ts eps_greedy \
      --sweep-seeds 3 --scenario stationary price_shock               # §10

The sweep runs as ONE device dispatch (`repro.sim.run_neuralucb_sweep`:
the whole T-slice Algorithm-1 scan vmapped over (grid x seed) lanes and
sharded across local devices), then each cell is summarized with the
shared ``core.protocol.summarize`` (slice 1 excluded, paper §4.2).
Writes summary + curves to --out (default ``paper_experiments.json``).
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.core.protocol import summarize, summarize_sweep
from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim
from repro.sim import (
    DeviceNeuralUCB,
    DeviceReplayEnv,
    ForgettingConfig,
    fixed_policy,
    greedy_policy,
    make_policy,
    random_policy,
    run_baseline_device,
    run_baseline_sweep,
    run_neuralucb_device,
    run_neuralucb_sweep,
    run_policy_sweep,
    run_protocol_device,
    sweep_point_results,
)


def run_summary_table(henv, denv, cfg, args):
    """Single-run NeuralUCB vs. baselines table (paper Table 1 shape)."""
    policies = {
        "random": random_policy(denv.K),
        "min-cost": fixed_policy(denv.min_cost_action(), "min-cost"),
        "max-quality-arm": fixed_policy(denv.max_quality_action(),
                                        "max-quality"),
        "greedy": greedy_policy(denv.K),
    }
    nucb = DeviceNeuralUCB(denv, cfg, seed=args.seed)
    results = run_protocol_device(denv, policies, neuralucb=nucb,
                                  epochs=args.epochs,
                                  verbose=not args.quiet)
    summ = summarize(results, skip_first=True)

    # multi-seed random sweep: mean +/- std of the per-slice average
    # reward (annotated schema: metric leaves are (G=1, n_seeds, T))
    sweep = run_baseline_sweep(denv, random_policy(denv.K),
                               range(args.random_seeds))
    r = sweep["avg_reward"][0, :, 1:].mean(axis=1)
    summ["random"]["avg_reward_seed_mean"] = float(r.mean())
    summ["random"]["avg_reward_seed_std"] = float(r.std())

    # oracle reference (full-information upper bound, not a policy)
    oracle = float(henv.reward_table.max(axis=1).mean())

    header = f"{'policy':<18}{'avg_reward':>11}{'avg_cost':>10}" \
             f"{'avg_quality':>12}"
    print("\n" + header)
    print("-" * len(header))
    order = ["neuralucb", "random", "min-cost", "max-quality-arm", "greedy"]
    for name in order:
        s = summ[name]
        print(f"{name:<18}{s['avg_reward']:>11.4f}{s['avg_cost']:>10.4f}"
              f"{s['avg_quality']:>12.4f}")
    print(f"{'oracle (ref)':<18}{oracle:>11.4f}")
    mq_cost = summ["max-quality-arm"]["avg_cost"]
    frac = summ["neuralucb"]["avg_cost"] / mq_cost if mq_cost else float("nan")
    print(f"\nneuralucb cost = {100 * frac:.1f}% of max-quality-arm "
          f"(paper: ~33%)")

    out = {
        "summary": summ,
        "oracle_reward": oracle,
        "neuralucb_cost_fraction_of_max_quality": frac,
        "per_slice": {k: {kk: vv for kk, vv in v.items()
                          if kk != "action_hist"}
                      for k, v in results.items()},
        "action_hist": {k: np.asarray(v["action_hist"]).tolist()
                        for k, v in results.items()},
    }
    ok = (summ["neuralucb"]["avg_reward"] > summ["random"]["avg_reward"]
          and summ["neuralucb"]["avg_reward"]
          > summ["max-quality-arm"]["avg_reward"] * 0.9)
    return out, ok


def run_figure_sweep(denv, cfg, args):
    """Figures 2-4: seeds x (beta, tau_g, cost_lambda) grid in one
    vmapped scan dispatch, each cell summarized with the shared
    ``summarize`` and aggregated mean +/- std over seeds."""
    lambdas = [None if l < 0 else l for l in args.cost_lambdas] \
        if args.cost_lambdas else [None]
    sweep = run_neuralucb_sweep(
        denv, cfg, seeds=range(args.sweep_seeds), betas=args.betas,
        tau_gs=args.tau_gs, cost_lambdas=lambdas, epochs=args.epochs,
        train_steps=args.train_steps)
    G, S = sweep["avg_reward"].shape[:2]
    points = []
    for g in range(G):
        cells = [summarize({"p": sweep_point_results(sweep, g, s)})["p"]
                 for s in range(S)]
        agg = {"beta": float(sweep["beta"][g]),
               "tau_g": float(sweep["tau_g"][g]),
               "cost_lambda": (None if np.isnan(sweep["cost_lambda"][g])
                               else float(sweep["cost_lambda"][g]))}
        for k in ("avg_reward", "avg_cost", "avg_quality"):
            vals = np.asarray([c[k] for c in cells])
            agg[f"{k}_mean"] = float(vals.mean())
            agg[f"{k}_std"] = float(vals.std())
        agg["per_slice_avg_reward_mean"] = \
            sweep["avg_reward"][g].mean(axis=0).tolist()
        points.append(agg)

    header = (f"{'beta':>6}{'tau_g':>7}{'lambda':>8}{'avg_reward':>16}"
              f"{'avg_cost':>14}{'avg_quality':>12}")
    print("\nNeuralUCB sweep "
          f"({args.sweep_seeds} seeds x {G} grid points, one dispatch)")
    print(header)
    print("-" * len(header))
    for p in points:
        lam = "env" if p["cost_lambda"] is None else f"{p['cost_lambda']:.2f}"
        print(f"{p['beta']:>6.2f}{p['tau_g']:>7.2f}{lam:>8}"
              f"{p['avg_reward_mean']:>9.4f}±{p['avg_reward_std']:.4f}"
              f"{p['avg_cost_mean']:>9.4f}±{p['avg_cost_std']:.4f}"
              f"{p['avg_quality_mean']:>12.4f}")
    ok = all(np.isfinite(p["avg_reward_mean"]) and p["avg_reward_mean"] > 0
             for p in points)
    return {"seeds": int(args.sweep_seeds),
            "train_steps": int(sweep["train_steps"]),
            "points": points}, ok


def run_policy_comparison(denv, cfg, args):
    """Exploration-strategy comparison (DESIGN.md §10): every requested
    zoo policy × seeds, per scenario (stationary when none named), each
    scenario ONE sharded device dispatch (``run_policy_sweep``'s policy
    axis). The paper's closing question — action discrimination and
    exploration — answered as a table."""
    seeds = range(max(1, args.sweep_seeds))
    policies = {name: make_policy(name, denv, cfg, ucb_backend="jnp")
                for name in args.policies}
    scenarios = args.scenario or [None]
    out = {}
    ok = True
    for scen in scenarios:
        sw = run_policy_sweep(denv, policies, seeds=seeds, scenario=scen,
                              train_steps=args.train_steps,
                              epochs=args.epochs)
        rows = {name: summarize_sweep(sw[name])[0] for name in sw}
        label = scen or "stationary"
        header = (f"{'policy':<14}{'avg_reward':>16}{'oracle':>9}"
                  f"{'dyn_regret':>11}{'avg_cost':>10}")
        print(f"\npolicy zoo ({label}, {len(list(seeds))} seeds, "
              f"one dispatch)")
        print(header)
        print("-" * len(header))
        for name, p in sorted(rows.items(),
                              key=lambda kv: -kv[1]["avg_reward_mean"]):
            print(f"{name:<14}{p['avg_reward_mean']:>9.4f}"
                  f"±{p['avg_reward_std']:.4f}"
                  f"{p['oracle_avg_reward_mean']:>9.4f}"
                  f"{p['dynamic_regret_mean']:>11.4f}"
                  f"{p['avg_cost_mean']:>10.4f}")
        out[label] = rows
        ok = ok and all(np.isfinite(p["avg_reward_mean"])
                        for p in rows.values())
    return out, ok


def run_scenario_suite(denv, cfg, args):
    """Non-stationary scenario runs (DESIGN.md §9): per scenario, the
    scanned NeuralUCB (vanilla AND the forgetting variant) plus greedy /
    random baselines over the identical drifting stream — each run one
    device dispatch — summarized with dynamic-oracle regret."""
    fcfg = ForgettingConfig(gamma=args.gamma, window=args.window,
                            replay_rho=args.replay_rho)
    out = {}
    ok = True
    for name in args.scenario:
        kw = dict(seed=args.seed, train_steps=args.train_steps,
                  epochs=args.epochs)
        results = {
            "neuralucb": run_neuralucb_device(denv, cfg, scenario=name,
                                              **kw),
            "neuralucb-forget": run_neuralucb_device(
                denv, cfg, scenario=name, forgetting=fcfg, **kw),
            "greedy": run_baseline_device(denv, greedy_policy(denv.K),
                                          seed=args.seed, scenario=name),
            "random": run_baseline_device(denv, random_policy(denv.K),
                                          seed=args.seed, scenario=name),
        }
        summ = summarize(results, skip_first=True)
        header = (f"{'policy':<18}{'avg_reward':>11}{'oracle':>9}"
                  f"{'dyn_regret':>11}{'avg_cost':>10}")
        print(f"\nscenario: {name}  (forgetting: gamma={args.gamma} "
              f"window={args.window} rho={args.replay_rho})")
        print(header)
        print("-" * len(header))
        for pol, s in summ.items():
            print(f"{pol:<18}{s['avg_reward']:>11.4f}"
                  f"{s['oracle_avg_reward']:>9.4f}"
                  f"{s['dynamic_regret']:>11.4f}{s['avg_cost']:>10.4f}")
        out[name] = {
            "summary": summ,
            "per_slice": {k: {kk: vv for kk, vv in v.items()
                              if kk not in ("action_hist",)}
                          for k, v in results.items()},
        }
        ok = ok and all(np.isfinite(s["avg_reward"])
                        for s in summ.values())
    return out, ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-samples", type=int, default=36_497)
    ap.add_argument("--n-slices", type=int, default=20)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--random-seeds", type=int, default=5,
                    help="seeds for the random-baseline sweep (vmap)")
    ap.add_argument("--cost-lambda", type=float, default=1.0)
    ap.add_argument("--sweep-seeds", type=int, default=0,
                    help="NeuralUCB sweep seeds; 0 disables the sweep")
    ap.add_argument("--betas", type=float, nargs="+", default=[1.0],
                    help="beta grid for the NeuralUCB sweep (Fig. 2-4)")
    ap.add_argument("--tau-gs", type=float, nargs="+", default=[0.5])
    ap.add_argument("--cost-lambdas", type=float, nargs="+", default=None,
                    help="cost_lambda grid; negative = env's own table")
    ap.add_argument("--train-steps", type=int, default=None,
                    help="fixed per-slice SGD budget for the scanned "
                         "runner (default: derived from --epochs)")
    ap.add_argument("--sweep-only", action="store_true",
                    help="skip the single-run summary table (CI smoke)")
    ap.add_argument("--scenario", nargs="+", default=None,
                    help="non-stationary scenario names (DESIGN.md §9); "
                         "each runs NeuralUCB (vanilla + forgetting) and "
                         "baselines over the drifting stream")
    ap.add_argument("--scenario-only", action="store_true",
                    help="run only the --scenario suite (CI smoke)")
    ap.add_argument("--policies", nargs="+", default=None,
                    help="registered policy-zoo names (DESIGN.md §10) for "
                         "the exploration-strategy comparison, e.g. "
                         "neuralucb linucb neural_ts eps_greedy; runs "
                         "(policy x seed) per scenario as one dispatch")
    ap.add_argument("--policies-only", action="store_true",
                    help="run only the --policies comparison (CI smoke)")
    ap.add_argument("--gamma", type=float, default=1.0,
                    help="A^-1 rebuild discount for the forgetting "
                         "variant (1.0 = off)")
    ap.add_argument("--window", type=int, default=0,
                    help="A^-1 sliding window in slices (0 = off)")
    ap.add_argument("--replay-rho", type=float, default=0.4,
                    help="recency weight for replay sampling "
                         "(1.0 = uniform)")
    ap.add_argument("--out", default="paper_experiments.json")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    henv = RouterBenchSim(seed=args.seed, n_samples=args.n_samples,
                          n_slices=args.n_slices,
                          cost_lambda=args.cost_lambda)
    denv = DeviceReplayEnv.from_host(henv)
    cfg = UtilityNetConfig(emb_dim=henv.x_emb.shape[1], num_actions=henv.K)

    out = {"config": vars(args)}
    ok = True
    if not args.sweep_only and not args.scenario_only \
            and not args.policies_only:
        table, ok_t = run_summary_table(henv, denv, cfg, args)
        out.update(table)
        ok = ok and ok_t
    if args.sweep_seeds > 0 and not args.policies_only:
        sweep_out, ok_s = run_figure_sweep(denv, cfg, args)
        out["sweep"] = sweep_out
        ok = ok and ok_s
    elif args.sweep_only:
        print("--sweep-only given but --sweep-seeds is 0; nothing to do",
              file=sys.stderr)
        ok = False
    if args.scenario and not args.policies_only:
        scen_out, ok_n = run_scenario_suite(denv, cfg, args)
        out["scenarios"] = scen_out
        ok = ok and ok_n
    elif args.scenario_only:
        print("--scenario-only given but no --scenario names",
              file=sys.stderr)
        ok = False
    if args.policies:
        zoo_out, ok_z = run_policy_comparison(denv, cfg, args)
        out["policy_zoo"] = zoo_out
        ok = ok and ok_z
    elif args.policies_only:
        print("--policies-only given but no --policies names",
              file=sys.stderr)
        ok = False

    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"\nwrote {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
