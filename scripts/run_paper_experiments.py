"""Paper experiment driver — a thin CLI over the declarative
ExperimentSpec API (``repro.experiments``, DESIGN.md §11).

Preset mode (the canonical interface — one spec, one artifact):

  PYTHONPATH=src python scripts/run_paper_experiments.py --list-presets
  PYTHONPATH=src python scripts/run_paper_experiments.py \
      --preset paper_table1                                # Table 1
  PYTHONPATH=src python scripts/run_paper_experiments.py \
      --preset fig2_beta_sweep                             # Fig. 2-4
  PYTHONPATH=src python scripts/run_paper_experiments.py \
      --preset scenario_suite --set seeds=0,1,2            # §9
  PYTHONPATH=src python scripts/run_paper_experiments.py \
      --preset ci_smoke                                    # CI, one call
  PYTHONPATH=src python scripts/run_paper_experiments.py \
      --preset policy_zoo \
      --set scenarios=stationary,price_shock,arm_outage    # §10

``--set key=value`` overrides address the spec's JSON form with dotted
paths (``data.n_samples=1500``, ``seeds=0,1``,
``policies.neuralucb.axes.beta=0.25,0.5,1.0``); unknown paths and
invalid values error loudly.

The pre-PR-5 flags are kept and MAPPED onto the same specs (e.g.
``--sweep-seeds 5 --betas 0.25 0.5 1.0 2.0`` builds the
``fig2_beta_sweep`` spec), so old invocations keep working — but every
run, flag-built or preset-built, compiles through
``repro.experiments.compile_spec`` into the minimal set of
single-dispatch ``run_policy_sweep`` calls and writes the
schema-versioned artifact (``--out``, default
``paper_experiments.json``).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

from repro.experiments import (
    DataSpec,
    ExperimentResult,
    ExperimentSpec,
    ForgettingSpec,
    PolicySpec,
    TrainSpec,
    build_env,
    compile_spec,
    format_cells,
    make_preset,
    parse_override_value,
    preset_table,
    run_plan,
)

# Legacy flags that SELECT work; meaningless (and silently ignored
# before PR-5) next to --preset, so their presence there is an error.
# They all parse with default=None (explicitly passing a flag at its
# old default value must still be DETECTED, not silently shadowed by
# the preset); the legacy branch fills in the pre-PR-5 defaults.
_LEGACY_SELECTORS = ("sweep_seeds", "betas", "tau_gs", "cost_lambdas",
                     "scenario", "policies", "sweep_only",
                     "scenario_only", "policies_only", "gamma", "window",
                     "replay_rho", "random_seeds", "train_steps",
                     "epochs", "n_samples", "n_slices", "seed",
                     "cost_lambda")

_LEGACY_DEFAULTS = {"n_samples": 36_497, "n_slices": 20, "epochs": 5,
                    "seed": 0, "cost_lambda": 1.0, "sweep_seeds": 0,
                    "betas": [1.0], "tau_gs": [0.5]}


def _parse_sets(ap: argparse.ArgumentParser,
                pairs: List[str]) -> Dict[str, object]:
    out = {}
    for pair in pairs:
        if "=" not in pair:
            ap.error(f"--set takes KEY=VALUE, got {pair!r}")
        key, _, val = pair.partition("=")
        out[key.strip()] = parse_override_value(val.strip())
    return out


def _data_overrides(args) -> Dict[str, object]:
    return {"data.seed": args.seed, "data.n_samples": args.n_samples,
            "data.n_slices": args.n_slices,
            "data.cost_lambda": args.cost_lambda}


def _train(args, batch_size: int = 256) -> TrainSpec:
    return TrainSpec(epochs=args.epochs, train_steps=args.train_steps,
                     batch_size=batch_size)


def _legacy_specs(ap: argparse.ArgumentParser,
                  args) -> List[Tuple[str, ExperimentSpec]]:
    """Map the pre-PR-5 flag surface onto specs — the compat layer.
    Invalid flag combinations (the ones the old driver silently
    ignored) error here."""
    # --gamma/--window/--replay-rho only feed the scenario suite's
    # forgetting variant; before PR-5 they were SILENTLY ignored
    # without --scenario (a sweep "with forgetting" quietly ran vanilla)
    forget_flags = [n for n, v in (("--gamma", args.gamma),
                                   ("--window", args.window),
                                   ("--replay-rho", args.replay_rho))
                    if v is not None]
    if forget_flags and not args.scenario:
        ap.error(f"{'/'.join(forget_flags)}: these flags configure the "
                 f"forgetting variant of the --scenario suite and have "
                 f"no effect without it; pass --scenario NAME... or "
                 f"drop them")
    if args.sweep_only and args.sweep_seeds <= 0:
        ap.error("--sweep-only requires --sweep-seeds > 0")
    if args.scenario_only and not args.scenario:
        ap.error("--scenario-only requires --scenario NAME...")
    if args.policies_only and not args.policies:
        ap.error("--policies-only requires --policies NAME...")
    if args.random_seeds is not None:
        print("note: --random-seeds is folded into the unified spec's "
              "seed axis; use --set seeds=0,1,... with --preset "
              "paper_table1 for a multi-seed table", file=sys.stderr)

    data = DataSpec(seed=args.seed, n_samples=args.n_samples,
                    n_slices=args.n_slices,
                    cost_lambda=args.cost_lambda)
    fg = ForgettingSpec(gamma=1.0 if args.gamma is None else args.gamma,
                        window=0 if args.window is None else args.window,
                        replay_rho=(0.4 if args.replay_rho is None
                                    else args.replay_rho))
    specs: List[Tuple[str, ExperimentSpec]] = []
    only = args.sweep_only or args.scenario_only or args.policies_only
    if not only:
        specs.append(("summary", make_preset(
            "paper_table1",
            {**_data_overrides(args), "seeds": [args.seed],
             "train.epochs": args.epochs,
             "train.train_steps": args.train_steps})))
    if args.sweep_seeds > 0 and not args.policies_only \
            and not args.scenario_only:
        lambdas = tuple(None if l < 0 else l
                        for l in (args.cost_lambdas or [-1.0]))
        specs.append(("sweep", ExperimentSpec(
            name="fig2_beta_sweep", data=data,
            policies=(PolicySpec("neuralucb", axes=(
                ("beta", tuple(args.betas)),
                ("tau_g", tuple(args.tau_gs)),
                ("cost_lambda", lambdas))),),
            seeds=tuple(range(args.sweep_seeds)),
            train=_train(args))))
    if args.scenario and not args.policies_only:
        specs.append(("scenarios", ExperimentSpec(
            name="scenario_suite", data=data,
            policies=(PolicySpec("neuralucb"),
                      PolicySpec("neuralucb", name="neuralucb-forget",
                                 forgetting=fg),
                      PolicySpec("greedy"), PolicySpec("random")),
            scenarios=tuple(args.scenario),
            seeds=(args.seed,), train=_train(args))))
    if args.policies:
        specs.append(("policy_zoo", ExperimentSpec(
            name="policy_zoo", data=data,
            policies=tuple(PolicySpec(p) for p in args.policies),
            scenarios=(tuple(args.scenario) if args.scenario
                       else (None,)),
            seeds=tuple(range(max(1, args.sweep_seeds))),
            train=_train(args))))
    return specs


def _print_result(section: str, result: ExperimentResult,
                  oracle: Optional[float]) -> None:
    spec = result.spec
    m = result.manifest
    print(f"\n== {section} ({spec.name}: {len(spec.seeds)} seed"
          f"{'s' if len(spec.seeds) != 1 else ''}, "
          f"{m['n_dispatches']} dispatch"
          f"{'es' if m['n_dispatches'] != 1 else ''}, "
          f"{m['wall_s']:.1f}s) ==")
    for scen in result.scenario_names():
        if len(result.scenario_names()) > 1:
            print(f"\n-- scenario: {scen} --")
        print(format_cells(result.cells_for(scen)))
    if oracle is not None:
        print(f"{'oracle (ref)':<18}{'':>9}{oracle:>16.4f}")
    pt = m.get("pretrain")
    if pt:
        hits = sum(v["cache_hit"] for v in pt["labels"].values())
        secs = sum(v["pretrain_s"] for v in pt["labels"].values())
        print(f"\npretrain: {len(pt['labels'])} warm label"
              f"{'s' if len(pt['labels']) != 1 else ''} on a "
              f"{pt['corpus_size']}-row {pt['behavior']!r} corpus "
              f"({hits} cache hit{'s' if hits != 1 else ''}, "
              f"{secs:.1f}s)")
    ope = m.get("ope")
    if ope:
        print(f"\nope: {len(ope['targets'])} targets scored from one "
              f"{ope['behavior']!r} log (n={ope['n']}) -> parity "
              f"{'ok' if ope['parity_ok'] else 'FAIL'}")
        for c in result.cells_for("offline"):
            e = c["ope"]
            pin = ""
            if "ope_ok" in c:
                pin = (f"  vs on-policy {c['onpolicy_value']:.4f} "
                       f"[{'ok' if c['ope_ok'] else 'FAIL'}]")
            print(f"  {c['policy']:<18} dr={e['dr']:.4f} "
                  f"snips={e['snips']:.4f} ips={e['ips']:.4f} "
                  f"ess={e['ess']:.0f}{pin}")


def _table_checks(result: ExperimentResult) -> bool:
    """The old summary-table acceptance: NeuralUCB beats random and is
    within 10% of the max-quality arm's reward."""
    try:
        nucb = result.cell("neuralucb")
        rand = result.cell("random")
        maxq = result.cell("max_quality")
    except KeyError:
        return result.ok
    return (result.ok
            and nucb["avg_reward_mean"] > rand["avg_reward_mean"]
            and nucb["avg_reward_mean"]
            > maxq["avg_reward_mean"] * 0.9)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--preset", default=None,
                    help="registered ExperimentSpec preset "
                         "(--list-presets shows all)")
    ap.add_argument("--set", action="append", default=[],
                    metavar="KEY=VALUE", dest="sets",
                    help="dotted-path spec override, e.g. "
                         "data.n_samples=1500 or "
                         "policies.neuralucb.axes.beta=0.5,1.0")
    ap.add_argument("--list-presets", action="store_true")
    # ---- legacy flags (mapped onto specs; defaults resolved late) ----
    ap.add_argument("--n-samples", type=int, default=None)
    ap.add_argument("--n-slices", type=int, default=None)
    ap.add_argument("--epochs", type=int, default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--random-seeds", type=int, default=None,
                    help="deprecated: folded into the spec seed axis")
    ap.add_argument("--cost-lambda", type=float, default=None)
    ap.add_argument("--sweep-seeds", type=int, default=None,
                    help="NeuralUCB sweep seeds; 0 disables the sweep")
    ap.add_argument("--betas", type=float, nargs="+", default=None)
    ap.add_argument("--tau-gs", type=float, nargs="+", default=None)
    ap.add_argument("--cost-lambdas", type=float, nargs="+", default=None,
                    help="cost_lambda grid; negative = env's own table")
    ap.add_argument("--train-steps", type=int, default=None)
    ap.add_argument("--sweep-only", action="store_true")
    ap.add_argument("--scenario", nargs="+", default=None)
    ap.add_argument("--scenario-only", action="store_true")
    ap.add_argument("--policies", nargs="+", default=None)
    ap.add_argument("--policies-only", action="store_true")
    ap.add_argument("--gamma", type=float, default=None,
                    help="A^-1 rebuild discount for the scenario "
                         "suite's forgetting variant (requires "
                         "--scenario)")
    ap.add_argument("--window", type=int, default=None,
                    help="A^-1 sliding window in slices (requires "
                         "--scenario)")
    ap.add_argument("--replay-rho", type=float, default=None,
                    help="recency weight for replay sampling (requires "
                         "--scenario; suite default 0.4)")
    ap.add_argument("--out", default="paper_experiments.json")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    if args.list_presets:
        for name, desc in preset_table():
            print(f"{name:<18} {desc}")
        return 0

    if args.preset is not None:
        # --preset takes its configuration from --set alone; a legacy
        # flag next to it would be silently shadowed by the spec (all
        # legacy flags parse with default=None / False, so even one
        # passed at its old default value is detected here)
        stray = [n for n in _LEGACY_SELECTORS
                 if getattr(args, n) not in (None, False)]
        if stray:
            flags = ", ".join("--" + n.replace("_", "-") for n in stray)
            ap.error(f"{flags} cannot be combined with --preset; use "
                     f"--set key=value overrides instead")
        try:
            spec = make_preset(args.preset, _parse_sets(ap, args.sets))
        except (KeyError, ValueError) as e:
            ap.error(str(e))
        sections = [(args.preset, spec)]
    else:
        if args.sets:
            ap.error("--set requires --preset")
        for name, default in _LEGACY_DEFAULTS.items():
            if getattr(args, name) is None:
                setattr(args, name, default)
        sections = _legacy_specs(ap, args)
        if not sections:
            ap.error("nothing to run")

    out: Dict[str, object] = {}
    ok = True
    # legacy multi-section runs share one DataSpec — build the replay
    # env once and inject it into every section's compile
    shared_data = shared_henv = shared_denv = None
    for section, spec in sections:
        try:
            if spec.armpool is not None:
                # a physical pool compiles its own env from the pool
                # tables — never the shared replay env
                plan = compile_spec(spec)
            else:
                if spec.data != shared_data:
                    shared_henv, shared_denv = build_env(spec.data)
                    shared_data = spec.data
                plan = compile_spec(spec, env=shared_denv,
                                    host_env=shared_henv)
        except ValueError as e:
            ap.error(str(e))
        result = run_plan(plan, verbose=not args.quiet)
        oracle = None
        if plan.host_env is not None and spec.scenarios == (None,):
            oracle = float(plan.host_env.reward_table.max(axis=1).mean())
            result.manifest["oracle_reward"] = oracle
        if not args.quiet:
            _print_result(section, result, oracle)
        if spec.name == "paper_table1":
            ok = ok and _table_checks(result)
            try:
                frac = (result.cell("neuralucb")["avg_cost_mean"]
                        / result.cell("max_quality")["avg_cost_mean"])
                result.manifest[
                    "neuralucb_cost_fraction_of_max_quality"] = frac
                if not args.quiet:
                    print(f"\nneuralucb cost = {100 * frac:.1f}% of "
                          f"max-quality-arm (paper: ~33%)")
            except (KeyError, ZeroDivisionError):
                pass
        else:
            ok = ok and result.ok
        out[section] = result.to_json()

    doc = next(iter(out.values())) if len(out) == 1 else out
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, default=float)
    print(f"\nwrote {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
