#!/usr/bin/env python
"""2-process ``jax.distributed`` CPU sweep smoke (DESIGN.md §15.3).

Driver mode (default): spawn 2 worker processes that form a
``jax.distributed`` cluster on localhost (1 forced host CPU device
each), each running its :func:`repro.distributed.run_sweep_multihost`
slice of a LinUCB hyper-grid sweep and dumping its artifact to JSON.
The driver then runs the SAME sweep single-process through the plain
`run_policy_sweep` engine and asserts:

* the two workers' grid spans partition the grid exactly;
* every worker lane is BIT-identical to the corresponding lane of the
  single-process reference (lane-parity: a sweep lane's trajectory must
  not depend on which host computed it);
* both workers emit byte-identical layout manifests recording the
  2-host global topology (host-invariant manifests).

Execution is process-local by design — sweep lanes are independent, and
the CPU backend cannot run cross-process programs anyway — so this
smoke pins exactly the contract multi-host sweeps rely on.

Worker mode (internal): ``--worker P --nproc N --port PORT --out F``.

Exit status 0 = parity holds (the CI gate).
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")

SEEDS = range(3)
ALPHAS = (0.5, 1.5)     # the 2-point grid split across the 2 workers
COMPARE = ("avg_reward", "avg_cost", "action_hist")


def _zoo(env):
    import jax.numpy as jnp

    from repro.sim import make_policy
    from repro.sim.policies import LinUCBHypers

    pol, _ = make_policy("linucb", env)
    hyp = LinUCBHypers(alpha=jnp.asarray(ALPHAS, jnp.float32),
                       ridge=jnp.ones(len(ALPHAS), jnp.float32))
    return {"linucb": (pol, hyp)}


def _env():
    from repro.data.routerbench import RouterBenchSim
    from repro.sim import DeviceReplayEnv

    return DeviceReplayEnv.from_host(
        RouterBenchSim(seed=0, n_samples=600, n_slices=3))


def worker(proc: int, nproc: int, port: int, out_path: str) -> None:
    import jax

    jax.distributed.initialize(
        coordinator_address=f"127.0.0.1:{port}",
        num_processes=nproc, process_id=proc)
    assert jax.process_count() == nproc, jax.process_count()
    from repro.distributed import run_sweep_multihost

    res = run_sweep_multihost(_env(), _zoo(_env()), seeds=SEEDS)["linucb"]
    doc = {k: (res[k].tolist() if k in res else None) for k in COMPARE}
    doc.update(layout=res["layout"], grid_span=res["grid_span"],
               lane_span=res["lane_span"],
               n_grid_total=res["n_grid_total"])
    with open(out_path, "w") as f:
        json.dump(doc, f, sort_keys=True)
    print(f"[worker {proc}] grid_span={res['grid_span']} "
          f"hosts={res['layout']['hosts']}", flush=True)


def driver(tmpdir: str) -> int:
    import numpy as np

    port = _free_port()
    outs = [os.path.join(tmpdir, f"worker{p}.json") for p in range(2)]
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep * bool(env.get("PYTHONPATH")) \
        + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=1"
    procs = [
        subprocess.Popen(
            [sys.executable, os.path.abspath(__file__), "--worker", str(p),
             "--nproc", "2", "--port", str(port), "--out", outs[p]],
            env=env)
        for p in range(2)
    ]
    codes = [p.wait(timeout=600) for p in procs]
    if any(codes):
        print(f"FAIL: worker exit codes {codes}")
        return 1
    docs = [json.load(open(o)) for o in outs]

    # reference: the same sweep, single process, plain engine path
    ref = _reference()

    spans = [tuple(d["grid_span"]) for d in docs]
    assert spans[0][0] == 0 and spans[-1][1] == len(ALPHAS), spans
    assert spans[0][1] == spans[1][0], spans
    assert docs[0]["layout"] == docs[1]["layout"], \
        "layout manifests differ across hosts"
    hosts = docs[0]["layout"]["hosts"]
    assert hosts == {"n_hosts": 2, "devices_per_host": 1}, hosts
    for d in docs:
        gs, ge = d["grid_span"]
        for k in COMPARE:
            got = np.asarray(d[k])
            want = ref[k][gs:ge]
            assert got.shape == want.shape, (k, got.shape, want.shape)
            assert np.array_equal(got, want), \
                f"lane parity broken for {k} in grid span [{gs}, {ge})"
    print("DISTRIBUTED_SWEEP_SMOKE_OK: 2-process lanes bit-identical to "
          "single-process reference; manifests host-invariant")
    return 0


def _reference():
    import numpy as np

    from repro.sim import run_policy_sweep

    res = run_policy_sweep(_env(), _zoo(_env()), seeds=SEEDS)["linucb"]
    return {k: np.asarray(res[k]) for k in COMPARE}


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--worker", type=int, default=None)
    ap.add_argument("--nproc", type=int, default=2)
    ap.add_argument("--port", type=int, default=None)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()
    sys.path.insert(0, SRC)
    if args.worker is not None:
        worker(args.worker, args.nproc, args.port, args.out)
        return 0
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        return driver(td)


if __name__ == "__main__":
    raise SystemExit(main())
