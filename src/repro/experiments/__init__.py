"""Declarative ExperimentSpec API (DESIGN.md §11): one typed spec →
one compile → one run → one schema-versioned artifact, shared by the
paper driver, the protocol benchmarks, CI, and the tests.

    spec = make_preset("fig2_beta_sweep")        # or ExperimentSpec(...)
    plan = compile_spec(spec)                    # registry resolution +
                                                 # minimal dispatch grouping
    result = run_plan(plan)                      # ONE run_policy_sweep
                                                 # dispatch per plan call
    result.save("fig2.json")                     # manifest + cells
"""
from repro.experiments.compiler import (
    ExperimentPlan,
    SweepCall,
    build_env,
    compile_spec,
)
from repro.experiments.presets import (
    PRESETS,
    make_preset,
    preset_table,
    register_preset,
)
from repro.experiments.runner import (
    RESULT_SCHEMA_VERSION,
    ExperimentResult,
    format_cells,
    run_plan,
    run_spec,
)
from repro.experiments.ope import score_policies_offline
from repro.experiments.pretrain import build_corpus, pretrained_states
from repro.experiments.spec import (
    SPEC_SCHEMA_VERSION,
    ArmPoolSpec,
    DataSpec,
    ExperimentSpec,
    ForgettingSpec,
    OPESpec,
    PolicySpec,
    PretrainSpec,
    ServingSpec,
    SummarizeSpec,
    TrainSpec,
    apply_overrides,
    parse_override_value,
    spec_from_json,
    spec_hash,
    spec_to_json,
)

# the ISSUE's verb names, kept as aliases of the explicit ones
compile = compile_spec  # noqa: A001  (deliberate: experiments.compile(spec))
run = run_plan

__all__ = [
    "SPEC_SCHEMA_VERSION",
    "RESULT_SCHEMA_VERSION",
    "ArmPoolSpec",
    "DataSpec",
    "ExperimentSpec",
    "ExperimentPlan",
    "ExperimentResult",
    "ForgettingSpec",
    "OPESpec",
    "PolicySpec",
    "PretrainSpec",
    "ServingSpec",
    "SummarizeSpec",
    "SweepCall",
    "TrainSpec",
    "PRESETS",
    "apply_overrides",
    "build_corpus",
    "build_env",
    "pretrained_states",
    "score_policies_offline",
    "compile",
    "compile_spec",
    "format_cells",
    "make_preset",
    "parse_override_value",
    "preset_table",
    "register_preset",
    "run",
    "run_plan",
    "run_spec",
    "spec_from_json",
    "spec_hash",
    "spec_to_json",
]
