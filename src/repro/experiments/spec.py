"""Declarative experiment specification (DESIGN.md §11).

An :class:`ExperimentSpec` is a frozen, JSON-round-trippable description
of a full (policy × scenario × hyper × seed) study: the replay data
source, the policy list (each with optional hyper-grid axes, builder
overrides, and a per-entry forgetting variant), the scenario list, the
seed list, the train schedule, and the summarize options. It is the ONE
input every consumer shares — the paper driver
(``scripts/run_paper_experiments.py``), the protocol benchmarks, CI
smokes, and the parity tests all express their runs as specs, so a new
scenario / policy / grid axis is a spec edit, not four parallel script
edits.

The spec layer is deliberately dumb: no registry lookups, no jax — just
typed fields, cheap invariant checks, and a strict JSON codec
(:func:`spec_to_json` / :func:`spec_from_json`; unknown keys are
rejected, round-trips are identity). Registry resolution and grouping
into device dispatches live in :mod:`repro.experiments.compiler`.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, Optional, Tuple

SPEC_SCHEMA_VERSION = "experiment-spec-v1"


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """RouterBench-surrogate replay source (DESIGN.md §5)."""

    seed: int = 0
    n_samples: int = 36_497
    n_slices: int = 20
    cost_lambda: float = 1.0

    def __post_init__(self):
        if self.n_samples <= 0 or self.n_slices <= 0:
            raise ValueError("DataSpec: n_samples and n_slices must be "
                             f"positive, got {self.n_samples}/"
                             f"{self.n_slices}")


@dataclasses.dataclass(frozen=True)
class ForgettingSpec:
    """Adaptivity knobs (DESIGN.md §9.2) as a JSON-friendly spec; maps
    onto :class:`repro.sim.policies.ForgettingConfig` at compile time."""

    gamma: float = 1.0
    window: int = 0
    replay_rho: float = 1.0

    def __post_init__(self):
        if not 0.0 < self.gamma <= 1.0:
            raise ValueError(f"ForgettingSpec: gamma must be in (0, 1], "
                             f"got {self.gamma}")
        if self.window < 0:
            raise ValueError(f"ForgettingSpec: window must be >= 0, "
                             f"got {self.window}")
        if not 0.0 < self.replay_rho <= 1.0:
            raise ValueError(f"ForgettingSpec: replay_rho must be in "
                             f"(0, 1], got {self.replay_rho}")

    def to_config(self):
        from repro.sim.policies import ForgettingConfig
        return ForgettingConfig(gamma=float(self.gamma),
                                window=int(self.window),
                                replay_rho=float(self.replay_rho))


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    """Per-slice replay-SGD schedule for policies with a train hook.
    ``train_steps=None`` derives the fixed per-slice budget from
    ``epochs`` (``repro.sim.neuralucb_train_schedule``).
    ``precision`` selects the forward/backward compute dtype of the
    train path ("f32" | "bf16"); losses, gradients, and optimizer state
    stay f32 either way (DESIGN.md §14.2)."""

    epochs: int = 5
    train_steps: Optional[int] = None
    batch_size: int = 256
    precision: str = "f32"

    def __post_init__(self):
        if self.epochs <= 0 or self.batch_size <= 0:
            raise ValueError("TrainSpec: epochs and batch_size must be "
                             "positive")
        if self.train_steps is not None and self.train_steps <= 0:
            raise ValueError("TrainSpec: train_steps must be positive "
                             "or None")
        if self.precision not in ("f32", "bf16"):
            raise ValueError(f"TrainSpec: precision must be 'f32' or "
                             f"'bf16', got {self.precision!r}")


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """One policy-zoo entry.

    * ``policy`` — registry name (``repro.sim.POLICIES``).
    * ``name`` — display label (defaults to ``policy``); must be unique
      within a spec so forgetting variants of the same policy can
      coexist (``neuralucb`` / ``neuralucb-forget``).
    * ``axes`` — hyper-grid axes as ``((field, (v0, v1, ...)), ...)``;
      the grid is the cartesian product in the given axis order, and
      each field must exist in the policy's hypers pytree. ``None`` is
      accepted only for ``cost_lambda`` (the "env's own reward table"
      sentinel).
    * ``overrides`` — scalar builder-kwarg overrides, e.g.
      ``(("explore", 0.2),)``.
    * ``forgetting`` — per-entry adaptivity variant; ``None`` inherits
      the spec-level default.
    """

    policy: str
    name: Optional[str] = None
    axes: Tuple[Tuple[str, Tuple[Any, ...]], ...] = ()
    overrides: Tuple[Tuple[str, Any], ...] = ()
    forgetting: Optional[ForgettingSpec] = None

    def __post_init__(self):
        seen = set()
        for field, values in self.axes:
            if field in seen:
                raise ValueError(f"PolicySpec({self.label}): duplicate "
                                 f"axis {field!r}")
            seen.add(field)
            if not values:
                raise ValueError(f"PolicySpec({self.label}): axis "
                                 f"{field!r} has no values")
            if any(v is None for v in values) and field != "cost_lambda":
                raise ValueError(f"PolicySpec({self.label}): axis "
                                 f"{field!r} has a null value (only "
                                 f"cost_lambda accepts the null "
                                 f"sentinel)")

    @property
    def label(self) -> str:
        return self.name or self.policy


@dataclasses.dataclass(frozen=True)
class ServingSpec:
    """Serving-storm study (DESIGN.md §12): drive the spec's single
    policy through the async serving engine under a traffic pattern and
    scripted faults instead of the batch protocol sweep.

    * ``requests`` / ``waves`` — total request budget, shaped into
      arrival waves by ``pattern`` (``repro.serving.TRAFFIC_PATTERNS``).
    * ``outages`` — announced ``(arm, start_wave, end_wave)`` windows.
    * ``fail_decide_calls`` — decide-call indices whose router call is
      forced to raise (the engine must degrade, not crash).
    * ``train_every`` — run the router's train hook every that many
      waves (0 = never).
    * Gates: ``require_zero_lost`` (accounting invariant),
      ``p99_decide_ms`` (None = unbounded), ``max_shed_fraction``
      (shed / submitted ceiling). They decide the cell's ``serving_ok``
      flag and hence ``ExperimentResult.ok`` — the CI exit status.
    """

    requests: int = 20_000
    waves: int = 40
    pattern: str = "flash_crowd"
    decide_batch: int = 256
    serve_batch: int = 256
    queue_capacity: int = 4096
    outages: Tuple[Tuple[int, int, int], ...] = ()
    fail_decide_calls: Tuple[int, ...] = ()
    train_every: int = 0
    max_train_lag: int = 0
    p99_decide_ms: Optional[float] = None
    max_shed_fraction: float = 1.0
    require_zero_lost: bool = True
    seed: int = 0

    def __post_init__(self):
        if self.waves <= 0 or self.requests < self.waves:
            raise ValueError(f"ServingSpec: need requests >= waves >= 1, "
                             f"got {self.requests}/{self.waves}")
        if self.decide_batch <= 0 or self.serve_batch <= 0 \
                or self.queue_capacity <= 0:
            raise ValueError("ServingSpec: decide_batch, serve_batch and "
                             "queue_capacity must be positive")
        for o in self.outages:
            if len(o) != 3:
                raise ValueError(f"ServingSpec: outage {o!r} is not "
                                 f"(arm, start_wave, end_wave)")
            arm, s, e = o
            if arm < 0 or s < 0 or not s < e:
                raise ValueError(f"ServingSpec: bad outage window {o!r} "
                                 f"(need arm >= 0, 0 <= start < end)")
        if self.train_every < 0:
            raise ValueError("ServingSpec: train_every must be >= 0")
        if self.max_train_lag < 0:
            raise ValueError("ServingSpec: max_train_lag must be >= 0 "
                             "(0 = synchronous end-of-slice train)")
        if self.p99_decide_ms is not None and self.p99_decide_ms <= 0:
            raise ValueError("ServingSpec: p99_decide_ms must be "
                             "positive or None")
        if not 0.0 <= self.max_shed_fraction <= 1.0:
            raise ValueError("ServingSpec: max_shed_fraction must be in "
                             "[0, 1]")


@dataclasses.dataclass(frozen=True)
class ArmPoolSpec:
    """Physical arm pool (DESIGN.md §16): each arm is a real
    ``ModelConfig`` from ``repro.configs`` with cost/latency derived
    from the roofline model on ``hardware``; quality comes from the
    RouterBench tables via the explicit ``mapping`` (defaulting to
    ``repro.armpool.DEFAULT_RB_MAPPING``).

    * ``arms`` — pool members (registry arch ids or their dashed
      aliases); duplicates and unknown names raise at compile.
    * ``mapping`` — ``(arm, routerbench_model)`` overrides; every arm
      must resolve to a table column (no positional pairing).
    * ``decode_batch`` / ``context`` — the serving operating point the
      roofline is evaluated at (a "price shock" is a re-derivation at a
      different point or target).
    * ``cost_source`` — ``"roofline"`` ($/token from chip-seconds) or
      ``"routerbench"`` (mapped replay columns as-is; the parity leg).
    * ``calibrate`` — fold the measured/analytic decode-step ratio into
      the tables for arms up to ``calibrate_max_params`` (times real
      jitted decode steps; keep off in CI).
    * Serving: arms up to ``serve_real_max_params`` execute REAL jitted
      decode steps in the storm (``reduced_decode`` uses the config's
      CPU-runnable reduced variant); larger arms sleep their roofline
      step time scaled by ``latency_scale``; ``max_new`` tokens are
      generated per request.
    """

    arms: Tuple[str, ...] = ()
    hardware: str = "tpu-v5e"
    mapping: Tuple[Tuple[str, str], ...] = ()
    decode_batch: int = 8
    context: int = 2048
    cost_source: str = "roofline"
    calibrate: bool = False
    calibrate_max_params: int = 2_000_000_000
    serve_real_max_params: int = 200_000_000
    reduced_decode: bool = True
    latency_scale: float = 1.0
    max_new: int = 4

    def __post_init__(self):
        if not self.arms:
            raise ValueError("ArmPoolSpec: no arms (list at least one "
                             "repro.configs arch id)")
        if self.decode_batch <= 0 or self.context <= 0:
            raise ValueError("ArmPoolSpec: decode_batch and context "
                             "must be positive")
        if self.cost_source not in ("roofline", "routerbench"):
            raise ValueError(f"ArmPoolSpec: cost_source must be "
                             f"'roofline' or 'routerbench', got "
                             f"{self.cost_source!r}")
        if self.latency_scale < 0:
            raise ValueError("ArmPoolSpec: latency_scale must be >= 0")
        if self.max_new <= 0:
            raise ValueError("ArmPoolSpec: max_new must be positive")
        for pair in self.mapping:
            if len(pair) != 2:
                raise ValueError(f"ArmPoolSpec: mapping entry {pair!r} "
                                 f"is not (arm, routerbench_model)")
        keys = [a for a, _ in self.mapping]
        if len(set(keys)) != len(keys):
            dup = sorted({k for k in keys if keys.count(k) > 1})
            raise ValueError(f"ArmPoolSpec: duplicate mapping keys "
                             f"{dup}")


@dataclasses.dataclass(frozen=True)
class PretrainSpec:
    """Offline pretraining phase (DESIGN.md §13.3): build a logged
    corpus, run every hooked policy's ``pretrain`` on it, and inject the
    resulting states into the online sweep as warm starts.

    * ``corpus_size`` — logged rows. ``behavior="random"`` uses the
      exact-propensity RouterBench replay generator
      (``repro.data.replay_corpus``); any other value must be a policy
      REGISTRY name, which is run online once (``record_log=True``) and
      subsampled to ``corpus_size``.
    * ``steps`` / ``batch_size`` — offline SGD budget (the ridge folds
      consume the whole corpus regardless).
    * ``warm_start`` — sweepable axis: for each value every hooked
      policy entry is expanded into a warm (pretrained state injected,
      no slice-0 uniform warm-up) and/or cold variant, labeled
      ``<name>:warm`` / ``<name>:cold`` when both are present.
    * ``cache`` — checkpoint pretrained states keyed by the spec hash
      (``training/checkpoint.py``) so repeated CI/bench runs skip the
      offline phase.
    """

    corpus_size: int = 20_000
    behavior: str = "random"
    steps: int = 512
    batch_size: int = 256
    warm_start: Tuple[bool, ...] = (True,)
    seed: int = 0
    cache: bool = True

    def __post_init__(self):
        if self.corpus_size <= 0 or self.steps <= 0 or self.batch_size <= 0:
            raise ValueError("PretrainSpec: corpus_size, steps and "
                             "batch_size must be positive")
        if not self.warm_start:
            raise ValueError("PretrainSpec: warm_start needs at least one "
                             "value (True and/or False)")
        ws = [bool(w) for w in self.warm_start]
        if len(set(ws)) != len(ws):
            raise ValueError(f"PretrainSpec: duplicate warm_start values "
                             f"{tuple(self.warm_start)}")


@dataclasses.dataclass(frozen=True)
class OPESpec:
    """Off-policy evaluation phase (DESIGN.md §13.4): one logged run of
    the ``behavior`` policy scores every ``targets`` policy
    counterfactually via ``repro.core.protocol.estimate_offline`` —
    policies that never ran get IPS / SNIPS / DR value estimates.

    * ``behavior`` — policy REGISTRY name producing the propensity-aware
      log (run online with ``record_log=True``); ``behavior_overrides``
      are its builder kwargs (e.g. a wider ``explore`` for coverage).
    * ``targets`` — registry names to score offline. Pretrainable
      targets are first pretrained ON THE BEHAVIOR LOG (offline policy
      selection); their decided actions are scored as the declared
      ε-smoothed point mass (``repro.sim.OPE_SMOOTHING_EPS``).
    * ``parity`` — subset of targets ALSO run on-policy; each cell's
      ``ope_ok`` gate requires |DR − on-policy value| <= ``parity_tol``
      (the satellite-c sanity pin; keep it to deterministic targets).
    * ``clip`` — importance-weight truncation (None = unclipped).
    """

    targets: Tuple[str, ...] = ()
    behavior: str = "eps_greedy"
    behavior_overrides: Tuple[Tuple[str, Any], ...] = ()
    seed: int = 0
    clip: Optional[float] = None
    parity: Tuple[str, ...] = ()
    parity_tol: float = 0.05

    def __post_init__(self):
        if not self.targets:
            raise ValueError("OPESpec: no targets to score")
        if len(set(self.targets)) != len(self.targets):
            raise ValueError(f"OPESpec: duplicate targets "
                             f"{tuple(self.targets)}")
        extra = set(self.parity) - set(self.targets)
        if extra:
            raise ValueError(f"OPESpec: parity names {sorted(extra)} are "
                             f"not in targets")
        if self.clip is not None and self.clip <= 0:
            raise ValueError("OPESpec: clip must be positive or None")
        if self.parity_tol <= 0:
            raise ValueError("OPESpec: parity_tol must be positive")


@dataclasses.dataclass(frozen=True)
class SummarizeSpec:
    """Artifact shaping: ``skip_first`` excludes the warm-start slice
    (paper §4.2); ``curves`` attaches seed-mean per-slice reward curves
    to each cell; ``per_seed`` attaches the per-seed summary values."""

    skip_first: bool = True
    curves: bool = True
    per_seed: bool = False


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """The one typed input (module docstring). ``scenarios`` entries are
    registry names or ``None`` (stationary fast path)."""

    name: str
    data: DataSpec = DataSpec()
    policies: Tuple[PolicySpec, ...] = (PolicySpec("neuralucb"),)
    scenarios: Tuple[Optional[str], ...] = (None,)
    seeds: Tuple[int, ...] = (0,)
    train: TrainSpec = TrainSpec()
    forgetting: ForgettingSpec = ForgettingSpec()
    ucb_backend: str = "jnp"
    summarize: SummarizeSpec = SummarizeSpec()
    serving: Optional[ServingSpec] = None
    pretrain: Optional[PretrainSpec] = None
    ope: Optional[OPESpec] = None
    armpool: Optional[ArmPoolSpec] = None

    def __post_init__(self):
        if not self.policies:
            raise ValueError("ExperimentSpec: no policies")
        if self.ope is not None and self.serving is not None:
            raise ValueError("ExperimentSpec: off-policy evaluation and "
                             "a serving storm cannot share a spec")
        if self.serving is not None:
            if len(self.policies) != 1 or self.policies[0].axes:
                raise ValueError("ExperimentSpec: a serving storm takes "
                                 "exactly one policy with no grid axes")
            if tuple(self.scenarios) != (None,):
                raise ValueError("ExperimentSpec: serving storms take "
                                 "outage windows (serving.outages), not "
                                 "sim scenarios; use scenarios=(None,)")
        if not self.seeds:
            raise ValueError("ExperimentSpec: no seeds")
        if not self.scenarios:
            raise ValueError("ExperimentSpec: no scenarios (use (None,) "
                             "for the stationary run)")
        labels = [p.label for p in self.policies]
        if len(set(labels)) != len(labels):
            dup = sorted({l for l in labels if labels.count(l) > 1})
            raise ValueError(f"ExperimentSpec: duplicate policy labels "
                             f"{dup}; set PolicySpec.name to "
                             f"disambiguate variants")


# ------------------------------------------------------------ JSON codec --
def _train_to_json(train: TrainSpec) -> Dict[str, Any]:
    tr = dataclasses.asdict(train)
    if tr.get("precision") == "f32":
        # default elided, so pre-mixed-precision specs keep their hashes
        tr.pop("precision")
    return tr


def spec_to_json(spec: ExperimentSpec) -> Dict[str, Any]:
    """Spec -> plain JSON-serializable dict (schema-versioned). Inverse
    of :func:`spec_from_json`: round-trips are identity."""
    j = {
        "schema": SPEC_SCHEMA_VERSION,
        "name": spec.name,
        "data": dataclasses.asdict(spec.data),
        "policies": [
            {
                "policy": p.policy,
                "name": p.name,
                "axes": [[f, list(v)] for f, v in p.axes],
                "overrides": [[k, v] for k, v in p.overrides],
                "forgetting": (None if p.forgetting is None
                               else dataclasses.asdict(p.forgetting)),
            }
            for p in spec.policies
        ],
        "scenarios": list(spec.scenarios),
        "seeds": list(spec.seeds),
        "train": _train_to_json(spec.train),
        "forgetting": dataclasses.asdict(spec.forgetting),
        "ucb_backend": spec.ucb_backend,
        "summarize": dataclasses.asdict(spec.summarize),
    }
    if spec.serving is not None:
        # emitted only when set, so pre-serving specs keep their hashes
        sv = dataclasses.asdict(spec.serving)
        sv["outages"] = [list(o) for o in spec.serving.outages]
        sv["fail_decide_calls"] = list(spec.serving.fail_decide_calls)
        if sv["max_train_lag"] == 0:
            # elide the default so pre-overlap serving specs keep their
            # hashes (same contract as _train_to_json's precision pop)
            sv.pop("max_train_lag")
        j["serving"] = sv
    if spec.pretrain is not None:
        # same emit-only-when-set contract: pre-lifecycle specs keep
        # their hashes
        pt = dataclasses.asdict(spec.pretrain)
        pt["warm_start"] = [bool(w) for w in spec.pretrain.warm_start]
        j["pretrain"] = pt
    if spec.ope is not None:
        op = dataclasses.asdict(spec.ope)
        op["targets"] = list(spec.ope.targets)
        op["parity"] = list(spec.ope.parity)
        op["behavior_overrides"] = [[k, v] for k, v
                                    in spec.ope.behavior_overrides]
        j["ope"] = op
    if spec.armpool is not None:
        # emit-only-when-set: pre-PR-10 specs keep their hashes
        ap = dataclasses.asdict(spec.armpool)
        ap["arms"] = list(spec.armpool.arms)
        ap["mapping"] = [[a, m] for a, m in spec.armpool.mapping]
        j["armpool"] = ap
    return j


def _strict(cls, d: Dict[str, Any]):
    """Construct a spec dataclass rejecting unknown keys — a typo'd
    field in a spec file must fail loudly, not silently run defaults."""
    if not isinstance(d, dict):
        raise ValueError(f"{cls.__name__}: expected an object, got "
                         f"{type(d).__name__}")
    fields = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - fields
    if unknown:
        raise ValueError(f"{cls.__name__}: unknown keys "
                         f"{sorted(unknown)} (known: {sorted(fields)})")
    return cls(**d)


def _policy_from_json(d: Dict[str, Any]) -> PolicySpec:
    d = dict(d)
    known = {"policy", "name", "axes", "overrides", "forgetting"}
    unknown = set(d) - known
    if unknown:
        raise ValueError(f"PolicySpec: unknown keys {sorted(unknown)} "
                         f"(known: {sorted(known)})")
    axes = tuple((f, tuple(v)) for f, v in d.get("axes", ()))
    overrides = tuple((k, v) for k, v in d.get("overrides", ()))
    fg = d.get("forgetting")
    return PolicySpec(
        policy=d["policy"], name=d.get("name"), axes=axes,
        overrides=overrides,
        forgetting=None if fg is None else _strict(ForgettingSpec, fg))


def _serving_from_json(d: Dict[str, Any]) -> ServingSpec:
    d = dict(d)
    known = {f.name for f in dataclasses.fields(ServingSpec)}
    unknown = set(d) - known
    if unknown:
        raise ValueError(f"ServingSpec: unknown keys {sorted(unknown)} "
                         f"(known: {sorted(known)})")
    if "outages" in d:
        d["outages"] = tuple(tuple(int(x) for x in o)
                             for o in d["outages"])
    if "fail_decide_calls" in d:
        d["fail_decide_calls"] = tuple(int(x)
                                       for x in d["fail_decide_calls"])
    return ServingSpec(**d)


def spec_from_json(d: Dict[str, Any]) -> ExperimentSpec:
    """Strict inverse of :func:`spec_to_json`. Unknown keys anywhere in
    the document raise ``ValueError``; an unknown / missing ``schema``
    tag raises too (a future schema must be converted, not guessed at).
    """
    if not isinstance(d, dict):
        raise ValueError("spec_from_json: expected a JSON object")
    d = dict(d)
    schema = d.pop("schema", None)
    if schema != SPEC_SCHEMA_VERSION:
        raise ValueError(f"spec_from_json: schema {schema!r} is not "
                         f"{SPEC_SCHEMA_VERSION!r}")
    known = {"name", "data", "policies", "scenarios", "seeds", "train",
             "forgetting", "ucb_backend", "summarize", "serving",
             "pretrain", "ope", "armpool"}
    unknown = set(d) - known
    if unknown:
        raise ValueError(f"ExperimentSpec: unknown keys "
                         f"{sorted(unknown)} (known: {sorted(known)})")
    if "name" not in d:
        raise ValueError("ExperimentSpec: missing required key 'name'")
    kw: Dict[str, Any] = {"name": d["name"]}
    if "data" in d:
        kw["data"] = _strict(DataSpec, d["data"])
    if "policies" in d:
        if not isinstance(d["policies"], (list, tuple)):
            raise ValueError("ExperimentSpec: 'policies' must be a "
                             "list of policy objects")
        kw["policies"] = tuple(_policy_from_json(p)
                               for p in d["policies"])
    if "scenarios" in d:
        # a bare scalar (e.g. --set scenarios=price_shock) means a
        # one-element list — NOT a string to iterate character-wise
        v = d["scenarios"]
        kw["scenarios"] = tuple(v) if isinstance(v, (list, tuple)) \
            else (v,)
    if "seeds" in d:
        v = d["seeds"]
        if not isinstance(v, (list, tuple)):
            v = [v]
        try:
            kw["seeds"] = tuple(int(s) for s in v)
        except (TypeError, ValueError) as e:
            raise ValueError(f"ExperimentSpec: 'seeds' must be a list "
                             f"of ints, got {d['seeds']!r}") from e
    if "train" in d:
        kw["train"] = _strict(TrainSpec, d["train"])
    if "forgetting" in d:
        kw["forgetting"] = _strict(ForgettingSpec, d["forgetting"])
    if "ucb_backend" in d:
        kw["ucb_backend"] = d["ucb_backend"]
    if "summarize" in d:
        kw["summarize"] = _strict(SummarizeSpec, d["summarize"])
    if "serving" in d and d["serving"] is not None:
        kw["serving"] = _serving_from_json(d["serving"])
    if "pretrain" in d and d["pretrain"] is not None:
        p = dict(d["pretrain"])
        if "warm_start" in p:
            v = p["warm_start"]
            p["warm_start"] = tuple(bool(w) for w in v) \
                if isinstance(v, (list, tuple)) else (bool(v),)
        kw["pretrain"] = _strict(PretrainSpec, p)
    if "ope" in d and d["ope"] is not None:
        o = dict(d["ope"])
        for f in ("targets", "parity"):
            if f in o:
                v = o[f]
                o[f] = tuple(v) if isinstance(v, (list, tuple)) else (v,)
        if "behavior_overrides" in o:
            o["behavior_overrides"] = tuple(
                (k, v) for k, v in o["behavior_overrides"])
        kw["ope"] = _strict(OPESpec, o)
    if "armpool" in d and d["armpool"] is not None:
        a = dict(d["armpool"])
        if "arms" in a:
            v = a["arms"]
            a["arms"] = tuple(v) if isinstance(v, (list, tuple)) \
                else (v,)
        if "mapping" in a:
            a["mapping"] = tuple((arm, m) for arm, m in a["mapping"])
        kw["armpool"] = _strict(ArmPoolSpec, a)
    return ExperimentSpec(**kw)


def spec_hash(spec: ExperimentSpec) -> str:
    """Content hash of the canonical JSON form — the artifact manifest's
    reproducibility key (same spec <=> same hash, field order
    irrelevant)."""
    canon = json.dumps(spec_to_json(spec), sort_keys=True,
                       separators=(",", ":"))
    return hashlib.sha256(canon.encode()).hexdigest()[:16]


# ------------------------------------------------------- ``--set`` paths --
def parse_override_value(text: str) -> Any:
    """Parse one ``--set key=value`` right-hand side: JSON when it
    parses (numbers, null, true/false, quoted strings, [lists]),
    comma-split into a list otherwise, bare string as a fallback."""
    try:
        return json.loads(text)
    except (json.JSONDecodeError, ValueError):
        pass
    if "," in text:
        return [parse_override_value(v) for v in text.split(",")]
    return text


def _set_path(node: Any, parts, value):
    head, rest = parts[0], parts[1:]
    if isinstance(node, list):
        # integer index, or a policy entry matched by its display label
        if head.lstrip("-").isdigit():
            target = node[int(head)]
        else:
            matches = [p for p in node
                       if isinstance(p, dict)
                       and (p.get("name") or p.get("policy")) == head]
            if not matches:
                raise KeyError(f"no policy entry labeled {head!r}")
            target = matches[0]
        if not rest:
            raise KeyError("cannot replace a whole policy entry via "
                           "--set; set its fields instead")
        return _set_path(target, rest, value)
    if not isinstance(node, dict):
        raise KeyError(f"cannot descend into {type(node).__name__} at "
                       f"{head!r}")
    if head == "axes" and rest:
        # axes are [field, values] pairs: address by hyper-field name
        if len(rest) != 1:
            raise KeyError(f"axes paths take exactly one field name, "
                           f"got {'.'.join(rest)!r}")
        field = rest[0]
        vals = value if isinstance(value, list) else [value]
        axes = node.setdefault("axes", [])
        for pair in axes:
            if pair[0] == field:
                pair[1] = vals
                return
        axes.append([field, vals])
        return
    if not rest:
        if head not in node:
            raise KeyError(f"unknown spec key {head!r} (known: "
                           f"{sorted(node)})")
        if head == "policies":
            # policies=<label,...> FILTERS the spec's entries by display
            # label (the CI-shrink idiom); entries can't be built from
            # scalar values, only selected
            labels = value if isinstance(value, list) else [value]
            by_label = {(p.get("name") or p.get("policy")): p
                        for p in node[head]}
            missing = [l for l in labels if l not in by_label]
            if missing:
                raise KeyError(f"no policy entry labeled "
                               f"{missing[0]!r} (have: "
                               f"{sorted(by_label)})")
            node[head] = [by_label[l] for l in labels]
            return
        node[head] = value
        return
    if head not in node:
        raise KeyError(f"unknown spec key {head!r} (known: "
                       f"{sorted(node)})")
    return _set_path(node[head], rest, value)


def apply_overrides(spec: ExperimentSpec,
                    assignments: Dict[str, Any]) -> ExperimentSpec:
    """Apply dotted-path overrides to a spec (the CLI's ``--set``).

    Paths address the JSON form: ``data.n_samples=1500``,
    ``seeds=0,1``, ``train.train_steps=32``,
    ``scenarios=price_shock,arm_outage``,
    ``policies.neuralucb.axes.beta=0.25,0.5,1.0`` (policy entries are
    addressed by display label, axes by hyper-field name). The result
    re-validates through the strict JSON codec, so a typo'd path or an
    invalid value errors loudly."""
    doc = spec_to_json(spec)
    for path, value in assignments.items():
        parts = path.split(".")
        if not parts or parts[0] == "schema":
            raise KeyError(f"cannot set {path!r}")
        try:
            _set_path(doc, parts, value)
        except (KeyError, IndexError) as e:
            raise KeyError(f"--set {path}: {e}") from e
    return spec_from_json(doc)
