"""Off-policy evaluation stage of the learning lifecycle (DESIGN.md
§13.4).

One propensity-aware behavior log, scored against every target policy
of the spec via ``repro.core.protocol.estimate_offline`` (IPS / SNIPS /
DM / DR). Target action distributions reuse the SERVING decide kernel
(``repro.serving.policy_router._srv_decide``) chunked over the logged
contexts at ``t=1`` — the post-warm-up step — so offline scoring runs
the exact routing code the online paths run, not a reimplementation.
Targets with a pretrain hook are first fit offline on the behavior log
(that is the selection story: pick a router from logs alone). For
targets named in ``spec.ope.parity`` the DR estimate is pinned against
an on-policy replay run of the same policy within ``parity_tol`` —
the artifact's ``ope_ok`` gate, wired into ``ExperimentResult.ok``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.protocol import estimate_offline
from repro.data.logged import LoggedInteractions
from repro.experiments.compiler import ExperimentPlan
from repro.sim import make_policy, pretrain_policy_state, run_policy_device
from repro.sim.policies import OPE_SMOOTHING_EPS, _lin_features, _no_pretrain

_CHUNK = 2048


def behavior_log(plan: ExperimentPlan) -> LoggedInteractions:
    """Run the spec's behavior policy over the replay env with
    ``record_log=True`` — the one logged run every target is scored
    from."""
    ope = plan.spec.ope
    pol, hyp = make_policy(ope.behavior, plan.env, plan.cfg,
                           ucb_backend=plan.spec.ucb_backend,
                           **dict(ope.behavior_overrides))
    _, logged = run_policy_device(
        plan.env, pol, hyp, seed=ope.seed, record_log=True,
        train_steps=plan.train_steps, epochs=plan.spec.train.epochs,
        batch_size=plan.spec.train.batch_size)
    return logged


def fit_qhat(logged: LoggedInteractions, *, ridge: float = 1.0
             ) -> np.ndarray:
    """Direct-method reward model for the DR estimator: one ridge
    regression per arm on the LinUCB featurization (L2-normalized
    embedding + bias), fit on the behavior log's observed
    (context, action, reward) triples. Returns ``(n, K)`` predictions
    for every logged context x every arm."""
    phi = np.asarray(_lin_features(jnp.asarray(logged.x_emb)),
                     np.float64)
    n, d = phi.shape
    k_arms = logged.num_actions
    theta = np.zeros((k_arms, d))
    for a in range(k_arms):
        rows = logged.action == a
        if not rows.any():
            continue
        gram = phi[rows].T @ phi[rows] + ridge * np.eye(d)
        theta[a] = np.linalg.solve(
            gram, phi[rows].T @ logged.reward[rows].astype(np.float64))
    return (phi @ theta.T).astype(np.float64)


def _target_actions(plan: ExperimentPlan, name: str,
                    logged: LoggedInteractions) -> np.ndarray:
    """Decide the target's action on every logged context through the
    serving kernel at ``t=1`` (past the neural warm-up slice), with the
    target pretrained on the behavior log when it has an offline
    phase."""
    from repro.serving.policy_router import _srv_decide, _srv_init
    from repro.sim.engine import _tables

    ope = plan.spec.ope
    env = plan.env
    pol, hyp = make_policy(name, env, plan.cfg,
                           ucb_backend=plan.spec.ucb_backend)
    key = jax.random.PRNGKey(ope.seed)
    state, _, ptables = _srv_init(pol, key, _tables(env), hyp, env.idx)
    if pol.pretrain is not _no_pretrain:
        pt = plan.spec.pretrain
        state = pretrain_policy_state(
            env, pol, hyp, logged, seed=ope.seed,
            steps=pt.steps if pt is not None else 512,
            batch_size=pt.batch_size if pt is not None else 256)

    ids = np.asarray(logged.sample_idx, np.int32)
    n = ids.shape[0]
    pad = (-n) % _CHUNK
    ids_p = np.concatenate([ids, np.zeros(pad, np.int32)]) if pad else ids
    avail = jnp.ones((_CHUNK, env.K), jnp.float32)
    t1 = jnp.int32(1)
    acts: List[np.ndarray] = []
    for c0 in range(0, ids_p.shape[0], _CHUNK):
        a, _, _ = _srv_decide(pol, state, jax.random.fold_in(key, c0),
                              ptables, hyp, jnp.asarray(ids_p[c0:c0 + _CHUNK]),
                              avail, t1)
        acts.append(np.asarray(a))
    return np.concatenate(acts)[:n]


def _target_probs(name: str, actions: np.ndarray, n: int, k_arms: int
                  ) -> np.ndarray:
    """Full per-row action distribution of a target. ``random`` is
    exactly uniform; every other target is the declared epsilon-smoothed
    point mass on its decided action (the same
    :data:`OPE_SMOOTHING_EPS` semantics the zoo's logp contract uses)."""
    if name == "random":
        return np.full((n, k_arms), 1.0 / k_arms)
    eps = OPE_SMOOTHING_EPS
    probs = np.full((n, k_arms), eps / k_arms)
    probs[np.arange(n), actions] += 1.0 - eps
    return probs


def score_policies_offline(plan: ExperimentPlan, *,
                           logged: Optional[LoggedInteractions] = None,
                           verbose: bool = False
                           ) -> Tuple[List[Dict[str, Any]],
                                      Dict[str, Any]]:
    """The full OPE stage: behavior log -> q-hat -> one artifact cell
    per target under scenario ``"offline"``. Returns ``(cells, info)``;
    ``info`` is the manifest block (behavior, log size, parity
    outcomes)."""
    ope = plan.spec.ope
    if logged is None:
        logged = behavior_log(plan)
    qhat = fit_qhat(logged)
    info: Dict[str, Any] = {"behavior": logged.behavior, "n": logged.n,
                            "targets": list(ope.targets)}
    cells: List[Dict[str, Any]] = []
    for name in ope.targets:
        acts = _target_actions(plan, name, logged)
        probs = _target_probs(name, acts, logged.n, logged.num_actions)
        est = estimate_offline(logged, probs, qhat=qhat, clip=ope.clip)
        cell = {"scenario": "offline", "policy": name, "point": {},
                "train_steps": 0,
                "avg_reward_mean": float(est["dr"]),
                "avg_reward_std": 0.0,
                "avg_cost_mean": float("nan"),
                "avg_quality_mean": float("nan"),
                "ope": est}
        if name in ope.parity:
            pol, hyp = make_policy(name, plan.env, plan.cfg,
                                   ucb_backend=plan.spec.ucb_backend)
            _, onlog = run_policy_device(
                plan.env, pol, hyp, seed=ope.seed, record_log=True,
                train_steps=plan.train_steps,
                epochs=plan.spec.train.epochs,
                batch_size=plan.spec.train.batch_size)
            value = float(onlog.reward.mean())
            cell["onpolicy_value"] = value
            cell["ope_ok"] = bool(abs(est["dr"] - value)
                                  <= ope.parity_tol)
        if verbose:
            gate = ""
            if "ope_ok" in cell:
                gate = (f" vs on-policy {cell['onpolicy_value']:.4f} "
                        f"-> {'ok' if cell['ope_ok'] else 'FAIL'}")
            print(f"[{plan.spec.name}] offline/{name}: "
                  f"dr={est['dr']:.4f} snips={est['snips']:.4f} "
                  f"ips={est['ips']:.4f} ess={est['ess']:.0f}{gate}",
                  flush=True)
        cells.append(cell)
    info["parity_ok"] = all(c.get("ope_ok", True) for c in cells)
    return cells, info
