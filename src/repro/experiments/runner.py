"""``run(plan) -> ExperimentResult`` (DESIGN.md §11.3).

Executes a compiled :class:`ExperimentPlan` — one
``repro.sim.run_policy_sweep`` dispatch per :class:`SweepCall` — and
shapes the outputs into a schema-versioned artifact: one CELL per
(scenario × policy × grid point) with the seed-aggregated paper metrics
(``repro.core.protocol.summarize_sweep``), optional per-slice curves
and per-seed values, and a MANIFEST recording the spec hash, backend /
device topology, resolved train schedule, dispatch count, and
compile/run wall time. The artifact is plain JSON: what the driver
writes, what CI uploads, and what the parity tests diff.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

import numpy as np

import jax

from repro.core.protocol import summarize_sweep
from repro.experiments.compiler import ExperimentPlan
from repro.experiments.spec import (
    ExperimentSpec,
    spec_hash,
    spec_to_json,
)
from repro.sim import run_policy_sweep

RESULT_SCHEMA_VERSION = "experiment-result-v1"

_STATIONARY = "stationary"


@dataclasses.dataclass
class ExperimentResult:
    """Schema-versioned run artifact. ``cells`` is the flat list of
    per-(scenario, policy, grid-point) summaries; ``manifest`` the
    provenance block. ``ok`` is the driver's exit-status predicate:
    every cell's headline metrics came back finite."""

    spec: ExperimentSpec
    manifest: Dict[str, Any]
    cells: List[Dict[str, Any]]

    @property
    def ok(self) -> bool:
        return all(np.isfinite(c["avg_reward_mean"])
                   and c.get("serving_ok", True)
                   and c.get("ope_ok", True) for c in self.cells)

    def scenario_names(self) -> List[str]:
        seen: List[str] = []
        for c in self.cells:
            if c["scenario"] not in seen:
                seen.append(c["scenario"])
        return seen

    def cells_for(self, scenario: str) -> List[Dict[str, Any]]:
        return [c for c in self.cells if c["scenario"] == scenario]

    def cell(self, policy: str, scenario: str = _STATIONARY,
             **point) -> Dict[str, Any]:
        """The unique cell for (policy, scenario[, axis values]) —
        raises if the selector is ambiguous or matches nothing."""
        hits = [c for c in self.cells
                if c["policy"] == policy and c["scenario"] == scenario
                and all(c["point"].get(k) == v for k, v in point.items())]
        if len(hits) != 1:
            raise KeyError(f"cell(policy={policy!r}, "
                           f"scenario={scenario!r}, {point}) matched "
                           f"{len(hits)} cells")
        return hits[0]

    def to_json(self) -> Dict[str, Any]:
        return {"schema": RESULT_SCHEMA_VERSION,
                "spec": spec_to_json(self.spec),
                "manifest": self.manifest,
                "cells": self.cells}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, default=float)


def _run_serving_cell(plan: ExperimentPlan, *,
                      pretrained_state: Any = None,
                      verbose: bool = False) -> Dict[str, Any]:
    """Serving-storm mode: drive the plan's single resolved policy
    through the async engine (DESIGN.md §12) and shape the storm
    metrics into one artifact cell. ``serving_ok`` applies the spec's
    gates (zero lost requests, p99 decide-latency bound, shed ceiling)
    — it feeds :attr:`ExperimentResult.ok`, the CI exit status."""
    from repro.serving import DevicePolicyRouter, run_storm
    from repro.sim.engine import _chunks_for, _tables

    spec = plan.spec
    sv = spec.serving
    label, pol, hyp, fcfg = plan.serving_policy
    chunks = _chunks_for(plan.env, pol, plan.train_steps,
                         spec.train.epochs, spec.train.batch_size)
    capacity = min(1024, -(-sv.requests // sv.decide_batch) + sv.waves)
    router = DevicePolicyRouter(
        pol, hyp, _tables(plan.env), seed=spec.seeds[0],
        slice_width=sv.decide_batch, capacity_slices=capacity,
        batch_size=spec.train.batch_size, train_chunks=chunks, fcfg=fcfg,
        pretrained_state=pretrained_state,
        max_train_lag=sv.max_train_lag)
    engines = None
    engine_info: Dict[str, Any] = {}
    max_new = 8
    if plan.pool is not None:
        # semi-real serve stage: small arms run REAL jitted decode
        # steps, large arms sleep their roofline step time
        from repro.armpool import build_arm_engines, engine_decode_steps
        engines, engine_info = build_arm_engines(plan.pool, spec.armpool)
        max_new = spec.armpool.max_new
    metrics = run_storm(
        plan.env, router, requests=sv.requests, waves=sv.waves,
        pattern=sv.pattern, outages=sv.outages,
        queue_capacity=sv.queue_capacity, decide_batch=sv.decide_batch,
        serve_batch=sv.serve_batch,
        fail_decide_calls=sv.fail_decide_calls,
        train_every=sv.train_every, epochs=spec.train.epochs,
        seed=sv.seed, engines=engines, max_new=max_new)
    if engines is not None:
        metrics["decode_steps"] = engine_decode_steps(engines)

    gates: Dict[str, bool] = {}
    if sv.require_zero_lost:
        gates["zero_lost"] = metrics["lost_requests"] == 0
    if sv.p99_decide_ms is not None:
        gates["p99_decide"] = \
            metrics["decide_p99_us"] / 1000.0 <= sv.p99_decide_ms
    gates["shed_fraction"] = \
        metrics["shed"] <= sv.max_shed_fraction * sv.requests
    ok = all(gates.values())
    if verbose:
        print(f"[{spec.name}] serving/{label}: "
              f"{metrics['requests_per_s']:.0f} req/s, "
              f"p99 decide {metrics['decide_p99_us'] / 1000:.2f} ms, "
              f"shed {metrics['shed']}, lost "
              f"{metrics['lost_requests']} -> "
              f"{'ok' if ok else 'FAIL ' + str(gates)}", flush=True)
    cell = {"scenario": f"serving:{sv.pattern}", "policy": label,
            "point": {}, "train_steps": int(plan.train_steps or 0),
            "avg_reward_mean": metrics["avg_reward"],
            "avg_reward_std": 0.0,
            "avg_cost_mean": metrics["avg_cost"],
            "avg_quality_mean": metrics["avg_quality"],
            "serving": metrics, "serving_gates": gates,
            "serving_ok": bool(ok)}
    if engine_info:
        cell["armpool_engines"] = engine_info
    return cell


def run_plan(plan: ExperimentPlan, *, verbose: bool = False
             ) -> ExperimentResult:
    """Execute every planned dispatch and assemble the artifact."""
    import time

    spec = plan.spec
    summ = spec.summarize
    cells: List[Dict[str, Any]] = []
    t0 = time.perf_counter()

    warm_states: Dict[str, Any] = {}
    pretrain_info: Dict[str, Any] = {}
    if spec.pretrain is not None and plan.pretrain_labels:
        from repro.experiments.pretrain import pretrained_states
        corpus, warm_states, pretrain_info = pretrained_states(
            plan, verbose=verbose)
        pretrain_info = {"behavior": spec.pretrain.behavior,
                         "corpus_size": None if corpus is None
                         else corpus.n,
                         "labels": pretrain_info}

    if spec.serving is not None:
        srv_label = plan.serving_policy[0]
        cells.append(_run_serving_cell(
            plan, pretrained_state=warm_states.get(srv_label),
            verbose=verbose))
    for call in plan.calls:
        inits = {lbl: warm_states[lbl] for lbl in call.policies
                 if lbl in warm_states}
        sweeps = run_policy_sweep(
            plan.env, call.policies, seeds=spec.seeds,
            scenario=call.scenario, forgetting=call.forgetting,
            train_steps=plan.train_steps, epochs=spec.train.epochs,
            batch_size=spec.train.batch_size,
            init_states=inits or None)
        scen_label = call.scenario or _STATIONARY
        for label, sweep in sweeps.items():
            points = summarize_sweep(sweep, skip_first=summ.skip_first)
            for g, p in enumerate(points):
                cell = {"scenario": scen_label, "policy": label,
                        "point": call.grids[label][g],
                        "train_steps": int(sweep["train_steps"]), **p}
                if summ.curves:
                    cell["curve_avg_reward"] = np.asarray(
                        sweep["avg_reward"][g], np.float64
                    ).mean(axis=0).tolist()
                if summ.per_seed:
                    s0 = 1 if summ.skip_first \
                        and sweep["avg_reward"].shape[2] > 1 else 0
                    cell["per_seed_avg_reward"] = np.asarray(
                        sweep["avg_reward"][g][:, s0:], np.float64
                    ).mean(axis=1).tolist()
                cells.append(cell)
            if verbose:
                best = max(points, key=lambda p: p["avg_reward_mean"])
                print(f"[{spec.name}] {scen_label}/{label}: "
                      f"avg_reward={best['avg_reward_mean']:.4f} "
                      f"({len(points)} grid point"
                      f"{'s' if len(points) != 1 else ''})", flush=True)

    ope_info: Dict[str, Any] = {}
    if spec.ope is not None:
        from repro.experiments.ope import score_policies_offline
        ope_cells, ope_info = score_policies_offline(plan, verbose=verbose)
        cells.extend(ope_cells)
    wall_s = time.perf_counter() - t0

    dev = jax.local_devices()
    manifest = {
        "schema": RESULT_SCHEMA_VERSION,
        "spec_name": spec.name,
        "spec_hash": spec_hash(spec),
        "backend": jax.default_backend(),
        "n_devices": len(dev),
        "device_kind": dev[0].device_kind if dev else "none",
        "jax_version": jax.__version__,
        "train_steps": plan.train_steps,
        "n_dispatches": plan.n_dispatches,
        "n_cells": len(cells),
        "n_seeds": len(spec.seeds),
        "compile_s": plan.compile_s,
        "wall_s": wall_s,
    }
    if pretrain_info:
        manifest["pretrain"] = pretrain_info
    if ope_info:
        manifest["ope"] = ope_info
    if plan.pool is not None:
        manifest["armpool"] = plan.pool.manifest()
    return ExperimentResult(spec=spec, manifest=manifest, cells=cells)


def run_spec(spec: ExperimentSpec, *, env=None, host_env=None,
             verbose: bool = False) -> ExperimentResult:
    """One-call convenience: compile then run."""
    from repro.experiments.compiler import compile_spec
    plan = compile_spec(spec, env=env, host_env=host_env)
    return run_plan(plan, verbose=verbose)


def format_cells(cells: List[Dict[str, Any]], *,
                 axes: Optional[List[str]] = None) -> str:
    """Fixed-width table of cells (the CLI's human face). ``axes``
    names the grid columns to show (default: every axis present)."""
    if not cells:
        return "(no cells)"
    if axes is None:
        axes = sorted({k for c in cells for k in c["point"]})
    head = f"{'policy':<18}" + "".join(f"{a:>9}" for a in axes) + \
        (f"{'avg_reward':>16}{'oracle':>9}{'dyn_regret':>11}"
         f"{'avg_cost':>10}{'avg_quality':>12}")
    lines = [head, "-" * len(head)]
    for c in sorted(cells, key=lambda c: -c["avg_reward_mean"]):
        ax = ""
        for a in axes:
            if a not in c["point"]:
                ax += f"{'':>9}"
            elif c["point"][a] is None:
                ax += f"{'env':>9}"
            else:
                ax += f"{c['point'][a]:>9.2f}"
        lines.append(
            f"{c['policy']:<18}{ax}"
            f"{c['avg_reward_mean']:>9.4f}±{c['avg_reward_std']:.4f}"
            f"{c.get('oracle_avg_reward_mean', float('nan')):>9.4f}"
            f"{c.get('dynamic_regret_mean', float('nan')):>11.4f}"
            f"{c['avg_cost_mean']:>10.4f}"
            f"{c['avg_quality_mean']:>12.4f}")
    return "\n".join(lines)
