"""Preset experiment specs (DESIGN.md §11.4).

Every named workload the repo runs — the paper's Table 1, the
Figures 2-4 β sweep, the non-stationary scenario suite, the policy-zoo
exploration comparison, the CI smoke, and the protocol-bench sweep
shapes — is a preset here: a function returning an
:class:`ExperimentSpec`. The driver exposes them as
``run_paper_experiments.py --preset NAME [--set key=value ...]``; the
benches and tests build the SAME specs, so a preset edit propagates to
every consumer at once.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.experiments.spec import (
    ArmPoolSpec,
    DataSpec,
    ExperimentSpec,
    ForgettingSpec,
    OPESpec,
    PolicySpec,
    PretrainSpec,
    ServingSpec,
    SummarizeSpec,
    TrainSpec,
    apply_overrides,
)

PRESETS: Dict[str, Callable[[], ExperimentSpec]] = {}


def register_preset(name: str):
    def deco(fn: Callable[[], ExperimentSpec]):
        PRESETS[name] = fn
        return fn
    return deco


def make_preset(name: str,
                overrides: Optional[Dict[str, Any]] = None
                ) -> ExperimentSpec:
    """Build a registered preset, optionally with ``--set``-style
    dotted-path overrides (``repro.experiments.spec.apply_overrides``).
    """
    if name not in PRESETS:
        raise KeyError(f"unknown preset {name!r}; registered: "
                       f"{sorted(PRESETS)}")
    spec = PRESETS[name]()
    if overrides:
        spec = apply_overrides(spec, overrides)
    return spec


def preset_table() -> List[Tuple[str, str]]:
    """(name, one-line description) rows for ``--list-presets`` and the
    README table."""
    rows = []
    for name in sorted(PRESETS):
        doc = (PRESETS[name].__doc__ or "").strip().splitlines()
        rows.append((name, doc[0] if doc else ""))
    return rows


_BASELINES = (PolicySpec("random"), PolicySpec("min_cost"),
              PolicySpec("max_quality"), PolicySpec("greedy"))


@register_preset("paper_table1")
def _paper_table1() -> ExperimentSpec:
    """Paper Table 1: NeuralUCB vs. the §4.1 baselines on the full
    replay stream (reward / cost / quality summary)."""
    return ExperimentSpec(
        name="paper_table1",
        policies=(PolicySpec("neuralucb"),) + _BASELINES)


@register_preset("fig2_beta_sweep")
def _fig2_beta_sweep() -> ExperimentSpec:
    """Figures 2-4: the seeds × β exploration grid as ONE vmapped,
    device-sharded scan dispatch."""
    return ExperimentSpec(
        name="fig2_beta_sweep",
        policies=(PolicySpec("neuralucb",
                             axes=(("beta", (0.25, 0.5, 1.0, 2.0)),)),),
        seeds=(0, 1, 2, 3, 4))


@register_preset("scenario_suite")
def _scenario_suite() -> ExperimentSpec:
    """Non-stationary suite (DESIGN.md §9): vanilla + forgetting
    NeuralUCB vs. greedy/random under price shocks and outages, with
    dynamic-oracle regret."""
    return ExperimentSpec(
        name="scenario_suite",
        policies=(PolicySpec("neuralucb"),
                  PolicySpec("neuralucb", name="neuralucb-forget",
                             forgetting=ForgettingSpec(replay_rho=0.4)),
                  PolicySpec("greedy"), PolicySpec("random")),
        scenarios=("price_shock", "arm_outage"))


@register_preset("policy_zoo")
def _policy_zoo() -> ExperimentSpec:
    """Exploration-strategy comparison (DESIGN.md §10): the whole zoo ×
    seeds, stationary and under a price shock, one dispatch per
    scenario."""
    return ExperimentSpec(
        name="policy_zoo",
        policies=(PolicySpec("neuralucb"), PolicySpec("linucb"),
                  PolicySpec("neural_ts"), PolicySpec("eps_greedy"),
                  PolicySpec("boltzmann")),
        scenarios=(None, "price_shock"),
        seeds=(0, 1, 2))


@register_preset("ci_smoke")
def _ci_smoke() -> ExperimentSpec:
    """CI: the sweep + scenario + cross-policy smokes as one tiny spec
    (β grid, forgetting variant, zoo members, three scenarios)."""
    return ExperimentSpec(
        name="ci_smoke",
        data=DataSpec(n_samples=1500, n_slices=3),
        policies=(PolicySpec("neuralucb",
                             axes=(("beta", (0.5, 1.0)),)),
                  PolicySpec("neuralucb", name="neuralucb-forget",
                             forgetting=ForgettingSpec(replay_rho=0.4)),
                  PolicySpec("linucb"), PolicySpec("neural_ts"),
                  PolicySpec("eps_greedy")),
        scenarios=(None, "price_shock", "arm_outage"),
        seeds=(0, 1),
        train=TrainSpec(train_steps=32, batch_size=64),
        summarize=SummarizeSpec(curves=False))


@register_preset("serving_storm")
def _serving_storm() -> ExperimentSpec:
    """Serving storm (DESIGN.md §12): flash-crowd traffic through the
    async engine with two cascading arm outages and an injected decide
    fault — gates on zero lost requests, the p99 decide-latency bound,
    and the shed ceiling. CI shrinks it via --set serving.requests=...
    serving.waves=...; the full size is the acceptance run."""
    return ExperimentSpec(
        name="serving_storm",
        data=DataSpec(n_samples=6000, n_slices=8),
        policies=(PolicySpec("neuralucb"),),
        seeds=(0,),
        train=TrainSpec(train_steps=32, batch_size=64),
        summarize=SummarizeSpec(curves=False),
        serving=ServingSpec(
            requests=20_000, waves=40, pattern="flash_crowd",
            decide_batch=256, queue_capacity=4096,
            outages=((0, 12, 28), (1, 20, 36)),
            fail_decide_calls=(5,),
            train_every=8, p99_decide_ms=250.0,
            max_shed_fraction=0.02, require_zero_lost=True))


@register_preset("physical_pool")
def _physical_pool() -> ExperimentSpec:
    """Physical arm pool (DESIGN.md §16): 8 real model configs costed
    through the roofline on tpu-v5e feed ONE spec that runs BOTH the
    replay policy sweep and a semi-real serving storm — mamba2-130m
    executes real jitted decode steps, the large arms sleep their
    roofline step time. CI shrinks it via --set serving.requests=...
    data.n_samples=...; calibration stays off (calibrate=true times
    real full-size decode steps — the bench's job)."""
    return ExperimentSpec(
        name="physical_pool",
        data=DataSpec(n_samples=6000, n_slices=8),
        policies=(PolicySpec("neuralucb"),),
        seeds=(0,),
        train=TrainSpec(train_steps=32, batch_size=64),
        summarize=SummarizeSpec(curves=False),
        armpool=ArmPoolSpec(
            arms=("mamba2_130m", "llama3_2_3b", "gemma3_4b",
                  "granite_moe_1b_a400m", "mistral_nemo_12b",
                  "qwen3_moe_30b_a3b", "mistral_large_123b",
                  "jamba_1_5_large_398b"),
            hardware="tpu-v5e", decode_batch=8, context=2048,
            calibrate=False, reduced_decode=True, max_new=4),
        serving=ServingSpec(
            requests=4000, waves=16, pattern="flash_crowd",
            decide_batch=128, serve_batch=64, queue_capacity=4096,
            train_every=4, p99_decide_ms=500.0,
            max_shed_fraction=0.05, require_zero_lost=True))


@register_preset("offline_online")
def _offline_online() -> ExperimentSpec:
    """Phased lifecycle (DESIGN.md §13): pretrain on a logged corpus,
    then stream online — warm vs cold start as a sweepable axis for the
    neural + supervised zoo members, cold baselines riding along. CI
    shrinks it via --set data.n_samples=... pretrain.corpus_size=...;
    the full size is the acceptance run."""
    return ExperimentSpec(
        name="offline_online",
        policies=(PolicySpec("neuralucb"), PolicySpec("sup_winrate"),
                  PolicySpec("linucb"), PolicySpec("greedy"),
                  PolicySpec("random")),
        seeds=(0, 1),
        pretrain=PretrainSpec(corpus_size=20_000, behavior="random",
                              steps=512, warm_start=(True, False)))


@register_preset("ope_selection")
def _ope_selection() -> ExperimentSpec:
    """Off-policy router selection (DESIGN.md §13.4): one eps-greedy
    behavior log scored against four targets via IPS/SNIPS/DR — the
    supervised router fit purely from the log — with the deterministic
    min-cost target's DR estimate parity-pinned against its on-policy
    replay run."""
    return ExperimentSpec(
        name="ope_selection",
        policies=(PolicySpec("eps_greedy"),),
        seeds=(0,),
        summarize=SummarizeSpec(curves=False),
        ope=OPESpec(behavior="eps_greedy",
                    targets=("min_cost", "greedy", "sup_winrate",
                             "random"),
                    parity=("min_cost",), parity_tol=0.05))


@register_preset("bench_nucb_sweep")
def _bench_nucb_sweep() -> ExperimentSpec:
    """Bench: the neuralucb_sweep section's multi-seed Algorithm-1
    workload (engine structure at reduced stream size)."""
    return ExperimentSpec(
        name="bench_nucb_sweep",
        data=DataSpec(n_samples=1200, n_slices=32),
        policies=(PolicySpec("neuralucb"),),
        seeds=(0, 1, 2, 3),
        train=TrainSpec(train_steps=32, batch_size=32),
        summarize=SummarizeSpec(curves=False))


@register_preset("bench_zoo_sweep")
def _bench_zoo_sweep() -> ExperimentSpec:
    """Bench: the policy_zoo_sweep section's 5-policy × seed one-
    dispatch workload."""
    return ExperimentSpec(
        name="bench_zoo_sweep",
        data=DataSpec(n_samples=1200, n_slices=8),
        policies=(PolicySpec("neuralucb"), PolicySpec("linucb"),
                  PolicySpec("neural_ts"), PolicySpec("eps_greedy"),
                  PolicySpec("boltzmann")),
        seeds=(0, 1, 2, 3),
        train=TrainSpec(train_steps=32, batch_size=32),
        summarize=SummarizeSpec(curves=False))
