"""Offline pretraining stage of the learning lifecycle (DESIGN.md §13.3).

Bridges the compiled plan and the engine's offline phase: build ONE
behavior corpus per spec (a uniform replay corpus, or the
propensity-aware log of a registered behavior policy run), call
``repro.sim.pretrain_policy_state`` for every warm-flagged label in
``plan.pretrain_labels``, and cache the resulting state pytrees as
``{spec_hash}-{label}.npz`` via ``repro.training.checkpoint`` so
re-running the same spec skips the offline phase entirely. The cache
directory comes from ``$REPRO_PRETRAIN_CACHE`` (default
``.pretrain_cache/``); keying by spec hash means any change to the
spec — corpus size, behavior, steps, data seed — invalidates it.
"""
from __future__ import annotations

import os
import re
import time
from typing import Any, Dict, Optional, Tuple

import jax

from repro.data.logged import LoggedInteractions, replay_corpus
from repro.experiments.compiler import ExperimentPlan
from repro.experiments.spec import spec_hash
from repro.sim import make_policy, pretrain_policy_state, run_policy_device
from repro.training.checkpoint import load_checkpoint, save_checkpoint

CACHE_ENV_VAR = "REPRO_PRETRAIN_CACHE"
_DEFAULT_CACHE = ".pretrain_cache"


def cache_dir() -> str:
    return os.environ.get(CACHE_ENV_VAR, _DEFAULT_CACHE)


def _safe(label: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]+", "_", label)


def _point0(hyp: Any) -> Any:
    """Grid point 0's scalar hypers. Pretraining is grid-invariant: one
    offline state is shared across the online sweep's G axis, so the
    (G,)-leaved grid hypers collapse to their first point."""
    return jax.tree_util.tree_map(
        lambda x: x[0] if getattr(x, "ndim", 0) == 1 else x, hyp)


def build_corpus(plan: ExperimentPlan) -> LoggedInteractions:
    """The spec's behavior corpus. ``behavior='random'`` draws a uniform
    replay corpus (exact ``-log K`` propensities); any registered policy
    name instead RUNS that policy over the replay env with
    ``record_log=True`` and subsamples its propensity-aware log."""
    pt = plan.spec.pretrain
    if pt.behavior == "random":
        return replay_corpus(plan.env, pt.corpus_size, seed=pt.seed)
    pol, hyp = make_policy(pt.behavior, plan.env, plan.cfg,
                           ucb_backend=plan.spec.ucb_backend)
    _, logged = run_policy_device(
        plan.env, pol, hyp, seed=pt.seed, record_log=True,
        train_steps=plan.train_steps, epochs=plan.spec.train.epochs,
        batch_size=plan.spec.train.batch_size)
    return logged.subsample(pt.corpus_size, seed=pt.seed)


def pretrained_states(plan: ExperimentPlan, *,
                      logged: Optional[LoggedInteractions] = None,
                      verbose: bool = False
                      ) -> Tuple[Optional[LoggedInteractions],
                                 Dict[str, Any], Dict[str, Any]]:
    """Pretrain every warm label of the plan. Returns ``(corpus,
    states, info)`` — ``states`` maps label -> pretrained state pytree
    (feed to ``run_policy_sweep(init_states=...)`` / the router's
    ``pretrained_state``), ``info`` the per-label manifest block
    (cache hit, wall time, checkpoint path)."""
    pt = plan.spec.pretrain
    if pt is None or not plan.pretrain_labels:
        return logged, {}, {}

    entries: Dict[str, Tuple[Any, Any]] = {}
    if plan.serving_policy is not None:
        label, pol, hyp, _ = plan.serving_policy
        entries[label] = (pol, hyp)
    for call in plan.calls:
        entries.update(call.policies)

    shash = spec_hash(plan.spec)
    states: Dict[str, Any] = {}
    info: Dict[str, Any] = {}
    for label, warm in plan.pretrain_labels.items():
        if not warm or label not in entries:
            continue
        pol, grid_hyp = entries[label]
        path = os.path.join(cache_dir(), f"{shash}-{_safe(label)}.npz")
        t0 = time.perf_counter()
        if pt.cache and os.path.exists(path):
            states[label] = load_checkpoint(path)
            info[label] = {"cache_hit": True, "path": path,
                           "pretrain_s": time.perf_counter() - t0}
            continue
        if logged is None:
            logged = build_corpus(plan)
        state = jax.block_until_ready(pretrain_policy_state(
            plan.env, pol, _point0(grid_hyp), logged, seed=pt.seed,
            steps=pt.steps, batch_size=pt.batch_size))
        if pt.cache:
            save_checkpoint(path, state)
        states[label] = state
        info[label] = {"cache_hit": False,
                       "path": path if pt.cache else None,
                       "pretrain_s": time.perf_counter() - t0}
        if verbose:
            print(f"[{plan.spec.name}] pretrain/{label}: "
                  f"{info[label]['pretrain_s']:.2f}s "
                  f"(corpus n={logged.n}, behavior "
                  f"{logged.behavior!r})", flush=True)
    return logged, states, info
