"""``compile(spec) -> ExperimentPlan`` (DESIGN.md §11.2).

Compilation resolves the spec against the live registries
(``repro.sim.POLICIES`` / ``repro.sim.SCENARIOS``), validates every
hyper-grid axis against the policy's hypers pytree, builds the (G,)
grid arrays in cartesian-product order, and groups the whole study into
the MINIMAL set of single-dispatch ``run_policy_sweep`` calls: one call
per (scenario × forgetting-variant) group, every policy of the group
riding the same jitted program (``repro.sim.engine._policy_zoo_scan``).
``plan.n_dispatches`` is therefore an exact device-dispatch count —
what the ``experiment_compile`` bench section pins against the
hand-wired equivalent.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core.utilitynet import UtilityNetConfig
from repro.data.routerbench import RouterBenchSim
from repro.experiments.spec import ExperimentSpec, ForgettingSpec
from repro.sim import (
    POLICIES,
    SCENARIOS,
    BanditPolicy,
    DeviceReplayEnv,
    ForgettingConfig,
    make_policy,
    neuralucb_train_schedule,
)
from repro.sim.policies import _no_pretrain, _no_train


@dataclasses.dataclass(frozen=True)
class SweepCall:
    """One device dispatch: every policy of one (scenario, forgetting)
    group. ``grids[label]`` holds the host-side per-grid-point axis
    values (``None`` preserved for the cost_lambda sentinel) in the same
    order as the sweep's G axis."""

    scenario: Optional[str]
    forgetting: ForgettingConfig
    policies: Dict[str, Tuple[BanditPolicy, Any]]
    grids: Dict[str, List[Dict[str, Any]]]


@dataclasses.dataclass(frozen=True)
class ExperimentPlan:
    """A compiled, ready-to-run study. ``env`` is the device-resident
    replay environment every call shares; ``train_steps`` is the
    RESOLVED fixed per-slice budget (spec value, or derived from
    ``train.epochs`` when the spec leaves it None and any policy
    trains)."""

    spec: ExperimentSpec
    env: DeviceReplayEnv
    host_env: Optional[RouterBenchSim]
    cfg: UtilityNetConfig
    calls: Tuple[SweepCall, ...]
    train_steps: Optional[int]
    compile_s: float
    # serving-storm mode (spec.serving set): the single resolved policy
    # as (label, policy, hypers, forgetting); ``calls`` is then empty —
    # the storm replaces the sweep dispatches.
    serving_policy: Optional[Tuple[str, BanditPolicy, Any,
                                   ForgettingConfig]] = None
    # lifecycle mode (spec.pretrain set): expanded label -> warm-start
    # flag. Labels marked True receive an offline-pretrained init state
    # from the runner; False labels are the cold controls.
    pretrain_labels: Optional[Dict[str, bool]] = None
    # physical-pool mode (spec.armpool set): the CompiledArmPool whose
    # tables the env was built from. With serving ALSO set the plan
    # keeps its sweep calls — one spec drives the replay sweep AND the
    # semi-real storm over the same pool (DESIGN.md §16.5).
    pool: Optional[Any] = None

    @property
    def n_dispatches(self) -> int:
        return len(self.calls)

    @property
    def n_cells(self) -> int:
        return sum(len(pts) for c in self.calls
                   for pts in c.grids.values())


def build_env(data) -> Tuple[RouterBenchSim, DeviceReplayEnv]:
    """Materialize a spec's :class:`DataSpec` as the (host, device)
    replay environment pair. Factored out of :func:`compile_spec` so
    callers running several specs over the same data (the driver's
    legacy multi-section mode, the bench) can build once and inject."""
    henv = RouterBenchSim(seed=data.seed, n_samples=data.n_samples,
                          n_slices=data.n_slices,
                          cost_lambda=data.cost_lambda)
    return henv, DeviceReplayEnv.from_host(henv)


def _axis_grid(ps_label: str, hypers: Any, axes) -> Tuple[Any, List[Dict]]:
    """Expand a policy's hyper-grid axes into (G,)-leaved hypers plus
    the per-point host annotation. The grid is the cartesian product in
    axis order (``itertools.product`` — the same order the PR-2
    ``run_neuralucb_sweep`` used for betas × tau_gs × cost_lambdas)."""
    if not axes:
        return hypers, [{}]
    fields = getattr(hypers, "_fields", ())
    if not fields:
        raise ValueError(f"policy {ps_label!r} has no hyper fields; "
                         f"axes {[f for f, _ in axes]} cannot apply")
    for field, _ in axes:
        if field not in fields:
            raise ValueError(f"policy {ps_label!r}: unknown hyper axis "
                             f"{field!r} (fields: {list(fields)})")
    names = [f for f, _ in axes]
    points = [dict(zip(names, combo))
              for combo in itertools.product(*(v for _, v in axes))]
    repl = {}
    for field in names:
        vals = [p[field] for p in points]
        # None -> the "env's own reward table" sentinel (engine contract)
        vals = [-1.0 if v is None else float(v) for v in vals]
        repl[field] = jnp.asarray(vals, jnp.float32)
    return hypers._replace(**repl), points


def compile_spec(spec: ExperimentSpec, *,
                 env: Optional[DeviceReplayEnv] = None,
                 host_env: Optional[RouterBenchSim] = None
                 ) -> ExperimentPlan:
    """Resolve + validate + group (module docstring). ``env`` /
    ``host_env`` short-circuit data construction (the bench/test hook:
    compile overhead can be measured without regenerating the replay
    tables); when omitted they are built from ``spec.data``."""
    t0 = time.perf_counter()
    for s in spec.scenarios:
        if s is not None and s not in SCENARIOS:
            raise ValueError(f"unknown scenario {s!r}; registered: "
                             f"{sorted(SCENARIOS)}")
    for ps in spec.policies:
        if ps.policy not in POLICIES:
            raise ValueError(f"unknown policy {ps.policy!r}; "
                             f"registered: {sorted(POLICIES)}")
    if spec.pretrain is not None and spec.pretrain.behavior != "random" \
            and spec.pretrain.behavior not in POLICIES:
        raise ValueError(f"pretrain behavior {spec.pretrain.behavior!r} "
                         f"is neither 'random' nor a registered policy; "
                         f"registered: {sorted(POLICIES)}")
    if spec.ope is not None:
        if spec.ope.behavior not in POLICIES:
            raise ValueError(f"ope behavior {spec.ope.behavior!r} not "
                             f"registered; registered: {sorted(POLICIES)}")
        for t in spec.ope.targets:
            if t not in POLICIES:
                raise ValueError(f"ope target {t!r} not registered; "
                                 f"registered: {sorted(POLICIES)}")
    pool = None
    if spec.armpool is not None:
        if env is not None or host_env is not None:
            raise ValueError("compile_spec: spec.armpool compiles its "
                             "own pool env; do not inject env/host_env")
        from repro.armpool import build_pool_env
        host_env, pool = build_pool_env(spec.armpool, spec.data)
        env = DeviceReplayEnv.from_host(host_env)
        pool.validate_against(env.K, what="device env")
    if env is None:
        if host_env is None:
            host_env, env = build_env(spec.data)
        else:
            env = DeviceReplayEnv.from_host(host_env)
    cfg = UtilityNetConfig(emb_dim=env.x_emb.shape[1],
                           num_actions=env.K)

    def _mk(policy: str, **kw):
        """make_policy with the spec's backend + train precision. The
        precision kwarg is only offered when non-default and dropped for
        builders without a train path (they have nothing to cast)."""
        if spec.train.precision != "f32":
            try:
                return make_policy(policy, env, cfg,
                                   ucb_backend=spec.ucb_backend,
                                   train_precision=spec.train.precision,
                                   **kw)
            except TypeError:
                pass
        return make_policy(policy, env, cfg,
                           ucb_backend=spec.ucb_backend, **kw)

    resolved = []   # (label, fspec, policy, grid_hypers, points)
    pretrain_labels: Dict[str, bool] = {}
    any_train = False
    for ps in spec.policies:
        try:
            pol, hyp = _mk(ps.policy, **dict(ps.overrides))
        except TypeError as e:
            # a misspelled builder override must fail loudly, with the
            # spec entry named, not as a bare TypeError
            raise ValueError(f"policy {ps.label!r}: bad override "
                             f"({e})") from e
        fspec = ps.forgetting if ps.forgetting is not None \
            else spec.forgetting
        any_train = any_train or pol.train is not _no_train
        hooked = (spec.pretrain is not None
                  and pol.pretrain is not _no_pretrain)
        if not hooked:
            grid_hyp, points = _axis_grid(ps.label, hyp, ps.axes)
            resolved.append((ps.label, fspec, pol, grid_hyp, points))
            continue
        # warm_start is a sweepable axis: one policy entry per value.
        # Warm entries drop the slice-0 uniform warm-up (warm_slice
        # False) so the pretrained net routes from the first request;
        # builders without the kwarg (linucb/supervised — no warm-up
        # to drop) reuse the base policy.
        ws_axis = spec.pretrain.warm_start
        for w in ws_axis:
            label = ps.label if len(ws_axis) == 1 \
                else f"{ps.label}:{'warm' if w else 'cold'}"
            use_pol, use_hyp = pol, hyp
            if w:
                try:
                    use_pol, use_hyp = _mk(ps.policy, warm_slice=False,
                                           **dict(ps.overrides))
                except TypeError:
                    pass
            grid_hyp, points = _axis_grid(label, use_hyp, ps.axes)
            points = [dict(p, warm_start=bool(w)) for p in points]
            resolved.append((label, fspec, use_pol, grid_hyp, points))
            pretrain_labels[label] = bool(w)

    train_steps = spec.train.train_steps
    if train_steps is None and any_train:
        train_steps = neuralucb_train_schedule(env, spec.train.epochs,
                                               spec.train.batch_size)

    serving_policy = None
    if spec.serving is not None:
        from repro.serving.traffic import TRAFFIC_PATTERNS
        sv = spec.serving
        if sv.pattern not in TRAFFIC_PATTERNS:
            raise ValueError(f"unknown traffic pattern {sv.pattern!r}; "
                             f"known: {sorted(TRAFFIC_PATTERNS)}")
        for arm, s, e in sv.outages:
            if arm >= env.K:
                raise ValueError(f"serving outage arm {arm} out of "
                                 f"range (env has {env.K} arms)")
            if s >= sv.waves:
                raise ValueError(f"serving outage ({arm}, {s}, {e}) "
                                 f"starts past the last wave "
                                 f"({sv.waves} waves)")
        label, fspec, pol, hyp, _ = resolved[0]
        serving_policy = (label, pol, hyp, fspec.to_config())
        if pool is None:
            # storm replaces the sweep (pre-PR-10 behavior). With a
            # physical pool the plan falls through and KEEPS its sweep
            # calls: one spec, one pool, sweep + semi-real storm.
            return ExperimentPlan(
                spec=spec, env=env, host_env=host_env, cfg=cfg,
                calls=(), train_steps=train_steps,
                compile_s=time.perf_counter() - t0,
                serving_policy=serving_policy,
                pretrain_labels=pretrain_labels or None)

    calls = []
    for scenario in spec.scenarios:
        variants: Dict[ForgettingSpec, SweepCall] = {}
        for label, fspec, pol, grid_hyp, points in resolved:
            call = variants.get(fspec)
            if call is None:
                call = SweepCall(scenario=scenario,
                                 forgetting=fspec.to_config(),
                                 policies={}, grids={})
                variants[fspec] = call
                calls.append(call)
            call.policies[label] = (pol, grid_hyp)
            call.grids[label] = points
    return ExperimentPlan(spec=spec, env=env, host_env=host_env, cfg=cfg,
                          calls=tuple(calls), train_steps=train_steps,
                          compile_s=time.perf_counter() - t0,
                          serving_policy=serving_policy,
                          pretrain_labels=pretrain_labels or None,
                          pool=pool)
