"""Physical arm pool: hardware targets, the arm↔RouterBench mapping,
and the per-arm roofline derivation (DESIGN.md §16).

An *arm* here is a real ``ModelConfig`` from ``repro.configs`` deployed
on a declared :class:`HardwareTarget`. Its serving economics are derived
analytically: ``repro.roofline.decode_step_costs`` gives the per-decode-
step FLOPs/bytes, the chip count follows from fitting the weights into
HBM, and the three-term roofline turns that into a step-time lower bound
— hence seconds/token and $/token (chip-hours burned per token). Its
QUALITY column comes from the RouterBench replay tables through an
EXPLICIT arm↔RouterBench-model mapping; nothing is paired positionally,
and every mapping error (unknown arch, unknown table model, duplicate
arm, K mismatch) raises with the offending names.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

from repro.common.config import ModelConfig
from repro.configs import ARCH_IDS, _ALIASES, get_config
from repro.roofline.model import (
    HW_CPU_HOST,
    HW_V5E,
    Hardware,
    _DTYPE_BYTES,
    decode_step_costs,
    roofline_terms,
)


@dataclasses.dataclass(frozen=True)
class HardwareTarget:
    """A deployment target: roofline constants + what a chip-hour costs
    (the bridge from step seconds to $/token)."""

    name: str
    hw: Hardware
    usd_per_chip_hour: float


HARDWARE_TARGETS: Dict[str, HardwareTarget] = {
    "tpu-v5e": HardwareTarget("tpu-v5e", HW_V5E, 1.20),
    # the calibration leg's host model (absolute scale is order-of-
    # magnitude; the measured/analytic RATIO is the deliverable)
    "cpu-host": HardwareTarget("cpu-host", HW_CPU_HOST, 0.10),
}

# Default arm -> RouterBench-model mapping, by capability tier: the
# pool's frontier-scale members grade against the frontier columns, the
# small members against the 7B-class columns. Overridable per-spec
# (ArmPoolSpec.mapping); every arm MUST resolve to a table column —
# there is deliberately no positional fallback.
DEFAULT_RB_MAPPING: Dict[str, str] = {
    "jamba_1_5_large_398b": "gpt-4",
    "mistral_large_123b": "claude-v2",
    "qwen3_moe_30b_a3b": "mixtral-8x7b",
    "mistral_nemo_12b": "gpt-3.5-turbo",
    "llama3_2_vision_11b": "claude-instant",
    "gemma3_4b": "yi-34b-chat",
    "llama3_2_3b": "mistral-7b-chat",
    "granite_moe_1b_a400m": "wizardlm-13b",
    "whisper_medium": "code-llama-34b",
    "mamba2_130m": "zephyr-7b",
}


def canonical_arm(name: str) -> str:
    """Normalize an arm name to its registry id (accepts the dashed
    aliases the configs package accepts)."""
    return _ALIASES.get(name, name).replace("-", "_").replace(".", "_")


def resolve_arms(arms: Sequence[str]) -> List[Tuple[str, ModelConfig]]:
    """Arm names -> [(canonical_name, ModelConfig)], loudly.

    Unknown arch names and duplicate arms raise with every offender
    listed (satellite: no silent positional pairing anywhere in the
    pool path)."""
    if not arms:
        raise ValueError("arm pool is empty: list at least one arch "
                         f"from {sorted(ARCH_IDS)}")
    canon = [canonical_arm(a) for a in arms]
    unknown = sorted({c for c in canon if c not in ARCH_IDS})
    if unknown:
        raise ValueError(f"unknown arm arch(s) {unknown}; known: "
                         f"{sorted(ARCH_IDS)}")
    dups = sorted({c for c in canon if canon.count(c) > 1})
    if dups:
        raise ValueError(f"duplicate arm(s) {dups}: each pool member "
                         f"appears once (use one config per deployment)")
    return [(c, get_config(c)) for c in canon]


def resolve_mapping(arm_names: Sequence[str], table_models: Sequence[str],
                    overrides: Sequence[Tuple[str, str]] = ()
                    ) -> List[int]:
    """Arm names -> RouterBench table column indices, loudly.

    ``table_models`` is the replay data's ``model_names`` column order;
    ``overrides`` are (arm, table_model) pairs layered over
    :data:`DEFAULT_RB_MAPPING`. Raises with the offending names on an
    override for an arm not in the pool, an arm with no mapping, or a
    mapped model missing from the tables."""
    cols = {str(m): i for i, m in enumerate(table_models)}
    mapping = dict(DEFAULT_RB_MAPPING)
    stray = sorted({canonical_arm(a) for a, _ in overrides}
                   - set(arm_names))
    if stray:
        raise ValueError(f"mapping override(s) for arm(s) {stray} that "
                         f"are not in the pool {sorted(arm_names)}")
    for a, m in overrides:
        mapping[canonical_arm(a)] = m
    unmapped = sorted(a for a in arm_names if a not in mapping)
    if unmapped:
        raise ValueError(f"arm(s) {unmapped} have no RouterBench "
                         f"mapping; add ArmPoolSpec.mapping entries "
                         f"(table models: {sorted(cols)})")
    missing = sorted({mapping[a] for a in arm_names} - set(cols))
    if missing:
        raise ValueError(f"mapped RouterBench model(s) {missing} not in "
                         f"the replay tables (have: {sorted(cols)})")
    return [cols[mapping[a]] for a in arm_names]


def arm_roofline(cfg: ModelConfig, target: HardwareTarget, *,
                 batch: int, context: int) -> Dict[str, float]:
    """One arm's serving economics on one target.

    Chip count = weights-fit-in-HBM (ideal tensor sharding); collective
    traffic models a ring all-reduce of the residual stream per layer
    when sharded. ``usd_per_token`` is chip-seconds burned per generated
    token at the roofline step time; ``sec_per_token`` is the per-
    request latency contribution of one token (one step)."""
    hw = target.hw
    db = _DTYPE_BYTES.get(cfg.dtype, 2)
    costs = decode_step_costs(cfg, batch, context)
    chips = max(1, math.ceil(cfg.param_count() * db / hw.hbm_bytes))
    coll = 0.0
    if chips > 1:
        coll = (2.0 * (chips - 1) / chips) * batch * cfg.d_model * db \
            * cfg.num_layers
    terms = roofline_terms(costs["flops"] / chips,
                           costs["hbm_bytes"] / chips, coll, hw)
    step_s = terms["step_lower_bound_s"]
    return {
        "flops": costs["flops"], "hbm_bytes": costs["hbm_bytes"],
        "chips": chips, "step_s": step_s,
        "dominant": terms["dominant"],
        "sec_per_token": step_s,
        "tokens_per_s": batch / step_s if step_s > 0 else float("inf"),
        "usd_per_token": chips * target.usd_per_chip_hour / 3600.0
        * step_s / batch,
    }


def get_hardware_target(name: str) -> HardwareTarget:
    if name not in HARDWARE_TARGETS:
        raise ValueError(f"unknown hardware target {name!r}; known: "
                         f"{sorted(HARDWARE_TARGETS)}")
    return HARDWARE_TARGETS[name]
