"""Pool compilation: arms + replay tables -> device-consumable
``(K,)`` / ``(n, K)`` cost/latency/quality tables (DESIGN.md §16).

``compile_pool`` is pure table algebra over a generated RouterBench
replay dict: quality columns are selected through the explicit arm
mapping, per-sample completion lengths are backed out of the mapped
column's cost (cost = price * (prompt + completion) / 1000), and the
roofline-derived $/token re-prices every request on the declared
hardware. The result drops into ``RouterBenchSim(data=...)`` unchanged,
so the scenario engine, ``run_policy_sweep``, and the serving storm all
consume the physical pool exactly as they consume the replay tables —
an ``arm_outage`` is now a pool member going down, a ``price_shock`` a
hardware/batch-shape re-derivation.

Determinism contract: compiling the same (spec, data) twice — in the
same process or across processes — yields bit-identical tables; the
``checksum`` field (crc32 over the table bytes + arm names, NOT
``hash()``) is what the cross-process test pins.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.armpool.pool import (
    arm_roofline,
    get_hardware_target,
    resolve_arms,
    resolve_mapping,
)
from repro.data.routerbench import model_prices

COMPLETION_CAP = 2048   # tokens; guards a degenerate backed-out length


@dataclasses.dataclass(frozen=True)
class CompiledArmPool:
    """The device-ready pool: per-arm scalars + (n, K) tables."""

    hardware: str
    arms: Tuple[str, ...]
    rb_models: Tuple[str, ...]
    cols: Tuple[int, ...]
    quality: np.ndarray          # (n, K)
    cost: np.ndarray             # (n, K) $ per request
    latency_s: np.ndarray        # (n, K) roofline seconds per request
    usd_per_token: np.ndarray    # (K,)
    sec_per_token: np.ndarray    # (K,)
    step_s: np.ndarray           # (K,)
    chips: np.ndarray            # (K,) int
    dominant: Tuple[str, ...]
    params_b: np.ndarray         # (K,) total params, billions
    decode_batch: int
    context: int
    cost_source: str
    checksum: int
    calibration: Optional[Dict[str, Any]] = None

    @property
    def K(self) -> int:
        return len(self.arms)

    def validate_against(self, K: int, what: str = "table") -> None:
        """Loud K-mismatch guard (satellite: no silent positional
        pairing between a pool and a differently-sized table/env)."""
        if K != self.K:
            raise ValueError(f"arm pool K mismatch: pool has {self.K} "
                             f"arms {list(self.arms)} but the {what} "
                             f"has K={K}")

    def as_data(self, base: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Replay-data dict with the pool's columns swapped in — the
        ``RouterBenchSim(data=...)`` payload. Features (topic/domain/
        x_feat) are untouched, so a pool whose costs are forced back to
        the RouterBench tables reproduces the replay sweep bit-exactly
        over its mapped columns (the parity test's contract)."""
        data = dict(base)
        data["quality"] = self.quality
        data["cost"] = self.cost
        data["latency_s"] = self.latency_s
        data["model_names"] = np.array(self.arms)
        return data

    def manifest(self) -> Dict[str, Any]:
        """Provenance block for artifacts / bench sections."""
        m: Dict[str, Any] = {
            "hardware": self.hardware,
            "arms": list(self.arms),
            "rb_models": list(self.rb_models),
            "decode_batch": self.decode_batch,
            "context": self.context,
            "cost_source": self.cost_source,
            "checksum": int(self.checksum),
            "params_b": [round(float(p), 4) for p in self.params_b],
            "chips": [int(c) for c in self.chips],
            "dominant": list(self.dominant),
            "usd_per_token": [float(u) for u in self.usd_per_token],
            "sec_per_token": [float(s) for s in self.sec_per_token],
        }
        if self.calibration is not None:
            m["calibration"] = self.calibration
        return m


def _table_checksum(pool_tables, arms) -> int:
    crc = zlib.crc32("|".join(arms).encode())
    for t in pool_tables:
        a = np.ascontiguousarray(t)
        crc = zlib.crc32(a.tobytes(), crc)
    return crc & 0xFFFFFFFF


def compile_pool(aspec, data: Dict[str, np.ndarray], *,
                 calibrate_fn=None) -> CompiledArmPool:
    """Compile ``aspec`` (an ``ArmPoolSpec``-shaped object) against a
    generated replay dict. ``calibrate_fn(cfg, batch)`` overrides the
    measurement hook (tests inject a stub; the default times real
    jitted decode steps via ``repro.armpool.calibrate``)."""
    target = get_hardware_target(aspec.hardware)
    resolved = resolve_arms(aspec.arms)
    names = [n for n, _ in resolved]
    cols = resolve_mapping(names, data["model_names"],
                           getattr(aspec, "mapping", ()))

    prices = model_prices()
    prompt = np.asarray(data["prompt_tokens"], np.float64)
    rb_cost = np.asarray(data["cost"], np.float64)
    rb_names = [str(m) for m in data["model_names"]]

    calibration: Optional[Dict[str, Any]] = None
    if aspec.calibrate:
        if calibrate_fn is None:
            from repro.armpool.calibrate import measured_ratio
            calibrate_fn = measured_ratio
        calibration = {}

    K = len(names)
    per_arm = []
    comp = np.empty((prompt.size, K), np.float64)
    for a, (name, cfg) in enumerate(resolved):
        rl = arm_roofline(cfg, target, batch=aspec.decode_batch,
                          context=aspec.context)
        if calibration is not None \
                and cfg.param_count() <= aspec.calibrate_max_params:
            info = calibrate_fn(cfg, aspec.decode_batch)
            ratio = float(info["ratio"])
            for k in ("step_s", "sec_per_token", "usd_per_token"):
                rl[k] *= ratio
            rl["tokens_per_s"] /= ratio
            calibration[name] = info
        per_arm.append(rl)
        # completion length the mapped model produced for each sample:
        # cost = price * (prompt + completion) / 1000
        price = prices.get(rb_names[cols[a]])
        if price is None:
            raise ValueError(f"no price for table model "
                             f"{rb_names[cols[a]]!r} (arm {name!r}); "
                             f"known: {sorted(prices)}")
        comp[:, a] = np.clip(rb_cost[:, cols[a]] * 1000.0 / price - prompt,
                             1.0, COMPLETION_CAP)

    usd_tok = np.array([r["usd_per_token"] for r in per_arm], np.float64)
    sec_tok = np.array([r["sec_per_token"] for r in per_arm], np.float64)
    tokens = prompt[:, None] + comp
    if aspec.cost_source == "roofline":
        cost = (usd_tok[None, :] * tokens).astype(np.float32)
    else:   # "routerbench": the parity leg — replay-table costs as-is
        cost = rb_cost[:, cols].astype(np.float32)
    latency = (sec_tok[None, :] * tokens).astype(np.float32)
    quality = np.asarray(data["quality"])[:, cols].astype(np.float32)

    pool = CompiledArmPool(
        hardware=aspec.hardware,
        arms=tuple(names),
        rb_models=tuple(rb_names[c] for c in cols),
        cols=tuple(int(c) for c in cols),
        quality=quality, cost=cost, latency_s=latency,
        usd_per_token=usd_tok, sec_per_token=sec_tok,
        step_s=np.array([r["step_s"] for r in per_arm], np.float64),
        chips=np.array([r["chips"] for r in per_arm], np.int64),
        dominant=tuple(r["dominant"] for r in per_arm),
        params_b=np.array([cfg.param_count() / 1e9
                           for _, cfg in resolved], np.float64),
        decode_batch=int(aspec.decode_batch),
        context=int(aspec.context),
        cost_source=str(aspec.cost_source),
        checksum=_table_checksum((quality, cost, latency), names),
        calibration=calibration)
    pool.validate_against(quality.shape[1])
    return pool


def build_pool_env(aspec, dspec, *, calibrate_fn=None):
    """(ArmPoolSpec, DataSpec) -> (RouterBenchSim over the pool tables,
    CompiledArmPool). The env is a drop-in for ``build_env``'s host
    env: ``DeviceReplayEnv.from_host`` and everything downstream
    consume it unchanged."""
    from repro.data.routerbench import RouterBenchSim, generate_routerbench

    data = generate_routerbench(dspec.seed, dspec.n_samples)
    pool = compile_pool(aspec, data, calibrate_fn=calibrate_fn)
    henv = RouterBenchSim(seed=dspec.seed, n_slices=dspec.n_slices,
                          cost_lambda=dspec.cost_lambda,
                          data=pool.as_data(data))
    pool.validate_against(henv.K, what="pool env")
    return henv, pool
