"""Physical arm pool (DESIGN.md §16): real ``ModelConfig`` arms with
roofline-derived cost/latency on a declared hardware target, quality
from RouterBench tables via an explicit arm mapping, compiled into
replay-compatible tables plus semi-real serving engines.

    pool.py     — hardware targets, mapping + loud validation, per-arm
                  roofline derivation
    compile.py  — CompiledArmPool / compile_pool / build_pool_env
    calibrate.py— measured-vs-analytic decode-step calibration
    serving.py  — DecodeArmEngine (real jitted decode) /
                  RooflineArmEngine (clocked) / build_arm_engines
"""
from repro.armpool.calibrate import (
    analytic_host_step_s,
    measured_decode_step_s,
    measured_ratio,
)
from repro.armpool.compile import (
    CompiledArmPool,
    build_pool_env,
    compile_pool,
)
from repro.armpool.pool import (
    DEFAULT_RB_MAPPING,
    HARDWARE_TARGETS,
    HardwareTarget,
    arm_roofline,
    canonical_arm,
    get_hardware_target,
    resolve_arms,
    resolve_mapping,
)
from repro.armpool.serving import (
    DecodeArmEngine,
    RooflineArmEngine,
    build_arm_engines,
    engine_decode_steps,
)

__all__ = [
    "DEFAULT_RB_MAPPING",
    "HARDWARE_TARGETS",
    "CompiledArmPool",
    "DecodeArmEngine",
    "HardwareTarget",
    "RooflineArmEngine",
    "analytic_host_step_s",
    "arm_roofline",
    "build_arm_engines",
    "build_pool_env",
    "canonical_arm",
    "compile_pool",
    "engine_decode_steps",
    "get_hardware_target",
    "measured_decode_step_s",
    "measured_ratio",
    "resolve_arms",
    "resolve_mapping",
]
