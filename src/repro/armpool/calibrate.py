"""Measured-vs-analytic decode calibration (DESIGN.md §16.3).

The roofline step time is a LOWER bound; real kernels run at some
efficiency below it. This module times REAL jitted decode steps (the
same ``repro.models.model.decode_step`` program the serving engine
runs) and reports the measured/analytic ratio against the host's
roofline model — the ``physical_pool`` bench section records it per
backend, and ``ArmPoolSpec(calibrate=True)`` folds it into the pool's
cost/latency tables as an efficiency de-rating for every arm small
enough to measure.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.roofline.model import HW_CPU_HOST, decode_step_costs, \
    roofline_terms


def measured_decode_step_s(cfg: ModelConfig, *, batch: int = 4,
                           steps: int = 6, seed: int = 0) -> Dict:
    """Time ``steps`` real jitted decode steps of ``cfg`` at ``batch``.

    Uses the serving engine's own decode program (prefill primes the
    cache, one warm step flushes compilation), so the number is the
    per-step wall the storm's real-decode arms actually pay."""
    from repro.serving.engine import ServingEngine

    t0 = time.perf_counter()
    eng = ServingEngine(cfg, seed=seed, max_seq=max(steps + 4, 16))
    init_s = time.perf_counter() - t0

    toks = jnp.ones((batch, 1), jnp.int32)
    t0 = time.perf_counter()
    _, cache = eng.prefill(toks)
    cur = jnp.ones((batch, 1), jnp.int32)
    out, cache = eng._decode(eng.params, cache, cur)   # warm step
    jax.block_until_ready(out)
    compile_s = time.perf_counter() - t0

    walls = []
    for _ in range(steps):
        t0 = time.perf_counter()
        out, cache = eng._decode(eng.params, cache, cur)
        jax.block_until_ready(out)
        walls.append(time.perf_counter() - t0)
    walls.sort()
    return {"step_s": walls[len(walls) // 2], "batch": int(batch),
            "steps": int(steps), "init_s": init_s,
            "compile_s": compile_s, "backend": jax.default_backend()}


def analytic_host_step_s(cfg: ModelConfig, batch: int,
                         context: int = 8) -> float:
    """Roofline step-time lower bound for ``cfg`` on THIS host's
    order-of-magnitude hardware model (the denominator of the
    calibration ratio — same backend as the measurement)."""
    costs = decode_step_costs(cfg, batch, context)
    return roofline_terms(costs["flops"], costs["hbm_bytes"], 0.0,
                          HW_CPU_HOST)["step_lower_bound_s"]


def measured_ratio(cfg: ModelConfig, batch: int, *,
                   steps: int = 6) -> Dict:
    """measured/analytic step-time ratio for one config on this
    backend — the ``compile_pool`` calibration hook."""
    m = measured_decode_step_s(cfg, batch=batch, steps=steps)
    analytic = analytic_host_step_s(cfg, batch)
    return {**m, "analytic_step_s": analytic,
            "ratio": m["step_s"] / max(analytic, 1e-12)}
