"""Semi-real serving over the physical pool (DESIGN.md §16.4).

``AsyncRouterEngine`` serves through per-arm engine objects; this
module builds the pool's engine list:

* arms at or below ``serve_real_max_params`` get a :class:`DecodeArmEngine`
  — REAL jitted decode steps through ``repro.serving.engine.ServingEngine``
  (with ``reduced_decode=True`` the config's CPU-runnable ``reduced()``
  variant: still the real decode program, smoke-test weights);
* every other arm gets a :class:`RooflineArmEngine` — a clocked sleep of
  the pool's roofline step time per decode step, so the storm's wall
  and per-arm service times reflect the declared hardware without
  materializing 100B-scale weights.

Both expose the engine protocol the async engine expects:
``generate(tokens, max_new) -> (new_tokens (B, max_new), steps)``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.armpool.compile import CompiledArmPool
from repro.configs import get_config


class RooflineArmEngine:
    """Roofline-clocked stand-in for a large pool member: each generate
    call sleeps ``step_s * latency_scale`` per decode step and returns
    stub tokens. ``decode_steps`` counts CLOCKED steps (kept separate
    from the real-decode counter)."""

    def __init__(self, name: str, step_s: float, *,
                 latency_scale: float = 1.0, max_seq: int = 4096):
        self.name = name
        self.step_s = float(step_s)
        self.latency_scale = float(latency_scale)
        self.max_seq = max_seq
        self.decode_steps = 0
        self.real_decode = False

    def generate(self, tokens, max_new: int = 8) -> Tuple[np.ndarray, int]:
        B = np.asarray(tokens).shape[0]
        steps = int(max_new)
        wait = self.step_s * self.latency_scale * steps
        if wait > 0:
            time.sleep(wait)
        self.decode_steps += steps
        return np.ones((B, max_new), np.int32), steps


class DecodeArmEngine:
    """A real pool member: greedy decode through the jitted serving
    engine. ``decode_steps`` counts REAL decode-step dispatches (the
    acceptance criterion's ">= 1 arm executes real jitted decode
    steps" evidence, surfaced in the storm metrics)."""

    def __init__(self, name: str, cfg, *, max_seq: int = 64,
                 seed: int = 0, warm: bool = True):
        from repro.serving.engine import ServingEngine

        self.name = name
        self.cfg = cfg
        self.max_seq = max_seq
        self.engine = ServingEngine(cfg, seed=seed, max_seq=max_seq)
        self.decode_steps = 0
        self.real_decode = True
        if warm:   # keep the one-off jit compile out of the storm wall
            self.generate(np.ones((1, 1), np.int32), max_new=2)
            self.decode_steps = 0

    def generate(self, tokens, max_new: int = 8) -> Tuple[np.ndarray, int]:
        import jax.numpy as jnp

        toks = np.asarray(tokens, np.int64)
        # clamp into the (possibly reduced) vocab and cache budget
        toks = np.clip(toks, 0, self.cfg.vocab_size - 1)
        keep = max(1, self.max_seq - max_new - 1)
        toks = toks[:, -keep:]
        new, steps = self.engine.generate(jnp.asarray(toks, jnp.int32),
                                          max_new=max_new)
        # prefill replays the prompt through width-1 decode steps, so
        # the real dispatch count per call is prompt + (max_new - 1)
        self.decode_steps += toks.shape[1] + steps
        return np.asarray(new), steps


def build_arm_engines(pool: CompiledArmPool, aspec
                      ) -> Tuple[List, Dict[str, object]]:
    """Pool -> per-arm engine list (+ an info block for the artifact).

    Raises on a pool/spec K disagreement (the engines MUST line up
    with the pool's arm order — the router's arm ids index this list).
    """
    pool.validate_against(len(pool.arms), what="engine list")
    engines: List = []
    real, clocked = [], []
    for a, name in enumerate(pool.arms):
        params = float(pool.params_b[a]) * 1e9
        if params <= aspec.serve_real_max_params:
            cfg = get_config(name)
            if aspec.reduced_decode:
                cfg = cfg.reduced()
            engines.append(DecodeArmEngine(name, cfg,
                                           seed=int(pool.checksum % 997)))
            real.append(name)
        else:
            engines.append(RooflineArmEngine(
                name, float(pool.step_s[a]),
                latency_scale=aspec.latency_scale))
            clocked.append(name)
    if not real and not clocked:
        raise ValueError("arm pool produced no engines")
    info = {"real_decode_arms": real, "roofline_clocked_arms": clocked,
            "reduced_decode": bool(aspec.reduced_decode),
            "latency_scale": float(aspec.latency_scale)}
    return engines, info


def engine_decode_steps(engines) -> Dict[str, int]:
    """Post-storm accounting: arm name -> decode steps executed,
    split by real vs clocked."""
    out = {"real": {}, "clocked": {}}
    for e in engines:
        bucket = "real" if getattr(e, "real_decode", False) else "clocked"
        out[bucket][e.name] = int(getattr(e, "decode_steps", 0))
    return out
