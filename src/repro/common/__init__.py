from repro.common.config import ModelConfig, InputShape, INPUT_SHAPES
from repro.common.tree import tree_size, tree_bytes, tree_finite

__all__ = [
    "ModelConfig",
    "InputShape",
    "INPUT_SHAPES",
    "tree_size",
    "tree_bytes",
    "tree_finite",
]
