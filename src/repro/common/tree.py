"""Pytree utilities (no flax/optax available — everything is plain pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(tree)
    )


def tree_finite(tree) -> bool:
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(tree)
              if jnp.issubdtype(x.dtype, jnp.floating)]
    if not leaves:
        return True
    return bool(jax.device_get(jnp.all(jnp.stack(leaves))))


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_cast(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )
