"""Configuration dataclasses shared across the framework.

``ModelConfig`` describes every architecture family in the pool with a
single schema; family-specific fields default to "off" (0 / False).
``InputShape`` describes the assigned benchmark input shapes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description.

    arch_type in {dense, moe, ssm, hybrid, audio, vlm}.
    """

    name: str
    arch_type: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1  # an MoE FFN every N layers (jamba: 2); dense FFN else
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # --- hybrid (jamba): one attention layer per `attn_every` layers ---
    attn_every: int = 0

    # --- attention pattern ---
    sliding_window: int = 0  # 0 = full attention
    # gemma-style local:global -> layer i is GLOBAL iff (i % (ratio+1)) == ratio
    local_global_ratio: int = 0
    # cap on global-attention KV during long-context decode (see DESIGN.md)
    global_attn_cap: int = 32768

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0

    # --- VLM: a gated cross-attention layer every N decoder layers ---
    cross_attn_every: int = 0
    num_image_tokens: int = 1601  # llama-3.2-vision: 1601 patch tokens/tile

    # --- audio stub frontend ---
    num_audio_frames: int = 1500  # whisper: 30s -> 1500 frames

    # --- misc ---
    remat: str = "layer"  # activation checkpointing for train: none|layer
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    citation: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def attn_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def supports_long_decode(self) -> bool:
        """True when 524k-token decode is sub-quadratic (see DESIGN.md)."""
        if self.arch_type in ("ssm",):
            return True
        if self.arch_type == "hybrid":
            return True  # attn layers bounded by sliding window / cap
        return self.sliding_window > 0 or self.local_global_ratio > 0

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper via its text decoder)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        return _param_count(self, active_only=True)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=256, <=4 experts."""
        d_model = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads)
        upd = dict(
            name=self.name + "-reduced",
            num_layers=2,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=64,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            ssm_state=min(self.ssm_state, 64) if self.ssm_state else 0,
            ssm_chunk=32,
            num_image_tokens=16,
            num_audio_frames=32,
            global_attn_cap=128,
        )
        if self.num_experts:
            upd["num_experts"] = min(self.num_experts, 4)
            upd["experts_per_token"] = min(self.experts_per_token, 2)
            # no capacity drops at toy scale: keeps decode == forward exactly
            upd["moe_capacity_factor"] = 8.0
        if self.num_encoder_layers:
            upd["num_encoder_layers"] = 2
        if self.attn_every:
            upd["attn_every"] = 2
            upd["num_layers"] = 4  # two (1 mamba + 1 attn) super-blocks
        if self.cross_attn_every:
            upd["cross_attn_every"] = 2
            upd["num_layers"] = 4
        if self.local_global_ratio:
            upd["local_global_ratio"] = 1
            upd["sliding_window"] = min(self.sliding_window or 128, 128)
        elif self.sliding_window:
            upd["sliding_window"] = 128
        return dataclasses.replace(self, **upd)


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    q = cfg.num_heads * hd
    kv = cfg.num_kv_heads * hd

    def attn_params() -> int:
        return d * q + 2 * d * kv + q * d

    def dense_ffn() -> int:
        return 3 * d * cfg.d_ff  # gate/up/down (SwiGLU)

    def moe_ffn() -> int:
        n = cfg.experts_per_token if active_only else cfg.num_experts
        return n * 3 * d * cfg.d_ff + d * cfg.num_experts  # experts + router

    def mamba_params() -> int:
        d_inner = cfg.ssm_expand * d
        nheads = d_inner // cfg.ssm_head_dim
        in_proj = d * (2 * d_inner + 2 * cfg.ssm_state + nheads)
        conv = cfg.ssm_conv_width * (d_inner + 2 * cfg.ssm_state)
        out = d_inner * d
        return in_proj + conv + out + 2 * nheads  # + A_log, D

    total = cfg.vocab_size * d  # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d  # unembed
    norms = 2 * d

    if cfg.arch_type == "ssm":
        total += cfg.num_layers * (mamba_params() + d)
        return total

    for i in range(cfg.num_layers):
        mixer_is_attn = True
        if cfg.attn_every:
            mixer_is_attn = (i % cfg.attn_every) == (cfg.attn_every - 1)
        total += attn_params() if mixer_is_attn else mamba_params()
        if cfg.is_moe and (i % cfg.moe_every) == (cfg.moe_every - 1):
            total += moe_ffn()
        elif cfg.d_ff:
            total += dense_ffn()
        total += norms
        if cfg.cross_attn_every and (i % cfg.cross_attn_every) == (
            cfg.cross_attn_every - 1
        ):
            total += attn_params() + d  # gated cross-attention block

    if cfg.is_encoder_decoder:
        # encoder self-attn + ffn, plus decoder cross-attention per layer
        total += cfg.num_encoder_layers * (attn_params() + dense_ffn() + norms)
        total += cfg.num_layers * (attn_params() + d)
    return total


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
