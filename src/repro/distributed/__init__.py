from repro.distributed.api import (
    logical_axis_rules,
    shard,
    logical_to_spec,
    current_rules,
    current_mesh,
    run_sweep_multihost,
)

__all__ = [
    "logical_axis_rules",
    "shard",
    "logical_to_spec",
    "current_rules",
    "current_mesh",
    "run_sweep_multihost",
]
