"""Per-architecture sharding rules (DESIGN.md §7).

Two halves:

* ``activation_rules(cfg, shape, mesh)`` — logical-axis -> mesh-axis map
  consumed by the ``shard()`` constraints inside the model code. Chosen
  per arch so every sharded dim divides the mesh axis (e.g. llama3.2-3b
  has 24 heads, not divisible by 16-way model parallelism, so its TP axis
  is head_dim instead of heads).
* ``param_partition_specs(cfg, params)`` — PartitionSpec pytree for the
  weights: column/row tensor parallelism over "model", FSDP over
  ("pod","data"), expert parallelism over "model" for MoE tables.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.common.config import ModelConfig, InputShape

Axis = Union[str, Tuple[str, ...], None]


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _div(n: int, k: int) -> bool:
    return n % k == 0 and n > 0


def activation_rules(cfg: ModelConfig, shape: InputShape, mesh,
                     decode: bool = False) -> Dict[str, Axis]:
    sizes = _mesh_axis_sizes(mesh)
    mp = sizes.get("model", 1)
    baxes = _batch_axes(mesh)
    bsize = int(np.prod([sizes[a] for a in baxes]))

    rules: Dict[str, Axis] = {}
    rules["batch"] = baxes if _div(shape.global_batch, bsize) else None
    rules["seq"] = None
    rules["frames"] = None
    rules["patches"] = None
    rules["vocab"] = "model"  # vocab is padded to a /256 multiple
    rules["ffn"] = "model" if _div(cfg.d_ff, mp) else None
    rules["experts"] = "model" if _div(cfg.num_experts, mp) else None

    hd = cfg.resolved_head_dim
    rules["attn_q_seq"] = None
    if not decode and _div(cfg.num_heads, mp):
        rules["heads"] = "model"
        rules["kv_heads"] = "model" if _div(cfg.num_kv_heads, mp) else None
        rules["head_dim"] = None
    elif not decode:
        # head count does not divide the model axis (llama3.2-3b: 24 heads,
        # gemma3: 8 heads): context-parallel attention — the score tensor
        # is sharded over the QUERY-sequence dim instead of heads, the QKV
        # projections stay TP over head_dim.
        rules["heads"] = None
        rules["kv_heads"] = None
        rules["head_dim"] = "model" if _div(hd, mp) else None
        rules["attn_q_seq"] = "model" if _div(shape.seq_len, mp) else None
    else:
        # decode: single-token queries; TP over head_dim keeps the KV cache
        # sharded without head-divisibility constraints
        rules["heads"] = None
        rules["kv_heads"] = None
        rules["head_dim"] = "model" if _div(hd, mp) else None

    if cfg.ssm_state:
        d_inner = cfg.ssm_expand * cfg.d_model
        nheads = d_inner // cfg.ssm_head_dim
        if _div(nheads, mp):
            rules["ssm_heads"] = "model"
            rules["ssm_pdim"] = None
        else:
            rules["ssm_heads"] = None
            rules["ssm_pdim"] = "model" if _div(cfg.ssm_head_dim, mp) else None
    return rules


# ---------------------------------------------------------------------------
# protocol-engine sweep sharding
# ---------------------------------------------------------------------------


def sweep_lane_sharding(n_items: int):
    """NamedSharding for an ``n_items``-wide sweep lane axis, or None
    when sharding buys nothing (single device, or no device count > 1
    divides the axis). Picks the largest local-device count that divides
    the axis so no grid shape is rejected. Factored out of
    :func:`shard_sweep_axis` so the policy-zoo sweep (DESIGN.md §10) can
    lay out EVERY policy's lane tree with one consistent rule even when
    their grid sizes differ."""
    devs = jax.local_devices()
    nd = len(devs)
    while nd > 1 and n_items % nd:
        nd -= 1
    if nd <= 1:
        return None
    mesh = jax.sharding.Mesh(np.asarray(devs[:nd]), ("sweep",))
    return jax.sharding.NamedSharding(mesh, P("sweep"))


def shard_sweep_axis(tree, n_items: Optional[int] = None):
    """Shard the leading (sweep) axis of every leaf across local devices.

    Legacy path (kept for external callers): when no device count > 1
    divides the axis this silently degrades toward 1 device. The engine's
    sweep runner now pads the lane axis instead — see
    :func:`sweep_lane_layout` / :func:`pad_sweep_lanes` — so every local
    device always carries an equal lane shard. Identity on a single
    device (CPU CI) so callers need no gating.
    """
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return tree
    n = n_items if n_items is not None else int(leaves[0].shape[0])
    sharding = sweep_lane_sharding(n)
    if sharding is None:
        return tree
    return jax.tree.map(lambda x: jax.device_put(x, sharding), tree)


class SweepLaneLayout(NamedTuple):
    """How a flattened (grid x seed) lane axis maps onto a sweep mesh:
    ``n_lanes`` real lanes + ``pad`` dead lanes = a multiple of the
    ``grid * seed`` device count, so the lane shard per device is always
    equal-sized (no silent degrade to fewer devices). Dead lanes replay
    lane 0 and are sliced off before any result leaves the runner.

    ``hosts`` records how many ``jax.distributed`` processes contribute
    devices to the mesh (1 off-cluster) — host TOPOLOGY only, never
    which host produced the artifact, so manifests from every worker of
    a multi-host sweep and from an equivalent single-host run with the
    same mesh are byte-identical (DESIGN.md §15.3)."""
    n_lanes: int
    pad: int
    grid: int
    seed: int
    hosts: int = 1

    @property
    def total(self) -> int:
        return self.n_lanes + self.pad

    @property
    def n_devices(self) -> int:
        return self.grid * self.seed

    def manifest(self) -> Dict[str, object]:
        """JSON-ready layout record for sweep result manifests."""
        return {"n_lanes": int(self.n_lanes), "pad": int(self.pad),
                "n_devices": int(self.n_devices),
                "mesh": {"grid": int(self.grid), "seed": int(self.seed)},
                "hosts": {"n_hosts": int(self.hosts),
                          "devices_per_host":
                              int(self.n_devices) // int(self.hosts)}}


def sweep_lane_layout(n_lanes: int, mesh=None) -> SweepLaneLayout:
    """Layout for ``n_lanes`` sweep lanes on ``mesh`` (a ("grid","seed")
    mesh from :func:`repro.launch.mesh.make_sweep_mesh`; None = all
    local devices on a 1 x nd seed row). Host topology is read off the
    mesh's device set, so a ``span="global"`` mesh yields a multi-host
    layout and a local mesh always yields ``hosts=1``."""
    if mesh is not None:
        g, s = (int(d) for d in mesh.devices.shape)
        hosts = len({d.process_index for d in mesh.devices.flat})
    else:
        g, s = 1, len(jax.local_devices())
        hosts = 1
    nd = g * s
    return SweepLaneLayout(n_lanes=int(n_lanes), pad=(-int(n_lanes)) % nd,
                           grid=g, seed=s, hosts=max(1, hosts))


def process_lane_slice(n_grid: int, n_seeds: int, n_procs: int,
                       proc: int) -> Tuple[int, int, int, int]:
    """Contiguous work span owned by one process of a multi-host sweep.

    Returns ``(g_start, g_stop, lane_start, lane_stop)``: process ``p``
    of ``h`` owns grid points ``[p*G//h, (p+1)*G//h)`` — whole grid
    points, never split seeds, so every process's slice is a clean
    (g, n_seeds, ...) block — which in the seed-major flattened lane
    axis is lanes ``[g_start*n_seeds, g_stop*n_seeds)``. Spans are
    contiguous, disjoint, cover the grid exactly, and are empty (start
    == stop) for trailing processes when ``n_grid < n_procs``."""
    if not 0 <= proc < n_procs:
        raise ValueError(f"process_lane_slice: proc {proc} outside "
                         f"[0, {n_procs})")
    gs = proc * n_grid // n_procs
    ge = (proc + 1) * n_grid // n_procs
    return gs, ge, gs * n_seeds, ge * n_seeds


def pad_sweep_lanes(tree, pad: int):
    """Append ``pad`` dead lanes to every leaf's leading axis (each a
    broadcast copy of lane 0, so the padded program computes real —
    discarded — work instead of tracing a second shape)."""
    if pad <= 0:
        return tree

    def one(x):
        x = jnp.asarray(x)
        return jnp.concatenate(
            [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])], axis=0)
    return jax.tree.map(one, tree)


def shard_sweep_lanes(tree, mesh):
    """Shard every leaf's (padded) leading lane axis over both mesh axes
    (``P(("grid", "seed"))``). Identity on a 1-device mesh."""
    if mesh is None or int(np.prod(mesh.devices.shape)) <= 1:
        return tree
    sh = jax.sharding.NamedSharding(mesh, P(("grid", "seed")))
    return jax.tree.map(lambda x: jax.device_put(x, sh), tree)


# ---------------------------------------------------------------------------
# parameter partition specs
# ---------------------------------------------------------------------------


def param_partition_specs(cfg: ModelConfig, params, mesh) -> Dict:
    """PartitionSpec pytree matching ``params``' structure, keyed on the
    conventional parameter names used across repro.models."""
    sizes = _mesh_axis_sizes(mesh)
    mp = sizes.get("model", 1)
    fsdp = _batch_axes(mesh)
    fsdp_size = int(np.prod([sizes[a] for a in fsdp])) if fsdp else 1

    def spec_for(path: Tuple[str, ...], leaf) -> P:
        name = path[-1]
        shp = leaf.shape
        nlead = _num_stack_dims(path, shp, name)
        lead = (None,) * nlead
        core = shp[nlead:]

        def fs(dim_idx: int) -> Axis:
            return fsdp if fsdp and _div(core[dim_idx], fsdp_size) else None

        def tp(dim_idx: int) -> Axis:
            return "model" if _div(core[dim_idx], mp) else None

        # ---- embeddings / heads -----------------------------------------
        if name == "embed":
            return P(tp(0), None)           # vocab-parallel embedding
        if name == "unembed":
            return P(fs(0), tp(1))          # column-parallel logits
        # ---- attention ----------------------------------------------------
        if name in ("wq", "wk", "wv"):
            return P(*lead, fs(0), tp(1))
        if name == "wo":
            return P(*lead, tp(0), fs(1))
        # ---- dense FFN ------------------------------------------------------
        if name in ("wg", "wu") and len(core) == 2:
            return P(*lead, fs(0), tp(1))
        if name == "wd" and len(core) == 2:
            return P(*lead, tp(0), fs(1))
        # ---- MoE expert tables (E, D, F) / (E, F, D) -----------------------
        if name in ("wg", "wu") and len(core) == 3:
            return P(*lead, tp(0), fs(1), None)
        if name == "wd" and len(core) == 3:
            return P(*lead, tp(0), None, fs(2))
        if name == "router":
            return P(*lead, fs(0), None)
        # ---- mamba ----------------------------------------------------------
        if name == "in_proj":
            return P(*lead, fs(0), tp(1))
        if name == "out_proj":
            return P(*lead, tp(0), fs(1))
        if name in ("conv_w", "conv_b"):
            return P(*lead, *((None,) * len(core)))
        # ---- everything else (norms, gates, A_log, D, dt_bias, scalars) ----
        return P(*lead, *((None,) * len(core)))

    return _map_with_path(spec_for, params)


def _num_stack_dims(path: Tuple[str, ...], shp, name: str) -> int:
    """Count leading layer-stacking dims: any dict level named blocks /
    enc_blocks / dec_blocks / mamba / moe / ffn_dense / self adds one."""
    stacking = {"blocks", "enc_blocks", "dec_blocks"}
    inner_stacking = {"mamba", "moe", "ffn_dense", "self"}
    n = 0
    for p in path[:-1]:
        if p in stacking:
            n += 1
        elif p in inner_stacking:
            n += 1
    # guard against miscount: never exceed ndim - 2 for matrices
    core_nd = 2 if name in ("wq", "wk", "wv", "wo", "wg", "wu", "wd",
                            "in_proj", "out_proj", "router", "w") else None
    if name in ("wg", "wu", "wd") and len(shp) - n == 3:
        core_nd = 3
    if core_nd is not None:
        n = len(shp) - core_nd
    return max(n, 0)


def _map_with_path(fn, tree, path=()):
    if isinstance(tree, dict):
        return {k: _map_with_path(fn, v, path + (k,)) for k, v in tree.items()}
    return fn(path, tree)


# ---------------------------------------------------------------------------
# input / state specs
# ---------------------------------------------------------------------------


def batch_partition_specs(cfg: ModelConfig, shape: InputShape, mesh,
                          batch_spec_tree) -> Dict:
    baxes = _batch_axes(mesh)
    sizes = _mesh_axis_sizes(mesh)
    bsize = int(np.prod([sizes[a] for a in baxes])) if baxes else 1
    b = baxes if _div(shape.global_batch, bsize) else None

    def spec_for(path, leaf):
        return P(b, *((None,) * (len(leaf.shape) - 1)))

    return _map_with_path(spec_for, batch_spec_tree)


def cache_partition_specs(cfg: ModelConfig, shape: InputShape, mesh,
                          cache_spec_tree) -> Dict:
    """KV/state cache: batch over (pod, data) when divisible; the head_dim
    (attention) / P dim (mamba) over "model"."""
    sizes = _mesh_axis_sizes(mesh)
    mp = sizes.get("model", 1)
    baxes = _batch_axes(mesh)
    bsize = int(np.prod([sizes[a] for a in baxes])) if baxes else 1
    b = baxes if _div(shape.global_batch, bsize) else None
    hd = cfg.resolved_head_dim
    tp_hd = "model" if _div(hd, mp) else None
    tp_p = "model" if _div(cfg.ssm_head_dim, mp) else None

    def spec_for(path, leaf):
        name = path[-1]
        nd = len(leaf.shape)
        if name in ("pos", "offset"):
            return P()
        if name in ("k", "v"):
            # (L?, B, KV, S, hd)
            lead = (None,) * (nd - 4)
            return P(*lead, b, None, None, tp_hd)
        if name == "ssm":
            # (L?, B, H, P, N)
            lead = (None,) * (nd - 4)
            return P(*lead, b, None, tp_p, None)
        if name == "conv":
            lead = (None,) * (nd - 3)
            return P(*lead, b, None, None)
        if name == "image_embed":
            return P(b, None, None)
        return P(*((None,) * nd))

    return _map_with_path(spec_for, cache_spec_tree)
