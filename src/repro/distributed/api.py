"""Logical-axis sharding: models annotate activations/weights with *logical*
axis names; a rules context maps them to physical mesh axes (or to nothing,
on a single device). This is the flax `logical partitioning` pattern,
re-implemented on plain pjit since flax is unavailable.

Logical axes used across the zoo:
  batch, seq, kv_seq, d_model, heads, kv_heads, head_dim, ffn, vocab,
  experts, expert_ffn, ssm_heads, ssm_state, frames, patches, layers

Plus the multi-HOST sweep entry point (DESIGN.md §15.3):
:func:`run_sweep_multihost` runs the protocol-engine policy sweep under
``jax.distributed`` — each process executes its contiguous slice of the
hyper grid on its LOCAL ("grid", "seed") mesh, while the artifact's
layout manifest describes the GLOBAL topology mesh. Sweep lanes are
fully independent (no cross-lane collectives anywhere in the scan), so
per-process execution is semantically exact, works on backends without
cross-process programs (the CPU smoke in CI), and still removes every
inter-host communication from the hot loop on real pods.
"""
from __future__ import annotations

import functools
import math
import threading
from contextlib import contextmanager
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

_state = threading.local()


def current_rules() -> Optional[Dict[str, Union[str, Tuple[str, ...], None]]]:
    return getattr(_state, "rules", None)


@contextmanager
def logical_axis_rules(rules: Dict[str, Union[str, Tuple[str, ...], None]],
                       mesh=None):
    """Activate a logical->physical axis mapping for the enclosed trace."""
    prev = getattr(_state, "rules", None)
    prev_mesh = getattr(_state, "mesh", None)
    _state.rules = rules
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules = prev
        _state.mesh = prev_mesh


def current_mesh():
    return getattr(_state, "mesh", None)


def logical_to_spec(names: Sequence[Optional[str]],
                    rules: Optional[Dict] = None) -> P:
    rules = rules if rules is not None else current_rules()
    if rules is None:
        return P()
    out = []
    used = set()
    for n in names:
        if n is None:
            out.append(None)
            continue
        axis = rules.get(n)
        # a mesh axis may appear at most once per spec: first logical axis
        # wins (e.g. context-parallel seq beats head_dim on the same axis)
        flat = axis if isinstance(axis, tuple) else (axis,)
        if any(a in used for a in flat if a):
            out.append(None)
            continue
        used.update(a for a in flat if a)
        out.append(axis)
    # trim trailing Nones (cosmetic)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical axis names (no-op w/o rules)."""
    rules = current_rules()
    if rules is None:
        return x
    spec = logical_to_spec(names, rules)
    mesh = current_mesh()
    if mesh is not None:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# multi-host protocol sweeps
# ---------------------------------------------------------------------------


def _slice_grid(grid: Any, G: int, gs: int, ge: int) -> Any:
    """Slice a hypers grid pytree to grid points [gs, ge): (G,) leaves
    are sliced, scalar (broadcast) leaves pass through untouched — the
    same broadcast rule `sim.engine._flatten_lanes` applies."""
    def one(x):
        x = jnp.asarray(x)
        if x.ndim >= 1 and x.shape[0] == G:
            return x[gs:ge]
        return x
    return jax.tree.map(one, grid)


def run_sweep_multihost(env, policies: Dict[str, Tuple[Any, Any]], *,
                        seeds: Sequence[int], **kwargs) -> Dict[str, Dict]:
    """`sim.engine.run_policy_sweep` across every ``jax.distributed``
    process: this process runs ONLY its :func:`process_lane_slice` of
    each policy's hyper grid (whole grid points, seed-major lanes), on
    its local mesh. Single-process (``jax.process_count() == 1``) this
    degenerates to a plain full-grid sweep with the same annotations.

    Returns the `run_policy_sweep` schema per policy, with metric leaves
    shaped ``(g_stop - g_start, n_seeds, T, ...)`` — this worker's grid
    rows — plus the multi-host annotations:

    * ``layout`` — the GLOBAL topology mesh manifest (host-invariant:
      every worker and an equivalent single-host run emit the same
      bytes; `scripts/run_distributed_sweep_smoke.py` pins this);
    * ``grid_span`` / ``lane_span`` — the [start, stop) grid-point and
      flattened-lane spans this artifact holds (host-variant by
      construction: they say which rows these are);
    * ``n_grid_total`` — the full grid size, so a driver can
      concatenate worker artifacts back into the single-host layout.

    A process whose span is empty (more processes than grid points)
    returns metric-less stubs carrying only the annotations."""
    from repro.launch.mesh import make_sweep_mesh
    from repro.distributed.sharding import (process_lane_slice,
                                            sweep_lane_layout)
    from repro.sim.engine import _grid_size, run_policy_sweep

    proc, nproc = jax.process_index(), jax.process_count()
    seeds = list(seeds)
    n_seeds = len(seeds)
    gsizes = {name: _grid_size(grid) for name, (_, grid) in policies.items()}
    # one topology mesh for the whole study, same gcd factorization rule
    # as the execution mesh run_policy_sweep builds locally
    gmesh = make_sweep_mesh(
        functools.reduce(math.gcd, gsizes.values(), 0) or 1, n_seeds,
        span="global")
    spans, sliced = {}, {}
    for name, (pol, grid) in policies.items():
        span = process_lane_slice(gsizes[name], n_seeds, nproc, proc)
        spans[name] = span
        if span[1] > span[0]:
            sliced[name] = (pol, _slice_grid(grid, gsizes[name],
                                             span[0], span[1]))
    out = (run_policy_sweep(env, sliced, seeds=seeds, **kwargs)
           if sliced else {})
    for name in policies:
        d = out.setdefault(name, {})
        gs, ge, ls, le = spans[name]
        d["layout"] = sweep_lane_layout(gsizes[name] * n_seeds,
                                        gmesh).manifest()
        d["grid_span"] = [int(gs), int(ge)]
        d["lane_span"] = [int(ls), int(le)]
        d["n_grid_total"] = int(gsizes[name])
    return out
