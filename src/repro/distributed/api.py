"""Logical-axis sharding: models annotate activations/weights with *logical*
axis names; a rules context maps them to physical mesh axes (or to nothing,
on a single device). This is the flax `logical partitioning` pattern,
re-implemented on plain pjit since flax is unavailable.

Logical axes used across the zoo:
  batch, seq, kv_seq, d_model, heads, kv_heads, head_dim, ffn, vocab,
  experts, expert_ffn, ssm_heads, ssm_state, frames, patches, layers
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def current_rules() -> Optional[Dict[str, Union[str, Tuple[str, ...], None]]]:
    return getattr(_state, "rules", None)


@contextmanager
def logical_axis_rules(rules: Dict[str, Union[str, Tuple[str, ...], None]],
                       mesh=None):
    """Activate a logical->physical axis mapping for the enclosed trace."""
    prev = getattr(_state, "rules", None)
    prev_mesh = getattr(_state, "mesh", None)
    _state.rules = rules
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules = prev
        _state.mesh = prev_mesh


def current_mesh():
    return getattr(_state, "mesh", None)


def logical_to_spec(names: Sequence[Optional[str]],
                    rules: Optional[Dict] = None) -> P:
    rules = rules if rules is not None else current_rules()
    if rules is None:
        return P()
    out = []
    used = set()
    for n in names:
        if n is None:
            out.append(None)
            continue
        axis = rules.get(n)
        # a mesh axis may appear at most once per spec: first logical axis
        # wins (e.g. context-parallel seq beats head_dim on the same axis)
        flat = axis if isinstance(axis, tuple) else (axis,)
        if any(a in used for a in flat if a):
            out.append(None)
            continue
        used.update(a for a in flat if a)
        out.append(axis)
    # trim trailing Nones (cosmetic)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical axis names (no-op w/o rules)."""
    rules = current_rules()
    if rules is None:
        return x
    spec = logical_to_spec(names, rules)
    mesh = current_mesh()
    if mesh is not None:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)
