"""UtilityNet (paper §3.2, Figure 1).

Branches:
  f_text :  x_emb -> h_emb                     (text encoder MLP)
  Emb_d  :  domain id -> e_d
  f_feat :  [x_feat, e_d] -> h_feat            (auxiliary feature encoder)
  Emb_a  :  action id -> e_a
  trunk  :  z_u = [h_emb, h_feat, e_a] -> h(x,a)  (last hidden, fed to UCB)
  u-head :  h(x,a) -> mu(x,a)                  (utility regression, Huber)
  gate   :  z_g = [h_emb, h_feat] -> p(x)      (BCE; activates UCB bonus)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class UtilityNetConfig:
    emb_dim: int = 384          # text-encoder embedding dim
    feat_dim: int = 4           # auxiliary scalar features
    num_domains: int = 86
    num_actions: int = 11
    d_domain: int = 16
    d_action: int = 16
    d_text: int = 256
    d_feat: int = 32
    d_hidden: int = 256
    d_last: int = 128           # h(x,a) — the NeuralUCB feature width
    d_gate: int = 64
    huber_delta: float = 1.0

    @property
    def ucb_feature_dim(self) -> int:
        return self.d_last + 1  # [h; 1] bias augmentation (paper §3.3)


def _linear(key, n_in, n_out):
    w = jax.random.normal(key, (n_in, n_out), jnp.float32)
    return {"w": w / jnp.sqrt(n_in), "b": jnp.zeros((n_out,), jnp.float32)}


def _apply(p, x):
    return x @ p["w"] + p["b"]


def init_utilitynet(key, cfg: UtilityNetConfig) -> Dict:
    ks = jax.random.split(key, 10)
    return {
        "text1": _linear(ks[0], cfg.emb_dim, cfg.d_text),
        "text2": _linear(ks[1], cfg.d_text, cfg.d_text),
        "emb_d": jax.random.normal(ks[2], (cfg.num_domains, cfg.d_domain)) * 1.0,
        "feat": _linear(ks[3], cfg.feat_dim + cfg.d_domain, cfg.d_feat),
        "emb_a": jax.random.normal(ks[4], (cfg.num_actions, cfg.d_action)) * 1.0,
        "trunk1": _linear(ks[5], cfg.d_text + cfg.d_feat + cfg.d_action,
                          cfg.d_hidden),
        "trunk2": _linear(ks[6], cfg.d_hidden, cfg.d_last),
        "u_head": _linear(ks[7], cfg.d_last, 1),
        "gate1": _linear(ks[8], cfg.d_text + cfg.d_feat, cfg.d_gate),
        "gate2": _linear(ks[9], cfg.d_gate, 1),
    }


def _context_encode(params, x_emb, x_feat, domain):
    # normalize embeddings (pre-trained sentence encoders are ~unit norm;
    # LayerNorm-free input standardization keeps the bandit features stable)
    x_emb = x_emb / jnp.maximum(
        jnp.linalg.norm(x_emb, axis=-1, keepdims=True), 1e-6)
    h = jax.nn.gelu(_apply(params["text1"], x_emb))
    h_emb = jax.nn.gelu(_apply(params["text2"], h))
    e_d = params["emb_d"][domain]
    h_feat = jax.nn.gelu(_apply(params["feat"],
                                jnp.concatenate([x_feat, e_d], axis=-1)))
    return h_emb, h_feat


def utilitynet_apply(params: Dict, x_emb, x_feat, domain, action
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single (context, action) pair per row.

    x_emb: (B, E); x_feat: (B, F); domain, action: (B,) int32.
    Returns (mu (B,), h (B, d_last), gate_p (B,)).
    """
    h_emb, h_feat = _context_encode(params, x_emb, x_feat, domain)
    e_a = params["emb_a"][action]
    z_u = jnp.concatenate([h_emb, h_feat, e_a], axis=-1)
    h = jax.nn.gelu(_apply(params["trunk1"], z_u))
    h = jax.nn.gelu(_apply(params["trunk2"], h))
    mu = _apply(params["u_head"], h)[..., 0]
    z_g = jnp.concatenate([h_emb, h_feat], axis=-1)
    g = jax.nn.gelu(_apply(params["gate1"], z_g))
    gate_p = jax.nn.sigmoid(_apply(params["gate2"], g))[..., 0]
    return mu, h, gate_p


def utilitynet_all_actions(params: Dict, cfg: UtilityNetConfig,
                           x_emb, x_feat, domain
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Score every action for each context.

    Returns (mu (B, K), h (B, K, d_last), gate_p (B,)).
    """
    B = x_emb.shape[0]
    h_emb, h_feat = _context_encode(params, x_emb, x_feat, domain)
    ctx = jnp.concatenate([h_emb, h_feat], axis=-1)       # (B, C)
    e_a = params["emb_a"]                                  # (K, A)
    K = e_a.shape[0]
    z_u = jnp.concatenate(
        [jnp.broadcast_to(ctx[:, None], (B, K, ctx.shape[-1])),
         jnp.broadcast_to(e_a[None], (B, K, e_a.shape[-1]))], axis=-1)
    h = jax.nn.gelu(_apply(params["trunk1"], z_u))
    h = jax.nn.gelu(_apply(params["trunk2"], h))
    mu = _apply(params["u_head"], h)[..., 0]
    g = jax.nn.gelu(_apply(params["gate1"], ctx))
    gate_p = jax.nn.sigmoid(_apply(params["gate2"], g))[..., 0]
    return mu, h, gate_p


def huber(pred, target, delta: float = 1.0):
    err = pred - target
    abs_e = jnp.abs(err)
    quad = jnp.minimum(abs_e, delta)
    return 0.5 * quad ** 2 + delta * (abs_e - quad)


def utilitynet_loss(params: Dict, cfg: UtilityNetConfig, batch: Dict
                    ) -> Tuple[jax.Array, Dict]:
    """batch: x_emb, x_feat, domain, action, reward, gate_label, gate_mask."""
    mu, _, gate_p = utilitynet_apply(params, batch["x_emb"], batch["x_feat"],
                                     batch["domain"], batch["action"])
    l_u = jnp.mean(huber(mu, batch["reward"], cfg.huber_delta))
    p = jnp.clip(gate_p, 1e-6, 1 - 1e-6)
    y = batch["gate_label"]
    bce = -(y * jnp.log(p) + (1 - y) * jnp.log(1 - p))
    gm = batch.get("gate_mask", jnp.ones_like(y))
    l_g = jnp.sum(bce * gm) / jnp.maximum(jnp.sum(gm), 1.0)
    loss = l_u + 0.5 * l_g
    return loss, {"loss_u": l_u, "loss_gate": l_g}
