"""Simulated online protocol (paper Algorithm 1).

20 slices processed sequentially; per slice: DECIDE every sample, UPDATE
the buffer + shared A^-1, TRAIN UtilityNet for E replay epochs, REBUILD
A^-1. Metrics tracked per slice for every policy: average reward,
cumulative reward, cost, selected quality, action rates — everything the
paper's Figures 2-4 plot.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.policy import NeuralUCBRouter
from repro.data.routerbench import RouterBenchSim


def run_protocol(env: RouterBenchSim, policies: Dict[str, object], *,
                 epochs: int = 5, verbose: bool = True,
                 max_slices: Optional[int] = None) -> Dict[str, Dict]:
    """Run every policy over the same slice stream (offline replay gives all
    policies identical queries and per-action feedback tables).

    Returns {policy: {"avg_reward": [...], "cum_reward": [...],
                      "avg_cost": [...], "avg_quality": [...],
                      "action_hist": (T, K), "wall_s": [...]}}.
    """
    T = env.n_slices if max_slices is None else min(env.n_slices, max_slices)
    K = env.K
    results = {
        name: {"avg_reward": [], "cum_reward": [], "avg_cost": [],
               "avg_quality": [], "action_hist": np.zeros((T, K)),
               "wall_s": []}
        for name in policies
    }
    cum = {name: 0.0 for name in policies}

    for t in range(T):
        batch = env.slice_batch(t)
        n = len(batch["idx"])
        for name, pol in policies.items():
            t0 = time.time()
            if isinstance(pol, NeuralUCBRouter):
                dec = pol.decide(batch["x_emb"], batch["x_feat"],
                                 batch["domain"])
                a = dec["action"]
                r = batch["reward"][np.arange(n), a]
                pol.update(batch["x_emb"], batch["x_feat"], batch["domain"],
                           dec, r)
                pol.end_slice(epochs)
            else:
                a = pol.decide(batch["x_emb"], batch["x_feat"],
                               batch["domain"])
                r = batch["reward"][np.arange(n), a]
                if hasattr(pol, "update"):
                    pol.update(batch["x_emb"], batch["x_feat"],
                               batch["domain"], a, r)
                pol.end_slice()
            q = batch["quality"][np.arange(n), a]
            c = batch["cost"][np.arange(n), a]
            cum[name] += float(r.sum())
            res = results[name]
            res["avg_reward"].append(float(r.mean()))
            res["cum_reward"].append(cum[name])
            res["avg_cost"].append(float(c.mean()))
            res["avg_quality"].append(float(q.mean()))
            res["action_hist"][t] = np.bincount(a, minlength=K)
            res["wall_s"].append(time.time() - t0)
        if verbose:
            line = " ".join(
                f"{name}={results[name]['avg_reward'][-1]:.3f}"
                for name in policies)
            print(f"[slice {t + 1:2d}/{T}] avg_reward: {line}", flush=True)
    return results


def summarize(results: Dict[str, Dict], skip_first: bool = True) -> Dict:
    """Paper-style summary: slice-1 is warm-start-affected and excluded
    from formal comparison (paper §4.2).

    When a result carries the engine's per-slice ``oracle_avg_reward``
    (the best AVAILABLE arm under that slice's effective tables —
    DESIGN.md §9.3), the summary adds dynamic-regret accounting:
    ``dynamic_regret`` is the summed per-slice average shortfall against
    the dynamic oracle over the compared slices, so stationary and
    drifting runs report directly comparable numbers. All values are
    plain Python floats (JSON-serializable)."""
    out = {}
    for name, res in results.items():
        s = 1 if skip_first and len(res["avg_reward"]) > 1 else 0
        summ = {
            "avg_reward": float(np.mean(res["avg_reward"][s:])),
            "final_cum_reward": float(res["cum_reward"][-1]),
            "avg_cost": float(np.mean(res["avg_cost"][s:])),
            "avg_quality": float(np.mean(res["avg_quality"][s:])),
        }
        if "oracle_avg_reward" in res:
            o = np.asarray(res["oracle_avg_reward"][s:], np.float64)
            r = np.asarray(res["avg_reward"][s:], np.float64)
            summ["oracle_avg_reward"] = float(o.mean())
            summ["dynamic_regret"] = float(np.sum(o - r))
            summ["dynamic_regret_per_slice"] = float(np.mean(o - r))
        out[name] = summ
    return out


def summarize_sweep(sweep: Dict, skip_first: bool = True) -> List[Dict]:
    """Summarize ONE policy's grid-annotated sweep (the unified
    ``repro.sim.run_policy_sweep`` schema: metric leaves shaped
    (G, n_seeds, T, ...) plus a ``grid`` dict of (G,) hyper arrays).

    Returns a list of G per-grid-point summaries, each with the point's
    hyper values and the seed-aggregated mean ± std of the standard
    paper metrics (slice 1 excluded per §4.2, as in :func:`summarize`).
    Works for every registered policy — baselines have G=1 and an empty
    grid dict. Values are plain Python floats (JSON-serializable)."""
    r = np.asarray(sweep["avg_reward"], np.float64)       # (G, n_seeds, T)
    G, _, T = r.shape
    s0 = 1 if skip_first and T > 1 else 0
    grid = sweep.get("grid", {})
    points = []
    for g in range(G):
        p = {f: float(np.asarray(v).reshape(-1)[g]) for f, v in grid.items()}
        for key in ("avg_reward", "avg_cost", "avg_quality",
                    "oracle_avg_reward", "mean_logp"):
            if key in sweep:
                per_seed = np.asarray(sweep[key], np.float64)[g, :, s0:]
                p[f"{key}_mean"] = float(per_seed.mean(axis=1).mean())
                p[f"{key}_std"] = float(per_seed.mean(axis=1).std())
        if "oracle_avg_reward" in sweep:
            o = np.asarray(sweep["oracle_avg_reward"], np.float64)[g, :, s0:]
            p["dynamic_regret_mean"] = float((o - r[g, :, s0:]).sum(axis=1)
                                             .mean())
        if "sum_reward" in sweep:
            cum = np.asarray(sweep["sum_reward"], np.float64)[g].sum(axis=1)
            p["final_cum_reward_mean"] = float(cum.mean())
        points.append(p)
    return points


# ------------------------------- off-policy evaluation (DESIGN.md §13.4) --
def estimate_offline(logged, target_probs: np.ndarray, *,
                     qhat: Optional[np.ndarray] = None,
                     clip: Optional[float] = None) -> Dict[str, float]:
    """Counterfactual value estimates of a TARGET policy from one logged
    run (Causal LLM Routing, PAPERS.md): score a policy that never ran.

    ``logged`` is a :class:`repro.data.logged.LoggedInteractions` from a
    propensity-aware producer; ``target_probs`` (n, K) is the target
    policy's action distribution per logged context (rows sum to 1);
    ``qhat`` (n, K), when given, is a direct-method reward model enabling
    the doubly-robust estimator. Returns per-request value estimates:

    * ``ips``   — inverse-propensity scoring, mean(w_i * r_i) with
      w_i = pi_t(a_i | x_i) / pi_b(a_i | x_i). Unbiased, high variance.
    * ``snips`` — self-normalized IPS, sum(w r) / sum(w). Biased
      O(1/n), far lower variance; invariant to propensity scale.
    * ``dm``    — direct method, mean_i sum_k pi_t(k|x_i) qhat[i, k]
      (NaN without ``qhat``). Biased by the reward model.
    * ``dr``    — doubly robust, dm + mean(w (r - qhat[i, a_i])).
      Unbiased when EITHER the propensities or qhat are right.
    * ``ess``   — Kish effective sample size of the weights, the
      reliability diagnostic (ess << n means the log barely covers the
      target).

    ``clip`` truncates importance weights at that value (bias-variance
    knob; SNIPS/DR use the clipped weights too). Fails loudly on logs
    without propensities — a producer that cannot state pi_b cannot feed
    counterfactual estimates (satellite b)."""
    if not logged.has_propensities:
        raise ValueError(
            f"estimate_offline: log from {logged.behavior!r} carries no "
            "propensities (logp=None) — only propensity-aware producers "
            "(record_log runs, replay_corpus, serving to_logged) can "
            "feed counterfactual estimates")
    n = logged.n
    tp = np.asarray(target_probs, np.float64)
    if tp.shape != (n, logged.num_actions):
        raise ValueError(
            f"estimate_offline: target_probs shape {tp.shape} != "
            f"(n={n}, K={logged.num_actions})")
    r = np.asarray(logged.reward, np.float64)
    a = np.asarray(logged.action)
    rows = np.arange(n)
    pb = np.exp(np.asarray(logged.logp, np.float64))
    w = tp[rows, a] / np.maximum(pb, 1e-12)
    if clip is not None:
        w = np.minimum(w, float(clip))
    out = {
        "ips": float((w * r).mean()),
        "snips": float((w * r).sum() / np.maximum(w.sum(), 1e-12)),
        "ess": float(w.sum() ** 2 / np.maximum((w ** 2).sum(), 1e-12)),
        "mean_w": float(w.mean()),
        "n": int(n),
    }
    if qhat is None:
        out["dm"] = float("nan")
        out["dr"] = float("nan")
    else:
        q = np.asarray(qhat, np.float64)
        if q.shape != (n, logged.num_actions):
            raise ValueError(
                f"estimate_offline: qhat shape {q.shape} != "
                f"(n={n}, K={logged.num_actions})")
        dm = (tp * q).sum(axis=1).mean()
        out["dm"] = float(dm)
        out["dr"] = float(dm + (w * (r - q[rows, a])).mean())
    return out
