"""Replay buffer for the simulated online protocol (Algorithm 1).

Partial feedback only: each record is the chosen action's outcome. Stored
as growable numpy arrays (host side — this is the control plane, not the
accelerator data path)."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


class ReplayBuffer:
    def __init__(self, emb_dim: int, feat_dim: int):
        self.emb_dim = emb_dim
        self.feat_dim = feat_dim
        self._chunks: list[Dict[str, np.ndarray]] = []
        self._cached: Dict[str, np.ndarray] | None = None

    def add_batch(self, x_emb, x_feat, domain, action, reward, gate_label,
                  gate_mask=None) -> None:
        n = len(action)
        chunk = {
            "x_emb": np.asarray(x_emb, np.float32).reshape(n, self.emb_dim),
            "x_feat": np.asarray(x_feat, np.float32).reshape(n, self.feat_dim),
            "domain": np.asarray(domain, np.int32).reshape(n),
            "action": np.asarray(action, np.int32).reshape(n),
            "reward": np.asarray(reward, np.float32).reshape(n),
            "gate_label": np.asarray(gate_label, np.float32).reshape(n),
            "gate_mask": (np.ones(n, np.float32) if gate_mask is None
                          else np.asarray(gate_mask, np.float32).reshape(n)),
        }
        self._chunks.append(chunk)
        self._cached = None

    def __len__(self) -> int:
        return sum(len(c["action"]) for c in self._chunks)

    def data(self) -> Dict[str, np.ndarray]:
        if self._cached is None:
            if not self._chunks:
                raise ValueError("empty buffer")
            self._cached = {
                k: np.concatenate([c[k] for c in self._chunks])
                for k in self._chunks[0]
            }
        return self._cached

    def state_dict(self) -> Dict[str, np.ndarray]:
        """Snapshot as one consolidated chunk (DESIGN.md §12): fixed key
        set with zero-length arrays when empty, so the serving snapshot
        format has a stable schema regardless of fill level."""
        if self._chunks:
            return {k: v.copy() for k, v in self.data().items()}
        return {
            "x_emb": np.zeros((0, self.emb_dim), np.float32),
            "x_feat": np.zeros((0, self.feat_dim), np.float32),
            "domain": np.zeros(0, np.int32),
            "action": np.zeros(0, np.int32),
            "reward": np.zeros(0, np.float32),
            "gate_label": np.zeros(0, np.float32),
            "gate_mask": np.zeros(0, np.float32),
        }

    def load_state_dict(self, d: Dict[str, np.ndarray]) -> None:
        n = len(d["action"])
        self._chunks = [] if n == 0 else [
            {k: np.asarray(v) for k, v in d.items()}]
        self._cached = None

    def minibatches(self, rng: np.random.Generator, batch_size: int, *,
                    drop_tail: bool = False
                    ) -> Iterator[Dict[str, np.ndarray]]:
        """Shuffled minibatches covering EVERY stored sample exactly once
        per epoch: full batches plus the short shuffle tail (``n %
        batch_size`` samples; the whole buffer when ``n < batch_size``).
        Dropping the tail silently skipped SGD on early protocol slices
        and small serving pools, and under-trained on up to
        ``batch_size - 1`` samples per epoch forever after. Each distinct
        tail size costs one extra trace of the jitted train step on this
        host reference path — pass ``drop_tail=True`` to keep only full
        batches (fixed shapes) when that matters; a buffer smaller than
        one batch always yields its single short batch."""
        data = self.data()
        n = len(self)
        order = rng.permutation(n)
        for i in range(0, n, batch_size):
            idx = order[i:i + batch_size]
            if drop_tail and i > 0 and len(idx) < batch_size:
                return
            yield {k: v[idx] for k, v in data.items()}
