"""Baseline routing policies (paper §4.1):

  random      — uniform over the candidate pool
  min-cost    — always the cheapest model (by average observed cost)
  max-quality — always the best-quality model (reference upper line, Fig. 4)
  RouteLLM-BERT — binary strong/weak router: strong and weak are the models
      with the highest/lowest average *utility reward*; a text-embedding
      classifier predicts whether the strong model is needed (Ong et al.
      2024, adapted as the paper describes)
  LinUCB      — disjoint linear contextual bandit (Li et al. 2010); not in
      the paper's figures but the canonical partial-feedback reference the
      related-work section positions NeuralUCB against.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


class RandomPolicy:
    def __init__(self, num_actions: int, seed: int = 0):
        self.K = num_actions
        self.rng = np.random.default_rng(seed)

    def decide(self, x_emb, x_feat, domain):
        return self.rng.integers(0, self.K, size=len(x_emb)).astype(np.int32)

    def update(self, *a, **k):
        pass

    def end_slice(self):
        pass


class FixedActionPolicy:
    """min-cost / max-quality: a fixed arm chosen from dataset statistics."""

    def __init__(self, action: int):
        self.action = int(action)

    def decide(self, x_emb, x_feat, domain):
        return np.full(len(x_emb), self.action, np.int32)

    def update(self, *a, **k):
        pass

    def end_slice(self):
        pass


class EmpiricalGreedy:
    """Context-free greedy: play the arm with the best empirical mean
    reward so far (ties -> lowest index; unplayed arms count as mean 0).

    Deterministic given the reward stream, which makes it the parity anchor
    between this host loop and the device-resident engine in
    ``repro.sim.engine`` (tests/test_sim_engine.py)."""

    def __init__(self, num_actions: int):
        self.K = num_actions
        self.sum_r = np.zeros(num_actions, np.float64)
        self.cnt = np.zeros(num_actions, np.float64)

    def decide(self, x_emb, x_feat, domain):
        mean_r = self.sum_r / np.maximum(self.cnt, 1.0)
        return np.full(len(x_emb), int(mean_r.argmax()), np.int32)

    def update(self, x_emb, x_feat, domain, actions, reward):
        np.add.at(self.sum_r, np.asarray(actions), np.asarray(reward))
        np.add.at(self.cnt, np.asarray(actions), 1.0)

    def end_slice(self):
        pass


class RouteLLMBert:
    """Binary strong/weak routing (Ong et al. 2024, as adapted in §4.1):
    strong/weak are the pool's best/worst models by average utility reward;
    a text-embedding classifier predicts whether the strong model is
    *needed* (quality gap), and routes accordingly. Like the original
    RouteLLM, the classifier is trained on preference/quality data and is
    cost-blind — which is exactly why it loses on *utility* (paper Fig. 2).

    ``fit_offline`` trains the head on held-out preference data (the
    full-information quality tables of the calibration split), mirroring
    RouteLLM's offline preference-data training."""

    def __init__(self, strong: int, weak: int, emb_dim: int, *,
                 lr: float = 0.05, threshold: float = 0.5, seed: int = 0,
                 gap: float = 0.3):
        self.strong, self.weak = int(strong), int(weak)
        self.threshold = threshold
        self.lr = lr
        self.gap = gap
        key = jax.random.PRNGKey(seed)
        self.w = jax.random.normal(key, (emb_dim,), jnp.float32) * 0.01
        self.b = jnp.zeros((), jnp.float32)

    def fit_offline(self, x_emb, quality_strong, quality_weak,
                    epochs: int = 200):
        """Label: strong needed iff its quality exceeds weak's by > gap."""
        y = (np.asarray(quality_strong) - np.asarray(quality_weak)
             > self.gap).astype(np.float32)
        Xj, yj = jnp.asarray(np.asarray(x_emb, np.float32)), jnp.asarray(y)
        for _ in range(epochs):
            p = jax.nn.sigmoid(Xj @ self.w + self.b)
            grad_z = (p - yj) / len(yj)
            self.w = self.w - self.lr * (Xj.T @ grad_z)
            self.b = self.b - self.lr * jnp.sum(grad_z)
        # calibrate the routing threshold so the strong-routing rate matches
        # the label base rate (RouteLLM calibrates its threshold for a
        # target cost budget the same way)
        p_train = np.asarray(jax.nn.sigmoid(Xj @ self.w + self.b))
        self.threshold = float(np.quantile(p_train, 1.0 - y.mean()))
        return self

    def _prob_strong(self, x_emb):
        z = jnp.asarray(x_emb) @ self.w + self.b
        return jax.nn.sigmoid(z)

    def decide(self, x_emb, x_feat, domain):
        p = np.asarray(self._prob_strong(x_emb))
        return np.where(p >= self.threshold, self.strong, self.weak
                        ).astype(np.int32)

    def update(self, *a, **k):
        pass

    def end_slice(self):
        pass


class LinUCB:
    """Disjoint LinUCB (one ridge model per arm) on text embeddings."""

    def __init__(self, num_actions: int, dim: int, *, alpha: float = 1.0,
                 ridge: float = 1.0):
        self.K, self.dim, self.alpha = num_actions, dim + 1, alpha
        self.ainv = jnp.stack([jnp.eye(self.dim) / ridge] * num_actions)
        self.bvec = jnp.zeros((num_actions, self.dim))

    def _aug(self, x_emb):
        x = np.asarray(x_emb, np.float32)
        x = x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-6)
        return jnp.asarray(np.concatenate([x, np.ones((len(x), 1), np.float32)],
                                          axis=-1))

    def decide(self, x_emb, x_feat, domain):
        g = self._aug(x_emb)                                 # (B, D)
        theta = jnp.einsum("kij,kj->ki", self.ainv, self.bvec)
        mu = jnp.einsum("bd,kd->bk", g, theta)
        bonus = jnp.sqrt(jnp.maximum(
            jnp.einsum("bd,kde,be->bk", g, self.ainv, g), 0.0))
        return np.asarray(jnp.argmax(mu + self.alpha * bonus, axis=-1)
                          ).astype(np.int32)

    def update(self, x_emb, x_feat, domain, actions, reward):
        g = self._aug(x_emb)
        actions = np.asarray(actions)
        reward = jnp.asarray(np.asarray(reward, np.float32))

        def step(state, inp):
            ainv, bvec = state
            gi, ai, ri = inp
            v = ainv[ai] @ gi
            ainv = ainv.at[ai].add(-jnp.outer(v, v) / (1.0 + gi @ v))
            bvec = bvec.at[ai].add(ri * gi)
            return (ainv, bvec), None

        (self.ainv, self.bvec), _ = jax.lax.scan(
            step, (self.ainv, self.bvec),
            (g, jnp.asarray(actions), reward))

    def end_slice(self):
        pass
