"""NeuralUCB routing policy (paper §3.3 + Algorithm 1).

DECIDE:   s(x,a) = mu(x,a) + beta * sqrt(g^T A^-1 g); take argmax_a s if
          the gate fires (p(x) >= tau_g), else the mean-greedy safe action.
          On TPU the scores come from the Pallas ucb_score kernel; the jnp
          einsum path is the portable fallback (see default_ucb_backend).
UPDATE:   push (x, a, r, y_gate) into the replay buffer; blocked rank-k
          Woodbury update of the shared A^-1 with the slice's g(x, a).
TRAIN:    E replay epochs of Huber + BCE on the buffer (AdamW).
REBUILD:  recompute all buffered features with the new net; Cholesky.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import neuralucb as NU
from repro.core import utilitynet as UN
from repro.core.replay import ReplayBuffer
from repro.kernels.ucb_score.ops import ucb_score
from repro.training.optim import adamw_init, adamw_update, clip_by_global_norm


def default_ucb_backend() -> str:
    """'pallas' on TPU (native Pallas kernel), 'jnp' elsewhere. The ops
    in repro.kernels self-dispatch the same way (kernels.backend), so
    backend='pallas' is safe everywhere — off-TPU it runs each op's jnp
    reference, never the interpreter."""
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


@functools.partial(jax.jit, static_argnames=("cfg", "backend"))
def _decide_jit(params, cfg: UN.UtilityNetConfig, ainv, beta, tau_g,
                x_emb, x_feat, domain, backend: str = "jnp"):
    mu, h, gate_p = UN.utilitynet_all_actions(params, cfg, x_emb, x_feat, domain)
    g = NU.augment(h)                                   # (B, K, F)
    if backend == "pallas":
        # serving path: (B*K, F) quadratic forms as one MXU GEMM sweep
        # with A^-1 VMEM-resident (repro.kernels.ucb_score); the op
        # picks compiled-vs-reference itself (kernels.backend)
        scores = ucb_score(g, ainv, mu, beta)
    else:
        bonus = NU.ucb_bonus(ainv, g)                   # (B, K)
        scores = mu + beta * bonus
    a_ucb = jnp.argmax(scores, axis=-1)
    a_safe = jnp.argmax(mu, axis=-1)
    use_ucb = gate_p >= tau_g
    actions = jnp.where(use_ucb, a_ucb, a_safe)
    g_taken = jnp.take_along_axis(
        g, actions[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    mu_safe = jnp.take_along_axis(mu, a_safe[:, None], axis=1)[:, 0]
    return actions, g_taken, mu_safe, gate_p, scores


@functools.partial(jax.jit, static_argnames=("cfg", "backend"))
def _score_jit(params, cfg: UN.UtilityNetConfig, ainv, x_emb, x_feat,
               domain, backend: str = "jnp"):
    """Raw scoring pieces for the non-UCB exploration rules: per-arm mean
    utility, posterior bonus sqrt(g^T A^-1 g), gate prob, and the full
    augmented feature tensor (B, K, F) so the chosen arm's g can be
    gathered host-side after the exploration draw."""
    mu, h, gate_p = UN.utilitynet_all_actions(params, cfg, x_emb, x_feat,
                                              domain)
    g = NU.augment(h)
    if backend == "pallas":
        bonus = ucb_score(g, ainv, jnp.zeros_like(mu), 1.0)
    else:
        bonus = NU.ucb_bonus(ainv, g)
    return mu, bonus, gate_p, g


@functools.partial(jax.jit, static_argnames=("cfg",))
def _train_step_jit(params, opt, cfg: UN.UtilityNetConfig, batch, lr):
    (loss, metrics), grads = jax.value_and_grad(
        UN.utilitynet_loss, has_aux=True)(params, cfg, batch)
    grads, gn = clip_by_global_norm(grads, 1.0)
    params, opt = adamw_update(grads, opt, params, lr=lr, weight_decay=1e-4)
    metrics = dict(metrics, grad_norm=gn, loss=loss)
    return params, opt, metrics


@functools.partial(jax.jit, static_argnames=("cfg",))
def _features_jit(params, cfg: UN.UtilityNetConfig, x_emb, x_feat, domain,
                  action):
    _, h, _ = UN.utilitynet_apply(params, x_emb, x_feat, domain, action)
    return NU.augment(h)


class NeuralUCBRouter:
    """Stateful router implementing the paper's policy — and, via
    ``exploration``, the serving-side face of the policy zoo (DESIGN.md
    §10): the same UtilityNet / replay / A^-1 stack with the decision
    rule swapped.

    * ``"ucb"`` (default) — the paper's gated UCB (§3.3).
    * ``"ts"`` — NeuralTS: scores mu + scale * bonus * z, z ~ N(0, 1).
    * ``"eps"`` — ε-greedy: argmax mu, uniform arm with prob ``scale``.
    * ``"boltzmann"`` — softmax(mu / scale) sampling.

    Hyperparameters follow §4.1: lr 1e-3, beta 1, ridge lambda0 1; tau_g and
    the gate-label margin are under-specified in the paper — see DESIGN.md §6.
    """

    def __init__(self, cfg: UN.UtilityNetConfig, *, seed: int = 0,
                 beta: float = 1.0, tau_g: float = 0.5,
                 ridge_lambda0: float = 1.0, lr: float = 1e-3,
                 gate_margin: float = 0.05, batch_size: int = 256,
                 ucb_backend: Optional[str] = None,
                 exploration: str = "ucb", explore_scale: float = 1.0):
        if exploration not in ("ucb", "ts", "eps", "boltzmann"):
            raise ValueError(f"unknown exploration rule {exploration!r}")
        self.cfg = cfg
        self.ucb_backend = ucb_backend or default_ucb_backend()
        self.exploration = exploration
        self.explore_scale = explore_scale
        self.beta = beta
        self.tau_g = tau_g
        self.ridge_lambda0 = ridge_lambda0
        self.lr = lr
        self.gate_margin = gate_margin
        self.batch_size = batch_size
        key = jax.random.PRNGKey(seed)
        self.params = UN.init_utilitynet(key, cfg)
        self.opt = adamw_init(self.params)
        self.ainv = NU.init_ainv(cfg.ucb_feature_dim, ridge_lambda0)
        self.buffer = ReplayBuffer(cfg.emb_dim, cfg.feat_dim)
        self.np_rng = np.random.default_rng(seed + 1)
        self.warm = True  # slice 1 explores uniformly (warm-start init)

    # ----------------------------------------------------------- DECIDE --
    def decide(self, x_emb: np.ndarray, x_feat: np.ndarray,
               domain: np.ndarray) -> Dict[str, np.ndarray]:
        B = x_emb.shape[0]
        if self.warm:
            actions = self.np_rng.integers(0, self.cfg.num_actions, size=B)
            g = np.asarray(_features_jit(
                self.params, self.cfg, jnp.asarray(x_emb), jnp.asarray(x_feat),
                jnp.asarray(domain), jnp.asarray(actions, jnp.int32)))
            mu_safe = np.zeros(B, np.float32)
            gate_p = np.ones(B, np.float32)
        elif self.exploration == "ucb":
            a, g, mu_safe, gate_p, _ = _decide_jit(
                self.params, self.cfg, self.ainv,
                jnp.float32(self.beta), jnp.float32(self.tau_g),
                jnp.asarray(x_emb), jnp.asarray(x_feat), jnp.asarray(domain),
                backend=self.ucb_backend)
            actions = np.asarray(a)
            g, mu_safe, gate_p = map(np.asarray, (g, mu_safe, gate_p))
        else:
            actions, g, mu_safe, gate_p = self._decide_explore(
                x_emb, x_feat, domain)
        return {"action": actions.astype(np.int32), "g": g,
                "mu_safe": mu_safe, "gate_p": gate_p}

    def _decide_explore(self, x_emb, x_feat, domain):
        """The zoo's non-UCB decision rules (class docstring), sharing
        the jitted scorer; exploration draws come from the host RNG that
        already owns the warm-slice stream."""
        mu, bonus, gate_p, g_all = map(np.asarray, _score_jit(
            self.params, self.cfg, self.ainv, jnp.asarray(x_emb),
            jnp.asarray(x_feat), jnp.asarray(domain),
            backend=self.ucb_backend))
        B, K = mu.shape
        a_safe = mu.argmax(axis=-1)
        s = self.explore_scale
        if self.exploration == "ts":
            actions = (mu + s * bonus
                       * self.np_rng.standard_normal(mu.shape)
                       ).argmax(axis=-1)
        elif self.exploration == "eps":
            flip = self.np_rng.random(B) < s
            actions = np.where(flip, self.np_rng.integers(0, K, size=B),
                               a_safe)
        else:                                   # boltzmann
            z = mu / max(s, 1e-6)
            p = np.exp(z - z.max(axis=-1, keepdims=True))
            p = p / p.sum(axis=-1, keepdims=True)
            # vectorized inverse-CDF draw (one RNG call for the batch)
            u = self.np_rng.random(B)
            actions = (p.cumsum(axis=-1) > u[:, None]).argmax(axis=-1)
        actions = actions.astype(np.int32)
        g = g_all[np.arange(B), actions]
        mu_safe = mu[np.arange(B), a_safe].astype(np.float32)
        return actions, g, mu_safe, gate_p

    # ----------------------------------------------------------- UPDATE --
    def update(self, x_emb, x_feat, domain, decision: Dict, reward) -> None:
        reward = np.asarray(reward, np.float32)
        # gate label (DESIGN.md §6): exploration would have been beneficial
        # iff the realized reward fell short of the predicted safe utility.
        gate_label = (reward < decision["mu_safe"] - self.gate_margin
                      ).astype(np.float32)
        gate_mask = np.zeros_like(gate_label) if self.warm else \
            np.ones_like(gate_label)
        self.buffer.add_batch(x_emb, x_feat, domain, decision["action"],
                              reward, gate_label, gate_mask)
        # blocked rank-k Woodbury: one Cholesky solve per block instead of
        # n sequential rank-1 Sherman-Morrison updates (DESIGN.md §6)
        self.ainv = NU.woodbury_update(self.ainv, jnp.asarray(decision["g"]))

    # ------------------------------------------------------------ TRAIN --
    def train(self, epochs: int = 5) -> Dict[str, float]:
        # The short shuffle tail IS consumed: each distinct tail length
        # retraces _train_step_jit once (<= batch_size - 1 shapes over a
        # run's lifetime, small net), which we accept on this host
        # reference path so every sample trains each epoch; jit-hot
        # callers can pass drop_tail=True instead (repro.core.replay).
        last = {}
        for _ in range(epochs):
            for mb in self.buffer.minibatches(self.np_rng, self.batch_size):
                jb = {k: jnp.asarray(v) for k, v in mb.items()}
                self.params, self.opt, m = _train_step_jit(
                    self.params, self.opt, self.cfg, jb, jnp.float32(self.lr))
                last = {k: float(v) for k, v in m.items()}
        return last

    # ---------------------------------------------------------- REBUILD --
    def rebuild(self) -> None:
        data = self.buffer.data()
        gs = []
        bs = 4096
        for i in range(0, len(self.buffer), bs):
            gs.append(np.asarray(_features_jit(
                self.params, self.cfg,
                jnp.asarray(data["x_emb"][i:i + bs]),
                jnp.asarray(data["x_feat"][i:i + bs]),
                jnp.asarray(data["domain"][i:i + bs]),
                jnp.asarray(data["action"][i:i + bs]))))
        self.ainv = NU.rebuild_ainv(jnp.asarray(np.concatenate(gs)),
                                    self.ridge_lambda0)

    def end_slice(self, epochs: int = 5) -> Dict[str, float]:
        metrics = self.train(epochs)
        self.rebuild()
        self.warm = False
        return metrics

    # --------------------------------------------------------- SNAPSHOT --
    def action_features(self, x_emb, x_feat, domain, actions) -> np.ndarray:
        """Augmented features g(x, a) for explicit (x, action) pairs —
        the serving engine's fallback hook: when a down arm reroutes a
        request after decide, the learned update must carry the features
        of the arm actually SERVED, not the one decided (DESIGN.md §12)."""
        return np.asarray(_features_jit(
            self.params, self.cfg, jnp.asarray(x_emb), jnp.asarray(x_feat),
            jnp.asarray(domain), jnp.asarray(actions, jnp.int32)))

    def state_dict(self) -> Dict:
        """Full learned state for snapshot/restore (the SNIPPETS.md §2
        production checklist): net + optimizer + A^-1 + replay buffer as
        an ``arrays`` pytree, plus JSON-able ``meta`` (host RNG state and
        the warm flag) — a restored router resumes the exact PRNG stream
        and learning trajectory (tests/test_serving_async.py)."""
        return {
            "arrays": {
                "params": jax.tree_util.tree_map(np.asarray, self.params),
                "opt": jax.tree_util.tree_map(np.asarray, self.opt),
                "ainv": np.asarray(self.ainv),
                "buffer": self.buffer.state_dict(),
            },
            "meta": {
                "rng": self.np_rng.bit_generator.state,
                "warm": bool(self.warm),
            },
        }

    def load_state_dict(self, d: Dict) -> None:
        arrays = d["arrays"]
        self.params = jax.tree_util.tree_map(jnp.asarray, arrays["params"])
        self.opt = jax.tree_util.tree_map(jnp.asarray, arrays["opt"])
        self.ainv = jnp.asarray(arrays["ainv"])
        self.buffer.load_state_dict(arrays["buffer"])
        self.np_rng.bit_generator.state = d["meta"]["rng"]
        self.warm = bool(d["meta"]["warm"])
