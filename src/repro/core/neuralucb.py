"""NeuralUCB statistics (paper §3.3).

A single *shared* inverse covariance A^-1 over the augmented last-layer
feature g(x,a) = [h(x,a); 1] — NOT per-arm statistics. Online updates are
rank-1 Sherman-Morrison; after each slice's replay training the matrix is
REBUILT from the buffer with the new network features via a Cholesky solve
(Algorithm 1 line 8), which maps onto the MXU far better than n rank-1
updates.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def augment(h: jax.Array) -> jax.Array:
    """h (..., d) -> g = [h; 1] (..., d+1), scaled to unit norm.

    The paper appends a bias 1 (§3.3); we additionally L2-normalize h and
    scale g to unit norm so the beta=1 exploration bonus starts at 1 and
    A^-1 stays well-conditioned regardless of the trunk's activation scale
    (DESIGN.md §6 — feature scaling is under-specified in the paper).
    """
    h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)
    ones = jnp.ones(h.shape[:-1] + (1,), h.dtype)
    return jnp.concatenate([h, ones], axis=-1) / jnp.sqrt(2.0).astype(h.dtype)


def init_ainv(dim: int, ridge_lambda0: float = 1.0) -> jax.Array:
    return jnp.eye(dim, dtype=jnp.float32) / ridge_lambda0


@jax.jit
def sherman_morrison_update(ainv: jax.Array, g: jax.Array) -> jax.Array:
    """Rank-1 update of A^-1 after observing feature g (d,):

        A^-1 <- A^-1 - (A^-1 g g^T A^-1) / (1 + g^T A^-1 g)
    """
    v = ainv @ g
    denom = 1.0 + g @ v
    return ainv - jnp.outer(v, v) / denom


@jax.jit
def sherman_morrison_batch(ainv: jax.Array, gs: jax.Array) -> jax.Array:
    """Sequential rank-1 updates for a batch gs (n, d) via lax.scan.

    Reference path: algebraically identical to :func:`woodbury_update` but
    n sequential (d, d) outer products instead of one blocked solve — keep
    for testing; the protocol engine uses the blocked update."""

    def step(a, g):
        return sherman_morrison_update(a, g), None

    out, _ = jax.lax.scan(step, ainv, gs)
    return out


@jax.jit
def _woodbury_block(ainv: jax.Array, gs: jax.Array) -> jax.Array:
    """One rank-k Woodbury step for a block gs (k, d):

        (A + GᵀG)⁻¹ = A⁻¹ − A⁻¹Gᵀ (I_k + G A⁻¹ Gᵀ)⁻¹ G A⁻¹

    i.e. one (k, k) Cholesky solve + three GEMMs on the MXU, replacing k
    sequential rank-1 Sherman-Morrison updates (DESIGN.md §6)."""
    u = gs @ ainv                                           # G A^-1   (k, d)
    k = gs.shape[0]
    s = jnp.eye(k, dtype=ainv.dtype) + u @ gs.T             # I + G A^-1 G^T
    cho = jax.scipy.linalg.cho_factor(s)
    x = jax.scipy.linalg.cho_solve(cho, u)                  # S^-1 G A^-1
    out = ainv - u.T @ x
    return 0.5 * (out + out.T)                              # keep symmetric


def woodbury_update(ainv: jax.Array, gs: jax.Array,
                    block_size: int = 0) -> jax.Array:
    """Blocked rank-k update of A^-1 after observing features gs (n, d).

    Equivalent to ``sherman_morrison_batch`` up to float error, but a
    whole slice (n ~ 1.8k) becomes ceil(n / block) Cholesky solves
    instead of n sequential rank-1 updates. ``block_size`` bounds the
    (k, k) system solved per step; 0 picks ``max(128, d)`` — the (k, k)
    solve is O(k^3) while the GEMMs are O(k d^2), so blocks much wider
    than the feature dim make the solve dominate and can end up slower
    than the sequential path it replaces.

    Multi-block updates run as ONE ``lax.fori_loop`` over equal-sized
    blocks with a zero-padded tail (a zero row contributes an identity
    row/column to S and a zero row to G A^-1, i.e. an exact no-op), so
    the trace holds one block body however many blocks stream through it
    — the old host loop re-sliced per block and inlined ceil(n / block)
    copies, recompiling the enclosing program for every distinct replay
    size."""
    n, d = gs.shape
    if n == 0:
        return ainv
    block = block_size if block_size > 0 else max(128, d)
    if n <= block:
        # single block: keep the unpadded shape (bit-exact with the
        # pre-loop path, which the golden suites pin)
        return _woodbury_block(ainv, gs)
    nb = -(-n // block)
    pad = nb * block - n
    if pad:
        gs = jnp.pad(gs, ((0, pad), (0, 0)))
    blocks = gs.reshape(nb, block, d)
    return jax.lax.fori_loop(
        0, nb, lambda i, a: _woodbury_block(a, blocks[i]), ainv)


@jax.jit
def rebuild_ainv(gs: jax.Array, ridge_lambda0: float = 1.0,
                 weights: jax.Array | None = None) -> jax.Array:
    """A = lambda0 I + sum_i w_i g_i g_i^T ; return A^-1 via Cholesky solve.

    gs: (n, d) features of all buffered (context, action) pairs recomputed
    with the freshly trained network. ``weights`` (n,) optionally weights
    rows LINEARLY in A: rows are scaled by sqrt(w) so each contributes
    w g g^T exactly — bit-identical to the old w-scaling for the binary
    validity masks (sqrt of 0/1 is 0/1), and correct for the fractional
    discounted-forgetting weights gamma^(t-s) (DESIGN.md §9.2), which the
    old w-scaling would have squared.
    """
    if weights is not None:
        gs = gs * jnp.sqrt(jnp.maximum(weights, 0.0))[..., None]
    d = gs.shape[-1]
    A = ridge_lambda0 * jnp.eye(d, dtype=jnp.float32) + gs.T @ gs
    cho = jax.scipy.linalg.cho_factor(A)
    return jax.scipy.linalg.cho_solve(cho, jnp.eye(d, dtype=jnp.float32))


def ucb_bonus(ainv: jax.Array, g: jax.Array) -> jax.Array:
    """sqrt(g^T A^-1 g) for g (..., d). Pure-jnp path (the Pallas kernel in
    repro.kernels.ucb_score is the TPU serving path)."""
    quad = jnp.einsum("...i,ij,...j->...", g, ainv, g)
    return jnp.sqrt(jnp.maximum(quad, 0.0))
