"""The paper's primary contribution: reward-based online LLM routing via
NeuralUCB (UtilityNet + shared-A^-1 UCB + gated exploration + the
simulated online protocol of Algorithm 1)."""
from repro.core.reward import utility_reward, normalize_cost
from repro.core.utilitynet import (
    init_utilitynet,
    utilitynet_apply,
    utilitynet_all_actions,
)
from repro.core.neuralucb import init_ainv, sherman_morrison_update, rebuild_ainv
from repro.core.policy import NeuralUCBRouter
from repro.core.protocol import estimate_offline, run_protocol

__all__ = [
    "estimate_offline",
    "utility_reward",
    "normalize_cost",
    "init_utilitynet",
    "utilitynet_apply",
    "utilitynet_all_actions",
    "init_ainv",
    "sherman_morrison_update",
    "rebuild_ainv",
    "NeuralUCBRouter",
    "run_protocol",
]
