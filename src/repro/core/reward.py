"""Utility reward (paper Eq. 1):

    r(x, a) = q(x, a) * exp(-lambda * c_tilde(x, a))
    c_tilde  = log(1 + c) / log(1 + C_max)

The log normalization maps cost into [0, 1] and tames the two-orders-of-
magnitude price spread across the candidate pool (paper §3.1).
"""
from __future__ import annotations

import jax.numpy as jnp


def normalize_cost(cost, c_max):
    """cost >= 0, c_max > 0 -> c_tilde in [0, 1] (for cost <= c_max)."""
    return jnp.log1p(cost) / jnp.log1p(c_max)


def utility_reward(quality, cost, c_max, cost_lambda: float = 1.0):
    """quality in [0,1], raw cost -> utility reward (paper Eq. 1)."""
    c_tilde = normalize_cost(cost, c_max)
    return quality * jnp.exp(-cost_lambda * c_tilde)
