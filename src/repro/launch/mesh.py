"""Production mesh builders (deliverable (e) step 1).

Target: TPU v5e pods; 256 chips per pod in a 16x16 (data, model) layout,
and 2 pods = 512 chips with a leading "pod" axis (pure data parallelism
across pods — ICI within a pod, DCN across pods).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.
"""
from __future__ import annotations

import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over the actually-available local devices (tests/examples)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def make_sweep_mesh(n_grid: int = 1, n_seeds: int = 1,
                    n_devices=None, *, span: str = "local"):
    """("grid", "seed") mesh for the protocol-engine lane sweeps
    (DESIGN.md §14.3, §15.3).

    The flattened (grid x seed) lane axis is sharded over BOTH axes —
    ``P(("grid", "seed"))`` — so the factorization only steers locality:
    the grid axis takes the largest device factor that divides the
    caller's hyper-grid size (lanes of one grid point then land on one
    grid row of devices, seed-major), and the seed axis absorbs the
    rest. The policy axis of the zoo sweep stays a static program axis
    (heterogeneous state pytrees can't share one mesh dim); every
    policy's lane tree is laid out over this same mesh. Degenerates to a
    1x1 mesh on a single device (CPU CI), so callers need no gating.

    ``span`` picks the device pool: ``"local"`` (default) spans this
    process's devices — the EXECUTION mesh; ``"global"`` spans every
    ``jax.distributed`` process's devices in process order — the
    TOPOLOGY mesh multi-host sweeps describe their layout with
    (`distributed.api.run_sweep_multihost` slices the grid per process
    and executes each slice on the local mesh, since sweep lanes are
    fully independent)."""
    if span == "local":
        devs = jax.local_devices()
    elif span == "global":
        devs = list(jax.devices())
    else:
        raise ValueError(f"make_sweep_mesh: unknown span {span!r} "
                         f"(use 'local' or 'global')")
    nd = len(devs) if n_devices is None else max(
        1, min(int(n_devices), len(devs)))
    g = math.gcd(nd, max(1, int(n_grid)))
    return jax.sharding.Mesh(
        np.asarray(devs[:nd]).reshape(g, nd // g), ("grid", "seed"))
