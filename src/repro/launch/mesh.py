"""Production mesh builders (deliverable (e) step 1).

Target: TPU v5e pods; 256 chips per pod in a 16x16 (data, model) layout,
and 2 pods = 512 chips with a leading "pod" axis (pure data parallelism
across pods — ICI within a pod, DCN across pods).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over the actually-available local devices (tests/examples)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return jax.make_mesh((n // mp, mp), ("data", "model"))
