"""ShapeDtypeStruct stand-ins for every model input — the dry-run lowers
against these; nothing is allocated (deliverable (e), step 2).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig, InputShape
from repro.models import model as MODEL


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_input_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": sds((B, S), jnp.int32),
        "labels": sds((B, S), jnp.int32),
    }
    if cfg.arch_type == "audio":
        batch["audio_embed"] = sds((B, cfg.num_audio_frames, cfg.d_model),
                                   cfg.dtype)
    if cfg.arch_type == "vlm":
        batch["image_embed"] = sds((B, cfg.num_image_tokens, cfg.d_model),
                                   cfg.dtype)
    return batch


def prefill_input_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((B, S), jnp.int32)}
    if cfg.arch_type == "audio":
        batch["audio_embed"] = sds((B, cfg.num_audio_frames, cfg.d_model),
                                   cfg.dtype)
    if cfg.arch_type == "vlm":
        batch["image_embed"] = sds((B, cfg.num_image_tokens, cfg.d_model),
                                   cfg.dtype)
    return batch


def decode_input_specs(cfg: ModelConfig, shape: InputShape
                       ) -> Tuple[Dict, Dict]:
    """Returns (cache_spec_pytree, token_spec). serve_step consumes ONE new
    token against a KV/state cache of ``shape.seq_len``."""
    B, S = shape.global_batch, shape.seq_len

    def build():
        memory = None
        if cfg.arch_type == "audio":
            memory = jnp.zeros((B, cfg.num_audio_frames, cfg.d_model),
                               jnp.dtype(cfg.dtype))
        if cfg.arch_type == "vlm":
            memory = jnp.zeros((B, cfg.num_image_tokens, cfg.d_model),
                               jnp.dtype(cfg.dtype))
        # cross K/V for whisper need params; use a param-free variant here:
        cache = MODEL.init_cache(cfg, B, S, memory=memory, params=None)
        if cfg.arch_type == "audio":
            hd = cfg.resolved_head_dim
            cache["cross"] = {
                "k": jnp.zeros((cfg.num_layers, B, cfg.num_kv_heads,
                                cfg.num_audio_frames, hd), jnp.dtype(cfg.dtype)),
                "v": jnp.zeros((cfg.num_layers, B, cfg.num_kv_heads,
                                cfg.num_audio_frames, hd), jnp.dtype(cfg.dtype)),
            }
        return cache

    cache_spec = jax.eval_shape(build)
    token_spec = sds((B, 1), jnp.int32)
    return cache_spec, token_spec


def param_specs(cfg: ModelConfig) -> Dict:
    return jax.eval_shape(
        lambda: MODEL.init_params(jax.random.PRNGKey(0), cfg))
