import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable (e)).

For every (architecture x input shape x mesh) combination: build the
production mesh from 512 placeholder host devices, lower the appropriate
step function against ShapeDtypeStruct inputs (nothing is allocated),
``.compile()`` it, and record memory analysis, cost analysis, and the
collective schedule. Failures here are sharding bugs in the system.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --arch llama3.2-3b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --all          # every combo, both meshes
"""
import argparse  # noqa: E402
import functools  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.common.config import INPUT_SHAPES, ModelConfig  # noqa: E402
from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.distributed import logical_axis_rules  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    activation_rules,
    batch_partition_specs,
    cache_partition_specs,
    param_partition_specs,
)
from repro.launch import specs as SPECS  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as MODEL  # noqa: E402
from repro.roofline import collective_bytes, roofline_terms  # noqa: E402
from repro.roofline.hlo_cost import hlo_cost  # noqa: E402
from repro.roofline.model import model_flops_estimate  # noqa: E402
from repro.training import train_step as TS  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts", "dryrun")


def shape_supported(cfg: ModelConfig, shape_name: str) -> bool:
    """DESIGN.md §4 skip matrix: long_500k only for sub-quadratic archs."""
    if shape_name == "long_500k":
        return cfg.supports_long_decode
    return True


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_combo(arch: str, shape_name: str, multi_pod: bool,
                donate: bool = True):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    decode = shape.kind == "decode"
    rules = activation_rules(cfg, shape, mesh, decode=decode)

    pspec_tree = SPECS.param_specs(cfg)
    pparts = param_partition_specs(cfg, pspec_tree, mesh)

    with logical_axis_rules(rules, mesh):
        if shape.kind == "train":
            batch = SPECS.train_input_specs(cfg, shape)
            bparts = batch_partition_specs(cfg, shape, mesh, batch)
            state = jax.eval_shape(
                lambda: TS.make_train_state(jax.random.PRNGKey(0), cfg))
            state_parts = {
                "params": pparts,
                "opt": {"mu": pparts, "nu": pparts, "count": P()},
                "step": P(),
            }
            data_shards = mesh.devices.size // mesh.shape["model"]
            accum = TS.default_accum_steps(cfg, shape.global_batch,
                                           shape.seq_len, data_shards)
            fn = functools.partial(TS.train_step, cfg=cfg,
                                   accum_steps=accum)
            jitted = jax.jit(
                fn,
                in_shardings=(_named(mesh, state_parts), _named(mesh, bparts)),
                out_shardings=(_named(mesh, state_parts), None),
                donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(state, batch)
        elif shape.kind == "prefill":
            batch = SPECS.prefill_input_specs(cfg, shape)
            bparts = batch_partition_specs(cfg, shape, mesh, batch)

            def prefill_fn(params, b):
                logits, _ = MODEL.forward_train(params, cfg, b)
                return logits

            jitted = jax.jit(prefill_fn,
                             in_shardings=(_named(mesh, pparts),
                                           _named(mesh, bparts)))
            lowered = jitted.lower(pspec_tree, batch)
        else:  # decode
            cache, token = SPECS.decode_input_specs(cfg, shape)
            cparts = cache_partition_specs(cfg, shape, mesh, cache)
            tok_part = batch_partition_specs(cfg, shape, mesh,
                                             {"t": token})["t"]

            def decode_fn(params, c, t):
                return MODEL.decode_step(params, cfg, c, t)

            jitted = jax.jit(
                decode_fn,
                in_shardings=(_named(mesh, pparts), _named(mesh, cparts),
                              NamedSharding(mesh, tok_part)),
                out_shardings=(None, _named(mesh, cparts)),
                donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(pspec_tree, cache, token)

        compiled = lowered.compile()
    return cfg, shape, mesh, lowered, compiled


def run_combo(arch: str, shape_name: str, multi_pod: bool,
              verbose: bool = True) -> dict:
    t0 = time.time()
    cfg, shape, mesh, lowered, compiled = lower_combo(
        arch, shape_name, multi_pod)
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    # loop-aware HLO cost (xla cost_analysis counts scan bodies once —
    # see repro/roofline/hlo_cost.py)
    own = hlo_cost(hlo)

    n_dev = mesh.devices.size
    flops_dev = float(own["flops"])
    bytes_dev = float(own["bytes"])
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mflops = model_flops_estimate(cfg.param_count(),
                                  cfg.active_param_count(), tokens,
                                  shape.kind)
    terms = roofline_terms(flops_dev, bytes_dev, coll.get("total", 0.0),
                           model_flops=mflops, num_devices=n_dev)

    report = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "num_devices": n_dev,
        "compile_s": round(compile_s, 1),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        "cost": {"flops_per_device": flops_dev,
                 "bytes_per_device": bytes_dev,
                 "xla_flops_loop_unaware": float(cost.get("flops", 0.0)),
                 "xla_bytes_loop_unaware": float(
                     cost.get("bytes accessed", 0.0))},
        "collectives": coll,
        "roofline": terms,
    }
    if verbose:
        print(json.dumps(report, indent=2, default=float))
        print(f"memory_analysis: {mem}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="write JSON report here")
    args = ap.parse_args()

    os.makedirs(ARTIFACT_DIR, exist_ok=True)

    if args.all:
        failures = []
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for shape_name in INPUT_SHAPES:
                if not shape_supported(cfg, shape_name):
                    print(f"SKIP {arch} x {shape_name} (full attention at "
                          f"500k decode unsupported by design)")
                    continue
                for mp in (False, True):
                    tag = f"{arch}_{shape_name}_{'2x16x16' if mp else '16x16'}"
                    path = os.path.join(ARTIFACT_DIR, tag + ".json")
                    if os.path.exists(path):
                        print(f"CACHED {tag}")
                        continue
                    try:
                        rep = run_combo(arch, shape_name, mp, verbose=False)
                        with open(path, "w") as f:
                            json.dump(rep, f, indent=1, default=float)
                        r = rep["roofline"]
                        print(f"OK {tag}: compile={rep['compile_s']}s "
                              f"dominant={r['dominant']} "
                              f"compute={r['compute_s']:.4f}s "
                              f"memory={r['memory_s']:.4f}s "
                              f"collective={r['collective_s']:.4f}s",
                              flush=True)
                    except Exception as e:  # noqa: BLE001
                        failures.append((tag, repr(e)))
                        print(f"FAIL {tag}: {e}", flush=True)
                        traceback.print_exc()
        if failures:
            print(f"{len(failures)} failures")
            sys.exit(1)
        print("all dry-runs passed")
        return

    rep = run_combo(args.arch, args.shape, args.multi_pod)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rep, f, indent=1, default=float)


if __name__ == "__main__":
    main()
