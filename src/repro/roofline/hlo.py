"""Parse collective ops out of optimized (post-SPMD) HLO text.

cost_analysis() has no collective accounting, so we regex the compiled
module: every ``all-reduce``/``all-gather``/``reduce-scatter``/
``all-to-all``/``collective-permute`` op line carries its result dtype and
shape; per-device traffic uses the standard ring-collective factors.

Collectives inside ``while`` bodies (the scan-over-layers pattern) execute
once per trip, so we reconstruct the computation call graph, extract each
while loop's trip count from its condition computation (the comparison
constant), and multiply accordingly.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9]+)\[([0-9,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")

_TUPLE_ELEM_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

# traffic factor applied to the RESULT bytes (ring algorithms, large groups)
_FACTORS = {
    "all-reduce": 2.0,        # reduce-scatter + all-gather phases
    "all-gather": 1.0,
    "reduce-scatter": 3.0,    # operand is n x result; ~operand bytes move
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->",
                          re.M)
_WHILE_RE = re.compile(
    r"while\([^)]*\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALL_RE = re.compile(
    r"(?:call|conditional)\([^)]*\),?.*?to_apply=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """name -> computation body text (brace-delimited blocks)."""
    comps: Dict[str, str] = {}
    pos = 0
    for m in _COMP_HDR_RE.finditer(hlo_text):
        start = hlo_text.find("{", m.end())
        if start < 0:
            continue
        depth = 0
        i = start
        while i < len(hlo_text):
            if hlo_text[i] == "{":
                depth += 1
            elif hlo_text[i] == "}":
                depth -= 1
                if depth == 0:
                    break
            i += 1
        comps[m.group(1)] = hlo_text[start:i + 1]
    return comps


def _own_collectives(body: str) -> List[Tuple[str, int]]:
    out = []
    for m in _OP_RE.finditer(body):
        tuple_body, dtype, dims, kind, phase = m.groups()
        if phase == "-done":
            continue  # -start carries the payload; avoid double count
        if tuple_body is not None:
            total = sum(_shape_bytes(d, s)
                        for d, s in _TUPLE_ELEM_RE.findall(tuple_body))
        else:
            total = _shape_bytes(dtype, dims)
        out.append((kind, total))
    return out


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> List[Tuple[str, int, int]]:
    """Returns [(kind, result_bytes, multiplicity)] with while-loop trip
    counts folded into multiplicity."""
    comps = _split_computations(hlo_text)
    if not comps:
        return [(k, b, 1) for k, b in _own_collectives(hlo_text)]

    # locate the entry computation: the one that is not referenced anywhere
    referenced = set()
    edges: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    for name, body in comps.items():
        for m in _WHILE_RE.finditer(body):
            cond, wbody = m.group(1), m.group(2)
            trips = 1
            if cond in comps:
                consts = [int(c) for c in _CONST_RE.findall(comps[cond])]
                if consts:
                    trips = max(consts)
            edges[name].append((wbody, max(trips, 1)))
            referenced.update((cond, wbody))
        for m in _CALL_RE.finditer(body):
            edges[name].append((m.group(1), 1))
            referenced.add(m.group(1))

    entries = [n for n in comps if n not in referenced]

    memo: Dict[str, List[Tuple[str, int, int]]] = {}

    def collect(name: str, depth=0) -> List[Tuple[str, int, int]]:
        if name in memo:
            return memo[name]
        if depth > 50:
            return []
        res = [(k, b, 1) for k, b in _own_collectives(comps.get(name, ""))]
        for child, trips in edges.get(name, ()):  # noqa: B007
            if child == name:
                continue
            for k, b, mult in collect(child, depth + 1):
                res.append((k, b, mult * trips))
        memo[name] = res
        return res

    out: List[Tuple[str, int, int]] = []
    for e in entries:
        out.extend(collect(e))
    return out


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Aggregate per-device collective traffic in bytes by kind (+ total),
    with ring factors and loop trip counts applied."""
    agg: Dict[str, float] = defaultdict(float)
    count = 0
    for kind, nbytes, mult in parse_collectives(hlo_text):
        agg[kind] += nbytes * mult * _FACTORS[kind]
        count += mult
    agg["total"] = float(sum(v for k, v in agg.items() if k != "total"))
    agg["num_ops"] = float(count)
    return dict(agg)
