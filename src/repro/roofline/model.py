"""Three-term roofline model (deliverable (g)).

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (values from the task spec).

cost_analysis() of the SPMD-partitioned module reports per-device FLOPs
and bytes, so no further division by chip count is needed; the "chips x
peak" denominators in the spec reduce to per-chip peaks against per-chip
numerators.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float       # FLOP/s (bf16)
    hbm_bw: float           # bytes/s
    link_bw: float          # bytes/s per ICI link
    hbm_bytes: float        # capacity


HW_V5E = Hardware("tpu-v5e", 197e12, 819e9, 50e9, 16e9)


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float,
                   hw: Hardware = HW_V5E,
                   model_flops: Optional[float] = None,
                   num_devices: int = 1) -> Dict[str, float]:
    compute_s = flops_per_device / hw.peak_flops
    memory_s = bytes_per_device / hw.hbm_bw
    collective_s = collective_bytes_per_device / hw.link_bw
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dominant = max(terms, key=terms.get)
    bound_s = terms[dominant]
    out = dict(terms)
    out["dominant"] = dominant
    out["step_lower_bound_s"] = bound_s
    if model_flops is not None and flops_per_device > 0:
        total_hlo_flops = flops_per_device * num_devices
        out["model_flops"] = model_flops
        out["useful_flop_fraction"] = model_flops / total_hlo_flops
        # MFU-at-roofline: useful FLOPs / (time lower bound x fleet peak)
        out["mfu_upper_bound"] = model_flops / (
            bound_s * hw.peak_flops * num_devices)
    return out


def model_flops_estimate(param_count: int, active_param_count: int,
                         tokens: int, kind: str) -> float:
    """6 N D for training, 2 N D for a forward/prefill/decode pass (per the
    standard transformer FLOPs accounting); MoE uses active params."""
    n = active_param_count
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
