"""Three-term roofline model (deliverable (g)).

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (values from the task spec).

cost_analysis() of the SPMD-partitioned module reports per-device FLOPs
and bytes, so no further division by chip count is needed; the "chips x
peak" denominators in the spec reduce to per-chip peaks against per-chip
numerators.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float       # FLOP/s (bf16)
    hbm_bw: float           # bytes/s
    link_bw: float          # bytes/s per ICI link
    hbm_bytes: float        # capacity


HW_V5E = Hardware("tpu-v5e", 197e12, 819e9, 50e9, 16e9)

# Order-of-magnitude single-core host model for the measured-vs-analytic
# calibration leg (benchmarks run on CPU runners): ~50 GFLOP/s f32 GEMM,
# ~20 GB/s stream bandwidth. The calibration RATIO is the deliverable,
# so the absolute scale only needs to be the right order.
HW_CPU_HOST = Hardware("cpu-host", 5e10, 2e10, 1e9, 64e9)

_DTYPE_BYTES = {"bfloat16": 2, "float16": 2, "float32": 4}


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float,
                   hw: Hardware = HW_V5E,
                   model_flops: Optional[float] = None,
                   num_devices: int = 1) -> Dict[str, float]:
    compute_s = flops_per_device / hw.peak_flops
    memory_s = bytes_per_device / hw.hbm_bw
    collective_s = collective_bytes_per_device / hw.link_bw
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dominant = max(terms, key=terms.get)
    bound_s = terms[dominant]
    out = dict(terms)
    out["dominant"] = dominant
    out["step_lower_bound_s"] = bound_s
    if model_flops is not None and flops_per_device > 0:
        total_hlo_flops = flops_per_device * num_devices
        out["model_flops"] = model_flops
        out["useful_flop_fraction"] = model_flops / total_hlo_flops
        # MFU-at-roofline: useful FLOPs / (time lower bound x fleet peak)
        out["mfu_upper_bound"] = model_flops / (
            bound_s * hw.peak_flops * num_devices)
    return out


def decode_step_costs(cfg, batch: int, context: int) -> Dict[str, float]:
    """Analytic FLOPs / HBM bytes for ONE greedy decode step of ``batch``
    sequences with ``context`` tokens of history (the armpool's cost
    primitive, DESIGN.md §16).

    Accounting mirrors ``repro.common.config._param_count``'s layer walk
    so every arch family is costed by its actual mixer schedule:

    * GEMMs: ``2 * active_params * batch`` FLOPs, weights read once per
      step (``active_params * dtype_bytes`` — the batch amortizes the
      weight traffic; MoE reads the per-token expert subset).
    * attention layers: QK^T + attn·V FLOPs over the layer's EFFECTIVE
      KV length (sliding window / local-global cap bound it) plus the
      KV-cache read+append traffic — the decode-dominant term at scale.
    * mamba/SSD layers: the recurrent state update — state read+write
      bytes and the state-contraction FLOPs, context-independent.
    * cross-attention (VLM / encoder-decoder): KV is precomputed at
      prefill, so decode pays the read traffic + attn FLOPs over the
      fixed memory length (image tokens / audio frames).
    """
    d, hd = cfg.d_model, cfg.resolved_head_dim
    db = _DTYPE_BYTES.get(cfg.dtype, 2)
    q_dim = cfg.num_heads * hd
    kv_dim = cfg.num_kv_heads * hd
    B = float(batch)

    flops = 2.0 * cfg.active_param_count() * B
    weight_bytes = float(cfg.active_param_count()) * db
    kv_bytes = 0.0

    def _attn(kv_len: float):
        """(flops, kv_bytes) of one self/cross-attention mixer at a
        given effective KV length."""
        f = 2.0 * B * cfg.num_heads * hd * kv_len * 2.0   # QK^T + attn.V
        by = B * 2.0 * kv_dim * kv_len * db               # K+V read
        return f, by

    def _ctx_eff(i: int) -> float:
        if cfg.local_global_ratio:
            is_global = (i % (cfg.local_global_ratio + 1)) \
                == cfg.local_global_ratio
            if is_global:
                return float(min(context, cfg.global_attn_cap))
            return float(min(context, cfg.sliding_window or context))
        if cfg.sliding_window:
            return float(min(context, cfg.sliding_window))
        return float(min(context, cfg.global_attn_cap))

    def _mamba():
        d_inner = cfg.ssm_expand * d
        state_elems = d_inner * cfg.ssm_state     # nheads*head_dim*state
        f = 2.0 * B * state_elems * 2.0           # state update + readout
        by = 2.0 * B * state_elems * db           # state read + write
        by += 2.0 * B * d_inner * cfg.ssm_conv_width * db   # conv state
        return f, by

    if cfg.arch_type == "ssm":
        for _ in range(cfg.num_layers):
            f, by = _mamba()
            flops += f
            kv_bytes += by
    else:
        for i in range(cfg.num_layers):
            mixer_is_attn = True
            if cfg.attn_every:
                mixer_is_attn = (i % cfg.attn_every) == (cfg.attn_every - 1)
            if mixer_is_attn:
                f, by = _attn(_ctx_eff(i))
                by += B * 2.0 * kv_dim * db       # append this step's K/V
            else:
                f, by = _mamba()
            flops += f
            kv_bytes += by
            if cfg.cross_attn_every and \
                    (i % cfg.cross_attn_every) == (cfg.cross_attn_every - 1):
                f, by = _attn(float(cfg.num_image_tokens))
                flops += f
                kv_bytes += by
        if cfg.is_encoder_decoder:
            # decoder cross-attention over the (prefill-encoded) memory
            for _ in range(cfg.num_layers):
                f, by = _attn(float(cfg.num_audio_frames))
                flops += f
                kv_bytes += by

    # activations round-trip once per layer (residual stream read+write)
    act_bytes = 2.0 * B * d * db * max(cfg.num_layers, 1)
    hbm = weight_bytes + kv_bytes + act_bytes
    return {"flops": flops, "hbm_bytes": hbm,
            "weight_bytes": weight_bytes, "kv_bytes": kv_bytes}


def model_flops_estimate(param_count: int, active_param_count: int,
                         tokens: int, kind: str) -> float:
    """6 N D for training, 2 N D for a forward/prefill/decode pass (per the
    standard transformer FLOPs accounting); MoE uses active params."""
    n = active_param_count
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
