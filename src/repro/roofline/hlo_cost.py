"""HLO-text cost model with loop awareness.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers program under-reports FLOPs/bytes by ~num_layers x
(verified on this host: an 8-step scanned matmul reports 1/8 the unrolled
flops). This module re-derives both from the optimized HLO text:

* FLOPs: every ``dot`` contributes 2 * prod(result) * prod(contracting);
  operand shapes come from a per-computation symbol table (this dialect
  does not inline operand types). While bodies are multiplied by the trip
  count from the loop's ``backend_config known_trip_count`` (fallback:
  the condition's comparison constant); fusions/calls are followed
  through the call graph.
* HBM bytes: post-fusion buffer model — each non-control op reads its
  operands and writes its result once per execution, mirroring one
  materialized buffer per fusion result.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

from repro.roofline.hlo import _COMP_HDR_RE, _CONST_RE, _DTYPE_BYTES

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)"
    r"\s+([\w\-]+)\((.*)", re.M)
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\])")
_WHILE_REF_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)')
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_FREE_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
    "transpose", "while", "conditional", "call", "get-dimension-size",
    "copy-done", "all-reduce-done", "all-gather-done",
}


_CONVERT_TOKENS = {"wrapped", "convert", "bitcast", "fusion", ""}


def _is_convert_only_fusion(name: str) -> bool:
    base = name.split(".")[0]
    return all(tok in _CONVERT_TOKENS for tok in base.split("_"))


def _elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _type_bytes(type_text: str) -> int:
    return sum(_elems(d) * _DTYPE_BYTES.get(t, 4)
               for t, d in _SHAPE_RE.findall(type_text))


_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->", re.M)


def _split_computations(hlo_text: str) -> Dict[str, Tuple[str, str]]:
    """name -> (header, body)."""
    comps = {}
    for m in _HDR_RE.finditer(hlo_text):
        hdr_start = hlo_text.rfind("\n", 0, m.start()) + 1
        start = hlo_text.find("{", m.end())
        if start < 0:
            continue
        depth, i = 0, start
        while i < len(hlo_text):
            if hlo_text[i] == "{":
                depth += 1
            elif hlo_text[i] == "}":
                depth -= 1
                if depth == 0:
                    break
            i += 1
        comps[m.group(1)] = (hlo_text[hdr_start:start], hlo_text[start:i + 1])
    return comps


def _analyze(header: str, body: str):
    """Returns (flops, bytes, edges) for one computation.

    edges: [(kind, target, mult_or_trip_text)]"""
    symtab: Dict[str, str] = {}
    for pm in _PARAM_RE.finditer(header):
        symtab[pm.group(1)] = pm.group(2)

    defs = list(_DEF_RE.finditer(body))
    for dm in defs:
        symtab[dm.group(1)] = dm.group(2)

    flops = 0.0
    nbytes = 0.0
    edges: List[Tuple[str, str, object]] = []
    for dm in defs:
        name, rtype, opname, rest = dm.groups()
        line_rest = rest.split("\n")[0]
        args_part = line_rest.split(")")[0]
        operands = [o for o in _OPERAND_RE.findall(args_part)]

        if opname == "dot":
            res_elems = sum(_elems(d) for _, d in _SHAPE_RE.findall(rtype))
            k = 1
            cd = _DOT_DIMS_RE.search(line_rest)
            if cd and operands and operands[0] in symtab:
                lhs_shapes = _SHAPE_RE.findall(symtab[operands[0]])
                if lhs_shapes:
                    lhs_dims = lhs_shapes[0][1].split(",")
                    for idx in cd.group(1).split(","):
                        if idx and int(idx) < len(lhs_dims):
                            k *= int(lhs_dims[int(idx)])
            flops += 2.0 * res_elems * k

        if opname == "while":
            wm = _WHILE_REF_RE.search(line_rest)
            if wm:
                tm = _TRIP_RE.search(line_rest)
                trips = int(tm.group(1)) if tm else None
                edges.append(("while", wm.group(2),
                              (trips, wm.group(1))))
            continue
        if opname == "conditional":
            branches = []
            bm = _BRANCHES_RE.search(line_rest)
            if bm:
                branches = [b.strip().lstrip("%")
                            for b in bm.group(1).split(",")]
            for tm in re.finditer(
                    r"(?:true|false)_computation=%?([\w\.\-]+)", line_rest):
                branches.append(tm.group(1))
            if branches:
                edges.append(("branches", tuple(branches), 1))
        elif opname in ("fusion", "call", "custom-call", "reduce", "sort",
                        "map", "scatter", "reduce-window",
                        "select-and-scatter"):
            for cm in _CALLS_RE.finditer(line_rest):
                edges.append(("call", cm.group(1), 1))
        if opname in _FREE_OPS:
            continue
        if opname == "convert" or _is_convert_only_fusion(name):
            # bf16->f32 upcasts around dots are an XLA-CPU artifact (TPU
            # executes bf16 dots natively and fuses converts); skip them so
            # the memory term reflects the TPU target, not the host backend.
            continue
        op_bytes = [_type_bytes(t) for t in
                    (symtab.get(o) for o in operands)
                    if t and not t.startswith("(")]
        if "dynamic-update-slice" in opname or "dynamic-update-slice" in name:
            # in-place update of an aliased buffer (KV-cache append): the
            # real traffic is the update slice, not the multi-GB buffer the
            # op nominally returns — drop the result and the largest
            # (aliased) operand, keep the update + indices.
            if op_bytes:
                op_bytes.remove(max(op_bytes))
            nbytes += 2 * sum(op_bytes)
            continue
        rbytes = _type_bytes(rtype)
        nbytes += rbytes
        for ob in op_bytes:
            # cap each operand at 8x the result: fusions that dynamic-slice
            # one layer out of an (L, ...) stacked buffer (remat backward)
            # really read ~result-sized slices, not the whole stack —
            # uncapped, a single backward fusion was attributed the entire
            # 283 GB saved-activation stack once per layer iteration.
            nbytes += min(ob, 8 * rbytes)
    return flops, nbytes, edges


def hlo_cost(hlo_text: str) -> Dict[str, float]:
    comps = _split_computations(hlo_text)
    analyzed = {n: _analyze(h, b) for n, (h, b) in comps.items()}

    referenced = set()
    for _, (_, _, edges) in analyzed.items():
        for kind, target, extra in edges:
            if kind == "branches":
                referenced.update(target)
            else:
                referenced.add(target)
            if kind == "while":
                referenced.add(extra[1])
    entries = [n for n in comps if n not in referenced]

    memo: Dict[str, Tuple[float, float]] = {}

    def total(name: str, depth=0) -> Tuple[float, float]:
        if name in memo:
            return memo[name]
        if name not in analyzed or depth > 64:
            return (0.0, 0.0)
        fl, by, edges = analyzed[name]
        for kind, target, extra in edges:
            if kind == "while":
                trips, cond_name = extra
                if trips is None:
                    consts = []
                    if cond_name in comps:
                        consts = [int(c) for c in
                                  _CONST_RE.findall(comps[cond_name][1])]
                    trips = max(consts) if consts else 1
                cf, cb = total(target, depth + 1)
                fl += cf * trips
                by += cb * trips
            elif kind == "branches":
                # conditional: exactly one branch executes per visit — take
                # the max-cost branch (the local/global attention dispatch
                # would otherwise be double-counted)
                totals = [total(b, depth + 1) for b in target]
                if totals:
                    fl += max(t[0] for t in totals) * extra
                    by += max(t[1] for t in totals) * extra
            else:
                # called/fused computations: their buffer traffic is already
                # accounted at the call site (operands+result of the fusion
                # op); only propagate FLOPs to avoid double counting bytes.
                cf, _cb = total(target, depth + 1)
                fl += cf * extra
        memo[name] = (fl, by)
        return memo[name]

    flops = nbytes = 0.0
    for e in entries:
        f, b = total(e)
        flops += f
        nbytes += b
    return {"flops": flops, "bytes": nbytes}
