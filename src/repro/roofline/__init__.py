from repro.roofline.hlo import collective_bytes, parse_collectives
from repro.roofline.model import roofline_terms, HW_V5E

__all__ = ["collective_bytes", "parse_collectives", "roofline_terms", "HW_V5E"]
