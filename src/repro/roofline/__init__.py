from repro.roofline.hlo import collective_bytes, parse_collectives
from repro.roofline.hlo_cost import hlo_cost
from repro.roofline.model import (
    HW_CPU_HOST,
    HW_V5E,
    Hardware,
    decode_step_costs,
    roofline_terms,
)

__all__ = ["collective_bytes", "parse_collectives", "roofline_terms",
           "decode_step_costs", "hlo_cost", "Hardware", "HW_V5E",
           "HW_CPU_HOST"]
