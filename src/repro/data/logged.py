"""Logged-interaction datasets for the phased learning lifecycle
(DESIGN.md §13).

A :class:`LoggedInteractions` is the ONE interchange format between the
lifecycle phases: the sim scan (``run_policy_device(record_log=True)``),
the async serving engine (``DevicePolicyRouter.to_logged``), and the
synthetic RouterBench replay generator (:func:`replay_corpus`) all
produce it; offline pretraining (``repro.sim.pretrain_policy_state``)
and off-policy evaluation (``repro.core.protocol.estimate_offline``)
consume it. One row = one served request: the context (embedding /
features / domain), the action taken, the realized reward, the
behavior policy's LOG-propensity of that action (None when the
producer could not state one), and the slice the request arrived in.

The format is self-contained (contexts are materialized, not table
references) so a log survives the env it came from; ``sample_idx``
additionally records replay-table provenance when known, which the OPE
scorer uses to re-decide targets against the resident tables.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import numpy as np

LOGGED_SCHEMA_VERSION = "logged-interactions-v1"


@dataclasses.dataclass
class LoggedInteractions:
    """One logged run (module docstring). ``logp`` is the behavior
    policy's log-propensity of the LOGGED action — exact for the
    stochastic zoo members, the declared ε-smoothed value for the
    deterministic/UCB family (``repro.sim.policies.OPE_SMOOTHING_EPS``),
    and None when the producer recorded no propensities (such a log can
    pretrain but not feed ``estimate_offline``)."""

    x_emb: np.ndarray                 # (N, E) float32 context embeddings
    x_feat: np.ndarray                # (N, F) float32 side features
    domain: np.ndarray                # (N,) int32 domain ids
    action: np.ndarray                # (N,) int32 logged arms
    reward: np.ndarray                # (N,) float32 realized rewards
    logp: Optional[np.ndarray]        # (N,) float32 behavior log-propensity
    slice_idx: np.ndarray             # (N,) int32 arrival slice
    num_actions: int
    behavior: str = "unknown"         # producing policy / run label
    sample_idx: Optional[np.ndarray] = None   # (N,) replay-table provenance

    def __post_init__(self):
        self.x_emb = np.asarray(self.x_emb, np.float32)
        self.x_feat = np.asarray(self.x_feat, np.float32)
        self.domain = np.asarray(self.domain, np.int32).reshape(-1)
        self.action = np.asarray(self.action, np.int32).reshape(-1)
        self.reward = np.asarray(self.reward, np.float32).reshape(-1)
        self.slice_idx = np.asarray(self.slice_idx, np.int32).reshape(-1)
        if self.logp is not None:
            self.logp = np.asarray(self.logp, np.float32).reshape(-1)
        if self.sample_idx is not None:
            self.sample_idx = np.asarray(self.sample_idx,
                                         np.int64).reshape(-1)
        n = self.n
        for name in ("x_feat", "domain", "action", "reward", "slice_idx"):
            v = getattr(self, name)
            if v.shape[0] != n:
                raise ValueError(f"LoggedInteractions: {name} has "
                                 f"{v.shape[0]} rows, x_emb has {n}")
        for name in ("logp", "sample_idx"):
            v = getattr(self, name)
            if v is not None and v.shape[0] != n:
                raise ValueError(f"LoggedInteractions: {name} has "
                                 f"{v.shape[0]} rows, x_emb has {n}")
        if self.num_actions <= 0:
            raise ValueError("LoggedInteractions: num_actions must be "
                             f"positive, got {self.num_actions}")
        if n and (self.action.min() < 0
                  or self.action.max() >= self.num_actions):
            raise ValueError(
                f"LoggedInteractions: actions outside "
                f"[0, {self.num_actions}): "
                f"[{self.action.min()}, {self.action.max()}]")
        if self.logp is not None and n and self.logp.max() > 1e-6:
            raise ValueError("LoggedInteractions: logp must be "
                             f"log-probabilities (<= 0), max is "
                             f"{self.logp.max()}")

    @property
    def n(self) -> int:
        return int(self.x_emb.shape[0])

    @property
    def has_propensities(self) -> bool:
        return self.logp is not None

    # ------------------------------------------------------------ slicing --
    def take(self, rows: np.ndarray,
             behavior: Optional[str] = None) -> "LoggedInteractions":
        opt = lambda v: None if v is None else v[rows]  # noqa: E731
        return LoggedInteractions(
            x_emb=self.x_emb[rows], x_feat=self.x_feat[rows],
            domain=self.domain[rows], action=self.action[rows],
            reward=self.reward[rows], logp=opt(self.logp),
            slice_idx=self.slice_idx[rows], num_actions=self.num_actions,
            behavior=behavior or self.behavior,
            sample_idx=opt(self.sample_idx))

    def subsample(self, size: int, *, seed: int = 0) -> "LoggedInteractions":
        """Uniform subsample without replacement (identity when the log
        is already no larger than ``size``)."""
        if self.n <= size:
            return self
        rng = np.random.default_rng(seed)
        rows = np.sort(rng.choice(self.n, size=size, replace=False))
        return self.take(rows)

    # ------------------------------------------------------------- device --
    def to_device(self) -> Dict[str, Any]:
        """The pretrain-hook view: a dict of device arrays with the
        per-row loss weights (all ones — padding never reaches a saved
        log)."""
        import jax.numpy as jnp
        return {"x_emb": jnp.asarray(self.x_emb),
                "x_feat": jnp.asarray(self.x_feat),
                "domain": jnp.asarray(self.domain),
                "action": jnp.asarray(self.action),
                "reward": jnp.asarray(self.reward),
                "w": jnp.ones((self.n,), jnp.float32)}

    # ---------------------------------------------------------------- I/O --
    def save(self, path: str) -> None:
        meta = np.array([LOGGED_SCHEMA_VERSION, self.behavior,
                         str(self.num_actions)])
        arrays = {"x_emb": self.x_emb, "x_feat": self.x_feat,
                  "domain": self.domain, "action": self.action,
                  "reward": self.reward, "slice_idx": self.slice_idx,
                  "__meta": meta}
        if self.logp is not None:
            arrays["logp"] = self.logp
        if self.sample_idx is not None:
            arrays["sample_idx"] = self.sample_idx
        np.savez_compressed(path, **arrays)

    @classmethod
    def load(cls, path: str) -> "LoggedInteractions":
        with np.load(path, allow_pickle=False) as z:
            meta = [str(v) for v in z["__meta"]]
            if meta[0] != LOGGED_SCHEMA_VERSION:
                raise ValueError(f"{path}: schema {meta[0]!r} is not "
                                 f"{LOGGED_SCHEMA_VERSION!r}")
            return cls(
                x_emb=z["x_emb"], x_feat=z["x_feat"], domain=z["domain"],
                action=z["action"], reward=z["reward"],
                logp=z["logp"] if "logp" in z.files else None,
                slice_idx=z["slice_idx"], num_actions=int(meta[2]),
                behavior=meta[1],
                sample_idx=(z["sample_idx"] if "sample_idx" in z.files
                            else None))


def _slice_of_sample(env) -> np.ndarray:
    """(n,) arrival slice per replay sample from the env's padded (T, S)
    index/mask layout."""
    idx = np.asarray(env.idx)
    mask = np.asarray(env.mask) > 0
    out = np.zeros(int(np.asarray(env.reward).shape[0]), np.int32)
    for t in range(idx.shape[0]):
        out[idx[t][mask[t]]] = t
    return out


def replay_corpus(env, size: int, *, seed: int = 0,
                  behavior: str = "random") -> LoggedInteractions:
    """Synthetic RouterBench replay corpus for offline pretraining: draw
    ``size`` (context, arm) pairs uniformly WITH replacement from the
    env's replay tables and read the realized reward off the reward
    table — i.e. the log a uniform-random production router would have
    written, with exact propensities log(1/K)."""
    if size <= 0:
        raise ValueError(f"replay_corpus: size must be positive, got {size}")
    reward = np.asarray(env.reward)
    n, K = reward.shape
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, n, size=size)
    a = rng.integers(0, K, size=size).astype(np.int32)
    sl = _slice_of_sample(env)
    return LoggedInteractions(
        x_emb=np.asarray(env.x_emb)[ids], x_feat=np.asarray(env.x_feat)[ids],
        domain=np.asarray(env.domain)[ids], action=a,
        reward=reward[ids, a],
        logp=np.full(size, -math.log(K), np.float32),
        slice_idx=sl[ids], num_actions=K, behavior=behavior,
        sample_idx=ids)


def from_run_log(env, log: Dict[str, np.ndarray],
                 behavior: str) -> LoggedInteractions:
    """Shape a scanned run's (T, S) action/logp/reward log (the
    ``record_log=True`` output of ``repro.sim.run_policy_device``) into a
    flat :class:`LoggedInteractions` — padded rows (env mask 0) are
    dropped."""
    mask = np.asarray(env.mask) > 0                      # (T, S)
    idx = np.asarray(env.idx)
    T = mask.shape[0]
    sl = np.broadcast_to(np.arange(T, dtype=np.int32)[:, None],
                         mask.shape)
    ids = idx[mask]
    return LoggedInteractions(
        x_emb=np.asarray(env.x_emb)[ids], x_feat=np.asarray(env.x_feat)[ids],
        domain=np.asarray(env.domain)[ids],
        action=np.asarray(log["action"])[mask],
        reward=np.asarray(log["reward"])[mask],
        logp=np.asarray(log["logp"])[mask],
        slice_idx=sl[mask],
        num_actions=int(np.asarray(env.reward).shape[1]),
        behavior=behavior, sample_idx=ids)
