"""Sentence-encoder stubs (DESIGN.md §5).

The paper's encoders (all-MiniLM-L6-v2, all-mpnet-base-v2,
Qwen3-Embedding-0.6B, multilingual-e5-large-instruct) are unavailable
offline. Each stub maps the sample's latent topic vector through a fixed
random projection into the encoder's native dimensionality, with an
encoder-specific signal-to-noise ratio and a nuisance subspace, so that
*relative* encoder quality mirrors the paper's Figure 3 finding
(mpnet ~ MiniLM > qwen3 > e5-instruct)."""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class EncoderSpec:
    name: str
    dim: int
    signal: float      # how much of the topic survives
    noise: float       # idiosyncratic per-sample noise
    domain_leak: float # how much domain identity leaks into the embedding


ENCODERS: Dict[str, EncoderSpec] = {
    "all-MiniLM-L6-v2": EncoderSpec("all-MiniLM-L6-v2", 384, 1.0, 0.30, 0.30),
    "all-mpnet-base-v2": EncoderSpec("all-mpnet-base-v2", 768, 1.0, 0.28, 0.30),
    "Qwen3-Embedding-0.6B": EncoderSpec("Qwen3-Embedding-0.6B", 1024, 0.9,
                                        0.45, 0.25),
    "multilingual-e5-large-instruct": EncoderSpec(
        "multilingual-e5-large-instruct", 1024, 0.55, 0.95, 0.10),
}


def encode(encoder: str, topic: np.ndarray, domain: np.ndarray,
           seed: int = 0) -> np.ndarray:
    """topic: (n, Z) latent; domain: (n,) ids -> (n, dim) embeddings."""
    spec = ENCODERS[encoder]
    # crc32, NOT hash(): str hash is randomized per process
    # (PYTHONHASHSEED), which silently made every embedding table — and
    # therefore every learned routing trajectory — irreproducible across
    # processes. A fixed digest keeps the dataset a pure function of
    # (encoder, seed).
    rng = np.random.default_rng(zlib.crc32(encoder.encode()) + seed)
    z_dim = topic.shape[1]
    proj = rng.normal(size=(z_dim, spec.dim)) / np.sqrt(z_dim)
    dom_proj = rng.normal(size=(domain.max() + 1, spec.dim)) * spec.domain_leak
    emb = (spec.signal * topic @ proj
           + dom_proj[domain]
           + spec.noise * rng.normal(size=(len(topic), spec.dim)))
    return (emb / np.maximum(np.linalg.norm(emb, axis=1, keepdims=True), 1e-9)
            ).astype(np.float32)
