"""Synthetic RouterBench surrogate (see DESIGN.md §5).

RouterBench (Hu et al. 2024) is not redistributable offline, so we generate
a *structured* replay dataset with the benchmark's published shape — 36,497
samples, 86 domains, 11 candidate models, full (quality, cost) feedback for
every (sample, model) pair — and latent structure that makes routing
learnable:

 * every domain has a latent topic vector (clustered into 8 task families);
 * every model has a capability bias, a specialty vector over the topic
   space, and a per-token price spanning ~2.5 orders of magnitude
   (GPT-4-class down to 7B-class, mirroring the real pool);
 * quality(i, m) = sigmoid(scale * (skill_m + specialty_m . topic_i
                   - difficulty_i)) with noise; a domain-dependent share of
   samples is graded binarily (exact-match domains), the rest continuously
   (rubric domains) — as in RouterBench;
 * cost(i, m) = price_m * (prompt_tokens_i + completion_tokens_{i,m}).

The generator is seeded and calibrated so the PAPER'S qualitative claims
reproduce (reward ordering, ~33% cost-of-max-quality, encoder spread); the
calibration targets are asserted by tests/test_paper_claims.py.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional

import numpy as np

N_SAMPLES = 36_497
N_DOMAINS = 86
N_MODELS = 11
N_FAMILIES = 8          # task families (math, code, qa, ...)
LATENT_DIM = 32


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    name: str
    skill: float          # base capability (logit units)
    price: float          # $ per 1k tokens (blended prompt+completion)
    verbosity: float      # completion-length multiplier
    specialty_seed: int   # seeds the specialty direction


# Pool mirrors the RouterBench candidate mix: frontier models, mid-tier,
# open 7B-70B. Prices are per-1k-token blends in the right relative ratios;
# absolute scale is calibrated so the log-normalized cost penalty matches
# the paper's operating point (see tests/test_paper_claims.py).
MODEL_POOL: List[ModelSpec] = [
    ModelSpec("gpt-4", 2.30, 2.10, 1.25, 1),
    ModelSpec("claude-v2", 2.00, 1.40, 1.35, 2),
    ModelSpec("gpt-3.5-turbo", 1.10, 0.100, 1.00, 3),
    ModelSpec("claude-instant", 0.90, 0.120, 1.10, 4),
    ModelSpec("llama-70b-chat", 0.70, 0.090, 0.95, 5),
    ModelSpec("mixtral-8x7b", 1.35, 0.010, 0.90, 6),
    ModelSpec("yi-34b-chat", 0.50, 0.050, 1.05, 7),
    ModelSpec("code-llama-34b", 0.20, 0.050, 0.80, 8),
    ModelSpec("wizardlm-13b", -0.30, 0.030, 1.00, 9),
    ModelSpec("mistral-7b-chat", 0.00, 0.015, 0.85, 10),
    ModelSpec("zephyr-7b", -0.50, 0.012, 0.95, 11),
]


def model_prices() -> Dict[str, float]:
    """name -> $/1k-token price for the replay pool — the armpool uses
    this to back out per-sample completion lengths from a mapped
    model's cost column (cost = price * (prompt + completion) / 1000),
    keyed BY NAME so a re-ordered pool cannot silently re-price arms."""
    return {m.name: m.price for m in MODEL_POOL}


def _unit(v, axis=-1):
    return v / np.maximum(np.linalg.norm(v, axis=axis, keepdims=True), 1e-9)


def generate_routerbench(seed: int = 0, n_samples: int = N_SAMPLES
                         ) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)

    # --- domains ----------------------------------------------------------
    family_dirs = _unit(rng.normal(size=(N_FAMILIES, LATENT_DIM)))
    dom_family = rng.integers(0, N_FAMILIES, size=N_DOMAINS)
    dom_topic = _unit(family_dirs[dom_family]
                      + 0.45 * rng.normal(size=(N_DOMAINS, LATENT_DIM)))
    # difficulty profile per domain (some domains are simply harder)
    dom_diff_mean = rng.uniform(0.6, 1.9, size=N_DOMAINS)
    # exact-match (binary grading) share per domain
    dom_binary = rng.uniform(0.0, 1.0, size=N_DOMAINS) < 0.30
    # heavy-tailed domain frequency (RouterBench domains are imbalanced)
    dom_weight = rng.dirichlet(np.full(N_DOMAINS, 0.35))

    # --- models -----------------------------------------------------------
    skills = np.array([m.skill for m in MODEL_POOL])
    prices = np.array([m.price for m in MODEL_POOL])
    verbosity = np.array([m.verbosity for m in MODEL_POOL])
    spec = np.stack([
        _unit(np.random.default_rng(m.specialty_seed)
              .normal(size=(LATENT_DIM,))) for m in MODEL_POOL])
    spec_strength = 3.0

    # --- samples ----------------------------------------------------------
    domain = rng.choice(N_DOMAINS, size=n_samples, p=dom_weight).astype(np.int32)
    topic = _unit(dom_topic[domain]
                  + 0.18 * rng.normal(size=(n_samples, LATENT_DIM)))
    difficulty = np.maximum(
        rng.normal(dom_diff_mean[domain], 0.35), 0.0).astype(np.float32)
    prompt_tokens = np.exp(rng.normal(5.4, 0.5, size=n_samples))  # ~250 avg
    prompt_tokens = np.clip(prompt_tokens, 16, 1024)

    # --- quality (n, K) ----------------------------------------------------
    match = topic @ spec.T                                   # (n, K)
    logit = 1.6 * (skills[None] + spec_strength * match
                   - difficulty[:, None]) + 0.20 * rng.normal(
                       size=(n_samples, N_MODELS))
    q_cont = 1.0 / (1.0 + np.exp(-logit))
    is_binary = dom_binary[domain]
    q_bin = (rng.uniform(size=q_cont.shape) < q_cont).astype(np.float32)
    quality = np.where(is_binary[:, None], q_bin, q_cont).astype(np.float32)

    # --- cost (n, K) -------------------------------------------------------
    completion = np.exp(rng.normal(5.2, 0.4, size=(n_samples, N_MODELS)))
    completion = np.clip(completion * verbosity[None], 8, 1024)
    cost = (prices[None] * (prompt_tokens[:, None] + completion) / 1000.0
            ).astype(np.float32)

    # --- auxiliary features (what a router could cheaply compute) ----------
    fam = dom_family[domain]
    x_feat = np.stack([
        np.log1p(prompt_tokens) / 10.0,
        (fam == 1).astype(np.float32) * 0.8
        + 0.1 * rng.normal(size=n_samples),            # "code-like" indicator
        np.clip(difficulty / 3.0 + 0.15 * rng.normal(size=n_samples), 0, 1),
        (fam == 0).astype(np.float32) * 0.8
        + 0.1 * rng.normal(size=n_samples),            # "math-like" indicator
    ], axis=1).astype(np.float32)

    return {
        "domain": domain,
        # latent task family per sample (math, code, qa, ...) — the
        # domain-mix-shift scenario re-slices the stream along this axis
        "family": fam.astype(np.int32),
        "topic": topic.astype(np.float32),
        "difficulty": difficulty,
        "prompt_tokens": prompt_tokens.astype(np.float32),
        "quality": quality,
        "cost": cost,
        "x_feat": x_feat,
        "model_names": np.array([m.name for m in MODEL_POOL]),
    }


class RouterBenchSim:
    """Offline-replay environment over the generated dataset (paper §2:
    "split-level simulation of an online environment")."""

    def __init__(self, seed: int = 0, n_samples: int = N_SAMPLES,
                 encoder: str = "all-MiniLM-L6-v2", n_slices: int = 20,
                 cost_lambda: float = 1.0,
                 data: Optional[Dict[str, np.ndarray]] = None):
        from repro.data.encoders import encode

        self.data = data if data is not None else generate_routerbench(
            seed, n_samples)
        self.n = len(self.data["domain"])
        self.K = self.data["quality"].shape[1]
        self.n_slices = n_slices
        self.cost_lambda = cost_lambda
        self.c_max = float(self.data["cost"].max())
        self.x_emb = encode(encoder, self.data["topic"],
                            self.data["domain"], seed=seed)
        order = np.random.default_rng(seed + 7).permutation(self.n)
        self.slices = np.array_split(order, n_slices)

        from repro.core.reward import utility_reward
        import jax.numpy as jnp
        self.reward_table = np.asarray(utility_reward(
            jnp.asarray(self.data["quality"]), jnp.asarray(self.data["cost"]),
            self.c_max, cost_lambda))

    # convenience statistics ------------------------------------------------
    def mean_quality(self) -> np.ndarray:
        return self.data["quality"].mean(0)

    def mean_cost(self) -> np.ndarray:
        return self.data["cost"].mean(0)

    def mean_reward(self) -> np.ndarray:
        return self.reward_table.mean(0)

    def min_cost_action(self) -> int:
        return int(self.mean_cost().argmin())

    def max_quality_action(self) -> int:
        return int(self.mean_quality().argmax())

    def strong_weak_actions(self):
        mr = self.mean_reward()
        return int(mr.argmax()), int(mr.argmin())

    def slice_batch(self, t: int) -> Dict[str, np.ndarray]:
        idx = self.slices[t]
        return {
            "idx": idx,
            "x_emb": self.x_emb[idx],
            "x_feat": self.data["x_feat"][idx],
            "domain": self.data["domain"][idx],
            "quality": self.data["quality"][idx],
            "cost": self.data["cost"][idx],
            "reward": self.reward_table[idx],
        }
