from repro.data.routerbench import (
    RouterBenchSim,
    generate_routerbench,
    MODEL_POOL,
)
from repro.data.encoders import ENCODERS, encode

__all__ = [
    "RouterBenchSim",
    "generate_routerbench",
    "MODEL_POOL",
    "ENCODERS",
    "encode",
]
