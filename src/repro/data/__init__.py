from repro.data.routerbench import (
    RouterBenchSim,
    generate_routerbench,
    MODEL_POOL,
)
from repro.data.encoders import ENCODERS, encode
from repro.data.logged import (
    LOGGED_SCHEMA_VERSION,
    LoggedInteractions,
    from_run_log,
    replay_corpus,
)

__all__ = [
    "RouterBenchSim",
    "generate_routerbench",
    "MODEL_POOL",
    "ENCODERS",
    "encode",
    "LOGGED_SCHEMA_VERSION",
    "LoggedInteractions",
    "from_run_log",
    "replay_corpus",
]
