"""AdamW + gradient clipping, written on plain pytrees (no optax here).

State layout: {"mu": tree, "nu": tree, "count": scalar} — f32 moments
regardless of param dtype (bf16 training keeps f32 optimizer state, the
standard mixed-precision recipe; the dry-run memory analysis accounts it).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def adamw_init(params, moment_dtype=jnp.float32) -> Dict[str, Any]:
    """``moment_dtype=jnp.bfloat16`` halves optimizer-state HBM (the
    EXPERIMENTS.md §Perf "next lever" for the 123B/398B single-pod fit);
    the update math still runs in f32."""
    return {
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, *, lr, b1: float = 0.9,
                 b2: float = 0.999, eps: float = 1e-8,
                 weight_decay: float = 0.0) -> Tuple[Any, Dict[str, Any]]:
    """Returns (new_params, new_state). ``lr`` may be a scalar or a
    schedule value already resolved by the caller."""
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(g, m, v, p):
        mdt = m.dtype
        g32 = g.astype(jnp.float32)
        m = b1 * m.astype(jnp.float32) + (1.0 - b1) * g32
        v = b2 * v.astype(jnp.float32) + (1.0 - b2) * (g32 * g32)
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            step = step + weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), m.astype(mdt), v.astype(mdt)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["mu"])
    flat_v = treedef.flatten_up_to(state["nu"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_m, "nu": new_v, "count": count}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn
