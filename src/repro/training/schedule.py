"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, base_lr: float, warmup_steps: int):
    frac = jnp.minimum(step.astype(jnp.float32) / max(warmup_steps, 1), 1.0)
    return base_lr * frac


def cosine_schedule(step, base_lr: float, total_steps: int,
                    warmup_steps: int = 0, min_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / max(warmup_steps, 1), 1.0) if warmup_steps else 1.0
    prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                    0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * cos
