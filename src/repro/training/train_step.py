"""LM training step used by the example driver and the multi-pod dry-run."""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import model as MODEL
from repro.training.optim import adamw_init, adamw_update, clip_by_global_norm

# remat policy applied to the per-layer scan body via jax.checkpoint on the
# forward; 'none' lowers without remat (more memory, fewer FLOPs).


def make_train_state(key, cfg: ModelConfig):
    params = MODEL.init_params(key, cfg)
    return {"params": params, "opt": adamw_init(params),
            "step": jnp.zeros((), jnp.int32)}


def loss_fn(params, cfg: ModelConfig, batch: Dict) -> jax.Array:
    hidden, aux = MODEL.forward_hidden(params, cfg, batch)
    return MODEL.lm_loss_chunked(hidden, MODEL.unembed_matrix(params),
                                 batch["labels"], cfg.vocab_size, aux)


def train_step(state, batch, *, cfg: ModelConfig, lr: float = 3e-4,
               max_grad_norm: float = 1.0, weight_decay: float = 0.01,
               accum_steps: int = 1) -> Tuple[Any, Dict]:
    """One optimizer step. ``accum_steps`` > 1 splits the global batch into
    microbatches processed sequentially with f32 gradient accumulation —
    per-layer remat bounds the per-LAYER working set, but the saved
    residual stream is still L x (B_local, S, D); at train_4k scale
    (1M tokens) that alone exceeds v5e HBM for the big dense archs, so
    microbatching is what makes the fit proof hold (EXPERIMENTS.md §Perf).
    """
    params = state["params"]
    if accum_steps <= 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
    else:
        def micro(carry, mb):
            gacc, lacc = carry
            l, g = jax.value_and_grad(loss_fn)(params, cfg, mb)
            gacc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / accum_steps,
                gacc, g)
            return (gacc, lacc + l / accum_steps), None

        micro_batches = jax.tree.map(
            lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                + x.shape[1:]), batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), _ = jax.lax.scan(
            micro, (zeros, jnp.float32(0.0)), micro_batches)
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    params, opt = adamw_update(grads, state["opt"], params, lr=lr,
                               weight_decay=weight_decay)
    new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
    return new_state, {"loss": loss, "grad_norm": gnorm}


def default_accum_steps(cfg: ModelConfig, global_batch: int, seq: int,
                        data_shards: int, budget_bytes: float = 6e9) -> int:
    """Pick the smallest power-of-two microbatch count so the saved
    per-layer residual stream fits the activation budget."""
    b_local = max(global_batch // data_shards, 1)
    per_mb = cfg.num_layers * b_local * seq * cfg.d_model * 2  # bf16
    m = 1
    while per_mb / m > budget_bytes and m < b_local:
        m *= 2
    return m


def jit_train_step(cfg: ModelConfig, **kw):
    return jax.jit(functools.partial(train_step, cfg=cfg, **kw))
