"""Checkpointing: flatten a pytree of arrays into a .npz with path-encoded
keys (no orbax/flax available offline). Handles nested dicts/lists/tuples
and scalar leaves; dtypes round-trip exactly."""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import ml_dtypes
import numpy as np

# npz cannot represent bfloat16 natively; store a uint16 view tagged in the
# key and restore the view on load.
_BF16_TAG = "@bf16"


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        tag = "T" if isinstance(tree, tuple) else "L"
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}__{tag}{i}/"))
    else:
        arr = np.asarray(tree)
        key = prefix.rstrip("/")
        if arr.dtype == ml_dtypes.bfloat16:
            out[key + _BF16_TAG] = arr.view(np.uint16)
        else:
            out[key] = arr
    return out


def save_checkpoint(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    np.savez(path, **flat)


def load_checkpoint(path: str) -> Any:
    data = dict(np.load(path, allow_pickle=False))
    root: Dict = {}
    for key, val in data.items():
        if key.endswith(_BF16_TAG):
            key = key[: -len(_BF16_TAG)]
            val = val.view(ml_dtypes.bfloat16)
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return _rebuild(root)


def _rebuild(node):
    if not isinstance(node, dict):
        return node
    keys = list(node)
    if keys and all(k.startswith("__L") or k.startswith("__T") for k in keys):
        tup = keys[0].startswith("__T")
        items = sorted(node.items(), key=lambda kv: int(kv[0][3:]))
        seq = [_rebuild(v) for _, v in items]
        return tuple(seq) if tup else seq
    return {k: _rebuild(v) for k, v in node.items()}
