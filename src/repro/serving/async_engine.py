"""High-throughput async router serving engine (DESIGN.md §12).

Continuous-batching front end for any bandit router:

    submit -> [bounded admission queue] -> microbatched DECIDE (one
    batched jit call per microbatch) -> per-arm fallback chain ->
    [per-arm RequestBatcher] -> serve -> reward feedback -> router UPDATE

The loop is cooperative and deterministic: ``pump()`` advances every
stage as far as it can (decide everything due, serve every ready arm
batch, finalize every completed microbatch), and ``drain()`` force-
flushes until nothing is in flight. "Async" here is the continuous-
batching sense — decides and serves interleave across microbatches, and
nothing blocks on a full wave — while keeping single-threaded replayable
semantics (an injectable ``clock`` makes every timeout testable).

Graceful degradation (the CostSavingRouter pattern, SNIPPETS.md §1):

* The admission queue is BOUNDED — a burst beyond ``queue_capacity`` is
  shed at submit with a counted drop, never an unbounded backlog.
* Every arm has a fallback chain (default: every other arm, cheapest
  first). A request decided onto a down arm walks its chain; only a
  fully-down chain sheds (counted). Routers that accept the live
  availability mask (``serving_v2``) never pick a down arm to begin
  with.
* A decide-path exception is caught and counted; the microbatch degrades
  to the cheapest healthy arm and is served WITHOUT a router update (the
  router never learns from decisions it did not make).
* Fallback-remapped rows reach the router with the arm actually served:
  routers exposing ``action_features`` get exact relearning (features
  recomputed for the served arm); ``serving_v2`` routers exclude the
  rows conservatively and count them.

Accounting invariant (asserted by tests/test_serving_faults.py): every
submitted request is exactly one of completed / shed-at-admission /
shed-no-arm / still in flight — nothing is silently dropped.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.reward import utility_reward
from repro.serving.batcher import Request, RequestBatcher
from repro.serving.snapshot import load_snapshot, save_snapshot, \
    unflatten_state

COUNTERS = ("submitted", "admitted", "completed", "shed_queue_full",
            "shed_no_arm", "fallbacks", "decide_calls", "decide_errors",
            "updates", "learned", "skipped_learn", "dropped_log_records")


class _Group:
    """One decided microbatch awaiting completion."""

    __slots__ = ("decision", "reqs", "decided", "served", "reward",
                 "quality", "cost", "depth", "remaining", "x_emb", "x_feat",
                 "domain")

    def __init__(self, decision, reqs, decided, x_emb=None, x_feat=None,
                 domain=None):
        n = len(reqs)
        self.decision = decision      # router decision dict, or None
        self.reqs = reqs
        self.decided = decided        # (n,) pre-fallback actions
        self.served = np.full(n, -1, np.int32)
        self.reward = np.zeros(n, np.float32)
        self.quality = np.zeros(n, np.float32)
        self.cost = np.zeros(n, np.float32)
        self.depth = np.zeros(n, np.int32)   # fallback-chain depth
        self.remaining = n
        self.x_emb, self.x_feat, self.domain = x_emb, x_feat, domain


class AsyncRouterEngine:
    """See module docstring. ``router`` is either the host
    `NeuralUCBRouter` interface (``decide(x_emb, x_feat, domain)`` /
    ``update(x_emb, x_feat, domain, decision, rewards)``) or a
    ``serving_v2`` router (`DevicePolicyRouter`: id-addressed decide with
    live availability, ``update_wave``). Feedback is table-replay mode
    when ``reward_table`` is given (requests carry ``sample_idx``),
    otherwise the pool's Eq.-1 utility mode from per-token prices."""

    def __init__(self, router, num_arms: int, *,
                 engines: Optional[Sequence] = None,
                 cost_per_token: Optional[Sequence[float]] = None,
                 reward_table: Optional[np.ndarray] = None,
                 quality_table: Optional[np.ndarray] = None,
                 cost_table: Optional[np.ndarray] = None,
                 c_max: Optional[float] = None, cost_lambda: float = 1.0,
                 queue_capacity: int = 2048, decide_batch: int = 256,
                 decide_flush: Optional[float] = None,
                 serve_batch: int = 64,
                 serve_flush: Optional[float] = None,
                 pad_to_multiple: int = 4,
                 fallback_chains: Optional[Dict[int, Sequence[int]]] = None,
                 max_new: int = 8,
                 clock: Callable[[], float] = time.monotonic,
                 fault_hook: Optional[Callable[[int], None]] = None,
                 log_capacity: Optional[int] = 10_000):
        if queue_capacity < decide_batch:
            raise ValueError("queue_capacity must be >= decide_batch "
                             f"({queue_capacity} < {decide_batch})")
        self.router = router
        self.K = int(num_arms)
        self.engines = list(engines) if engines is not None else None
        self.cost_per_token = (None if cost_per_token is None
                               else np.asarray(cost_per_token, np.float64))
        self.reward_table = reward_table
        self.quality_table = quality_table
        self.cost_table = cost_table
        if reward_table is None:
            if self.cost_per_token is None:
                raise ValueError("utility feedback needs cost_per_token "
                                 "(or pass reward_table for replay mode)")
            if c_max is None:
                max_seq = max((getattr(e, "max_seq", 4096)
                               for e in self.engines or []), default=4096)
                c_max = float(self.cost_per_token.max() * max_seq)
        self.c_max = c_max
        self.cost_lambda = cost_lambda
        self.queue_capacity = int(queue_capacity)
        self.decide_batch = int(decide_batch)
        self.decide_flush = decide_flush
        self.max_new = int(max_new)
        self.clock = clock
        self.fault_hook = fault_hook
        self.batcher = RequestBatcher(max_batch=serve_batch,
                                      pad_to_multiple=pad_to_multiple,
                                      flush_timeout=serve_flush, clock=clock)
        self.arm_up = np.ones(self.K, bool)
        self.chains = self._default_chains() if fallback_chains is None \
            else {int(a): [int(x) for x in c]
                  for a, c in fallback_chains.items()}
        self._admit: deque = deque()          # (Request, arrival clock)
        self._groups: Dict[int, _Group] = {}
        self._rid_slot: Dict[int, tuple] = {}  # rid -> (gid, pos)
        self._next_gid = 0
        self.counters = {k: 0 for k in COUNTERS}
        self.decide_wall_s: List[float] = []
        self.log = deque(maxlen=log_capacity)
        self._serving_v2 = bool(getattr(router, "serving_v2", False))

    # ------------------------------------------------------------ health --
    def _arm_cost_rank(self) -> np.ndarray:
        if self.cost_per_token is not None:
            return np.argsort(self.cost_per_token, kind="stable")
        if self.cost_table is not None:
            return np.argsort(np.asarray(self.cost_table).mean(axis=0),
                              kind="stable")
        return np.arange(self.K)

    def _default_chains(self) -> Dict[int, List[int]]:
        order = [int(a) for a in self._arm_cost_rank()]
        return {a: [b for b in order if b != a] for a in range(self.K)}

    def set_arm_health(self, arm: int, up: bool) -> None:
        self.arm_up[int(arm)] = bool(up)

    def _safe_arm(self) -> int:
        for a in self._arm_cost_rank():
            if self.arm_up[a]:
                return int(a)
        return -1

    def _resolve_arm(self, a: int):
        """(served_arm, chain_depth); served < 0 = whole chain down."""
        if self.arm_up[a]:
            return a, 0
        for d, b in enumerate(self.chains.get(a, ()), start=1):
            if self.arm_up[b]:
                return b, d
        return -1, len(self.chains.get(a, ())) + 1

    # -------------------------------------------------------- admission --
    def submit(self, requests: Sequence[Request]):
        """Admit into the bounded queue; excess is shed with a counted
        drop (and a log record). Returns (n_admitted, n_shed)."""
        now = self.clock()
        shed = 0
        for r in requests:
            self.counters["submitted"] += 1
            if len(self._admit) >= self.queue_capacity:
                self.counters["shed_queue_full"] += 1
                shed += 1
                self._log({"rid": r.rid, "status": "shed_queue_full",
                           "action": -1, "reward": 0.0})
            else:
                self.counters["admitted"] += 1
                self._admit.append((r, now))
        return len(requests) - shed, shed

    @property
    def in_flight(self) -> int:
        return len(self._admit) + self.batcher.pending()

    # ------------------------------------------------------------- pump --
    def pump(self, force: bool = False) -> List[Dict]:
        """Advance decide -> dispatch -> serve as far as currently due;
        returns the records completed by this call."""
        out: List[Dict] = []
        while self._decide_due(force):
            reqs = [self._admit.popleft()[0]
                    for _ in range(min(self.decide_batch, len(self._admit)))]
            self._decide_and_dispatch(reqs, out)
        while True:
            nb = self.batcher.next_batch(force=force)
            if nb is None:
                break
            self._serve_batch(*nb, out)
        return out

    def drain(self, max_rounds: int = 10_000) -> List[Dict]:
        """Force-flush until nothing is in flight. Bounded: a round that
        makes no progress raises with the counter state instead of
        spinning (the no-deadlock guarantee is 'shed or serve, loudly')."""
        out: List[Dict] = []
        for _ in range(max_rounds):
            if self.in_flight == 0:
                return out
            before = self.in_flight
            out.extend(self.pump(force=True))
            if self.in_flight >= before:
                break
        raise RuntimeError(f"drain stalled with {self.in_flight} in flight; "
                           f"counters={self.counters}")

    def end_slice(self, epochs: int = 1):
        return self.router.end_slice(epochs)

    # ----------------------------------------------------------- decide --
    def _decide_due(self, force: bool) -> bool:
        n = len(self._admit)
        if n == 0:
            return False
        if force or n >= self.decide_batch or self.decide_flush is None:
            return True
        return self.clock() - self._admit[0][1] >= self.decide_flush

    def _decide_and_dispatch(self, reqs: List[Request], out: List[Dict]):
        n = len(reqs)
        if not self.arm_up.any():
            for r in reqs:
                self.counters["shed_no_arm"] += 1
                rec = {"rid": r.rid, "status": "shed_no_arm", "action": -1,
                       "reward": 0.0}
                self._log(rec)
                out.append(rec)
            return
        x_emb = x_feat = domain = None
        if not self._serving_v2:
            x_emb = np.stack([r.x_emb for r in reqs])
            x_feat = np.stack([r.x_feat for r in reqs])
            domain = np.array([r.domain for r in reqs], np.int32)
        call_idx = self.counters["decide_calls"]
        self.counters["decide_calls"] += 1
        decision = None
        t0 = time.perf_counter()
        try:
            if self.fault_hook is not None:
                self.fault_hook(call_idx)
            if self._serving_v2:
                ids = np.array([r.sample_idx for r in reqs], np.int64)
                decision = self.router.decide(
                    sample_idx=ids,
                    avail=self.arm_up.astype(np.float32))
            else:
                decision = self.router.decide(x_emb, x_feat, domain)
            decided = np.asarray(decision["action"], np.int32).copy()
            self.decide_wall_s.append(time.perf_counter() - t0)
        except Exception:
            # degrade, don't die: cheapest healthy arm, no router update
            self.counters["decide_errors"] += 1
            decision = None
            decided = np.full(n, self._safe_arm(), np.int32)

        gid = self._next_gid
        self._next_gid += 1
        g = _Group(decision, reqs, decided, x_emb, x_feat, domain)
        self._groups[gid] = g
        for i, r in enumerate(reqs):
            served, depth = self._resolve_arm(int(decided[i]))
            if served < 0:
                self.counters["shed_no_arm"] += 1
                g.remaining -= 1
                rec = {"rid": r.rid, "status": "shed_no_arm", "action": -1,
                       "reward": 0.0}
                self._log(rec)
                out.append(rec)
                continue
            if depth > 0:
                self.counters["fallbacks"] += 1
            g.depth[i] = depth
            self._rid_slot[r.rid] = (gid, i)
            self.batcher.submit(served, r)
        if g.remaining == 0:
            self._finalize(gid, out)

    # ------------------------------------------------------------ serve --
    def _serve_batch(self, target: int, reqs: List[Request],
                     toks: np.ndarray, out: List[Dict]):
        n_new = self.max_new
        if self.engines is not None:
            new_tokens, _ = self.engines[target].generate(
                toks, max_new=self.max_new)
            n_new = new_tokens.shape[1]
        ids = np.array([r.sample_idx for r in reqs], np.int64)
        if self.reward_table is not None:
            rw = np.asarray(self.reward_table[ids, target], np.float32)
            q = rw if self.quality_table is None else \
                np.asarray(self.quality_table[ids, target], np.float32)
            c = np.zeros(len(reqs), np.float32) if self.cost_table is None \
                else np.asarray(self.cost_table[ids, target], np.float32)
        else:
            n_tok = np.array([len(r.tokens) + n_new for r in reqs])
            c = (self.cost_per_token[target] * n_tok).astype(np.float32)
            q = np.full(len(reqs), 0.5, np.float32)
            if self.quality_table is not None:
                sel = ids >= 0
                q[sel] = self.quality_table[ids[sel], target]
            rw = np.asarray(utility_reward(q, c, self.c_max,
                                           self.cost_lambda), np.float32)
        touched = set()
        for i, r in enumerate(reqs):
            gid, pos = self._rid_slot.pop(r.rid)
            g = self._groups[gid]
            g.served[pos] = target
            g.reward[pos] = rw[i]
            g.quality[pos] = q[i]
            g.cost[pos] = c[i]
            g.remaining -= 1
            touched.add(gid)
        for gid in sorted(touched):
            if self._groups[gid].remaining == 0:
                self._finalize(gid, out)

    # --------------------------------------------------------- feedback --
    def _finalize(self, gid: int, out: List[Dict]):
        g = self._groups.pop(gid)
        ok = g.served >= 0
        if g.decision is not None and ok.any():
            if self._serving_v2:
                served = np.where(ok, g.served, g.decided)
                learned = self.router.update_wave(
                    g.decision, served, g.reward, learn_mask=ok)
                self.counters["updates"] += 1
                self.counters["learned"] += int(learned)
                self.counters["skipped_learn"] += int(ok.sum()) - int(learned)
            else:
                self._update_legacy(g, ok)
        elif g.decision is None:
            self.counters["skipped_learn"] += int(ok.sum())
        for i, r in enumerate(g.reqs):
            if not ok[i]:
                continue   # shed rows were logged at dispatch
            self.counters["completed"] += 1
            rec = {"rid": r.rid, "status": "ok", "action": int(g.served[i]),
                   "decided": int(g.decided[i]),
                   "fallback_depth": int(g.depth[i]),
                   "reward": float(g.reward[i]),
                   "quality": float(g.quality[i]),
                   "cost": float(g.cost[i])}
            self._log(rec)
            out.append(rec)

    def _update_legacy(self, g: _Group, ok: np.ndarray):
        """Host-router feedback: slice the decision to completed rows;
        remapped rows relearn EXACTLY when the router can recompute
        features for the served arm, otherwise they are excluded."""
        rows = np.flatnonzero(ok)
        served = g.served[rows]
        changed = served != g.decided[rows]
        dec = {k: np.asarray(v)[rows].copy() for k, v in g.decision.items()}
        dec["action"] = served.astype(np.int32)
        if changed.any():
            if hasattr(self.router, "action_features"):
                sub = rows[changed]
                dec["g"][changed] = self.router.action_features(
                    g.x_emb[sub], g.x_feat[sub], g.domain[sub],
                    served[changed])
            else:
                keep = ~changed
                self.counters["skipped_learn"] += int(changed.sum())
                rows, served = rows[keep], served[keep]
                dec = {k: v[keep] for k, v in dec.items()}
                if rows.size == 0:
                    return
        self.router.update(g.x_emb[rows], g.x_feat[rows], g.domain[rows],
                           dec, g.reward[rows])
        self.counters["updates"] += 1
        self.counters["learned"] += int(rows.size)

    # ------------------------------------------------------- accounting --
    def _log(self, rec: Dict):
        if self.log.maxlen is not None and len(self.log) == self.log.maxlen:
            self.counters["dropped_log_records"] += 1
        self.log.append(rec)

    def check_accounting(self) -> Dict[str, int]:
        """The no-silent-drop invariant; raises if any request is
        unaccounted for."""
        c = self.counters
        lost = (c["submitted"] - c["completed"] - c["shed_queue_full"]
                - c["shed_no_arm"] - self.in_flight)
        if lost != 0:
            raise AssertionError(f"{lost} requests unaccounted for: {c}")
        return {"lost": 0, **c}

    # --------------------------------------------------------- snapshot --
    def snapshot(self, path) -> None:
        """Persist router state + engine counters (drained engines only —
        a checkpoint between waves, the production pattern)."""
        if self.in_flight:
            raise RuntimeError(
                f"snapshot with {self.in_flight} requests in flight; "
                "drain() first")
        d = self.router.state_dict()
        save_snapshot(path, d["arrays"],
                      {"router": d["meta"],
                       "counters": {k: int(v) for k, v in
                                    self.counters.items()}})

    def restore(self, path) -> None:
        flat, meta = load_snapshot(path)
        like = self.router.state_dict()["arrays"]
        self.router.load_state_dict({"arrays": unflatten_state(flat, like),
                                     "meta": meta["router"]})
        self.counters.update({k: int(v) for k, v in
                              meta["counters"].items()})
