from repro.serving.engine import ServingEngine
from repro.serving.batcher import RequestBatcher, Request
from repro.serving.routed import RoutedServingPool
from repro.serving.async_engine import AsyncRouterEngine
from repro.serving.policy_router import DevicePolicyRouter
from repro.serving.faults import DecideFault, ScriptedFaults
from repro.serving.storm import run_storm
from repro.serving.traffic import (
    TRAFFIC_PATTERNS,
    outages_from_scenario,
    wave_sizes,
)

__all__ = [
    "ServingEngine", "RequestBatcher", "Request", "RoutedServingPool",
    "AsyncRouterEngine", "DevicePolicyRouter", "DecideFault",
    "ScriptedFaults", "run_storm", "TRAFFIC_PATTERNS",
    "outages_from_scenario", "wave_sizes",
]
