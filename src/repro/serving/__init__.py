from repro.serving.engine import ServingEngine
from repro.serving.batcher import RequestBatcher, Request
from repro.serving.routed import RoutedServingPool

__all__ = ["ServingEngine", "RequestBatcher", "Request", "RoutedServingPool"]
