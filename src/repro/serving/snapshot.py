"""Router-state snapshot/restore (DESIGN.md §12.4).

Format ``router-snapshot-v1``: one ``.npz`` holding every array leaf of
the router's state pytree (nested dict/tuple paths flattened to
``a/b/0/c`` keys) plus a ``.json`` sidecar for the non-array metadata
(host RNG state, warm flag, engine counters, schema tag). No pickle —
both files are inspectable, diffable, and loadable across processes.

A snapshot restores onto a FRESHLY CONSTRUCTED router of the same
configuration: :func:`unflatten_state` rebuilds the nested pytree against
the new router's own state structure, so a wrong-shape or wrong-config
restore fails loudly instead of corrupting state.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, Tuple

import numpy as np

SCHEMA = "router-snapshot-v1"
_SEP = "/"


def flatten_state(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    """Flatten a nested dict/tuple/list pytree of arrays to path keys."""
    flat: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        items = [(str(k), v) for k, v in sorted(tree.items())]
    elif isinstance(tree, (tuple, list)):
        items = [(str(i), v) for i, v in enumerate(tree)]
    else:
        flat[prefix.rstrip(_SEP)] = np.asarray(tree)
        return flat
    for k, v in items:
        if _SEP in k:
            raise ValueError(f"state key {k!r} contains the path separator")
        flat.update(flatten_state(v, f"{prefix}{k}{_SEP}"))
    return flat


def unflatten_state(flat: Dict[str, np.ndarray], like: Any,
                    prefix: str = "") -> Any:
    """Rebuild ``flat`` into the structure of the reference pytree
    ``like`` (a freshly initialized router's state). Missing or extra
    keys raise — a snapshot must match the target's structure exactly."""
    if isinstance(like, dict):
        return {k: unflatten_state(flat, v, f"{prefix}{k}{_SEP}")
                for k, v in like.items()}
    if isinstance(like, (tuple, list)):
        seq = [unflatten_state(flat, v, f"{prefix}{i}{_SEP}")
               for i, v in enumerate(like)]
        return type(like)(seq)
    key = prefix.rstrip(_SEP)
    if key not in flat:
        raise KeyError(f"snapshot missing state leaf {key!r}")
    return flat[key]


def save_snapshot(path, arrays: Any, meta: Dict) -> None:
    """Write ``<path>.npz`` (array leaves) + ``<path>.json`` (metadata)."""
    path = Path(path)
    flat = flatten_state(arrays)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(path.with_suffix(".npz"), **flat)
    manifest = {"schema": SCHEMA, "n_leaves": len(flat), **meta}
    path.with_suffix(".json").write_text(json.dumps(manifest, indent=1))


def load_snapshot(path) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Read back (flat arrays, metadata); validates the schema tag."""
    path = Path(path)
    with np.load(path.with_suffix(".npz")) as z:
        flat = {k: z[k] for k in z.files}
    meta = json.loads(path.with_suffix(".json").read_text())
    if meta.get("schema") != SCHEMA:
        raise ValueError(f"unknown snapshot schema {meta.get('schema')!r}")
    return flat, meta
