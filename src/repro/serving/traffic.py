"""Traffic generation for the serving engine (DESIGN.md §12.5).

Arrival patterns shape a total request budget into per-wave arrival
counts — steady load, random bursts, a diurnal curve, a flash crowd —
and the sim scenario engine (`sim/scenarios.py`) doubles as the outage
generator: a scenario's availability / zero-quality windows map onto
announced arm-outage windows for the engine's health mask, so the same
declarative non-stationarity that drives the protocol studies drives the
serving storms (the `arm_outage` scenario becomes the cascading-outage
storm, `arm_arrival` the capacity-ramp storm).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.sim.scenarios import make_scenario


def _steady(n_waves: int, rng) -> np.ndarray:
    return np.ones(n_waves)


def _bursts(n_waves: int, rng) -> np.ndarray:
    """Low baseline with random 8x spikes on ~1/6 of the waves."""
    w = np.ones(n_waves)
    spikes = rng.random(n_waves) < 1 / 6
    if not spikes.any():
        spikes[int(rng.integers(0, n_waves))] = True
    w[spikes] = 8.0
    return w


def _diurnal(n_waves: int, rng) -> np.ndarray:
    """One day-night cycle across the trace: trough at 1/5 of the peak."""
    phase = np.linspace(0, 2 * np.pi, n_waves, endpoint=False)
    return 0.6 - 0.4 * np.cos(phase)


def _flash_crowd(n_waves: int, rng) -> np.ndarray:
    """Steady load, then a 10x crowd arriving over ~1/8 of the trace
    starting at the 1/3 mark, decaying geometrically."""
    w = np.ones(n_waves)
    start = n_waves // 3
    width = max(n_waves // 8, 1)
    for i in range(start, n_waves):
        decay = 0.5 ** max(0, (i - start - width) / max(width, 1))
        w[i] += 9.0 * decay if i >= start else 0.0
    return w


TRAFFIC_PATTERNS = {
    "steady": _steady,
    "bursts": _bursts,
    "diurnal": _diurnal,
    "flash_crowd": _flash_crowd,
}


def wave_sizes(pattern: str, n_requests: int, n_waves: int, *,
               seed: int = 0) -> np.ndarray:
    """(n_waves,) int arrival counts summing exactly to ``n_requests``."""
    if pattern not in TRAFFIC_PATTERNS:
        raise ValueError(f"unknown traffic pattern {pattern!r}; "
                         f"known: {sorted(TRAFFIC_PATTERNS)}")
    if n_requests < n_waves:
        raise ValueError(f"need >= 1 request per wave "
                         f"({n_requests} requests, {n_waves} waves)")
    w = TRAFFIC_PATTERNS[pattern](n_waves, np.random.default_rng(seed))
    sizes = np.maximum(1, np.floor(w / w.sum() * n_requests)).astype(np.int64)
    # distribute the rounding remainder over the largest waves
    order = np.argsort(-w, kind="stable")
    rem = n_requests - int(sizes.sum())
    step = 1 if rem > 0 else -1
    i = 0
    while rem != 0:
        t = order[i % n_waves]
        if step > 0 or sizes[t] > 1:
            sizes[t] += step
            rem -= step
        i += 1
    return sizes


def outages_from_scenario(scenario, env, n_waves: int
                          ) -> List[Tuple[int, int, int]]:
    """Map a sim scenario's per-slice arm masks onto announced outage
    windows ``(arm, start_wave, end_wave)`` for the engine health mask.
    Both the availability mask (announced arrivals/exits) and hard
    zero-quality windows (the `arm_outage` cascades) count as DOWN —
    serving a known-dead arm is an outage whether or not the protocol
    study treats it as announced."""
    scn = (make_scenario(env, scenario) if isinstance(scenario, str)
           else scenario)
    down = np.zeros((n_waves, env.K), bool)
    if scn.tables is not None:
        slice_down = (np.asarray(scn.tables.avail) <= 0) | (
            np.asarray(scn.tables.quality_mult) <= 0)   # (T, K)
        T = slice_down.shape[0]
        rows = np.minimum((np.arange(n_waves) * T) // n_waves, T - 1)
        down = slice_down[rows]
    out: List[Tuple[int, int, int]] = []
    for k in range(env.K):
        edges = np.flatnonzero(np.diff(np.r_[0, down[:, k], 0]))
        for s, e in zip(edges[::2], edges[1::2]):
            out.append((int(k), int(s), int(e)))
    return out


def outage_health(outages, n_arms: int, wave: int) -> Dict[int, bool]:
    """Arm -> up? at ``wave`` under explicit outage windows."""
    up = {k: True for k in range(n_arms)}
    for arm, s, e in outages:
        if s <= wave < e:
            up[int(arm)] = False
    return up
