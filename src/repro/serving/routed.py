"""The end-to-end system the paper presumes: NeuralUCB router in front of a
pool of candidate LLMs.

The pool members are (reduced variants of) the 10 assigned architectures,
each behind a ServingEngine. A request flows:

  encode -> router.decide -> batcher -> per-arch engine generate
        -> (quality, cost) feedback -> router.update / train / rebuild

Quality feedback comes from the offline-replay table (as in the paper's
protocol — live grading is out of scope); cost feedback is REAL: it is
derived from each architecture's roofline terms (chip-seconds per token x
a $/chip-hour price), so the router is optimizing a cost model grounded in
the actual serving pool rather than the benchmark's API prices.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.core.reward import utility_reward
from repro.serving.batcher import Request, RequestBatcher
from repro.serving.engine import ServingEngine


class RoutedServingPool:
    """Router-fronted serving pool. ``router`` is any bandit router
    implementing the ``decide(x_emb, x_feat, domain) -> {"action", ...}``
    / ``update(x_emb, x_feat, domain, decision, rewards)`` /
    ``end_slice(epochs)`` interface — the paper's
    :class:`repro.core.policy.NeuralUCBRouter` (including its ``ts`` /
    ``eps`` / ``boltzmann`` exploration variants, the serving face of the
    DESIGN.md §10 policy zoo) or any compatible policy object.

    The default ``c_max`` (the Eq.-1 reward normalizer) is derived from
    the pool's ACTUAL maximum sequence length: the engines cap sequences
    at ``engine.max_seq``, so normalizing by a fixed 4096-token horizon
    (the old default) compressed every realizable cost toward 0 and
    collapsed the reward's cost discrimination between arms.

    ``log`` keeps the most recent ``log_capacity`` per-request records
    (a bounded deque — under sustained traffic an unbounded list grew
    without limit and eventually OOM'd the serving process). Pass
    ``log_capacity=None`` to opt out of the bound; ``dropped_log_records``
    counts records evicted by the cap so monitoring can tell a short log
    from a trimmed one.
    """

    def __init__(self, router,
                 engines: Sequence[ServingEngine],
                 cost_per_token: Sequence[float],
                 quality_table: Optional[np.ndarray] = None,
                 c_max: Optional[float] = None,
                 cost_lambda: float = 1.0,
                 max_batch: int = 8,
                 log_capacity: Optional[int] = 10_000):
        assert len(engines) == len(cost_per_token)
        if log_capacity is not None and log_capacity <= 0:
            raise ValueError("log_capacity must be positive or None "
                             f"(unbounded), got {log_capacity}")
        self.router = router
        self.engines = list(engines)
        self.cost_per_token = np.asarray(cost_per_token, np.float64)
        self.quality_table = quality_table
        if c_max is None:
            max_seq = max(getattr(e, "max_seq", 4096) for e in engines)
            c_max = float(self.cost_per_token.max() * max_seq)
        self.c_max = c_max
        self.cost_lambda = cost_lambda
        self.batcher = RequestBatcher(max_batch=max_batch)
        self.log: Deque[Dict] = deque(maxlen=log_capacity)
        self.dropped_log_records = 0

    def submit(self, requests: Sequence[Request]) -> List[Dict]:
        """Route + serve a wave of requests; returns per-request records."""
        x_emb = np.stack([r.x_emb for r in requests])
        x_feat = np.stack([r.x_feat for r in requests])
        domain = np.array([r.domain for r in requests], np.int32)
        decision = self.router.decide(x_emb, x_feat, domain)
        for r, a in zip(requests, decision["action"]):
            self.batcher.submit(int(a), r)

        records: Dict[int, Dict] = {}
        while True:
            nb = self.batcher.next_batch()
            if nb is None:
                break
            target, reqs, toks = nb
            eng = self.engines[target]
            t0 = time.time()
            new_tokens, _ = eng.generate(toks, max_new=8)
            wall = time.time() - t0
            for i, r in enumerate(reqs):
                n_tok = len(r.tokens) + new_tokens.shape[1]
                cost = float(self.cost_per_token[target] * n_tok)
                q = 0.5
                if self.quality_table is not None and r.sample_idx >= 0:
                    q = float(self.quality_table[r.sample_idx, target])
                records[r.rid] = {
                    "rid": r.rid, "action": target, "cost": cost,
                    "quality": q, "wall_s": wall / len(reqs),
                    "tokens": np.asarray(new_tokens[i]),
                }

        # feedback to the bandit
        rewards = np.array([
            float(utility_reward(records[r.rid]["quality"],
                                 records[r.rid]["cost"], self.c_max,
                                 self.cost_lambda))
            for r in requests], np.float32)
        self.router.update(x_emb, x_feat, domain, decision, rewards)
        out = [dict(records[r.rid], reward=float(rw))
               for r, rw in zip(requests, rewards)]
        if self.log.maxlen is not None:
            self.dropped_log_records += max(
                0, len(self.log) + len(out) - self.log.maxlen)
        self.log.extend(out)
        return out

    def end_slice(self, epochs: int = 5):
        return self.router.end_slice(epochs)
