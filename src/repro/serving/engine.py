"""Single-model serving engine: prefill + jit'd decode loop over the KV/state
cache, greedy or temperature sampling. CPU-runnable with reduced configs;
the same step functions are what the dry-run lowers at production shapes.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import model as MODEL


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Optional[Dict] = None,
                 seed: int = 0, max_seq: int = 256):
        self.cfg = cfg
        self.max_seq = max_seq
        if params is None:
            params = MODEL.init_params(jax.random.PRNGKey(seed), cfg)
        self.params = params
        self._decode = jax.jit(
            lambda p, c, t: MODEL.decode_step(p, cfg, c, t))
        self._forward = jax.jit(
            lambda p, b: MODEL.forward_train(p, cfg, b)[0])

    def prefill(self, tokens: jnp.ndarray, memory: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Dict]:
        """tokens (B, S) -> (next-token logits (B, V), cache primed to S).

        Prefill writes the prompt K/V into the cache by replaying the prompt
        through decode steps of width 1 (correct, if not the fast path; the
        fused prefill kernel is the flash_attention op on TPU)."""
        B, S = tokens.shape
        if self.cfg.arch_type == "audio":
            if memory is None:
                memory = jnp.zeros((B, self.cfg.num_audio_frames,
                                    self.cfg.d_model),
                                   jnp.dtype(self.cfg.dtype))
            memory = MODEL.encode_audio(self.params, self.cfg, memory)
        if self.cfg.arch_type == "vlm" and memory is None:
            memory = jnp.zeros((B, self.cfg.num_image_tokens,
                                self.cfg.d_model), jnp.dtype(self.cfg.dtype))
        cache = MODEL.init_cache(self.cfg, B, self.max_seq, memory=memory,
                                 params=self.params)
        logits = None
        for i in range(S):
            logits, cache = self._decode(self.params, cache, tokens[:, i:i + 1])
        return logits[:, -1], cache

    def generate(self, tokens: jnp.ndarray, max_new: int = 16,
                 memory: Optional[jnp.ndarray] = None,
                 temperature: float = 0.0, seed: int = 0
                 ) -> Tuple[jnp.ndarray, int]:
        """Greedy/temperature generation. Returns (B, max_new) new tokens and
        the number of decode steps executed."""
        B = tokens.shape[0]
        logits, cache = self.prefill(tokens, memory=memory)
        key = jax.random.PRNGKey(seed)
        out = []
        cur = None
        steps = 0
        vocab = self.cfg.vocab_size
        for i in range(max_new):
            if cur is None:
                nxt_logits = logits
            else:
                nxt, cache = self._decode(self.params, cache, cur)
                nxt_logits = nxt[:, -1]
                steps += 1
            nxt_logits = jnp.where(
                jnp.arange(nxt_logits.shape[-1]) < vocab, nxt_logits, -1e30)
            if temperature > 0:
                key, sub = jax.random.split(key)
                cur = jax.random.categorical(
                    sub, nxt_logits / temperature, axis=-1)[:, None]
            else:
                cur = jnp.argmax(nxt_logits, axis=-1)[:, None]
            out.append(cur)
        return jnp.concatenate(out, axis=1), steps
