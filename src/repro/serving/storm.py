"""Traffic-storm driver (DESIGN.md §12.5): replay-table traffic through
the async engine at scale.

One call = one storm: a traffic pattern shapes the request budget into
arrival waves, scripted (or scenario-derived) outage windows toggle arm
health at wave boundaries, and the engine's decide-latency samples and
counters roll up into the `BENCH_serving.json` / `serving_storm`-preset
metrics — p50/p99 decide latency, sustained requests/s, shed/fallback
accounting, and the zero-lost-requests invariant.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.serving.async_engine import AsyncRouterEngine
from repro.serving.batcher import Request
from repro.serving.faults import ScriptedFaults
from repro.serving.traffic import outages_from_scenario, wave_sizes

_TOKENS = np.arange(1, 5, dtype=np.int32)   # shared stub prompt


def run_storm(env, router, *, requests: int, waves: int,
              pattern: str = "flash_crowd",
              outages: Sequence[Tuple[int, int, int]] = (),
              scenario: Optional[str] = None,
              queue_capacity: int = 4096, decide_batch: int = 256,
              serve_batch: int = 256,
              fail_decide_calls: Sequence[int] = (),
              train_every: int = 0, epochs: int = 1, seed: int = 0,
              log_capacity: Optional[int] = 1024,
              engines: Optional[Sequence] = None,
              max_new: int = 8) -> Dict:
    """Drive ``router`` through a storm over ``env``'s replay tables.

    ``env`` is a `DeviceReplayEnv` (feedback = its reward/quality/cost
    tables); ``outages`` are announced ``(arm, start_wave, end_wave)``
    windows, optionally augmented from a sim ``scenario``'s masks;
    ``train_every`` runs `end_slice` every that many waves (0 = never).
    ``engines`` (one per arm, the armpool's semi-real mode) makes the
    serve stage actually execute each request — ``max_new`` generated
    tokens per request — while feedback stays table-replay.
    Returns the metrics dict (see `BENCH_serving.json` schema, README).
    """
    reward = np.asarray(env.reward)
    quality = np.asarray(env.quality)
    cost = np.asarray(env.cost)
    n, K = reward.shape
    outages = [(int(a), int(s), int(e)) for a, s, e in outages]
    if scenario is not None:
        outages += outages_from_scenario(scenario, env, waves)
    faults = ScriptedFaults(fail_decide_calls=fail_decide_calls,
                            outages=outages)
    if engines is not None and len(engines) != K:
        raise ValueError(f"run_storm: {len(engines)} engines for "
                         f"{K} arms (one engine per arm)")
    engine = AsyncRouterEngine(
        router, K, engines=engines, reward_table=reward,
        quality_table=quality,
        cost_table=cost, queue_capacity=queue_capacity,
        decide_batch=decide_batch, serve_batch=serve_batch,
        max_new=max_new,
        fault_hook=faults.on_decide, log_capacity=log_capacity)
    sizes = wave_sizes(pattern, requests, waves, seed=seed)
    rng = np.random.default_rng(seed)
    if hasattr(router, "warmup"):
        router.warmup()   # keep jit compiles out of the latency samples

    sum_reward = sum_quality = sum_cost = 0.0
    n_ok = 0
    per_wave_shed = np.zeros(waves, np.int64)
    # slice-boundary train stalls: (index of the first decide call AFTER
    # the stall, stall seconds) — the request-visible decide path waits
    # behind a blocking end_slice, so p99 over walls+stalls is the tail
    # a caller actually sees (the overlap bench compares this)
    stalls: list = []
    t0 = time.perf_counter()
    for w in range(waves):
        faults.apply_wave(engine, w)
        ids = rng.integers(0, n, size=int(sizes[w]))
        reqs = [Request(tokens=_TOKENS, sample_idx=int(i)) for i in ids]
        shed0 = (engine.counters["shed_queue_full"]
                 + engine.counters["shed_no_arm"])
        engine.submit(reqs)
        recs = engine.pump()
        recs += engine.drain()
        for r in recs:
            if r["status"] == "ok":
                n_ok += 1
                sum_reward += r["reward"]
                sum_quality += r["quality"]
                sum_cost += r["cost"]
        per_wave_shed[w] = (engine.counters["shed_queue_full"]
                            + engine.counters["shed_no_arm"]) - shed0
        if train_every and (w + 1) % train_every == 0:
            ts = time.perf_counter()
            engine.end_slice(epochs)
            stalls.append((len(engine.decide_wall_s),
                           time.perf_counter() - ts))
    wall = time.perf_counter() - t0
    acct = engine.check_accounting()

    walls_us = np.asarray(engine.decide_wall_s) * 1e6
    path_us = walls_us.copy()
    stall_us = np.asarray([s for _, s in stalls]) * 1e6
    for idx, s in stalls:
        if idx < path_us.size:
            path_us[idx] += s * 1e6
    c = engine.counters
    shed = c["shed_queue_full"] + c["shed_no_arm"]
    return {
        "pattern": pattern, "requests": int(requests), "waves": int(waves),
        "decide_batch": int(decide_batch),
        "outages": [list(o) for o in outages],
        "wall_s": float(wall),
        "requests_per_s": float(c["completed"] / max(wall, 1e-9)),
        "decide_calls": int(c["decide_calls"]),
        "decide_p50_us": float(np.percentile(walls_us, 50))
        if walls_us.size else 0.0,
        "decide_p99_us": float(np.percentile(walls_us, 99))
        if walls_us.size else 0.0,
        "decide_p50_per_req_us": float(
            np.percentile(walls_us, 50) / decide_batch)
        if walls_us.size else 0.0,
        "decide_path_p99_us": float(np.percentile(path_us, 99))
        if path_us.size else 0.0,
        "train_stall_p99_us": float(np.percentile(stall_us, 99))
        if stall_us.size else 0.0,
        "train_stall_total_s": float(stall_us.sum() / 1e6),
        "completed": int(c["completed"]), "shed": int(shed),
        "shed_queue_full": int(c["shed_queue_full"]),
        "shed_no_arm": int(c["shed_no_arm"]),
        "fallbacks": int(c["fallbacks"]),
        "decide_errors": int(c["decide_errors"]),
        "learned": int(c["learned"]),
        "skipped_learn": int(c["skipped_learn"]),
        "lost_requests": int(acct["lost"]),
        "max_wave_shed": int(per_wave_shed.max()) if waves else 0,
        "avg_reward": float(sum_reward / max(n_ok, 1)),
        "avg_quality": float(sum_quality / max(n_ok, 1)),
        "avg_cost": float(sum_cost / max(n_ok, 1)),
    }
