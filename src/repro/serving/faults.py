"""Scripted fault injection for the async serving engine (DESIGN.md
§12.6): deterministic outage windows and decide-call failures, driven by
the engine's own counters so tests replay the exact same storm every run.
"""
from __future__ import annotations

from typing import Iterable, Sequence, Tuple


class DecideFault(RuntimeError):
    """The injected decide-path failure (never escapes the engine)."""


class ScriptedFaults:
    """A fault script against engine-counter time:

    * ``fail_decide_calls`` — decide-call indices (0-based, the engine's
      ``decide_calls`` counter) whose router call raises
      :class:`DecideFault` — exercising the engine's catch/degrade path.
    * ``outages`` — ``(arm, start_wave, end_wave)`` windows applied to
      the engine health mask by :meth:`apply_wave` at each wave boundary.

    Attach via ``AsyncRouterEngine(fault_hook=faults.on_decide)`` and
    call ``faults.apply_wave(engine, w)`` per wave.
    """

    def __init__(self, *, fail_decide_calls: Iterable[int] = (),
                 outages: Sequence[Tuple[int, int, int]] = ()):
        self.fail_decide_calls = frozenset(int(i) for i in fail_decide_calls)
        self.outages = [(int(a), int(s), int(e)) for a, s, e in outages]
        self.injected_decide_faults = 0

    def on_decide(self, call_idx: int) -> None:
        if call_idx in self.fail_decide_calls:
            self.injected_decide_faults += 1
            raise DecideFault(f"injected decide fault at call {call_idx}")

    def apply_wave(self, engine, wave: int) -> None:
        for arm, s, e in self.outages:
            engine.set_arm_health(arm, not (s <= wave < e))
