"""Request batching: group pending requests per target model, pad to the
engine's batch granularity, preserve submission order within a group."""
from __future__ import annotations

import dataclasses
import itertools
from collections import defaultdict
from typing import Dict, List, Optional

import numpy as np

_counter = itertools.count()


@dataclasses.dataclass
class Request:
    tokens: np.ndarray                      # (S,) prompt token ids
    x_emb: Optional[np.ndarray] = None      # router features
    x_feat: Optional[np.ndarray] = None
    domain: int = 0
    sample_idx: int = -1                    # replay-table row (quality/cost)
    rid: int = dataclasses.field(default_factory=lambda: next(_counter))


class RequestBatcher:
    def __init__(self, max_batch: int = 8, pad_to_multiple: int = 4,
                 pad_token: int = 0, max_starve: int = 4):
        self.max_batch = max_batch
        self.pad_to_multiple = pad_to_multiple
        self.pad_token = pad_token
        self.max_starve = max_starve
        self.queues: Dict[int, List[Request]] = defaultdict(list)
        # rounds a non-empty queue has been passed over (aging)
        self._age: Dict[int, int] = defaultdict(int)

    def submit(self, target: int, req: Request) -> None:
        self.queues[target].append(req)

    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def next_batch(self):
        """Pop up to max_batch requests for the highest-priority queue.
        Returns (target, requests, padded_tokens (B, S)) or None.

        Pure fullest-first starved minority targets indefinitely: a
        queue that refills above a small queue's length every round is
        served forever while the small one waits. Round-robin aging
        fixes this in two tiers — a queue passed over ``max_starve``
        times is served unconditionally (oldest first, one starving
        queue per round: with m queues starving simultaneously the worst
        wait is ``max_starve + m - 1`` rounds), even when a majority
        backlog GROWS every round; otherwise priority is queue length
        plus age (throughput-first with drift toward fairness). Ties
        break to the lowest target id (deterministic)."""
        if not self.pending():
            return None
        starving = [t for t in self.queues
                    if self._age[t] >= self.max_starve]
        if starving:
            target = max(starving, key=lambda t: (self._age[t], -t))
        else:
            target = max(self.queues,
                         key=lambda t: (len(self.queues[t]) + self._age[t],
                                        -t))
        q = self.queues[target]
        reqs, self.queues[target] = q[:self.max_batch], q[self.max_batch:]
        if not self.queues[target]:
            del self.queues[target]
        self._age.pop(target, None)
        for t in self.queues:
            if t != target:
                self._age[t] += 1
        max_len = max(len(r.tokens) for r in reqs)
        max_len = -(-max_len // self.pad_to_multiple) * self.pad_to_multiple
        toks = np.full((len(reqs), max_len), self.pad_token, np.int32)
        for i, r in enumerate(reqs):
            toks[i, :len(r.tokens)] = r.tokens
        return target, reqs, toks
