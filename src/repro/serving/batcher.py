"""Request batching: group pending requests per target model, pad to the
engine's batch granularity, preserve submission order within a group.

Flush timeouts: with ``flush_timeout`` set, a queue becomes *ready* when
it holds ``max_batch`` requests OR its oldest request has waited at least
``flush_timeout`` seconds. Deadlines are armed per request from its own
arrival time — never from the last flush. The old epoch-deadline scheme
kept a stale deadline armed across an idle period, so the first request
of a post-idle burst "expired" immediately and was flushed alone in an
undersized batch; deriving readiness from arrival timestamps makes an
empty epoch leave nothing armed (see the regression test in
tests/test_serving.py)."""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import defaultdict
from typing import Callable, Dict, List, Optional

import numpy as np

_counter = itertools.count()


@dataclasses.dataclass
class Request:
    tokens: np.ndarray                      # (S,) prompt token ids
    x_emb: Optional[np.ndarray] = None      # router features
    x_feat: Optional[np.ndarray] = None
    domain: int = 0
    sample_idx: int = -1                    # replay-table row (quality/cost)
    rid: int = dataclasses.field(default_factory=lambda: next(_counter))


class RequestBatcher:
    def __init__(self, max_batch: int = 8, pad_to_multiple: int = 4,
                 pad_token: int = 0, max_starve: int = 4,
                 flush_timeout: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.max_batch = max_batch
        self.pad_to_multiple = pad_to_multiple
        self.pad_token = pad_token
        self.max_starve = max_starve
        self.flush_timeout = flush_timeout
        self.clock = clock
        self.queues: Dict[int, List[Request]] = defaultdict(list)
        # arrival clock() per queued request, parallel to ``queues``
        self._arrivals: Dict[int, List[float]] = defaultdict(list)
        # rounds a non-empty queue has been passed over (aging)
        self._age: Dict[int, int] = defaultdict(int)

    def submit(self, target: int, req: Request) -> None:
        self.queues[target].append(req)
        self._arrivals[target].append(self.clock())

    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def _ready(self, target: int, now: float, force: bool) -> bool:
        q = self.queues[target]
        if force or self.flush_timeout is None or len(q) >= self.max_batch:
            return True
        return now - self._arrivals[target][0] >= self.flush_timeout

    def next_batch(self, force: bool = False):
        """Pop up to max_batch requests for the highest-priority READY
        queue. Returns (target, requests, padded_tokens (B, S)) or None
        — None either because nothing is pending or because no queue is
        ready yet (partial fills still inside their flush window).
        ``force=True`` treats every non-empty queue as ready (drain).

        Pure fullest-first starved minority targets indefinitely: a
        queue that refills above a small queue's length every round is
        served forever while the small one waits. Round-robin aging
        fixes this in two tiers — a queue passed over ``max_starve``
        times is served unconditionally (oldest first, one starving
        queue per round: with m queues starving simultaneously the worst
        wait is ``max_starve + m - 1`` rounds), even when a majority
        backlog GROWS every round; otherwise priority is queue length
        plus age (throughput-first with drift toward fairness). Ties
        break to the lowest target id (deterministic). With a flush
        timeout, both tiers select among ready queues only — a queue
        inside its window is waiting, not passed over."""
        if not self.pending():
            return None
        now = self.clock()
        ready = [t for t in self.queues if self._ready(t, now, force)]
        if not ready:
            return None
        starving = [t for t in ready if self._age[t] >= self.max_starve]
        if starving:
            target = max(starving, key=lambda t: (self._age[t], -t))
        else:
            target = max(ready,
                         key=lambda t: (len(self.queues[t]) + self._age[t],
                                        -t))
        q = self.queues[target]
        reqs, self.queues[target] = q[:self.max_batch], q[self.max_batch:]
        self._arrivals[target] = self._arrivals[target][len(reqs):]
        if not self.queues[target]:
            del self.queues[target]
            self._arrivals.pop(target, None)
        self._age.pop(target, None)
        for t in self.queues:
            if t != target:
                self._age[t] += 1
        max_len = max(len(r.tokens) for r in reqs)
        max_len = -(-max_len // self.pad_to_multiple) * self.pad_to_multiple
        toks = np.full((len(reqs), max_len), self.pad_token, np.int32)
        for i, r in enumerate(reqs):
            toks[i, :len(r.tokens)] = r.tokens
        return target, reqs, toks
