"""Request batching: group pending requests per target model, pad to the
engine's batch granularity, preserve submission order within a group."""
from __future__ import annotations

import dataclasses
import itertools
from collections import defaultdict
from typing import Dict, List, Optional

import numpy as np

_counter = itertools.count()


@dataclasses.dataclass
class Request:
    tokens: np.ndarray                      # (S,) prompt token ids
    x_emb: Optional[np.ndarray] = None      # router features
    x_feat: Optional[np.ndarray] = None
    domain: int = 0
    sample_idx: int = -1                    # replay-table row (quality/cost)
    rid: int = dataclasses.field(default_factory=lambda: next(_counter))


class RequestBatcher:
    def __init__(self, max_batch: int = 8, pad_to_multiple: int = 4,
                 pad_token: int = 0):
        self.max_batch = max_batch
        self.pad_to_multiple = pad_to_multiple
        self.pad_token = pad_token
        self.queues: Dict[int, List[Request]] = defaultdict(list)

    def submit(self, target: int, req: Request) -> None:
        self.queues[target].append(req)

    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def next_batch(self):
        """Pop up to max_batch requests for the fullest queue. Returns
        (target, requests, padded_tokens (B, S)) or None."""
        if not self.pending():
            return None
        target = max(self.queues, key=lambda t: len(self.queues[t]))
        q = self.queues[target]
        reqs, self.queues[target] = q[:self.max_batch], q[self.max_batch:]
        if not self.queues[target]:
            del self.queues[target]
        max_len = max(len(r.tokens) for r in reqs)
        max_len = -(-max_len // self.pad_to_multiple) * self.pad_to_multiple
        toks = np.full((len(reqs), max_len), self.pad_token, np.int32)
        for i, r in enumerate(reqs):
            toks[i, :len(r.tokens)] = r.tokens
        return target, reqs, toks
