"""Device-resident serving router: any `BanditPolicy` behind the async
engine (DESIGN.md §12.2).

The sim engine (`sim/engine.py`) already runs the whole protocol —
DECIDE / UPDATE / TRAIN / REBUILD — as jitted device code against
resident replay tables. This adapter reuses those exact policy callbacks
for SERVING: router state (net, optimizer, A^-1, outcome ring buffers)
never leaves the device, requests carry only their sample id (features
are gathered on device from the resident tables — zero host feature
transfer per request), and each microbatch is ONE jitted decide call and
ONE jitted update call regardless of batch width.

Outcome buffers are a (T, S) ring: row = wave mod capacity, S = the
microbatch width. `end_slice` runs the policy's chunked replay SGD +
Cholesky rebuild over everything the ring holds, with the same PRNG
discipline as the scanned runner — a wave-per-slice serving run is
bit-identical to `run_policy_device` (tests/test_serving_async.py).

Fallback remaps (a request rerouted after decide because its arm went
down mid-flight) are EXCLUDED from learning by default: the decide aux
(features g, safe mean) describes the decided arm, and the adapter is
policy-agnostic so it cannot recompute aux for an arbitrary policy.
Remapped rows get weight 0 and are counted by the engine; the common
outage path never hits this — `decide` takes the live availability mask,
so availability-aware policies never pick a down arm in the first place.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.sim.policies import (
    VANILLA_FORGETTING,
    BanditPolicy,
    ForgettingConfig,
    PolicyCtx,
)

_STATIC = ("policy", "fcfg", "train_chunks", "batch_size")


def _ctx(tables, hyp, *, env_idx=None, cum0=None, t=None, idx=None,
         mask=None, avail=None, fcfg=VANILLA_FORGETTING, train_chunks=1,
         batch_size=256):
    return PolicyCtx(tables=tables, env_idx=env_idx, cum0=cum0, hyp=hyp,
                     eff=None, t=t, idx=idx, mask=mask, avail=avail,
                     delay=0, fcfg=fcfg, train_chunks=train_chunks,
                     batch_size=batch_size)


@functools.partial(jax.jit, static_argnames=_STATIC)
def _srv_init(policy: BanditPolicy, key, tables, hyp, env_idx,
              fcfg=VANILLA_FORGETTING, train_chunks=1, batch_size=256):
    tables = policy.prepare(tables, hyp)
    cum0 = jnp.zeros(env_idx.shape[0] + 1, jnp.int32)
    ctx = _ctx(tables, hyp, env_idx=env_idx, cum0=cum0, fcfg=fcfg,
               train_chunks=train_chunks, batch_size=batch_size)
    state, key = policy.init(key, ctx)
    return state, key, tables


@functools.partial(jax.jit, static_argnames=_STATIC)
def _srv_decide(policy: BanditPolicy, state, key, tables, hyp, ids, avail,
                t, fcfg=VANILLA_FORGETTING, train_chunks=1, batch_size=256):
    batch = {"x_emb": tables["x_emb"][ids], "x_feat": tables["x_feat"][ids],
             "domain": tables["domain"][ids]}
    ctx = _ctx(tables, hyp, t=t, idx=ids, avail=avail, fcfg=fcfg,
               train_chunks=train_chunks, batch_size=batch_size)
    return policy.decide(state, key, batch, ctx)


@functools.partial(jax.jit, static_argnames=_STATIC,
                   donate_argnames=("state", "env_idx"))
def _srv_update(policy: BanditPolicy, state, env_idx, tables, hyp, row,
                ids, a, r, mask, perm, aux, fcfg=VANILLA_FORGETTING,
                train_chunks=1, batch_size=256):
    """One microbatch's feedback write + A^-1 maintenance. ``perm``
    compacts learnable rows to the row prefix (ring rows keep the
    prefix-validity layout `_sample_valid` assumes); identity when
    nothing was remapped or shed, so the permuted gather is a no-op and
    the sim-parity path stays bit-exact. ``state`` and ``env_idx`` are
    donated — the router rebinds both from the outputs every wave, so
    the ring buffers and A^-1 update in place."""
    n = perm.shape[0]
    ids, a, r, mask = ids[perm], a[perm], r[perm], mask[perm]
    aux = jax.tree_util.tree_map(
        lambda x: x[perm] if (getattr(x, "ndim", 0) >= 1
                              and x.shape[0] == n) else x, aux)
    env_idx = env_idx.at[row].set(ids)
    batch = {"x_emb": tables["x_emb"][ids], "x_feat": tables["x_feat"][ids],
             "domain": tables["domain"][ids]}
    ctx = _ctx(tables, hyp, env_idx=env_idx, t=row, idx=ids, mask=mask,
               fcfg=fcfg, train_chunks=train_chunks, batch_size=batch_size)
    state = policy.update(state, batch, a, r, ctx, aux)
    return state, env_idx


@functools.partial(jax.jit, static_argnames=_STATIC,
                   donate_argnames=("state",))
def _srv_train(policy: BanditPolicy, state, key, tables, hyp, env_idx,
               cum0, t, fcfg=VANILLA_FORGETTING, train_chunks=1,
               batch_size=256):
    """``state`` is donated: the sync path rebinds it immediately, and
    the overlapped path (``max_train_lag > 0``) feeds a device-side copy
    so the committed state decide reads stays live."""
    ctx = _ctx(tables, hyp, env_idx=env_idx, cum0=cum0, t=t, fcfg=fcfg,
               train_chunks=train_chunks, batch_size=batch_size)
    state, key = policy.train(state, key, ctx)
    state = policy.rebuild(state, ctx)
    return state, key


@functools.partial(jax.jit, static_argnames=_STATIC,
                   donate_argnames=("state",))
def _srv_train_sgd(policy: BanditPolicy, state, key, tables, hyp, env_idx,
                   cum0, t, fcfg=VANILLA_FORGETTING, train_chunks=1,
                   batch_size=256):
    """Replay-SGD stage only — the overlapped path dispatches this and
    `_srv_rebuild` as SEPARATE device programs so an interleaved decide
    queues behind at most one stage, not the whole train (the fused
    `_srv_train` would head-of-line-block the decide stream for its full
    duration on a busy device)."""
    ctx = _ctx(tables, hyp, env_idx=env_idx, cum0=cum0, t=t, fcfg=fcfg,
               train_chunks=train_chunks, batch_size=batch_size)
    return policy.train(state, key, ctx)


@functools.partial(jax.jit, static_argnames=_STATIC,
                   donate_argnames=("state",))
def _srv_rebuild(policy: BanditPolicy, state, tables, hyp, env_idx, cum0,
                 t, fcfg=VANILLA_FORGETTING, train_chunks=1,
                 batch_size=256):
    """A^-1 rebuild stage of the staged overlapped train."""
    ctx = _ctx(tables, hyp, env_idx=env_idx, cum0=cum0, t=t, fcfg=fcfg,
               train_chunks=train_chunks, batch_size=batch_size)
    return policy.rebuild(state, ctx)


def _merge_trained(new, cur):
    """Commit a finished train into the live router state: trained
    leaves come from ``new``, but the outcome ring keeps the LIVE
    ``cur["bufs"]`` — waves absorbed while the train was in flight must
    not be rolled back to the dispatch-time snapshot (train/rebuild
    never write bufs, so ``new["bufs"]`` is exactly that stale
    snapshot). Non-dict or ring-less states commit wholesale."""
    if (isinstance(new, dict) and isinstance(cur, dict)
            and "bufs" in new and "bufs" in cur):
        return dict(new, bufs=cur["bufs"])
    return new


def _tree_ready(tree) -> bool:
    return all(leaf.is_ready()
               for leaf in jax.tree_util.tree_leaves(tree)
               if hasattr(leaf, "is_ready"))


class DevicePolicyRouter:
    """Serving face of the `BanditPolicy` zoo (class docstring above).

    ``tables`` is the resident-table dict (`sim.engine._tables(env)`);
    ``slice_width`` is the microbatch capacity S (decide pads shorter
    batches); ``capacity_slices`` is the ring depth T. The PRNG
    discipline mirrors the scanned runner exactly: one split per decide
    call, train splitting further from the carried stream.

    ``max_train_lag`` bounds the zero-sync train overlap (DESIGN.md
    §15.2). 0 (default): ``end_slice`` blocks until train + rebuild
    finish — bit-identical to the sim scan. N > 0: ``end_slice``
    dispatches train on a device-side copy of the freshest state and
    returns immediately; decide keeps reading the last COMMITTED state
    while at most N trains are in flight (dispatching the (N+1)-th
    blocks on the oldest). Finished trains commit lazily before each
    decide — trained params/opt/A^-1 land atomically while the live
    outcome ring (which kept absorbing waves) is preserved, so feedback
    is never lost and decide staleness is bounded by
    ``train_epoch - committed_epoch <= max_train_lag``."""

    serving_v2 = True

    def __init__(self, policy: BanditPolicy, hypers: Any, tables: Dict,
                 *, seed: int = 0, slice_width: int = 256,
                 capacity_slices: int = 256, batch_size: int = 256,
                 train_chunks: int = 1,
                 fcfg: ForgettingConfig = VANILLA_FORGETTING,
                 pretrained_state: Any = None, log_capacity: int = 0,
                 max_train_lag: int = 0):
        self.policy = policy
        self.hyp = hypers
        self.S = int(slice_width)
        self.T = int(capacity_slices)
        self.batch_size = int(batch_size)
        self.train_chunks = int(train_chunks)
        self.fcfg = fcfg
        self.num_actions = int(np.asarray(tables["reward"]).shape[1])
        env_idx = jnp.zeros((self.T, self.S), jnp.int32)
        self.state, self._key, self.tables = _srv_init(
            policy, jax.random.PRNGKey(seed), tables, hypers, env_idx,
            fcfg=fcfg, train_chunks=train_chunks, batch_size=batch_size)
        if pretrained_state is not None:
            # warm start (DESIGN.md §13.3): the offline phase's state
            # (sim.pretrain_policy_state) replaces the fresh init; the
            # PRNG stream is untouched, matching the scanned runner's
            # init_state injection. A REAL copy (not asarray's identity
            # on device arrays): update/train donate their state args,
            # and the caller's checkpoint must survive that.
            self.state = jax.tree_util.tree_map(
                lambda x: jnp.array(x), pretrained_state)
        self._env_idx = env_idx
        # zero-sync train overlap (max_train_lag > 0): FIFO of dispatched
        # but uncommitted (epoch, state) train results
        self.max_train_lag = int(max_train_lag)
        if self.max_train_lag < 0:
            raise ValueError("max_train_lag must be >= 0")
        self._pending: list = []
        self.train_epoch = 0       # trains dispatched
        self.committed_epoch = 0   # trains visible to decide
        self._counts = np.zeros(self.T, np.int64)  # learned rows per ring row
        self.wave = 0          # microbatches absorbed (ring write cursor)
        self.slices = 0        # end_slice count (0 = warm)
        # propensity-aware request log (DESIGN.md §13.1): bounded ring of
        # LEARNED rows, drained by to_logged(); 0 disables (the storm
        # bench path pays nothing)
        self.log_capacity = int(log_capacity)
        self._log: list = []
        self._log_rows = 0

    def _statics(self):
        return dict(fcfg=self.fcfg, train_chunks=self.train_chunks,
                    batch_size=self.batch_size)

    # --------------------------------------------- train-overlap plumbing --
    @property
    def decide_staleness(self) -> int:
        """Trains dispatched but not yet visible to decide; bounded by
        ``max_train_lag`` at every point (tests/test_serving_async.py)."""
        return self.train_epoch - self.committed_epoch

    def _commit(self, epoch, out) -> None:
        self.state = _merge_trained(out, self.state)
        self.committed_epoch = epoch

    def _dispatch_rebuild(self, entry) -> None:
        """Advance a pending train from its finished SGD stage to the
        rebuild stage (one async dispatch). The SGD output is donated
        into the rebuild but stays referenced as the entry's keep-alive:
        dropping the last reference to a donated array blocks the host
        until the consuming computation finishes."""
        _epoch, _stage, s1, _keep, (env_c, cum0, t) = entry
        s2 = _srv_rebuild(self.policy, s1, self.tables, self.hyp,
                          env_c, cum0, t, **self._statics())
        entry[1] = "rebuild"
        entry[2] = s2
        entry[3] = s1

    def _advance(self) -> None:
        """Non-blocking pipeline tick (called before each decide reads
        the state and at every slice boundary): dispatch the rebuild
        stage for any train whose SGD finished, then commit every
        train whose rebuild finished, oldest first."""
        for entry in self._pending:
            if entry[1] == "sgd" and _tree_ready(entry[2]):
                self._dispatch_rebuild(entry)
        while (self._pending and self._pending[0][1] == "rebuild"
               and _tree_ready(self._pending[0][2])):
            entry = self._pending.pop(0)
            self._commit(entry[0], entry[2])

    def _force_oldest(self) -> None:
        """Blockingly drive the oldest in-flight train to commit."""
        entry = self._pending.pop(0)
        if entry[1] == "sgd":
            jax.block_until_ready(entry[2])
            self._dispatch_rebuild(entry)
        jax.block_until_ready(entry[2])
        self._commit(entry[0], entry[2])

    def _flush(self) -> None:
        """Block until every in-flight train is committed — snapshot,
        log-export, and restore paths need the fully-settled state."""
        while self._pending:
            self._force_oldest()

    def warmup(self) -> None:
        """Compile both decide variants (mask-free fast path and masked
        outage path) with a throwaway key, so jit compile time never
        lands in a storm's decide-latency samples. State and PRNG stream
        are untouched — compilation caches by shape, not value."""
        k, _ = jax.random.split(jax.random.PRNGKey(0))
        ids = jnp.zeros(self.S, jnp.int32)
        for av in (None, jnp.ones(self.num_actions, jnp.float32)):
            a, _, _ = _srv_decide(self.policy, self.state, k, self.tables,
                                  self.hyp, ids, av, jnp.int32(0),
                                  **self._statics())
            jax.block_until_ready(a)

    # ----------------------------------------------------------- DECIDE --
    def decide(self, x_emb=None, x_feat=None, domain=None, *,
               sample_idx=None, avail=None) -> Dict:
        """Decide for a microbatch of replay sample ids. ``avail`` is the
        engine's live arm-health mask ((K,) float, 1 = up); None or
        all-up takes the stationary fast trace (bit-identical to the sim
        scan's no-scenario path)."""
        ids = np.asarray(sample_idx, np.int64).reshape(-1)
        B = ids.size
        if not 0 < B <= self.S:
            raise ValueError(f"microbatch size {B} outside (0, {self.S}]")
        ids_pad = np.zeros(self.S, np.int32)
        ids_pad[:B] = ids
        av = None
        if avail is not None and not np.all(np.asarray(avail) > 0):
            av = jnp.asarray(avail, jnp.float32)
        if self._pending:
            # overlapped mode: tick the train pipeline (SGD -> rebuild
            # -> commit) — decide reads the freshest COMMITTED state
            self._advance()
        self._key, k = jax.random.split(self._key)
        a, logp, aux = _srv_decide(
            self.policy, self.state, k, self.tables, self.hyp,
            jnp.asarray(ids_pad), av, jnp.int32(min(self.slices, 1)),
            **self._statics())
        return {"action": np.asarray(a)[:B].astype(np.int32),
                "logp": np.asarray(logp)[:B].astype(np.float32),
                "ids": ids, "aux": aux, "n": B}

    # ----------------------------------------------------------- UPDATE --
    def update_wave(self, decision: Dict, served, rewards,
                    learn_mask=None) -> int:
        """Absorb one decided microbatch's outcomes into the ring.
        ``served`` are the arms actually run (== decided unless a
        fallback remapped); ``learn_mask`` marks rows to learn from
        (sheds and remaps excluded by the engine). Returns the number of
        rows learned."""
        B = decision["n"]
        served = np.asarray(served, np.int32).reshape(-1)
        rewards = np.asarray(rewards, np.float32).reshape(-1)
        assert served.size == B and rewards.size == B
        learn = (np.ones(B, bool) if learn_mask is None
                 else np.asarray(learn_mask, bool).reshape(-1))
        decided = decision["action"]
        learn = learn & (served == decided)   # remapped rows: aux is stale
        order = np.argsort(~learn, kind="stable")
        perm = np.concatenate([order, np.arange(B, self.S)]).astype(np.int32)
        pad = lambda v, dt: np.concatenate(  # noqa: E731
            [v, np.zeros(self.S - B, dt)]).astype(dt)
        row = self.wave % self.T
        self.state, self._env_idx = _srv_update(
            self.policy, self.state, self._env_idx, self.tables, self.hyp,
            jnp.int32(row), jnp.asarray(pad(decision["ids"], np.int32)),
            jnp.asarray(pad(served, np.int32)),
            jnp.asarray(pad(rewards, np.float32)),
            jnp.asarray(pad(learn.astype(np.float32), np.float32)),
            jnp.asarray(perm), decision["aux"], **self._statics())
        self._counts[row] = int(learn.sum())
        self.wave += 1
        if self.log_capacity and learn.any():
            lp = decision.get("logp")
            lp = (np.zeros(B, np.float32) if lp is None
                  else np.asarray(lp, np.float32).reshape(-1))
            self._log.append((
                np.asarray(decision["ids"], np.int64)[learn],
                served[learn].copy(), rewards[learn].copy(), lp[learn],
                np.full(int(learn.sum()), self.slices, np.int32)))
            self._log_rows += int(learn.sum())
            while self._log_rows > self.log_capacity and len(self._log) > 1:
                self._log_rows -= len(self._log.pop(0)[0])
        return int(learn.sum())

    # ------------------------------------------------------- REQUEST LOG --
    def to_logged(self):
        """Round-trip the serving request log into a
        :class:`repro.data.logged.LoggedInteractions` (DESIGN.md §13.1):
        the production loop's log -> pretrain -> redeploy closer. Only
        LEARNED rows are logged (sheds and fallback remaps carry no
        usable propensity); contexts are gathered from the resident
        tables. Requires ``log_capacity > 0`` at construction."""
        from repro.data.logged import LoggedInteractions
        if not self.log_capacity:
            raise ValueError(
                "DevicePolicyRouter: request logging is disabled "
                "(log_capacity=0); construct with log_capacity > 0")
        if not self._log:
            raise ValueError("DevicePolicyRouter: request log is empty — "
                             "serve some traffic first")
        ids = np.concatenate([c[0] for c in self._log])
        a = np.concatenate([c[1] for c in self._log])
        r = np.concatenate([c[2] for c in self._log])
        lp = np.concatenate([c[3] for c in self._log])
        sl = np.concatenate([c[4] for c in self._log])
        return LoggedInteractions(
            x_emb=np.asarray(self.tables["x_emb"])[ids],
            x_feat=np.asarray(self.tables["x_feat"])[ids],
            domain=np.asarray(self.tables["domain"])[ids],
            action=a, reward=r, logp=lp, slice_idx=sl,
            num_actions=self.num_actions,
            behavior=f"serving:{self.policy.name}", sample_idx=ids)

    # ------------------------------------------------- TRAIN + REBUILD --
    def end_slice(self, epochs: Optional[int] = None) -> None:
        """Replay-SGD + A^-1 rebuild over the ring (one jitted dispatch);
        ends the warm phase. ``epochs`` is accepted for interface parity
        with the host router — the SGD budget here is the constructor's
        static ``train_chunks``.

        ``max_train_lag == 0``: dispatch and BLOCK (the train pause owns
        its own wall time instead of bleeding into the next decide's
        latency sample — and the next decide reads the trained state,
        bit-identical to the sim scan). ``max_train_lag > 0``: dispatch
        on a copy of the freshest state and return without syncing; the
        host thread goes straight back to admitting and deciding the
        next microbatches while the device grinds the train program."""
        del epochs
        if self.wave > 0:
            t = min(self.wave, self.T) - 1
            cum0 = jnp.asarray(np.concatenate(
                [[0], np.cumsum(self._counts)]).astype(np.int32))
            if self.max_train_lag == 0:
                self.state, self._key = _srv_train(
                    self.policy, self.state, self._key, self.tables,
                    self.hyp, self._env_idx, cum0, jnp.int32(t),
                    **self._statics())
                self.train_epoch += 1
                self.committed_epoch = self.train_epoch
                jax.block_until_ready(self.state)
            else:
                # bounded staleness: dispatching the (lag+1)-th in-flight
                # train blocks on the oldest, so decide never lags the
                # freshest dispatched train by more than max_train_lag
                self._advance()
                while len(self._pending) >= self.max_train_lag:
                    self._force_oldest()
                base = (_merge_trained(self._pending[-1][2], self.state)
                        if self._pending else self.state)
                # donate a device-side copy: `base` aliases the committed
                # state (and possibly a pending commit target) that
                # decide keeps reading while this train is in flight.
                # env_idx is copied too — the live buffer is donated away
                # by the next update_wave, and the rebuild stage reads it
                # later than this dispatch.
                tin = jax.tree_util.tree_map(jnp.copy, base)
                env_c = jnp.copy(self._env_idx)
                t32 = jnp.int32(t)
                s1, self._key = _srv_train_sgd(
                    self.policy, tin, self._key, self.tables, self.hyp,
                    env_c, cum0, t32, **self._statics())
                self.train_epoch += 1
                # `tin` rides along as a keep-alive: dropping the last
                # reference to a DONATED array blocks the host until the
                # consuming computation finishes (which would make this
                # "zero-sync" dispatch silently synchronous). It is
                # released when the stage completes, when deletion is
                # free. Entry: [epoch, stage, output, keep, rebuild ctx].
                self._pending.append(
                    [self.train_epoch, "sgd", s1, tin, (env_c, cum0, t32)])
        self.slices += 1

    # --------------------------------------------------------- SNAPSHOT --
    def state_dict(self) -> Dict:
        self._flush()
        return {
            "arrays": {
                "state": jax.tree_util.tree_map(np.asarray, self.state),
                "key": np.asarray(self._key),
                "env_idx": np.asarray(self._env_idx),
                "counts": self._counts.copy(),
            },
            "meta": {"wave": int(self.wave), "slices": int(self.slices)},
        }

    def load_state_dict(self, d: Dict) -> None:
        arrays = d["arrays"]
        # in-flight trains describe the state being replaced — discard
        self._pending = []
        self.committed_epoch = self.train_epoch
        self.state = jax.tree_util.tree_map(jnp.asarray, arrays["state"])
        self._key = jnp.asarray(arrays["key"])
        self._env_idx = jnp.asarray(arrays["env_idx"])
        self._counts = np.asarray(arrays["counts"], np.int64).copy()
        self.wave = int(d["meta"]["wave"])
        self.slices = int(d["meta"]["slices"])
