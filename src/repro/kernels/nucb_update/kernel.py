"""Fused rank-k Woodbury A^-1 update as ONE Pallas launch.

The third leg of Algorithm 1's hot loop (after the fused decide and the
blocked-Cholesky rebuild): fold a slice's observed features G (n, F)
into the shared inverse covariance,

    (A + G_b^T G_b)^-1 = A^-1 - A^-1 G_b^T (I_k + G_b A^-1 G_b^T)^-1 G_b A^-1

applied block-by-block over row blocks G_b of ``block_k`` rows. The jnp
path (`core.neuralucb.woodbury_update`) runs the same recurrence as a
``fori_loop`` of XLA matmuls, round-tripping A^-1 through HBM between
blocks; here A^-1 lives in a single (Fp, Fp) f32 VMEM scratch for the
whole launch while the grid streams G row blocks past it:

    step 0:        acc <- A^-1 (copied once from HBM)
    every step i:  u = G_i acc            (block_k, Fp)   MXU
                   S = I + u G_i^T        (block_k, block_k)
                   Sinv = chol(S) solve   (in-VMEM blocked Cholesky,
                                           reused from kernels/ainv_rebuild)
                   x = Sinv u
                   acc <- sym(acc - u^T x)
    last step:     out <- acc             (written once to HBM)

Zero rows of G are exact no-ops (identity row/col in S, zero row in u),
so the caller pads both the row count (to a ``block_k`` multiple) and
the feature dim (to the 128-lane multiple) with zeros and slices the
result — the padded A^-1 block stays identically zero.

The symmetrization uses 0.5 * (u^T x + x^T u) — two `_GRAM`
dot_generals — instead of materializing a transpose, which Mosaic would
otherwise have to lay out separately; both forms keep acc bit-symmetric
given a symmetric input.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ainv_rebuild.kernel import _GRAM, _spd_inverse
from repro.kernels.compat import CompilerParams

_INNER = (((1,), (1,)), ((), ()))   # (k,n) x (m,n) -> X Y^T


def _update_kernel(g_ref, ainv_ref, out_ref, acc_ref, *, block_s: int):
    i = pl.program_id(0)
    f32 = jnp.float32

    @pl.when(i == 0)
    def _():
        acc_ref[...] = ainv_ref[...].astype(f32)

    g = g_ref[...].astype(f32)                               # (Bk, Fp)
    acc = acc_ref[...]
    u = jax.lax.dot(g, acc, preferred_element_type=f32)      # G A^-1
    k = g.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (k, k), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (k, k), 1)
    eye = jnp.where(rows == cols, 1.0, 0.0).astype(f32)
    s = eye + jax.lax.dot_general(u, g, _INNER,
                                  preferred_element_type=f32)
    sinv = _spd_inverse(s, block_s)                          # (Bk, Bk)
    x = jax.lax.dot(sinv, u, preferred_element_type=f32)     # S^-1 G A^-1
    down = jax.lax.dot_general(u, x, _GRAM, preferred_element_type=f32)
    down_t = jax.lax.dot_general(x, u, _GRAM, preferred_element_type=f32)
    acc_ref[...] = acc - 0.5 * (down + down_t)

    @pl.when(i == pl.num_programs(0) - 1)
    def _():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("block_k", "block_s", "interpret"))
def nucb_update_padded(gs, ainv, *, block_k: int = 128,
                       block_s: int = 128, interpret: bool = False):
    """gs (N, Fp) with N % block_k == 0 and Fp % 128 == 0 (zero rows and
    zero feature columns are exact no-ops); ainv (Fp, Fp) f32, zero in
    the padded block. block_s is the in-kernel Cholesky panel width and
    must divide block_k. Returns the updated A^-1 (Fp, Fp) f32."""
    n, fp = gs.shape
    assert n % block_k == 0 and block_k % block_s == 0, (n, block_k, block_s)
    nb = n // block_k
    kern = functools.partial(_update_kernel, block_s=block_s)
    return pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_k, fp), lambda i: (i, 0)),
            pl.BlockSpec((fp, fp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((fp, fp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((fp, fp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((fp, fp), jnp.float32)],
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(gs, ainv)
