"""Public entry point for the fused rank-k Woodbury A^-1 update.

``nucb_update(ainv, gs)`` is a drop-in for
``core.neuralucb.woodbury_update(ainv, gs)`` behind the one backend
gate in `kernels/backend.py`: the jnp backend delegates to it verbatim
(bit-identical in f32), the Pallas backends pad to TPU tiles and run
the single-launch kernel with A^-1 VMEM-resident across row blocks.

Padding contract (all zeros, all exact no-ops):

* feature dim F -> Fp, the next 128 multiple; A^-1 is zero-padded (NOT
  identity-padded like the rebuild kernel's lambda0 diagonal) so the
  padded block stays identically zero through every Woodbury step and
  the ``[:F, :F]`` slice is exact;
* row count N -> the next ``block_k`` multiple; a zero feature row
  contributes an identity row/column to S and a zero row to G A^-1.

bf16 features are accepted and cast to f32 at the kernel boundary —
A^-1 is f32 statistics state on every path (DESIGN.md §14).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.backend import INTERPRET, REF, resolve_backend
from repro.kernels.nucb_update.kernel import nucb_update_padded
from repro.kernels.nucb_update.ref import nucb_update_ref


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def _nucb_update_pallas(ainv, gs, *, block_k: int, interpret: bool):
    n, f = gs.shape
    gs = gs.astype(jnp.float32)
    ainv = ainv.astype(jnp.float32)
    pad_f = -f % 128
    if pad_f:
        gs = jnp.pad(gs, ((0, 0), (0, pad_f)))
        ainv = jnp.pad(ainv, ((0, pad_f), (0, pad_f)))
    bk = min(block_k, max(8, n))
    pad_n = -n % bk
    if pad_n:
        gs = jnp.pad(gs, ((0, pad_n), (0, 0)))
    # in-kernel Cholesky panel width must divide the row block; a short
    # final bk (< block_k, only when n < block_k) becomes its own panel
    bs = 128 if bk % 128 == 0 else bk
    out = nucb_update_padded(gs, ainv, block_k=bk, block_s=bs,
                             interpret=interpret)
    return out[:f, :f]


def nucb_update(ainv: jax.Array, gs: jax.Array, *, block_k: int = 128,
                interpret: Optional[bool] = None) -> jax.Array:
    """Rank-k Woodbury update of A^-1 (F, F) with features gs (N, F).

    ``interpret`` resolves via `kernels.backend.resolve_backend`:
    None -> compiled kernel on TPU, jnp reference elsewhere (or the
    ``REPRO_KERNEL_BACKEND`` override); True -> Pallas interpreter.
    """
    backend = resolve_backend(interpret)
    if backend == REF:
        return nucb_update_ref(ainv, gs)
    if gs.shape[0] == 0:
        return ainv.astype(jnp.float32)
    return _nucb_update_pallas(ainv, gs, block_k=block_k,
                               interpret=backend == INTERPRET)
