from repro.kernels.nucb_update.ops import nucb_update
from repro.kernels.nucb_update.ref import nucb_update_ref

__all__ = ["nucb_update", "nucb_update_ref"]
