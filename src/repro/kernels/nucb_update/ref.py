"""Pure-jnp oracle for the fused rank-k Woodbury update kernel: the
repo's existing blocked update (`core.neuralucb.woodbury_update`) IS
the reference — on the jnp backend `nucb_update` must be bit-identical
to it in f32, not merely close."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import neuralucb as NU


def nucb_update_ref(ainv, gs, block_size: int = 0):
    """ainv (F, F), gs (N, F). Returns the updated A^-1 (F, F) f32."""
    return NU.woodbury_update(ainv.astype(jnp.float32),
                              gs.astype(jnp.float32), block_size)
