"""Pure-jnp oracle for flash-decode."""
from __future__ import annotations

import math

import jax.numpy as jnp


def decode_attention_ref(q, k, v, pos, *, window: int = 0):
    """q: (B, H, D); k, v: (B, KV, S, D); pos: scalar int (last valid index).
    Returns (B, H, D)."""
    B, H, D = q.shape
    KV, S = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qg, k.astype(jnp.float32)) / math.sqrt(D)
    k_pos = jnp.arange(S)
    mask = k_pos <= pos
    if window > 0:
        mask &= k_pos > (pos - window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)
