"""Public flash-decode op: pads, runs split-K partials, combines."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_padded


@functools.partial(jax.jit, static_argnames=("window", "block_s", "interpret"))
def decode_attention(q, k, v, pos, *, window: int = 0, block_s: int = 1024,
                     interpret: bool = True):
    """q: (B, H, D); k, v: (B, KV, S, D); pos: scalar int32 (index of the
    newest valid cache entry). Returns (B, H, D)."""
    B, H, D = q.shape
    KV, S = k.shape[1], k.shape[2]
    G = H // KV
    bs = min(block_s, max(128, S))
    pad_s = (-S) % bs
    pad_d = (-D) % 128
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
    qg = q.reshape(B, KV, G, D)
    if pad_d:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad_d)))

    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)
    m, l, acc = decode_attention_padded(qg, k, v, pos_arr, window=window,
                                        block_s=bs, scale_dim=D,
                                        interpret=interpret)
    # combine splits: global logsumexp over the NS axis
    m_g = jnp.max(m, axis=2, keepdims=True)                    # (B,KV,1,G)
    w = jnp.exp(m - m_g)                                       # (B,KV,NS,G)
    l_g = jnp.sum(l * w, axis=2)                               # (B,KV,G)
    acc_g = jnp.sum(acc * w[..., None], axis=2)                # (B,KV,G,D)
    out = acc_g / jnp.maximum(l_g[..., None], 1e-30)
    out = out[..., :D]
    return out.reshape(B, H, D).astype(q.dtype)
