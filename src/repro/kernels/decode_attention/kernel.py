"""Flash-decode Pallas kernel (TPU target).

One query token per sequence attends over a long KV cache. The cache is
split along the sequence dimension (split-K); each grid step computes
partial softmax statistics (m, l, acc) for its span; ops.py does the
logsumexp combine over splits. This is how decode saturates HBM bandwidth
on TPU: every split streams its KV span HBM->VMEM exactly once, and the
(G x Bk) score tile plus (G x D) accumulator stay in VMEM/VREGs.

Grid: (batch, kv_heads, num_splits). Query layout (B, KV, G, D) groups the
GQA query heads that share a KV head, so the MXU contraction is
(G x D) @ (D x Bk) per step.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref, *,
                   scale: float, window: int, block_s: int):
    i_s = pl.program_id(2)
    pos = pos_ref[0]  # valid cache entries are [0, pos]
    q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (Bs, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    k_pos = i_s * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos <= pos
    if window > 0:
        mask &= k_pos > (pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m = jnp.max(s, axis=1, keepdims=True)               # (G, 1)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=1, keepdims=True)
    acc = jax.lax.dot(p, v, preferred_element_type=jnp.float32)  # (G, D)

    m_ref[0, 0, 0] = m[:, 0]
    l_ref[0, 0, 0] = l[:, 0]
    acc_ref[0, 0, 0] = acc


@functools.partial(
    jax.jit,
    static_argnames=("window", "block_s", "scale_dim", "interpret"))
def decode_attention_padded(q, k, v, pos, *, window: int = 0,
                            block_s: int = 1024, scale_dim: int = 0,
                            interpret: bool = True):
    """q: (B, KV, G, D); k, v: (B, KV, S, D) with S % block_s == 0;
    pos: (1,) int32. Returns partial (m, l, acc) over splits:
    m, l: (B, KV, NS, G); acc: (B, KV, NS, G, D)."""
    B, KV, G, D = q.shape
    S = k.shape[2]
    ns = S // block_s
    scale = 1.0 / math.sqrt(scale_dim or D)
    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=(B, KV, ns),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, i: (b, h, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, G), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, 1, G), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, 1, G, D), lambda b, h, i: (b, h, i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, ns, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, ns, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, ns, G, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(pos, q, k, v)
