"""One backend gate for every Pallas op in the repo.

Every ``ops.py`` entry point takes ``interpret=None`` and resolves it
here instead of hard-coding its own ``jax.default_backend() != "tpu"``
check (the pre-PR-8 state: ``ucb_score`` defaulted to ``interpret=True``
— the slow Pallas interpreter — even on TPU, and two call sites in
``sim/policies.py`` plus two in ``core/policy.py`` each carried their
own copy of the gate).

Resolution of ``interpret``:

* ``None``  (the default) — auto: run the compiled Pallas kernel on
  TPU, dispatch to the op's pure-jnp ``ref.py`` everywhere else. The
  interpreter is never chosen implicitly; it exists for tests.
* ``True``  — force the Pallas interpreter (kernel parity tests on CPU
  exercise the actual kernel body this way).
* ``False`` — force the compiled Pallas kernel (TPU only).

The ``REPRO_KERNEL_BACKEND`` environment variable overrides the
``interpret=None`` auto-detection for EVERY op at once (``pallas`` /
``jnp`` / ``interpret``), so CI and users can force interpret-mode
parity runs without code edits. Explicit ``interpret=True/False`` at a
call site still wins — the override only replaces the default.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

#: resolve_backend() return values
REF = "ref"            # pure-jnp reference (ref.py)
PALLAS = "pallas"      # compiled Pallas kernel
INTERPRET = "interpret"  # Pallas interpreter (kernel body on CPU)

#: REPRO_KERNEL_BACKEND values -> resolve_backend() results
_ENV_BACKENDS = {"pallas": PALLAS, "jnp": REF, "interpret": INTERPRET}


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(interpret: Optional[bool]) -> str:
    """Map an op's ``interpret`` flag to one of REF/PALLAS/INTERPRET."""
    if interpret is None:
        env = os.environ.get("REPRO_KERNEL_BACKEND")
        if env:
            try:
                return _ENV_BACKENDS[env.strip().lower()]
            except KeyError:
                raise ValueError(
                    f"REPRO_KERNEL_BACKEND={env!r} is not a known backend; "
                    f"use one of {sorted(_ENV_BACKENDS)}") from None
        return PALLAS if on_tpu() else REF
    return INTERPRET if interpret else PALLAS
