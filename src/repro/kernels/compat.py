"""Pallas-TPU API compatibility shims.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` around
0.5.x; the kernels in this package are written against the new name and on
older jax (e.g. 0.4.37, the pinned CI version) resolve it through this
module instead of ``pltpu`` directly. Import the symbol from here in every
kernel so one shim covers the whole package:

    from repro.kernels.compat import CompilerParams
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

if hasattr(pltpu, "CompilerParams"):
    CompilerParams = pltpu.CompilerParams
else:  # jax <= 0.4.x
    CompilerParams = pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
