"""Blocked-Cholesky A^-1 rebuild Pallas kernel (TPU target) — Algorithm 1
line 8 as ONE launch:

    A = lambda0 I + sum_i w_i g_i g_i^T        (streamed Gram accumulation)
    A = L L^T ; A^-1 = L^-T L^-1               (blocked Cholesky inverse)

Feature rows g (N, F_pad) stream through the grid in blocks of
``block_r``; a VMEM scratch accumulates the Gram matrix in f32 across
grid steps (initialized to lambda0 I at step 0, one MXU GEMM per
block), and the final grid step factorizes and inverts in-VMEM — A and
A^-1 never round-trip to HBM between the two phases, unlike the jnp
path (`core.neuralucb.rebuild_ainv`), which materializes the (N, F)
feature matrix and calls a host-library Cholesky at full capacity.

The factorization is a right-looking *blocked* Cholesky: within a
column panel of width ``block_s`` the per-column pivot/scale/update
runs on the VPU restricted to the panel, and each finished panel
applies its trailing update as a single MXU GEMM. The triangular
inverse is a forward substitution with one (1, n) x (n, n) MXU row
solve per column. All index selection uses 2-D broadcasted_iota masks
(TPU has no 1-D iota and Mosaic prefers masked full-width ops over
sub-tile slicing); everything stays f32.

Padding contract: F padded to a 128 multiple with ZERO feature columns
and lambda0 on the FULL padded diagonal, so A_pad is block-diagonal
([A, 0; 0, lambda0 I]) and invertible, and A_pad^-1[:F, :F] is exactly
A^-1 (the caller slices).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

_OUTER = (((1,), (1,)), ((), ()))   # (n,1) x (n,1) -> outer product (n,n)
_GRAM = (((0,), (0,)), ((), ()))    # (m,n) x (m,k) -> X^T Y


def _chol_blocked(a, block_s: int):
    """Lower Cholesky factor of SPD ``a`` (n, n), right-looking with
    column panels of width ``block_s`` (n % block_s == 0)."""
    f32 = jnp.float32
    n = a.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    rvec = jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)

    def column(j, hi, m):
        """Finalize column j; trailing update restricted to cols < hi
        (the panel) — the inter-panel part goes through the GEMM."""
        pivot = jnp.sum(jnp.where((rows == j) & (cols == j), m, 0.0))
        d = jnp.sqrt(jnp.maximum(pivot, 1e-30))
        colj = jnp.sum(jnp.where(cols == j, m, 0.0), axis=1,
                       keepdims=True)                        # (n, 1)
        below = jnp.where(rvec > j, colj / d, 0.0)           # (n, 1)
        newcol = below + jnp.where(rvec == j, d, 0.0)
        m = jnp.where(cols == j, newcol, m)
        outer = jax.lax.dot_general(below, below, _OUTER,
                                    preferred_element_type=f32)
        upd = (rows > j) & (cols > j) & (cols < hi)
        return m - jnp.where(upd, outer, 0.0)

    m = a.astype(f32)
    for lo in range(0, n, block_s):                 # static panel loop
        hi = lo + block_s
        m = jax.lax.fori_loop(
            lo, hi, lambda j, mm: column(j, hi, mm), m)
        if hi < n:
            # one MXU GEMM applies the panel to the whole trailing block
            p = jnp.where((cols >= lo) & (cols < hi) & (rows >= hi),
                          m, 0.0)
            gram = jax.lax.dot_general(p, p, (((1,), (1,)), ((), ())),
                                       preferred_element_type=f32)
            m = m - jnp.where((rows >= hi) & (cols >= hi), gram, 0.0)
    return jnp.where(rows >= cols, m, 0.0)


def _tril_inv(ell):
    """Inverse of a lower-triangular ``ell`` (n, n) by forward
    substitution — one masked (1, n) x (n, n) MXU row solve per step."""
    f32 = jnp.float32
    n = ell.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    cvec = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)

    def body(j, x):
        lrow = jnp.sum(jnp.where(rows == j, ell, 0.0), axis=0,
                       keepdims=True)                        # (1, n)
        ljj = jnp.sum(jnp.where(cvec == j, lrow, 0.0))
        strict = jnp.where(cvec < j, lrow, 0.0)
        contrib = jax.lax.dot(strict, x,
                              preferred_element_type=f32)    # (1, n)
        ej = jnp.where(cvec == j, 1.0, 0.0).astype(f32)
        newrow = (ej - contrib) / ljj
        return jnp.where(rows == j, newrow, x)

    return jax.lax.fori_loop(0, n, body, jnp.zeros((n, n), f32))


def _spd_inverse(a, block_s: int):
    ell = _chol_blocked(a, block_s)
    linv = _tril_inv(ell)
    # A^-1 = L^-T L^-1, one Gram GEMM
    return jax.lax.dot_general(linv, linv, _GRAM,
                               preferred_element_type=jnp.float32)


def _rebuild_kernel(g_ref, w_ref, lam_ref, out_ref, acc_ref, *,
                    block_s: int):
    i = pl.program_id(0)
    n = acc_ref.shape[0]

    @pl.when(i == 0)
    def _():
        rows = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
        acc_ref[...] = jnp.where(rows == cols, lam_ref[0], 0.0)

    sw = jnp.sqrt(jnp.maximum(w_ref[...], 0.0))              # (Br,)
    gw = g_ref[...].astype(jnp.float32) * sw[:, None]
    acc_ref[...] += jax.lax.dot_general(
        gw, gw, _GRAM, preferred_element_type=jnp.float32)

    @pl.when(i == pl.num_programs(0) - 1)
    def _():
        out_ref[...] = _spd_inverse(acc_ref[...], block_s)


@functools.partial(jax.jit, static_argnames=("block_r", "block_s",
                                             "interpret"))
def ainv_rebuild_padded(g, w, lam, *, block_r: int = 1024,
                        block_s: int = 128, interpret: bool = False):
    """g: (N, Fp) with N % block_r == 0 and Fp % 128 == 0 (zero-padded
    feature columns); w: (N,) row weights (padded rows carry 0);
    lam: (1,) f32. Returns A_pad^-1 (Fp, Fp) f32."""
    N, Fp = g.shape
    nr = N // block_r
    kern = functools.partial(_rebuild_kernel, block_s=block_s)
    return pl.pallas_call(
        kern,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((block_r, Fp), lambda i: (i, 0)),
            pl.BlockSpec((block_r,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((Fp, Fp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((Fp, Fp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((Fp, Fp), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(g, w, lam)
