"""Public A^-1 rebuild op: pads rows/features, runs the kernel.

Backend selection follows :mod:`repro.kernels.backend`: compiled kernel
on TPU, the jnp Cholesky-solve reference elsewhere, interpreter only on
request (tests).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ainv_rebuild.kernel import ainv_rebuild_padded
from repro.kernels.ainv_rebuild.ref import ainv_rebuild_ref
from repro.kernels.backend import INTERPRET, REF, resolve_backend


def ainv_rebuild(gs, ridge_lambda0=1.0, weights=None, *,
                 block_r: int = 1024, interpret: Optional[bool] = None):
    """gs: (N, F) buffered features; ``weights`` (N,) scales row i's
    contribution to A = lambda0 I + sum_i w_i g_i g_i^T linearly (rows
    are scaled by sqrt(w) inside the kernel). Returns A^-1 (F, F) f32.
    """
    backend = resolve_backend(interpret)
    if backend == REF:
        return ainv_rebuild_ref(gs, ridge_lambda0, weights=weights)
    if weights is None:
        weights = jnp.ones((gs.shape[0],), jnp.float32)
    return _ainv_rebuild_pallas(
        gs, weights, jnp.asarray(ridge_lambda0, jnp.float32).reshape(1),
        block_r=block_r, interpret=backend == INTERPRET)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def _ainv_rebuild_pallas(gs, weights, lam, *, block_r: int,
                         interpret: bool):
    N, F = gs.shape
    pad_f = (-F) % 128
    br = min(block_r, max(8, N))
    pad_n = (-N) % br
    if pad_f:
        # zero feature columns + lambda0 on the full padded diagonal
        # (kernel contract) keep A_pad block-diagonal: the [:F, :F]
        # block of its inverse is exactly A^-1
        gs = jnp.pad(gs, ((0, 0), (0, pad_f)))
    if pad_n:
        gs = jnp.pad(gs, ((0, pad_n), (0, 0)))
        weights = jnp.pad(weights, (0, pad_n))   # w=0: inert rows
    out = ainv_rebuild_padded(gs, weights.astype(jnp.float32), lam,
                              block_r=br, interpret=interpret)
    return out[:F, :F]
