"""Pure-jnp oracle for the A^-1 rebuild kernel: the repo's existing
Cholesky-solve path (`core.neuralucb.rebuild_ainv`) IS the reference —
the kernel must match it, not the other way round."""
from __future__ import annotations

from repro.core import neuralucb as NU


def ainv_rebuild_ref(gs, ridge_lambda0=1.0, weights=None):
    """gs: (N, F); weights: (N,) or None. Returns A^-1 (F, F) f32."""
    return NU.rebuild_ainv(gs, ridge_lambda0, weights=weights)
