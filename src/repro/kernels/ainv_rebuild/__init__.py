from repro.kernels.ainv_rebuild.ops import ainv_rebuild
from repro.kernels.ainv_rebuild.ref import ainv_rebuild_ref

__all__ = ["ainv_rebuild", "ainv_rebuild_ref"]
