"""Public flash-attention op: pads to MXU/block multiples, calls the Pallas
kernel, unpads. Interpret mode on CPU; compiled on TPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_padded


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=512,
                    block_k=512, interpret=True):
    """q: (B, H, Sq, D); k, v: (B, KV, Sk, D) -> (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    bq = min(block_q, max(128, 1 << (Sq - 1).bit_length()))
    bk = min(block_k, max(128, 1 << (Sk - 1).bit_length()))
    qp = _pad_to(_pad_to(q, 2, bq), 3, 128)
    kp = _pad_to(_pad_to(k, 2, bk), 3, 128)
    vp = _pad_to(_pad_to(v, 2, bk), 3, 128)
    out = flash_attention_padded(qp, kp, vp, causal=causal, window=window,
                                 block_q=bq, block_k=bk, kv_len=Sk,
                                 scale_dim=D, interpret=interpret)
    return out[:, :, :Sq, :D]
