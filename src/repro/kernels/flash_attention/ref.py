"""Pure-jnp oracle for flash_attention (independent of repro.models)."""
from __future__ import annotations

import math

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, H, Sq, D); k, v: (B, KV, Sk, D) -> (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
