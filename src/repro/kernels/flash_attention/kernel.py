"""Flash-attention Pallas kernel (TPU target).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the last dim is the
reduction ("arbitrary") dimension; m/l/acc live in VMEM scratch and the
output block is written on the final KV step (the classic revisiting
pattern). GQA is handled in the K/V index_map: query head ``h`` reads KV
head ``h // group_size``, so K/V tiles are fetched once per group.

VMEM budget per step (bf16 inputs, f32 scratch):
  q (Bq x D) + k,v (Bk x D) + scratch acc (Bq x D) + p (Bq x Bk)
  with Bq=Bk=512, D=128: ~0.9 MB << 16 MB VMEM. MXU dims are multiples
  of 128 by construction (ops.py pads D and the sequence).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, block_q: int,
                  block_k: int, kv_len: int, num_kv_blocks: int):
    i_q = pl.program_id(2)
    i_k = pl.program_id(3)

    @pl.when(i_k == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (Bq, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (Bk, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = i_q * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = i_k * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < kv_len  # padding
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(i_k == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "kv_len",
                     "scale_dim", "interpret"))
def flash_attention_padded(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           block_q: int = 512, block_k: int = 512,
                           kv_len: int = 0, scale_dim: int = 0,
                           interpret: bool = True) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, KV, Sk, D); all dims pre-padded so that
    Sq % block_q == Sk % block_k == 0 and D % 128 == 0. ``kv_len`` is the
    true (unpadded) KV length; ``scale_dim`` the true head dim."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    nq, nk = Sq // block_q, Sk // block_k
    scale = 1.0 / math.sqrt(scale_dim or D)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_len=kv_len or Sk,
        num_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
