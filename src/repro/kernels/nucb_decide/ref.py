"""Pure-jnp oracle for the fused NeuralUCB decide kernel.

Operates on the same preprocessed inputs as the kernel (context GEMM
split out of trunk1, per-action bias rows ``act1``) so kernel parity
tests compare like against like; ``sim.policies._decide_ucb`` with
``backend="jnp"`` is the independent end-to-end reference (same math
through ``utilitynet_all_actions``, different op order).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def nucb_decide_ref(ctx, w1ctx, act1, w2, b2, wu, bu, ainv, gate_p,
                    avail, beta, tau_g):
    """ctx (B, C); w1ctx (C, H); act1 (K, H); w2 (H, D); b2, wu (D,);
    bu, beta, tau_g scalars; ainv (F, F) with F == D + 1; gate_p (B,);
    avail (K,) f32 or None. Returns (a (B,) i32, g (B, F) f32,
    mu_safe (B,) f32)."""
    f32 = jnp.float32
    base = ctx.astype(f32) @ w1ctx.astype(f32)               # (B, H)
    h1 = jax.nn.gelu(base[:, None, :] + act1.astype(f32)[None])
    h = jax.nn.gelu(h1 @ w2.astype(f32) + b2.astype(f32))    # (B, K, D)
    mu = jnp.sum(h * wu.astype(f32), axis=-1) + bu           # (B, K)
    hn = h / jnp.maximum(
        jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)
    ones = jnp.ones(hn.shape[:-1] + (1,), hn.dtype)
    g_all = jnp.concatenate([hn, ones], axis=-1) / jnp.sqrt(2.0)
    quad = jnp.einsum("bkf,fe,bke->bk", g_all, ainv.astype(f32), g_all)
    scores = mu + beta * jnp.sqrt(jnp.maximum(quad, 0.0))
    if avail is not None:
        neg = jnp.where(avail > 0, 0.0, -jnp.inf)
        scores = scores + neg
        mu_m = mu + neg
    else:
        mu_m = mu
    a_ucb = jnp.argmax(scores, axis=-1)
    a_safe = jnp.argmax(mu_m, axis=-1)
    a = jnp.where(gate_p >= tau_g, a_ucb, a_safe).astype(jnp.int32)
    g = jnp.take_along_axis(g_all, a[:, None, None], axis=1)[:, 0]
    mu_safe = jnp.take_along_axis(mu_m, a_safe[:, None], axis=1)[:, 0]
    return a, g, mu_safe
