r"""Fused NeuralUCB decide Pallas kernel (TPU target) — one launch for the
whole per-request hot path of paper §3.3 / Algorithm 1 line 4:

    trunk forward  ->  mu(x,a)          (UtilityNet trunk + u-head)
    augment        ->  g = [h; 1]/|.|   (NeuralUCB feature)
    bonus          ->  g^T A^-1 g       (shared inverse covariance)
    gate + mask    ->  argmax_a         (availability-masked, gated UCB)

The context half of trunk1 is action-independent, so the caller
precomputes ``base-GEMM`` inputs once per request and the kernel
amortizes them over all K actions:

    z_u @ W1 + b1 = ctx @ W1[:C] + (e_a[k] @ W1[C:] + b1)
                    \__ one GEMM __/   \__ act1[k], (K, H), tiny __/

Per row-block the kernel runs ONE (Bb, C)x(C, H) context GEMM, then a
static K-unrolled loop of two small GEMMs + the A^-1 quadratic form per
action, tracking the running masked argmax — mu, h, g, scores for all
(request, action) pairs never round-trip to HBM. A^-1 and all weights
stay VMEM-resident across the grid; requests stream in blocks.

Outputs per row: chosen action, its augmented feature g (the Woodbury
update input), and the safe-greedy mean mu[argmax mu] (the gate-label
reference) — exactly what ``sim.policies._decide_ucb`` needs.

VMEM per step at block_b=256, C=384, H=256, F_pad=256: ~1.8 MB f32.

``compute_dtype`` selects the GEMM input precision (f32 or bf16); all
accumulation, the augment normalization, and the quadratic form stay
f32 (``preferred_element_type=jnp.float32``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

_NEG = float("-inf")


def _decide_kernel(ctx_ref, w1ctx_ref, act1_ref, w2_ref, b2_ref, wu_ref,
                   ainv_ref, gate_ref, avail_ref, scal_ref,
                   a_ref, g_ref, mu_safe_ref, *,
                   num_actions: int, d_last: int, compute_dtype):
    f32 = jnp.float32
    cd = compute_dtype
    beta = scal_ref[0]
    tau_g = scal_ref[1]
    bu = scal_ref[2]

    # one context GEMM, shared by all K actions
    base = jax.lax.dot(ctx_ref[...].astype(cd), w1ctx_ref[...].astype(cd),
                       preferred_element_type=f32)           # (Bb, H)
    w2 = w2_ref[...].astype(cd)                               # (H, D)
    b2 = b2_ref[...].astype(f32)                              # (1, D)
    wu = wu_ref[...].astype(f32)                              # (1, D)
    ainv = ainv_ref[...].astype(f32)                          # (Fp, Fp)
    a11 = ainv[:d_last, :d_last]
    a12 = ainv[:d_last, d_last]                               # (D,)
    a21 = ainv[d_last, :d_last]                               # (D,)
    a22 = ainv[d_last, d_last]
    use_ucb = gate_ref[...] >= tau_g                          # (Bb,)

    nb = base.shape[0]
    best_sel = jnp.full((nb,), _NEG, f32)
    best_mu = jnp.full((nb,), _NEG, f32)
    best_a = jnp.zeros((nb,), jnp.int32)
    h_best = jnp.zeros((nb, d_last), f32)
    inv_s2 = f32(1.0) / jnp.sqrt(f32(2.0))

    for k in range(num_actions):  # static unroll (K ~ 11)
        h1 = jax.nn.gelu(base + act1_ref[k, :].astype(f32)[None, :])
        h = jax.nn.gelu(
            jax.lax.dot(h1.astype(cd), w2,
                        preferred_element_type=f32) + b2)     # (Bb, D)
        mu_k = jnp.sum(h * wu, axis=1) + bu                   # (Bb,)
        # augment (core.neuralucb.augment): L2-normalize h, append 1,
        # scale by 1/sqrt(2); the quadratic form expands blockwise so g
        # is never materialized per action:
        #   2 quad = hn^T A11 hn + hn . (a12 + a21) + a22
        hn = h / jnp.maximum(
            jnp.sqrt(jnp.sum(h * h, axis=1)), 1e-6)[:, None]
        v = jax.lax.dot(hn, a11, preferred_element_type=f32)
        quad = 0.5 * (jnp.sum(v * hn, axis=1)
                      + jnp.sum(hn * (a12 + a21)[None, :], axis=1)
                      + a22)
        score = mu_k + beta * jnp.sqrt(jnp.maximum(quad, 0.0))
        ok = avail_ref[k] > 0.0
        score_m = jnp.where(ok, score, _NEG)
        mu_m = jnp.where(ok, mu_k, _NEG)
        sel = jnp.where(use_ucb, score_m, mu_m)
        upd = sel > best_sel                 # strict: first max wins,
        best_sel = jnp.where(upd, sel, best_sel)  # matching jnp.argmax
        best_a = jnp.where(upd, k, best_a)
        h_best = jnp.where(upd[:, None], hn, h_best)
        best_mu = jnp.maximum(best_mu, mu_m)

    a_ref[...] = best_a
    mu_safe_ref[...] = best_mu
    g_ref[:, 0:d_last] = h_best * inv_s2
    tail = g_ref.shape[1] - d_last
    cix = jax.lax.broadcasted_iota(jnp.int32, (nb, tail), 1)
    g_ref[:, d_last:] = jnp.where(cix == 0, inv_s2, 0.0)


@functools.partial(jax.jit, static_argnames=("num_actions", "d_last",
                                             "block_b", "interpret",
                                             "compute_dtype"))
def nucb_decide_padded(ctx, w1ctx, act1, w2, b2, wu, ainv, gate_p, avail,
                       scal, *, num_actions: int, d_last: int,
                       block_b: int = 256, interpret: bool = False,
                       compute_dtype=jnp.float32):
    """Padded entry: ctx (B, Cp) with B % block_b == 0, Cp % 128 == 0;
    w1ctx (Cp, H); act1 (Kp, H); w2 (H, D); b2, wu (1, D); ainv (Fp, Fp)
    with Fp % 128 == 0 and d_last == D % 128 == 0; gate_p (B,);
    avail (Kp,) f32 SMEM; scal (3,) f32 SMEM = [beta, tau_g, bu].
    Returns (a (B,) i32, g (B, Fp) f32, mu_safe (B,) f32)."""
    B, Cp = ctx.shape
    H = w1ctx.shape[1]
    D = w2.shape[1]
    Fp = ainv.shape[0]
    Kp = act1.shape[0]
    nr = B // block_b
    kern = functools.partial(_decide_kernel, num_actions=num_actions,
                             d_last=d_last, compute_dtype=compute_dtype)
    return pl.pallas_call(
        kern,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((block_b, Cp), lambda i: (i, 0)),
            pl.BlockSpec((Cp, H), lambda i: (0, 0)),
            pl.BlockSpec((Kp, H), lambda i: (0, 0)),
            pl.BlockSpec((H, D), lambda i: (0, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
            pl.BlockSpec((Fp, Fp), lambda i: (0, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, Fp), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B, Fp), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(ctx, w1ctx, act1, w2, b2, wu, ainv, gate_p, avail, scal)
