"""Public fused NeuralUCB decide op.

``nucb_decide`` takes raw UtilityNet params + a request batch, runs the
action-independent context encode (text/feat MLPs + domain gather + gate
head — O(B), K-times smaller than the per-action trunk) in plain jnp,
splits trunk1 into its context GEMM and per-action bias rows, and hands
the per-action hot loop to the Pallas kernel. Backend selection follows
:mod:`repro.kernels.backend`: compiled kernel on TPU, jnp reference
elsewhere, interpreter only on request.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import utilitynet as UN
from repro.kernels.backend import INTERPRET, REF, resolve_backend
from repro.kernels.nucb_decide.kernel import nucb_decide_padded
from repro.kernels.nucb_decide.ref import nucb_decide_ref


def prepare_decide_inputs(params, x_emb, x_feat, domain):
    """Action-independent preprocessing shared by kernel and ref: the
    encoded context, the gate probability, trunk1 split into its context
    weight block and per-action bias rows (b1 folded in), and the flat
    trunk2/u-head weights."""
    h_emb, h_feat = UN._context_encode(params, x_emb, x_feat, domain)
    ctx = jnp.concatenate([h_emb, h_feat], axis=-1)          # (B, C)
    gp = jax.nn.gelu(ctx @ params["gate1"]["w"] + params["gate1"]["b"])
    gate_p = jax.nn.sigmoid(
        gp @ params["gate2"]["w"] + params["gate2"]["b"])[..., 0]
    C = ctx.shape[-1]
    w1 = params["trunk1"]["w"]                               # (C + A, H)
    act1 = params["emb_a"] @ w1[C:] + params["trunk1"]["b"]  # (K, H)
    return (ctx, gate_p, w1[:C], act1, params["trunk2"]["w"],
            params["trunk2"]["b"], params["u_head"]["w"][:, 0],
            params["u_head"]["b"][0])


def nucb_decide(params, cfg: UN.UtilityNetConfig, x_emb, x_feat, domain,
                ainv, beta, tau_g, avail=None, *, block_b: int = 256,
                interpret: Optional[bool] = None,
                compute_dtype=jnp.float32):
    """Fused gated-UCB decision over all actions.

    Returns (a (B,) i32, g (B, F) f32 — the chosen arm's augmented
    feature, mu_safe (B,) f32 — the safe-greedy mean reference,
    gate_p (B,) f32)."""
    ctx, gate_p, w1ctx, act1, w2, b2, wu, bu = prepare_decide_inputs(
        params, x_emb, x_feat, domain)
    if avail is not None:
        avail = avail.astype(jnp.float32)
    backend = resolve_backend(interpret)
    if backend == REF:
        a, g, mu_safe = nucb_decide_ref(
            ctx, w1ctx, act1, w2, b2, wu, bu, ainv,
            gate_p, avail, beta, tau_g)
        return a, g, mu_safe, gate_p
    a, g, mu_safe = _nucb_decide_pallas(
        ctx, w1ctx, act1, w2, b2, wu,
        jnp.asarray(bu, jnp.float32).reshape(()),
        ainv, gate_p, avail,
        jnp.asarray(beta, jnp.float32).reshape(()),
        jnp.asarray(tau_g, jnp.float32).reshape(()),
        num_actions=cfg.num_actions, block_b=block_b,
        interpret=backend == INTERPRET, compute_dtype=compute_dtype)
    return a, g[:, :cfg.ucb_feature_dim], mu_safe, gate_p


@functools.partial(jax.jit, static_argnames=("num_actions", "block_b",
                                             "interpret",
                                             "compute_dtype"))
def _nucb_decide_pallas(ctx, w1ctx, act1, w2, b2, wu, bu, ainv, gate_p,
                        avail, beta, tau_g, *, num_actions: int,
                        block_b: int, interpret: bool, compute_dtype):
    B, C = ctx.shape
    H = w1ctx.shape[1]
    D = w2.shape[1]
    F = ainv.shape[0]
    K = num_actions
    if H % 128 or D % 128:
        raise ValueError(f"nucb_decide kernel needs d_hidden and d_last "
                         f"to be multiples of 128, got {H} and {D}")

    pad_c = (-C) % 128
    pad_f = (-F) % 128
    pad_k = (-K) % 8
    bb = min(block_b, max(8, B))
    pad_b = (-B) % bb
    if pad_c:
        ctx = jnp.pad(ctx, ((0, 0), (0, pad_c)))
        w1ctx = jnp.pad(w1ctx, ((0, pad_c), (0, 0)))
    if pad_f:
        # zero padding keeps the (unused) padded block of A^-1 inert:
        # the kernel only reads the leading (D+1, D+1) entries
        ainv = jnp.pad(ainv, ((0, pad_f), (0, pad_f)))
    if pad_k:
        act1 = jnp.pad(act1, ((0, pad_k), (0, 0)))
    if pad_b:
        ctx = jnp.pad(ctx, ((0, pad_b), (0, 0)))
        gate_p = jnp.pad(gate_p, (0, pad_b))

    # padded action rows are never read (the kernel's loop is static
    # over the true K); zeros keep them inert regardless
    avail_full = jnp.zeros((K + pad_k,), jnp.float32)
    avail_full = avail_full.at[:K].set(
        1.0 if avail is None else avail)
    scal = jnp.stack([beta.astype(jnp.float32),
                      tau_g.astype(jnp.float32),
                      bu.astype(jnp.float32)])
    a, g, mu_safe = nucb_decide_padded(
        ctx, w1ctx, act1, w2.astype(jnp.float32),
        b2.reshape(1, -1).astype(jnp.float32),
        wu.reshape(1, -1).astype(jnp.float32),
        ainv.astype(jnp.float32), gate_p.astype(jnp.float32),
        avail_full, scal, num_actions=K, d_last=D, block_b=bb,
        interpret=interpret, compute_dtype=compute_dtype)
    return a[:B], g[:B], mu_safe[:B]
