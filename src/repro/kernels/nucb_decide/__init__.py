from repro.kernels.nucb_decide.ops import nucb_decide, prepare_decide_inputs
from repro.kernels.nucb_decide.ref import nucb_decide_ref

__all__ = ["nucb_decide", "nucb_decide_ref", "prepare_decide_inputs"]
