from repro.kernels.mamba2_ssd.ops import ssd_chunk_scan

__all__ = ["ssd_chunk_scan"]
