"""Pure-jnp oracle for the SSD scan: the naive O(L) sequential recurrence.

    h_t = exp(A dt_t) h_{t-1} + dt_t * x_t B_t^T
    y_t = C_t h_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, Bm, Cm, init_state=None):
    """x: (B, L, H, P); dt: (B, L, H); A: (H,); Bm, Cm: (B, L, N).
    Returns (y (B, L, H, P), final_state (B, H, P, N))."""
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt.astype(jnp.float32) * A)  # (B,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtt.astype(jnp.float32),
                         xt.astype(jnp.float32), bt.astype(jnp.float32))
        h = h * decay[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h, ct.astype(jnp.float32))
        return h, y

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, init_state, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
