"""Mamba2 SSD within-chunk Pallas kernel (TPU target).

The SSD decomposition (arXiv:2405.21060) splits the recurrence into a
quadratic *within-chunk* part (MXU-friendly: per chunk a (cs x cs) masked
"attention" matrix against decay factors) and a linear *inter-chunk* state
recurrence (done with ``lax.scan`` in ops.py — it is O(L/cs) sequential
steps and bandwidth-bound, not compute-bound).

This kernel computes, per (batch x head-block, chunk) grid step:
  y_diag      = ((C B^T) .* L) diag(dt) x          -- within-chunk output
  chunk_state = sum_s B_s (dt_s decay_to_end_s) x_s -- state contribution
  exp_acum    = exp(cumsum(A dt))                  -- for inter-chunk y_off
  decay_last  = exp(acum[-1])                      -- state carry decay

VMEM per step, cs=256, HB=8 heads, P=64, N=128 (mamba2-130m):
  x (cs,HB,P) + B,C (cs,N) + L (cs,cs,HB) + state (HB,P,N) ~= 2.5 MB f32,
  comfortably inside the ~16 MB v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                ydiag_ref, state_ref, expacum_ref, decay_ref):
    x = x_ref[0, 0, 0].astype(jnp.float32)     # (cs, HB, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)   # (cs, HB)
    A = a_ref[0].astype(jnp.float32)           # (HB,)
    Bm = b_ref[0, 0].astype(jnp.float32)       # (cs, N)
    Cm = c_ref[0, 0].astype(jnp.float32)       # (cs, N)

    adt = dt * A[None, :]                      # (cs, HB), negative
    acum = jnp.cumsum(adt, axis=0)             # (cs, HB)
    # decay(t<-s) = exp(acum_t - acum_s), lower triangular
    seg = acum[:, None, :] - acum[None, :, :]  # (t, s, HB)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1)
    Lmat = jnp.where(t_idx >= s_idx, jnp.exp(seg), 0.0)

    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (t, s)
    wdt = CB[:, :, None] * Lmat * dt[None, :, :]   # (t, s, HB)
    ydiag = jnp.einsum("tsh,shp->thp", wdt, x)     # (cs, HB, P)

    decay_to_end = jnp.exp(acum[-1, :][None, :] - acum)  # (cs, HB)
    w = dt * decay_to_end
    state = jnp.einsum("sn,sh,shp->hpn", Bm, w, x)       # (HB, P, N)

    ydiag_ref[0, 0, 0] = ydiag.astype(ydiag_ref.dtype)
    state_ref[0, 0, 0] = state
    expacum_ref[0, 0, 0] = jnp.exp(acum)
    decay_ref[0, 0, 0] = jnp.exp(acum[-1, :])


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_kernel(x, dt, A, Bm, Cm, *, interpret: bool = True):
    """x: (B, NC, cs, H, P); dt: (B, NC, cs, H); A: (H,);
    Bm, Cm: (B, NC, cs, N). Heads are processed in blocks of HB<=8.

    Returns: ydiag (B,NC,cs,H,P), chunk_state (B,NC,H,P,N),
             exp_acum (B,NC,cs,H), decay_last (B,NC,H).
    """
    B, NC, cs, H, P = x.shape
    N = Bm.shape[-1]
    HB = 8 if H % 8 == 0 else (4 if H % 4 == 0 else 1)
    nh = H // HB

    xg = x.reshape(B, NC, cs, nh, HB, P).transpose(0, 3, 1, 2, 4, 5)
    dtg = dt.reshape(B, NC, cs, nh, HB).transpose(0, 3, 1, 2, 4)
    Ag = A.reshape(nh, HB)

    ydiag, state, expacum, decay = pl.pallas_call(
        _ssd_kernel,
        grid=(B * nh, NC),
        in_specs=[
            pl.BlockSpec((1, 1, 1, cs, HB, P),
                         lambda bh, c, nh=nh: (bh // nh, bh % nh, c, 0, 0, 0)),
            pl.BlockSpec((1, 1, 1, cs, HB),
                         lambda bh, c, nh=nh: (bh // nh, bh % nh, c, 0, 0)),
            pl.BlockSpec((1, HB), lambda bh, c, nh=nh: (bh % nh, 0)),
            pl.BlockSpec((1, 1, cs, N),
                         lambda bh, c, nh=nh: (bh // nh, c, 0, 0)),
            pl.BlockSpec((1, 1, cs, N),
                         lambda bh, c, nh=nh: (bh // nh, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, cs, HB, P),
                         lambda bh, c, nh=nh: (bh // nh, bh % nh, c, 0, 0, 0)),
            pl.BlockSpec((1, 1, 1, HB, P, N),
                         lambda bh, c, nh=nh: (bh // nh, bh % nh, c, 0, 0, 0)),
            pl.BlockSpec((1, 1, 1, cs, HB),
                         lambda bh, c, nh=nh: (bh // nh, bh % nh, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, HB),
                         lambda bh, c, nh=nh: (bh // nh, bh % nh, c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nh, NC, cs, HB, P), x.dtype),
            jax.ShapeDtypeStruct((B, nh, NC, HB, P, N), jnp.float32),
            jax.ShapeDtypeStruct((B, nh, NC, cs, HB), jnp.float32),
            jax.ShapeDtypeStruct((B, nh, NC, HB), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xg, dtg, Ag, Bm, Cm)

    ydiag = ydiag.transpose(0, 2, 3, 1, 4, 5).reshape(B, NC, cs, H, P)
    state = state.transpose(0, 2, 1, 3, 4, 5).reshape(B, NC, H, P, N)
    expacum = expacum.transpose(0, 2, 3, 1, 4).reshape(B, NC, cs, H)
    decay = decay.transpose(0, 2, 1, 3).reshape(B, NC, H)
    return ydiag, state, expacum, decay
