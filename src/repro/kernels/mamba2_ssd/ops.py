"""Public SSD op: chunk the sequence, run the Pallas within-chunk kernel,
carry the inter-chunk state recurrence with ``lax.scan``."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mamba2_ssd.kernel import ssd_chunk_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_scan(x, dt, A, Bm, Cm, *, chunk: int = 256,
                   init_state=None, interpret: bool = True):
    """x: (B, L, H, P); dt: (B, L, H) (post-softplus); A: (H,) negative;
    Bm, Cm: (B, L, N). Returns (y (B, L, H, P), final_state (B, H, P, N))."""
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    cs = min(chunk, L)
    nc = -(-L // cs)
    pad = nc * cs - L

    def padl(a):
        if pad == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[1] = (0, pad)
        return jnp.pad(a, widths)

    xc = padl(x).reshape(B, nc, cs, H, P)
    dtc = padl(dt).reshape(B, nc, cs, H)
    Bc = padl(Bm).reshape(B, nc, cs, N)
    Cc = padl(Cm).reshape(B, nc, cs, N)

    ydiag, cstate, expacum, decay = ssd_chunk_kernel(
        xc, dtc, A, Bc, Cc, interpret=interpret)

    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), jnp.float32)

    # inter-chunk: carry the state, emit the incoming state per chunk
    def step(h, inp):
        cst, dcy = inp  # (B,H,P,N), (B,H)
        h_new = h * dcy[:, :, None, None] + cst
        return h_new, h

    (final, h_in) = jax.lax.scan(
        step, init_state,
        (cstate.transpose(1, 0, 2, 3, 4), decay.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)  # (B, NC, H, P, N) state BEFORE chunk

    # y_off_t = exp_acum_t * C_t . h_in
    y_off = jnp.einsum("bcsn,bchpn,bcsh->bcshp",
                       Cc.astype(jnp.float32), h_in,
                       expacum.astype(jnp.float32))
    y = ydiag.astype(jnp.float32) + y_off
    y = y.reshape(B, nc * cs, H, P)[:, :L]
    return y.astype(x.dtype), final
