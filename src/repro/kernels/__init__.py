"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package has:
  kernel.py — ``pl.pallas_call`` with explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper (padding, GQA plumbing, combines)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels target TPU (MXU-aligned 128-multiples, VMEM-resident blocks) and
are VALIDATED in interpret mode on this CPU-only host. The pure-XLA model
zoo paths in ``repro.models`` are numerically equivalent; on real TPU
deployments the ops here replace them behind the ``use_pallas`` flag.

Inventory:
  flash_attention — prefill/train attention (causal + sliding window + GQA)
  decode_attention — flash-decode: 1 query token over a long KV cache,
      split-K partial-softmax with a jnp combine
  mamba2_ssd — the quadratic within-chunk part of the SSD scan
  ucb_score — the paper's serving-time hot loop: batched
      mu + beta * sqrt(g^T A^-1 g) over (requests x actions)
"""
