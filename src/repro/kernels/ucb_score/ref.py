"""Pure-jnp oracle for NeuralUCB scoring."""
from __future__ import annotations

import jax.numpy as jnp


def ucb_score_ref(g, ainv, mu, beta):
    """g: (..., F); ainv: (F, F); mu: (...,). Returns (...,) f32 scores."""
    g32 = g.astype(jnp.float32)
    quad = jnp.einsum("...i,ij,...j->...", g32, ainv.astype(jnp.float32), g32)
    return mu.astype(jnp.float32) + beta * jnp.sqrt(jnp.maximum(quad, 0.0))
