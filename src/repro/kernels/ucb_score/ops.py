"""Public NeuralUCB scoring op: pads rows/features, runs the kernel.

Backend selection is centralized in :mod:`repro.kernels.backend`:
``interpret=None`` (the default) runs the compiled kernel on TPU and
the jnp reference everywhere else, so call sites never carry their own
``jax.default_backend()`` gate and never fall into the interpreter by
accident. Pass ``interpret=True`` to force the interpreter (tests).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.backend import INTERPRET, REF, resolve_backend
from repro.kernels.ucb_score.kernel import ucb_score_padded
from repro.kernels.ucb_score.ref import ucb_score_ref


def ucb_score(g, ainv, mu, beta, *, block_r: int = 512,
              interpret: Optional[bool] = None):
    """g: (..., F); ainv: (F, F); mu: (...,); beta scalar.
    Returns UCB scores with g's leading shape, f32.

    Feature padding is safe: padded g columns are zero, and padding A^-1
    with zeros (not identity) keeps the quadratic form unchanged.
    """
    backend = resolve_backend(interpret)
    if backend == REF:
        return ucb_score_ref(g, ainv, mu, beta)
    return _ucb_score_pallas(g, ainv, mu, beta, block_r=block_r,
                             interpret=backend == INTERPRET)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def _ucb_score_pallas(g, ainv, mu, beta, *, block_r: int,
                      interpret: bool):
    lead = g.shape[:-1]
    F = g.shape[-1]
    R = 1
    for d in lead:
        R *= d
    g2 = g.reshape(R, F)
    mu2 = mu.reshape(R)

    pad_f = (-F) % 128
    br = min(block_r, max(8, R))
    pad_r = (-R) % br
    if pad_f:
        g2 = jnp.pad(g2, ((0, 0), (0, pad_f)))
        ainv = jnp.pad(ainv, ((0, pad_f), (0, pad_f)))
    if pad_r:
        g2 = jnp.pad(g2, ((0, pad_r), (0, 0)))
        mu2 = jnp.pad(mu2, (0, pad_r))

    beta_arr = jnp.asarray(beta, jnp.float32).reshape(1)
    out = ucb_score_padded(g2, ainv, mu2, beta_arr, block_r=br,
                           interpret=interpret)
    return out[:R].reshape(lead)
