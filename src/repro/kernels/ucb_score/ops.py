"""Public NeuralUCB scoring op: pads rows/features, runs the kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ucb_score.kernel import ucb_score_padded


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def ucb_score(g, ainv, mu, beta, *, block_r: int = 512,
              interpret: bool = True):
    """g: (..., F); ainv: (F, F); mu: (...,); beta scalar.
    Returns UCB scores with g's leading shape, f32.

    Feature padding is safe: padded g columns are zero, and padding A^-1
    with zeros (not identity) keeps the quadratic form unchanged.
    """
    lead = g.shape[:-1]
    F = g.shape[-1]
    R = 1
    for d in lead:
        R *= d
    g2 = g.reshape(R, F)
    mu2 = mu.reshape(R)

    pad_f = (-F) % 128
    br = min(block_r, max(8, R))
    pad_r = (-R) % br
    if pad_f:
        g2 = jnp.pad(g2, ((0, 0), (0, pad_f)))
        ainv = jnp.pad(ainv, ((0, pad_f), (0, pad_f)))
    if pad_r:
        g2 = jnp.pad(g2, ((0, pad_r), (0, 0)))
        mu2 = jnp.pad(mu2, (0, pad_r))

    beta_arr = jnp.asarray(beta, jnp.float32).reshape(1)
    out = ucb_score_padded(g2, ainv, mu2, beta_arr, block_r=br,
                           interpret=interpret)
    return out[:R].reshape(lead)
