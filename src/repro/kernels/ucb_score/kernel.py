"""NeuralUCB scoring Pallas kernel (TPU target) — the paper's serving-time
hot loop: for every (request, action) pair score

    s = mu + beta * sqrt(g^T A^-1 g)

over the shared last-layer feature g(x,a) = [h(x,a); 1] and the shared
inverse covariance A^-1 (paper §3.3). At router scale this is R=batch*K
quadratic forms of width F (feature dim + bias), i.e. a (R x F) @ (F x F)
GEMM on the MXU followed by a row-wise VPU reduce — exactly the layout
this kernel uses. A^-1 stays VMEM-resident across the whole grid; G rows
stream through in blocks of ``block_r``.

VMEM per step: Ainv (F x F) + g (block_r x F) + h (block_r x F); with
F=256, block_r=512: ~1.3 MB f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _ucb_kernel(g_ref, ainv_ref, mu_ref, beta_ref, out_ref):
    g = g_ref[...].astype(jnp.float32)        # (Br, F)
    ainv = ainv_ref[...].astype(jnp.float32)  # (F, F)
    mu = mu_ref[...].astype(jnp.float32)      # (Br,)
    beta = beta_ref[0]

    h = jax.lax.dot(g, ainv, preferred_element_type=jnp.float32)  # (Br, F)
    quad = jnp.sum(h * g, axis=1)                                  # (Br,)
    bonus = jnp.sqrt(jnp.maximum(quad, 0.0))
    out_ref[...] = mu + beta * bonus


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def ucb_score_padded(g, ainv, mu, beta, *, block_r: int = 512,
                     interpret: bool = True):
    """g: (R, F) with R % block_r == 0, F % 128 == 0; ainv: (F, F);
    mu: (R,); beta: (1,) f32. Returns scores (R,) f32."""
    R, F = g.shape
    nr = R // block_r
    return pl.pallas_call(
        _ucb_kernel,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((block_r, F), lambda i: (i, 0)),
            pl.BlockSpec((F, F), lambda i: (0, 0)),
            pl.BlockSpec((block_r,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((block_r,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((R,), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(g, ainv, mu, beta)
