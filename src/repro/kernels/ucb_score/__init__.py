from repro.kernels.ucb_score.ops import ucb_score

__all__ = ["ucb_score"]
