"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
(per expert), vocab=49155, MoE 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    arch_type="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    num_experts=32,
    experts_per_token=8,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
