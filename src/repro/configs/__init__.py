"""Architecture registry: the 10 assigned pool members + the paper's own
router config. Each module defines CONFIG (exact assigned spec)."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.common.config import ModelConfig

ARCH_IDS: List[str] = [
    "granite_moe_1b_a400m",
    "gemma3_4b",
    "mamba2_130m",
    "whisper_medium",
    "qwen3_moe_30b_a3b",
    "jamba_1_5_large_398b",
    "mistral_large_123b",
    "llama3_2_3b",
    "mistral_nemo_12b",
    "llama3_2_vision_11b",
]

_ALIASES = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "gemma3-4b": "gemma3_4b",
    "mamba2-130m": "mamba2_130m",
    "whisper-medium": "whisper_medium",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "mistral-large-123b": "mistral_large_123b",
    "llama3.2-3b": "llama3_2_3b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
}


def get_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
