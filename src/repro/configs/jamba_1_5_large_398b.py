"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, vocab=65536, MoE 16 experts top-2; Mamba+attention 1:7
interleave (one attention layer per 8), MoE every 2nd layer.
[arXiv:2403.19887]

Runs ``long_500k``: mamba layers carry O(1) state; the 9 attention layers
are bounded by ``global_attn_cap`` during long decode (DESIGN.md §4).
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,
    attn_every=8,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    global_attn_cap=32768,
    citation="arXiv:2403.19887",
)
