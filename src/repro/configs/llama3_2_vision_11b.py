"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; gated cross-attention image layers every 5th layer. The
ViT/projector vision frontend is a STUB: the input pipeline supplies patch
embeddings (B, 1601, d_model). [hf:meta-llama/Llama-3.2-11B-Vision]
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    arch_type="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    cross_attn_every=5,
    num_image_tokens=1601,
    rope_theta=500_000.0,
    citation="hf:meta-llama/Llama-3.2-11B-Vision",
)
