"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144; 5:1 local:global sliding-window attention, 128k context.
[hf:google/gemma-3-1b-pt]

The 5:1 local:global interleave makes this the one *dense* arch that runs
the ``long_500k`` decode shape: local layers use a 1024-token sliding
window; global layers are capped at ``global_attn_cap`` during long decode
(deviation from true full-context global attention, recorded in DESIGN.md §4).
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    arch_type="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    sliding_window=1024,
    local_global_ratio=5,
    global_attn_cap=32768,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    citation="hf:google/gemma-3-1b-pt",
)
