"""mamba2-130m [ssm] — 24L d_model=768, attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060]
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    num_layers=24,
    d_model=768,
    num_heads=1,   # attention-free; unused
    num_kv_heads=1,
    d_ff=0,        # mamba2 block has no separate FFN
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    tie_embeddings=True,
    citation="arXiv:2405.21060",
)
