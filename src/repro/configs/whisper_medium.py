"""whisper-medium [audio] — enc-dec, 24L encoder + 24L decoder, d_model=1024
16H (kv=16), d_ff=4096, vocab=51865; conv/mel frontend is a STUB: the input
pipeline supplies precomputed frame embeddings (B, 1500, d_model).
[arXiv:2212.04356]
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    num_layers=24,          # decoder layers
    num_encoder_layers=24,  # encoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    is_encoder_decoder=True,
    num_audio_frames=1500,
    citation="arXiv:2212.04356",
)
