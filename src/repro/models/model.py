"""Model zoo assembly: init / train-forward / prefill / decode for all six
architecture families, with ``lax.scan`` over (super-)blocks so HLO size is
independent of depth (compile-time critical at 72-88 layers on this host).

Families
--------
dense   : uniform [attn + SwiGLU] blocks; gemma-style local:global sliding
          window handled with per-layer flags scanned alongside the params.
moe     : uniform [attn + MoE] blocks (granite, qwen3).
ssm     : uniform Mamba2 blocks (mamba2-130m).
hybrid  : jamba super-blocks of 8 layers: 7 mamba + 1 attention mixer,
          alternating dense/MoE FFNs (MoE every 2nd layer).
audio   : whisper encoder-decoder backbone; conv/mel frontend is a stub —
          the caller supplies frame embeddings (B, T_frames, d_model).
vlm     : llama-3.2-vision style: decoder super-blocks of 5 layers where the
          5th carries an extra gated cross-attention into patch embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.distributed import shard
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE


def pad_vocab(v: int) -> int:
    return -(-v // 256) * 256


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stack_init(fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def _init_dense_block(cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)

    def f(key):
        ka, kf = jax.random.split(key)
        return {
            "attn": L.init_attention(ka, cfg),
            "ffn": L.init_ffn(kf, cfg),
            "ln1": L.rmsnorm_init(cfg.d_model, dt),
            "ln2": L.rmsnorm_init(cfg.d_model, dt),
        }

    return f


def _init_moe_block(cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)

    def f(key):
        ka, kf = jax.random.split(key)
        return {
            "attn": L.init_attention(ka, cfg),
            "moe": MOE.init_moe(kf, cfg),
            "ln1": L.rmsnorm_init(cfg.d_model, dt),
            "ln2": L.rmsnorm_init(cfg.d_model, dt),
        }

    return f


def _init_ssm_block(cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)

    def f(key):
        return {
            "mamba": M.init_mamba(key, cfg),
            "ln1": L.rmsnorm_init(cfg.d_model, dt),
        }

    return f


def _init_hybrid_superblock(cfg: ModelConfig):
    """Jamba super-block: `attn_every` layers, last mixer is attention, the
    rest mamba; FFN alternates dense / MoE (MoE at odd positions)."""
    dt = jnp.dtype(cfg.dtype)
    n = cfg.attn_every
    n_mamba = n - 1
    n_moe = n // cfg.moe_every
    n_dense = n - n_moe

    def f(key):
        km, ka, kd, ke = jax.random.split(key, 4)
        return {
            "mamba": _stack_init(lambda k: M.init_mamba(k, cfg), km, n_mamba),
            "attn": L.init_attention(ka, cfg),
            "ffn_dense": _stack_init(lambda k: L.init_ffn(k, cfg), kd, n_dense),
            "moe": _stack_init(lambda k: MOE.init_moe(k, cfg), ke, n_moe),
            "ln_mix": jnp.ones((n, cfg.d_model), dt),
            "ln_ffn": jnp.ones((n, cfg.d_model), dt),
        }

    return f


def _init_whisper(cfg: ModelConfig, key) -> dict:
    dt = jnp.dtype(cfg.dtype)
    ke, kd, kc = jax.random.split(key, 3)

    def enc_block(k):
        ka, kf = jax.random.split(k)
        return {
            "attn": L.init_attention(ka, cfg),
            "ffn": L.init_ffn(kf, cfg),
            "ln1": L.rmsnorm_init(cfg.d_model, dt),
            "ln2": L.rmsnorm_init(cfg.d_model, dt),
        }

    def dec_block(k):
        ka, kx, kf = jax.random.split(k, 3)
        return {
            "attn": L.init_attention(ka, cfg),
            "cross": L.init_attention(kx, cfg),
            "ffn": L.init_ffn(kf, cfg),
            "ln1": L.rmsnorm_init(cfg.d_model, dt),
            "ln2": L.rmsnorm_init(cfg.d_model, dt),
            "ln3": L.rmsnorm_init(cfg.d_model, dt),
        }

    return {
        "enc_blocks": _stack_init(enc_block, ke, cfg.num_encoder_layers),
        "enc_norm": L.rmsnorm_init(cfg.d_model, dt),
        "dec_blocks": _stack_init(dec_block, kd, cfg.num_layers),
    }


def _init_vlm_superblock(cfg: ModelConfig):
    """Super-block of `cross_attn_every` self-attn layers; the last one is
    followed by a gated cross-attention layer into the image tokens."""
    dt = jnp.dtype(cfg.dtype)
    n = cfg.cross_attn_every

    def f(key):
        ks, kx, kf = jax.random.split(key, 3)

        def self_layer(k):
            ka, kff = jax.random.split(k)
            return {
                "attn": L.init_attention(ka, cfg),
                "ffn": L.init_ffn(kff, cfg),
                "ln1": L.rmsnorm_init(cfg.d_model, dt),
                "ln2": L.rmsnorm_init(cfg.d_model, dt),
            }

        return {
            "self": _stack_init(self_layer, ks, n),
            "cross": L.init_attention(kx, cfg),
            "cross_ffn": L.init_ffn(kf, cfg),
            "cross_ln1": L.rmsnorm_init(cfg.d_model, dt),
            "cross_ln2": L.rmsnorm_init(cfg.d_model, dt),
            "gate_attn": jnp.zeros((1,), jnp.float32),
            "gate_ffn": jnp.zeros((1,), jnp.float32),
        }

    return f


def init_params(key: jax.Array, cfg: ModelConfig) -> Dict:
    ke, kb, ku = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    vp = pad_vocab(cfg.vocab_size)
    params: Dict = {
        "embed": L.embed_init(ke, vp, cfg.d_model, dt),
        "final_norm": L.rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(ku, cfg.d_model, vp, dt)

    at = cfg.arch_type
    if at == "dense":
        params["blocks"] = _stack_init(_init_dense_block(cfg), kb, cfg.num_layers)
    elif at == "moe":
        params["blocks"] = _stack_init(_init_moe_block(cfg), kb, cfg.num_layers)
    elif at == "ssm":
        params["blocks"] = _stack_init(_init_ssm_block(cfg), kb, cfg.num_layers)
    elif at == "hybrid":
        nsb = cfg.num_layers // cfg.attn_every
        params["blocks"] = _stack_init(_init_hybrid_superblock(cfg), kb, nsb)
    elif at == "audio":
        params.update(_init_whisper(cfg, kb))
    elif at == "vlm":
        nsb = cfg.num_layers // cfg.cross_attn_every
        params["blocks"] = _stack_init(_init_vlm_superblock(cfg), kb, nsb)
    else:
        raise ValueError(f"unknown arch_type {at}")
    return params


# ---------------------------------------------------------------------------
# per-layer attention windows (gemma local:global pattern)
# ---------------------------------------------------------------------------


def layer_windows(cfg: ModelConfig, decode: bool = False) -> jax.Array:
    """(num_layers,) int32: sliding window per layer; 0 = full attention.

    During long-context decode, "global" layers are capped at
    ``global_attn_cap`` (see DESIGN.md §4)."""
    n = cfg.num_layers
    if cfg.local_global_ratio > 0:
        period = cfg.local_global_ratio + 1
        idx = jnp.arange(n)
        is_global = (idx % period) == (period - 1)
        gwin = cfg.global_attn_cap if decode else 0
        return jnp.where(is_global, gwin, cfg.sliding_window).astype(jnp.int32)
    w = cfg.sliding_window
    return jnp.full((n,), w, jnp.int32)


# ---------------------------------------------------------------------------
# block bodies (shared between train forward and decode)
# ---------------------------------------------------------------------------


def _dense_body(p, cfg, x, positions, window, cache_l=None, cache_pos=None,
                k_offset=0):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    a, new_c = L.attention(p["attn"], cfg, h, positions=positions,
                           causal=True, window=window, cache=cache_l,
                           cache_pos=cache_pos, k_offset=k_offset)
    x = x + a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + L.ffn(p["ffn"], h)
    return x, new_c


def _moe_body(p, cfg, x, positions, window, cache_l=None, cache_pos=None,
              k_offset=0):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    a, new_c = L.attention(p["attn"], cfg, h, positions=positions, causal=True,
                           window=window, cache=cache_l, cache_pos=cache_pos,
                           k_offset=k_offset)
    x = x + a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    mo, aux = MOE.moe_ffn(p["moe"], cfg, h)
    return x + mo, new_c, aux


def _ssm_body(p, cfg, x, cache_l=None):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    m, new_c = M.mamba_block(p["mamba"], cfg, h, cache=cache_l)
    return x + m, new_c


def _hybrid_body(p, cfg, x, positions, cache_l=None, cache_pos=None):
    """One jamba super-block, unrolled over its `attn_every` positions."""
    n = cfg.attn_every
    aux_total = jnp.float32(0.0)
    new_cache = {"mamba": [], "attn": None} if cache_l is not None else None
    i_mamba = i_dense = i_moe = 0
    for pos in range(n):
        ln_mix = p["ln_mix"][pos]
        ln_ffn = p["ln_ffn"][pos]
        is_attn = pos == (n - 1)
        h = L.rmsnorm(ln_mix, x, cfg.norm_eps)
        if is_attn:
            c = cache_l["attn"] if cache_l is not None else None
            a, nc = L.attention(p["attn"], cfg, h, positions=positions,
                                causal=True, window=cfg.sliding_window,
                                cache=c, cache_pos=cache_pos)
            if new_cache is not None:
                new_cache["attn"] = nc
            x = x + a
        else:
            mp = jax.tree.map(lambda t: t[i_mamba], p["mamba"])
            c = (jax.tree.map(lambda t: t[i_mamba], cache_l["mamba"])
                 if cache_l is not None else None)
            m, nc = M.mamba_block(mp, cfg, h, cache=c)
            if new_cache is not None:
                new_cache["mamba"].append(nc)
            x = x + m
            i_mamba += 1
        h = L.rmsnorm(ln_ffn, x, cfg.norm_eps)
        if (pos % cfg.moe_every) == (cfg.moe_every - 1):
            ep = jax.tree.map(lambda t: t[i_moe], p["moe"])
            mo, aux = MOE.moe_ffn(ep, cfg, h)
            x = x + mo
            aux_total = aux_total + aux
            i_moe += 1
        else:
            fp = jax.tree.map(lambda t: t[i_dense], p["ffn_dense"])
            x = x + L.ffn(fp, h)
            i_dense += 1
    if new_cache is not None:
        new_cache["mamba"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *new_cache["mamba"])
    return x, new_cache, aux_total


def _vlm_superblock_body(p, cfg, x, positions, image_embed, window,
                         cache_l=None, cache_pos=None, cross_cache=None):
    n = cfg.cross_attn_every
    new_self = [] if cache_l is not None else None
    for i in range(n):
        sp = jax.tree.map(lambda t: t[i], p["self"])
        c = (jax.tree.map(lambda t: t[i], cache_l) if cache_l is not None else None)
        x, nc = _dense_body(sp, cfg, x, positions, window, c, cache_pos)
        if new_self is not None:
            new_self.append(nc)
    # gated cross-attention into image tokens
    h = L.rmsnorm(p["cross_ln1"], x, cfg.norm_eps)
    ca, _ = L.attention(p["cross"], cfg, h, positions=positions, causal=False,
                        kv_source=image_embed, cache=cross_cache,
                        use_rope=False)
    x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * ca
    h = L.rmsnorm(p["cross_ln2"], x, cfg.norm_eps)
    x = x + jnp.tanh(p["gate_ffn"]).astype(x.dtype) * L.ffn(p["cross_ffn"], h)
    new_cache = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_self)
                 if new_self is not None else None)
    return x, new_cache


# ---------------------------------------------------------------------------
# train / prefill forward
# ---------------------------------------------------------------------------


def forward_hidden(params: Dict, cfg: ModelConfig, batch: Dict
                   ) -> Tuple[jax.Array, jax.Array]:
    """Backbone forward up to (and including) the final norm.

    Returns (hidden (B, S, D), moe_aux_loss scalar).
    batch: {"tokens": (B,S)} plus, per family:
      audio: {"audio_embed": (B, T_frames, D)}
      vlm:   {"image_embed": (B, N_patches, D)}
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params["embed"][tokens]
    x = shard(x, "batch", "seq", None)
    positions = jnp.arange(S)
    aux = jnp.float32(0.0)
    at = cfg.arch_type
    ckpt = (jax.checkpoint if cfg.remat == "layer" else (lambda f: f))

    if (at == "dense" and cfg.local_global_ratio > 0
            and S >= 2 * cfg.sliding_window):
        # gemma local:global interleave with STATIC structure: scan over
        # super-blocks of (ratio local + 1 global) layers so the banded
        # O(S*w) kernel is hard-wired for local layers (no per-layer cond;
        # the roofline accounts each branch exactly). Layout holds because
        # globals sit at index (period-1) mod period.
        period = cfg.local_global_ratio + 1
        n_full = (cfg.num_layers // period) * period
        w = cfg.sliding_window

        def local_layer(p, x):
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            a, _ = L.attention(p["attn"], cfg, h, positions=positions,
                               causal=True, local_window=w)
            x = x + a
            h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
            return x + L.ffn(p["ffn"], h)

        @ckpt
        def group_body(x, g):
            for j in range(period - 1):
                x = local_layer(jax.tree.map(lambda t, j=j: t[j], g), x)
            gp = jax.tree.map(lambda t: t[period - 1], g)
            x, _ = _dense_body(gp, cfg, x, positions, 0)
            return x, None

        groups = jax.tree.map(
            lambda t: t[:n_full].reshape((n_full // period, period)
                                         + t.shape[1:]), params["blocks"])
        x, _ = jax.lax.scan(group_body, x, groups)
        if n_full < cfg.num_layers:
            @ckpt
            def tail_body(x, p):
                return local_layer(p, x), None

            tail = jax.tree.map(lambda t: t[n_full:], params["blocks"])
            x, _ = jax.lax.scan(tail_body, x, tail)
    elif at in ("dense", "moe"):
        @ckpt
        def body(carry, xs):
            x, aux = carry
            p, w = xs
            if at == "dense":
                x, _ = _dense_body(p, cfg, x, positions, w)
            else:
                x, _, a = _moe_body(p, cfg, x, positions, w)
                aux = aux + a
            return (x, aux), None

        (x, aux), _ = jax.lax.scan(body, (x, aux),
                                   (params["blocks"], layer_windows(cfg)))
    elif at == "ssm":
        @ckpt
        def body(x, p):
            x, _ = _ssm_body(p, cfg, x)
            return x, None

        x, _ = jax.lax.scan(body, x, params["blocks"])
    elif at == "hybrid":
        @ckpt
        def body(carry, p):
            x, aux = carry
            x, _, a = _hybrid_body(p, cfg, x, positions)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"])
    elif at == "audio":
        x = _whisper_forward(params, cfg, batch, tokens, positions)
    elif at == "vlm":
        img = batch["image_embed"].astype(x.dtype)
        w0 = int(cfg.sliding_window)

        @ckpt
        def body(x, p):
            x, _ = _vlm_superblock_body(p, cfg, x, positions, img, w0)
            return x, None

        x, _ = jax.lax.scan(body, x, params["blocks"])
    else:
        raise ValueError(at)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def unembed_matrix(params: Dict) -> jax.Array:
    unembed = params.get("unembed")
    return unembed if unembed is not None else params["embed"].T


def forward_train(params: Dict, cfg: ModelConfig, batch: Dict
                  ) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S, V_pad), moe_aux_loss scalar)."""
    x, aux = forward_hidden(params, cfg, batch)
    logits = x @ unembed_matrix(params)
    logits = shard(logits, "batch", "seq", "vocab")
    return logits, aux


def encode_audio(params, cfg, audio_embed):
    """Whisper encoder over stub frame embeddings (B, T_frames, d_model) ->
    memory for decoder cross-attention."""
    Ta = audio_embed.shape[1]
    pe = L.sinusoidal_positions(Ta, cfg.d_model).astype(audio_embed.dtype)
    h_enc = shard(audio_embed + pe[None], "batch", "frames", None)
    ckpt = (jax.checkpoint if cfg.remat == "layer" else (lambda f: f))

    @ckpt
    def enc_body(h, p):
        z = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
        a, _ = L.attention(p["attn"], cfg, z, positions=jnp.arange(Ta),
                           causal=False, use_rope=False)
        h = h + a
        z = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
        return h + L.ffn(p["ffn"], z), None

    h_enc, _ = jax.lax.scan(enc_body, h_enc, params["enc_blocks"])
    return L.rmsnorm(params["enc_norm"], h_enc, cfg.norm_eps)


def _whisper_forward(params, cfg, batch, tokens, positions):
    memory = encode_audio(params, cfg, batch["audio_embed"])
    ckpt = (jax.checkpoint if cfg.remat == "layer" else (lambda f: f))

    x = params["embed"][tokens]
    x = shard(x, "batch", "seq", None)

    @ckpt
    def dec_body(x, p):
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, _ = L.attention(p["attn"], cfg, h, positions=positions, causal=True)
        x = x + a
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        ca, _ = L.attention(p["cross"], cfg, h, positions=positions,
                            causal=False, kv_source=memory, use_rope=False)
        x = x + ca
        h = L.rmsnorm(p["ln3"], x, cfg.norm_eps)
        return x + L.ffn(p["ffn"], h), None

    x, _ = jax.lax.scan(dec_body, x, params["dec_blocks"])
    return x


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def lm_loss(logits: jax.Array, labels: jax.Array, vocab_size: int,
            aux: jax.Array = 0.0, aux_weight: float = 0.01) -> jax.Array:
    """Next-token cross entropy over the *unpadded* vocabulary."""
    vp = logits.shape[-1]
    logits = logits[:, :-1].astype(jnp.float32)
    labels = labels[:, 1:]
    if vp > vocab_size:
        neg = jnp.full((vp,), -1e30, jnp.float32)
        mask = jnp.where(jnp.arange(vp) < vocab_size, 0.0, neg)
        logits = logits + mask
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold) + aux_weight * aux


def lm_loss_chunked(hidden: jax.Array, unembed: jax.Array, labels: jax.Array,
                    vocab_size: int, aux: jax.Array = 0.0,
                    aux_weight: float = 0.01, chunk: int = 512) -> jax.Array:
    """Fused final-projection + next-token cross entropy, scanned over
    sequence chunks so the f32 logits of only ``chunk`` positions are ever
    live (materializing (B, S, V_pad) f32 logits dominated train-step temp
    memory for the 128k-262k-vocab archs — §Perf iteration 5).

    hidden: (B, S, D) final-norm output; unembed: (D, V_pad)."""
    B, S, D = hidden.shape
    vp = unembed.shape[-1]
    h = hidden[:, :-1]
    y = labels[:, 1:]
    Sm = S - 1
    nc = -(-Sm // chunk)
    pad = nc * chunk - Sm
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        y = jnp.pad(y, ((0, 0), (0, pad)))
    valid = (jnp.arange(nc * chunk) < Sm)
    hc = h.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    yc = y.reshape(B, nc, chunk).transpose(1, 0, 2)
    vc = valid.reshape(nc, chunk)
    vmask = jnp.where(jnp.arange(vp) < vocab_size, 0.0, -1e30)

    def chunk_loss(acc, inp):
        hb, yb, vb = inp
        logits = (hb @ unembed).astype(jnp.float32) + vmask
        logits = shard(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yb[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((lse - gold) * vb[None].astype(jnp.float32)), None

    total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), (hc, yc, vc))
    return total / (B * Sm) + aux_weight * aux


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------


def _attn_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, cfg.num_kv_heads, cache_len, hd), dtype),
        "v": jnp.zeros((batch, cfg.num_kv_heads, cache_len, hd), dtype),
    }


def cache_len_for(cfg: ModelConfig, seq_len: int) -> int:
    """Attention-cache length: full seq, or the sliding/global cap when the
    arch is sub-quadratic (long_500k path)."""
    if cfg.local_global_ratio > 0 or cfg.sliding_window > 0:
        cap = max(cfg.sliding_window, cfg.global_attn_cap
                  if cfg.local_global_ratio > 0 else cfg.sliding_window)
        return min(seq_len, cap)
    if cfg.arch_type == "hybrid":
        return min(seq_len, cfg.global_attn_cap)
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               memory: Optional[jax.Array] = None,
               params: Optional[Dict] = None) -> Dict:
    """Decode-state pytree. ``seq_len`` is the context length the cache must
    hold. For whisper, ``memory``+``params`` precompute cross K/V."""
    dt = jnp.dtype(cfg.dtype)
    at = cfg.arch_type
    W = cache_len_for(cfg, seq_len)
    cache: Dict = {"pos": jnp.zeros((), jnp.int32),
                   "offset": jnp.zeros((), jnp.int32)}

    if at in ("dense", "moe"):
        cache["layers"] = jax.vmap(
            lambda _: _attn_cache(cfg, batch, W, dt))(jnp.arange(cfg.num_layers))
    elif at == "ssm":
        cache["layers"] = jax.vmap(
            lambda _: M.init_mamba_cache(cfg, batch, dt))(jnp.arange(cfg.num_layers))
    elif at == "hybrid":
        nsb = cfg.num_layers // cfg.attn_every

        def one(_):
            return {
                "attn": _attn_cache(cfg, batch, W, dt),
                "mamba": jax.vmap(lambda __: M.init_mamba_cache(cfg, batch, dt))(
                    jnp.arange(cfg.attn_every - 1)),
            }

        cache["layers"] = jax.vmap(one)(jnp.arange(nsb))
    elif at == "audio":
        cache["layers"] = jax.vmap(
            lambda _: _attn_cache(cfg, batch, W, dt))(jnp.arange(cfg.num_layers))
        if memory is not None and params is not None:
            cache["cross"] = jax.vmap(
                lambda p: L.init_cross_kv(p["cross"], cfg, memory)
            )(params["dec_blocks"])
    elif at == "vlm":
        nsb = cfg.num_layers // cfg.cross_attn_every

        def one(_):
            return jax.vmap(lambda __: _attn_cache(cfg, batch, W, dt))(
                jnp.arange(cfg.cross_attn_every))

        cache["layers"] = jax.vmap(one)(jnp.arange(nsb))
        if memory is not None:
            cache["image_embed"] = memory.astype(dt)
    return cache


def _scan_decode(body, x, blocks, cache_layers, extra_xs=None):
    """Scan over layers with the FULL stacked cache as a loop CARRY,
    sliced/updated per layer with dynamic(-update)-index. Carries alias
    in-place under XLA, so the multi-GB cache is never copied per step —
    passing the cache as scan xs/ys instead reallocates (and on this
    backend, copies) the whole stack every decode step."""
    xs = (blocks, extra_xs) if extra_xs is not None else blocks

    def f(carry, layer_xs):
        x, cache_all, i = carry
        c = jax.tree.map(
            lambda t: jax.lax.dynamic_index_in_dim(t, i, 0, keepdims=False),
            cache_all)
        x, nc = body(x, layer_xs, c)
        cache_all = jax.tree.map(
            lambda t, u: jax.lax.dynamic_update_index_in_dim(t, u, i, 0),
            cache_all, nc)
        return (x, cache_all, i + 1), None

    (x, new_cache, _), _ = jax.lax.scan(
        f, (x, cache_layers, jnp.int32(0)), xs)
    return x, new_cache


def decode_step(params: Dict, cfg: ModelConfig, cache: Dict,
                tokens: jax.Array) -> Tuple[jax.Array, Dict]:
    """One serving step: tokens (B, 1) -> (logits (B, 1, V_pad), new cache)."""
    B, S = tokens.shape
    pos = cache["pos"]
    real_pos = (cache["offset"] + pos)[None]
    x = params["embed"][tokens]
    x = shard(x, "batch", None, None)
    at = cfg.arch_type

    if at in ("dense", "moe"):
        def body(x, layer_xs, c):
            (p, w) = layer_xs
            if at == "dense":
                return _dense_body(p, cfg, x, real_pos, w, c, pos,
                                   k_offset=cache["offset"])
            x, nc, _ = _moe_body(p, cfg, x, real_pos, w, c, pos,
                                 k_offset=cache["offset"])
            return x, nc

        x, new_layers = _scan_decode(
            lambda x, xs, c: body(x, xs, c), x, params["blocks"],
            cache["layers"], extra_xs=layer_windows(cfg, decode=True))
    elif at == "ssm":
        x, new_layers = _scan_decode(
            lambda x, p, c: _ssm_body(p, cfg, x, c), x, params["blocks"],
            cache["layers"])
    elif at == "hybrid":
        def body(x, p, c):
            x, nc, _ = _hybrid_body(p, cfg, x, real_pos, c, pos)
            return x, nc

        x, new_layers = _scan_decode(body, x, params["blocks"],
                                     cache["layers"])
    elif at == "audio":
        def body(x, layer_xs, c):
            (p, cx) = layer_xs
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            a, nc = L.attention(p["attn"], cfg, h, positions=real_pos,
                                causal=True, cache=c, cache_pos=pos)
            x = x + a
            h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
            ca, _ = L.attention(p["cross"], cfg, h, positions=real_pos,
                                causal=False, cache=cx, cache_pos=pos,
                                kv_source=jnp.zeros((B, 1, cfg.d_model), x.dtype),
                                use_rope=False)
            x = x + ca
            h = L.rmsnorm(p["ln3"], x, cfg.norm_eps)
            return x + L.ffn(p["ffn"], h), nc

        x, new_layers = _scan_decode(body, x, params["dec_blocks"],
                                     cache["layers"], extra_xs=cache["cross"])
    elif at == "vlm":
        img = cache["image_embed"]
        w0 = int(cfg.sliding_window)

        def body(x, p, c):
            return _vlm_superblock_body(p, cfg, x, real_pos, img, w0,
                                        cache_l=c, cache_pos=pos)

        x, new_layers = _scan_decode(body, x, params["blocks"],
                                     cache["layers"])
    else:
        raise ValueError(at)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    unembed = params.get("unembed")
    logits = x @ unembed if unembed is not None else x @ params["embed"].T
    logits = shard(logits, "batch", None, "vocab")

    new_cache = dict(cache)
    new_cache["layers"] = new_layers
    new_cache["pos"] = pos + S
    return logits, new_cache
