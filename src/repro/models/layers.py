"""Shared building blocks for the model zoo.

Conventions
-----------
* Params are nested dicts of jnp arrays; every linear weight is a flat 2D
  matrix ``(in_dim, out_dim)`` so a single universal partition rule applies
  (column-parallel over ``model``, FSDP over ``data``); activations are
  annotated with logical axes via ``repro.distributed.shard``.
* RoPE uses the *interleaved* (even/odd pair) formulation so the pairing
  stays local under head_dim sharding (see DESIGN.md §7).
* Attention is memory-efficient (online-softmax over KV chunks with
  ``lax.scan``) whenever ``q_len * kv_len`` exceeds a threshold, so 32k+
  contexts lower without materializing the full score matrix. On real TPU
  hardware the Pallas kernels in ``repro.kernels`` replace this path.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.distributed import shard

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(dim: int, dtype) -> jax.Array:
    return jnp.ones((dim,), dtype)


def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * w


# ---------------------------------------------------------------------------
# RoPE (interleaved pairing)
# ---------------------------------------------------------------------------


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> sin/cos of shape (..., head_dim//2)."""
    freqs = theta ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32) * 2.0 / head_dim)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (B, S, H, D); sin/cos: (B, S, D//2) or (S, D//2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x_even = x[..., 0::2]
    x_odd = x[..., 1::2]
    if sin.ndim == 2:  # (S, D/2) -> broadcast over batch & heads
        sin = sin[None, :, None, :]
        cos = cos[None, :, None, :]
    else:  # (B, S, D/2)
        sin = sin[:, :, None, :]
        cos = cos[:, :, None, :]
    r_even = x_even * cos - x_odd * sin
    r_odd = x_odd * cos + x_even * sin
    out = jnp.stack([r_even, r_odd], axis=-1).reshape(x.shape)
    return out.astype(dt)


def sinusoidal_positions(seq_len: int, dim: int) -> jax.Array:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim))
    pe = jnp.zeros((seq_len, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

# chunk sizes for the memory-efficient path
_Q_CHUNK = 1024
_KV_CHUNK = 1024
_DIRECT_LIMIT = 4096 * 4096  # q_len*kv_len above this -> chunked path


def init_attention(key, cfg: ModelConfig, d_model: Optional[int] = None) -> dict:
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    return {
        "wq": dense_init(kq, d, cfg.num_heads * hd, dt),
        "wk": dense_init(kk, d, cfg.num_kv_heads * hd, dt),
        "wv": dense_init(kv, d, cfg.num_kv_heads * hd, dt),
        "wo": dense_init(ko, cfg.num_heads * hd, d, dt),
        "q_norm": rmsnorm_init(hd, dt),
        "k_norm": rmsnorm_init(hd, dt),
    }


def _attn_mask(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window) -> jax.Array:
    """(Q, K) boolean mask. window<=0 -> no window. ``window`` may be a
    traced scalar (gemma local/global flags are scanned over layers)."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    window = jnp.asarray(window)
    in_window = k_pos[None, :] > (q_pos[:, None] - window)
    m &= (window <= 0) | in_window
    return m


def _sdpa_grouped(q, k, v, mask) -> jax.Array:
    """Decode-path attention (q_len small): q: (B,Q,H,D); k,v in the KV
    cache's NATIVE layout (B,KV,S,D) — no transpose, so the multi-GB cache
    is never copied for a layout change; GQA via grouped reshape so it is
    never head-repeated either. mask: (B?,Q,K) bool."""
    B, Q, H, D = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, Q, KV, G, D)
    scores = jnp.einsum("bqkgd,bksd->bkgqs", qg, k).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(D)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bksd->bqkgd", p, v)
    return out.reshape(B, Q, H, D)


def _sdpa_folded(q, k, v, mask) -> jax.Array:
    """Train/prefill attention with GQA groups FOLDED into the head dim and
    explicit sharding constraints on the score tensor. Without this, SPMD
    propagation computes (B, H, S, T) scores replicated over the model axis
    (measured: 88 x ~300 GB/op on mistral-large train) because the grouped
    (KV, G) einsum layout admits no 16-way head sharding. k/v are repeated
    to H heads — local (and fusable) when heads are model-sharded.

    q: (B,Q,H,D); k,v: (B,K,KV,D); mask (Q,K) or (B,Q,K)."""
    B, Q, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    k = shard(k, "batch", None, "heads", "head_dim")
    v = shard(v, "batch", None, "heads", "head_dim")
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(D)
    scores = shard(scores, "batch", "heads", "attn_q_seq", None)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    p = shard(p, "batch", "heads", "attn_q_seq", None)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    return shard(out, "batch", "attn_q_seq", "heads", "head_dim")


def _sdpa_local(q, k, v, window: int) -> jax.Array:
    """Banded block attention for sliding-window layers: queries in blocks
    of ``window``; each block attends to its own and the previous block
    (2w keys) — O(S*w) score work/memory instead of the O(S^2) full band
    that the generic paths compute and mask away (gemma3: 29 of 34 layers
    are 1024-window local; at 32k prefill this is ~16x less score work).

    q: (B,S,H,D); k,v: (B,S,KV,D). Assumes causal, positions 0..S-1.
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    w = window
    nb = -(-S // w)
    qp = _pad_axis(q, 1, nb * w)
    kp = _pad_axis(k, 1, nb * w)
    vp = _pad_axis(v, 1, nb * w)

    qb = qp.reshape(B, nb, w, H, D)
    kb = kp.reshape(B, nb, w, H, D)
    vb = vp.reshape(B, nb, w, H, D)
    # previous block (zeros before block 0)
    kprev = jnp.pad(kb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :nb]
    vprev = jnp.pad(vb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :nb]
    k2 = jnp.concatenate([kprev, kb], axis=2)  # (B, nb, 2w, H, D)
    v2 = jnp.concatenate([vprev, vb], axis=2)
    qb = shard(qb, "batch", None, None, "heads", "head_dim")
    k2 = shard(k2, "batch", None, None, "heads", "head_dim")

    s = jnp.einsum("bnqhd,bnkhd->bhnqk", qb, k2).astype(jnp.float32)
    s *= 1.0 / math.sqrt(D)
    s = shard(s, "batch", "heads", None, None, None)
    a_idx = jax.lax.broadcasted_iota(jnp.int32, (nb, w, 2 * w), 1)
    b_idx = jax.lax.broadcasted_iota(jnp.int32, (nb, w, 2 * w), 2)
    blk = jax.lax.broadcasted_iota(jnp.int32, (nb, w, 2 * w), 0)
    # dist = w + a - b: causal dist>=0, window dist<w; block 0 has no prev
    mask = (b_idx <= a_idx + w) & (b_idx > a_idx) & ((blk > 0) | (b_idx >= w))
    s = jnp.where(mask[None, None], s, -1e30)
    # padded tail queries attend only within pad; softmax stays finite via
    # the b==a+w diagonal (self) entry
    p = jax.nn.softmax(s, axis=-1).astype(v2.dtype)
    out = jnp.einsum("bhnqk,bnkhd->bnqhd", p, v2)
    out = out.reshape(B, nb * w, H, D)[:, :S]
    return out


def _sdpa_chunked(q, k, v, q_pos, k_pos, causal, window) -> jax.Array:
    """Online-softmax attention (the pure-XLA flash equivalent): ALL queries
    held live, ``lax.scan`` over KV chunks only; the live score block is
    (B, H, Q, kv_chunk). Folded-head layout like ``_sdpa_folded``, with the
    query dim sharded over "attn_q_seq" (context parallelism) when heads
    cannot be model-sharded — a sequential outer q-chunk scan would leave
    that dimension unshardable and the whole score computation replicated
    across the model axis (measured 16x memory waste on llama3.2-3b
    prefill_32k)."""
    B, Q, H, D = q.shape
    K, KV = k.shape[1], k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    k = shard(k, "batch", None, "heads", "head_dim")
    v = shard(v, "batch", None, "heads", "head_dim")
    kc = min(_KV_CHUNK, K)
    nk = -(-K // kc)
    kp = _pad_axis(k, 1, nk * kc)
    vp = _pad_axis(v, 1, nk * kc)
    kpos = _pad_axis(k_pos, 0, nk * kc, fill=10 ** 9)

    qh = q.transpose(0, 2, 1, 3)  # (B, H, Q, D)
    qh = shard(qh, "batch", "heads", "attn_q_seq", "head_dim")
    kblocks = kp.reshape(B, nk, kc, H, D).transpose(1, 0, 3, 2, 4)
    vblocks = vp.reshape(B, nk, kc, H, D).transpose(1, 0, 3, 2, 4)
    kpos_b = kpos.reshape(nk, kc)
    scale = 1.0 / math.sqrt(D)

    def kv_block(state, kb):
        m_prev, l_prev, acc = state
        ktile, vtile, kpos_tile = kb  # (B,H,kc,D), (kc,)
        s = jnp.einsum("bhqd,bhsd->bhqs", qh, ktile).astype(jnp.float32)
        s *= scale
        s = shard(s, "batch", "heads", "attn_q_seq", None)
        mask = _attn_mask(q_pos, kpos_tile, causal, window)
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqs,bhsd->bhqd", p.astype(vtile.dtype), vtile
        ).astype(jnp.float32)
        return (m_new, l_new, acc), None

    init = (
        jnp.full((B, H, Q), -jnp.inf, jnp.float32),
        jnp.zeros((B, H, Q), jnp.float32),
        jnp.zeros((B, H, Q, D), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(kv_block, init, (kblocks, vblocks, kpos_b))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype).transpose(0, 2, 1, 3)  # (B, Q, H, D)


def _pad_axis(x, axis, size, fill=0):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def attention(params: dict, cfg: ModelConfig, x: jax.Array, *,
              positions: jax.Array,
              causal: bool = True,
              window: int = 0,
              cache: Optional[dict] = None,
              cache_pos: Optional[jax.Array] = None,
              kv_source: Optional[jax.Array] = None,
              use_rope: bool = True,
              k_offset: jax.Array | int = 0,
              local_window: Optional[int] = None
              ) -> Tuple[jax.Array, Optional[dict]]:
    """General attention: self/cross, train/prefill/decode.

    x: (B, S, D). positions: (S,) absolute positions of the query tokens.
    cache: {"k": (B, KV, S_max, hd), "v": ...} ring buffer written at
    ``cache_pos``; decode attends over the cache.
    kv_source: cross-attention memory (B, T, D) -- no cache path needed for
    training; for decode the cross K/V are precomputed in the cache.
    """
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    dt = x.dtype

    q = (x @ params["wq"]).reshape(B, S, H, hd)
    src = kv_source if kv_source is not None else x
    Tsrc = src.shape[1]
    k = (src @ params["wk"]).reshape(B, Tsrc, KV, hd)
    v = (src @ params["wv"]).reshape(B, Tsrc, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)

    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")

    if use_rope and kv_source is None:
        sin, cos = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)

    new_cache = None
    if cache is not None and kv_source is None:
        # decode: write S (normally 1) new entries at cache_pos
        kc = cache["k"]  # (B, KV, S_max, hd)
        vc = cache["v"]
        k_t = k.transpose(0, 2, 1, 3)  # (B, KV, S, hd)
        v_t = v.transpose(0, 2, 1, 3)
        kc = jax.lax.dynamic_update_slice(kc, k_t.astype(kc.dtype), (0, 0, cache_pos, 0))
        vc = jax.lax.dynamic_update_slice(vc, v_t.astype(vc.dtype), (0, 0, cache_pos, 0))
        new_cache = {"k": kc, "v": vc}
        k_idx = jnp.arange(kc.shape[2])
        valid = k_idx <= (cache_pos + S - 1)
        k_pos = k_idx + k_offset  # real positions of cache slots
        q_pos = positions
        mask = _attn_mask(q_pos, k_pos, causal, window) & valid[None, :]
        if q.shape[1] * kc.shape[2] <= _DIRECT_LIMIT or q.shape[1] == 1:
            out = _sdpa_grouped(q, kc, vc, mask)  # native cache layout
        else:
            out = _sdpa_chunked(q, kc.transpose(0, 2, 1, 3),
                                vc.transpose(0, 2, 1, 3), q_pos,
                                jnp.where(valid, k_pos, 10 ** 9),
                                causal, window)
    elif cache is not None and kv_source is not None:
        # decode-time cross-attention: cached K/V (native layout), no update
        kc, vc = cache["k"], cache["v"]
        mask = jnp.ones((S, kc.shape[2]), bool)
        out = _sdpa_grouped(q, kc, vc, mask)
        new_cache = cache
    else:
        k_pos = positions if kv_source is None else jnp.arange(Tsrc)
        q_pos = positions
        if (local_window is not None and kv_source is None and causal
                and S >= 2 * local_window):
            # banded block path: O(S*w) instead of O(S^2)-then-mask
            out = _sdpa_local(q, k, v, local_window)
        elif S * Tsrc <= _DIRECT_LIMIT:
            mask = _attn_mask(q_pos, k_pos, causal and kv_source is None, window)
            out = _sdpa_folded(q, k, v, mask)
        else:
            out = _sdpa_chunked(q, k, v, q_pos, k_pos,
                                causal and kv_source is None, window)

    out = shard(out, "batch", "seq", "heads", "head_dim")
    out = out.reshape(B, S, H * hd).astype(dt) @ params["wo"]
    return shard(out, "batch", "seq", None), new_cache


def init_cross_kv(params: dict, cfg: ModelConfig, memory: jax.Array) -> dict:
    """Precompute cross-attention K/V from encoder output (decode path)."""
    B, T, _ = memory.shape
    hd = cfg.resolved_head_dim
    k = (memory @ params["wk"]).reshape(B, T, cfg.num_kv_heads, hd)
    v = (memory @ params["wv"]).reshape(B, T, cfg.num_kv_heads, hd)
    return {"k": k.transpose(0, 2, 1, 3), "v": v.transpose(0, 2, 1, 3)}


# ---------------------------------------------------------------------------
# feed-forward
# ---------------------------------------------------------------------------


def init_ffn(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    f = d_ff or cfg.d_ff
    kg, ku, kd = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    return {
        "wg": dense_init(kg, cfg.d_model, f, dt),
        "wu": dense_init(ku, cfg.d_model, f, dt),
        "wd": dense_init(kd, f, cfg.d_model, dt),
    }


def ffn(params: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ params["wg"]) * (x @ params["wu"])
    h = shard(h, "batch", "seq", "ffn")
    return shard(h @ params["wd"], "batch", "seq", None)
