from repro.models.model import (
    init_params,
    forward_train,
    init_cache,
    decode_step,
    lm_loss,
)

__all__ = ["init_params", "forward_train", "init_cache", "decode_step", "lm_loss"]
