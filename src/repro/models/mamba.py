"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block in pure JAX.

The chunked SSD algorithm: within-chunk work is quadratic in the chunk size
and maps onto the MXU; the inter-chunk recurrence is a ``lax.scan`` carrying
the (B, H, P, N) state. Decode is the O(1) recurrent update. A Pallas TPU
kernel for the within-chunk part lives in ``repro.kernels.mamba2_ssd``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.distributed import shard
from repro.models.layers import dense_init


def mamba_dims(cfg: ModelConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    d_inner = cfg.ssm_expand * d
    nheads = d_inner // cfg.ssm_head_dim
    return d, d_inner, nheads, cfg.ssm_head_dim, cfg.ssm_state


def init_mamba(key, cfg: ModelConfig, d_model: Optional[int] = None) -> dict:
    d, d_inner, H, P, N = mamba_dims(cfg, d_model)
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    conv_dim = d_inner + 2 * N
    return {
        "in_proj": dense_init(k1, d, 2 * d_inner + 2 * N + H, dt),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv_width, conv_dim), jnp.float32)
                   / math.sqrt(cfg.ssm_conv_width)).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(k3, (H,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))),
        "out_proj": dense_init(k4, d_inner, d, dt),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array, d_inner: int, H: int, N: int):
    z, xc, Bm, Cm, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1)
    return z, xc, Bm, Cm, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: (B, L, C); w: (W, C).

    Returns (out, new_state) where state is the last W-1 inputs (B, W-1, C).
    """
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xin = jnp.concatenate([state, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xin[:, i:i + x.shape[1]] * w[i]
    new_state = xin[:, -(W - 1):] if W > 1 else state
    return out + b, new_state


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, D: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x: (B, L, H, P); dt: (B, L, H) (post-softplus); A: (H,) (negative);
    Bm, Cm: (B, L, N); D: (H,). Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    cs = min(chunk, L)
    nc = -(-L // cs)
    pad = nc * cs - L

    def padl(a):
        if pad == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[1] = (0, pad)
        return jnp.pad(a, widths)

    xp, dtp, Bp, Cp = padl(x), padl(dt), padl(Bm), padl(Cm)
    xc = xp.reshape(Bsz, nc, cs, H, P).transpose(1, 0, 2, 3, 4)
    dtc = dtp.reshape(Bsz, nc, cs, H).transpose(1, 0, 2, 3)
    Bc = Bp.reshape(Bsz, nc, cs, N).transpose(1, 0, 2, 3)
    Cc = Cp.reshape(Bsz, nc, cs, N).transpose(1, 0, 2, 3)

    tri = jnp.tril(jnp.ones((cs, cs), bool))

    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(state, inp):
        xb, dtb, Bb, Cb = inp  # (B,cs,H,P),(B,cs,H),(B,cs,N),(B,cs,N)
        adt = dtb.astype(jnp.float32) * A  # (B,cs,H), negative
        acum = jnp.cumsum(adt, axis=1)  # (B,cs,H)
        # decay(t<-s) = exp(acum_t - acum_s) for t>=s
        seg = acum[:, :, None, :] - acum[:, None, :, :]  # (B,t,s,H)
        Lmat = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        CB = jnp.einsum("btn,bsn->bts", Cb.astype(jnp.float32),
                        Bb.astype(jnp.float32))
        scores = CB[:, :, :, None] * Lmat  # (B,t,s,H)
        y_diag = jnp.einsum("btsh,bsh,bshp->bthp", scores,
                            dtb.astype(jnp.float32), xb.astype(jnp.float32))
        # contribution from carried state
        y_off = jnp.einsum("btn,bhpn,bth->bthp", Cb.astype(jnp.float32), state,
                           jnp.exp(acum))
        # state update
        decay_to_end = jnp.exp(acum[:, -1:, :] - acum)  # (B,cs,H)
        w = dtb.astype(jnp.float32) * decay_to_end
        new_contrib = jnp.einsum("bsn,bsh,bshp->bhpn", Bb.astype(jnp.float32),
                                 w, xb.astype(jnp.float32))
        state = state * jnp.exp(acum[:, -1, :])[:, :, None, None] + new_contrib
        y = y_diag + y_off
        return state, y

    final_state, ys = jax.lax.scan(step, init_state, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, nc * cs, H, P)[:, :L]
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), final_state


def ssd_decode_step(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                    Cm: jax.Array, D: jax.Array, state: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrence. x: (B,H,P); dt: (B,H); Bm,Cm: (B,N);
    state: (B,H,P,N)."""
    dt32 = dt.astype(jnp.float32)
    decay = jnp.exp(dt32 * A)  # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt32, x.astype(jnp.float32),
                     Bm.astype(jnp.float32))
    state = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    y = y + x.astype(jnp.float32) * D[None, :, None]
    return y.astype(x.dtype), state


def mamba_block(params: dict, cfg: ModelConfig, x: jax.Array, *,
                cache: Optional[dict] = None
                ) -> Tuple[jax.Array, Optional[dict]]:
    """Full Mamba2 block. x: (B, S, D).

    cache (decode): {"conv": (B, W-1, conv_dim), "ssm": (B, H, P, N)}.
    """
    Bsz, S, Dm = x.shape
    _, d_inner, H, P, N = mamba_dims(cfg, Dm)
    proj = x @ params["in_proj"]
    z, xc, Bm, Cm, dt = _split_proj(cfg, proj, d_inner, H, N)

    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv_state = _causal_conv(conv_in, params["conv_w"],
                                            params["conv_b"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xc, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = xc.reshape(Bsz, S, H, P)
    xh = shard(xh, "batch", "seq", "ssm_heads", "ssm_pdim")

    new_cache = None
    if cache is not None and S == 1:
        y, new_state = ssd_decode_step(xh[:, 0], dt[:, 0], A, Bm[:, 0],
                                       Cm[:, 0], params["D"], cache["ssm"])
        y = y[:, None]
        new_cache = {"conv": new_conv_state, "ssm": new_state}
    else:
        init_state = cache["ssm"] if cache is not None else None
        y, final_state = ssd_chunked(xh, dt, A, Bm, Cm, params["D"],
                                     cfg.ssm_chunk, init_state)
        if cache is not None:
            new_cache = {"conv": new_conv_state, "ssm": final_state}

    y = y.reshape(Bsz, S, d_inner) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return shard(out, "batch", "seq", None), new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype,
                     d_model: Optional[int] = None) -> dict:
    _, d_inner, H, P, N = mamba_dims(cfg, d_model)
    conv_dim = d_inner + 2 * N
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
    }
