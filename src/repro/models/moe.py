"""Mixture-of-Experts FFN: top-k routing with sort/gather-based dispatch
and shard_map expert parallelism.

Why not the classic GShard one-hot dispatch/combine einsums: their cost is
T x E x C x D_model, which at production shapes (qwen3-moe train_4k:
T=1M, E=128, C=100k) is ~630x the useful expert FLOPs — the §Perf roofline
baseline measured exactly that. Instead tokens are ROUTED BY SORTING
(argsort by expert id, rank-within-expert for capacity, scatter into
(E_local, C, D) buffers), which is O(Tk log(Tk)) scalar work + O(TkD)
gather/scatter traffic, and the expert GEMMs run at their natural
E x C x D x F cost.

Expert parallelism: experts are sharded over the "model" mesh axis;
activations arrive replicated across that axis (they are batch-sharded
over "data"), so each model rank gathers the tokens destined to ITS
experts, runs the GEMMs, scatters back a partial output, and a psum over
"model" combines — the collective cost is one (B, S, D) all-reduce per
MoE layer, the same shape as the dense-TP pattern.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.common.config import ModelConfig
from repro.distributed import current_mesh, current_rules
from repro.models.layers import dense_init

CAPACITY_FACTOR = 1.25


def init_moe(key, cfg: ModelConfig) -> dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
    scale = 1.0 / math.sqrt(D)

    def ew(k, a, b):
        return (jax.random.normal(k, (E, a, b), jnp.float32) * scale).astype(dt)

    return {
        "router": dense_init(kr, D, E, jnp.float32),
        "wg": ew(kg, D, F),
        "wu": ew(ku, D, F),
        "wd": (jax.random.normal(kd, (E, F, D), jnp.float32) / math.sqrt(F)).astype(dt),
    }


def expert_capacity(num_tokens: int, num_experts: int, top_k: int,
                    factor: float = CAPACITY_FACTOR) -> int:
    cap = int(math.ceil(num_tokens * top_k * factor / num_experts))
    return max(cap, 4)


def _moe_local(router_w, wg, wu, wd, cfg: ModelConfig, xt,
               e_lo, E_l: int, C: int) -> Tuple[jax.Array, jax.Array]:
    """Sort-based dispatch on tokens xt (T, D) for the E_l experts whose
    GLOBAL ids start at ``e_lo`` (wg/wu/wd are the local tables).
    Returns (partial output (T, D), aux load-balance loss over full E)."""
    T, D = xt.shape
    E, K = cfg.num_experts, cfg.experts_per_token

    logits = xt.astype(jnp.float32) @ router_w          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    density = jnp.mean(probs, axis=0)
    topv, topi = jax.lax.top_k(probs, K)                # (T, K)
    gates = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    frac = jnp.mean(jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(1), 0) / K
    aux = E * jnp.sum(frac * density)

    flat_e = topi.reshape(T * K)
    flat_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_g = gates.reshape(T * K)

    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    stok = flat_tok[order]
    sg = flat_g[order]

    # rank within expert = sorted position - start of that expert's run
    counts = jnp.bincount(flat_e, length=E)
    starts = (jnp.cumsum(counts) - counts).astype(jnp.int32)
    rank = jnp.arange(T * K, dtype=jnp.int32) - starts[se]

    local = (se >= e_lo) & (se < e_lo + E_l) & (rank < C)
    e_local = jnp.where(local, se - e_lo, 0).astype(jnp.int32)
    slot = jnp.where(local, e_local * C + rank, E_l * C)  # last bin = dropped

    buf = jnp.zeros((E_l * C + 1, D), xt.dtype)
    buf = buf.at[slot].set(xt[stok])
    expert_in = buf[:-1].reshape(E_l, C, D)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, wg))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, wu)
    expert_out = jnp.einsum("ecf,efd->ecd", h, wd).reshape(E_l * C, D)

    safe_slot = jnp.where(local, slot, 0)
    contrib = expert_out[safe_slot] * (sg * local).astype(xt.dtype)[:, None]
    out = jnp.zeros((T, D), xt.dtype).at[stok].add(contrib)
    return out, aux


def _axis_size(mesh, spec) -> int:
    if spec is None:
        return 1
    axes = spec if isinstance(spec, tuple) else (spec,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def moe_ffn(params: dict, cfg: ModelConfig, x: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss). Expert-parallel over the "model"
    mesh axis when a mesh is active; plain local execution otherwise."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    cf = getattr(cfg, "moe_capacity_factor", CAPACITY_FACTOR)

    mesh = current_mesh()
    rules = current_rules() or {}
    ep = (mesh is not None and "model" in getattr(mesh, "axis_names", ())
          and rules.get("experts") == "model"
          and E % mesh.shape["model"] == 0)

    if not ep:
        out, aux = _moe_local(params["router"], params["wg"], params["wu"],
                              params["wd"], cfg, x.reshape(B * S, D),
                              0, E, expert_capacity(B * S, E, K, cf))
        return out.reshape(B, S, D), aux

    mp = mesh.shape["model"]
    E_l = E // mp
    bspec = rules.get("batch")
    x_spec = P(bspec, None, None)
    T_local = (B // _axis_size(mesh, bspec)) * S
    C = expert_capacity(T_local, E, K, cf)
    pspec = {
        "router": P(None, None),
        "wg": P("model", None, None),
        "wu": P("model", None, None),
        "wd": P("model", None, None),
    }
    batch_axes = bspec if isinstance(bspec, tuple) else (
        (bspec,) if bspec else ())

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(pspec, x_spec),
        out_specs=(x_spec, P()),
        check_rep=False)
    def run(p, xb):
        Bl, Sl, _ = xb.shape
        r = jax.lax.axis_index("model")
        out, aux = _moe_local(p["router"], p["wg"], p["wu"], p["wd"], cfg,
                              xb.reshape(Bl * Sl, D), r * E_l, E_l, C)
        out = jax.lax.psum(out, "model")
        aux = jax.lax.pmean(aux, "model")
        for a in batch_axes:
            aux = jax.lax.pmean(aux, a)
        return out.reshape(Bl, Sl, D), aux

    return run(params, x)
