"""Vectorized online protocol engine (DESIGN.md §8).

Runners over a :class:`repro.sim.env.DeviceReplayEnv`:

* :func:`run_baseline_device` — a full T-slice protocol run of one
  stateless baseline as a single jitted ``lax.scan`` (one device dispatch
  for the whole run, vs. the seed host loop's T × policies round-trips).
* :func:`run_baseline_sweep` — the same scan ``vmap``-ed over PRNG keys
  for multi-seed sweeps.
* :func:`run_neuralucb_device` — Algorithm 1 end to end as ONE device
  dispatch (DESIGN.md §8.4): the whole T-slice run — DECIDE → feedback →
  rank-k Woodbury UPDATE → replay-train scan → Cholesky REBUILD — is a
  single ``lax.scan`` over a pure :class:`NeuralUCBState` pytree with a
  fixed per-slice training schedule.
* :func:`run_neuralucb_sweep` — that scan ``vmap``-ed over PRNG keys and
  over a ``(beta, tau_g, cost_lambda)`` hyperparameter grid, sharded over
  local devices when more than one is present.

Every runner accepts a ``scenario`` (DESIGN.md §9): the declarative
non-stationary transforms from :mod:`repro.sim.scenarios` are applied
per slice INSIDE the same scans (one device dispatch either way), and
the NeuralUCB runners additionally take a
:class:`repro.sim.policies.ForgettingConfig` selecting sliding-window /
discounted A^-1 forgetting and recency-weighted replay sampling.
* :class:`DeviceNeuralUCB` — the host-stepped runner (one fused jit call
  per slice phase), kept as the parity reference; its ``run()`` delegates
  to the scanned path when the schedule allows.

Differences vs. the seed host loop (``repro.core.protocol.run_protocol``),
see DESIGN.md §8.3/§8.4: the random baseline and warm-slice exploration
draw from the jax PRNG (numpy's in the seed), and replay training samples
minibatches with replacement (permutation epochs in the seed). Policies
that are deterministic given the reward stream (fixed arms, greedy) are
bit-compatible — asserted by tests/test_sim_engine.py.
"""
from __future__ import annotations

import functools
import itertools
import time
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import neuralucb as NU
from repro.core import utilitynet as UN
from repro.core.policy import default_ucb_backend
from repro.core.reward import normalize_cost
from repro.distributed.sharding import shard_sweep_axis
from repro.kernels.ucb_score.ops import ucb_score
from repro.sim.env import DeviceReplayEnv
from repro.sim.policies import (
    VANILLA_FORGETTING,
    DevicePolicy,
    ForgettingConfig,
    NeuralUCBHypers,
    NeuralUCBState,
)
from repro.sim.scenarios import ScenarioTables, resolve_scenario
from repro.training.optim import adamw_init, adamw_update, clip_by_global_norm


def _tables(env: DeviceReplayEnv) -> Dict[str, jnp.ndarray]:
    """Resident replay tables. ``cnorm`` is the Eq.-1 normalized cost,
    carried so sweep harnesses can re-derive the reward table for any
    ``cost_lambda`` on device (baseline scans simply never read it);
    ``c_max`` / ``env_lambda`` / ``mean_cost`` feed the scenario
    engine's per-slice reward recompute and availability fallback."""
    return {"x_emb": env.x_emb, "x_feat": env.x_feat, "domain": env.domain,
            "quality": env.quality, "cost": env.cost, "reward": env.reward,
            "cnorm": normalize_cost(env.cost, env.cost.max()),
            "c_max": env.cost.max(),
            "env_lambda": jnp.float32(env.cost_lambda),
            "mean_cost": env.cost.mean(axis=0),
            "oracle_max": env.reward.max(axis=1)}


def _context(tables, idx):
    return {"x_emb": tables["x_emb"][idx], "x_feat": tables["x_feat"][idx],
            "domain": tables["domain"][idx]}


def _effective_slice(tables, scn: Optional[ScenarioTables], t, idx, lam):
    """Slice-t effective tables (DESIGN.md §9.1). With no scenario this
    is None — the metrics/feedback paths then use the PR-2 (S,)-gather
    fast path against the resident tables directly (materializing (S, K)
    temporaries per slice measurably regressed the vmapped sweep). With
    a scenario, the declarative per-slice transforms are applied to the
    gathered (S, K) rows and the Eq.-1 reward is re-derived on device
    with the env's stationary C_max (a shocked price may push the
    normalized cost past 1 — that is the point of a shock)."""
    if scn is None:
        return None
    q = jnp.clip(tables["quality"][idx] * scn.quality_mult[t]
                 + scn.quality_add[t], 0.0, 1.0)
    c = tables["cost"][idx] * scn.cost_mult[t]
    r = q * jnp.exp(-lam * normalize_cost(c, tables["c_max"]))
    return {"quality": q, "cost": c, "reward": r, "avail": scn.avail[t]}


def _avail_fallback(a, avail, mean_cost):
    """Engine-level failover for availability-unaware policies: a request
    routed to an unavailable arm falls back to the cheapest available
    arm (deterministic, like production failover to the budget tier)."""
    fb = jnp.argmin(jnp.where(avail > 0, mean_cost, jnp.inf)).astype(
        jnp.int32)
    return jnp.where(avail[a] > 0, a, fb).astype(jnp.int32)


def _pick(tables, eff, key, idx, actions):
    """Chosen-action values (S,): resident-table gather on the
    stationary fast path, effective-table gather under a scenario."""
    if eff is None:
        return tables[key][idx, actions]
    rows = jnp.arange(actions.shape[0], dtype=jnp.int32)
    return eff[key][rows, actions]


def _slice_metrics(tables, eff, idx, mask, actions):
    denom = jnp.maximum(mask.sum(), 1.0)
    r = _pick(tables, eff, "reward", idx, actions) * mask
    q = _pick(tables, eff, "quality", idx, actions) * mask
    c = _pick(tables, eff, "cost", idx, actions) * mask
    K = tables["reward"].shape[1]
    hist = (jax.nn.one_hot(actions, K, dtype=jnp.float32)
            * mask[:, None]).sum(axis=0)
    # dynamic oracle: best AVAILABLE arm per sample under the slice's
    # effective tables (the regret reference, §9.3); precomputed per
    # sample on the stationary path
    if eff is None:
        o = tables["oracle_max"][idx] * mask
    else:
        r_all = eff["reward"]
        if eff["avail"] is not None:
            r_all = jnp.where(eff["avail"] > 0, r_all, -1.0)
        o = r_all.max(axis=1) * mask
    return {"sum_reward": r.sum(), "avg_reward": r.sum() / denom,
            "avg_cost": c.sum() / denom, "avg_quality": q.sum() / denom,
            "action_hist": hist, "oracle_avg_reward": o.sum() / denom}


def _metrics_to_results(ms: Dict[str, np.ndarray], wall_s: float) -> Dict:
    """Convert stacked per-slice device metrics to the
    ``core.protocol.run_protocol`` per-policy result format."""
    T = len(ms["avg_reward"])
    cum = np.cumsum(np.asarray(ms["sum_reward"], np.float64))
    return {
        "avg_reward": [float(v) for v in ms["avg_reward"]],
        "cum_reward": [float(v) for v in cum],
        "avg_cost": [float(v) for v in ms["avg_cost"]],
        "avg_quality": [float(v) for v in ms["avg_quality"]],
        "oracle_avg_reward": [float(v) for v in ms["oracle_avg_reward"]],
        "action_hist": np.asarray(ms["action_hist"]),
        "wall_s": [wall_s / T] * T,
    }


# --------------------------------------------------------------- baselines --
def _baseline_scan_impl(tables, xs, key, policy: DevicePolicy, scn=None):
    state = policy.init(key)

    def step(carry, x):
        state, key = carry
        key, kd = jax.random.split(key)
        t, idx, mask = x["t"], x["idx"], x["mask"]
        eff = _effective_slice(tables, scn, t, idx, tables["env_lambda"])
        batch = _context(tables, idx)
        a = policy.decide(state, kd, batch)
        if eff is not None and eff["avail"] is not None:
            a = _avail_fallback(a, eff["avail"], tables["mean_cost"])
        m = _slice_metrics(tables, eff, idx, mask, a)
        r = _pick(tables, eff, "reward", idx, a)
        state = policy.update(state, batch, a, r, mask)
        return (state, key), m

    _, ms = jax.lax.scan(step, (state, key), xs)
    return ms


_baseline_scan = jax.jit(_baseline_scan_impl, static_argnames=("policy",))


@functools.partial(jax.jit, static_argnames=("policy",))
def _baseline_sweep_scan(tables, xs, keys, policy: DevicePolicy, scn=None):
    """The full T-slice scan vmapped over PRNG keys, compiled as one unit
    so repeated sweeps are a single cached dispatch. Scenario transforms
    are broadcast (not vmapped): all lanes replay the same drift."""
    return jax.vmap(
        lambda k: _baseline_scan_impl(tables, xs, k, policy, scn))(keys)


def run_baseline_device(env: DeviceReplayEnv, policy: DevicePolicy, *,
                        seed: int = 0, scenario=None) -> Dict:
    """One policy, all T slices, one device dispatch. Returns the
    ``run_protocol`` per-policy result dict (summarize-compatible).
    ``scenario`` is a registered name or :class:`Scenario` (DESIGN.md
    §9); the scan stays a single dispatch either way."""
    env, scn, _ = resolve_scenario(env, scenario)
    t0 = time.perf_counter()
    ms = jax.block_until_ready(_baseline_scan(
        _tables(env), env.slice_xs(), jax.random.PRNGKey(seed), policy,
        scn))
    return _metrics_to_results(ms, time.perf_counter() - t0)


def run_baseline_sweep(env: DeviceReplayEnv, policy: DevicePolicy,
                       seeds, scenario=None) -> Dict[str, np.ndarray]:
    """Multi-seed sweep: vmap the whole T-slice scan over PRNG keys,
    sharded across local devices on the seed axis when several exist.

    Returns stacked raw metrics with a leading seed axis, e.g.
    ``out["avg_reward"]`` has shape (n_seeds, T)."""
    env, scn, _ = resolve_scenario(env, scenario)
    keys = shard_sweep_axis(
        jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds]))
    ms = _baseline_sweep_scan(_tables(env), env.slice_xs(), keys, policy,
                              scn)
    return {k: np.asarray(v) for k, v in ms.items()}


# --------------------------------------------------------------- neuralucb --
def _weighted_loss(params, cfg: UN.UtilityNetConfig, batch):
    """Replay loss with per-row validity weights (padded rows carry w=0)."""
    mu, _, gate_p = UN.utilitynet_apply(
        params, batch["x_emb"], batch["x_feat"], batch["domain"],
        batch["action"])
    w = batch["w"]
    l_u = (UN.huber(mu, batch["reward"], cfg.huber_delta) * w
           ).sum() / jnp.maximum(w.sum(), 1.0)
    p = jnp.clip(gate_p, 1e-6, 1 - 1e-6)
    y = batch["gate_label"]
    bce = -(y * jnp.log(p) + (1 - y) * jnp.log(1 - p))
    gw = batch["gate_w"]
    l_g = (bce * gw).sum() / jnp.maximum(gw.sum(), 1.0)
    return l_u + 0.5 * l_g, {"loss_u": l_u, "loss_gate": l_g}


def _apply_cost_lambda(tables, cost_lambda):
    """Re-derive the reward table for a swept ``cost_lambda`` (Eq. 1):
    r = q * exp(-lambda * c_tilde). Negative lambda is the sentinel for
    "keep the env's precomputed table" (both sides of the where are cheap
    elementwise passes over the resident (n, K) tables)."""
    swept = tables["quality"] * jnp.exp(
        -jnp.abs(cost_lambda) * tables["cnorm"])
    reward = jnp.where(cost_lambda >= 0, swept, tables["reward"])
    # keep the per-sample dynamic-oracle reference consistent with the
    # re-derived table (one (n, K) max per dispatch, outside the scan)
    return dict(tables, reward=reward, oracle_max=reward.max(axis=1))


def _decide_warm(params, batch, key, cfg: UN.UtilityNetConfig, avail=None):
    """Slice-1 warm start: uniform exploration (over AVAILABLE arms when
    a scenario masks some); the safe-utility reference is 0 and the gate
    loss is masked (gate scale 0). The masked draw is a randint over the
    available COUNT mapped through the availability CDF, so with all
    arms available it consumes the key identically to the plain draw
    (an identity scenario reproduces the fast path bit-for-bit)."""
    B = batch["x_emb"].shape[0]
    if avail is None:
        a = jax.random.randint(key, (B,), 0, cfg.num_actions, jnp.int32)
    else:
        n_av = avail.astype(jnp.int32).sum()
        r = jax.random.randint(key, (B,), 0, jnp.maximum(n_av, 1),
                               jnp.int32)
        rank = jnp.cumsum(avail.astype(jnp.int32)) - 1  # arm -> avail rank
        a = jnp.searchsorted(rank, r, side="left").astype(jnp.int32)
    _, h, _ = UN.utilitynet_apply(
        params, batch["x_emb"], batch["x_feat"], batch["domain"], a)
    return a, NU.augment(h), jnp.zeros((B,), jnp.float32), jnp.float32(0.0)


def _decide_ucb(params, ainv, batch, beta, tau_g,
                cfg: UN.UtilityNetConfig, backend: str, avail=None):
    """Gated UCB decision over all actions (paper §3.3). Unavailable
    arms (scenario avail mask) are excluded from BOTH the UCB argmax and
    the safe mean-greedy argmax."""
    mu, h, gate_p = UN.utilitynet_all_actions(
        params, cfg, batch["x_emb"], batch["x_feat"], batch["domain"])
    g_all = NU.augment(h)                                  # (B, K, F)
    if backend == "pallas":
        interpret = jax.default_backend() != "tpu"
        scores = ucb_score(g_all, ainv, mu, beta, interpret=interpret)
    else:
        scores = mu + beta * NU.ucb_bonus(ainv, g_all)
    mu_sel = mu
    if avail is not None:
        neg = jnp.where(avail > 0, 0.0, -jnp.inf)
        scores = scores + neg
        mu_sel = mu + neg
    a_ucb = jnp.argmax(scores, axis=-1)
    a_safe = jnp.argmax(mu_sel, axis=-1)
    a = jnp.where(gate_p >= tau_g, a_ucb, a_safe).astype(jnp.int32)
    g = jnp.take_along_axis(
        g_all, a[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    mu_safe = jnp.take_along_axis(mu, a_safe[:, None], axis=1)[:, 0]
    return a, g, mu_safe, jnp.float32(1.0)


def _post_decide(ainv, tables, eff, bufs, t, idx, mask, a, g, mu_safe,
                 gate_scale, gate_margin, update_ainv: bool = True):
    """Feedback lookup -> buffer write -> rank-k Woodbury UPDATE, shared
    by the static-warm step and the scanned traced-warm step.
    ``update_ainv=False`` defers the online A^-1 update (delayed-feedback
    scenarios apply the newly-VISIBLE slice instead, §9.1)."""
    r = _pick(tables, eff, "reward", idx, a)
    gate_label = (r < mu_safe - gate_margin).astype(jnp.float32)
    bufs = {
        "action": bufs["action"].at[t].set(a),
        "reward": bufs["reward"].at[t].set(r),
        "gate_label": bufs["gate_label"].at[t].set(gate_label),
        "w": bufs["w"].at[t].set(mask),
        "gate_w": bufs["gate_w"].at[t].set(mask * gate_scale),
    }
    if update_ainv:
        # padded rows are zeroed -> contribute nothing to the rank-k update
        ainv = NU.woodbury_update(ainv, g * mask[:, None])
    return ainv, bufs, _slice_metrics(tables, eff, idx, mask, a)


@functools.partial(jax.jit, static_argnames=("cfg", "backend", "warm"))
def _nucb_slice_step(params, ainv, tables, bufs, t, idx, mask, key,
                     beta, tau_g, gate_margin,
                     cfg: UN.UtilityNetConfig, backend: str, warm: bool):
    """DECIDE -> feedback lookup -> buffer write -> rank-k UPDATE, fused.
    Host-stepped entry point: ``warm`` is static (one trace per phase).
    Stationary tables only — scenarios are a scanned-runner feature."""
    batch = _context(tables, idx)
    if warm:
        a, g, mu_safe, gs = _decide_warm(params, batch, key, cfg)
    else:
        a, g, mu_safe, gs = _decide_ucb(params, ainv, batch, beta, tau_g,
                                        cfg, backend)
    return _post_decide(ainv, tables, None, bufs, t, idx, mask, a, g,
                        mu_safe, gs, gate_margin)


# SGD steps per compiled training dispatch. Per-slice step budgets are
# rounded UP to a multiple of this, so the training scan compiles exactly
# once for the whole run instead of once per distinct step count.
TRAIN_CHUNK = 32


def _sample_valid(key, batch_size: int, cum0, count):
    """Uniform flat draw over the first ``count`` VALID buffer entries.

    Valid entries are the per-row prefixes of the (T, S) buffers (the
    padded tail of each row carries mask 0 — DeviceReplayEnv layout), so
    with cum0 = [0, cumsum(slice_sizes)] a flat u in [0, count) maps to
    row = searchsorted(cum0, u, 'right') - 1 and col = u - cum0[row].
    Sampling the raw (t+1)*S padded range instead (the PR-1 bug) shrank
    the effective minibatch by the padding fraction: padded rows carry
    w=0, so they neutralize their loss term but still occupy batch slots.
    """
    flat = jax.random.randint(key, (batch_size,), 0, jnp.maximum(count, 1))
    row = jnp.searchsorted(cum0, flat, side="right").astype(jnp.int32) - 1
    col = flat - cum0[row]
    return row, col


def _sample_recency(key, batch_size: int, cum0, t_vis, rho: float):
    """Recency-weighted replay draw (DESIGN.md §9.2): slice s <= t_vis is
    drawn with probability proportional to size_s * rho^(t_vis - s), then
    a column uniformly within the slice — so the UtilityNet's minibatches
    lean toward post-drift feedback instead of averaging it away."""
    sizes = (cum0[1:] - cum0[:-1]).astype(jnp.float32)          # (T,)
    s = jnp.arange(sizes.shape[0], dtype=jnp.int32)
    ok = (s <= jnp.maximum(t_vis, 0)) & (sizes > 0)
    logw = jnp.where(
        ok,
        jnp.log(jnp.maximum(sizes, 1.0))
        + (t_vis - s).astype(jnp.float32) * jnp.log(jnp.float32(rho)),
        -jnp.inf)
    k_row, k_col = jax.random.split(key)
    row = jax.random.categorical(
        k_row, logw, shape=(batch_size,)).astype(jnp.int32)
    u = jax.random.uniform(k_col, (batch_size,))
    col = jnp.minimum(jnp.floor(u * sizes[row]),
                      jnp.maximum(sizes[row] - 1, 0)).astype(jnp.int32)
    return row, col


def _train_chunk(params, opt, tables, env_idx, bufs, key, cum0, count, lr,
                 cfg: UN.UtilityNetConfig, num_steps: int, batch_size: int,
                 t_vis=None, fcfg: ForgettingConfig = VANILLA_FORGETTING,
                 delayed: bool = False):
    """``num_steps`` SGD steps on sampled replay minibatches, all on
    device; ``count`` (traced) is the number of VISIBLE buffered samples.
    Shared verbatim by the host-stepped and scanned runners so identical
    keys give identical training trajectories. ``fcfg`` (static) selects
    uniform vs recency-weighted sampling; ``delayed`` (static) zeroes the
    loss weights of rows past the visibility horizon ``t_vis`` (a
    delayed-feedback slice's rows are written but not yet learnable)."""

    def step(carry, k):
        params, opt = carry
        if fcfg.replay_rho < 1.0:
            row, col = _sample_recency(k, batch_size, cum0, t_vis,
                                       fcfg.replay_rho)
        else:
            row, col = _sample_valid(k, batch_size, cum0, count)
        sid = env_idx[row, col]
        w = bufs["w"][row, col]
        gw = bufs["gate_w"][row, col]
        if delayed:
            vis = (row <= t_vis).astype(jnp.float32)
            w = w * vis
            gw = gw * vis
        batch = {
            "x_emb": tables["x_emb"][sid],
            "x_feat": tables["x_feat"][sid],
            "domain": tables["domain"][sid],
            "action": bufs["action"][row, col],
            "reward": bufs["reward"][row, col],
            "gate_label": bufs["gate_label"][row, col],
            "w": w,
            "gate_w": gw,
        }
        (_, _), grads = jax.value_and_grad(
            _weighted_loss, has_aux=True)(params, cfg, batch)
        grads, _ = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_update(grads, opt, params, lr=lr,
                                   weight_decay=1e-4)
        return (params, opt), None

    (params, opt), _ = jax.lax.scan(
        step, (params, opt), jax.random.split(key, num_steps))
    return params, opt


_nucb_train = jax.jit(
    _train_chunk,
    static_argnames=("cfg", "num_steps", "batch_size", "fcfg", "delayed"))


def _slice_weights(T: int, t, delay: int, fcfg: ForgettingConfig):
    """(T,) per-slice A^-1 rebuild weights: delayed visibility x
    discounted/sliding-window forgetting (DESIGN.md §9.2). Only built
    when delay > 0 or forgetting is active — the vanilla path passes
    ``row_w=None`` and keeps the PR-2 rebuild bit-exact."""
    s = jnp.arange(T, dtype=jnp.int32)
    t_vis = t - delay
    w = (s <= t_vis).astype(jnp.float32)
    if fcfg.gamma < 1.0:
        age = jnp.maximum(t_vis - s, 0).astype(jnp.float32)
        w = w * jnp.float32(fcfg.gamma) ** age
    if fcfg.window > 0:
        w = w * (s > t_vis - fcfg.window).astype(jnp.float32)
    return w


def _rebuild_impl(params, tables, env_idx, action_buf, w_buf,
                  cfg: UN.UtilityNetConfig, ridge_lambda0, row_w=None):
    """Recompute g for every buffered pair with the fresh net; one masked
    full-capacity pass (unwritten/padded rows have w=0 and vanish from
    A = lambda0 I + sum w_i g_i g_i^T), then one Cholesky solve.
    ``row_w`` (T,) optionally reweights whole slices — the forgetting /
    delayed-visibility hook (:func:`_slice_weights`)."""
    if row_w is not None:
        w_buf = w_buf * row_w[:, None]
    sid = env_idx.reshape(-1)
    a = action_buf.reshape(-1)
    w = w_buf.reshape(-1)
    _, h, _ = UN.utilitynet_apply(
        params, tables["x_emb"][sid], tables["x_feat"][sid],
        tables["domain"][sid], a)
    return NU.rebuild_ainv(NU.augment(h), ridge_lambda0, weights=w)


_nucb_rebuild = jax.jit(_rebuild_impl, static_argnames=("cfg",))


# ------------------------------------------------ single-dispatch scan -----
def _scan_xs(env: DeviceReplayEnv) -> Dict[str, jnp.ndarray]:
    return env.slice_xs()


def _cum_valid(env: DeviceReplayEnv) -> jnp.ndarray:
    """(T+1,) int32 cumulative VALID sample counts: cum0[t+1] = number of
    real (unpadded) samples in slices 0..t — the searchsorted table for
    :func:`_sample_valid` and the training-budget base."""
    sizes = np.asarray(env.slice_sizes, np.int64)
    return jnp.asarray(np.concatenate([[0], np.cumsum(sizes)]), jnp.int32)


def neuralucb_train_schedule(env: DeviceReplayEnv, epochs: int = 5,
                             batch_size: int = 256,
                             max_slices: Optional[int] = None) -> int:
    """Fixed per-slice SGD budget (steps) for the scanned runner.

    The host-stepped growing schedule spends ``epochs * (seen_t //
    batch)`` steps after slice t (rounded up to TRAIN_CHUNK dispatches);
    the scan needs ONE static budget for every slice, so we spread the
    growing schedule's total chunk count evenly (rounded up) — same total
    compute to within T chunks, uniform trace.
    """
    sizes = np.asarray(env.slice_sizes, np.int64)
    if max_slices is not None:
        sizes = sizes[:max_slices]
    seen = np.cumsum(sizes)
    chunks = [-(-int(epochs * (s // batch_size)) // TRAIN_CHUNK)
              for s in seen]
    per_slice = max(1, -(-sum(chunks) // len(chunks)))
    return per_slice * TRAIN_CHUNK


def _init_state(key, cfg: UN.UtilityNetConfig, T: int, S: int,
                ridge_lambda0) -> NeuralUCBState:
    """One key split feeds BOTH the network init and the run stream —
    split[0] -> init, split[1] -> exploration/training draws. (The PR-1
    runner fed PRNGKey(seed) to both, correlating warm-slice exploration
    with the weight init; the host router uses seed and seed+1.)"""
    k_init, key = jax.random.split(key)
    params = UN.init_utilitynet(k_init, cfg)
    return NeuralUCBState(
        params=params,
        opt=adamw_init(params),
        ainv=NU.init_ainv(cfg.ucb_feature_dim, ridge_lambda0),
        bufs={
            "action": jnp.zeros((T, S), jnp.int32),
            "reward": jnp.zeros((T, S), jnp.float32),
            "gate_label": jnp.zeros((T, S), jnp.float32),
            "w": jnp.zeros((T, S), jnp.float32),
            "gate_w": jnp.zeros((T, S), jnp.float32),
        },
        key=key)


def _nucb_slice_full(state: NeuralUCBState, x, tables, env_idx, cum0,
                     hyp: NeuralUCBHypers, cfg: UN.UtilityNetConfig,
                     backend: str, train_chunks: int, batch_size: int,
                     scn: Optional[ScenarioTables] = None, delay: int = 0,
                     fcfg: ForgettingConfig = VANILLA_FORGETTING):
    """One whole slice of Algorithm 1 (DECIDE → UPDATE → TRAIN → REBUILD)
    as a pure scan body. Key discipline mirrors the host-stepped runner
    exactly (one split per slice step, one per training chunk) so both
    paths consume identical PRNG streams. ``scn`` applies the scenario
    engine's per-slice transforms; ``delay`` (static) lags learning
    visibility by d slices; ``fcfg`` (static) selects the forgetting
    variant — all three default to the PR-2 stationary path, bit-exact.
    """
    params, opt, ainv, bufs, key = state
    t, idx, mask = x["t"], x["idx"], x["mask"]
    key, k_slice = jax.random.split(key)
    lam = jnp.where(hyp.cost_lambda >= 0, jnp.abs(hyp.cost_lambda),
                    tables["env_lambda"])
    eff = _effective_slice(tables, scn, t, idx, lam)
    batch = _context(tables, idx)
    avail = None if eff is None else eff["avail"]
    a, g, mu_safe, gs = jax.lax.cond(
        t == 0,
        lambda: _decide_warm(params, batch, k_slice, cfg, avail),
        lambda: _decide_ucb(params, ainv, batch, hyp.beta, hyp.tau_g,
                            cfg, backend, avail))
    ainv, bufs, metrics = _post_decide(
        ainv, tables, eff, bufs, t, idx, mask, a, g, mu_safe, gs,
        hyp.gate_margin, update_ainv=(delay == 0))
    t_vis = t - delay
    if delay > 0:
        # the online rank-k update applies the slice that just became
        # visible (t - delay), its features recomputed with current params
        tv = jnp.maximum(t_vis, 0)
        vid = env_idx[tv]
        _, h, _ = UN.utilitynet_apply(
            params, tables["x_emb"][vid], tables["x_feat"][vid],
            tables["domain"][vid], bufs["action"][tv])
        vw = bufs["w"][tv] * (t_vis >= 0).astype(jnp.float32)
        ainv = NU.woodbury_update(ainv, NU.augment(h) * vw[:, None])
    count = cum0[jnp.clip(t + 1 - delay, 0, cum0.shape[0] - 1)]

    def chunk(carry, _):
        params, opt, key = carry
        key, kc = jax.random.split(key)
        params, opt = _train_chunk(
            params, opt, tables, env_idx, bufs, kc, cum0, count, hyp.lr,
            cfg, TRAIN_CHUNK, batch_size, t_vis, fcfg, delay > 0)
        return (params, opt, key), None

    (params, opt, key), _ = jax.lax.scan(
        chunk, (params, opt, key), None, length=train_chunks)
    row_w = None
    if delay > 0 or not fcfg.is_vanilla:
        row_w = _slice_weights(env_idx.shape[0], t, delay, fcfg)
    ainv = _rebuild_impl(params, tables, env_idx, bufs["action"],
                         bufs["w"], cfg, hyp.ridge_lambda0, row_w)
    return NeuralUCBState(params, opt, ainv, bufs, key), metrics


def _nucb_scan_impl(tables, xs, env_idx, cum0, key, hyp: NeuralUCBHypers,
                    cfg: UN.UtilityNetConfig, backend: str,
                    train_chunks: int, batch_size: int,
                    scn: Optional[ScenarioTables] = None, delay: int = 0,
                    fcfg: ForgettingConfig = VANILLA_FORGETTING):
    T, S = env_idx.shape
    if scn is None:
        # stationary: pre-derive the whole reward table once per run;
        # scenario runs re-derive per slice inside _effective_slice
        tables = _apply_cost_lambda(tables, hyp.cost_lambda)
    state = _init_state(key, cfg, T, S, hyp.ridge_lambda0)

    def step(carry, x):
        return _nucb_slice_full(carry, x, tables, env_idx, cum0, hyp,
                                cfg, backend, train_chunks, batch_size,
                                scn, delay, fcfg)

    return jax.lax.scan(step, state, xs)


_nucb_scan = jax.jit(
    _nucb_scan_impl,
    static_argnames=("cfg", "backend", "train_chunks", "batch_size",
                     "delay", "fcfg"))


@functools.partial(
    jax.jit, static_argnames=("cfg", "backend", "train_chunks",
                              "batch_size", "delay", "fcfg"))
def _nucb_sweep_scan(tables, xs, env_idx, cum0, keys,
                     hyp: NeuralUCBHypers, cfg: UN.UtilityNetConfig,
                     backend: str, train_chunks: int, batch_size: int,
                     scn: Optional[ScenarioTables] = None, delay: int = 0,
                     fcfg: ForgettingConfig = VANILLA_FORGETTING):
    """One flat vmap over (grid x seed) lanes — ``keys`` (L, 2) and every
    ``hyp`` leaf (L,) are pre-flattened by the caller, which reshapes the
    (L, T, ...) metrics back to (G, n_seeds, T, ...). A single batching
    axis compiles to markedly better CPU code than nested grid/seed
    vmaps, and gives the device sharding one unambiguous axis. Scenario
    transforms are broadcast, not vmapped: every lane replays the same
    drift (one resident copy of the (T, K) transform tables)."""
    def one(k, h):
        return _nucb_scan_impl(tables, xs, env_idx, cum0, k, h, cfg,
                               backend, train_chunks, batch_size,
                               scn, delay, fcfg)[1]

    return jax.vmap(one)(keys, hyp)


def _hypers(beta, tau_g, gate_margin, lr, ridge_lambda0,
            cost_lambda) -> NeuralUCBHypers:
    f = jnp.float32
    return NeuralUCBHypers(
        beta=f(beta), tau_g=f(tau_g), gate_margin=f(gate_margin), lr=f(lr),
        ridge_lambda0=f(ridge_lambda0),
        cost_lambda=f(-1.0 if cost_lambda is None else cost_lambda))


def run_neuralucb_device(env: DeviceReplayEnv, cfg: UN.UtilityNetConfig, *,
                         seed: int = 0, epochs: int = 5,
                         train_steps: Optional[int] = None,
                         beta: float = 1.0, tau_g: float = 0.5,
                         ridge_lambda0: float = 1.0, lr: float = 1e-3,
                         gate_margin: float = 0.05, batch_size: int = 256,
                         cost_lambda: Optional[float] = None,
                         ucb_backend: Optional[str] = None,
                         scenario=None,
                         forgetting: ForgettingConfig = VANILLA_FORGETTING,
                         return_state: bool = False):
    """Algorithm 1 end to end as ONE device dispatch (DESIGN.md §8.4).

    ``train_steps`` is the fixed per-slice SGD budget (rounded up to a
    TRAIN_CHUNK multiple); when omitted it is derived from ``epochs`` via
    :func:`neuralucb_train_schedule` to match the stepped runner's total
    budget. ``scenario`` (name | Scenario | None) applies the DESIGN.md
    §9 non-stationary transforms inside the same single scan;
    ``forgetting`` selects the adaptivity variant (§9.2). Returns the
    ``run_protocol`` per-policy result dict; with ``return_state=True``
    also the final :class:`NeuralUCBState`.
    """
    backend = ucb_backend or default_ucb_backend()
    env, scn, delay = resolve_scenario(env, scenario)
    if train_steps is None:
        train_steps = neuralucb_train_schedule(env, epochs, batch_size)
    chunks = -(-int(train_steps) // TRAIN_CHUNK)
    hyp = _hypers(beta, tau_g, gate_margin, lr, ridge_lambda0, cost_lambda)
    t0 = time.perf_counter()
    state, ms = _nucb_scan(_tables(env), _scan_xs(env), env.idx,
                           _cum_valid(env), jax.random.PRNGKey(seed), hyp,
                           cfg, backend, chunks, batch_size,
                           scn, delay, forgetting)
    jax.block_until_ready(ms)
    res = _metrics_to_results({k: np.asarray(v) for k, v in ms.items()},
                              time.perf_counter() - t0)
    return (res, state) if return_state else res


def run_neuralucb_sweep(env: DeviceReplayEnv, cfg: UN.UtilityNetConfig, *,
                        seeds: Sequence[int], betas=(1.0,), tau_gs=(0.5,),
                        cost_lambdas=(None,), epochs: int = 5,
                        train_steps: Optional[int] = None,
                        ridge_lambda0: float = 1.0, lr: float = 1e-3,
                        gate_margin: float = 0.05, batch_size: int = 256,
                        ucb_backend: str = "jnp", scenario=None,
                        forgetting: ForgettingConfig = VANILLA_FORGETTING
                        ) -> Dict[str, np.ndarray]:
    """Multi-seed, multi-hyper NeuralUCB sweep as one dispatch.

    The hyper grid is the cartesian product ``betas x tau_gs x
    cost_lambdas`` (G points, ``itertools.product`` order, recorded in the
    returned ``beta`` / ``tau_g`` / ``cost_lambda`` arrays); metric leaves
    come back with shape (G, n_seeds, T, ...). The flattened (grid x
    seed) lane axis is sharded across local devices when more than one is
    present. The default UCB backend is the portable jnp path — the
    Pallas kernel is the single-run serving path and is not batched under
    the sweep vmap.
    """
    seeds = list(seeds)
    env, scn, delay = resolve_scenario(env, scenario)
    if train_steps is None:
        train_steps = neuralucb_train_schedule(env, epochs, batch_size)
    chunks = -(-int(train_steps) // TRAIN_CHUNK)
    grid = list(itertools.product(betas, tau_gs, cost_lambdas))
    G, n_seeds = len(grid), len(seeds)
    f = functools.partial(jnp.asarray, dtype=jnp.float32)
    # flatten (grid x seed) into one lane axis: lane l = (g, s) with
    # g = l // n_seeds, s = l % n_seeds — one vmap, one shardable axis
    L = G * n_seeds
    rep = functools.partial(jnp.repeat, repeats=n_seeds)
    hyp = NeuralUCBHypers(
        beta=rep(f([b for b, _, _ in grid])),
        tau_g=rep(f([t for _, t, _ in grid])),
        gate_margin=jnp.full((L,), gate_margin, jnp.float32),
        lr=jnp.full((L,), lr, jnp.float32),
        ridge_lambda0=jnp.full((L,), ridge_lambda0, jnp.float32),
        cost_lambda=rep(f([-1.0 if l is None else l for _, _, l in grid])))
    keys = jnp.tile(
        jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds]), (G, 1))
    keys, hyp = shard_sweep_axis((keys, hyp), L)
    ms = _nucb_sweep_scan(_tables(env), _scan_xs(env), env.idx,
                          _cum_valid(env), keys, hyp, cfg, ucb_backend,
                          chunks, batch_size, scn, delay, forgetting)
    out = {k: np.asarray(v).reshape((G, n_seeds) + v.shape[1:])
           for k, v in ms.items()}
    out["beta"] = np.asarray([b for b, _, _ in grid], np.float32)
    out["tau_g"] = np.asarray([t for _, t, _ in grid], np.float32)
    out["cost_lambda"] = np.asarray(
        [np.nan if l is None else l for _, _, l in grid], np.float32)
    out["seeds"] = np.asarray(list(seeds))
    out["train_steps"] = np.asarray(chunks * TRAIN_CHUNK)
    return out


def sweep_point_results(sweep: Dict[str, np.ndarray], g: int,
                        s: int) -> Dict:
    """Extract one (grid point, seed) run from a sweep as a
    ``run_protocol`` per-policy result dict, so sweep cells feed
    ``repro.core.protocol.summarize`` unchanged."""
    cum = np.cumsum(np.asarray(sweep["sum_reward"][g, s], np.float64))
    T = len(cum)
    return {
        "avg_reward": [float(v) for v in sweep["avg_reward"][g, s]],
        "cum_reward": [float(v) for v in cum],
        "avg_cost": [float(v) for v in sweep["avg_cost"][g, s]],
        "avg_quality": [float(v) for v in sweep["avg_quality"][g, s]],
        "oracle_avg_reward": [float(v)
                              for v in sweep["oracle_avg_reward"][g, s]],
        "action_hist": np.asarray(sweep["action_hist"][g, s]),
        "wall_s": [0.0] * T,
    }


class DeviceNeuralUCB:
    """Host-stepped NeuralUCB protocol runner (paper Algorithm 1).

    Same hyperparameters as :class:`repro.core.policy.NeuralUCBRouter`;
    the replay buffer is (T, S) device arrays of outcomes keyed by the
    env's slice-index matrix, so buffered contexts are looked up from the
    resident tables instead of being copied.

    This is the parity reference for the single-dispatch scanned path
    (:func:`run_neuralucb_device`): ~ceil(steps/TRAIN_CHUNK)+2 dispatches
    and one sync per slice, identical math. ``run()`` delegates to the
    scanned path when the schedule allows (fixed ``train_steps``, full
    stream, fresh state); pass ``scan=False`` to force stepping."""

    def __init__(self, env: DeviceReplayEnv, cfg: UN.UtilityNetConfig, *,
                 seed: int = 0, beta: float = 1.0, tau_g: float = 0.5,
                 ridge_lambda0: float = 1.0, lr: float = 1e-3,
                 gate_margin: float = 0.05, batch_size: int = 256,
                 ucb_backend: Optional[str] = None,
                 forgetting: ForgettingConfig = VANILLA_FORGETTING):
        self.env = env
        self.cfg = cfg
        self.seed = seed
        self.beta = beta
        self.tau_g = tau_g
        self.ridge_lambda0 = ridge_lambda0
        self.lr = lr
        self.gate_margin = gate_margin
        self.batch_size = batch_size
        self.forgetting = forgetting
        self.ucb_backend = ucb_backend or default_ucb_backend()
        T, S = env.idx.shape
        # same split discipline as the scanned _init_state: split[0] ->
        # network init, split[1] -> run stream (the PR-1 runner fed
        # PRNGKey(seed) to both, correlating warm-slice exploration with
        # the weight init)
        state = _init_state(jax.random.PRNGKey(seed), cfg, T, S,
                            ridge_lambda0)
        self.params, self.opt = state.params, state.opt
        self.ainv, self.bufs, self.key = state.ainv, state.bufs, state.key
        self._cum0 = _cum_valid(env)
        self._stepped = False   # True once run() has mutated state host-side

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def run(self, *, epochs: int = 5, verbose: bool = False,
            max_slices: Optional[int] = None,
            train_steps: Optional[int] = None, scan="auto") -> Dict:
        """Run Algorithm 1 end to end; returns the ``run_protocol``
        per-policy result dict (summarize-compatible).

        ``train_steps`` fixes the per-slice SGD budget (scanned-runner
        schedule); without it the budget grows with the buffer
        (``epochs * (seen // batch)``, the seed-loop schedule), which only
        the stepped path can express. ``scan="auto"`` delegates to the
        single-dispatch scanned runner whenever the schedule allows —
        fixed ``train_steps``, full stream, state untouched by a previous
        stepped run; ``scan=False`` forces stepping (parity reference)."""
        can_scan = (train_steps is not None and max_slices is None
                    and not self._stepped)
        if scan is True and not can_scan:
            raise ValueError(
                "scan=True requires a fixed train_steps schedule, "
                "max_slices=None, and state untouched by a stepped run")
        if scan is not False and can_scan:
            return self._run_scanned(train_steps, verbose)
        return self._run_stepped(epochs=epochs, verbose=verbose,
                                 max_slices=max_slices,
                                 train_steps=train_steps)

    def _run_scanned(self, train_steps: int, verbose: bool) -> Dict:
        res, state = run_neuralucb_device(
            self.env, self.cfg, seed=self.seed, train_steps=train_steps,
            beta=self.beta, tau_g=self.tau_g,
            ridge_lambda0=self.ridge_lambda0, lr=self.lr,
            gate_margin=self.gate_margin, batch_size=self.batch_size,
            ucb_backend=self.ucb_backend, forgetting=self.forgetting,
            return_state=True)
        self.params, self.opt = state.params, state.opt
        self.ainv, self.bufs, self.key = state.ainv, state.bufs, state.key
        self._stepped = True
        if verbose:
            T = len(res["avg_reward"])
            for t, v in enumerate(res["avg_reward"]):
                print(f"[sim slice {t + 1:2d}/{T}] avg_reward={v:.3f}",
                      flush=True)
        return res

    def _run_stepped(self, *, epochs: int, verbose: bool,
                     max_slices: Optional[int],
                     train_steps: Optional[int]) -> Dict:
        env = self.env
        self._stepped = True
        tables = _tables(env)
        T = env.n_slices if max_slices is None else min(env.n_slices,
                                                        max_slices)
        per_slice = []
        wall = []
        for t in range(T):
            t0 = time.perf_counter()
            self.ainv, self.bufs, m = _nucb_slice_step(
                self.params, self.ainv, tables, self.bufs,
                jnp.int32(t), env.idx[t], env.mask[t], self._next_key(),
                jnp.float32(self.beta), jnp.float32(self.tau_g),
                jnp.float32(self.gate_margin),
                self.cfg, self.ucb_backend, t == 0)
            # valid samples observed so far — the sampling range AND the
            # growing-schedule budget base (was the padded (t+1)*S range)
            count = self._cum0[t + 1]
            if train_steps is not None:
                num_steps = int(train_steps)
            else:
                num_steps = epochs * (int(count) // self.batch_size)
            # round the step budget up to TRAIN_CHUNK-sized dispatches:
            # as a static jit arg each distinct value would recompile the
            # whole training scan
            for _ in range(-(-num_steps // TRAIN_CHUNK)):
                self.params, self.opt = _nucb_train(
                    self.params, self.opt, tables, env.idx, self.bufs,
                    self._next_key(), self._cum0, count,
                    jnp.float32(self.lr), self.cfg, TRAIN_CHUNK,
                    self.batch_size, jnp.int32(t), self.forgetting, False)
            row_w = None if self.forgetting.is_vanilla else _slice_weights(
                env.idx.shape[0], jnp.int32(t), 0, self.forgetting)
            self.ainv = _nucb_rebuild(
                self.params, tables, env.idx, self.bufs["action"],
                self.bufs["w"], self.cfg, jnp.float32(self.ridge_lambda0),
                row_w)
            jax.block_until_ready(self.ainv)
            per_slice.append(m)
            wall.append(time.perf_counter() - t0)
            if verbose:
                print(f"[sim slice {t + 1:2d}/{T}] "
                      f"avg_reward={float(m['avg_reward']):.3f}", flush=True)
        ms = {k: np.stack([np.asarray(m[k]) for m in per_slice])
              for k in per_slice[0]}
        out = _metrics_to_results(ms, sum(wall))
        out["wall_s"] = wall
        return out


def run_protocol_device(env: DeviceReplayEnv,
                        policies: Dict[str, DevicePolicy], *,
                        neuralucb: Optional[DeviceNeuralUCB] = None,
                        epochs: int = 5, seed: int = 0,
                        verbose: bool = False,
                        scenario=None) -> Dict[str, Dict]:
    """Drop-in device-resident counterpart of
    ``repro.core.protocol.run_protocol``: every policy replays the same
    slice stream (and the same scenario drift, when one is named);
    results feed ``repro.core.protocol.summarize``.

    Scheduling caveat: with ``scenario=None`` the NeuralUCB leg is
    ``neuralucb.run(epochs=...)`` — the stepped growing schedule (or its
    scan delegation). With a scenario — INCLUDING the named
    ``"stationary"`` — it is the scanned runner with the fixed
    epochs-derived schedule (a scan cannot express a growing budget,
    DESIGN.md §8.4), so the two calls are not sample-identical; the
    byte-identical stationary contract holds at the
    ``run_neuralucb_device`` / ``run_baseline_device`` level."""
    results = {}
    if neuralucb is not None:
        if scenario is not None:
            results["neuralucb"] = run_neuralucb_device(
                env, neuralucb.cfg, seed=neuralucb.seed,
                epochs=epochs, beta=neuralucb.beta, tau_g=neuralucb.tau_g,
                ridge_lambda0=neuralucb.ridge_lambda0, lr=neuralucb.lr,
                gate_margin=neuralucb.gate_margin,
                batch_size=neuralucb.batch_size,
                ucb_backend=neuralucb.ucb_backend,
                forgetting=neuralucb.forgetting, scenario=scenario)
            if verbose:
                r = results["neuralucb"]["avg_reward"]
                name = getattr(scenario, "name", scenario)
                print(f"[sim] neuralucb ({name}): avg_reward="
                      f"{np.mean(r):.3f}", flush=True)
        else:
            results["neuralucb"] = neuralucb.run(epochs=epochs,
                                                 verbose=verbose)
    for name, pol in policies.items():
        results[name] = run_baseline_device(env, pol, seed=seed,
                                            scenario=scenario)
        if verbose:
            print(f"[sim] {name}: avg_reward="
                  f"{np.mean(results[name]['avg_reward']):.3f}", flush=True)
    return results
