"""Vectorized online protocol engine (DESIGN.md §8).

Three runners over a :class:`repro.sim.env.DeviceReplayEnv`:

* :func:`run_baseline_device` — a full T-slice protocol run of one
  stateless baseline as a single jitted ``lax.scan`` (one device dispatch
  for the whole run, vs. the seed host loop's T × policies round-trips).
* :func:`run_baseline_sweep` — the same scan ``vmap``-ed over PRNG keys
  for multi-seed sweeps.
* :class:`DeviceNeuralUCB` — Algorithm 1 with the whole slice's
  DECIDE → feedback-lookup → UPDATE fused into one jit call; replay
  training is a ``lax.scan`` over uniformly-sampled minibatches and the
  A^-1 rebuild is a single masked full-capacity pass (both stay on
  device; only per-slice scalar metrics ever reach the host).

Differences vs. the seed host loop (``repro.core.protocol.run_protocol``),
see DESIGN.md §8.3: the random baseline and warm-slice exploration draw
from the jax PRNG (numpy's in the seed), and replay training samples
minibatches with replacement (permutation epochs in the seed). Policies
that are deterministic given the reward stream (fixed arms, greedy) are
bit-compatible — asserted by tests/test_sim_engine.py.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import neuralucb as NU
from repro.core import utilitynet as UN
from repro.core.policy import default_ucb_backend
from repro.kernels.ucb_score.ops import ucb_score
from repro.sim.env import DeviceReplayEnv
from repro.sim.policies import DevicePolicy
from repro.training.optim import adamw_init, adamw_update, clip_by_global_norm


def _tables(env: DeviceReplayEnv) -> Dict[str, jnp.ndarray]:
    return {"x_emb": env.x_emb, "x_feat": env.x_feat, "domain": env.domain,
            "quality": env.quality, "cost": env.cost, "reward": env.reward}


def _context(tables, idx):
    return {"x_emb": tables["x_emb"][idx], "x_feat": tables["x_feat"][idx],
            "domain": tables["domain"][idx]}


def _slice_metrics(tables, idx, mask, actions):
    denom = jnp.maximum(mask.sum(), 1.0)
    r = tables["reward"][idx, actions] * mask
    q = tables["quality"][idx, actions] * mask
    c = tables["cost"][idx, actions] * mask
    K = tables["reward"].shape[1]
    hist = (jax.nn.one_hot(actions, K, dtype=jnp.float32)
            * mask[:, None]).sum(axis=0)
    return {"sum_reward": r.sum(), "avg_reward": r.sum() / denom,
            "avg_cost": c.sum() / denom, "avg_quality": q.sum() / denom,
            "action_hist": hist}


def _metrics_to_results(ms: Dict[str, np.ndarray], wall_s: float) -> Dict:
    """Convert stacked per-slice device metrics to the
    ``core.protocol.run_protocol`` per-policy result format."""
    T = len(ms["avg_reward"])
    cum = np.cumsum(np.asarray(ms["sum_reward"], np.float64))
    return {
        "avg_reward": [float(v) for v in ms["avg_reward"]],
        "cum_reward": [float(v) for v in cum],
        "avg_cost": [float(v) for v in ms["avg_cost"]],
        "avg_quality": [float(v) for v in ms["avg_quality"]],
        "action_hist": np.asarray(ms["action_hist"]),
        "wall_s": [wall_s / T] * T,
    }


# --------------------------------------------------------------- baselines --
def _baseline_scan_impl(tables, xs, key, policy: DevicePolicy):
    state = policy.init(key)

    def step(carry, x):
        state, key = carry
        key, kd = jax.random.split(key)
        idx, mask = x["idx"], x["mask"]
        batch = _context(tables, idx)
        a = policy.decide(state, kd, batch)
        m = _slice_metrics(tables, idx, mask, a)
        state = policy.update(state, batch, a, tables["reward"][idx, a], mask)
        return (state, key), m

    _, ms = jax.lax.scan(step, (state, key), xs)
    return ms


_baseline_scan = jax.jit(_baseline_scan_impl, static_argnames=("policy",))


@functools.partial(jax.jit, static_argnames=("policy",))
def _baseline_sweep_scan(tables, xs, keys, policy: DevicePolicy):
    """The full T-slice scan vmapped over PRNG keys, compiled as one unit
    so repeated sweeps are a single cached dispatch."""
    return jax.vmap(
        lambda k: _baseline_scan_impl(tables, xs, k, policy))(keys)


def run_baseline_device(env: DeviceReplayEnv, policy: DevicePolicy, *,
                        seed: int = 0) -> Dict:
    """One policy, all T slices, one device dispatch. Returns the
    ``run_protocol`` per-policy result dict (summarize-compatible)."""
    t0 = time.perf_counter()
    ms = jax.block_until_ready(_baseline_scan(
        _tables(env), env.slice_xs(), jax.random.PRNGKey(seed), policy))
    return _metrics_to_results(ms, time.perf_counter() - t0)


def run_baseline_sweep(env: DeviceReplayEnv, policy: DevicePolicy,
                       seeds) -> Dict[str, np.ndarray]:
    """Multi-seed sweep: vmap the whole T-slice scan over PRNG keys.

    Returns stacked raw metrics with a leading seed axis, e.g.
    ``out["avg_reward"]`` has shape (n_seeds, T)."""
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    ms = _baseline_sweep_scan(_tables(env), env.slice_xs(), keys, policy)
    return {k: np.asarray(v) for k, v in ms.items()}


# --------------------------------------------------------------- neuralucb --
def _weighted_loss(params, cfg: UN.UtilityNetConfig, batch):
    """Replay loss with per-row validity weights (padded rows carry w=0)."""
    mu, _, gate_p = UN.utilitynet_apply(
        params, batch["x_emb"], batch["x_feat"], batch["domain"],
        batch["action"])
    w = batch["w"]
    l_u = (UN.huber(mu, batch["reward"], cfg.huber_delta) * w
           ).sum() / jnp.maximum(w.sum(), 1.0)
    p = jnp.clip(gate_p, 1e-6, 1 - 1e-6)
    y = batch["gate_label"]
    bce = -(y * jnp.log(p) + (1 - y) * jnp.log(1 - p))
    gw = batch["gate_w"]
    l_g = (bce * gw).sum() / jnp.maximum(gw.sum(), 1.0)
    return l_u + 0.5 * l_g, {"loss_u": l_u, "loss_gate": l_g}


@functools.partial(jax.jit, static_argnames=("cfg", "backend", "warm"))
def _nucb_slice_step(params, ainv, tables, bufs, t, idx, mask, key,
                     beta, tau_g, gate_margin,
                     cfg: UN.UtilityNetConfig, backend: str, warm: bool):
    """DECIDE -> feedback lookup -> buffer write -> rank-k UPDATE, fused."""
    batch = _context(tables, idx)
    B = idx.shape[0]
    if warm:
        a = jax.random.randint(key, (B,), 0, cfg.num_actions, jnp.int32)
        _, h, _ = UN.utilitynet_apply(
            params, batch["x_emb"], batch["x_feat"], batch["domain"], a)
        g = NU.augment(h)
        mu_safe = jnp.zeros((B,), jnp.float32)
    else:
        mu, h, gate_p = UN.utilitynet_all_actions(
            params, cfg, batch["x_emb"], batch["x_feat"], batch["domain"])
        g_all = NU.augment(h)                                  # (B, K, F)
        if backend == "pallas":
            interpret = jax.default_backend() != "tpu"
            scores = ucb_score(g_all, ainv, mu, beta, interpret=interpret)
        else:
            scores = mu + beta * NU.ucb_bonus(ainv, g_all)
        a_ucb = jnp.argmax(scores, axis=-1)
        a_safe = jnp.argmax(mu, axis=-1)
        a = jnp.where(gate_p >= tau_g, a_ucb, a_safe).astype(jnp.int32)
        g = jnp.take_along_axis(
            g_all, a[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        mu_safe = jnp.take_along_axis(mu, a_safe[:, None], axis=1)[:, 0]

    r = tables["reward"][idx, a]
    gate_label = (r < mu_safe - gate_margin).astype(jnp.float32)
    gate_w = jnp.zeros_like(mask) if warm else mask

    bufs = {
        "action": bufs["action"].at[t].set(a),
        "reward": bufs["reward"].at[t].set(r),
        "gate_label": bufs["gate_label"].at[t].set(gate_label),
        "w": bufs["w"].at[t].set(mask),
        "gate_w": bufs["gate_w"].at[t].set(gate_w),
    }
    # padded rows are zeroed -> contribute nothing to the rank-k update
    ainv = NU.woodbury_update(ainv, g * mask[:, None])
    metrics = _slice_metrics(tables, idx, mask, a)
    return ainv, bufs, metrics


# SGD steps per compiled training dispatch. The per-slice step budget is
# rounded UP to a multiple of this, so the scan compiles exactly once for
# the whole run instead of once per distinct per-slice step count.
TRAIN_CHUNK = 32


@functools.partial(jax.jit,
                   static_argnames=("cfg", "num_steps", "batch_size"))
def _nucb_train(params, opt, tables, env_idx, bufs, key, count, lr,
                cfg: UN.UtilityNetConfig, num_steps: int, batch_size: int):
    """``num_steps`` SGD steps on uniformly-sampled replay minibatches,
    all on device. ``count`` (traced) bounds the flat sample range; padded
    rows are neutralized by their w=0 weights."""
    S = env_idx.shape[1]

    def step(carry, k):
        params, opt = carry
        flat = jax.random.randint(k, (batch_size,), 0, count)
        row, col = flat // S, flat % S
        sid = env_idx[row, col]
        batch = {
            "x_emb": tables["x_emb"][sid],
            "x_feat": tables["x_feat"][sid],
            "domain": tables["domain"][sid],
            "action": bufs["action"][row, col],
            "reward": bufs["reward"][row, col],
            "gate_label": bufs["gate_label"][row, col],
            "w": bufs["w"][row, col],
            "gate_w": bufs["gate_w"][row, col],
        }
        (_, _), grads = jax.value_and_grad(
            _weighted_loss, has_aux=True)(params, cfg, batch)
        grads, _ = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_update(grads, opt, params, lr=lr,
                                   weight_decay=1e-4)
        return (params, opt), None

    (params, opt), _ = jax.lax.scan(
        step, (params, opt), jax.random.split(key, num_steps))
    return params, opt


@functools.partial(jax.jit, static_argnames=("cfg",))
def _nucb_rebuild(params, tables, env_idx, action_buf, w_buf,
                  cfg: UN.UtilityNetConfig, ridge_lambda0):
    """Recompute g for every buffered pair with the fresh net; one masked
    full-capacity pass (unwritten/padded rows have w=0 and vanish from
    A = lambda0 I + sum w_i g_i g_i^T), then one Cholesky solve."""
    sid = env_idx.reshape(-1)
    a = action_buf.reshape(-1)
    w = w_buf.reshape(-1)
    _, h, _ = UN.utilitynet_apply(
        params, tables["x_emb"][sid], tables["x_feat"][sid],
        tables["domain"][sid], a)
    g = NU.augment(h) * w[:, None]
    return NU.rebuild_ainv(g, ridge_lambda0)


class DeviceNeuralUCB:
    """Device-resident NeuralUCB protocol runner (paper Algorithm 1).

    Same hyperparameters as :class:`repro.core.policy.NeuralUCBRouter`;
    the replay buffer is (T, S) device arrays of outcomes keyed by the
    env's slice-index matrix, so buffered contexts are looked up from the
    resident tables instead of being copied."""

    def __init__(self, env: DeviceReplayEnv, cfg: UN.UtilityNetConfig, *,
                 seed: int = 0, beta: float = 1.0, tau_g: float = 0.5,
                 ridge_lambda0: float = 1.0, lr: float = 1e-3,
                 gate_margin: float = 0.05, batch_size: int = 256,
                 ucb_backend: Optional[str] = None):
        self.env = env
        self.cfg = cfg
        self.beta = beta
        self.tau_g = tau_g
        self.ridge_lambda0 = ridge_lambda0
        self.lr = lr
        self.gate_margin = gate_margin
        self.batch_size = batch_size
        self.ucb_backend = ucb_backend or default_ucb_backend()
        self.key = jax.random.PRNGKey(seed)
        self.params = UN.init_utilitynet(jax.random.PRNGKey(seed), cfg)
        self.opt = adamw_init(self.params)
        self.ainv = NU.init_ainv(cfg.ucb_feature_dim, ridge_lambda0)
        T, S = env.idx.shape
        self.bufs = {
            "action": jnp.zeros((T, S), jnp.int32),
            "reward": jnp.zeros((T, S), jnp.float32),
            "gate_label": jnp.zeros((T, S), jnp.float32),
            "w": jnp.zeros((T, S), jnp.float32),
            "gate_w": jnp.zeros((T, S), jnp.float32),
        }

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def run(self, *, epochs: int = 5, verbose: bool = False,
            max_slices: Optional[int] = None) -> Dict:
        """Run Algorithm 1 end to end; returns the ``run_protocol``
        per-policy result dict (summarize-compatible)."""
        env = self.env
        tables = _tables(env)
        T = env.n_slices if max_slices is None else min(env.n_slices,
                                                        max_slices)
        S = env.slice_width
        sizes = env.slice_sizes
        per_slice = []
        wall = []
        seen = 0
        for t in range(T):
            t0 = time.perf_counter()
            self.ainv, self.bufs, m = _nucb_slice_step(
                self.params, self.ainv, tables, self.bufs,
                jnp.int32(t), env.idx[t], env.mask[t], self._next_key(),
                jnp.float32(self.beta), jnp.float32(self.tau_g),
                jnp.float32(self.gate_margin),
                self.cfg, self.ucb_backend, t == 0)
            seen += int(sizes[t])
            # round the step budget up to TRAIN_CHUNK-sized dispatches:
            # num_steps grows every slice, and as a static jit arg each
            # distinct value would recompile the whole training scan
            num_steps = epochs * (seen // self.batch_size)
            for _ in range(-(-num_steps // TRAIN_CHUNK)):
                self.params, self.opt = _nucb_train(
                    self.params, self.opt, tables, env.idx, self.bufs,
                    self._next_key(), jnp.int32((t + 1) * S),
                    jnp.float32(self.lr), self.cfg, TRAIN_CHUNK,
                    self.batch_size)
            self.ainv = _nucb_rebuild(
                self.params, tables, env.idx, self.bufs["action"],
                self.bufs["w"], self.cfg, jnp.float32(self.ridge_lambda0))
            jax.block_until_ready(self.ainv)
            per_slice.append(m)
            wall.append(time.perf_counter() - t0)
            if verbose:
                print(f"[sim slice {t + 1:2d}/{T}] "
                      f"avg_reward={float(m['avg_reward']):.3f}", flush=True)
        ms = {k: np.stack([np.asarray(m[k]) for m in per_slice])
              for k in per_slice[0]}
        out = _metrics_to_results(ms, sum(wall))
        out["wall_s"] = wall
        return out


def run_protocol_device(env: DeviceReplayEnv,
                        policies: Dict[str, DevicePolicy], *,
                        neuralucb: Optional[DeviceNeuralUCB] = None,
                        epochs: int = 5, seed: int = 0,
                        verbose: bool = False) -> Dict[str, Dict]:
    """Drop-in device-resident counterpart of
    ``repro.core.protocol.run_protocol``: every policy replays the same
    slice stream; results feed ``repro.core.protocol.summarize``."""
    results = {}
    if neuralucb is not None:
        results["neuralucb"] = neuralucb.run(epochs=epochs, verbose=verbose)
    for name, pol in policies.items():
        results[name] = run_baseline_device(env, pol, seed=seed)
        if verbose:
            print(f"[sim] {name}: avg_reward="
                  f"{np.mean(results[name]['avg_reward']):.3f}", flush=True)
    return results
