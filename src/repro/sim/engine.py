"""Vectorized online protocol engine (DESIGN.md §8, §10).

ONE scan drives every policy: :func:`_policy_scan_impl` runs a full
T-slice protocol — DECIDE → feedback lookup → UPDATE → TRAIN → REBUILD —
for any :class:`repro.sim.policies.BanditPolicy` as a single jitted
``lax.scan`` (one device dispatch for the whole run), with scenarios
(DESIGN.md §9), ``ForgettingConfig`` adaptivity, delayed feedback, and
availability fallback threaded through the shared :class:`PolicyCtx`.

Public runners:

* :func:`run_policy_device` — one policy, all T slices, one dispatch.
* :func:`run_policy_sweep` — a POLICY AXIS of (grid × seed) lane vmaps:
  every policy's lanes are padded to a device-count multiple and
  sharded over a ("grid", "seed") mesh (``launch.mesh.make_sweep_mesh``
  + ``distributed.sharding.sweep_lane_layout``), and ALL policies
  execute inside one jitted dispatch, so a (policy × hypers × seed ×
  scenario) study is one compiled program per scenario.
* :func:`run_baseline_device` / :func:`run_baseline_sweep` — thin
  wrappers lifting legacy :class:`DevicePolicy` triples; the sweep now
  emits the same grid-annotated ``(G, n_seeds, T, ...)`` schema as
  every other policy.
* :func:`run_neuralucb_device` / :func:`run_neuralucb_sweep` — the
  paper's Algorithm 1 through the same runner (NeuralUCB is just the
  richest registered policy); bit-exact with the pre-unification scans
  (tests/test_golden.py).
* :class:`DeviceNeuralUCB` — the host-stepped runner (one fused jit call
  per slice phase), kept as the bit-exact parity reference; its
  ``run()`` delegates to the scanned path when the schedule allows.

Differences vs. the seed host loop (``repro.core.protocol.run_protocol``),
see DESIGN.md §8.3/§8.4: the random baseline and warm-slice exploration
draw from the jax PRNG (numpy's in the seed), and replay training samples
minibatches with replacement (permutation epochs in the seed). Policies
that are deterministic given the reward stream (fixed arms, greedy) are
bit-compatible — asserted by tests/test_sim_engine.py.
"""
from __future__ import annotations

import functools
import itertools
import math
import time
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import neuralucb as NU
from repro.core import utilitynet as UN
from repro.core.policy import default_ucb_backend
from repro.core.reward import normalize_cost
from repro.distributed.sharding import (
    pad_sweep_lanes,
    shard_sweep_axis,  # noqa: F401  (re-export: legacy callers import here)
    shard_sweep_lanes,
    sweep_lane_layout,
)
from repro.kernels.nucb_update import nucb_update
from repro.launch.mesh import make_sweep_mesh
from repro.sim.env import DeviceReplayEnv
from repro.sim.policies import (
    TRAIN_CHUNK,
    VANILLA_FORGETTING,
    BanditPolicy,
    DevicePolicy,
    ForgettingConfig,
    NeuralUCBHypers,
    NeuralUCBState,
    PolicyCtx,
    _decide_ucb,
    _decide_warm,
    _no_train,
    _rebuild_impl,
    _sample_valid,  # noqa: F401  (re-export: tests/benchmarks import here)
    _slice_weights,
    _train_chunk,
    as_bandit_policy,
    neural_init_state,
    neuralucb_policy,
)
from repro.sim.scenarios import ScenarioTables, resolve_scenario


def _tables(env: DeviceReplayEnv) -> Dict[str, jnp.ndarray]:
    """Resident replay tables. ``cnorm`` is the Eq.-1 normalized cost,
    carried so sweep harnesses can re-derive the reward table for any
    ``cost_lambda`` on device (baseline scans simply never read it);
    ``c_max`` / ``env_lambda`` / ``mean_cost`` feed the scenario
    engine's per-slice reward recompute and availability fallback."""
    return {"x_emb": env.x_emb, "x_feat": env.x_feat, "domain": env.domain,
            "quality": env.quality, "cost": env.cost, "reward": env.reward,
            "cnorm": normalize_cost(env.cost, env.cost.max()),
            "c_max": env.cost.max(),
            "env_lambda": jnp.float32(env.cost_lambda),
            "mean_cost": env.cost.mean(axis=0),
            "oracle_max": env.reward.max(axis=1)}


def _context(tables, idx):
    return {"x_emb": tables["x_emb"][idx], "x_feat": tables["x_feat"][idx],
            "domain": tables["domain"][idx]}


def _effective_slice(tables, scn: Optional[ScenarioTables], t, idx, lam):
    """Slice-t effective tables (DESIGN.md §9.1). With no scenario this
    is None — the metrics/feedback paths then use the PR-2 (S,)-gather
    fast path against the resident tables directly (materializing (S, K)
    temporaries per slice measurably regressed the vmapped sweep). With
    a scenario, the declarative per-slice transforms are applied to the
    gathered (S, K) rows and the Eq.-1 reward is re-derived on device
    with the env's stationary C_max (a shocked price may push the
    normalized cost past 1 — that is the point of a shock)."""
    if scn is None:
        return None
    q = jnp.clip(tables["quality"][idx] * scn.quality_mult[t]
                 + scn.quality_add[t], 0.0, 1.0)
    c = tables["cost"][idx] * scn.cost_mult[t]
    r = q * jnp.exp(-lam * normalize_cost(c, tables["c_max"]))
    return {"quality": q, "cost": c, "reward": r, "avail": scn.avail[t]}


def _avail_fallback(a, avail, mean_cost):
    """Engine-level failover for availability-unaware policies: a request
    routed to an unavailable arm falls back to the cheapest available
    arm (deterministic, like production failover to the budget tier)."""
    fb = jnp.argmin(jnp.where(avail > 0, mean_cost, jnp.inf)).astype(
        jnp.int32)
    return jnp.where(avail[a] > 0, a, fb).astype(jnp.int32)


def _pick(tables, eff, key, idx, actions):
    """Chosen-action values (S,): resident-table gather on the
    stationary fast path, effective-table gather under a scenario."""
    if eff is None:
        return tables[key][idx, actions]
    rows = jnp.arange(actions.shape[0], dtype=jnp.int32)
    return eff[key][rows, actions]


def _slice_metrics(tables, eff, idx, mask, actions):
    denom = jnp.maximum(mask.sum(), 1.0)
    r = _pick(tables, eff, "reward", idx, actions) * mask
    q = _pick(tables, eff, "quality", idx, actions) * mask
    c = _pick(tables, eff, "cost", idx, actions) * mask
    K = tables["reward"].shape[1]
    hist = (jax.nn.one_hot(actions, K, dtype=jnp.float32)
            * mask[:, None]).sum(axis=0)
    # dynamic oracle: best AVAILABLE arm per sample under the slice's
    # effective tables (the regret reference, §9.3); precomputed per
    # sample on the stationary path
    if eff is None:
        o = tables["oracle_max"][idx] * mask
    else:
        r_all = eff["reward"]
        if eff["avail"] is not None:
            r_all = jnp.where(eff["avail"] > 0, r_all, -1.0)
        o = r_all.max(axis=1) * mask
    return {"sum_reward": r.sum(), "avg_reward": r.sum() / denom,
            "avg_cost": c.sum() / denom, "avg_quality": q.sum() / denom,
            "action_hist": hist, "oracle_avg_reward": o.sum() / denom}


def _metrics_to_results(ms: Dict[str, np.ndarray], wall_s: float) -> Dict:
    """Convert stacked per-slice device metrics to the
    ``core.protocol.run_protocol`` per-policy result format."""
    T = len(ms["avg_reward"])
    cum = np.cumsum(np.asarray(ms["sum_reward"], np.float64))
    out = {
        "avg_reward": [float(v) for v in ms["avg_reward"]],
        "cum_reward": [float(v) for v in cum],
        "avg_cost": [float(v) for v in ms["avg_cost"]],
        "avg_quality": [float(v) for v in ms["avg_quality"]],
        "oracle_avg_reward": [float(v) for v in ms["oracle_avg_reward"]],
        "action_hist": np.asarray(ms["action_hist"]),
        "wall_s": [wall_s / T] * T,
    }
    if "mean_logp" in ms:
        out["mean_logp"] = [float(v) for v in ms["mean_logp"]]
    return out


def _resolve_lam(tables, hyp):
    """The Eq.-1 lambda driving a scenario's per-slice reward re-derive:
    policies that sweep ``cost_lambda`` (the neural hypers pytrees) use
    it when non-negative; everything else replays the env's own."""
    cl = getattr(hyp, "cost_lambda", None)
    if cl is None:
        return tables["env_lambda"]
    return jnp.where(cl >= 0, jnp.abs(cl), tables["env_lambda"])


# ----------------------------------------------- THE protocol scan (§10) --
def _policy_scan_impl(tables, xs, env_idx, cum0, key, hyp,
                      policy: BanditPolicy,
                      scn: Optional[ScenarioTables] = None, delay: int = 0,
                      fcfg: ForgettingConfig = VANILLA_FORGETTING,
                      train_chunks: int = 1, batch_size: int = 256,
                      init_state: Any = None, record_log: bool = False):
    """The single protocol scan driving every registered policy: one
    whole T-slice run as a pure ``lax.scan`` over (state, key). Key
    discipline is fixed by the runner — one split per slice feeds
    ``decide``; ``train`` splits further from the carried stream — so
    every policy (and the host-stepped NeuralUCB reference) consumes an
    identical PRNG stream for identical schedules.

    ``init_state`` injects a PRETRAINED state pytree (DESIGN.md §13.3):
    ``policy.init`` still runs — its key fold fixes the run stream, so a
    warm and a cold run differ only by state, never by PRNG — and its
    state is then replaced. ``record_log`` (static) additionally stacks
    the per-slice (action, log-propensity, realized reward) into the
    metrics pytree so the runner can shape a
    :class:`repro.data.logged.LoggedInteractions` from the run."""
    if scn is None:
        # stationary: pre-derive the whole reward table once per run;
        # scenario runs re-derive per slice inside _effective_slice
        tables = policy.prepare(tables, hyp)
    lam = _resolve_lam(tables, hyp)
    ctx0 = PolicyCtx(tables=tables, env_idx=env_idx, cum0=cum0, hyp=hyp,
                     eff=None, t=None, idx=None, mask=None, avail=None,
                     delay=delay, fcfg=fcfg, train_chunks=train_chunks,
                     batch_size=batch_size)
    state, key = policy.init(key, ctx0)
    if init_state is not None:
        state = init_state

    def step(carry, x):
        state, key = carry
        key, k_slice = jax.random.split(key)
        t, idx, mask = x["t"], x["idx"], x["mask"]
        eff = _effective_slice(tables, scn, t, idx, lam)
        batch = _context(tables, idx)
        avail = None if eff is None else eff["avail"]
        ctx = ctx0._replace(eff=eff, t=t, idx=idx, mask=mask, avail=avail)
        a, logp, aux = policy.decide(state, k_slice, batch, ctx)
        if not policy.availability_aware and avail is not None:
            a = _avail_fallback(a, avail, tables["mean_cost"])
        m = _slice_metrics(tables, eff, idx, mask, a)
        r = _pick(tables, eff, "reward", idx, a)
        m["mean_logp"] = (logp * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        if record_log:
            m["action"], m["logp"], m["reward"] = a, logp, r
        state = policy.update(state, batch, a, r, ctx, aux)
        state, key = policy.train(state, key, ctx)
        state = policy.rebuild(state, ctx)
        return (state, key), m

    return jax.lax.scan(step, (state, key), xs)


_STATIC = ("policy", "delay", "fcfg", "train_chunks", "batch_size",
           "record_log")

_policy_scan = jax.jit(_policy_scan_impl, static_argnames=_STATIC)


@functools.partial(
    jax.jit, static_argnames=("policies", "delay", "fcfg", "train_chunks",
                              "batch_size"))
def _policy_zoo_scan(tables, xs, env_idx, cum0, keys_tup, hyp_tup,
                     policies: Tuple[BanditPolicy, ...], scn=None,
                     delay: int = 0,
                     fcfg: ForgettingConfig = VANILLA_FORGETTING,
                     train_chunks: int = 1, batch_size: int = 256,
                     init_tup: Any = None):
    """The POLICY AXIS: every policy's (grid x seed) lane vmap, compiled
    and executed as ONE jitted dispatch. Per policy, ``keys`` (L, 2) and
    every hyp leaf (L,) are pre-flattened by the caller into one lane
    axis (lane l = (g, s), g = l // n_seeds) — a single batching axis
    compiles to markedly better CPU code than nested grid/seed vmaps and
    gives the device sharding one unambiguous axis. Policies carry
    heterogeneous state/hypers pytrees, so the policy axis is a static
    tuple (each member its own vmapped scan inside the one program)
    rather than one more vmap dimension — what stays uniform is the lane
    schema, the sharding, and the (G, n_seeds, T, ...) result layout.
    Scenario transforms are broadcast, not vmapped: every lane replays
    the same drift (one resident copy of the transform tables)."""
    out = []
    for i, p in enumerate(policies):
        # a pretrained init state (one per policy) is CLOSED OVER, so it
        # broadcasts across the lane vmap instead of growing a lane axis
        ist = None if init_tup is None else init_tup[i]

        def one(k, h, p=p, ist=ist):
            return _policy_scan_impl(tables, xs, env_idx, cum0, k, h, p,
                                     scn, delay, fcfg, train_chunks,
                                     batch_size, init_state=ist)[1]
        out.append(jax.vmap(one)(keys_tup[i], hyp_tup[i]))
    return tuple(out)


def _cum_valid(env: DeviceReplayEnv) -> jnp.ndarray:
    """(T+1,) int32 cumulative VALID sample counts: cum0[t+1] = number of
    real (unpadded) samples in slices 0..t — the searchsorted table for
    ``policies._sample_valid`` and the training-budget base."""
    sizes = np.asarray(env.slice_sizes, np.int64)
    return jnp.asarray(np.concatenate([[0], np.cumsum(sizes)]), jnp.int32)


def neuralucb_train_schedule(env: DeviceReplayEnv, epochs: int = 5,
                             batch_size: int = 256,
                             max_slices: Optional[int] = None) -> int:
    """Fixed per-slice SGD budget (steps) for the scanned runner.

    The host-stepped growing schedule spends ``epochs * (seen_t //
    batch)`` steps after slice t (rounded up to TRAIN_CHUNK dispatches);
    the scan needs ONE static budget for every slice, so we spread the
    growing schedule's total chunk count evenly (rounded up) — same total
    compute to within T chunks, uniform trace.
    """
    sizes = np.asarray(env.slice_sizes, np.int64)
    if max_slices is not None:
        sizes = sizes[:max_slices]
    seen = np.cumsum(sizes)
    chunks = [-(-int(epochs * (s // batch_size)) // TRAIN_CHUNK)
              for s in seen]
    per_slice = max(1, -(-sum(chunks) // len(chunks)))
    return per_slice * TRAIN_CHUNK


def _chunks_for(env: DeviceReplayEnv, policy: BanditPolicy,
                train_steps: Optional[int], epochs: int,
                batch_size: int) -> int:
    """TRAIN_CHUNK dispatches per slice. Policies without a train hook
    get the canonical 1 (the value is a static jit arg — pinning it
    avoids gratuitous retraces across differently-scheduled calls)."""
    if policy.train is _no_train:
        return 1
    if train_steps is None:
        train_steps = neuralucb_train_schedule(env, epochs, batch_size)
    return -(-int(train_steps) // TRAIN_CHUNK)


def run_policy_device(env: DeviceReplayEnv, policy: BanditPolicy,
                      hypers: Any = (), *, seed: int = 0, scenario=None,
                      forgetting: ForgettingConfig = VANILLA_FORGETTING,
                      train_steps: Optional[int] = None, epochs: int = 5,
                      batch_size: int = 256, return_state: bool = False,
                      init_state: Any = None, record_log: bool = False):
    """Any registered policy, all T slices, ONE device dispatch.

    ``hypers`` is the policy's scalar hypers pytree (see
    ``repro.sim.policies.make_policy``); ``scenario`` (name | Scenario |
    None) applies the DESIGN.md §9 non-stationary transforms inside the
    same single scan; ``forgetting`` selects the §9.2 adaptivity variant;
    ``train_steps`` / ``epochs`` set the per-slice replay-SGD budget for
    policies with a train hook. ``init_state`` injects a pretrained state
    (:func:`pretrain_policy_state`); ``record_log`` also returns the
    run's propensity-annotated :class:`~repro.data.logged
    .LoggedInteractions`. Returns the ``run_protocol`` per-policy result
    dict; with ``record_log=True`` ``(res, logged)``; with
    ``return_state=True`` additionally ``state, key`` appended."""
    from repro.data.logged import from_run_log
    env, scn, delay = resolve_scenario(env, scenario)
    chunks = _chunks_for(env, policy, train_steps, epochs, batch_size)
    t0 = time.perf_counter()
    (state, key), ms = _policy_scan(
        _tables(env), env.slice_xs(), env.idx, _cum_valid(env),
        jax.random.PRNGKey(seed), hypers, policy, scn, delay, forgetting,
        chunks, batch_size, init_state, record_log)
    jax.block_until_ready(ms)
    ms = {k: np.asarray(v) for k, v in ms.items()}
    log = {k: ms.pop(k) for k in ("action", "logp", "reward")
           if k in ms}
    res = _metrics_to_results(ms, time.perf_counter() - t0)
    extras = []
    if record_log:
        extras.append(from_run_log(env, log, behavior=policy.name))
    if return_state:
        extras.extend([state, key])
    return (res, *extras) if extras else res


# ------------------------------------------- offline pretraining (§13.3) --
@functools.partial(
    jax.jit, static_argnames=("policy", "fcfg", "train_chunks",
                              "batch_size", "pretrain_steps"))
def _pretrain_impl(tables, env_idx, cum0, key, hyp, logged,
                   policy: BanditPolicy,
                   fcfg: ForgettingConfig = VANILLA_FORGETTING,
                   train_chunks: int = 1, batch_size: int = 256,
                   pretrain_steps: int = 0):
    """prepare -> init -> pretrain as one jitted dispatch: the offline
    phase of the lifecycle, producing the state the online scan starts
    from."""
    tables = policy.prepare(tables, hyp)
    ctx = PolicyCtx(tables=tables, env_idx=env_idx, cum0=cum0, hyp=hyp,
                    eff=None, t=None, idx=None, mask=None, avail=None,
                    delay=0, fcfg=fcfg, train_chunks=train_chunks,
                    batch_size=batch_size, pretrain_steps=pretrain_steps)
    state, key = policy.init(key, ctx)
    state, _ = policy.pretrain(state, key, logged, ctx)
    return state


def pretrain_policy_state(env: DeviceReplayEnv, policy: BanditPolicy,
                          hypers: Any = (), logged=None, *, seed: int = 0,
                          steps: int = 512, batch_size: int = 256,
                          forgetting: ForgettingConfig = VANILLA_FORGETTING):
    """Run a policy's OFFLINE phase on a logged corpus (DESIGN.md §13.3).

    ``logged`` is a :class:`repro.data.logged.LoggedInteractions`;
    ``steps`` is the offline SGD budget (``PolicyCtx.pretrain_steps`` —
    the ridge folds ignore it, they consume the whole corpus). Returns
    the pretrained state pytree, injectable into the online scan via
    ``run_policy_device(init_state=...)`` /
    ``run_policy_sweep(init_states={name: ...})`` — warm and cold runs
    then share an identical PRNG stream and differ only by this state."""
    if logged is None:
        raise ValueError("pretrain_policy_state: a LoggedInteractions "
                         "corpus is required")
    return _pretrain_impl(_tables(env), env.idx, _cum_valid(env),
                          jax.random.PRNGKey(seed), hypers,
                          logged.to_device(), policy, forgetting, 1,
                          batch_size, int(steps))


def _grid_size(hypers: Any) -> int:
    leaves = jax.tree.leaves(hypers)
    sizes = [int(l.shape[0]) for l in map(jnp.asarray, leaves)
             if jnp.ndim(l) >= 1]
    if sizes and len(set(sizes)) > 1:
        raise ValueError(f"ragged hypers grid: leaf sizes {sorted(set(sizes))}")
    return sizes[0] if sizes else 1


def _flatten_lanes(hypers: Any, G: int, n_seeds: int):
    """Broadcast scalar leaves to (G,), then repeat each grid point per
    seed — lane l = (g, s) with g = l // n_seeds, s = l % n_seeds."""
    def lane(l):
        l = jnp.asarray(l)
        if jnp.ndim(l) == 0:
            l = jnp.broadcast_to(l, (G,))
        return jnp.repeat(l, n_seeds, axis=0)
    return jax.tree.map(lane, hypers)


def run_policy_sweep(env: DeviceReplayEnv,
                     policies: Dict[str, Tuple[BanditPolicy, Any]], *,
                     seeds: Sequence[int], scenario=None,
                     forgetting: ForgettingConfig = VANILLA_FORGETTING,
                     train_steps: Optional[int] = None, epochs: int = 5,
                     batch_size: int = 256,
                     init_states: Optional[Dict[str, Any]] = None
                     ) -> Dict[str, Dict]:
    """(policy × hypers × seed) study as ONE sharded device dispatch.

    ``policies`` maps name -> (BanditPolicy, hypers_grid) where each
    hypers_grid leaf is a scalar (broadcast) or a (G,) array of grid
    points (G may differ per policy). Every policy's (G x n_seeds) lane
    axis is sharded across local devices, and all policies run inside
    one jitted program (``_policy_zoo_scan``). ``init_states`` maps
    name -> pretrained state pytree (:func:`pretrain_policy_state`) —
    one state per policy, broadcast across its lanes.

    Returns {name: sweep} in the unified annotated schema: metric leaves
    (G, n_seeds, T, ...), plus ``seeds``, ``train_steps``, ``grid``
    (each hypers field as a (G,) array), and ``layout`` (the lane→device
    manifest, :meth:`SweepLaneLayout.manifest`) — every cell feeds
    ``core.protocol.summarize`` via :func:`sweep_point_results`, and the
    whole sweep feeds ``core.protocol.summarize_sweep``.

    Device layout (DESIGN.md §14.3): every policy's lane axis is PADDED
    with dead lanes (broadcast copies of lane 0) up to a device-count
    multiple and sharded over a ("grid", "seed") mesh — all local
    devices always participate, where the legacy ``shard_sweep_axis``
    silently fell back toward 1 device on non-dividing lane counts. Dead
    lanes are sliced off before results leave this function."""
    seeds = list(seeds)
    n_seeds = len(seeds)
    env, scn, delay = resolve_scenario(env, scenario)
    any_train = any(p.train is not _no_train for p, _ in policies.values())
    if train_steps is None and any_train:
        train_steps = neuralucb_train_schedule(env, epochs, batch_size)
    chunks = -(-int(train_steps) // TRAIN_CHUNK) if any_train else 1
    base_keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    gsizes = [_grid_size(grid) for _, grid in policies.values()]
    mesh = make_sweep_mesh(functools.reduce(math.gcd, gsizes, 0) or 1,
                           n_seeds)
    names, pols, keys_t, hyp_t, grids, layouts = [], [], [], [], [], []
    for (name, (pol, grid)), G in zip(policies.items(), gsizes):
        hyp = _flatten_lanes(grid, G, n_seeds)
        keys = jnp.tile(base_keys, (G, 1))
        layout = sweep_lane_layout(G * n_seeds, mesh)
        keys, hyp = pad_sweep_lanes((keys, hyp), layout.pad)
        keys, hyp = shard_sweep_lanes((keys, hyp), mesh)
        names.append(name)
        pols.append(pol)
        keys_t.append(keys)
        hyp_t.append(hyp)
        grids.append(grid)
        layouts.append(layout)
    init_tup = None
    if init_states:
        init_tup = tuple(init_states.get(n) for n in names)
    ms_t = _policy_zoo_scan(_tables(env), env.slice_xs(), env.idx,
                            _cum_valid(env), tuple(keys_t), tuple(hyp_t),
                            tuple(pols), scn, delay, forgetting, chunks,
                            batch_size, init_tup=init_tup)
    out = {}
    for name, pol, G, grid, layout, ms in zip(names, pols, gsizes, grids,
                                              layouts, ms_t):
        # dead pad lanes are dropped HERE, before any consumer
        # (sweep_point_results / summarize_sweep) can see them
        d = {k: np.asarray(v)[:layout.n_lanes].reshape(
                 (G, n_seeds) + v.shape[1:])
             for k, v in ms.items()}
        d["seeds"] = np.asarray(seeds)
        # annotate the steps that actually RAN: a sweep of train-less
        # policies executes zero SGD steps whatever the caller requested
        d["train_steps"] = np.asarray(
            chunks * TRAIN_CHUNK if pol.train is not _no_train else 0)
        d["grid"] = {
            f: np.asarray(jnp.broadcast_to(jnp.asarray(v), (G,)))
            for f, v in (zip(grid._fields, grid)
                         if hasattr(grid, "_fields") else ())}
        d["layout"] = layout.manifest()
        out[name] = d
    return out


# --------------------------------------------------------------- baselines --
def run_baseline_device(env: DeviceReplayEnv, policy, *, seed: int = 0,
                        scenario=None) -> Dict:
    """One baseline, all T slices, one device dispatch, via the unified
    runner (``policy`` may be a legacy :class:`DevicePolicy` triple or a
    :class:`BanditPolicy`). Returns the ``run_protocol`` per-policy
    result dict (summarize-compatible)."""
    if isinstance(policy, DevicePolicy):
        policy = as_bandit_policy(policy)
    return run_policy_device(env, policy, (), seed=seed, scenario=scenario)


def run_baseline_sweep(env: DeviceReplayEnv, policy, seeds,
                       scenario=None) -> Dict[str, np.ndarray]:
    """Multi-seed baseline sweep through the unified sweep runner.

    Returns the same grid-annotated schema as every policy sweep: metric
    leaves have shape (G=1, n_seeds, T, ...) plus ``seeds`` — a cell
    feeds ``summarize`` via :func:`sweep_point_results`."""
    if isinstance(policy, DevicePolicy):
        policy = as_bandit_policy(policy)
    return run_policy_sweep(env, {policy.name: (policy, ())},
                            seeds=seeds, scenario=scenario)[policy.name]


# --------------------------------------------------------------- neuralucb --
def _hypers(beta, tau_g, gate_margin, lr, ridge_lambda0,
            cost_lambda) -> NeuralUCBHypers:
    f = jnp.float32
    return NeuralUCBHypers(
        beta=f(beta), tau_g=f(tau_g), gate_margin=f(gate_margin), lr=f(lr),
        ridge_lambda0=f(ridge_lambda0),
        cost_lambda=f(-1.0 if cost_lambda is None else cost_lambda))


def run_neuralucb_device(env: DeviceReplayEnv, cfg: UN.UtilityNetConfig, *,
                         seed: int = 0, epochs: int = 5,
                         train_steps: Optional[int] = None,
                         beta: float = 1.0, tau_g: float = 0.5,
                         ridge_lambda0: float = 1.0, lr: float = 1e-3,
                         gate_margin: float = 0.05, batch_size: int = 256,
                         cost_lambda: Optional[float] = None,
                         ucb_backend: Optional[str] = None,
                         scenario=None,
                         forgetting: ForgettingConfig = VANILLA_FORGETTING,
                         return_state: bool = False):
    """Algorithm 1 end to end as ONE device dispatch (DESIGN.md §8.4) —
    the registered ``neuralucb`` policy on the unified runner.

    ``train_steps`` is the fixed per-slice SGD budget (rounded up to a
    TRAIN_CHUNK multiple); when omitted it is derived from ``epochs`` via
    :func:`neuralucb_train_schedule` to match the stepped runner's total
    budget. ``scenario`` (name | Scenario | None) applies the DESIGN.md
    §9 non-stationary transforms inside the same single scan;
    ``forgetting`` selects the adaptivity variant (§9.2). Returns the
    ``run_protocol`` per-policy result dict; with ``return_state=True``
    also the final :class:`NeuralUCBState`.
    """
    backend = ucb_backend or default_ucb_backend()
    policy = neuralucb_policy(cfg, backend)
    hyp = _hypers(beta, tau_g, gate_margin, lr, ridge_lambda0, cost_lambda)
    out = run_policy_device(env, policy, hyp, seed=seed, scenario=scenario,
                            forgetting=forgetting, train_steps=train_steps,
                            epochs=epochs, batch_size=batch_size,
                            return_state=return_state)
    if not return_state:
        return out
    res, state, key = out
    return res, NeuralUCBState(params=state["params"], opt=state["opt"],
                               ainv=state["ainv"], bufs=state["bufs"],
                               key=key)


def run_neuralucb_sweep(env: DeviceReplayEnv, cfg: UN.UtilityNetConfig, *,
                        seeds: Sequence[int], betas=(1.0,), tau_gs=(0.5,),
                        cost_lambdas=(None,), epochs: int = 5,
                        train_steps: Optional[int] = None,
                        ridge_lambda0: float = 1.0, lr: float = 1e-3,
                        gate_margin: float = 0.05, batch_size: int = 256,
                        ucb_backend: str = "jnp", scenario=None,
                        forgetting: ForgettingConfig = VANILLA_FORGETTING
                        ) -> Dict[str, np.ndarray]:
    """Multi-seed, multi-hyper NeuralUCB sweep as one dispatch.

    The hyper grid is the cartesian product ``betas x tau_gs x
    cost_lambdas`` (G points, ``itertools.product`` order, recorded in the
    returned ``beta`` / ``tau_g`` / ``cost_lambda`` arrays); metric leaves
    come back with shape (G, n_seeds, T, ...). The flattened (grid x
    seed) lane axis is padded to a device-count multiple and sharded
    over the ("grid", "seed") sweep mesh. The default UCB backend is the
    portable jnp path; ``ucb_backend="pallas"`` routes DECIDE through
    the fused decide kernel and REBUILD through the blocked-Cholesky
    kernel (`repro.kernels`) — off-TPU these self-dispatch to their jnp
    references, so the option is safe (if slower to trace) under the
    sweep vmap everywhere.
    """
    grid = list(itertools.product(betas, tau_gs, cost_lambdas))
    G = len(grid)
    f = functools.partial(jnp.asarray, dtype=jnp.float32)
    hyp_grid = NeuralUCBHypers(
        beta=f([b for b, _, _ in grid]),
        tau_g=f([t for _, t, _ in grid]),
        gate_margin=jnp.full((G,), gate_margin, jnp.float32),
        lr=jnp.full((G,), lr, jnp.float32),
        ridge_lambda0=jnp.full((G,), ridge_lambda0, jnp.float32),
        cost_lambda=f([-1.0 if l is None else l for _, _, l in grid]))
    out = run_policy_sweep(
        env, {"neuralucb": (neuralucb_policy(cfg, ucb_backend), hyp_grid)},
        seeds=seeds, scenario=scenario, forgetting=forgetting,
        train_steps=train_steps, epochs=epochs,
        batch_size=batch_size)["neuralucb"]
    # legacy flat annotations (the grid subdict carries the same data)
    out["beta"] = np.asarray([b for b, _, _ in grid], np.float32)
    out["tau_g"] = np.asarray([t for _, t, _ in grid], np.float32)
    out["cost_lambda"] = np.asarray(
        [np.nan if l is None else l for _, _, l in grid], np.float32)
    return out


def sweep_point_results(sweep: Dict[str, np.ndarray], g: int,
                        s: int) -> Dict:
    """Extract one (grid point, seed) run from ANY policy's annotated
    sweep as a ``run_protocol`` per-policy result dict, so sweep cells
    feed ``repro.core.protocol.summarize`` unchanged."""
    cum = np.cumsum(np.asarray(sweep["sum_reward"][g, s], np.float64))
    T = len(cum)
    out = {
        "avg_reward": [float(v) for v in sweep["avg_reward"][g, s]],
        "cum_reward": [float(v) for v in cum],
        "avg_cost": [float(v) for v in sweep["avg_cost"][g, s]],
        "avg_quality": [float(v) for v in sweep["avg_quality"][g, s]],
        "oracle_avg_reward": [float(v)
                              for v in sweep["oracle_avg_reward"][g, s]],
        "action_hist": np.asarray(sweep["action_hist"][g, s]),
        "wall_s": [0.0] * T,
    }
    if "mean_logp" in sweep:
        out["mean_logp"] = [float(v) for v in sweep["mean_logp"][g, s]]
    return out


# -------------------------------------------- host-stepped parity runner --
@functools.partial(jax.jit, static_argnames=("cfg", "backend", "warm"),
                   donate_argnames=("ainv", "bufs"))
def _nucb_slice_step(params, ainv, tables, bufs, t, idx, mask, key,
                     beta, tau_g, gate_margin,
                     cfg: UN.UtilityNetConfig, backend: str, warm: bool):
    """DECIDE -> feedback lookup -> buffer write -> rank-k UPDATE, fused.
    Host-stepped entry point: ``warm`` is static (one trace per phase).
    Stationary tables only — scenarios are a scanned-runner feature.
    A^-1 and the ring buffers are donated — the caller threads them
    through every slice and never reads the stale copy, so XLA updates
    them in place instead of double-buffering (F, F) + (T, S) state."""
    batch = _context(tables, idx)
    if warm:
        a, _, g, mu_safe, gs = _decide_warm(params, batch, key, cfg)
    else:
        a, _, g, mu_safe, gs = _decide_ucb(params, ainv, batch, beta,
                                           tau_g, cfg, backend)
    r = _pick(tables, None, "reward", idx, a)
    gate_label = (r < mu_safe - gate_margin).astype(jnp.float32)
    bufs = {
        "action": bufs["action"].at[t].set(a),
        "reward": bufs["reward"].at[t].set(r),
        "gate_label": bufs["gate_label"].at[t].set(gate_label),
        "w": bufs["w"].at[t].set(mask),
        "gate_w": bufs["gate_w"].at[t].set(mask * gs),
    }
    # padded rows are zeroed -> contribute nothing to the rank-k update
    if backend == "pallas":
        ainv = nucb_update(ainv, g * mask[:, None])
    else:
        ainv = NU.woodbury_update(ainv, g * mask[:, None])
    return ainv, bufs, _slice_metrics(tables, None, idx, mask, a)


# params/opt are donated: the stepped runner overwrites its references
# with the returned leaves, so the pre-step weights and AdamW moments
# never need to coexist with the post-step ones in HBM.
_nucb_train = jax.jit(
    _train_chunk,
    static_argnames=("cfg", "num_steps", "batch_size", "fcfg", "delayed"),
    donate_argnames=("params", "opt"))

# NOT donated: every input (params, buffers, tables) outlives the call —
# the rebuild reads the replay buffers it does not own (DESIGN.md §15).
_nucb_rebuild = jax.jit(_rebuild_impl, static_argnames=("cfg", "backend"))


class DeviceNeuralUCB:
    """Host-stepped NeuralUCB protocol runner (paper Algorithm 1).

    Same hyperparameters as :class:`repro.core.policy.NeuralUCBRouter`;
    the replay buffer is (T, S) device arrays of outcomes keyed by the
    env's slice-index matrix, so buffered contexts are looked up from the
    resident tables instead of being copied.

    This is the parity reference for the single-dispatch scanned path
    (:func:`run_neuralucb_device`): ~ceil(steps/TRAIN_CHUNK)+2 dispatches
    and one sync per slice, identical math. ``run()`` delegates to the
    scanned path when the schedule allows (fixed ``train_steps``, full
    stream, fresh state); pass ``scan=False`` to force stepping."""

    def __init__(self, env: DeviceReplayEnv, cfg: UN.UtilityNetConfig, *,
                 seed: int = 0, beta: float = 1.0, tau_g: float = 0.5,
                 ridge_lambda0: float = 1.0, lr: float = 1e-3,
                 gate_margin: float = 0.05, batch_size: int = 256,
                 ucb_backend: Optional[str] = None,
                 forgetting: ForgettingConfig = VANILLA_FORGETTING):
        self.env = env
        self.cfg = cfg
        self.seed = seed
        self.beta = beta
        self.tau_g = tau_g
        self.ridge_lambda0 = ridge_lambda0
        self.lr = lr
        self.gate_margin = gate_margin
        self.batch_size = batch_size
        self.forgetting = forgetting
        self.ucb_backend = ucb_backend or default_ucb_backend()
        T, S = env.idx.shape
        # same split discipline as the unified runner's neural init:
        # split[0] -> network init, split[1] -> run stream (the PR-1
        # runner fed PRNGKey(seed) to both, correlating warm-slice
        # exploration with the weight init)
        state, key = neural_init_state(jax.random.PRNGKey(seed), cfg, T, S,
                                       ridge_lambda0)
        self.params, self.opt = state["params"], state["opt"]
        self.ainv, self.bufs, self.key = state["ainv"], state["bufs"], key
        self._cum0 = _cum_valid(env)
        self._stepped = False   # True once run() has mutated state host-side

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def run(self, *, epochs: int = 5, verbose: bool = False,
            max_slices: Optional[int] = None,
            train_steps: Optional[int] = None, scan="auto") -> Dict:
        """Run Algorithm 1 end to end; returns the ``run_protocol``
        per-policy result dict (summarize-compatible).

        ``train_steps`` fixes the per-slice SGD budget (scanned-runner
        schedule); without it the budget grows with the buffer
        (``epochs * (seen // batch)``, the seed-loop schedule), which only
        the stepped path can express. ``scan="auto"`` delegates to the
        single-dispatch scanned runner whenever the schedule allows —
        fixed ``train_steps``, full stream, state untouched by a previous
        stepped run; ``scan=False`` forces stepping (parity reference)."""
        can_scan = (train_steps is not None and max_slices is None
                    and not self._stepped)
        if scan is True and not can_scan:
            raise ValueError(
                "scan=True requires a fixed train_steps schedule, "
                "max_slices=None, and state untouched by a stepped run")
        if scan is not False and can_scan:
            return self._run_scanned(train_steps, verbose)
        return self._run_stepped(epochs=epochs, verbose=verbose,
                                 max_slices=max_slices,
                                 train_steps=train_steps)

    def _run_scanned(self, train_steps: int, verbose: bool) -> Dict:
        res, state = run_neuralucb_device(
            self.env, self.cfg, seed=self.seed, train_steps=train_steps,
            beta=self.beta, tau_g=self.tau_g,
            ridge_lambda0=self.ridge_lambda0, lr=self.lr,
            gate_margin=self.gate_margin, batch_size=self.batch_size,
            ucb_backend=self.ucb_backend, forgetting=self.forgetting,
            return_state=True)
        self.params, self.opt = state.params, state.opt
        self.ainv, self.bufs, self.key = state.ainv, state.bufs, state.key
        self._stepped = True
        if verbose:
            T = len(res["avg_reward"])
            for t, v in enumerate(res["avg_reward"]):
                print(f"[sim slice {t + 1:2d}/{T}] avg_reward={v:.3f}",
                      flush=True)
        return res

    def _run_stepped(self, *, epochs: int, verbose: bool,
                     max_slices: Optional[int],
                     train_steps: Optional[int]) -> Dict:
        env = self.env
        self._stepped = True
        tables = _tables(env)
        T = env.n_slices if max_slices is None else min(env.n_slices,
                                                        max_slices)
        per_slice = []
        wall = []
        for t in range(T):
            t0 = time.perf_counter()
            self.ainv, self.bufs, m = _nucb_slice_step(
                self.params, self.ainv, tables, self.bufs,
                jnp.int32(t), env.idx[t], env.mask[t], self._next_key(),
                jnp.float32(self.beta), jnp.float32(self.tau_g),
                jnp.float32(self.gate_margin),
                self.cfg, self.ucb_backend, t == 0)
            # valid samples observed so far — the sampling range AND the
            # growing-schedule budget base (was the padded (t+1)*S range)
            count = self._cum0[t + 1]
            if train_steps is not None:
                num_steps = int(train_steps)
            else:
                num_steps = epochs * (int(count) // self.batch_size)
            # round the step budget up to TRAIN_CHUNK-sized dispatches:
            # as a static jit arg each distinct value would recompile the
            # whole training scan
            for _ in range(-(-num_steps // TRAIN_CHUNK)):
                self.params, self.opt = _nucb_train(
                    self.params, self.opt, tables, env.idx, self.bufs,
                    self._next_key(), self._cum0, count,
                    jnp.float32(self.lr), self.cfg, TRAIN_CHUNK,
                    self.batch_size, jnp.int32(t), self.forgetting, False)
            row_w = None if self.forgetting.is_vanilla else _slice_weights(
                env.idx.shape[0], jnp.int32(t), 0, self.forgetting)
            self.ainv = _nucb_rebuild(
                self.params, tables, env.idx, self.bufs["action"],
                self.bufs["w"], self.cfg, jnp.float32(self.ridge_lambda0),
                row_w, backend=self.ucb_backend)
            jax.block_until_ready(self.ainv)
            per_slice.append(m)
            wall.append(time.perf_counter() - t0)
            if verbose:
                print(f"[sim slice {t + 1:2d}/{T}] "
                      f"avg_reward={float(m['avg_reward']):.3f}", flush=True)
        ms = {k: np.stack([np.asarray(m[k]) for m in per_slice])
              for k in per_slice[0]}
        out = _metrics_to_results(ms, sum(wall))
        out["wall_s"] = wall
        return out


def run_protocol_device(env: DeviceReplayEnv,
                        policies: Dict[str, Any], *,
                        neuralucb: Optional[DeviceNeuralUCB] = None,
                        epochs: int = 5, seed: int = 0,
                        verbose: bool = False,
                        scenario=None) -> Dict[str, Dict]:
    """Drop-in device-resident counterpart of
    ``repro.core.protocol.run_protocol``: every policy (legacy
    :class:`DevicePolicy` triples and unified :class:`BanditPolicy`
    members alike) replays the same slice stream (and the same scenario
    drift, when one is named); results feed
    ``repro.core.protocol.summarize``.

    Scheduling caveat: with ``scenario=None`` the NeuralUCB leg is
    ``neuralucb.run(epochs=...)`` — the stepped growing schedule (or its
    scan delegation). With a scenario — INCLUDING the named
    ``"stationary"`` — it is the scanned runner with the fixed
    epochs-derived schedule (a scan cannot express a growing budget,
    DESIGN.md §8.4), so the two calls are not sample-identical; the
    byte-identical stationary contract holds at the
    ``run_neuralucb_device`` / ``run_baseline_device`` level."""
    results = {}
    if neuralucb is not None:
        if scenario is not None:
            results["neuralucb"] = run_neuralucb_device(
                env, neuralucb.cfg, seed=neuralucb.seed,
                epochs=epochs, beta=neuralucb.beta, tau_g=neuralucb.tau_g,
                ridge_lambda0=neuralucb.ridge_lambda0, lr=neuralucb.lr,
                gate_margin=neuralucb.gate_margin,
                batch_size=neuralucb.batch_size,
                ucb_backend=neuralucb.ucb_backend,
                forgetting=neuralucb.forgetting, scenario=scenario)
            if verbose:
                r = results["neuralucb"]["avg_reward"]
                name = getattr(scenario, "name", scenario)
                print(f"[sim] neuralucb ({name}): avg_reward="
                      f"{np.mean(r):.3f}", flush=True)
        else:
            results["neuralucb"] = neuralucb.run(epochs=epochs,
                                                 verbose=verbose)
    for name, pol in policies.items():
        results[name] = run_baseline_device(env, pol, seed=seed,
                                            scenario=scenario)
        if verbose:
            print(f"[sim] {name}: avg_reward="
                  f"{np.mean(results[name]['avg_reward']):.3f}", flush=True)
    return results
