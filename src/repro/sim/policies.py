"""The unified bandit-policy runtime and the policy zoo (DESIGN.md §10).

Every online learner — NeuralUCB included — is a :class:`BanditPolicy`:
a pytree-of-callables protocol over an explicit state pytree plus a
lane-vmappable hypers pytree, scanned end-to-end by the ONE generic
runner in :mod:`repro.sim.engine` (``run_policy_device`` /
``run_policy_sweep``). The protocol:

    init(key, ctx)                      -> (state, run_key)
    decide(state, key, batch, ctx)      -> (actions (S,) i32,
                                            log_propensities (S,) f32, aux)
    update(state, batch, a, r, ctx, aux)-> state      # in-slice feedback
    train(state, key, ctx)              -> (state, key)  # end-of-slice SGD
    rebuild(state, ctx)                 -> state      # end-of-slice refresh
    prepare(tables, hyp)                -> tables     # stationary pre-derive
    pretrain(state, key, logged, ctx)   -> (state, key)  # offline phase

``decide``'s second output is the behavior LOG-PROPENSITY of each chosen
action (DESIGN.md §13.2): exact for the stochastic members (uniform
warm-up, ε-greedy, Boltzmann, random), and the declared ε-smoothed
point-mass value (:data:`OPE_SMOOTHING_EPS`) for the
deterministic-given-state family (UCB, TS, LinUCB, supervised, fixed
arms use exact 0). ``pretrain`` consumes a
:class:`repro.data.logged.LoggedInteractions` device view and runs the
offline phase (replay SGD + A^-1 fold) before any online slice;
policies without an offline phase keep the default no-op.

``ctx`` is a :class:`PolicyCtx` carrying the resident replay tables, the
slice cursor, the scenario's effective tables / availability mask, and
the (static) delay / forgetting / training-schedule knobs — so every
policy composes with scenarios, ``ForgettingConfig``, delayed feedback,
and the sharded sweep vmap for free. Key discipline is owned by the
runner (one split per slice feeds ``decide``; ``train`` splits further
from the carried stream), which keeps the NeuralUCB trajectories
bit-exact with the pre-unification scans (tests/test_golden.py).

Registered zoo (``POLICIES`` / :func:`make_policy`) — the paper's
closing question ("remaining challenges in action discrimination and
exploration") made comparable across exploration mechanisms:

    random / min_cost / max_quality / greedy — the paper's §4.1 baselines
    dyn_min_cost — scenario-aware: cheapest AVAILABLE arm under the
        slice's effective cost tables (the honest min-cost under drift)
    linucb       — disjoint LinUCB on raw text embeddings (per-arm
        blocked Sherman–Morrison/Woodbury, no network)
    neuralucb    — the paper's policy (gated UCB over shared A^-1)
    neural_ts    — NeuralTS: Thompson sampling via posterior-perturbed
        scores mu + nu * sigma * z, sigma from the same A^-1 bonus
        (Pallas ``ucb_score`` kernel on TPU)
    eps_greedy   — ε-uniform over the UtilityNet's mean estimates
    boltzmann    — softmax(mu / temperature) sampling
    sup_winrate  — supervised win-rate classifier: per-arm ridge fitted
        purely offline by ``pretrain``, frozen online (routellm-style)
    sup_mf       — supervised matrix-factorization router: domain × arm
        embeddings fitted purely offline, frozen online

The neural variants share the UtilityNet replay-training path verbatim
(`_train_chunk`), so a zoo comparison isolates the exploration rule.

Legacy: :class:`DevicePolicy` (stateless init/decide/update triples) is
kept as the lightweight baseline authoring surface; :func:`as_bandit_policy`
lifts one into the unified protocol bit-compatibly.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import neuralucb as NU
from repro.core import utilitynet as UN
from repro.core.reward import normalize_cost
from repro.kernels.ainv_rebuild import ainv_rebuild
from repro.kernels.nucb_decide import nucb_decide
from repro.kernels.nucb_update import nucb_update
from repro.kernels.ucb_score.ops import ucb_score
from repro.training.optim import adamw_init, adamw_update, clip_by_global_norm


# -------------------------------------------------- propensity semantics --
# The declared behavior-smoothing rate for policies whose decide is
# deterministic given their state (UCB / TS / LinUCB): the logged
# propensity is that of the ε-smoothed point mass
# (1 - ε) δ(a*) + ε uniform(available), so off-policy importance weights
# stay bounded (DESIGN.md §13.2). Exactly-stochastic policies log exact
# propensities and never consult this.
OPE_SMOOTHING_EPS = 0.05


def _n_avail(num_actions: int, avail):
    if avail is None:
        return jnp.float32(num_actions)
    return jnp.maximum((avail > 0).sum().astype(jnp.float32), 1.0)


def _uniform_logp(B: int, num_actions: int, avail):
    """Exact log-propensity of a uniform draw over available arms."""
    return jnp.full((B,), -jnp.log(_n_avail(num_actions, avail)),
                    jnp.float32)


def _smoothed_logp(B: int, num_actions: int, avail):
    """Declared ε-smoothed log-propensity of a deterministic choice."""
    nav = _n_avail(num_actions, avail)
    return jnp.full(
        (B,),
        jnp.log(1.0 - OPE_SMOOTHING_EPS + OPE_SMOOTHING_EPS / nav),
        jnp.float32)


def _zero_logp(B: int):
    """Deterministic policies: propensity 1 (log 0) on the chosen arm."""
    return jnp.zeros((B,), jnp.float32)


# ------------------------------------------------------------ legacy API --
class DevicePolicy(NamedTuple):
    """Stateless baseline triple (DESIGN.md §8.2); lift with
    :func:`as_bandit_policy` to run on the unified runtime. ``logp``
    optionally maps ``(actions, batch) -> (B,)`` log-propensities; None
    means deterministic (log-propensity 0)."""

    name: str
    init: Callable
    decide: Callable
    update: Callable
    logp: Optional[Callable] = None


class NeuralUCBState(NamedTuple):
    """Everything Algorithm 1 mutates across slices, as one explicit pytree
    (DESIGN.md §8.4) — the state snapshot the host-stepped runner threads
    between jit calls, and the ``return_state`` schema of
    ``run_neuralucb_device`` (the unified runner carries the same leaves
    as a plain dict plus the runner-owned key).
    """

    params: Dict[str, Any]      # UtilityNet weights
    opt: Dict[str, Any]         # AdamW moments
    ainv: jnp.ndarray           # shared inverse covariance (F, F)
    bufs: Dict[str, jnp.ndarray]  # (T, S) replay outcome buffers
    key: jnp.ndarray            # PRNG stream (network init already split off)


class NeuralUCBHypers(NamedTuple):
    """Per-run scalar hyperparameters, grouped so the sweep harness can
    ``vmap`` one leading grid axis over all of them at once. A negative
    ``cost_lambda`` is the sentinel for "keep the env's precomputed reward
    table" (the replay tables carry normalized cost so reward can be
    re-derived per Eq. 1 for any positive lambda on device)."""

    beta: jnp.ndarray           # UCB exploration scale
    tau_g: jnp.ndarray          # gate threshold
    gate_margin: jnp.ndarray    # gate-label margin
    lr: jnp.ndarray             # AdamW learning rate
    ridge_lambda0: jnp.ndarray  # A = lambda0 I + ... ridge
    cost_lambda: jnp.ndarray    # reward trade-off; < 0 -> env's table


class NeuralPolicyHypers(NamedTuple):
    """Hypers for the non-UCB neural zoo members (NeuralTS / ε-greedy /
    Boltzmann). ``explore`` is the policy's single exploration knob —
    nu (TS posterior scale), ε (uniform-mix rate), or the softmax
    temperature; 0 reproduces net-greedy for TS and ε-greedy."""

    explore: jnp.ndarray
    gate_margin: jnp.ndarray    # gate-label margin (shared train path)
    lr: jnp.ndarray
    ridge_lambda0: jnp.ndarray  # TS A^-1 ridge (unused by eps/boltzmann)
    cost_lambda: jnp.ndarray    # < 0 -> env's reward table


class LinUCBHypers(NamedTuple):
    """Disjoint-LinUCB hypers: exploration scale and per-arm ridge."""

    alpha: jnp.ndarray
    ridge: jnp.ndarray


class SupervisedHypers(NamedTuple):
    """Win-rate supervised router hypers: the per-arm ridge of the
    offline reward regression."""

    ridge: jnp.ndarray


class MFHypers(NamedTuple):
    """Matrix-factorization supervised router hypers: offline AdamW
    learning rate and embedding L2 regularization."""

    lr: jnp.ndarray
    reg: jnp.ndarray


class ForgettingConfig(NamedTuple):
    """Non-stationarity adaptivity knobs (DESIGN.md §9.2). A plain
    hashable NamedTuple of Python scalars so it rides through jit as a
    STATIC argument: the vanilla config compiles to exactly the
    stationary code path (bit-exact with PR-2), and each non-vanilla
    combination is its own trace.

    * ``gamma`` — per-slice discount on the A^-1 rebuild weights:
      A_t = lambda0 I + sum_s gamma^(t-s) sum_{i in s} w_i g_i g_i^T.
      1.0 = vanilla (infinite memory).
    * ``window`` — sliding window in slices: only the last ``window``
      slices enter the rebuild. 0 = off. Composes with ``gamma``.
    * ``replay_rho`` — recency weight for replay sampling: slice s is
      drawn with probability proportional to size_s * rho^(t-s) (then
      uniform within the slice), so the UtilityNet relearns drifted
      rewards instead of averaging over stale ones. 1.0 = uniform.
    """

    gamma: float = 1.0
    window: int = 0
    replay_rho: float = 1.0

    @property
    def is_vanilla(self) -> bool:
        return (self.gamma >= 1.0 and self.window == 0
                and self.replay_rho >= 1.0)


VANILLA_FORGETTING = ForgettingConfig()


# ------------------------------------------------------ unified protocol --
class PolicyCtx(NamedTuple):
    """Everything a policy callback may need beyond its own state, built
    once per run and ``_replace``-d per slice by the generic runner.
    Array fields are traced; ``delay`` / ``fcfg`` / ``train_chunks`` /
    ``batch_size`` are static Python values baked into the trace."""

    tables: Any                 # resident replay tables (engine._tables)
    env_idx: Any                # (T, S) slice-index matrix
    cum0: Any                   # (T+1,) cumulative valid sample counts
    hyp: Any                    # this lane's hypers pytree
    eff: Any                    # slice effective tables (None = stationary)
    t: Any                      # slice cursor (traced scalar)
    idx: Any                    # (S,) sample ids of the slice
    mask: Any                   # (S,) validity mask
    avail: Any                  # (K,) availability or None
    delay: int                  # static: feedback delay in slices
    fcfg: ForgettingConfig      # static: forgetting variant
    train_chunks: int           # static: TRAIN_CHUNK dispatches per slice
    batch_size: int             # static: replay minibatch size
    pretrain_steps: int = 0     # static: offline SGD steps (pretrain hook)


def _no_train(state, key, ctx):
    return state, key


def _no_pretrain(state, key, logged, ctx):
    return state, key


def _no_rebuild(state, ctx):
    return state


def _no_prepare(tables, hyp):
    return tables


class BanditPolicy(NamedTuple):
    """The unified policy protocol (module docstring). A NamedTuple of
    callables is hashable, so a policy instance rides through jit as a
    STATIC argument — factories are ``lru_cache``-d so repeated runs with
    the same configuration share one compiled scan.

    ``availability_aware`` policies exclude scenario-masked arms inside
    ``decide``; for unaware policies the runner applies the engine-level
    cheapest-available fallback after the fact."""

    name: str
    init: Callable
    decide: Callable
    update: Callable
    train: Callable = _no_train
    rebuild: Callable = _no_rebuild
    prepare: Callable = _no_prepare
    pretrain: Callable = _no_pretrain
    availability_aware: bool = False


def as_bandit_policy(pol: DevicePolicy) -> BanditPolicy:
    """Lift a legacy stateless triple into the unified protocol. Key
    discipline matches the pre-unification `_baseline_scan` exactly:
    ``init`` sees the unsplit seed key and passes it through as the run
    stream, and ``decide`` consumes the runner's one split per slice."""
    return _as_bandit_policy_cached(pol)


@functools.lru_cache(maxsize=None)
def _as_bandit_policy_cached(pol: DevicePolicy) -> BanditPolicy:
    def init(key, ctx):
        return pol.init(key), key

    def decide(state, key, batch, ctx):
        a = pol.decide(state, key, batch)
        lp = (_zero_logp(a.shape[0]) if pol.logp is None
              else pol.logp(a, batch))
        return a, lp, None

    def update(state, batch, a, r, ctx, aux):
        return pol.update(state, batch, a, r, ctx.mask)

    return BanditPolicy(pol.name, init, decide, update)


# --------------------------------------------------------- §8.2 baselines --
def _dev_no_update(state, batch, actions, rewards, mask):
    return state


@functools.lru_cache(maxsize=None)
def random_policy(num_actions: int) -> DevicePolicy:
    """Uniform over the pool, one fold of the scan key per slice."""

    def init(key):
        return ()

    def decide(state, key, batch):
        B = batch["x_emb"].shape[0]
        return jax.random.randint(key, (B,), 0, num_actions, jnp.int32)

    def logp(actions, batch):
        return _uniform_logp(actions.shape[0], num_actions, None)

    return DevicePolicy("random", init, decide, _dev_no_update, logp)


@functools.lru_cache(maxsize=None)
def fixed_policy(action: int, name: str = "fixed") -> DevicePolicy:
    """min-cost / max-quality: a fixed arm chosen from dataset statistics."""

    def init(key):
        return ()

    def decide(state, key, batch):
        B = batch["x_emb"].shape[0]
        return jnp.full((B,), action, jnp.int32)

    return DevicePolicy(name, init, decide, _dev_no_update)


@functools.lru_cache(maxsize=None)
def greedy_policy(num_actions: int) -> DevicePolicy:
    """Context-free empirical-mean greedy (= core.baselines.EmpiricalGreedy).

    State is (sum_r, cnt) per arm; a slice's update is one masked one-hot
    matmul instead of a per-sample scatter loop.
    """

    def init(key):
        return (jnp.zeros((num_actions,), jnp.float32),
                jnp.zeros((num_actions,), jnp.float32))

    def decide(state, key, batch):
        sum_r, cnt = state
        mean_r = sum_r / jnp.maximum(cnt, 1.0)
        a = jnp.argmax(mean_r)          # ties -> lowest index, as np.argmax
        B = batch["x_emb"].shape[0]
        return jnp.full((B,), a, jnp.int32)

    def update(state, batch, actions, rewards, mask):
        sum_r, cnt = state
        onehot = jax.nn.one_hot(actions, num_actions, dtype=jnp.float32)
        onehot = onehot * mask[:, None]
        return (sum_r + onehot.T @ rewards, cnt + onehot.sum(axis=0))

    return DevicePolicy("greedy", init, decide, update)


@functools.lru_cache(maxsize=None)
def dyn_min_cost_policy() -> BanditPolicy:
    """Scenario-aware dynamic min-cost: the cheapest AVAILABLE arm under
    the CURRENT slice's effective cost tables — the honest budget-tier
    baseline under price drift/shocks (the static ``min_cost`` arm keeps
    routing to a repriced provider forever)."""

    def init(key, ctx):
        return (), key

    def decide(state, key, batch, ctx):
        if ctx.eff is None:
            c = ctx.tables["mean_cost"]
        else:
            denom = jnp.maximum(ctx.mask.sum(), 1.0)
            c = (ctx.eff["cost"] * ctx.mask[:, None]).sum(axis=0) / denom
        if ctx.avail is not None:
            c = jnp.where(ctx.avail > 0, c, jnp.inf)
        a = jnp.argmin(c).astype(jnp.int32)
        B = batch["x_emb"].shape[0]
        return jnp.full((B,), a, jnp.int32), _zero_logp(B), None

    def update(state, batch, a, r, ctx, aux):
        return state

    return BanditPolicy("dyn-min-cost", init, decide, update,
                        availability_aware=True)


# ----------------------------------------------------------------- LinUCB --
def _lin_features(x_emb) -> jnp.ndarray:
    """Raw-feature LinUCB context: L2-normalized embedding + bias 1 —
    same featurization as the host ``core.baselines.LinUCB``."""
    x = x_emb / jnp.maximum(
        jnp.linalg.norm(x_emb, axis=-1, keepdims=True), 1e-6)
    return jnp.concatenate(
        [x, jnp.ones(x.shape[:-1] + (1,), x.dtype)], axis=-1)


@functools.lru_cache(maxsize=None)
def linucb_policy() -> BanditPolicy:
    """Disjoint LinUCB (Li et al. 2010) on raw text embeddings: one ridge
    model per arm, no network. A slice's update is K masked blocked
    Woodbury steps (vmapped over arms; zero-weight rows are no-ops) —
    algebraically the per-sample Sherman–Morrison recursion, but MXU
    GEMMs instead of S sequential rank-1 updates."""

    def init(key, ctx):
        K = ctx.tables["reward"].shape[1]
        D = ctx.tables["x_emb"].shape[1] + 1
        eye = jnp.eye(D, dtype=jnp.float32) / ctx.hyp.ridge
        return {"ainv": jnp.repeat(eye[None], K, axis=0),
                "b": jnp.zeros((K, D), jnp.float32)}, key

    def decide(state, key, batch, ctx):
        g = _lin_features(batch["x_emb"])                       # (B, D)
        theta = jnp.einsum("kij,kj->ki", state["ainv"], state["b"])
        mu = g @ theta.T                                        # (B, K)
        quad = jnp.einsum("bi,kij,bj->bk", g, state["ainv"], g)
        scores = mu + ctx.hyp.alpha * jnp.sqrt(jnp.maximum(quad, 0.0))
        if ctx.avail is not None:
            scores = scores + jnp.where(ctx.avail > 0, 0.0, -jnp.inf)
        a = jnp.argmax(scores, axis=-1).astype(jnp.int32)
        K = state["ainv"].shape[0]
        return a, _smoothed_logp(a.shape[0], K, ctx.avail), g

    def update(state, batch, a, r, ctx, aux):
        g = aux
        K = state["ainv"].shape[0]
        w = jax.nn.one_hot(a, K, dtype=jnp.float32) * ctx.mask[:, None]
        ainv = jax.vmap(
            lambda ak, wk: NU.woodbury_update(ak, g * wk[:, None]))(
                state["ainv"], w.T)
        b = state["b"] + jnp.einsum("bk,bd->kd", w, g * r[:, None])
        return {"ainv": ainv, "b": b}

    return BanditPolicy("linucb", init, decide, update,
                        pretrain=_ridge_pretrain(),
                        availability_aware=True)


def _ridge_pretrain(chunk: int = 256):
    """Offline phase shared by LinUCB and the win-rate supervised router:
    fold a whole logged corpus into the per-arm (A^-1, b) ridge state as
    a scan of blocked Woodbury steps (``chunk`` rows per step, vmapped
    over arms; zero-weight rows are no-ops)."""

    def pretrain(state, key, logged, ctx):
        g = _lin_features(logged["x_emb"])                     # (N, D)
        K = state["ainv"].shape[0]
        w = jax.nn.one_hot(logged["action"], K, dtype=jnp.float32) \
            * logged["w"][:, None]                             # (N, K)
        N, D = g.shape
        pad = (-N) % chunk
        gp = jnp.pad(g, ((0, pad), (0, 0)))
        wp = jnp.pad(w, ((0, pad), (0, 0)))
        rp = jnp.pad(logged["reward"], (0, pad))

        def fold(ainv, xs):
            gc, wc = xs
            ainv = jax.vmap(
                lambda ak, wk: NU.woodbury_update(ak, gc * wk[:, None]))(
                    ainv, wc.T)
            return ainv, None

        ainv, _ = jax.lax.scan(
            fold, state["ainv"],
            (gp.reshape(-1, chunk, D), wp.reshape(-1, chunk, K)))
        b = state["b"] + jnp.einsum("nk,nd->kd", wp, gp * rp[:, None])
        return {"ainv": ainv, "b": b}, key

    return pretrain


# --------------------------------------------- shared neural scaffolding --
# SGD steps per compiled training dispatch. Per-slice step budgets are
# rounded UP to a multiple of this, so the training scan compiles exactly
# once for the whole run instead of once per distinct step count.
TRAIN_CHUNK = 32


#: train-path precision names -> network compute dtype. "bf16" casts the
#: params and float network inputs to bfloat16 for the forward/backward
#: GEMMs while the loss, gradients, AdamW moments, and master params all
#: stay f32 (mixed precision with f32 accumulators); "f32" is the
#: bit-exact default (golden snapshots pin it).
TRAIN_PRECISIONS = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def _weighted_loss(params, cfg: UN.UtilityNetConfig, batch,
                   precision: str = "f32"):
    """Replay loss with per-row validity weights (padded rows carry w=0)."""
    dtype = TRAIN_PRECISIONS[precision]
    if dtype != jnp.float32:
        params = jax.tree.map(lambda x: x.astype(dtype), params)
        batch = dict(batch, x_emb=batch["x_emb"].astype(dtype),
                     x_feat=batch["x_feat"].astype(dtype))
    mu, _, gate_p = UN.utilitynet_apply(
        params, batch["x_emb"], batch["x_feat"], batch["domain"],
        batch["action"])
    mu = mu.astype(jnp.float32)
    gate_p = gate_p.astype(jnp.float32)
    w = batch["w"]
    l_u = (UN.huber(mu, batch["reward"], cfg.huber_delta) * w
           ).sum() / jnp.maximum(w.sum(), 1.0)
    p = jnp.clip(gate_p, 1e-6, 1 - 1e-6)
    y = batch["gate_label"]
    bce = -(y * jnp.log(p) + (1 - y) * jnp.log(1 - p))
    gw = batch["gate_w"]
    l_g = (bce * gw).sum() / jnp.maximum(gw.sum(), 1.0)
    return l_u + 0.5 * l_g, {"loss_u": l_u, "loss_gate": l_g}


def _apply_cost_lambda(tables, cost_lambda):
    """Re-derive the reward table for a swept ``cost_lambda`` (Eq. 1):
    r = q * exp(-lambda * c_tilde). Negative lambda is the sentinel for
    "keep the env's precomputed table" (both sides of the where are cheap
    elementwise passes over the resident (n, K) tables)."""
    swept = tables["quality"] * jnp.exp(
        -jnp.abs(cost_lambda) * tables["cnorm"])
    reward = jnp.where(cost_lambda >= 0, swept, tables["reward"])
    # keep the per-sample dynamic-oracle reference consistent with the
    # re-derived table (one (n, K) max per dispatch, outside the scan)
    return dict(tables, reward=reward, oracle_max=reward.max(axis=1))


def _masked_uniform(key, B: int, num_actions: int, avail=None):
    """Uniform draw over arms — over AVAILABLE arms when a scenario masks
    some. The masked draw is a randint over the available COUNT mapped
    through the availability CDF, so with all arms available it consumes
    the key identically to the plain draw (an identity scenario
    reproduces the fast path bit-for-bit)."""
    if avail is None:
        return jax.random.randint(key, (B,), 0, num_actions, jnp.int32)
    n_av = avail.astype(jnp.int32).sum()
    r = jax.random.randint(key, (B,), 0, jnp.maximum(n_av, 1), jnp.int32)
    rank = jnp.cumsum(avail.astype(jnp.int32)) - 1  # arm -> avail rank
    return jnp.searchsorted(rank, r, side="left").astype(jnp.int32)


def _decide_warm(params, batch, key, cfg: UN.UtilityNetConfig, avail=None):
    """Slice-1 warm start for every neural policy: uniform exploration
    (over AVAILABLE arms when a scenario masks some); the safe-utility
    reference is 0 and the gate loss is masked (gate scale 0)."""
    B = batch["x_emb"].shape[0]
    a = _masked_uniform(key, B, cfg.num_actions, avail)
    _, h, _ = UN.utilitynet_apply(
        params, batch["x_emb"], batch["x_feat"], batch["domain"], a)
    return (a, _uniform_logp(B, cfg.num_actions, avail), NU.augment(h),
            jnp.zeros((B,), jnp.float32), jnp.float32(0.0))


def _decide_ucb(params, ainv, batch, beta, tau_g,
                cfg: UN.UtilityNetConfig, backend: str, avail=None):
    """Gated UCB decision over all actions (paper §3.3). Unavailable
    arms (scenario avail mask) are excluded from BOTH the UCB argmax and
    the safe mean-greedy argmax.

    ``backend="pallas"`` routes through the fused decide op
    (`kernels.nucb_decide`): trunk forward, augment, A^-1 bonus, and the
    gated masked argmax in one kernel launch on TPU (its jnp reference
    elsewhere — backend auto-detection lives in `kernels.backend`, not
    here). ``backend="jnp"`` is the plain-XLA reference path."""
    if backend == "pallas":
        a, g, mu_safe, _ = nucb_decide(
            params, cfg, batch["x_emb"], batch["x_feat"],
            batch["domain"], ainv, beta, tau_g, avail)
        lp = _smoothed_logp(a.shape[0], cfg.num_actions, avail)
        return a, lp, g, mu_safe, jnp.float32(1.0)
    mu, h, gate_p = UN.utilitynet_all_actions(
        params, cfg, batch["x_emb"], batch["x_feat"], batch["domain"])
    g_all = NU.augment(h)                                  # (B, K, F)
    scores = mu + beta * NU.ucb_bonus(ainv, g_all)
    mu_sel = mu
    if avail is not None:
        neg = jnp.where(avail > 0, 0.0, -jnp.inf)
        scores = scores + neg
        mu_sel = mu + neg
    a_ucb = jnp.argmax(scores, axis=-1)
    a_safe = jnp.argmax(mu_sel, axis=-1)
    a = jnp.where(gate_p >= tau_g, a_ucb, a_safe).astype(jnp.int32)
    g = jnp.take_along_axis(
        g_all, a[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    mu_safe = jnp.take_along_axis(mu, a_safe[:, None], axis=1)[:, 0]
    lp = _smoothed_logp(a.shape[0], cfg.num_actions, avail)
    return a, lp, g, mu_safe, jnp.float32(1.0)


def _sample_valid(key, batch_size: int, cum0, count):
    """Uniform flat draw over the first ``count`` VALID buffer entries.

    Valid entries are the per-row prefixes of the (T, S) buffers (the
    padded tail of each row carries mask 0 — DeviceReplayEnv layout), so
    with cum0 = [0, cumsum(slice_sizes)] a flat u in [0, count) maps to
    row = searchsorted(cum0, u, 'right') - 1 and col = u - cum0[row].
    Sampling the raw (t+1)*S padded range instead (the PR-1 bug) shrank
    the effective minibatch by the padding fraction: padded rows carry
    w=0, so they neutralize their loss term but still occupy batch slots.
    """
    flat = jax.random.randint(key, (batch_size,), 0, jnp.maximum(count, 1))
    row = jnp.searchsorted(cum0, flat, side="right").astype(jnp.int32) - 1
    col = flat - cum0[row]
    return row, col


def _sample_recency(key, batch_size: int, cum0, t_vis, rho: float):
    """Recency-weighted replay draw (DESIGN.md §9.2): slice s <= t_vis is
    drawn with probability proportional to size_s * rho^(t_vis - s), then
    a column uniformly within the slice — so the UtilityNet's minibatches
    lean toward post-drift feedback instead of averaging it away."""
    sizes = (cum0[1:] - cum0[:-1]).astype(jnp.float32)          # (T,)
    s = jnp.arange(sizes.shape[0], dtype=jnp.int32)
    ok = (s <= jnp.maximum(t_vis, 0)) & (sizes > 0)
    logw = jnp.where(
        ok,
        jnp.log(jnp.maximum(sizes, 1.0))
        + (t_vis - s).astype(jnp.float32) * jnp.log(jnp.float32(rho)),
        -jnp.inf)
    k_row, k_col = jax.random.split(key)
    row = jax.random.categorical(
        k_row, logw, shape=(batch_size,)).astype(jnp.int32)
    u = jax.random.uniform(k_col, (batch_size,))
    col = jnp.minimum(jnp.floor(u * sizes[row]),
                      jnp.maximum(sizes[row] - 1, 0)).astype(jnp.int32)
    return row, col


def _train_chunk(params, opt, tables, env_idx, bufs, key, cum0, count, lr,
                 cfg: UN.UtilityNetConfig, num_steps: int, batch_size: int,
                 t_vis=None, fcfg: ForgettingConfig = VANILLA_FORGETTING,
                 delayed: bool = False, precision: str = "f32"):
    """``num_steps`` SGD steps on sampled replay minibatches, all on
    device; ``count`` (traced) is the number of VISIBLE buffered samples.
    Shared verbatim by the host-stepped and scanned runners so identical
    keys give identical training trajectories. ``fcfg`` (static) selects
    uniform vs recency-weighted sampling; ``delayed`` (static) zeroes the
    loss weights of rows past the visibility horizon ``t_vis`` (a
    delayed-feedback slice's rows are written but not yet learnable);
    ``precision`` (static, see :data:`TRAIN_PRECISIONS`) selects the
    network compute dtype — gradients arrive back in f32 through the
    cast, and AdamW keeps f32 moments and master params either way."""

    def step(carry, k):
        params, opt = carry
        if fcfg.replay_rho < 1.0:
            row, col = _sample_recency(k, batch_size, cum0, t_vis,
                                       fcfg.replay_rho)
        else:
            row, col = _sample_valid(k, batch_size, cum0, count)
        sid = env_idx[row, col]
        w = bufs["w"][row, col]
        gw = bufs["gate_w"][row, col]
        if delayed:
            vis = (row <= t_vis).astype(jnp.float32)
            w = w * vis
            gw = gw * vis
        batch = {
            "x_emb": tables["x_emb"][sid],
            "x_feat": tables["x_feat"][sid],
            "domain": tables["domain"][sid],
            "action": bufs["action"][row, col],
            "reward": bufs["reward"][row, col],
            "gate_label": bufs["gate_label"][row, col],
            "w": w,
            "gate_w": gw,
        }
        (_, _), grads = jax.value_and_grad(
            _weighted_loss, has_aux=True)(params, cfg, batch, precision)
        grads, _ = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_update(grads, opt, params, lr=lr,
                                   weight_decay=1e-4)
        return (params, opt), None

    (params, opt), _ = jax.lax.scan(
        step, (params, opt), jax.random.split(key, num_steps))
    return params, opt


def _slice_weights(T: int, t, delay: int, fcfg: ForgettingConfig):
    """(T,) per-slice A^-1 rebuild weights: delayed visibility x
    discounted/sliding-window forgetting (DESIGN.md §9.2). Only built
    when delay > 0 or forgetting is active — the vanilla path passes
    ``row_w=None`` and keeps the PR-2 rebuild bit-exact."""
    s = jnp.arange(T, dtype=jnp.int32)
    t_vis = t - delay
    w = (s <= t_vis).astype(jnp.float32)
    if fcfg.gamma < 1.0:
        age = jnp.maximum(t_vis - s, 0).astype(jnp.float32)
        w = w * jnp.float32(fcfg.gamma) ** age
    if fcfg.window > 0:
        w = w * (s > t_vis - fcfg.window).astype(jnp.float32)
    return w


def _rebuild_impl(params, tables, env_idx, action_buf, w_buf,
                  cfg: UN.UtilityNetConfig, ridge_lambda0, row_w=None,
                  backend: str = "jnp"):
    """Recompute g for every buffered pair with the fresh net; one masked
    pass over the given buffer rows (unwritten/padded rows have w=0 and
    vanish from A = lambda0 I + sum w_i g_i g_i^T), then one Cholesky
    solve. ``row_w`` (T,) optionally reweights whole slices — the
    forgetting / delayed-visibility hook (:func:`_slice_weights`).
    ``backend="pallas"`` swaps the solve for the streamed blocked-
    Cholesky kernel (`kernels.ainv_rebuild`) on TPU; callers pass only
    the valid buffer prefix (:func:`_neural_rebuild` buckets it) so the
    feature recompute stops round-tripping full capacity every slice."""
    if row_w is not None:
        w_buf = w_buf * row_w[:, None]
    sid = env_idx.reshape(-1)
    a = action_buf.reshape(-1)
    w = w_buf.reshape(-1)
    _, h, _ = UN.utilitynet_apply(
        params, tables["x_emb"][sid], tables["x_feat"][sid],
        tables["domain"][sid], a)
    if backend == "pallas":
        return ainv_rebuild(NU.augment(h), ridge_lambda0, weights=w)
    return NU.rebuild_ainv(NU.augment(h), ridge_lambda0, weights=w)


def neural_init_state(key, cfg: UN.UtilityNetConfig, T: int, S: int,
                      ridge_lambda0, with_ainv: bool = True
                      ) -> Tuple[Dict[str, Any], jnp.ndarray]:
    """Shared neural-policy state init. One key split feeds BOTH the
    network init and the run stream — split[0] -> init, split[1] ->
    exploration/training draws (the PR-1 runner fed PRNGKey(seed) to
    both, correlating warm-slice exploration with the weight init)."""
    k_init, key = jax.random.split(key)
    params = UN.init_utilitynet(k_init, cfg)
    state = {
        "params": params,
        "opt": adamw_init(params),
        "bufs": {
            "action": jnp.zeros((T, S), jnp.int32),
            "reward": jnp.zeros((T, S), jnp.float32),
            "gate_label": jnp.zeros((T, S), jnp.float32),
            "w": jnp.zeros((T, S), jnp.float32),
            "gate_w": jnp.zeros((T, S), jnp.float32),
        },
    }
    if with_ainv:
        state["ainv"] = NU.init_ainv(cfg.ucb_feature_dim, ridge_lambda0)
    return state, key


def _neural_init(cfg: UN.UtilityNetConfig, with_ainv: bool):
    def init(key, ctx):
        T, S = ctx.env_idx.shape
        return neural_init_state(key, cfg, T, S, ctx.hyp.ridge_lambda0,
                                 with_ainv)
    return init


def _neural_update(cfg: UN.UtilityNetConfig, with_ainv: bool,
                   backend: str = "jnp"):
    """Feedback write + A^-1 maintenance shared by the neural zoo: the
    slice's outcomes land in the (T, S) buffers, then the online rank-k
    Woodbury update applies — the current slice when feedback is
    immediate, the newly-VISIBLE slice (t - delay, features recomputed
    with current params) under a delayed-feedback scenario.
    ``backend="pallas"`` routes the Woodbury step through the fused
    single-launch kernel (`kernels.nucb_update`, A^-1 VMEM-resident
    across row blocks); ``"jnp"`` is the blocked-XLA reference."""
    wood = (nucb_update if backend == "pallas"
            else lambda ainv, gs: NU.woodbury_update(ainv, gs))

    def update(state, batch, a, r, ctx, aux):
        g, mu_safe, gate_scale = aux
        t, mask = ctx.t, ctx.mask
        gate_label = (r < mu_safe - ctx.hyp.gate_margin).astype(jnp.float32)
        bufs = state["bufs"]
        bufs = {
            "action": bufs["action"].at[t].set(a),
            "reward": bufs["reward"].at[t].set(r),
            "gate_label": bufs["gate_label"].at[t].set(gate_label),
            "w": bufs["w"].at[t].set(mask),
            "gate_w": bufs["gate_w"].at[t].set(mask * gate_scale),
        }
        state = dict(state, bufs=bufs)
        if not with_ainv:
            return state
        if ctx.delay == 0:
            # padded rows are zeroed -> contribute nothing to the update
            ainv = wood(state["ainv"], g * mask[:, None])
        else:
            t_vis = t - ctx.delay
            tv = jnp.maximum(t_vis, 0)
            vid = ctx.env_idx[tv]
            _, h, _ = UN.utilitynet_apply(
                state["params"], ctx.tables["x_emb"][vid],
                ctx.tables["x_feat"][vid], ctx.tables["domain"][vid],
                bufs["action"][tv])
            vw = bufs["w"][tv] * (t_vis >= 0).astype(jnp.float32)
            ainv = wood(state["ainv"], NU.augment(h) * vw[:, None])
        return dict(state, ainv=ainv)

    return update


def _neural_train(cfg: UN.UtilityNetConfig, precision: str = "f32"):
    """Chunked replay SGD (shared UtilityNet train path). Key discipline:
    one split per chunk from the runner-carried stream — identical to
    the pre-unification scan and the host-stepped parity reference.
    ``precision`` selects the network compute dtype for the SGD steps
    (:data:`TRAIN_PRECISIONS`); f32 is the bit-exact default."""
    if precision not in TRAIN_PRECISIONS:
        raise KeyError(f"unknown train precision {precision!r}; "
                       f"known: {sorted(TRAIN_PRECISIONS)}")

    def train(state, key, ctx):
        t_vis = ctx.t - ctx.delay
        count = ctx.cum0[jnp.clip(ctx.t + 1 - ctx.delay, 0,
                                  ctx.cum0.shape[0] - 1)]
        bufs = state["bufs"]

        def chunk(carry, _):
            params, opt, key = carry
            key, kc = jax.random.split(key)
            params, opt = _train_chunk(
                params, opt, ctx.tables, ctx.env_idx, bufs, kc, ctx.cum0,
                count, ctx.hyp.lr, cfg, TRAIN_CHUNK, ctx.batch_size,
                t_vis, ctx.fcfg, ctx.delay > 0, precision)
            return (params, opt, key), None

        (params, opt, key), _ = jax.lax.scan(
            chunk, (state["params"], state["opt"], key), None,
            length=ctx.train_chunks)
        return dict(state, params=params, opt=opt), key

    return train


def _rebuild_buckets(T: int):
    """Static quarter-capacity prefix buckets for the end-of-slice
    rebuild. Only slices 0..t are ever written, so rebuilding over the
    smallest bucket covering t+1 rows skips the feature recompute for
    the untouched tail — dropped rows all carry w=0, i.e. they appended
    exact zero products to the Gram accumulation, so every bucket yields
    the same A^-1 the full-capacity pass does (the scanned-vs-stepped
    parity and golden suites pin this). Average cost over a run: ~62.5%
    of the full-capacity rebuild FLOPs."""
    return sorted({max(1, (T * m) // 4) for m in (1, 2, 3)} | {T})


def _neural_rebuild(cfg: UN.UtilityNetConfig, backend: str = "jnp"):
    def rebuild(state, ctx):
        T = ctx.env_idx.shape[0]
        row_w = None
        if ctx.delay > 0 or not ctx.fcfg.is_vanilla:
            row_w = _slice_weights(T, ctx.t, ctx.delay, ctx.fcfg)
        bufs = state["bufs"]
        buckets = _rebuild_buckets(T)

        def branch(b: int):
            def f():
                return _rebuild_impl(
                    state["params"], ctx.tables, ctx.env_idx[:b],
                    bufs["action"][:b], bufs["w"][:b], cfg,
                    ctx.hyp.ridge_lambda0,
                    None if row_w is None else row_w[:b], backend)
            return f

        if len(buckets) == 1:
            ainv = branch(buckets[0])()
        else:
            needed = jnp.clip(ctx.t + 1, 1, T)
            idx = jnp.sum(needed > jnp.asarray(buckets, jnp.int32))
            ainv = jax.lax.switch(idx, [branch(b) for b in buckets])
        return dict(state, ainv=ainv)
    return rebuild


def _neural_prepare(tables, hyp):
    return _apply_cost_lambda(tables, hyp.cost_lambda)


def _neural_pretrain(cfg: UN.UtilityNetConfig, with_ainv: bool):
    """Offline phase of the neural zoo (DESIGN.md §13.3):
    ``ctx.pretrain_steps`` AdamW steps on minibatches drawn with
    replacement from the logged corpus (utility head only — the gate
    needs an online safe-mean reference, so its loss weight is 0 and it
    stays at initialization), then one weighted A^-1 rebuild over the
    whole corpus with the pretrained features. The online scan fine-tunes
    from here; the replay ring starts empty either way."""

    def pretrain(state, key, logged, ctx):
        N = logged["reward"].shape[0]
        bs = ctx.batch_size
        zeros = jnp.zeros((bs,), jnp.float32)

        def step(carry, k):
            params, opt = carry
            i = jax.random.randint(k, (bs,), 0, N)
            batch = {
                "x_emb": logged["x_emb"][i],
                "x_feat": logged["x_feat"][i],
                "domain": logged["domain"][i],
                "action": logged["action"][i],
                "reward": logged["reward"][i],
                "gate_label": zeros,
                "w": logged["w"][i],
                "gate_w": zeros,
            }
            (_, _), grads = jax.value_and_grad(
                _weighted_loss, has_aux=True)(params, cfg, batch)
            grads, _ = clip_by_global_norm(grads, 1.0)
            params, opt = adamw_update(grads, opt, params, lr=ctx.hyp.lr,
                                       weight_decay=1e-4)
            return (params, opt), None

        key, kp = jax.random.split(key)
        (params, opt), _ = jax.lax.scan(
            step, (state["params"], state["opt"]),
            jax.random.split(kp, ctx.pretrain_steps))
        state = dict(state, params=params, opt=opt)
        if with_ainv:
            _, h, _ = UN.utilitynet_apply(
                params, logged["x_emb"], logged["x_feat"],
                logged["domain"], logged["action"])
            state["ainv"] = NU.rebuild_ainv(
                NU.augment(h), ctx.hyp.ridge_lambda0, weights=logged["w"])
        return state, key

    return pretrain


def _avail_neg(avail):
    return 0.0 if avail is None else jnp.where(avail > 0, 0.0, -jnp.inf)


# ------------------------------------------------------------ neural zoo --
@functools.lru_cache(maxsize=None)
def neuralucb_policy(cfg: UN.UtilityNetConfig, backend: str = "jnp",
                     warm_slice: bool = True,
                     precision: str = "f32") -> BanditPolicy:
    """The paper's policy (§3.3 + Algorithm 1) as a registered
    BanditPolicy — the richest member of the zoo: gated UCB decide,
    buffer + Woodbury update, chunked replay train, Cholesky rebuild.
    ``warm_slice=False`` drops the slice-0 uniform warm-up — the
    pretrained (warm-start) variant routes by the offline net + A^-1
    from the first request (DESIGN.md §13.3). ``backend="pallas"``
    swaps decide and rebuild onto the fused kernels
    (`kernels.nucb_decide` / `kernels.ainv_rebuild`); ``precision``
    selects the train-path compute dtype (:data:`TRAIN_PRECISIONS`)."""

    def decide(state, key, batch, ctx):
        hyp = ctx.hyp

        def ucb():
            return _split_aux(_decide_ucb(state["params"], state["ainv"],
                                          batch, hyp.beta, hyp.tau_g,
                                          cfg, backend, ctx.avail))

        if not warm_slice:
            return ucb()
        return jax.lax.cond(
            ctx.t == 0,
            lambda: _split_aux(_decide_warm(state["params"], batch, key,
                                            cfg, ctx.avail)),
            ucb)

    return BanditPolicy(
        "neuralucb", _neural_init(cfg, True), decide,
        _neural_update(cfg, True, backend), _neural_train(cfg, precision),
        _neural_rebuild(cfg, backend),
        _neural_prepare, pretrain=_neural_pretrain(cfg, True),
        availability_aware=True)


def _split_aux(dec):
    a, lp, g, mu_safe, gs = dec
    return a, lp, (g, mu_safe, gs)


@functools.lru_cache(maxsize=None)
def neural_ts_policy(cfg: UN.UtilityNetConfig, backend: str = "jnp",
                     warm_slice: bool = True,
                     precision: str = "f32") -> BanditPolicy:
    """NeuralTS: Thompson sampling by posterior perturbation — score
    mu + nu * sigma * z with z ~ N(0, 1) per (sample, arm) and sigma the
    same sqrt(g^T A^-1 g) bonus NeuralUCB uses (the Pallas ``ucb_score``
    kernel with mu=0, beta=1 on TPU). nu = 0 reproduces net-greedy.
    Shares the UtilityNet train path and A^-1 maintenance verbatim, so a
    NeuralUCB-vs-NeuralTS comparison isolates the exploration rule."""

    def decide(state, key, batch, ctx):
        hyp = ctx.hyp

        def explore():
            mu, h, _ = UN.utilitynet_all_actions(
                state["params"], cfg, batch["x_emb"], batch["x_feat"],
                batch["domain"])
            g_all = NU.augment(h)
            if backend == "pallas":
                # backend auto-detection (compiled on TPU, jnp ref
                # elsewhere) lives inside the op — no gate here
                sigma = ucb_score(g_all, state["ainv"],
                                  jnp.zeros_like(mu), 1.0)
            else:
                sigma = NU.ucb_bonus(state["ainv"], g_all)
            z = jax.random.normal(key, mu.shape)
            neg = _avail_neg(ctx.avail)
            a = jnp.argmax(mu + hyp.explore * sigma * z + neg,
                           axis=-1).astype(jnp.int32)
            a_safe = jnp.argmax(mu + neg, axis=-1)
            g = jnp.take_along_axis(
                g_all, a[:, None, None].astype(jnp.int32), axis=1)[:, 0]
            mu_safe = jnp.take_along_axis(mu, a_safe[:, None], axis=1)[:, 0]
            # the TS perturbation makes the exact propensity an orthant
            # integral; the declared smoothing scheme applies
            lp = _smoothed_logp(a.shape[0], cfg.num_actions, ctx.avail)
            return a, lp, (g, mu_safe, jnp.float32(1.0))

        if not warm_slice:
            return explore()
        return jax.lax.cond(
            ctx.t == 0,
            lambda: _split_aux(_decide_warm(state["params"], batch, key,
                                            cfg, ctx.avail)),
            explore)

    return BanditPolicy(
        "neural-ts", _neural_init(cfg, True), decide,
        _neural_update(cfg, True, backend), _neural_train(cfg, precision),
        _neural_rebuild(cfg, backend),
        _neural_prepare, pretrain=_neural_pretrain(cfg, True),
        availability_aware=True)


def _mean_greedy_decide(state, key, batch, ctx, cfg, pick):
    """Shared post-warm scaffold for the mean-based neural policies:
    compute mu over all arms, let ``pick(mu, neg, key, B)`` choose (and
    state its exact log-propensities), and return the chosen features +
    safe-mean reference for the gate label."""
    mu, h, _ = UN.utilitynet_all_actions(
        state["params"], cfg, batch["x_emb"], batch["x_feat"],
        batch["domain"])
    g_all = NU.augment(h)
    neg = _avail_neg(ctx.avail)
    B = batch["x_emb"].shape[0]
    a, lp = pick(mu, neg, key, B)
    a = a.astype(jnp.int32)
    a_safe = jnp.argmax(mu + neg, axis=-1)
    g = jnp.take_along_axis(
        g_all, a[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    mu_safe = jnp.take_along_axis(mu, a_safe[:, None], axis=1)[:, 0]
    return a, lp, (g, mu_safe, jnp.float32(1.0))


@functools.lru_cache(maxsize=None)
def eps_greedy_policy(cfg: UN.UtilityNetConfig, warm_slice: bool = True,
                      precision: str = "f32") -> BanditPolicy:
    """Neural ε-greedy: argmax of the UtilityNet mean with probability
    1-ε, a uniform (availability-masked) arm otherwise. ε = 0 reproduces
    net-greedy. No A^-1 — the cheapest neural explorer (no per-slice
    Cholesky rebuild), sharing the UtilityNet train path verbatim.
    Logged propensities are EXACT: ε/n_avail + (1-ε)·[a = greedy arm]."""

    def decide(state, key, batch, ctx):
        def pick(mu, neg, key, B):
            k_r, k_b = jax.random.split(key)
            a_rand = _masked_uniform(k_r, B, cfg.num_actions, ctx.avail)
            flip = jax.random.uniform(k_b, (B,)) < ctx.hyp.explore
            a_greedy = jnp.argmax(mu + neg, axis=-1)
            a = jnp.where(flip, a_rand, a_greedy)
            nav = _n_avail(cfg.num_actions, ctx.avail)
            p = ctx.hyp.explore / nav \
                + (1.0 - ctx.hyp.explore) * (a == a_greedy)
            return a, jnp.log(jnp.maximum(p, 1e-12))

        def explore():
            return _mean_greedy_decide(state, key, batch, ctx, cfg, pick)

        if not warm_slice:
            return explore()
        return jax.lax.cond(
            ctx.t == 0,
            lambda: _split_aux(_decide_warm(state["params"], batch, key,
                                            cfg, ctx.avail)),
            explore)

    return BanditPolicy(
        "eps-greedy", _neural_init(cfg, False), decide,
        _neural_update(cfg, False), _neural_train(cfg, precision),
        prepare=_neural_prepare, pretrain=_neural_pretrain(cfg, False),
        availability_aware=True)


@functools.lru_cache(maxsize=None)
def boltzmann_policy(cfg: UN.UtilityNetConfig, warm_slice: bool = True,
                     precision: str = "f32") -> BanditPolicy:
    """Neural Boltzmann / softmax-temperature exploration: sample arm a
    with probability softmax(mu / temperature). Temperature -> 0
    approaches net-greedy. No A^-1; shares the UtilityNet train path.
    Logged propensities are EXACT: log_softmax of the sampled arm."""

    def decide(state, key, batch, ctx):
        def pick(mu, neg, key, B):
            logits = mu / jnp.maximum(ctx.hyp.explore, 1e-6) + neg
            a = jax.random.categorical(key, logits, axis=-1)
            lp = jnp.take_along_axis(
                jax.nn.log_softmax(logits, axis=-1), a[:, None],
                axis=1)[:, 0]
            return a, lp

        def explore():
            return _mean_greedy_decide(state, key, batch, ctx, cfg, pick)

        if not warm_slice:
            return explore()
        return jax.lax.cond(
            ctx.t == 0,
            lambda: _split_aux(_decide_warm(state["params"], batch, key,
                                            cfg, ctx.avail)),
            explore)

    return BanditPolicy(
        "boltzmann", _neural_init(cfg, False), decide,
        _neural_update(cfg, False), _neural_train(cfg, precision),
        prepare=_neural_prepare, pretrain=_neural_pretrain(cfg, False),
        availability_aware=True)


# ------------------------------------------- supervised router family --
@functools.lru_cache(maxsize=None)
def sup_winrate_policy() -> BanditPolicy:
    """Win-rate classifier router (DESIGN.md §13.3): a per-arm ridge
    regression of realized reward on the LinUCB featurization, fitted
    PURELY OFFLINE by :func:`_ridge_pretrain` and frozen — decide is the
    argmax of the predicted win rate with no exploration bonus and no
    online updates. The "what would a supervised router do with the same
    log" baseline the bandits have to beat."""

    def init(key, ctx):
        K = ctx.tables["reward"].shape[1]
        D = ctx.tables["x_emb"].shape[1] + 1
        eye = jnp.eye(D, dtype=jnp.float32) / ctx.hyp.ridge
        return {"ainv": jnp.repeat(eye[None], K, axis=0),
                "b": jnp.zeros((K, D), jnp.float32)}, key

    def decide(state, key, batch, ctx):
        g = _lin_features(batch["x_emb"])
        theta = jnp.einsum("kij,kj->ki", state["ainv"], state["b"])
        mu = g @ theta.T + _avail_neg(ctx.avail)
        a = jnp.argmax(mu, axis=-1).astype(jnp.int32)
        return a, _zero_logp(a.shape[0]), None

    def update(state, batch, a, r, ctx, aux):
        return state

    return BanditPolicy("sup-winrate", init, decide, update,
                        pretrain=_ridge_pretrain(),
                        availability_aware=True)


@functools.lru_cache(maxsize=None)
def sup_mf_policy(n_domains: int, num_actions: int,
                  rank: int = 16) -> BanditPolicy:
    """Matrix-factorization router: rewards factorize as
    <U_domain, V_arm> + arm bias, fitted purely offline by AdamW on the
    logged corpus and frozen online. Domain-level — requests from one
    RouterBench domain share a row of U — the collaborative-filtering
    counterpart of the per-request win-rate classifier."""

    def init(key, ctx):
        key, kp = jax.random.split(key)
        ku, kv = jax.random.split(kp)
        params = {
            "U": 0.1 * jax.random.normal(ku, (n_domains, rank),
                                         jnp.float32),
            "V": 0.1 * jax.random.normal(kv, (num_actions, rank),
                                         jnp.float32),
            "ba": jnp.zeros((num_actions,), jnp.float32),
        }
        return {"params": params, "opt": adamw_init(params)}, key

    def decide(state, key, batch, ctx):
        p = state["params"]
        mu = p["U"][batch["domain"]] @ p["V"].T + p["ba"]
        mu = mu + _avail_neg(ctx.avail)
        a = jnp.argmax(mu, axis=-1).astype(jnp.int32)
        return a, _zero_logp(a.shape[0]), None

    def update(state, batch, a, r, ctx, aux):
        return state

    def pretrain(state, key, logged, ctx):
        N = logged["reward"].shape[0]
        bs = ctx.batch_size

        def loss(params, i):
            dom = logged["domain"][i]
            act = logged["action"][i]
            pred = ((params["U"][dom] * params["V"][act]).sum(-1)
                    + params["ba"][act])
            w = logged["w"][i]
            mse = (w * (pred - logged["reward"][i]) ** 2).sum() \
                / jnp.maximum(w.sum(), 1.0)
            reg = ctx.hyp.reg * (jnp.mean(params["U"] ** 2)
                                 + jnp.mean(params["V"] ** 2))
            return mse + reg

        def step(carry, k):
            params, opt = carry
            i = jax.random.randint(k, (bs,), 0, N)
            grads = jax.grad(loss)(params, i)
            grads, _ = clip_by_global_norm(grads, 1.0)
            params, opt = adamw_update(grads, opt, params, lr=ctx.hyp.lr)
            return (params, opt), None

        key, kp = jax.random.split(key)
        (params, opt), _ = jax.lax.scan(
            step, (state["params"], state["opt"]),
            jax.random.split(kp, ctx.pretrain_steps))
        return {"params": params, "opt": opt}, key

    return BanditPolicy("sup-mf", init, decide, update, pretrain=pretrain,
                        availability_aware=True)


# --------------------------------------------------------------- registry --
POLICIES: Dict[str, Callable] = {}


def register_policy(name: str):
    """Register ``builder(env, cfg, **kw) -> (BanditPolicy, hypers)``
    under ``name`` (see :func:`make_policy`)."""
    def deco(fn):
        POLICIES[name] = fn
        return fn
    return deco


def _f(v) -> jnp.ndarray:
    return jnp.float32(v)


def make_policy(name: str, env=None, cfg: Optional[UN.UtilityNetConfig]
                = None, **kw) -> Tuple[BanditPolicy, Any]:
    """Build a registered policy plus its default scalar hypers pytree.

    ``env`` (a DeviceReplayEnv) supplies arm statistics for the fixed-arm
    baselines; ``cfg`` is required by the neural policies. Keyword
    overrides reach the builder (e.g. ``explore=0.2``, ``beta=0.5``,
    ``ucb_backend="pallas"``). The hypers pytree is what
    ``run_policy_sweep`` broadcasts over (G,) grid leaves."""
    if name not in POLICIES:
        raise KeyError(f"unknown policy {name!r}; registered: "
                       f"{sorted(POLICIES)}")
    return POLICIES[name](env, cfg, **kw)


# Builders accept the cross-cutting ``ucb_backend`` even when they don't
# score with A^-1 (so one override dict can serve a whole zoo), but no
# blanket **kw: a misspelled hyper override must raise, not silently run
# with defaults.
@register_policy("random")
def _b_random(env, cfg, ucb_backend: str = "jnp"):
    return as_bandit_policy(random_policy(env.K)), ()


@register_policy("min_cost")
def _b_min_cost(env, cfg, ucb_backend: str = "jnp"):
    return as_bandit_policy(
        fixed_policy(env.min_cost_action(), "min-cost")), ()


@register_policy("max_quality")
def _b_max_quality(env, cfg, ucb_backend: str = "jnp"):
    return as_bandit_policy(
        fixed_policy(env.max_quality_action(), "max-quality")), ()


@register_policy("greedy")
def _b_greedy(env, cfg, ucb_backend: str = "jnp"):
    return as_bandit_policy(greedy_policy(env.K)), ()


@register_policy("dyn_min_cost")
def _b_dyn_min_cost(env, cfg, ucb_backend: str = "jnp"):
    return dyn_min_cost_policy(), ()


@register_policy("linucb")
def _b_linucb(env, cfg, alpha: float = 1.0, ridge: float = 1.0,
              ucb_backend: str = "jnp"):
    return linucb_policy(), LinUCBHypers(alpha=_f(alpha), ridge=_f(ridge))


def _neural_hypers(explore, gate_margin=0.05, lr=1e-3, ridge_lambda0=1.0,
                   cost_lambda=None) -> NeuralPolicyHypers:
    return NeuralPolicyHypers(
        explore=_f(explore), gate_margin=_f(gate_margin), lr=_f(lr),
        ridge_lambda0=_f(ridge_lambda0),
        cost_lambda=_f(-1.0 if cost_lambda is None else cost_lambda))


@register_policy("neuralucb")
def _b_neuralucb(env, cfg, beta: float = 1.0, tau_g: float = 0.5,
                 gate_margin: float = 0.05, lr: float = 1e-3,
                 ridge_lambda0: float = 1.0, cost_lambda=None,
                 ucb_backend: str = "jnp", warm_slice: bool = True,
                 train_precision: str = "f32"):
    hyp = NeuralUCBHypers(
        beta=_f(beta), tau_g=_f(tau_g), gate_margin=_f(gate_margin),
        lr=_f(lr), ridge_lambda0=_f(ridge_lambda0),
        cost_lambda=_f(-1.0 if cost_lambda is None else cost_lambda))
    return neuralucb_policy(cfg, ucb_backend, warm_slice,
                            train_precision), hyp


@register_policy("neural_ts")
def _b_neural_ts(env, cfg, explore: float = 1.0,
                 ucb_backend: str = "jnp", warm_slice: bool = True,
                 train_precision: str = "f32", **kw):
    return (neural_ts_policy(cfg, ucb_backend, warm_slice,
                             train_precision),
            _neural_hypers(explore, **kw))


@register_policy("eps_greedy")
def _b_eps_greedy(env, cfg, explore: float = 0.1,
                  ucb_backend: str = "jnp", warm_slice: bool = True,
                  train_precision: str = "f32", **kw):
    return (eps_greedy_policy(cfg, warm_slice, train_precision),
            _neural_hypers(explore, **kw))


@register_policy("boltzmann")
def _b_boltzmann(env, cfg, explore: float = 0.05,
                 ucb_backend: str = "jnp", warm_slice: bool = True,
                 train_precision: str = "f32", **kw):
    return (boltzmann_policy(cfg, warm_slice, train_precision),
            _neural_hypers(explore, **kw))


@register_policy("sup_winrate")
def _b_sup_winrate(env, cfg, ridge: float = 1.0, ucb_backend: str = "jnp"):
    return sup_winrate_policy(), SupervisedHypers(ridge=_f(ridge))


@register_policy("sup_mf")
def _b_sup_mf(env, cfg, rank: int = 16, lr: float = 5e-2,
              reg: float = 1e-4, ucb_backend: str = "jnp"):
    n_dom = int(jnp.max(env.domain)) + 1
    return (sup_mf_policy(n_dom, env.K, rank),
            MFHypers(lr=_f(lr), reg=_f(reg)))
